"""Gaussian mixture model via enumeration (Pyro's GMM tutorial, ported).

The discrete assignment `z` is never sampled during training: it is
annotated for parallel enumeration and `TraceEnum_ELBO` marginalizes it
exactly inside the compiled SVI step (no REINFORCE variance). The guide is
an `AutoNormal` over the continuous latents only — autoguides skip
enumerated sites automatically. After training, `infer_discrete` decodes
the exact MAP cluster assignment for every point under the learned
parameters.

Expected output for the default seed: the two learned locs land within
~0.1 of the true (-2.0, 3.0), the mixture weight lands near the empirical
cluster fraction (~0.31 for seed 0), and the decoded assignments achieve
>98% accuracy against the generating labels.

Run:  PYTHONPATH=src python examples/gmm.py [--steps 400]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import distributions as dist, optim
from repro.core import handlers, primitives as P
from repro.infer import SVI, AutoNormal, TraceEnum_ELBO, config, infer_discrete

K = 2
TRUE_LOCS = np.array([-2.0, 3.0])
TRUE_SCALE = 0.7
TRUE_WEIGHT = 0.375  # P(z = 1)


def make_data(n=300, seed=0):
    rng = np.random.default_rng(seed)
    labels = (rng.uniform(size=n) < TRUE_WEIGHT).astype(int)
    points = rng.normal(TRUE_LOCS[labels], TRUE_SCALE).astype(np.float32)
    return jnp.asarray(points), labels


@config(enumerate=True)
def model(data):
    weight = P.sample("weight", dist.Beta(1.0, 1.0))
    with P.plate("components", K):
        locs = P.sample("locs", dist.Normal(0.0, 10.0))
    scale = P.sample("scale", dist.LogNormal(0.0, 2.0))
    with P.plate("N", data.shape[0]):
        z = P.sample("z", dist.Categorical(jnp.stack([1 - weight, weight])))
        P.sample("obs", dist.Normal(locs[z], scale), obs=data)


def main(argv=None):
    parser = argparse.ArgumentParser(description="enumerated GMM with SVI")
    parser.add_argument("--steps", type=int, default=400)
    parser.add_argument("--num-points", type=int, default=300)
    parser.add_argument("--seed", type=int, default=0)
    args = parser.parse_args(argv)

    data, labels = make_data(args.num_points, args.seed)

    def init_loc(name, value, unconstrained):
        # break the mixture symmetry: start the component locs at the data
        # extremes (the classic GMM failure mode is a collapsed symmetric init)
        if name == "locs":
            return jnp.asarray([data.min(), data.max()])
        return unconstrained

    guide = AutoNormal(model, init_loc_fn=init_loc)  # skips the enumerated "z"
    elbo = TraceEnum_ELBO(num_particles=2)
    svi = SVI(model, guide, optim.Adam(0.05), elbo)

    state = svi.init(jax.random.PRNGKey(args.seed), data)
    t0 = time.time()
    for step in range(args.steps):
        state, loss = svi.update_jit(state, data)
        if step % 100 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  elbo loss {float(loss):10.2f}")
    print(f"{args.steps} steps in {time.time() - t0:.1f}s "
          f"(compiled once: num_traces={elbo.num_traces})")

    params = svi.get_params(state)
    locs = np.asarray(params["auto_locs_loc"])
    weight = float(jax.nn.sigmoid(params["auto_weight_loc"]))
    order = np.argsort(locs)
    print(f"learned locs   {locs[order]}  (true {TRUE_LOCS})")
    print(f"learned weight {weight if order[1] == 1 else 1 - weight:.3f}  "
          f"(true {TRUE_WEIGHT})")

    # decode MAP assignments under the learned continuous posterior means
    posterior_means = {
        "weight": jnp.asarray(weight),
        "locs": jnp.asarray(locs),
        "scale": jnp.exp(params["auto_scale_loc"]),
    }
    decoded = infer_discrete(
        handlers.substitute(model, data=posterior_means),
        temperature=0,
        rng_key=jax.random.PRNGKey(1),
    )
    tr = handlers.trace(handlers.seed(decoded, jax.random.PRNGKey(2))).get_trace(data)
    assignments = np.asarray(tr["z"]["value"])
    # align cluster ids with the generating labels before scoring
    if order[1] != 1:
        assignments = 1 - assignments
    accuracy = float((assignments == labels).mean())
    print(f"MAP assignment accuracy vs generating labels: {accuracy:.3f}")
    return accuracy


if __name__ == "__main__":
    main()
