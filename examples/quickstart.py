"""Quickstart: the paper's Figure-1 example — a VAE trained with SVI.

    model:  z ~ N(0, I);  x ~ Bernoulli(decoder(z))        (generative)
    guide:  z ~ N(encoder_loc(x), encoder_scale(x))        (amortized posterior)

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 500]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import SVI, Trace_ELBO
from repro import optim

LATENT, HIDDEN, OBS = 8, 64, 196  # 14x14 synthetic digits


def mlp_init(key, sizes):
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, k2, key = jax.random.split(key, 3)
        params[f"w{i}"] = jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5
        params[f"b{i}"] = jnp.zeros(b)
    return params


def mlp_apply(params, x, n, final=None):
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.softplus(x)
    return x if final is None else final(x)


def model(batch):
    """p(x, z) — the decoder is registered via `module` (pyro.module)."""
    dec = P.module("decoder", mlp_init(jax.random.PRNGKey(1), [LATENT, HIDDEN, OBS]))
    B = batch.shape[0]
    with P.plate("data", B, dim=-1):
        z = P.sample("z", dist.Normal(jnp.zeros((B, LATENT)), 1.0).to_event(1))
        probs = mlp_apply(dec, z, 2, jax.nn.sigmoid)
        P.sample("x", dist.Bernoulli(probs=probs).to_event(1), obs=batch)


def guide(batch):
    """q(z | x) — amortized encoder."""
    enc = P.module("encoder", mlp_init(jax.random.PRNGKey(2), [OBS, HIDDEN, 2 * LATENT]))
    B = batch.shape[0]
    h = mlp_apply(enc, batch, 2)
    loc, log_scale = h[:, :LATENT], h[:, LATENT:]
    with P.plate("data", B, dim=-1):
        P.sample("z", dist.Normal(loc, jnp.exp(0.5 * log_scale)).to_event(1))


def synthetic_digits(key, n):
    """Blobby binary images with latent structure (stands in for MNIST)."""
    k1, k2 = jax.random.split(key)
    centers = jax.random.uniform(k1, (n, 2), minval=3, maxval=11)
    yy, xx = jnp.mgrid[0:14, 0:14]
    d2 = (xx[None] - centers[:, 0, None, None]) ** 2 + (yy[None] - centers[:, 1, None, None]) ** 2
    probs = jnp.exp(-d2 / 8.0)
    return (jax.random.uniform(k2, (n, 14, 14)) < probs).astype(jnp.float32).reshape(n, OBS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args()

    data = synthetic_digits(jax.random.PRNGKey(0), 4096)
    svi = SVI(model, guide, optim.Adam(1e-3), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(3), data[: args.batch])

    step = svi.update_jit  # compile-once jitted update

    t0, losses = time.time(), []
    for i in range(args.steps):
        idx = jax.random.choice(jax.random.fold_in(jax.random.PRNGKey(4), i),
                                data.shape[0], (args.batch,), replace=False)
        state, loss = step(state, data[idx])
        losses.append(float(loss))
        if i % 100 == 0:
            print(f"step {i:4d}  -ELBO/example {loss / args.batch:8.4f}")
    print(f"final -ELBO/example {losses[-1]/args.batch:.4f} "
          f"(start {losses[0]/args.batch:.4f}) in {time.time()-t0:.1f}s")
    assert losses[-1] < losses[0] * 0.8, "VAE did not converge"
    print("OK")


if __name__ == "__main__":
    main()
