"""Deep Markov Model (Krishnan et al. 2017) — the paper's Figure-4
experiment, reproduced on synthetic JSB-chorales-like polyphonic data.

model:  z_t ~ N(gated_transition(z_{t-1}));  x_t ~ Bernoulli(emitter(z_t))
guide:  backward GRU over x; q(z_t | z_{t-1}, h_t) = N(combiner(...)),
        optionally pushed through `--iaf N` autoregressive flows (the
        paper's extension: "improving the results with a few lines of code").

Run:  PYTHONPATH=src python examples/dmm.py --steps 300 --iaf 0
      PYTHONPATH=src python examples/dmm.py --steps 300 --iaf 2
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.distributions.transforms import (
    InverseAutoregressiveTransform,
    init_made_params,
    made_masks,
)
from repro.infer import SVI, Trace_ELBO
from repro import optim

Z, X, H, RNN_H = 16, 88, 32, 32  # latent, emission (piano roll), hidden dims


# --------------------------- parameter helpers ----------------------------


def dense_init(key, a, b):
    return {"w": jax.random.normal(key, (a, b)) * (1.0 / a) ** 0.5, "b": jnp.zeros(b)}


def dense(p, x):
    return x @ p["w"] + p["b"]


def dmm_params(key):
    ks = jax.random.split(key, 12)
    return {
        # gated transition p(z_t | z_{t-1})
        "trans_gate1": dense_init(ks[0], Z, H), "trans_gate2": dense_init(ks[1], H, Z),
        "trans_prop1": dense_init(ks[2], Z, H), "trans_prop2": dense_init(ks[3], H, Z),
        "trans_loc": dense_init(ks[4], Z, Z),
        "trans_scale": dense_init(ks[5], Z, Z),
        # emitter p(x_t | z_t)
        "emit1": dense_init(ks[6], Z, H), "emit2": dense_init(ks[7], H, H),
        "emit3": dense_init(ks[8], H, X),
        "z0": jnp.zeros(Z),
    }


def guide_params(key):
    ks = jax.random.split(key, 8)
    return {
        # backward GRU
        "gru_rz": dense_init(ks[0], X + RNN_H, 2 * RNN_H),
        "gru_h": dense_init(ks[1], X + RNN_H, RNN_H),
        # combiner q(z_t | z_{t-1}, h_t)
        "comb_z": dense_init(ks[2], Z, RNN_H),
        "comb_loc": dense_init(ks[3], RNN_H, Z),
        "comb_scale": dense_init(ks[4], RNN_H, Z),
        "h0": jnp.zeros(RNN_H),
        "zq0": jnp.zeros(Z),
    }


# ------------------------------- model ------------------------------------


def gated_transition(p, z):
    gate = jax.nn.sigmoid(dense(p["trans_gate2"], jax.nn.relu(dense(p["trans_gate1"], z))))
    prop = dense(p["trans_prop2"], jax.nn.relu(dense(p["trans_prop1"], z)))
    loc = (1 - gate) * dense(p["trans_loc"], z) + gate * prop
    scale = jax.nn.softplus(dense(p["trans_scale"], jax.nn.relu(prop))) + 1e-3
    return loc, scale


def emitter(p, z):
    h = jax.nn.relu(dense(p["emit1"], z))
    h = jax.nn.relu(dense(p["emit2"], h))
    return dense(p["emit3"], h)  # logits


def model(batch, mask):
    """batch: (B, T, X) binary; mask: (B, T) validity."""
    p = P.module("dmm", dmm_params(jax.random.PRNGKey(11)))
    B, T, _ = batch.shape
    z = jnp.broadcast_to(p["z0"], (B, Z))
    with P.plate("data", B, dim=-1):
        for t in range(T):
            loc, scale = gated_transition(p, z)
            from repro.core.handlers import mask as mask_h

            with mask_h(mask=mask[:, t]):
                z = P.sample(f"z_{t}", dist.Normal(loc, scale).to_event(1))
                P.sample(
                    f"x_{t}",
                    dist.Bernoulli(logits=emitter(p, z)).to_event(1),
                    obs=batch[:, t],
                )


# ------------------------------- guide ------------------------------------


def gru_step(p, h, x):
    inp = jnp.concatenate([x, h], -1)
    rz = jax.nn.sigmoid(dense(p["gru_rz"], inp))
    r, zg = rz[..., :RNN_H], rz[..., RNN_H:]
    hh = jnp.tanh(dense(p["gru_h"], jnp.concatenate([x, r * h], -1)))
    return (1 - zg) * h + zg * hh


def make_guide(num_iaf: int):
    masks = made_masks(Z, [2 * Z]) if num_iaf else None

    def guide(batch, mask):
        p = P.module("dmm_guide", guide_params(jax.random.PRNGKey(12)))
        iafs = []
        for i in range(num_iaf):
            made = {
                k: P.param(f"iaf{i}_{k}", v)
                for k, v in init_made_params(jax.random.PRNGKey(100 + i), Z, [2 * Z]).items()
            }
            iafs.append(InverseAutoregressiveTransform(made, masks))
        B, T, _ = batch.shape
        # backward RNN over the observations
        h = jnp.broadcast_to(p["h0"], (B, RNN_H))
        hs = []
        for t in range(T - 1, -1, -1):
            h = gru_step(p, h, batch[:, t])
            hs.append(h)
        hs = hs[::-1]
        z = jnp.broadcast_to(p["zq0"], (B, Z))
        from repro.core.handlers import mask as mask_h

        with P.plate("data", B, dim=-1):
            for t in range(T):
                hc = 0.5 * (jnp.tanh(dense(p["comb_z"], z)) + hs[t])
                loc = dense(p["comb_loc"], hc)
                scale = jax.nn.softplus(dense(p["comb_scale"], hc)) + 1e-3
                base = dist.Normal(loc, scale).to_event(1)
                q = dist.TransformedDistribution(base, list(iafs)) if iafs else base
                with mask_h(mask=mask[:, t]):
                    z = P.sample(f"z_{t}", q)

    return guide


# ------------------------------- data -------------------------------------


def synthetic_chorales(key, n, T=24):
    """Markov chord progressions on an 88-key roll (JSB-like structure)."""
    k1, k2, k3 = jax.random.split(key, 3)
    n_chords = 12
    roots = jax.random.randint(k1, (n_chords,), 30, 70)
    chords = jnp.stack([
        jnp.clip(jnp.stack([r, r + 4, r + 7, r + 12]), 0, 87) for r in roots
    ])  # (12, 4)
    trans = jax.nn.softmax(3.0 * jax.random.normal(k2, (n_chords, n_chords)), -1)

    def one(key):
        def step(c, k):
            c2 = jax.random.choice(k, n_chords, p=trans[c])
            return c2, c2
        ks = jax.random.split(key, T)
        c0 = jax.random.randint(ks[0], (), 0, n_chords)
        _, cs = jax.lax.scan(step, c0, ks)
        roll = jnp.zeros((T, X)).at[jnp.arange(T)[:, None], chords[cs]].set(1.0)
        return roll

    rolls = jax.vmap(one)(jax.random.split(k3, n))
    lengths = jnp.full((n,), T)
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    return rolls, mask


def run(num_iaf: int, steps: int, batch: int = 32, seed: int = 0, log=print):
    data, mask = synthetic_chorales(jax.random.PRNGKey(seed), 512)
    guide = make_guide(num_iaf)
    svi = SVI(model, guide, optim.Adam(3e-3, clip_norm=10.0), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(seed + 1), data[:batch], mask[:batch])
    step_fn = svi.update_jit  # compile-once jitted update
    t0 = time.time()
    last = None
    n_obs = float(mask[:batch].sum() * X)
    for i in range(steps):
        idx = jax.random.choice(jax.random.fold_in(jax.random.PRNGKey(seed + 2), i),
                                data.shape[0], (batch,), replace=False)
        state, loss = step_fn(state, data[idx], mask[idx])
        last = float(loss)
        if i % 50 == 0:
            log(f"  step {i:4d}  -ELBO/frame {last/n_obs*X:10.4f}")
    # held-out ELBO (last 128 sequences)
    heldout = float(svi.evaluate(state, data[-128:], mask[-128:]))
    n_h = float(mask[-128:].sum() * X)
    elbo_frame = -heldout / n_h * X
    log(f"  heldout ELBO/frame {elbo_frame:.4f}  ({time.time()-t0:.1f}s)")
    return elbo_frame


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--iaf", type=int, default=0)
    args = ap.parse_args()
    print(f"DMM with {args.iaf} IAF flows:")
    run(args.iaf, args.steps)


if __name__ == "__main__":
    main()
