"""End-to-end driver: train a ~135M-parameter LM (smollm-135m, the full
assigned config) for a few hundred steps on the synthetic pipeline, with
checkpoints, auto-resume, and the step watchdog — the paper's SVI machinery
as the training loop of a production LM.

By default uses a width-reduced variant so a few hundred steps finish on
CPU in minutes; pass --full for the exact 135M config (slow on CPU).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--config", choices=["full", "mid", "smoke"], default="mid",
                    help="mid (~25M, CPU-minutes) by default; full = exact 135M")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    argv = [
        "--arch", "smollm-135m",
        "--config", args.config,
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--resume", "auto",
        "--lr", "1e-3",
    ]
    return train_mod.main(argv)


if __name__ == "__main__":
    sys.exit(main())
