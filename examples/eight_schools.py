"""Eight schools (Rubin 1981; Gelman et al., BDA) — the canonical NUTS
benchmark: a hierarchical meta-analysis of coaching effects in J=8 schools.

We use the non-centered parameterization (theta = mu + tau * theta_std),
which removes the funnel geometry that makes the centered version produce
divergences, and run 4 NUTS chains with the multi-chain MCMC engine —
warmup + collection compile to a single XLA call, and all chains step
together through the fused batched driver (`REPRO_MCMC_FUSED=0` falls back
to the per-chain vmap sampler; add `mesh="auto"` to spread
chains across devices).

Expected diagnostics for this setup (4 chains x 500 draws, seed 0, fused
driver; exact values vary slightly by platform/backend):

* r_hat in [0.99, 1.03] for every site — the chains mix well;
* bulk n_eff of mu and tau of order 400-1000 (a decent fraction of the
  2000 collected draws; mu/tau mix slowest since they control the funnel;
  the theta_std sites sit in the 600-1000 range);
* divergences around 0.1% of draws or fewer (the centered
  parameterization, by contrast, typically diverges an order of magnitude
  more often at target_accept=0.8);
* posterior mu ~ 4.4 +/- 3.5, tau median ~ 2.9 (heavy right tail).

Run:  PYTHONPATH=src python examples/eight_schools.py [--chains 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import MCMC, NUTS

J = 8
Y = jnp.asarray([28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0])
SIGMA = jnp.asarray([15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0])


def eight_schools(y, sigma):
    mu = P.sample("mu", dist.Normal(0.0, 5.0))
    tau = P.sample("tau", dist.HalfCauchy(5.0))
    with P.plate("J", J):
        theta_std = P.sample("theta_std", dist.Normal(0.0, 1.0))
        theta = P.deterministic("theta", mu + tau * theta_std)
        P.sample("obs", dist.Normal(theta, sigma), obs=y)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--chains", type=int, default=4)
    ap.add_argument("--warmup", type=int, default=500)
    ap.add_argument("--samples", type=int, default=500)
    ap.add_argument("--sharded", action="store_true",
                    help="shard chains across devices via the mesh rules")
    args = ap.parse_args(argv)

    kernel = NUTS(eight_schools, max_tree_depth=8)
    mcmc = MCMC(
        kernel,
        num_warmup=args.warmup,
        num_samples=args.samples,
        num_chains=args.chains,
        mesh="auto" if args.sharded else None,
    )
    t0 = time.time()
    mcmc.run(jax.random.PRNGKey(0), Y, SIGMA)
    dt = time.time() - t0

    total = args.chains * args.samples
    print(f"{args.chains} chains x {args.samples} draws in {dt:.1f}s "
          f"({total / dt:.0f} draws/s, {mcmc.num_traces} compiled call)\n")
    stats = mcmc.summary()  # prints the table, returns the stats dict

    n_div = int(mcmc.get_extra_fields()["diverging"].sum())
    worst_rhat = max(float(jnp.max(s["r_hat"])) for s in stats.values())
    print(f"\nworst r_hat: {worst_rhat:.3f} (expect < 1.05)")
    assert n_div < 0.02 * total, f"too many divergences: {n_div}"
    assert worst_rhat < 1.1, f"chains did not converge: r_hat={worst_rhat:.3f}"


if __name__ == "__main__":
    main()
