"""Multi-chain MCMC microbench (acceptance criterion for the engine PR).

Demonstrates that the scan-based driver compiles the whole run — chain init,
warmup with windowed mass-matrix re-estimation, and collection — into a
single XLA call: `MCMC.num_traces` stays at 1 per run *regardless of
num_samples* (no per-draw retracing, no per-draw host round-trip), and
measures draws/sec as the chain count grows (vectorized chains are nearly
free until the machine runs out of parallelism). Also asserts
`mesh="auto"` (sharded chains) is bit-identical to `mesh=None` (local vmap)
on the default mesh when it degenerates to one device.

Run: PYTHONPATH=src python benchmarks/mcmc_chains.py [--smoke]
(--smoke: CI-sized run — shorter warmup/collection, same retrace assertions)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import HMC, MCMC

N = 64


def model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    scale = P.sample("scale", dist.LogNormal(0.0, 1.0))
    with P.plate("N", data.shape[0]):
        P.sample("obs", dist.Normal(loc, scale), obs=data)


def make_kernel():
    return HMC(model, max_num_steps=32)


def main(num_warmup: int = 200, smoke: bool = False, log=print):
    data = 1.5 + 0.7 * jax.random.normal(jax.random.PRNGKey(0), (N,))
    sample_counts = (50, 100) if smoke else (100, 400)
    chain_counts = (1, 2) if smoke else (1, 2, 4, 8)

    # -- 1. constant compiled-call count, independent of num_samples --------
    log("# trace count vs num_samples (must stay 1: scan-based collection)")
    for num_samples in sample_counts:
        mcmc = MCMC(make_kernel(), num_warmup, num_samples, num_chains=4)
        mcmc.run(jax.random.PRNGKey(1), data)
        log(f"  num_samples={num_samples:>4}  traces={mcmc.num_traces}")
        assert mcmc.num_traces == 1, (
            f"per-draw retracing detected: {mcmc.num_traces} traces "
            f"for num_samples={num_samples}"
        )
    # a second run (fresh key, same shapes) must reuse the executable: model
    # data rides the traced signature, so nothing retraces
    mcmc.run(jax.random.PRNGKey(99), data)
    log(f"  re-run same shapes     traces={mcmc.num_traces}")
    assert mcmc.num_traces == 1, "second run retraced the driver"

    # -- 2. draws/sec vs chain count ----------------------------------------
    num_samples = 100 if smoke else 500
    log(f"\n# draws/sec vs num_chains ({jax.device_count()} device(s), "
        f"{num_warmup} warmup + {num_samples} samples)")
    log(f"{'chains':>7} {'total_s':>9} {'draws/s':>10}")
    for num_chains in chain_counts:
        mcmc = MCMC(make_kernel(), num_warmup, num_samples, num_chains=num_chains)
        t0 = time.perf_counter()
        samples = mcmc.run(jax.random.PRNGKey(2), data)
        jax.block_until_ready(samples)
        dt = time.perf_counter() - t0
        log(f"{num_chains:>7} {dt:9.3f} {num_chains * num_samples / dt:10.1f}")
        assert mcmc.num_traces == 1

    # -- 3. sharded == vectorized parity ------------------------------------
    out = {}
    for method in ("vectorized", "sharded"):
        mcmc = MCMC(make_kernel(), num_warmup, 50 if smoke else 200, num_chains=4,
                    mesh=None if method == "vectorized" else "auto")
        mcmc.run(jax.random.PRNGKey(3), data)
        out[method] = mcmc.get_samples(group_by_chain=True)
    if jax.device_count() == 1:
        same = all(
            bool(jnp.array_equal(out["vectorized"][k], out["sharded"][k]))
            for k in out["vectorized"]
        )
        assert same, "sharded chains diverged from vectorized on a 1-device mesh"
        log("\nOK: sharded == vectorized bit-for-bit (1-device mesh)")
    log("OK: constant compiled-call count; no per-draw retracing")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args()
    main(num_warmup=50 if args.smoke else 200, smoke=args.smoke)
