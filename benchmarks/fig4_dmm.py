"""Figure-4 analogue: DMM test ELBO with 0/1/2 IAF flows in the guide.

The paper's point: Pyro reproduces the DMM exactly and then improves it
"with a few lines of code" by adding IAF flows to the guide. We train the
DMM (examples/dmm.py) on synthetic chorales with 0/1/2 flows and report
held-out ELBO per frame (higher = better, as in Fig 4)."""
from __future__ import annotations

import importlib.util
from pathlib import Path

# load the example by file path (cwd-independent, no sys.path mutation)
_spec = importlib.util.spec_from_file_location(
    "dmm", Path(__file__).resolve().parent.parent / "examples" / "dmm.py"
)
_dmm = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_dmm)
dmm_run = _dmm.run


def main(steps: int = 250, log=print):
    log("# Fig-4 analogue: DMM heldout ELBO/frame vs number of IAF flows")
    rows = []
    for n_iaf in (0, 1, 2):
        log(f"DMM + {n_iaf} IAF:")
        elbo = dmm_run(n_iaf, steps, log=lambda s: None)
        log(f"  heldout ELBO/frame = {elbo:.4f}")
        rows.append({"iaf": n_iaf, "heldout_elbo_frame": elbo})
    return rows


if __name__ == "__main__":
    main()
