"""Assemble the §Roofline table from the dry-run JSON dumps.

Reads dryrun_single.json (+ dryrun_multi.json if present) produced by
`python -m repro.launch.dryrun --all --out ...` and prints the per-cell
three-term roofline with bottleneck + useful-flops ratio."""
from __future__ import annotations

import json
import os


def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def fmt_row(r):
    rf = r.get("roofline")
    if not rf:
        return f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR {r.get('error','')[:40]} |"
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
        f"{rf['t_compute']*1e3:9.1f} | {rf['t_memory']*1e3:9.1f} | "
        f"{rf['t_collective']*1e3:9.1f} | {rf['bottleneck']:>10} | "
        f"{rf['useful_flops_ratio']:5.2f} | {rf['roofline_fraction']:5.3f} |"
    )


def main(log=print):
    groups = [
        ("single-pod (optimized)", load("dryrun_single.json")),
        ("single-pod (paper-faithful baseline)", load("dryrun_baseline.json")),
        ("multi-pod (optimized)", load("dryrun_multi.json")),
    ]
    if not any(rows for _, rows in groups):
        log("no dryrun JSON found — run `python -m repro.launch.dryrun --all "
            "--out dryrun_single.json` first")
        return []
    out = []
    for title, rows in groups:
        if not rows:
            continue
        log(f"\n## {title}")
        log("| arch | shape | mesh | compute ms | memory ms | collective ms | "
            "bottleneck | useful | frac |")
        log("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            log(fmt_row(r))
        out.extend(rows)
    return out


if __name__ == "__main__":
    main()
