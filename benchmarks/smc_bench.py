"""SMC engine benchmark: sweep throughput, estimator quality, and the
sharding contract, across the particle-count axis.

Measures, for num_particles in {64, 4096, 65536} on a linear-Gaussian
state-space model (the model with a closed-form Kalman answer, so the
log-marginal-likelihood estimate can be scored against truth):

* ``steps_per_sec``   steady-state filter steps per wall-second (T steps /
                      best steady sweep; the whole sweep is ONE compiled
                      call, so this is the `lax.scan` body throughput)
* ``log_z_var``       variance of log Ẑ across repeated sweeps — the
                      estimator-quality axis: more particles must buy lower
                      variance, and the mean must sit near the exact answer
* ``cold_s``          cold-start wall time (trace + compile + first sweep)
* ``num_traces``      the retrace counter: MUST be 1 after a cold sweep plus
                      repeated same-shape re-runs (the compile-once contract)

Also asserts the sharding contract at every size: a sweep with the particle
axis constrained onto a 1-device mesh is bit-for-bit identical to the plain
vectorized sweep (same contract `benchmarks/mcmc_chains.py` pins for
chains, here for particles).

Usage:
  python benchmarks/smc_bench.py --smoke --json BENCH_smc.json
  python benchmarks/smc_bench.py            # full sizes, stdout only
"""

import argparse
import json
import math
import os
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

PARTICLE_GRID = (64, 4096, 65536)
REPEATS = 5

# SSM coefficients (shared with the exact Kalman scorer below)
A, S_TRANS, S_OBS = 0.9, 0.3, 0.5


def exact_log_z(ys) -> float:
    """Closed-form log p(y_0..y_{T-1}) for the scalar linear-Gaussian SSM:
    x_0 ~ N(0,1), x_t ~ N(A x_{t-1}, S_TRANS), y_t ~ N(x_t, S_OBS)."""
    m, p = 0.0, 1.0
    ll = 0.0
    for y in ys:
        s = p + S_OBS**2
        ll += -0.5 * (math.log(2 * math.pi * s) + (float(y) - m) ** 2 / s)
        k = p / s
        m = m + k * (float(y) - m)
        p = (1.0 - k) * p
        m, p = A * m, A * A * p + S_TRANS**2
    return ll


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI sizes")
    ap.add_argument("--json", type=str, default=None, help="write results here")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_platforms", os.environ.get("JAX_PLATFORMS", "cpu"))
    import jax.numpy as jnp
    import numpy as np

    from repro import distributions as dist
    from repro.core import primitives as P
    from repro.infer import SMC

    T = 16 if args.smoke else 64
    grid = (64, 1024, 4096) if args.smoke else PARTICLE_GRID

    def model_init(y):
        x = P.sample("x", dist.Normal(0.0, 1.0))
        P.sample("y", dist.Normal(x, S_OBS), obs=y)
        return {"x": x}

    def model_step(carry, y):
        x = P.sample("x", dist.Normal(A * carry["x"], S_TRANS))
        P.sample("y", dist.Normal(x, S_OBS), obs=y)
        return {"x": x}

    # one fixed observation sequence, simulated from the model itself
    gen = np.random.default_rng(0)
    xs_true = [gen.normal(0.0, 1.0)]
    for _ in range(T - 1):
        xs_true.append(A * xs_true[-1] + gen.normal(0.0, S_TRANS))
    ys = jnp.asarray(
        [x + gen.normal(0.0, S_OBS) for x in xs_true], dtype=jnp.float32
    )
    log_z_exact = exact_log_z(ys)
    print(f"T={T} observations, exact log Z = {log_z_exact:.4f}")

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rows = []
    for n in grid:
        smc = SMC(model_init, model_step, num_particles=n)

        t0 = time.perf_counter()
        smc.run(jax.random.PRNGKey(0), ys)
        jax.block_until_ready(smc.log_evidence())
        cold_s = time.perf_counter() - t0

        # steady state: fresh keys, identical shapes -> the cached executable
        # must be reused (num_traces stays 1); log Z across repeats scores
        # the estimator
        steady_s, log_zs = float("inf"), []
        for rep in range(1, REPEATS + 1):
            t0 = time.perf_counter()
            smc.run(jax.random.PRNGKey(rep), ys)
            log_zs.append(float(jax.block_until_ready(smc.log_evidence())))
            steady_s = min(steady_s, time.perf_counter() - t0)
        assert smc.num_traces == 1, (
            f"retrace regression: N={n} num_traces={smc.num_traces}"
        )

        # sharding contract: particle axis on a mesh == plain vmap,
        # bit-for-bit when the mesh degenerates to one device
        sharded = SMC(model_init, model_step, num_particles=n, mesh=mesh)
        sharded.run(jax.random.PRNGKey(1), ys)
        bit_identical = None
        if jax.device_count() == 1:
            bit_identical = bool(
                jnp.array_equal(sharded.log_weights, _rerun(smc, ys))
                and float(sharded.log_evidence()) == log_zs[0]
            )
            assert bit_identical, (
                f"sharded sweep diverged from vectorized at N={n} "
                "on a 1-device mesh"
            )

        lz_mean = sum(log_zs) / len(log_zs)
        lz_var = sum((v - lz_mean) ** 2 for v in log_zs) / len(log_zs)
        row = {
            "bench": "smc",
            "particles": n,
            "T": T,
            "cold_s": round(cold_s, 3),
            "steady_s": round(steady_s, 4),
            "steps_per_sec": round(T / steady_s, 1),
            "log_z_mean": round(lz_mean, 4),
            "log_z_var": round(lz_var, 5),
            "log_z_exact": round(log_z_exact, 4),
            "num_traces": smc.num_traces,
            "sharded_bit_identical": bit_identical,
        }
        rows.append(row)
        print(
            f"N={n:<6d} cold={row['cold_s']:.2f}s steady={row['steady_s']:.4f}s "
            f"steps/s={row['steps_per_sec']:.0f} "
            f"logZ={lz_mean:.3f}±{math.sqrt(lz_var):.3f} "
            f"(exact {log_z_exact:.3f}) traces={row['num_traces']}"
        )

    # estimator sanity: variance shrinks (weakly) from the smallest to the
    # largest population, and the biggest population lands near truth
    assert rows[-1]["log_z_var"] <= rows[0]["log_z_var"] + 0.05, (
        "log Z variance did not shrink with particle count: "
        f"{[r['log_z_var'] for r in rows]}"
    )
    sigma = math.sqrt(rows[-1]["log_z_var"]) + 1e-3
    assert abs(rows[-1]["log_z_mean"] - log_z_exact) < max(5 * sigma, 0.5), (
        f"log Z biased at N={rows[-1]['particles']}: "
        f"{rows[-1]['log_z_mean']} vs exact {log_z_exact}"
    )

    results = {
        "bench": "smc",
        "smoke": bool(args.smoke),
        "model": f"linear_gaussian_ssm(A={A}, s_trans={S_TRANS}, s_obs={S_OBS})",
        "T": T,
        "repeats": REPEATS,
        "log_z_exact": round(log_z_exact, 4),
        "sweeps": rows,
    }
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")
    return 0


def _rerun(smc, ys):
    """Re-run the vectorized engine with the sharded comparison's key and
    return the final log-weights (keeps the parity check key-aligned)."""
    import jax

    smc.run(jax.random.PRNGKey(1), ys)
    return smc.log_weights


if __name__ == "__main__":
    sys.exit(main())
