"""Enumeration VE microbench: pairwise greedy elimination vs the planned
contraction path (acceptance criterion for the contraction-planner PR).

Three levels:

1. Contraction level — a synthetic hidden-Markov chain of T binary K x K
   log-factors plus unary observation factors, contracted by
   `contract_log_factors` with ``dispatch="pairwise"`` (legacy greedy path:
   O(T) sequential pairwise logsumexp eliminations, O(T^2) trace-time Python,
   and an XLA graph whose compile time explodes superlinearly in T) vs
   ``dispatch="auto"`` (cost-based contraction planner: short chains stay on
   the bit-identical unrolled path, long chains roll through a plan-level
   `lax.scan` whose traced graph is O(1) in T). At T=512, K=32 the pairwise
   path does not finish *compiling* inside any sane budget, so it runs in a
   budgeted subprocess and is reported as a lower bound when it times out.

2. Plan-cache level — a second, freshly jitted contraction of the same
   structure must be served from the plan cache (hits > 0, ~zero planning
   time): the plan is a compiler artifact keyed on the factor graph's
   structural fingerprint, not rediscovered per trace.

3. Model level — a real enumerated HMM and GMM driven through
   `TraceEnum_ELBO` + `SVI.update_jit`: per-step wall time and the retrace
   counter, which must stay at 1 (fresh same-shape data must never recompile).

Writes a machine-readable BENCH_enum.json (wall-time per step, retrace
counters, plan-cache stats, GMM/HMM sizes) and exits nonzero on any retrace
regression, if auto fails to hold steady-state parity with pairwise at
matched T, if the T=512 cold compile misses its budget, or if the plan cache
misses on a repeated structure (reference backend, CPU).

Run: PYTHONPATH=src python benchmarks/enum_ve.py [--smoke] [--json PATH]
"""

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# contraction-level chain benchmark
# ---------------------------------------------------------------------------


def chain_inputs(T: int, K: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    trans = jax.random.normal(key, (T, K, K))
    obs = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
    prior = jax.random.normal(jax.random.fold_in(key, 2), (K,))
    return trans, obs, prior


def build_chain_factors(T: int, K: int, trans, obs, prior):
    """Factors in `_collect_factors` layout: z_t lives on enum dim -(t+1), so
    the transition factor t -> t+1 is right-aligned with rank t+1 (the deep
    negative dims are what the enum messenger allocates for a T-step chain)."""
    factors = [(frozenset(), prior, None)]
    for t in range(1, T + 1):
        factors.append(
            (frozenset(), trans[t - 1].reshape((K, K) + (1,) * (t - 1)), None)
        )
        factors.append(
            (frozenset(), obs[t - 1].reshape((K,) + (1,) * t), None)
        )
    return factors, frozenset(-(t + 1) for t in range(T + 1))


def time_contract(T: int, K: int, dispatch: str, reps: int = 10):
    from repro.infer.traceenum_elbo import contract_log_factors

    trans, obs, prior = chain_inputs(T, K)
    pool = build_chain_factors(T, K, trans, obs, prior)[1]

    @jax.jit
    def run(trans, obs, prior):
        factors, _ = build_chain_factors(T, K, trans, obs, prior)
        return contract_log_factors(factors, {}, pool, dispatch=dispatch)

    t0 = time.perf_counter()
    r = run(trans, obs, prior)
    jax.block_until_ready(r)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        r = run(trans, obs, prior)
    jax.block_until_ready(r)
    return {
        "T": T,
        "K": K,
        "dispatch": dispatch,
        "cold_s": round(cold_s, 3),  # trace + compile + first step
        "steady_ms": round((time.perf_counter() - t0) / reps * 1e3, 3),
        "log_z": round(float(jnp.squeeze(r)), 4),
    }


def time_contract_budgeted(T: int, K: int, dispatch: str, budget_s: float):
    """Run `time_contract` in a subprocess with a wall-clock budget: the
    pairwise path at T=512 spends its time inside XLA compilation, which
    cannot be interrupted cooperatively."""
    cmd = [sys.executable, str(Path(__file__).resolve()), "--worker", str(T), str(K), dispatch]
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}{os.pathsep}{env.get('PYTHONPATH', '')}"
    # inherit the parent's platform untouched: both sides of the winner
    # comparison must run on the same device (ci.sh exports JAX_PLATFORMS=cpu)
    try:
        out = subprocess.run(
            cmd, capture_output=True, text=True, timeout=budget_s, check=True, env=env
        )
        return json.loads(out.stdout.strip().splitlines()[-1])
    except subprocess.TimeoutExpired:
        return {"T": T, "K": K, "dispatch": dispatch, "timed_out": True, "budget_s": budget_s}
    except subprocess.CalledProcessError as e:
        return {"T": T, "K": K, "dispatch": dispatch, "failed": True, "stderr": e.stderr[-2000:]}


# ---------------------------------------------------------------------------
# model-level: real enumerated GMM / HMM through TraceEnum_ELBO
# ---------------------------------------------------------------------------


def model_stage(hmm_T: int, hmm_K: int, gmm_N: int, steps: int, log=print):
    from repro import distributions as dist
    from repro import optim
    from repro.core import handlers
    from repro.core import primitives as P
    from repro.infer import SVI, TraceEnum_ELBO, config, infer_discrete

    out = {}

    # -- GMM: global mixture weights, enumerated assignment under a plate ----
    weights = jnp.asarray([0.4, 0.6])
    data = jnp.concatenate(
        [
            -1.0 + 0.5 * jax.random.normal(jax.random.PRNGKey(0), (gmm_N // 2,)),
            2.0 + 0.5 * jax.random.normal(jax.random.PRNGKey(1), (gmm_N - gmm_N // 2,)),
        ]
    )

    def gmm(data):
        locs_p = P.param("locs", jnp.asarray([-0.5, 0.5]))
        with P.plate("N", data.shape[0]):
            z = P.sample("z", dist.Categorical(weights), infer={"enumerate": "parallel"})
            P.sample("obs", dist.Normal(locs_p[z], 0.5), obs=data)

    elbo = TraceEnum_ELBO()
    svi = SVI(gmm, lambda data: None, optim.Adam(0.05), elbo)
    state = svi.init(jax.random.PRNGKey(0), data)
    elbo.num_traces = 0
    times = []
    for i in range(steps):
        t0 = time.perf_counter()
        state, loss = svi.update_jit(state, data + 1e-4 * i)  # fresh same-shape data
        loss.block_until_ready()
        times.append(time.perf_counter() - t0)
    out["gmm"] = {
        "N": gmm_N,
        "K": 2,
        "steps": steps,
        "step_ms": round(min(times) * 1e3, 3),
        "num_traces": elbo.num_traces,
    }
    assert elbo.num_traces == 1, f"GMM retraced: {elbo.num_traces} traces in {steps} steps"

    # -- HMM: enumerated Markov chain (the chain-dispatch consumer) ----------
    trans_p = jnp.asarray(
        jax.random.dirichlet(jax.random.PRNGKey(2), jnp.ones(hmm_K), (hmm_K,))
    )
    init_p = jnp.ones(hmm_K) / hmm_K
    locs_h = jnp.linspace(-2.0, 2.0, hmm_K)
    obs_seq = jax.random.normal(jax.random.PRNGKey(3), (hmm_T,))

    @config(enumerate=True)
    def hmm(obs_seq):
        scale = P.param("scale", jnp.asarray(1.0))
        z = P.sample("z_0", dist.Categorical(init_p))
        P.sample("x_0", dist.Normal(locs_h[z], scale), obs=obs_seq[0])
        for t in range(1, hmm_T):
            z = P.sample(f"z_{t}", dist.Categorical(trans_p[z]))
            P.sample(f"x_{t}", dist.Normal(locs_h[z], scale), obs=obs_seq[t])

    elbo_h = TraceEnum_ELBO()
    svi_h = SVI(hmm, lambda obs_seq: None, optim.Adam(0.01), elbo_h)
    state = svi_h.init(jax.random.PRNGKey(4), obs_seq)
    elbo_h.num_traces = 0
    times = []
    for i in range(steps):
        t0 = time.perf_counter()
        state, loss = svi_h.update_jit(state, obs_seq + 1e-4 * i)
        loss.block_until_ready()
        times.append(time.perf_counter() - t0)
    assert elbo_h.num_traces == 1, f"HMM retraced: {elbo_h.num_traces} traces in {steps} steps"

    # Viterbi decode (max-product semiring through the same dispatch)
    t0 = time.perf_counter()
    dec = infer_discrete(hmm, temperature=0, rng_key=jax.random.PRNGKey(5))
    tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(6))).get_trace(obs_seq)
    path = [int(tr[f"z_{t}"]["value"]) for t in range(hmm_T)]
    out["hmm"] = {
        "T": hmm_T,
        "K": hmm_K,
        "steps": steps,
        "step_ms": round(min(times) * 1e3, 3),
        "num_traces": elbo_h.num_traces,
        "viterbi_s": round(time.perf_counter() - t0, 3),
        "viterbi_states_visited": len(set(path)),
    }
    log(f"  gmm: {out['gmm']}")
    log(f"  hmm: {out['hmm']}")
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--json", default=str(REPO / "BENCH_enum.json"), help="output path")
    ap.add_argument("--budget", type=float, default=None,
                    help="wall-clock budget (s) for the pairwise T=512 attempt")
    ap.add_argument("--worker", nargs=3, metavar=("T", "K", "DISPATCH"),
                    help=argparse.SUPPRESS)  # internal: budgeted subprocess entry
    args = ap.parse_args(argv)

    if args.worker:
        T, K, dispatch = int(args.worker[0]), int(args.worker[1]), args.worker[2]
        print(json.dumps(time_contract(T, K, dispatch, reps=5)))
        return 0

    from repro.infer import clear_plan_cache, plan_cache_stats
    from repro.launch.compile_cache import compilation_cache_stats

    budget = args.budget or (30.0 if args.smoke else 120.0)
    big_T, big_K = 512, 32
    matched = [16, 64] if args.smoke else [16, 64, 128]

    clear_plan_cache()
    results = {
        "bench": "enum_ve",
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "kernel_backend_env": os.environ.get("REPRO_KERNEL_BACKEND", "auto (reference off-TPU)"),
        "smoke": bool(args.smoke),
        "chain": [],
    }

    print(f"# contraction level: pairwise vs planned dispatch (K={big_K})")
    print(f"{'T':>5} {'dispatch':>9} {'cold_s':>9} {'steady_ms':>10}")
    steady = {}
    for T in matched:
        for dispatch in ("pairwise", "auto"):
            r = time_contract(T, big_K, dispatch)
            results["chain"].append(r)
            steady[(T, dispatch)] = r["steady_ms"]
            print(f"{T:>5} {dispatch:>9} {r['cold_s']:>9.2f} {r['steady_ms']:>10.2f}")
    # the planner's cost model must keep auto's steady state at least at
    # parity with the greedy path at small/medium T (the pre-planner auto was
    # 3-4x slower here); 25% + 0.2ms slack absorbs scheduler noise on sub-ms
    # timings
    for T in matched:
        auto_ms, pair_ms = steady[(T, "auto")], steady[(T, "pairwise")]
        assert auto_ms <= pair_ms * 1.25 + 0.2, (
            f"auto steady-state regressed vs pairwise at T={T}: "
            f"{auto_ms:.3f}ms vs {pair_ms:.3f}ms"
        )

    # the acceptance point: T=512 — dispatch runs inline, pairwise gets a
    # budgeted subprocess (its XLA compile alone exceeds any sane budget).
    # The budget scales with the machine: at least 2x the measured hmm_scan
    # wall time, so a slow CI runner can't fail the comparison spuriously.
    scan512 = time_contract(big_T, big_K, "auto")
    results["chain"].append(scan512)
    print(f"{big_T:>5} {'auto':>9} {scan512['cold_s']:>9.2f} {scan512['steady_ms']:>10.2f}")
    budget = max(budget, 2.0 * scan512["cold_s"])
    pair512 = time_contract_budgeted(big_T, big_K, "pairwise", budget_s=budget)
    results["chain"].append(pair512)
    if pair512.get("timed_out"):
        print(f"{big_T:>5} {'pairwise':>9} >{budget:>8.0f} {'(budget exceeded)':>10}")
        pairwise_total = budget
    elif pair512.get("failed"):
        raise RuntimeError(f"pairwise worker failed: {pair512['stderr']}")
    else:
        print(f"{big_T:>5} {'pairwise':>9} {pair512['cold_s']:>9.2f} {pair512['steady_ms']:>10.2f}")
        pairwise_total = pair512["cold_s"]
    scan_total = scan512["cold_s"]
    results["winner"] = {
        "T": big_T,
        "K": big_K,
        "planned_total_s": scan_total,
        "pairwise_total_s_lower_bound": pairwise_total,
        "speedup_lower_bound": round(pairwise_total / scan_total, 2),
    }
    assert scan_total < pairwise_total, (
        f"planned path ({scan_total:.1f}s) did not beat pairwise "
        f"({pairwise_total:.1f}s lower bound) at T={big_T}, K={big_K}"
    )
    print(f"planned path beats pairwise at T={big_T}, K={big_K}: "
          f">= {results['winner']['speedup_lower_bound']}x")
    # the compile-time war: cold trace+compile+run of the T=512 chain must
    # stay within half of the pre-planner 27.7s committed baseline (env
    # override for slow hosted runners)
    cold_budget = float(os.environ.get("REPRO_BENCH_COLD_BUDGET_S", "13.85"))
    assert scan_total <= cold_budget, (
        f"T={big_T} cold compile {scan_total:.1f}s exceeds the "
        f"{cold_budget:.1f}s budget (REPRO_BENCH_COLD_BUDGET_S)"
    )

    # -- plan-cache level: same structure, fresh jit -> plan served from cache
    print("\n# plan-cache level: second same-structure contraction")
    warm_stats0 = plan_cache_stats()
    replan_T = matched[-1]
    t0 = time.perf_counter()
    r2 = time_contract(replan_T, big_K, "auto")
    warm_stats = plan_cache_stats()
    hits = warm_stats["hits"] - warm_stats0["hits"]
    misses = warm_stats["misses"] - warm_stats0["misses"]
    replan_ms = round((warm_stats["plan_time_s"] - warm_stats0["plan_time_s"]) * 1e3, 3)
    results["plan_cache"] = {
        "bench": "replan",
        "T": replan_T,
        "K": big_K,
        "hits": hits,
        "misses": misses,
        "replan_ms": replan_ms,
        "cold_s": r2["cold_s"],
        "stats": warm_stats,
    }
    print(f"  T={replan_T} refit: hits={hits} misses={misses} "
          f"plan_time={replan_ms}ms cold={r2['cold_s']}s "
          f"(total wall {time.perf_counter() - t0:.2f}s)")
    assert hits > 0 and misses == 0, (
        f"plan cache missed on a repeated structure (hits={hits}, "
        f"misses={misses}) — the structural fingerprint is unstable"
    )
    print(f"  plan cache: {warm_stats}")
    cc_stats = compilation_cache_stats()
    results["compilation_cache"] = cc_stats
    print(f"  compilation cache: {cc_stats}")

    print("\n# model level: TraceEnum_ELBO retrace counters (must stay 1)")
    # hmm_T sites -> hmm_T - 1 binary factors; both sizes stay above the
    # planner's ~18-edge scan crossover (smoke: 19 edges, full: 23), so the
    # model level genuinely exercises the fused chain lowering
    results["models"] = model_stage(
        hmm_T=20 if args.smoke else 24,
        hmm_K=4 if args.smoke else 8,
        gmm_N=512 if args.smoke else 4096,
        steps=8 if args.smoke else 25,
    )

    Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
    print(f"\nwrote {args.json}")
    print("OK: retrace counters == 1; planned dispatch wins the T=512 chain; "
          "plan cache hit on repeated structure")
    return 0


if __name__ == "__main__":
    sys.exit(main())
