"""Gaussian-semiring VE microbench: O(log T) parallel Kalman scan vs the
sequential information-form fold (acceptance criterion for the Gaussian
semiring PR).

Three levels, mirroring enum_ve.py:

1. Contraction level — a linear-Gaussian Markov chain of T scalar edge
   factors plus unary observation factors, eliminated by
   `eliminate_gaussian_factors` under the two chain lowerings:
   ``REPRO_ENUM_CHAIN_LOWER=scan`` (sequential `lax.scan` Kalman fold — O(T)
   depth, O(1) traced graph) vs ``tree`` with the ``interpret`` kernel
   backend (`ops.gaussian_scan`'s O(log T) associative combine tree over the
   fused pairwise kernel). At T=512 the tree must win steady-state: log-depth
   batched combines beat 512 sequential while-loop iterations even on CPU.
   Both lowerings must agree on log Z to float-association tolerance.

2. Plan-cache level — re-eliminating the same chain structure with fresh
   values must be served from the plan cache (hits > 0, no misses): Gaussian
   plans are keyed under ``semiring="gaussian"`` fingerprints in the same
   cache the log-semiring uses, and a refit never replans.

3. Model level — a scalar Kalman smoother with a learnable transition
   coefficient driven through `TraceEnum_ELBO` + `SVI.update_jit`: per-step
   wall time and the retrace counter, which must stay at 1 (fresh same-shape
   observations must never recompile the lowering or the elimination).

Writes a machine-readable BENCH_gaussian.json (steady/cold wall times,
speedup, plan-cache stats, retrace counters) for the check_regression.py
gate, and exits nonzero if the tree fails to beat the sequential fold at
T=512, if the lowerings disagree, if the plan cache misses on a repeated
structure, or on any retrace regression (reference/interpret backends, CPU).

Run: PYTHONPATH=src python benchmarks/gaussian_ve.py [--smoke] [--json PATH]
"""

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# contraction-level chain benchmark
# ---------------------------------------------------------------------------


def chain_inputs(T: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.uniform(0.5, 0.95, (T - 1,)).astype(np.float32)),  # coeffs
        jnp.asarray(rng.normal(size=(T,)).astype(np.float32)),             # obs
    )


def build_chain_factors(T: int, coeffs, obs):
    """A scalar Kalman chain in lowered form: prior on x0, T-1 transition
    edge factors, T unary observation factors — the exact factor layout
    `_lower_gaussian_trace` produces for the equivalent model."""
    from repro.infer.contract import affine_gaussian_factor

    one = jnp.ones((1, 1), jnp.float32)
    factors = [affine_gaussian_factor(("x0",), (1,), {}, jnp.zeros((1,)), one, "x0")]
    for t in range(1, T):
        factors.append(
            affine_gaussian_factor(
                (f"x{t - 1}", f"x{t}"),
                (1, 1),
                {f"x{t - 1}": coeffs[t - 1].reshape(1, 1)},
                jnp.zeros((1,)),
                0.5 * one,
                f"x{t}",
            )
        )
    for t in range(T):
        factors.append(
            affine_gaussian_factor(
                (f"x{t}",),
                (1,),
                {f"x{t}": one},
                obs[t].reshape(1),
                0.6 * one,
                None,
            )
        )
    return factors, [f"x{t}" for t in range(T)]


LOWERINGS = {
    # mode -> env pinning {REPRO_ENUM_CHAIN_LOWER, REPRO_ENUM_CHAIN_MIN,
    # REPRO_KERNEL_BACKEND}. The tree needs a non-reference kernel backend:
    # under "reference", ops.gaussian_scan deliberately runs the sequential
    # oracle instead of the combine tree.
    "scan": {"REPRO_ENUM_CHAIN_LOWER": "scan"},
    "tree": {"REPRO_ENUM_CHAIN_LOWER": "tree", "REPRO_KERNEL_BACKEND": "interpret"},
}


def time_contract(T: int, mode: str, reps: int = 20):
    from repro.infer.contract import eliminate_gaussian_factors

    saved = {k: os.environ.get(k) for v in LOWERINGS.values() for k in v}
    os.environ.update(LOWERINGS[mode])
    try:
        coeffs, obs = chain_inputs(T)

        @jax.jit
        def run(coeffs, obs):
            factors, order = build_chain_factors(T, coeffs, obs)
            return sum(eliminate_gaussian_factors(factors, order))

        t0 = time.perf_counter()
        r = run(coeffs, obs)
        jax.block_until_ready(r)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            r = run(coeffs, obs)
        jax.block_until_ready(r)
        return {
            "T": T,
            "mode": mode,
            "cold_s": round(cold_s, 3),  # plan + trace + compile + first step
            "steady_ms": round((time.perf_counter() - t0) / reps * 1e3, 3),
            "log_z": round(float(r), 4),
        }
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


# ---------------------------------------------------------------------------
# model level: Kalman smoother through TraceEnum_ELBO + SVI
# ---------------------------------------------------------------------------


def model_stage(T: int, steps: int, log=print):
    from repro import distributions as dist
    from repro import optim
    from repro.core import primitives as P
    from repro.infer import SVI, TraceEnum_ELBO, gaussian_marginals

    GM = {"marginalize": "gaussian"}
    obs = chain_inputs(T, seed=1)[1]

    def kalman(obs):
        a = P.param("a", jnp.asarray(0.7))
        x = P.sample("x0", dist.Normal(0.0, 1.0), infer=GM)
        P.sample("y0", dist.Normal(x, 0.6), obs=obs[0])
        for t in range(1, T):
            x = P.sample(f"x{t}", dist.Normal(a * x, 0.5), infer=GM)
            P.sample(f"y{t}", dist.Normal(x, 0.6), obs=obs[t])

    elbo = TraceEnum_ELBO(max_plate_nesting=0)
    svi = SVI(kalman, lambda obs: None, optim.Adam(0.01), elbo)
    state = svi.init(jax.random.PRNGKey(0), obs)
    elbo.num_traces = 0
    times = []
    for i in range(steps):
        t1 = time.perf_counter()
        state, loss = svi.update_jit(state, obs + 1e-4 * i)  # fresh same-shape data
        loss.block_until_ready()
        times.append(time.perf_counter() - t1)
    out = {
        "T": T,
        "steps": steps,
        "cold_s": round(times[0], 3),  # first step = trace + compile + run
        "step_ms": round(min(times) * 1e3, 3),
        "num_traces": elbo.num_traces,
    }
    assert elbo.num_traces == 1, (
        f"Kalman SVI retraced: {elbo.num_traces} traces in {steps} steps"
    )

    # smoother-marginal query (the cumulant-trick surface), probing 3 sites
    t1 = time.perf_counter()
    marg = gaussian_marginals(
        lambda: kalman(obs), jax.random.PRNGKey(1),
        sites=["x0", f"x{T // 2}", f"x{T - 1}"],
    )
    out["marginals_s"] = round(time.perf_counter() - t1, 3)
    out["marginal_mid"] = round(float(marg[f"x{T // 2}"][0]), 4)
    log(f"  kalman svi: {out}")
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--json", default=str(REPO / "BENCH_gaussian.json"), help="output path")
    args = ap.parse_args(argv)

    from repro.infer import clear_plan_cache, plan_cache_stats

    clear_plan_cache()
    results = {
        "bench": "gaussian_ve",
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "chain": [],
    }

    big_T = 512
    matched = [64, big_T]
    reps = 20 if args.smoke else 50

    print("# contraction level: sequential scan fold vs O(log T) combine tree")
    print(f"{'T':>5} {'mode':>5} {'cold_s':>9} {'steady_ms':>10}")
    steady, logz = {}, {}
    for T in matched:
        for mode in ("scan", "tree"):
            r = time_contract(T, mode, reps=reps)
            results["chain"].append(r)
            steady[(T, mode)] = r["steady_ms"]
            logz[(T, mode)] = r["log_z"]
            print(f"{T:>5} {mode:>5} {r['cold_s']:>9.2f} {r['steady_ms']:>10.3f}")
    for T in matched:
        # float-association tolerance: same chain, different combine order
        assert abs(logz[(T, "scan")] - logz[(T, "tree")]) <= 1e-3 * max(
            1.0, abs(logz[(T, "scan")])
        ), f"lowerings disagree at T={T}: {logz[(T, 'scan')]} vs {logz[(T, 'tree')]}"

    # the acceptance point: the parallel scan must beat the sequential fold
    # at T=512 (log-depth batched combines vs 512 while-loop iterations)
    speedup = round(steady[(big_T, "scan")] / steady[(big_T, "tree")], 2)
    results["winner"] = {
        "T": big_T,
        "scan_steady_ms": steady[(big_T, "scan")],
        "tree_steady_ms": steady[(big_T, "tree")],
        "speedup_steady": speedup,
    }
    assert steady[(big_T, "tree")] < steady[(big_T, "scan")], (
        f"parallel scan ({steady[(big_T, 'tree')]:.3f}ms) did not beat the "
        f"sequential fold ({steady[(big_T, 'scan')]:.3f}ms) at T={big_T}"
    )
    print(f"parallel scan beats sequential fold at T={big_T}: {speedup}x")

    # -- plan-cache level: same structure, fresh values -> plan from cache --
    print("\n# plan-cache level: second same-structure elimination")
    from repro.infer.contract import eliminate_gaussian_factors

    T = matched[0]
    # first fit plans (the chain stage above ran under pinned lowering env,
    # which is part of the fingerprint); the refit with fresh values must hit
    coeffs, obs = chain_inputs(T, seed=2)
    factors, order = build_chain_factors(T, coeffs, obs)
    jax.block_until_ready(sum(eliminate_gaussian_factors(factors, order)))
    before = plan_cache_stats()
    coeffs, obs = chain_inputs(T, seed=3)
    factors, order = build_chain_factors(T, coeffs, obs)
    jax.block_until_ready(sum(eliminate_gaussian_factors(factors, order)))
    after = plan_cache_stats()
    hits = after["hits"] - before["hits"]
    misses = after["misses"] - before["misses"]
    results["plan_cache"] = {
        "bench": "refit", "T": T, "hits": hits, "misses": misses, "stats": after,
    }
    print(f"  T={T} refit: hits={hits} misses={misses}")
    assert hits > 0 and misses == 0, (
        f"plan cache missed on a repeated Gaussian structure (hits={hits}, "
        f"misses={misses}) — the semiring fingerprint is unstable"
    )

    print("\n# model level: TraceEnum_ELBO retrace counter (must stay 1)")
    results["model"] = model_stage(
        T=24 if args.smoke else 48,
        steps=8 if args.smoke else 25,
    )

    Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
    print(f"\nwrote {args.json}")
    print("OK: parallel scan wins the T=512 chain; plan cache hit on refit; "
          "retrace counter == 1")
    return 0


if __name__ == "__main__":
    sys.exit(main())
