"""Bench-regression gate: diff freshly written BENCH_*.json numbers against
the committed baselines (HEAD) and fail on regression.

Usage: python benchmarks/check_regression.py BENCH_enum.json BENCH_serve.json \
           BENCH_mcmc.json

For each file, the committed baseline is read from ``git show HEAD:<file>``
(a file with no committed baseline is skipped with a note — its first run
commits the baseline). The two JSON trees are walked in parallel; numeric
leaves whose key names a gated metric are compared:

* lower-is-better steady-state (``steady_ms``, ``step_ms``, ``p50_ms``,
  ``p99_ms``, ``bucketed_ms_per_req``, ``swap_gap_ms``): fail when
  ``fresh > base * (1 + tol) + abs_slack``
* higher-is-better (``requests_per_sec``, ``rows_per_sec``,
  ``speedup_steady``, ``draws_per_sec``, ``ess_per_sec``): fail when
  ``fresh < base / (1 + tol)``
* lower-is-better cold-compile (``cold_s``, ``cold_compile_s``,
  ``viterbi_s``): fail when ``fresh > base * (1 + cold_tol) + cold_abs_s`` —
  a separate, looser tolerance, because compile time is noisier than
  steady-state but a silent 2x compile regression is exactly what the
  contraction planner exists to prevent.
* lower-is-better [0,1] rates (``shed_rate``): fail when
  ``fresh > base + REPRO_BENCH_ABS_RATE`` — purely absolute slack, since the
  healthy baseline is 0.0 shed and a relative tolerance on zero is vacuous.

The naive-baseline numbers are deliberately NOT gated (they measure the
rejected path, not the engine). List entries are matched positionally, but
only when their identifying fields (``T``/``K``/``dispatch``) agree — a
reordered or resized benchmark matrix skips the mismatched entries instead
of comparing apples to pears.

Knobs (env):
  REPRO_BENCH_TOLERANCE       relative tolerance on steady-state metrics,
                              default 0.25 (= fail >25% regression). Hosted
                              CI runners with noisy/slower hardware than the
                              baseline machine should raise it.
  REPRO_BENCH_ABS_MS          absolute slack added to lower-is-better *_ms
                              gates, default 0.5 — keeps sub-millisecond
                              metrics from failing on scheduler noise.
  REPRO_BENCH_ABS_RATE        absolute slack on [0,1] rate gates
                              (``shed_rate``), default 0.05.
  REPRO_BENCH_COLD_TOLERANCE  relative tolerance on cold-compile metrics,
                              default 1.0 (= fail >2x regression).
  REPRO_BENCH_COLD_ABS_S      absolute slack (seconds) on cold-compile
                              gates, default 2.0.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _knob_float(name: str, fallback: float) -> float:
    """Tolerance knobs come from `repro.settings` so the defaults live in one
    registry, but this script must stay runnable standalone (CI calls it
    without PYTHONPATH=src), so fall back to the local default if the package
    isn't importable."""
    try:
        from repro import settings
        return settings.get_float(name)
    except ImportError:
        return float(os.environ.get(name, str(fallback)))

LOWER_BETTER = {"steady_ms", "step_ms", "p50_ms", "p99_ms",
                "bucketed_ms_per_req", "swap_gap_ms"}
HIGHER_BETTER = {"requests_per_sec", "rows_per_sec", "speedup_steady",
                 "draws_per_sec", "ess_per_sec", "steps_per_sec"}
COLD_LOWER_BETTER = {"cold_s", "cold_compile_s", "viterbi_s"}
# dimensionless [0,1] rates gated with a purely absolute slack — a relative
# tolerance is meaningless when the baseline is 0.0 (zero requests shed)
RATE_LOWER_BETTER = {"shed_rate"}
IDENTITY_KEYS = ("T", "K", "dispatch", "bench", "chains", "mode", "scenario",
                 "particles")


def committed_baseline(name: str):
    try:
        out = subprocess.run(
            ["git", "-C", str(REPO), "show", f"HEAD:{name}"],
            capture_output=True, text=True, check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return json.loads(out)


def walk(base, fresh, path, rows):
    if isinstance(base, dict) and isinstance(fresh, dict):
        for k in base:
            if k in fresh:
                walk(base[k], fresh[k], f"{path}.{k}" if path else k, rows)
    elif isinstance(base, list) and isinstance(fresh, list):
        for i, (b, f) in enumerate(zip(base, fresh)):
            if isinstance(b, dict) and isinstance(f, dict):
                if any(b.get(k) != f.get(k) for k in IDENTITY_KEYS):
                    continue  # matrix entry moved/resized: not comparable
            walk(b, f, f"{path}[{i}]", rows)
    elif isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        key = path.rsplit(".", 1)[-1].split("[")[0]
        if (key in LOWER_BETTER or key in HIGHER_BETTER
                or key in COLD_LOWER_BETTER or key in RATE_LOWER_BETTER):
            rows.append((path, key, float(base), float(fresh)))


def gate(name: str, tol: float, abs_ms: float, cold_tol: float,
         cold_abs_s: float, abs_rate: float) -> int:
    fresh_path = REPO / name
    if not fresh_path.exists():
        print(f"FAIL {name}: fresh file missing (did the bench stage run?)")
        return 1
    base = committed_baseline(name)
    if base is None:
        print(f"skip {name}: no committed baseline in HEAD (first run commits it)")
        return 0
    fresh = json.loads(fresh_path.read_text())
    rows = []
    walk(base, fresh, "", rows)
    failures = 0
    print(f"\n== {name} (steady tol {tol:.0%} +{abs_ms}ms, "
          f"cold tol {cold_tol:.0%} +{cold_abs_s}s)")
    print(f"{'metric':<44} {'base':>10} {'fresh':>10} {'delta':>8}")
    for path, key, b, f in rows:
        if key in LOWER_BETTER:
            limit = b * (1 + tol) + abs_ms
            bad = f > limit
        elif key in COLD_LOWER_BETTER:
            limit = b * (1 + cold_tol) + cold_abs_s
            bad = f > limit
        elif key in RATE_LOWER_BETTER:
            limit = b + abs_rate
            bad = f > limit
        else:
            limit = b / (1 + tol)
            bad = f < limit
        delta = (f - b) / b if b else 0.0
        verdict = "FAIL" if bad else "ok"
        print(f"{path:<44} {b:>10.3f} {f:>10.3f} {delta:>+7.1%} {verdict}")
        failures += bad
    if not rows:
        print("  (no comparable gated metrics found)")
    return failures


def main(argv=None) -> int:
    names = (argv if argv is not None else sys.argv[1:]) or [
        "BENCH_enum.json", "BENCH_serve.json", "BENCH_mcmc.json"
    ]
    tol = _knob_float("REPRO_BENCH_TOLERANCE", 0.25)
    abs_ms = _knob_float("REPRO_BENCH_ABS_MS", 0.5)
    cold_tol = _knob_float("REPRO_BENCH_COLD_TOLERANCE", 1.0)
    cold_abs_s = _knob_float("REPRO_BENCH_COLD_ABS_S", 2.0)
    abs_rate = _knob_float("REPRO_BENCH_ABS_RATE", 0.05)
    failures = sum(
        gate(n, tol, abs_ms, cold_tol, cold_abs_s, abs_rate) for n in names
    )
    if failures:
        print(f"\n{failures} gated metric(s) regressed beyond tolerance "
              f"(steady {tol:.0%} +{abs_ms}ms; cold {cold_tol:.0%} "
              f"+{cold_abs_s}s). If the regression is intended, commit the "
              f"fresh BENCH_*.json as the new baseline; for noisy runners "
              f"raise REPRO_BENCH_TOLERANCE / REPRO_BENCH_COLD_TOLERANCE.")
        return 1
    print("\nbench-regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
