"""Figure-3 analogue: PPL abstraction overhead vs hand-written JAX.

The paper measures Pyro-vs-PyTorch wall-clock per VAE gradient update and
shows the gap shrinks as tensor work grows. In the JAX port, handlers run at
TRACE time, so we measure BOTH:
  (a) compiled per-step wall time, PPL path vs raw path (should be ~equal
      — the compiled HLO is the same modulo RNG plumbing), and
  (b) one-off trace+compile time for each (the real cost of the
      abstraction here), across VAE sizes mirroring Fig-3's (#z, #h) grid.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro import optim
from repro.core import primitives as P
from repro.infer import SVI, Trace_ELBO

OBS = 784


def _mlp_init(key, sizes):
    p = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        p[f"w{i}"] = jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5
        p[f"b{i}"] = jnp.zeros(b)
    return p


def _mlp(p, x, final=None):
    n = sum(1 for k in p if k.startswith("w"))
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1:
            x = jax.nn.softplus(x)
    return x if final is None else final(x)


def _time(f, *args, iters=30):
    f(*args)  # warmup/compile outside timing
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(nz: int, nh: int, batch: int = 128, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    dec0 = _mlp_init(jax.random.fold_in(key, 1), [nz, nh, nh, OBS])
    enc0 = _mlp_init(jax.random.fold_in(key, 2), [OBS, nh, nh, 2 * nz])
    data = (jax.random.uniform(key, (batch, OBS)) < 0.3).astype(jnp.float32)

    # ---------------- PPL path (the paper's Fig-1 program) ----------------
    def model(x):
        dec = P.module("dec", dec0)
        B = x.shape[0]
        with P.plate("data", B, dim=-1):
            z = P.sample("z", dist.Normal(jnp.zeros((B, nz)), 1.0).to_event(1))
            P.sample("x", dist.Bernoulli(logits=_mlp(dec, z)).to_event(1), obs=x)

    def guide(x):
        enc = P.module("enc", enc0)
        h = _mlp(enc, x)
        with P.plate("data", x.shape[0], dim=-1):
            P.sample("z", dist.Normal(h[:, :nz], jnp.exp(0.5 * h[:, nz:])).to_event(1))

    svi = SVI(model, guide, optim.Adam(1e-3), Trace_ELBO())
    t0 = time.perf_counter()
    state = svi.init(jax.random.PRNGKey(seed + 1), data)
    ppl_step = svi.update_jit  # SVI's compile-once entry point
    state, _ = ppl_step(state, data)  # trace + compile
    ppl_compile = time.perf_counter() - t0
    ppl_time = _time(lambda s: ppl_step(s, data)[0], state)

    # ---------------- raw JAX path (idiomatic hand-written VAE) -----------
    def raw_loss(params, key, x):
        h = _mlp(params["enc"], x)
        loc, log_var = h[:, :nz], h[:, nz:]
        eps = jax.random.normal(key, loc.shape)
        z = loc + jnp.exp(0.5 * log_var) * eps
        logits = _mlp(params["dec"], z)
        rec = jnp.sum(x * jax.nn.log_sigmoid(logits) + (1 - x) * jax.nn.log_sigmoid(-logits))
        kl = -0.5 * jnp.sum(1 + log_var - loc**2 - jnp.exp(log_var))
        return -(rec - kl)

    raw_opt = optim.Adam(1e-3)
    raw_params = {"enc": enc0, "dec": dec0}
    t0 = time.perf_counter()
    raw_state = raw_opt.init(raw_params)

    @jax.jit
    def raw_step(state, key, x):
        params = raw_opt.get_params(state)
        grads = jax.grad(raw_loss)(params, key, x)
        return raw_opt.update(grads, state)

    raw_state = raw_step(raw_state, key, data)
    raw_compile = time.perf_counter() - t0
    raw_time = _time(lambda s: raw_step(s, key, data), raw_state)

    return {
        "nz": nz, "nh": nh,
        "raw_ms": raw_time * 1e3, "ppl_ms": ppl_time * 1e3,
        "ratio": ppl_time / raw_time,
        "raw_compile_s": raw_compile, "ppl_compile_s": ppl_compile,
    }


def main(log=print):
    log("# Fig-3 analogue: VAE step time, hand-written JAX vs PPL path")
    log(f"{'#z':>4} {'#h':>6} {'raw ms':>8} {'ppl ms':>8} {'ratio':>6} "
        f"{'raw compile s':>14} {'ppl compile s':>14}")
    rows = []
    for nz, nh in [(10, 400), (30, 400), (10, 2000), (30, 2000)]:
        r = run(nz, nh)
        rows.append(r)
        log(f"{r['nz']:>4} {r['nh']:>6} {r['raw_ms']:8.2f} {r['ppl_ms']:8.2f} "
            f"{r['ratio']:6.2f} {r['raw_compile_s']:14.2f} {r['ppl_compile_s']:14.2f}")
    return rows


if __name__ == "__main__":
    main()
