"""Sharded-SVI microbench (acceptance criterion for the engine PR).

Demonstrates that the jit-compiled sharded `SVI.update` executes with NO
per-step retracing: a fresh minibatch (fresh subsample indices) every step
hits the same compiled executable, so steady-state step time is flat after
step 1 and `update_jit._cache_size()` stays at 1.

Run: PYTHONPATH=src python benchmarks/svi_sharded.py [--smoke]
(--smoke: CI-sized run — fewer steps/particles, same retrace assertions)
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import distributions as dist
from repro import optim
from repro.core import primitives as P
from repro.infer import SVI, AutoNormal, Trace_ELBO

N_FULL = 4096
N_BATCH = 256


def model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    scale = P.sample("scale", dist.LogNormal(0.0, 1.0))
    with P.plate("N", N_FULL, subsample_size=N_BATCH) as idx:
        P.sample("obs", dist.Normal(loc, scale), obs=data[idx])


def main(steps: int = 50, particles: int = 8, log=print):
    data = 1.5 + 0.7 * jax.random.normal(jax.random.PRNGKey(0), (N_FULL,))
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=particles), mesh=mesh)
    state = svi.init(jax.random.PRNGKey(1), data)

    log(f"# sharded SVI.update: {jax.device_count()} device(s), "
        f"{particles} particles, N={N_FULL} subsample={N_BATCH}")
    log(f"{'step':>5} {'ms':>9} {'jit cache':>10}")
    times = []
    for i in range(steps):
        idx = jax.random.choice(
            jax.random.fold_in(jax.random.PRNGKey(2), i), N_FULL, (N_BATCH,), replace=False
        )
        t0 = time.perf_counter()
        state, loss = svi.update_jit(state, data, subsample={"N": idx})
        loss.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e3
        times.append(dt)
        if i < 3 or i % 10 == 0:
            log(f"{i:>5} {dt:9.3f} {svi.update_jit._cache_size():>10}")

    steady = times[1:]
    log(f"step 0 (compile): {times[0]:9.3f} ms")
    log(f"steady-state:     {sum(steady)/len(steady):9.3f} ms "
        f"(min {min(steady):.3f}, max {max(steady):.3f})")
    cache = svi.update_jit._cache_size()
    log(f"compiled executables: {cache}")
    assert cache == 1, f"per-step retracing detected: cache_size={cache}"
    assert max(steady) < times[0], "steady-state should be far below compile step"
    log("OK: no per-step retracing; steady-state flat after step 1")
    return times


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args()
    if args.smoke:
        main(steps=12, particles=2)
    else:
        main()
