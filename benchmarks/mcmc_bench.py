"""MCMC raw-speed benchmark: fused batched driver vs the legacy per-chain
vmap sampler, across the many-chains axis.

Measures, for num_chains in {1, 64, 1024} on a non-centered eight-schools
model widened to 64 schools (D = 66 continuous parameters):

* ``draws_per_sec``   steady-state posterior draws per wall-second (all
                      chains x kept samples / best steady run)
* ``ess_per_sec``     bulk effective sample size of ``mu`` per wall-second —
                      raw speed is worthless if the chains stop mixing
* ``cold_s``          cold-start wall time (trace + compile + first run)
* ``num_traces``      the retrace counter: MUST be 1 after a cold run plus
                      repeated same-shape reruns (the compile-once contract)

Each configuration runs in its OWN subprocess (`--worker`) so cold-compile
numbers are honest and the legacy baseline can be wall-clock budgeted: the
legacy worker gets ``max(--budget, 6x the fused worker's wall time)`` and a
timeout is treated as a *lower bound* on its steady time (the fused/legacy
speedup is then itself a lower bound, so the >= 2x assertion below stays
sound).

Assertions (exit nonzero on violation — this doubles as a CI gate):
  * every worker reports ``num_traces == 1``;
  * at the top chain count the fused driver's draws/sec is at least 2x the
    legacy sampler's (``speedup_steady >= 2``).

Usage:
  python benchmarks/mcmc_bench.py --smoke --json BENCH_mcmc.json
  python benchmarks/mcmc_bench.py            # full sizes, stdout only
"""

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

CHAIN_GRID = (1, 64, 1024)
MIN_SPEEDUP = 2.0


# ---------------------------------------------------------------------------
# worker: one (mode, chains) configuration, isolated in its own process
# ---------------------------------------------------------------------------


def run_case(mode: str, chains: int, warmup: int, samples: int) -> dict:
    import jax

    jax.config.update("jax_platforms", os.environ.get("JAX_PLATFORMS", "cpu"))
    import jax.numpy as jnp

    from repro import distributions as dist
    from repro.core import primitives as P
    from repro.infer import HMC, MCMC, effective_sample_size

    # 64 synthetic "schools" (D = 66): enough likelihood work per gradient
    # that the benchmark measures sampler efficiency, not RNG/bookkeeping
    import numpy as np

    gen = np.random.default_rng(0)
    y = jnp.asarray(gen.normal(5.0, 8.0, 64).astype(np.float32))
    sigma = jnp.asarray(gen.uniform(8.0, 18.0, 64).astype(np.float32))

    def eight_schools(y, sigma):
        mu = P.sample("mu", dist.Normal(0.0, 5.0))
        log_tau = P.sample("log_tau", dist.Normal(0.0, 1.0))
        with P.plate("J", y.shape[0]):
            theta = P.sample("theta", dist.Normal(0.0, 1.0))
            P.sample("obs", dist.Normal(mu + jnp.exp(log_tau) * theta, sigma), obs=y)

    # Both samplers get the same moderate step cap (the class default is
    # 1024, which would be absurdly slow for the legacy path). The legacy
    # per-chain scan pays 2 gradients x the FULL cap every draw (its masked
    # steps still execute under vmap); the fused while_loop pays only the
    # steps actually taken (cross-chain max) — that cap-vs-actual gap is the
    # structural win this benchmark exists to measure.
    fused = mode == "fused"
    kernel = HMC(eight_schools, max_num_steps=64, adapt_trajectory_length=fused)
    mcmc = MCMC(
        kernel, num_warmup=warmup, num_samples=samples, num_chains=chains, fused=fused
    )

    t0 = time.perf_counter()
    mcmc.run(jax.random.PRNGKey(0), y, sigma)
    jax.block_until_ready(mcmc.get_samples())
    cold_s = time.perf_counter() - t0

    # steady state: fresh key + perturbed data, identical shapes -> the cached
    # executable must be reused (num_traces stays 1)
    steady_s = float("inf")
    for rep in (1, 2, 3):
        t0 = time.perf_counter()
        mcmc.run(jax.random.PRNGKey(rep), y + 1e-4 * rep, sigma)
        jax.block_until_ready(mcmc.get_samples())
        steady_s = min(steady_s, time.perf_counter() - t0)

    mu = mcmc.get_samples(group_by_chain=True)["mu"]  # (chains, samples)
    ess = float(effective_sample_size(mu))
    return {
        "mode": mode,
        "chains": chains,
        "warmup": warmup,
        "samples": samples,
        "cold_s": round(cold_s, 3),
        "steady_s": round(steady_s, 4),
        "draws_per_sec": round(chains * samples / steady_s, 1),
        "ess_per_sec": round(ess / steady_s, 1),
        "num_traces": mcmc.num_traces,
    }


# ---------------------------------------------------------------------------
# driver: spawn workers, budget the baseline, assert the contracts
# ---------------------------------------------------------------------------


def spawn_worker(mode: str, chains: int, warmup: int, samples: int, budget_s: float):
    env = os.environ.copy()
    src = str(REPO / "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [
        sys.executable, __file__, "--worker", mode, str(chains),
        "--warmup", str(warmup), "--samples", str(samples),
    ]
    t0 = time.perf_counter()
    try:
        proc = subprocess.run(
            cmd, capture_output=True, text=True, env=env, timeout=budget_s
        )
    except subprocess.TimeoutExpired:
        # lower bound: the whole budget elapsed without finishing one cold +
        # three steady runs, so steady_s >= budget and draws/sec <= draws/budget
        return {
            "mode": mode, "chains": chains, "timed_out": True,
            "budget_s": budget_s, "steady_s": budget_s,
            "draws_per_sec": round(chains * samples / budget_s, 1),
        }, time.perf_counter() - t0
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise SystemExit(f"worker {mode}/chains={chains} failed")
    return json.loads(proc.stdout.strip().splitlines()[-1]), time.perf_counter() - t0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI sizes")
    ap.add_argument("--json", type=str, default=None, help="write results here")
    ap.add_argument("--budget", type=float, default=None,
                    help="baseline wall-clock budget floor, seconds")
    ap.add_argument("--worker", nargs=2, metavar=("MODE", "CHAINS"), default=None)
    ap.add_argument("--warmup", type=int, default=None)
    ap.add_argument("--samples", type=int, default=None)
    args = ap.parse_args()

    # warmup is deliberately short relative to draws: draws/sec includes the
    # warmup wall clock, and warmup transitions run near the step cap while
    # the step size is still adapting (both samplers pay that equally)
    warmup = args.warmup if args.warmup is not None else (50 if args.smoke else 100)
    samples = args.samples if args.samples is not None else (500 if args.smoke else 1000)

    if args.worker is not None:
        mode, chains = args.worker[0], int(args.worker[1])
        print(json.dumps(run_case(mode, chains, warmup, samples)))
        return 0

    budget_floor = args.budget if args.budget is not None else (240.0 if args.smoke else 600.0)

    fused_rows, fused_wall_top = [], 0.0
    for chains in CHAIN_GRID:
        row, wall = spawn_worker("fused", chains, warmup, samples, budget_s=1200.0)
        print(f"fused  chains={chains:<5d} cold={row['cold_s']:.2f}s "
              f"steady={row['steady_s']:.4f}s draws/s={row['draws_per_sec']:.0f} "
              f"ESS/s={row['ess_per_sec']:.0f} traces={row['num_traces']}")
        assert row["num_traces"] == 1, (
            f"retrace regression: fused chains={chains} num_traces={row['num_traces']}"
        )
        fused_rows.append(row)
        fused_wall_top = wall

    # legacy baseline at the top chain count only — it measures the rejected
    # path, and its wall clock is budgeted off the fused worker's
    top = CHAIN_GRID[-1]
    budget = max(budget_floor, 6.0 * fused_wall_top)
    legacy, _ = spawn_worker("legacy", top, warmup, samples, budget_s=budget)
    if legacy.get("timed_out"):
        print(f"legacy chains={top}: timed out after {budget:.0f}s "
              f"(draws/s <= {legacy['draws_per_sec']:.0f}, treated as lower-bound "
              f"speedup)")
    else:
        print(f"legacy chains={top:<5d} cold={legacy['cold_s']:.2f}s "
              f"steady={legacy['steady_s']:.4f}s draws/s={legacy['draws_per_sec']:.0f}")
        assert legacy["num_traces"] == 1, "legacy retrace regression"

    fused_top = fused_rows[-1]
    speedup = fused_top["draws_per_sec"] / max(legacy["draws_per_sec"], 1e-9)
    print(f"speedup (fused vs legacy, chains={top}): {speedup:.2f}x"
          + (" (lower bound)" if legacy.get("timed_out") else ""))
    assert speedup >= MIN_SPEEDUP, (
        f"fused driver only {speedup:.2f}x over the legacy sampler at "
        f"chains={top}; the raw-speed pass requires >= {MIN_SPEEDUP}x"
    )

    results = {
        "bench": "mcmc",
        "smoke": bool(args.smoke),
        "model": "eight_schools_noncentered(J=64, D=66)",
        "warmup": warmup,
        "samples": samples,
        "fused": fused_rows,
        # baseline keys deliberately NOT gate-named (it measures the rejected
        # path); speedup_steady IS gated higher-is-better
        "legacy_baseline": {
            "chains": top,
            "steady_s_baseline": legacy["steady_s"],
            "draws_per_sec_baseline": legacy["draws_per_sec"],
            "timed_out": bool(legacy.get("timed_out", False)),
        },
        "speedup_steady": round(speedup, 2),
    }
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
