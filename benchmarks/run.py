"""Benchmark harness — one entry per paper table/figure:

  fig3      Pyro-vs-raw overhead (paper Fig. 3)   -> fig3_overhead.py
  fig4      DMM + IAF test ELBO (paper Fig. 4)    -> fig4_dmm.py
  kernels   Pallas hot-spot accounting            -> kernel_bench.py
  roofline  40-cell dry-run roofline table        -> roofline_table.py

`python -m benchmarks.run` runs everything; `--only fig3` filters."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "fig3", "fig4", "kernels", "roofline"])
    ap.add_argument("--fig4-steps", type=int, default=400)
    args = ap.parse_args()

    from . import fig3_overhead, fig4_dmm, kernel_bench, roofline_table

    jobs = {
        "fig3": fig3_overhead.main,
        "fig4": lambda: fig4_dmm.main(steps=args.fig4_steps),
        "kernels": kernel_bench.main,
        "roofline": roofline_table.main,
    }
    selected = [args.only] if args.only else list(jobs)
    for name in selected:
        print(f"\n===== {name} =====")
        t0 = time.time()
        jobs[name]()
        print(f"===== {name} done in {time.time()-t0:.0f}s =====")
    return 0


if __name__ == "__main__":
    sys.exit(main())
