"""Posterior-serving benchmark: bucketed compiled endpoint vs naive
per-request `Predictive` (acceptance criterion for the serving PR).

Three stages:

1. Steady state — a stream of variable-size requests through a
   `ServableModel` endpoint (pad-to-bucket, one jit cache) vs the naive
   path (`Predictive(jit_compile=False)`: eager re-vmap + re-trace on
   every request, which is exactly what `Predictive.__call__` did before
   the serving PR). Asserts the bucketed path is >= 5x faster per request
   at steady state and that the engine's retrace counter equals the number
   of shape buckets touched (compiles are bounded by buckets, not by
   distinct request sizes).

2. Micro-batcher throughput — concurrent clients submit through
   `serve.MicroBatcher`; reports requests/sec, p50/p99 latency, mean
   coalesced batch size vs `max_batch`.

3. Sustained load — concurrent clients with a per-request deadline;
   overload must be *shed at admission* (LoadShedError -> 429 at the HTTP
   front end), never dropped. Reports req/s, p50/p99, shed_rate.

4. Refresh under traffic — the streaming-service hard property: hot-swap
   the servable's params while clients hammer it. Reports ``swap_gap_ms``
   (refresh() to the first response serving the new posterior) and asserts
   zero dropped requests and zero recompiles across swaps.

5. Sharding parity — serving through a 1-device mesh
   (`distributed.sharding.default_mesh`) must be bit-identical to
   unsharded serving.

Writes BENCH_serve.json and exits nonzero on any contract violation.

Run: PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--json PATH]
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO = Path(__file__).resolve().parent.parent

DIM = 4
SPEEDUP_FLOOR = 5.0


def make_artifact(train_steps: int):
    from repro import distributions as dist, optim
    from repro.core import primitives as P
    from repro.infer import SVI, AutoNormal, Trace_ELBO

    def model(x, y=None):
        w = P.sample("w", dist.Normal(jnp.zeros(DIM), 1.0).to_event(1))
        b = P.sample("b", dist.Normal(0.0, 1.0))
        with P.plate("B", x.shape[0]):
            mu = P.deterministic("mu", x @ w + b)
            P.sample("y", dist.Normal(mu, 0.1), obs=y)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, DIM))
    y = x @ jnp.arange(1.0, DIM + 1.0) + 0.5
    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO())
    state, _ = svi.run(jax.random.PRNGKey(1), train_steps, x, y=y)
    params = svi.optim.get_params(state.optim_state)
    return model, guide, params


def request_sizes(n_requests: int, max_request: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    return [int(s) for s in rng.integers(1, max_request + 1, size=n_requests)]


def bench_steady_state(model, guide, params, *, num_samples, max_batch,
                       n_requests, log=print):
    """Per-request wall time: naive eager Predictive vs bucketed engine."""
    from repro.infer import Predictive
    from repro.serve import ServableModel

    sizes = request_sizes(n_requests, max_batch)
    reqs = [
        jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), i), (n, DIM))
        for i, n in enumerate(sizes)
    ]

    # -- naive: the pre-PR read path (re-vmap + re-trace every call) --------
    naive = Predictive(model, guide=guide, params=params,
                       num_samples=num_samples, jit_compile=False)
    naive(jax.random.PRNGKey(3), reqs[0])  # absorb first-touch imports
    t0 = time.perf_counter()
    for i, r in enumerate(reqs):
        jax.block_until_ready(naive(jax.random.fold_in(jax.random.PRNGKey(3), i), r))
    naive_ms = (time.perf_counter() - t0) / len(reqs) * 1e3

    # -- bucketed: the serving engine ---------------------------------------
    servable = ServableModel.from_svi(
        "bench", model, guide, params, num_samples=num_samples, max_batch=max_batch
    )
    t0 = time.perf_counter()
    for b in servable.engine.buckets:  # cold: compile every bucket once
        jax.block_until_ready(
            servable.predict(jax.random.PRNGKey(4), jnp.ones((b, DIM)))
        )
    cold_s = time.perf_counter() - t0
    for r in reqs:  # steady state: request shapes recur under real traffic
        jax.block_until_ready(servable.predict(jax.random.PRNGKey(4), r))

    lat = []
    for i, r in enumerate(reqs):
        t0 = time.perf_counter()
        jax.block_until_ready(
            servable.predict(jax.random.fold_in(jax.random.PRNGKey(5), i), r)
        )
        lat.append((time.perf_counter() - t0) * 1e3)
    lat_sorted = sorted(lat)
    bucketed_ms = sum(lat) / len(lat)
    out = {
        "requests": len(reqs),
        "num_samples": num_samples,
        "max_batch": max_batch,
        "naive_ms_per_req": round(naive_ms, 3),
        "bucketed_ms_per_req": round(bucketed_ms, 3),
        "p50_ms": round(lat_sorted[len(lat) // 2], 3),
        "p99_ms": round(lat_sorted[min(len(lat) - 1, int(0.99 * len(lat)))], 3),
        "cold_compile_s": round(cold_s, 3),
        "speedup_steady": round(naive_ms / bucketed_ms, 2),
        "num_traces": servable.num_traces,
        "buckets": sorted(servable.buckets_touched),
    }
    log(f"  naive {naive_ms:8.2f} ms/req   bucketed {bucketed_ms:8.3f} ms/req "
        f"  speedup {out['speedup_steady']:.1f}x")
    log(f"  compiles {out['num_traces']} over buckets {out['buckets']}")
    assert servable.num_traces == len(servable.buckets_touched), (
        f"retrace regression: {servable.num_traces} compiles for "
        f"{len(servable.buckets_touched)} buckets"
    )
    assert out["speedup_steady"] >= SPEEDUP_FLOOR, (
        f"bucketed serve path only {out['speedup_steady']}x faster than naive "
        f"Predictive (floor: {SPEEDUP_FLOOR}x)"
    )
    return out


def bench_batcher(model, guide, params, *, num_samples, max_batch,
                  n_requests, n_clients, log=print):
    """Concurrent clients through the micro-batcher."""
    import threading

    from repro.serve import MicroBatcher, ServableModel

    servable = ServableModel.from_svi(
        "bench-batcher", model, guide, params,
        num_samples=num_samples, max_batch=max_batch,
    )
    for b in servable.engine.buckets:  # steady-state measurement: warm all
        servable.predict(jax.random.PRNGKey(0), jnp.ones((b, DIM)))

    sizes = request_sizes(n_requests, max(1, max_batch // 4), seed=11)
    with MicroBatcher(servable.engine, max_wait_ms=2.0) as mb:
        mb.stats = type(mb.stats)(window=mb.stats.window)  # reset after warmup
        per_client = (len(sizes) + n_clients - 1) // n_clients

        def client(cid):
            for i, n in enumerate(sizes[cid * per_client : (cid + 1) * per_client]):
                x = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(6), cid * 10_000 + i),
                    (n, DIM),
                )
                mb.predict(x, timeout=120)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        summary = mb.stats.summary()
    summary["wall_s"] = round(wall_s, 3)
    summary["clients"] = n_clients
    log(f"  {summary['requests']} reqs / {summary['batches']} batches "
        f"({summary['mean_batch_rows']} rows/batch)  "
        f"{summary['requests_per_sec']} req/s  "
        f"p50 {summary['p50_ms']}ms p99 {summary['p99_ms']}ms")
    return summary


def bench_sustained_load(model, guide, params, *, num_samples, max_batch,
                         n_requests, n_clients, deadline_ms, log=print):
    """Streaming-service scenario: concurrent clients with a per-request
    deadline. Overload is admission-controlled (shed with `LoadShedError`),
    never dropped: every request either completes or is shed — a queue
    that silently eats requests fails the bench."""
    import threading

    from repro.serve import LoadShedError, MicroBatcher, ServableModel

    servable = ServableModel.from_svi(
        "bench-load", model, guide, params,
        num_samples=num_samples, max_batch=max_batch,
    )
    for b in servable.engine.buckets:
        servable.predict(jax.random.PRNGKey(0), jnp.ones((b, DIM)))

    sizes = request_sizes(n_requests, max(1, max_batch // 4), seed=13)
    counts = {"ok": 0, "shed": 0, "dropped": 0}
    lock = threading.Lock()
    with MicroBatcher(servable.engine, max_wait_ms=2.0) as mb:
        mb.stats = type(mb.stats)(window=mb.stats.window)
        per_client = (len(sizes) + n_clients - 1) // n_clients

        def client(cid):
            for i, n in enumerate(sizes[cid * per_client : (cid + 1) * per_client]):
                x = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(14), cid * 10_000 + i),
                    (n, DIM),
                )
                try:
                    mb.predict(x, timeout=120, deadline_ms=deadline_ms)
                    outcome = "ok"
                except LoadShedError:
                    outcome = "shed"
                except Exception:  # noqa: BLE001 — the contract: never happens
                    outcome = "dropped"
                with lock:
                    counts[outcome] += 1

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        summary = mb.stats.summary()
    out = {
        "scenario": "sustained_load",
        "clients": n_clients,
        "deadline_ms": deadline_ms,
        "wall_s": round(wall_s, 3),
        "ok": counts["ok"],
        "shed": counts["shed"],
        "dropped_requests": counts["dropped"],
        "requests_per_sec": summary["requests_per_sec"],
        "p50_ms": summary["p50_ms"],
        "p99_ms": summary["p99_ms"],
        "shed_rate": round(counts["shed"] / max(len(sizes), 1), 4),
    }
    log(f"  {counts['ok']} ok / {counts['shed']} shed / "
        f"{counts['dropped']} dropped  "
        f"p50 {out['p50_ms']}ms p99 {out['p99_ms']}ms "
        f"shed_rate {out['shed_rate']}")
    assert counts["dropped"] == 0, (
        f"sustained load dropped {counts['dropped']} requests — overload must "
        "shed at admission, never drop"
    )
    assert counts["ok"] + counts["shed"] == len(sizes)
    return out


def bench_refresh_under_traffic(*, max_batch, n_swaps, n_clients, log=print):
    """Streaming-service scenario: hot-swap the servable's params while
    concurrent clients hammer it. Measures ``swap_gap_ms`` — refresh() call
    to the first served response reflecting the new params — and asserts
    the hard contract: zero dropped requests, zero recompiles."""
    import threading

    from repro import distributions as dist, optim
    from repro.core import primitives as P
    from repro.infer import SVI, AutoDelta, Trace_ELBO
    from repro.serve import MicroBatcher, ServableModel

    # AutoDelta => deterministic serving (mu == x @ w_loc + b_loc), so "the
    # new params are live" is an exact check, not a statistical one
    def model(batch):
        x, y = batch["x"], batch.get("y")
        w = P.sample("w", dist.Normal(jnp.zeros(DIM), 1.0).to_event(1))
        b = P.sample("b", dist.Normal(0.0, 1.0))
        with P.plate("B", x.shape[0]):
            mu = P.deterministic("mu", x @ w + b)
            P.sample("y", dist.Normal(mu, 0.1), obs=y)

    key = jax.random.PRNGKey(0)
    x_train = jax.random.normal(key, (64, DIM))
    y_train = x_train @ jnp.arange(1.0, DIM + 1.0) + 0.5
    guide = AutoDelta(model)
    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(1), {"x": x_train, "y": y_train})
    for _ in range(10):
        state, _ = svi.update_jit(state, {"x": x_train, "y": y_train})
    params = svi.optim.get_params(state.optim_state)
    servable = ServableModel.from_svi(
        "bench-refresh", model, guide, params,
        num_samples=1, return_sites=["mu"], max_batch=max_batch,
    )

    probe_x = jnp.ones((1, DIM))
    stop = threading.Event()
    dropped = []
    with MicroBatcher(servable, max_wait_ms=1.0) as mb:
        for b in servable.engine.buckets:
            mb.predict({"x": jnp.ones((b, DIM))}, timeout=120)
        traces_before = servable.num_traces

        def client(cid):
            i = 0
            while not stop.is_set():
                x = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(15), cid * 10_000 + i),
                    (1 + (i % 3), DIM),
                )
                try:
                    mb.predict({"x": x}, timeout=120)
                except Exception as e:  # noqa: BLE001 — the contract: none
                    dropped.append(e)
                    return
                i += 1

        threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
        for t in threads:
            t.start()
        gaps = []
        for swap in range(1, n_swaps + 1):
            # full_like: the replacement tree must carry the SAME avals
            # (shape/dtype/weak_type) as the trained params — that is the
            # no-recompile contract a real checkpoint refresh satisfies
            new_params = {
                "auto_w_loc": jnp.full_like(params["auto_w_loc"], float(swap)),
                "auto_b_loc": jnp.full_like(params["auto_b_loc"], -float(swap)),
            }
            expect = float(DIM * swap - swap)
            t0 = time.perf_counter()
            servable.refresh(params=new_params)
            while True:  # first probe that serves the new posterior
                mu = float(
                    np.asarray(mb.predict({"x": probe_x}, timeout=120)["mu"]).ravel()[0]
                )
                if abs(mu - expect) < 1e-4:
                    gaps.append((time.perf_counter() - t0) * 1e3)
                    break
        stop.set()
        for t in threads:
            t.join()
        summary = mb.stats.summary()
    gaps_sorted = sorted(gaps)
    out = {
        "scenario": "refresh_under_traffic",
        "swaps": n_swaps,
        "clients": n_clients,
        "requests": summary["requests"],
        "dropped_requests": len(dropped),
        "swap_gap_ms": round(sum(gaps) / len(gaps), 3),
        "swap_gap_max_ms": round(gaps_sorted[-1], 3),
        "num_traces": servable.num_traces,
        "recompiles_across_swaps": servable.num_traces - traces_before,
    }
    log(f"  {n_swaps} hot swaps under {summary['requests']} requests: "
        f"swap gap {out['swap_gap_ms']}ms (max {out['swap_gap_max_ms']}ms), "
        f"{out['dropped_requests']} dropped, "
        f"{out['recompiles_across_swaps']} recompiles")
    assert not dropped, f"hot swap dropped {len(dropped)} requests: {dropped[:3]}"
    assert servable.num_traces == traces_before, (
        f"hot swap recompiled: {traces_before} -> {servable.num_traces}"
    )
    assert servable.num_traces == len(servable.buckets_touched)
    return out


def bench_sharding_parity(model, guide, params, *, num_samples, log=print):
    """1-device mesh serving must be bit-identical to unsharded."""
    from repro.distributed.sharding import default_mesh
    from repro.serve import ServableModel

    x = jax.random.normal(jax.random.PRNGKey(9), (6, DIM))
    plain = ServableModel.from_svi(
        "parity-plain", model, guide, params, num_samples=num_samples, max_batch=8
    )
    sharded = ServableModel.from_svi(
        "parity-sharded", model, guide, params, num_samples=num_samples,
        max_batch=8, mesh=default_mesh(),
    )
    key = jax.random.PRNGKey(10)
    o1 = plain.predict(key, x)
    o2 = sharded.predict(key, x)
    bitwise = all(
        bool(jnp.array_equal(a, b, equal_nan=True))
        for a, b in zip(jax.tree_util.tree_leaves(o1), jax.tree_util.tree_leaves(o2))
    )
    log(f"  sharded(1-device mesh) == unsharded: {bitwise}")
    assert bitwise, "sharded serving is not bit-identical to unsharded on 1 device"
    return {"bit_identical": bitwise, "devices": jax.device_count()}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--json", default=str(REPO / "BENCH_serve.json"))
    args = ap.parse_args(argv)

    from repro.launch.compile_cache import (
        compilation_cache_stats,
        enable_compilation_cache,
    )

    cache_dir = enable_compilation_cache()
    if cache_dir is not None:
        print(f"# persistent compilation cache: {cache_dir}")

    if args.smoke:
        train_steps, n_requests, max_batch, num_samples, n_clients = 20, 40, 16, 8, 4
    else:
        train_steps, n_requests, max_batch, num_samples, n_clients = 200, 200, 32, 16, 8

    model, guide, params = make_artifact(train_steps)
    results = {
        "bench": "serve",
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "smoke": bool(args.smoke),
    }

    print("# steady state: bucketed engine vs naive per-request Predictive")
    results["steady_state"] = bench_steady_state(
        model, guide, params, num_samples=num_samples, max_batch=max_batch,
        n_requests=n_requests,
    )
    print("# micro-batcher throughput")
    results["batcher"] = bench_batcher(
        model, guide, params, num_samples=num_samples, max_batch=max_batch,
        n_requests=n_requests, n_clients=n_clients,
    )
    print("# sustained load: deadline admission control under concurrency")
    results["sustained_load"] = bench_sustained_load(
        model, guide, params, num_samples=num_samples, max_batch=max_batch,
        n_requests=n_requests, n_clients=n_clients,
        deadline_ms=50.0 if args.smoke else 100.0,
    )
    print("# refresh under traffic: hot-swap gap + zero-drop/zero-recompile")
    results["refresh_under_traffic"] = bench_refresh_under_traffic(
        max_batch=max_batch, n_swaps=3 if args.smoke else 10,
        n_clients=n_clients,
    )
    print("# sharding parity (1-device mesh)")
    results["sharding"] = bench_sharding_parity(
        model, guide, params, num_samples=num_samples,
    )

    results["compilation_cache"] = compilation_cache_stats()
    print(f"# compilation cache: {results['compilation_cache']}")

    Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
    print(f"\nwrote {args.json}")
    print(f"OK: speedup {results['steady_state']['speedup_steady']}x >= "
          f"{SPEEDUP_FLOOR}x; compiles == buckets; zero dropped requests; "
          f"swap gap {results['refresh_under_traffic']['swap_gap_ms']}ms "
          f"with zero recompiles; sharding bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
