"""Kernel micro-benchmarks: Pallas(interpret) correctness scale sweep + the
jnp-reference wall time (the CPU-measurable proxy; real-TPU numbers come
from the roofline analysis, benchmarks/roofline_table.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ref import (
    categorical_logprob_ref,
    flash_attention_ref,
    ssd_scan_ref,
)


def _time(f, *args, iters=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main(log=print):
    key = jax.random.PRNGKey(0)
    log("# kernel reference-path wall times (CPU) + arithmetic intensities")
    # categorical_logprob: the PPL hot spot at LM vocab sizes
    for T, V in [(4096, 32768), (4096, 151936)]:
        logits = jax.random.normal(key, (T, V))
        toks = jax.random.randint(key, (T,), 0, V)
        f = jax.jit(categorical_logprob_ref)
        dt = _time(f, logits, toks)
        naive_bytes = T * V * 4 * 2  # read logits + write logprobs
        fused_bytes = T * V * 4  # kernel: single streamed read
        log(f"categorical_logprob T={T} V={V}: ref {dt*1e3:.1f} ms; "
            f"HBM bytes naive {naive_bytes/1e9:.2f} GB -> fused {fused_bytes/1e9:.2f} GB "
            f"(kernel saves {(1-fused_bytes/naive_bytes)*100:.0f}%)")
    # flash attention
    B, H, K, S, d = 1, 8, 2, 2048, 64
    q = jax.random.normal(key, (B, H, S, d), jnp.bfloat16)
    k = jax.random.normal(key, (B, K, S, d), jnp.bfloat16)
    v = jax.random.normal(key, (B, K, S, d), jnp.bfloat16)
    dt = _time(jax.jit(flash_attention_ref), q, k, v)
    scores_bytes = B * H * S * S * 4
    log(f"flash_attention S={S}: ref {dt*1e3:.1f} ms; materialized scores "
        f"{scores_bytes/1e9:.2f} GB avoided by the kernel")
    # ssd
    b, s, h, p, n = 1, 4096, 24, 64, 128
    x = jax.random.normal(key, (b, s, h, p))
    dtm = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    A = -jnp.exp(jax.random.normal(key, (h,)))
    Bm = jax.random.normal(key, (b, s, n))
    Cm = jax.random.normal(key, (b, s, n))
    f = jax.jit(lambda *a: ssd_scan_ref(*a, chunk=128))
    dt = _time(f, x, dtm, A, Bm, Cm)
    log(f"ssd_scan s={s} heads={h}: ref {dt*1e3:.1f} ms "
        f"(chunked quadratic, MXU-shaped)")
    return []


if __name__ == "__main__":
    main()
