"""Multi-chain MCMC engine + convergence diagnostics.

Diagnostics are validated against hand-computed references (explicit
numpy transcriptions of the split-R̂ formula) and known asymptotics
(iid chains -> ESS ~ total draws, AR(1) chains -> ESS far below it);
the engine is checked for chain layout, trace-count, sharded/vectorized
bit-identity on a 1-device mesh, and posterior correctness with 4 chains.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import (
    HMC,
    MCMC,
    NUTS,
    Predictive,
    effective_sample_size,
    split_rhat,
)

DATA = jnp.asarray([1.0, 2.0, 3.0, 2.5, 1.5])
POST_MEAN = float(DATA.sum() / (len(DATA) + 1 / 100.0))
POST_SD = float((1.0 / (len(DATA) + 0.01)) ** 0.5)


def normal_model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    with P.plate("N", data.shape[0]):
        P.sample("obs", dist.Normal(loc, 1.0), obs=data)


def small_hmc():
    return HMC(normal_model, max_num_steps=16)


# ---------------------------------------------------------------------------
# diagnostics: split-R̂
# ---------------------------------------------------------------------------


def test_split_rhat_hand_computed():
    """2 chains x 4 draws, reference computed by hand from the split-chain
    formula: split -> 4 half-chains of 2 draws; W = mean within-chain var,
    B/n = var of half-chain means; rhat = sqrt(((n-1)/n * W + B/n) / W)."""
    x = jnp.asarray([[1.0, 2.0, 3.0, 4.0], [3.0, 4.0, 5.0, 6.0]])
    halves = np.asarray([[1.0, 2.0], [3.0, 4.0], [3.0, 4.0], [5.0, 6.0]])
    n = halves.shape[1]
    w = halves.var(axis=1, ddof=1).mean()
    b_over_n = halves.mean(axis=1).var(ddof=1)
    expected = np.sqrt(((n - 1) / n * w + b_over_n) / w)
    assert float(split_rhat(x)) == pytest.approx(float(expected), rel=1e-5)


def test_split_rhat_well_mixed_chains_near_one():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1000))
    assert float(split_rhat(x)) == pytest.approx(1.0, abs=0.02)


def test_split_rhat_shifted_chains_much_greater_than_one():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 500))
    shifted = x + 10.0 * jnp.arange(4.0)[:, None]
    assert float(split_rhat(shifted)) > 3.0


def test_split_rhat_detects_within_chain_drift():
    """A strong trend inside each chain inflates split-R̂ even though the
    chains agree with each other — that's what the split buys."""
    trend = jnp.linspace(0.0, 8.0, 600)[None, :]
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 600)) + trend
    assert float(split_rhat(x)) > 1.5


def test_split_rhat_event_shapes():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 400, 3))
    r = split_rhat(x)
    assert r.shape == (3,)
    assert np.allclose(np.asarray(r), 1.0, atol=0.05)


# ---------------------------------------------------------------------------
# diagnostics: effective sample size
# ---------------------------------------------------------------------------


def test_ess_iid_close_to_total_draws():
    m, n = 4, 1000
    x = jax.random.normal(jax.random.PRNGKey(4), (m, n))
    ess = float(effective_sample_size(x))
    assert 0.5 * m * n < ess <= 1.2 * m * n


def test_ess_ar1_far_below_total_draws():
    """AR(1) with rho=0.9 has asymptotic ESS factor (1-rho)/(1+rho) ~ 0.053;
    the estimate must come out far below the raw draw count."""
    m, n, rho = 4, 1000, 0.9
    eps = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (m, n)))
    x = np.zeros((m, n))
    x[:, 0] = eps[:, 0]
    for t in range(1, n):
        x[:, t] = rho * x[:, t - 1] + np.sqrt(1 - rho**2) * eps[:, t]
    ess = float(effective_sample_size(jnp.asarray(x)))
    assert ess < 0.3 * m * n
    # and in the right ballpark of the theoretical factor
    assert ess == pytest.approx(m * n * (1 - rho) / (1 + rho), rel=1.0)


def test_tail_ess_iid_reasonable():
    m, n = 4, 1000
    x = jax.random.normal(jax.random.PRNGKey(6), (m, n))
    tail = float(effective_sample_size(x, kind="tail"))
    assert 0.2 * m * n < tail <= 1.2 * m * n


def test_ess_kind_validation():
    x = jnp.zeros((2, 10))
    with pytest.raises(ValueError):
        effective_sample_size(x, kind="bogus")


# ---------------------------------------------------------------------------
# diagnostics: degenerate inputs must give documented values, not garbage
# ---------------------------------------------------------------------------


def test_diagnostics_single_chain_well_defined():
    x = jax.random.normal(jax.random.PRNGKey(10), (1, 400))
    assert float(split_rhat(x)) == pytest.approx(1.0, abs=0.05)
    assert float(effective_sample_size(x)) > 100
    assert float(effective_sample_size(x, kind="tail")) > 50


def test_diagnostics_length_one_chain_nan_not_crash():
    x = jnp.ones((2, 1))
    assert bool(jnp.isnan(split_rhat(x)))
    for kind in ("bulk", "tail", "raw"):
        assert bool(jnp.isnan(effective_sample_size(x, kind=kind)))


def test_diagnostics_too_few_draws_nan():
    # split halves need >= 2 draws each; below 4 everything is documented NaN
    x = jax.random.normal(jax.random.PRNGKey(11), (4, 3))
    assert bool(jnp.isnan(split_rhat(x)))
    assert bool(jnp.isnan(effective_sample_size(x)))


def test_diagnostics_constant_chain():
    const = jnp.full((4, 100), 2.5)
    # no variance at all: R̂ undefined (NaN), ESS = total draws by convention
    assert bool(jnp.isnan(split_rhat(const)))
    for kind in ("bulk", "tail", "raw"):
        assert float(effective_sample_size(const, kind=kind)) == 400.0


def test_diagnostics_constant_distinct_chains_inf_rhat():
    # chains frozen at different values: maximally unconverged -> +inf
    x = jnp.broadcast_to(jnp.arange(4.0)[:, None], (4, 100))
    assert bool(jnp.isinf(split_rhat(x)))


def test_diagnostics_nan_draws_propagate():
    """A NaN draw (e.g. a diverged chain) must surface as NaN diagnostics —
    rank-normalization and tail indicators would otherwise silently convert
    it into a finite, trustworthy-looking number."""
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 100)).at[1, 3].set(jnp.nan)
    assert bool(jnp.isnan(split_rhat(x)))
    for kind in ("bulk", "tail", "raw"):
        assert bool(jnp.isnan(effective_sample_size(x, kind=kind)))
    # event-shaped input: only the poisoned column goes NaN
    y = jax.random.normal(jax.random.PRNGKey(13), (4, 100, 2)).at[0, 0, 1].set(jnp.nan)
    ess = effective_sample_size(y)
    assert not bool(jnp.isnan(ess[0]))
    assert bool(jnp.isnan(ess[1]))


# ---------------------------------------------------------------------------
# engine: chain layout, trace count, sharding parity
# ---------------------------------------------------------------------------


def test_multichain_shapes_and_grouping():
    mcmc = MCMC(small_hmc(), num_warmup=50, num_samples=40, num_chains=3)
    flat = mcmc.run(jax.random.PRNGKey(0), DATA)
    assert flat["loc"].shape == (120,)
    grouped = mcmc.get_samples(group_by_chain=True)
    assert grouped["loc"].shape == (3, 40)
    extras = mcmc.get_extra_fields()
    for name in ("accept_prob", "diverging", "num_steps", "potential_energy"):
        assert extras[name].shape == (3, 40)
    assert mcmc.get_extra_fields(group_by_chain=False)["accept_prob"].shape == (120,)
    # the whole run (init + warmup + collection) traced exactly once
    assert mcmc.num_traces == 1


def test_trace_count_independent_of_num_samples():
    counts = []
    for num_samples in (20, 80):
        mcmc = MCMC(small_hmc(), num_warmup=30, num_samples=num_samples)
        mcmc.run(jax.random.PRNGKey(0), DATA)
        counts.append(mcmc.num_traces)
    assert counts == [1, 1]


def test_thinning_shapes():
    mcmc = MCMC(small_hmc(), num_warmup=30, num_samples=25, thinning=2)
    s = mcmc.run(jax.random.PRNGKey(0), DATA)
    assert s["loc"].shape == (25,)


def test_sharded_matches_vectorized_on_one_device_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    runs = {}
    for method, kw in (("vectorized", {"mesh": None}), ("sharded", {"mesh": mesh})):
        mcmc = MCMC(
            small_hmc(), num_warmup=60, num_samples=50, num_chains=2, **kw,
        )
        mcmc.run(jax.random.PRNGKey(0), DATA)
        runs[method] = (
            mcmc.get_samples(group_by_chain=True),
            mcmc.get_extra_fields(),
        )
    s_vec, e_vec = runs["vectorized"]
    s_sh, e_sh = runs["sharded"]
    assert jnp.array_equal(s_vec["loc"], s_sh["loc"])  # bit-for-bit
    assert jnp.array_equal(e_vec["accept_prob"], e_sh["accept_prob"])


def test_chain_method_validation():
    with pytest.warns(FutureWarning), pytest.raises(ValueError):
        MCMC(small_hmc(), 10, 10, chain_method="pmap")
    with pytest.raises(ValueError):
        MCMC(small_hmc(), 10, 10, mesh="tpu")


def test_fused_sharded_matches_vectorized_with_kernels(monkeypatch):
    """Sharded/vectorized bit-identity must survive the fused path with the
    Pallas kernel body enabled (interpret backend): the sharding constraint
    is a layout annotation, never a math change."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    mesh = jax.make_mesh((1,), ("data",))
    runs = {}
    for method, kw in (("vectorized", {"mesh": None}), ("sharded", {"mesh": mesh})):
        mcmc = MCMC(
            small_hmc(), num_warmup=40, num_samples=30, num_chains=2,
            fused=True, **kw,
        )
        mcmc.run(jax.random.PRNGKey(0), DATA)
        runs[method] = (mcmc.get_samples(group_by_chain=True), mcmc.get_extra_fields())
    s_vec, e_vec = runs["vectorized"]
    s_sh, e_sh = runs["sharded"]
    assert jnp.array_equal(s_vec["loc"], s_sh["loc"])  # bit-for-bit
    assert jnp.array_equal(e_vec["accept_prob"], e_sh["accept_prob"])
    assert jnp.array_equal(e_vec["num_steps"], e_sh["num_steps"])


def test_num_traces_one_under_chees():
    """ChEES cross-chain adaptation must not break the compile-once
    contract: one trace per executable, reused across repeat runs."""
    kernel = HMC(normal_model, max_num_steps=32, adapt_trajectory_length=True)
    mcmc = MCMC(kernel, num_warmup=50, num_samples=40, num_chains=4, fused=True)
    mcmc.run(jax.random.PRNGKey(0), DATA)
    assert mcmc.num_traces == 1
    # same shapes, fresh key/data -> the cached executable is reused
    mcmc.run(jax.random.PRNGKey(1), DATA + 0.5)
    assert mcmc.num_traces == 1


def test_fused_vs_legacy_same_posterior():
    """The fused driver is a new execution strategy, not a new sampler: both
    paths recover the same conjugate posterior."""
    post = {}
    for fused in (False, True):
        mcmc = MCMC(
            HMC(normal_model, max_num_steps=16), num_warmup=150,
            num_samples=150, num_chains=2, fused=fused,
        )
        mcmc.run(jax.random.PRNGKey(3), DATA)
        post[fused] = mcmc.get_samples()["loc"]
    for fused, draws in post.items():
        assert float(draws.mean()) == pytest.approx(POST_MEAN, abs=0.2), fused
        assert float(draws.std()) == pytest.approx(POST_SD, abs=0.15), fused


def test_init_params_broadcast_and_potential_fn():
    mcmc = MCMC(small_hmc(), num_warmup=40, num_samples=30, num_chains=2)
    s = mcmc.run(jax.random.PRNGKey(0), DATA, init_params={"loc": jnp.asarray(0.5)})
    assert s["loc"].shape == (60,)

    def pe(z):
        return 0.5 * jnp.sum(jnp.square(z["x"]))

    kernel = HMC(potential_fn=pe, max_num_steps=16)
    mcmc = MCMC(kernel, num_warmup=40, num_samples=60, num_chains=2)
    with pytest.raises(ValueError):
        mcmc.run(jax.random.PRNGKey(1))
    s = mcmc.run(jax.random.PRNGKey(1), init_params={"x": jnp.zeros(2)})
    assert s["x"].shape == (120, 2)


def test_multichain_posterior_and_diagnostics():
    mcmc = MCMC(
        NUTS(normal_model, max_tree_depth=5),
        num_warmup=150, num_samples=150, num_chains=4,
    )
    mcmc.run(jax.random.PRNGKey(7), DATA)
    g = mcmc.get_samples(group_by_chain=True)["loc"]
    assert float(g.mean()) == pytest.approx(POST_MEAN, abs=0.15)
    assert float(g.std()) == pytest.approx(POST_SD, abs=0.15)
    assert float(split_rhat(g)) < 1.1
    assert float(effective_sample_size(g)) > 50
    stats = mcmc.summary(print_table=False)
    assert set(stats) == {"loc"}
    assert {"mean", "std", "n_eff", "ess_tail", "r_hat"} <= set(stats["loc"])


def test_predictive_chain_shaped_fanout():
    post = {"loc": jnp.zeros((2, 5))}
    out = Predictive(normal_model, posterior_samples=post, batch_ndims=2)(
        jax.random.PRNGKey(8), DATA
    )
    assert out["obs"].shape == (2, 5, len(DATA))
    # flat draws keep working unchanged
    out1 = Predictive(normal_model, posterior_samples={"loc": jnp.zeros(7)})(
        jax.random.PRNGKey(9), DATA
    )
    assert out1["obs"].shape == (7, len(DATA))
