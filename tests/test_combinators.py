"""Inference-combinator semantics (ISSUE 10): programs as values.

`primitive`/`compose`/`extend`/`propose`/`resample` are the algebra the SMC
engine is assembled from; these tests pin their weight/trace semantics on
models with closed-form answers — the propose weight against an analytic
marginal likelihood, compose/extend trace merging (duplicate sites must
raise), the resample combinator's population-only contract, and the
`ImportanceSampling` engine (the degenerate one-step propose) against both
the analytic evidence and its own documented accessors.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import ImportanceSampling, compose, extend, primitive, propose
from repro.infer import resample as resample_combinator
from repro.infer.combinators import (
    Population,
    Primitive,
    effective_sample_size,
)

KEY = jax.random.PRNGKey(0)


# conjugate pair: z ~ N(0,1), y | z ~ N(z,1)  =>  p(y) = N(y; 0, sqrt(2))
Y_OBS = jnp.float32(0.5)
LOG_Z_EXACT = float(dist.Normal(0.0, jnp.sqrt(2.0)).log_prob(Y_OBS))


def model():
    z = P.sample("z", dist.Normal(0.0, 1.0))
    P.sample("y", dist.Normal(z, 1.0), obs=Y_OBS)
    return z


def guide():
    # the exact posterior N(y/2, 1/sqrt(2)): zero-variance importance weights
    return P.sample("z", dist.Normal(Y_OBS / 2.0, 1.0 / jnp.sqrt(2.0)))


# ---------------------------------------------------------------------------
# primitive
# ---------------------------------------------------------------------------


def test_primitive_run_returns_trace_output_weight():
    r = primitive(model).run(KEY, {})
    assert "z" in r.trace and "y" in r.trace
    # weight = observed log prob only
    expected = float(dist.Normal(r.trace["z"]["value"], 1.0).log_prob(Y_OBS))
    assert np.isclose(float(r.log_weight), expected, rtol=1e-6)
    assert float(r.output) == float(r.trace["z"]["value"])


def test_primitive_is_idempotent():
    p = primitive(model)
    assert primitive(p) is p
    assert isinstance(p, Primitive)


# ---------------------------------------------------------------------------
# compose / extend
# ---------------------------------------------------------------------------


def test_compose_merges_traces_and_adds_weights():
    def f1():
        x = P.sample("x", dist.Normal(0.0, 1.0))
        P.sample("obs1", dist.Normal(x, 1.0), obs=jnp.float32(0.1))
        return x

    def f2(x):
        y = P.sample("y", dist.Normal(x, 1.0))
        P.sample("obs2", dist.Normal(y, 1.0), obs=jnp.float32(0.2))
        return y

    r = compose(f2, f1).run(KEY, {})
    assert set(r.trace.nodes) >= {"x", "y", "obs1", "obs2"}
    w1 = float(dist.Normal(r.trace["x"]["value"], 1.0).log_prob(0.1))
    w2 = float(dist.Normal(r.trace["y"]["value"], 1.0).log_prob(0.2))
    assert np.isclose(float(r.log_weight), w1 + w2, rtol=1e-5)


def test_compose_duplicate_site_raises():
    def f1():
        return P.sample("z", dist.Normal(0.0, 1.0))

    def f2(z):
        return P.sample("z", dist.Normal(z, 1.0))

    with pytest.raises(RuntimeError, match="duplicate site"):
        compose(f2, f1).run(KEY, {})


def test_extend_is_compose_with_swapped_roles():
    def p_prog():
        return P.sample("a", dist.Normal(0.0, 1.0))

    def f_prog(a):
        return P.sample("b", dist.Normal(a, 1.0))

    r = extend(p_prog, f_prog).run(KEY, {})
    assert "a" in r.trace and "b" in r.trace


# ---------------------------------------------------------------------------
# propose
# ---------------------------------------------------------------------------


def test_propose_weight_is_importance_weight():
    """With the exact-posterior guide the importance weight is constant
    (= log Z) for every particle — the zero-variance property."""
    prog = propose(primitive(model), primitive(guide))
    weights = [
        float(prog.run(jax.random.PRNGKey(i), {}).log_weight) for i in range(20)
    ]
    assert np.allclose(weights, LOG_Z_EXACT, atol=1e-5), (weights[:3], LOG_Z_EXACT)


def test_propose_guide_value_replayed_into_model():
    r = propose(primitive(model), primitive(guide)).run(KEY, {})
    # the model's z is the guide's draw, and both ended up in the trace
    assert float(r.output) == float(r.trace["z"]["value"])


# ---------------------------------------------------------------------------
# resample combinator
# ---------------------------------------------------------------------------


def test_resample_validates_arguments():
    with pytest.raises(ValueError):
        resample_combinator(primitive(model), ess_threshold=1.5)
    with pytest.raises(ValueError):
        resample_combinator(primitive(model), method="stratified")


def test_resample_rejects_single_particle_run():
    prog = resample_combinator(primitive(model))
    with pytest.raises(TypeError):
        prog.run(KEY, {})


def _step_population(ess_threshold, log_weights):
    """Drive one resample(primitive(step)) population step from a synthetic
    incoming population and report whether resampling triggered."""

    def step(carry):
        x = P.sample("x", dist.Normal(carry, 1.0))
        return x

    n = log_weights.shape[0]
    prog = resample_combinator(primitive(step), ess_threshold=ess_threshold)
    pop = Population(jnp.zeros(n), jnp.asarray(log_weights, jnp.float32))
    _, aux = jax.jit(
        lambda k, p: prog.run_population(k, {}, p, ())
    )(KEY, pop)
    return aux


def test_ess_boundary_equal_weights_never_resample():
    """Equal weights sit exactly at ESS == N; the trigger is strict `<`, so
    even ess_threshold=1.0 (resample 'always') must not fire — resampling a
    uniform population is pure ancestry noise."""
    aux = _step_population(1.0, jnp.zeros(64))
    assert not bool(aux.resampled)
    assert float(aux.log_z_incr) == 0.0


def test_skewed_weights_trigger_resample_and_reset():
    lw = jnp.concatenate([jnp.zeros(4), jnp.full(60, -30.0)])
    aux = _step_population(0.5, lw)
    assert bool(aux.resampled)
    # logZ increment flushed at the event: logsumexp(W) - log N
    expected = float(jax.scipy.special.logsumexp(lw) - jnp.log(64.0))
    assert np.isclose(float(aux.log_z_incr), expected, rtol=1e-5)


def test_threshold_zero_never_resamples():
    lw = jnp.concatenate([jnp.zeros(2), jnp.full(62, -30.0)])
    aux = _step_population(0.0, lw)
    assert not bool(aux.resampled)


def test_effective_sample_size_contract():
    assert float(effective_sample_size(jnp.zeros(128))) == 128.0
    one = jnp.full(16, -jnp.inf).at[3].set(0.0)
    assert np.isclose(float(effective_sample_size(one)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# ImportanceSampling: the degenerate one-step propose
# ---------------------------------------------------------------------------


def test_importance_sampling_log_evidence():
    eng = ImportanceSampling(model, guide, num_particles=4000)
    eng.run(jax.random.PRNGKey(2))
    assert np.isclose(float(eng.log_evidence()), LOG_Z_EXACT, atol=1e-4)
    # exact-posterior guide => ESS == N
    assert np.isclose(float(eng.effective_sample_size()), 4000.0, rtol=1e-4)


def test_importance_sampling_no_guide_prior_proposal():
    eng = ImportanceSampling(model, num_particles=20000)
    eng.run(jax.random.PRNGKey(3))
    assert np.isclose(float(eng.log_evidence()), LOG_Z_EXACT, atol=0.05)


def test_importance_sampling_accessors():
    eng = ImportanceSampling(model, guide, num_particles=64)
    assert eng.run(jax.random.PRNGKey(4)) is eng  # fluent (the legacy contract)
    assert eng.get_samples()["z"].shape == (64,)
    assert eng.get_samples(group_by_chain=True)["z"].shape == (1, 64)
    assert eng.log_weights.shape == (64,)
    assert eng.num_traces == 1  # vmap traces the particle program once
    draws = eng.resample(jax.random.PRNGKey(5), 32)
    assert draws["z"].shape == (32,)


def test_importance_sampling_sharded_matches_vectorized():
    mesh = jax.make_mesh((1,), ("data",))
    vec = ImportanceSampling(model, guide, num_particles=256)
    sh = ImportanceSampling(model, guide, num_particles=256, mesh=mesh)
    vec.run(jax.random.PRNGKey(6))
    sh.run(jax.random.PRNGKey(6))
    assert jnp.array_equal(vec.log_weights, sh.log_weights)
    assert jnp.array_equal(vec.get_samples()["z"], sh.get_samples()["z"])
