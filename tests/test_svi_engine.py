"""Sharded multi-particle SVI engine + kernel backend dispatch (PR 1).

Covers the ISSUE acceptance list: sharded vs single-device ELBO bit-for-bit
on a 1-device mesh; plate subsampling rescaling under the jitted update with
indices in the pure signature (no per-step retracing); kernel dispatch
falling back to the reference backend on CPU; and the unified particle path
(RenyiELBO num_particles == 1 guard)."""
import jax
import jax.numpy as jnp
import pytest

from repro import distributions as dist
from repro import optim
from repro.core import primitives as P
from repro.infer import (
    SVI,
    AutoNormal,
    RenyiELBO,
    Trace_ELBO,
    TraceGraph_ELBO,
    TraceMeanField_ELBO,
)
from repro.kernels import ops
from repro.kernels.ref import categorical_logprob_ref, flash_attention_ref

DATA = jnp.asarray([1.0, 2.0, 3.0, 2.5, 1.5])


def normal_model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    with P.plate("N", data.shape[0]):
        P.sample("obs", dist.Normal(loc, 1.0), obs=data)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def trained_params():
    guide = AutoNormal(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO())
    state, _ = svi.run(jax.random.PRNGKey(0), 100, DATA)
    return guide, svi.optim.get_params(state.optim_state)


# -- sharded particle path ---------------------------------------------------


@pytest.mark.parametrize("Loss", [Trace_ELBO, TraceMeanField_ELBO, TraceGraph_ELBO])
def test_sharded_elbo_bitwise_equals_local_on_1device_mesh(mesh, trained_params, Loss):
    guide, params = trained_params
    key = jax.random.PRNGKey(42)
    local = Loss(num_particles=8).loss(key, params, normal_model, guide, DATA)
    sharded = Loss(num_particles=8, mesh=mesh).loss(key, params, normal_model, guide, DATA)
    assert float(local) == float(sharded)  # bit-for-bit


def test_sharded_renyi_bitwise_equals_local(mesh, trained_params):
    guide, params = trained_params
    key = jax.random.PRNGKey(43)
    local = RenyiELBO(num_particles=8).loss(key, params, normal_model, guide, DATA)
    sharded = RenyiELBO(num_particles=8, mesh=mesh).loss(
        key, params, normal_model, guide, DATA
    )
    assert float(local) == float(sharded)


def test_indivisible_particle_count_still_correct(mesh, trained_params):
    """Particle counts that don't divide the mesh axis replicate instead of
    failing, and the value is unchanged."""
    guide, params = trained_params
    key = jax.random.PRNGKey(44)
    local = Trace_ELBO(num_particles=3).loss(key, params, normal_model, guide, DATA)
    sharded = Trace_ELBO(num_particles=3, mesh=mesh).loss(
        key, params, normal_model, guide, DATA
    )
    assert float(local) == float(sharded)


def test_renyi_single_particle_unified_guard(trained_params):
    """num_particles == 1 flows through the shared path: the Renyi bound
    degenerates to the plain one-sample ELBO, bitwise."""
    guide, params = trained_params
    key = jax.random.PRNGKey(45)
    l_trace = Trace_ELBO(num_particles=1).loss(key, params, normal_model, guide, DATA)
    l_renyi = RenyiELBO(num_particles=1).loss(key, params, normal_model, guide, DATA)
    assert float(l_trace) == float(l_renyi)


def test_elbo_rejects_bad_particle_count():
    with pytest.raises(ValueError):
        Trace_ELBO(num_particles=0)
    with pytest.raises(ValueError):
        RenyiELBO(alpha=1.0)


# -- subsampling + jit-stable update signature -------------------------------


N_FULL, N_BATCH = 12, 4


def subsampled_model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    with P.plate("N", N_FULL, subsample_size=N_BATCH) as idx:
        P.sample("obs", dist.Normal(loc, 1.0), obs=data[idx])


def full_model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    with P.plate("N", N_FULL):
        P.sample("obs", dist.Normal(loc, 1.0), obs=data)


def test_plate_subsampling_rescales_under_jitted_update():
    """With constant data the N/B-rescaled minibatch ELBO equals the
    full-data ELBO for any index set — checked through the jitted update."""
    data = jnp.full((N_FULL,), 1.5)
    key = jax.random.PRNGKey(0)

    guide_s = AutoNormal(subsampled_model)
    svi_s = SVI(subsampled_model, guide_s, optim.Adam(0.05), Trace_ELBO())
    state_s = svi_s.init(key, data)
    idx = jnp.asarray([2, 5, 7, 11])
    _, loss_sub = svi_s.update_jit(state_s, data, subsample={"N": idx})

    guide_f = AutoNormal(full_model)
    svi_f = SVI(full_model, guide_f, optim.Adam(0.05), Trace_ELBO())
    state_f = svi_f.init(key, data)
    _, loss_full = svi_f.update_jit(state_f, data)

    assert float(loss_sub) == pytest.approx(float(loss_full), rel=1e-6)


def test_update_jit_no_retrace_across_minibatches():
    """Fresh subsample indices each step reuse one compiled executable."""
    data = jnp.arange(float(N_FULL))
    guide = AutoNormal(subsampled_model)
    svi = SVI(subsampled_model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=2))
    state = svi.init(jax.random.PRNGKey(0), data)
    for i in range(6):
        idx = jax.random.choice(
            jax.random.fold_in(jax.random.PRNGKey(1), i), N_FULL, (N_BATCH,), replace=False
        )
        state, loss = svi.update_jit(state, data, subsample={"N": idx})
        assert jnp.isfinite(loss)
    assert svi.update_jit._cache_size() == 1


def test_run_reuses_one_executable():
    guide = AutoNormal(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=2))
    svi.run(jax.random.PRNGKey(0), 10, DATA)
    svi.run(jax.random.PRNGKey(1), 10, DATA)  # second run: same cache entry
    assert svi.update_jit._cache_size() == 1


def test_sharded_svi_end_to_end(mesh):
    """mesh= turns on sharded state + sharded particles; converges on the
    1-device mesh exactly like the local path."""
    guide = AutoNormal(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=4), mesh=mesh)
    state, losses = svi.run(jax.random.PRNGKey(0), 300, DATA)
    assert losses[-1] < losses[0]
    assert svi.update_jit._cache_size() == 1
    post_mean = float(DATA.sum() / (len(DATA) + 1 / 100.0))
    assert float(svi.get_params(state)["auto_loc_loc"]) == pytest.approx(post_mean, abs=0.2)


def test_python_scalar_param_init():
    """P.param with a python-float init must survive SVI.init's leaf
    canonicalization and still train compile-once."""

    def model():
        P.sample("x", dist.Normal(0.0, 1.0), obs=jnp.asarray(0.7))

    def guide():
        P.param("loc", 0.0)

    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0))
    state, loss = svi.update_jit(state)
    state, loss = svi.update_jit(state)
    assert jnp.isfinite(loss) and svi.update_jit._cache_size() == 1


def test_mesh_without_data_axis_works():
    """Generic mesh axis names fall back to the first axis instead of
    crashing on a missing 'data' axis."""
    odd_mesh = jax.make_mesh((1,), ("x",))
    guide = AutoNormal(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=4), mesh=odd_mesh)
    state = svi.init(jax.random.PRNGKey(0), DATA)
    state, loss = svi.update_jit(state, DATA)
    assert jnp.isfinite(loss)


def test_mesh_svi_does_not_mutate_shared_loss(mesh):
    """SVI(mesh=...) must not bind the caller's estimator to its mesh."""
    shared = Trace_ELBO(num_particles=4)
    SVI(normal_model, AutoNormal(normal_model), optim.Adam(0.05), shared, mesh=mesh)
    assert shared.mesh is None


def test_bad_subsample_shape_raises():
    data = jnp.arange(float(N_FULL))
    guide = AutoNormal(subsampled_model)
    svi = SVI(subsampled_model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0), data)
    with pytest.raises(ValueError, match="subsample indices"):
        svi.update(state, data, subsample={"N": jnp.asarray([0, 1])})  # wrong length


def test_typod_subsample_key_raises():
    """A subsample key naming no plate must fail loudly, not silently train
    on the plate's own random indices (or corrupt a sample site)."""
    data = jnp.arange(float(N_FULL))
    guide = AutoNormal(subsampled_model)
    svi = SVI(subsampled_model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0), data)
    with pytest.raises(KeyError, match="match no plate"):
        svi.update(state, data, subsample={"n": jnp.arange(N_BATCH)})  # 'n' != 'N'
    with pytest.raises(KeyError, match="match no plate"):
        svi.update(state, data, subsample={"loc": jnp.arange(N_BATCH)})  # latent name


# -- kernel backend dispatch -------------------------------------------------


def test_backend_resolves_to_reference_on_cpu(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert jax.default_backend() != "tpu"
    assert ops.resolve_backend() == "reference"


def test_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert ops.resolve_backend() == "interpret"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    assert ops.resolve_backend() == "reference"
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    with pytest.warns(FutureWarning, match="REPRO_KERNEL_BACKEND"):
        assert ops.resolve_backend() == "interpret"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    with pytest.warns(FutureWarning, match="deprecated"):
        assert ops.resolve_backend() == "tpu"


def test_legacy_flag_warns_on_surprising_values(monkeypatch):
    """Any REPRO_PALLAS_INTERPRET value other than 0/false means interpret —
    historically silently. The resolution is unchanged (compatibility) but now
    warns, naming the value, what it resolved to, and the replacement env var."""
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    for value, expect in [("interpret", "interpret"), ("2", "interpret"),
                          ("tpu", "interpret"), ("false", "tpu")]:
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", value)
        with pytest.warns(FutureWarning, match="REPRO_PALLAS_INTERPRET"):
            assert ops.resolve_backend() == expect, value
    # the modern env var takes precedence and never warns
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert ops.resolve_backend() == "reference"


def test_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown kernel backend"):
        ops.resolve_backend("mosaic-gpu")


def test_reference_dispatch_matches_oracle_bitwise(monkeypatch):
    """On CPU the default path IS ref.py — outputs must be identical."""
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (16, 64))
    toks = jax.random.randint(jax.random.fold_in(key, 1), (16,), 0, 64)
    out = ops.categorical_logprob(logits, toks)
    assert jnp.array_equal(out, jax.jit(categorical_logprob_ref)(logits, toks))


def test_reference_flash_attention_matches_interpret():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 64, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 32))
    ref_out = ops.flash_attention(q, k, v, backend="reference")
    interp_out = ops.flash_attention(q, k, v, block_q=32, block_k=32, backend="interpret")
    assert jnp.allclose(ref_out, interp_out, atol=1e-4)
    assert jnp.allclose(ref_out, flash_attention_ref(q, k, v), atol=1e-6)


def test_backend_support_matrix_complete():
    m = ops.backend_support_matrix()
    assert set(m) == {
        "flash_attention",
        "categorical_logprob",
        "ssd_scan",
        "semiring_matmul",
        "hmm_scan",
        "leapfrog",
        "gaussian_combine",
        "gaussian_scan",
        "resample",
    }
    for row in m.values():
        assert set(row) == set(ops.BACKENDS)
