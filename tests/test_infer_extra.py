"""TraceGraph_ELBO (variance-reduced score function) and the reparam
handler (decentering)."""
import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro import optim
from repro.core import primitives as P
from repro.core.handlers import seed, trace
from repro.core.reparam import LocScaleReparam, reparam
from repro.infer import SVI, AutoNormal, Trace_ELBO, TraceGraph_ELBO


def test_tracegraph_matches_trace_elbo_value():
    """For fully reparameterizable models the ELBO value is identical."""

    def model(data):
        loc = P.sample("loc", dist.Normal(0.0, 10.0))
        with P.plate("N", data.shape[0]):
            P.sample("obs", dist.Normal(loc, 1.0), obs=data)

    data = jnp.asarray([1.0, 2.0, 3.0])
    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0), data)
    params = svi.optim.get_params(state.optim_state)
    key = jax.random.PRNGKey(1)
    l1 = Trace_ELBO().loss(key, params, model, guide, data)
    l2 = TraceGraph_ELBO().loss(key, params, model, guide, data)
    assert jnp.allclose(l1, l2, atol=1e-5)


def test_tracegraph_discrete_guide_converges():
    def model():
        z = P.sample("z", dist.Bernoulli(probs=0.5))
        P.sample("x", dist.Normal(z * 2.0, 0.5), obs=jnp.asarray(2.1))

    def guide():
        q = P.param("q", jnp.asarray(0.4), constraint=dist.constraints.unit_interval)
        P.sample("z", dist.Bernoulli(probs=q))

    svi = SVI(model, guide, optim.Adam(0.05), TraceGraph_ELBO(num_particles=16))
    state, _ = svi.run(jax.random.PRNGKey(4), 600)
    assert float(svi.get_params(state)["q"]) > 0.9


def test_tracegraph_gradient_variance_reduced():
    """Plate decomposition must cut score-gradient variance vs Trace_ELBO
    on a model with many independent discrete latents."""

    def model(data):
        with P.plate("N", data.shape[0]):
            z = P.sample("z", dist.Bernoulli(probs=0.5 * jnp.ones(data.shape[0])))
            P.sample("x", dist.Normal(z, 0.5), obs=data)

    def guide(data):
        q = P.param(
            "q", 0.5 * jnp.ones(data.shape[0]), constraint=dist.constraints.unit_interval
        )
        with P.plate("N", data.shape[0]):
            P.sample("z", dist.Bernoulli(probs=q))

    data = (jax.random.uniform(jax.random.PRNGKey(0), (16,)) > 0.5).astype(jnp.float32)
    params = {"q": jnp.zeros(16)}  # unconstrained logit 0 -> q=0.5

    def grad_at(Loss, key):
        def loss_fn(p):
            return Loss.loss_with_surrogate(key, p, model, guide, data)[1]
        return jax.grad(loss_fn)(params)["q"]

    keys = jax.random.split(jax.random.PRNGKey(7), 512)
    g_naive = jax.vmap(lambda k: grad_at(Trace_ELBO(), k))(keys)
    g_graph = jax.vmap(lambda k: grad_at(TraceGraph_ELBO(), k))(keys)
    v_naive = float(jnp.mean(jnp.var(g_naive, axis=0)))
    v_graph = float(jnp.mean(jnp.var(g_graph, axis=0)))
    assert v_graph < 0.2 * v_naive, (v_naive, v_graph)
    # and the estimators agree in expectation (both unbiased)
    sem = float(jnp.max(jnp.std(g_naive, axis=0))) / (512 ** 0.5)
    assert jnp.allclose(g_naive.mean(0), g_graph.mean(0), atol=5 * sem + 0.05)


def test_reparam_decenters_site():
    def funnel():
        scale = P.sample("scale_log", dist.Normal(0.0, 3.0))
        P.sample("x", dist.Normal(0.0, jnp.exp(scale / 2)))

    cfg = {"x": LocScaleReparam()}
    tr = trace(reparam(seed(funnel, 0), config=cfg)).get_trace()
    assert "x_decentered" in tr.nodes
    assert tr["x"]["type"] == "sample"
    # x is now a Delta at loc + scale * z (deterministic transform)
    z = tr["x_decentered"]["value"]
    scale = jnp.exp(tr["scale_log"]["value"] / 2)
    assert jnp.allclose(tr["x"]["value"], scale * z, atol=1e-6)


def test_reparam_funnel_trains_stably():
    """Decentered Neal's funnel: finite losses, converging SVI, and the
    auxiliary site carries the gradient (the centered `x` site is gone
    from the guide's latent set)."""

    def funnel(data):
        log_s = P.sample("log_s", dist.Normal(0.0, 3.0))
        x = P.sample("x", dist.Normal(0.0, jnp.exp(log_s / 2)))
        P.sample("obs", dist.Normal(x, 0.1), obs=data)

    data = jnp.asarray(1.0)
    model = reparam(funnel, config={"x": LocScaleReparam()})
    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=4))
    state, losses = svi.run(jax.random.PRNGKey(1), 400, data)
    assert bool(jnp.all(jnp.isfinite(losses)))
    assert float(jnp.mean(losses[-50:])) < float(jnp.mean(losses[:50]))
    params = svi.get_params(state)
    assert "auto_x_decentered_loc" in params and "auto_x_loc" not in params
