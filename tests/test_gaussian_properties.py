"""Property tests for the Gaussian semiring algebra (ISSUE 8 satellite).

Semiring laws that the planner is allowed to rely on when it reorders a
contraction: ⊗ associativity/commutativity, marginalization-order
invariance, neutrality of the identity factor, and PSD preservation under
Schur elimination. Properties are checked pointwise — factors are compared
by evaluating log F(x) = -1/2 x^T J x + h^T x + c at random points, which is
layout-permutation invariant (⊗ is free to order the union layout however
it likes).
"""
import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.infer.contract import (
    GaussianFactor,
    gaussian_marginalize,
    gaussian_multiply,
)

VARS = ("a", "b", "c")
WIDTH = {"a": 1, "b": 2, "c": 1}


def make_factor(seed, vars):
    """Well-conditioned random info-form factor over the given variables:
    J = A A^T + I/2 keeps eigenvalues in roughly [0.5, ~10]."""
    rng = np.random.default_rng(seed)
    widths = tuple(WIDTH[v] for v in vars)
    D = sum(widths)
    A = rng.normal(size=(D, D))
    J = A @ A.T + 0.5 * np.eye(D)
    return GaussianFactor(
        tuple(vars),
        widths,
        jnp.asarray(J, jnp.float32),
        jnp.asarray(rng.normal(size=(D,)), jnp.float32),
        jnp.asarray(rng.normal(), jnp.float32),
    )


def logdens(f, points):
    """Evaluate log F at a dict {var: value} — canonical, layout-free."""
    x = jnp.concatenate([jnp.asarray(points[v], jnp.float32) for v in f.vars])
    J, h = f.precision, f.info_vec
    return float(-0.5 * x @ J @ x + h @ x + f.log_norm)


def rand_points(seed):
    rng = np.random.default_rng(seed)
    return {v: rng.normal(size=(WIDTH[v],)) for v in VARS}


subsets = st.sampled_from(
    [("a",), ("b",), ("c",), ("a", "b"), ("b", "c"), ("a", "c"), ("a", "b", "c")]
)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(seeds, subsets, subsets, subsets)
def test_multiply_associative(seed, va, vb, vc):
    f, g, h = make_factor(seed, va), make_factor(seed + 1, vb), make_factor(seed + 2, vc)
    left = gaussian_multiply(gaussian_multiply(f, g), h)
    right = gaussian_multiply(f, gaussian_multiply(g, h))
    assert set(left.vars) == set(right.vars)
    for p in range(3):
        pts = rand_points(seed + 10 + p)
        assert np.allclose(logdens(left, pts), logdens(right, pts), rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seeds, subsets, subsets)
def test_multiply_commutative(seed, va, vb):
    f, g = make_factor(seed, va), make_factor(seed + 1, vb)
    fg, gf = gaussian_multiply(f, g), gaussian_multiply(g, f)
    assert set(fg.vars) == set(gf.vars)
    for p in range(3):
        pts = rand_points(seed + 10 + p)
        assert np.allclose(logdens(fg, pts), logdens(gf, pts), rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seeds)
def test_marginalization_order_invariant(seed):
    """Integrating a and b out one at a time — in either order — or jointly
    gives the same factor over c."""
    f = make_factor(seed, VARS)
    ab = gaussian_marginalize(gaussian_marginalize(f, ["a"]), ["b"])
    ba = gaussian_marginalize(gaussian_marginalize(f, ["b"]), ["a"])
    joint = gaussian_marginalize(f, ["a", "b"])
    for g in (ab, ba, joint):
        assert g.vars == ("c",)
    for p in range(3):
        pts = rand_points(seed + 10 + p)
        vals = [logdens(g, pts) for g in (ab, ba, joint)]
        assert np.allclose(vals[0], vals[1], rtol=1e-5, atol=1e-4)
        assert np.allclose(vals[0], vals[2], rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seeds, subsets, st.sampled_from(VARS))
def test_identity_factor_neutral(seed, vs, iv):
    """The zero potential (J=0, h=0, c=0) is the ⊗ identity — even when it
    introduces a variable the other factor doesn't mention (the new variable
    enters flat, and eliminating it later contributes exactly its Lebesgue
    normalizer, never changing the others' marginals)."""
    f = make_factor(seed, vs)
    w = WIDTH[iv]
    e = GaussianFactor(
        (iv,), (w,), jnp.zeros((w, w)), jnp.zeros((w,)), jnp.zeros(())
    )
    fe = gaussian_multiply(f, e)
    for p in range(3):
        pts = rand_points(seed + 10 + p)
        assert np.allclose(logdens(fe, pts), logdens(f, pts), rtol=1e-6, atol=1e-5)
    if iv not in f.vars:
        assert fe.vars == f.vars + (iv,)


@settings(max_examples=25, deadline=None)
@given(seeds, st.sampled_from([("a",), ("b",), ("a", "b")]))
def test_schur_preserves_psd(seed, drop):
    """The Schur complement of a PSD precision is PSD: eliminating variables
    can never manufacture a negative direction."""
    f = make_factor(seed, VARS)
    g = gaussian_marginalize(f, list(drop))
    eig = np.linalg.eigvalsh(np.asarray(g.precision, np.float64))
    assert np.all(eig > -1e-5), eig
    assert np.all(np.isfinite(np.asarray(g.info_vec)))
    assert np.isfinite(float(g.log_norm))
