"""The unified `repro.infer.config` annotation surface: identical traces to
the legacy `config_enumerate`/`config_gaussian` wrappers, which survive as
FutureWarning aliases.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributions as dist
from repro.core import handlers
from repro.core.handlers import config, config_enumerate, config_gaussian
from repro.core import primitives as P
from repro.infer import config as config_from_infer


def mixed_model():
    z = P.sample("z", dist.Categorical(probs=jnp.array([0.3, 0.7])))
    x = P.sample("x", dist.Normal(jnp.float32(z), 1.0))
    P.sample("obs", dist.Normal(x, 1.0), obs=jnp.float32(0.5))


def get_trace(model):
    return handlers.trace(
        handlers.seed(model, jax.random.PRNGKey(0))
    ).get_trace()


def infer_annotations(tr):
    return {
        name: {k: v for k, v in site["infer"].items() if not k.startswith("_")}
        for name, site in tr.nodes.items()
        if site["type"] == "sample"
    }


class TestUnifiedConfig:
    def test_exported_from_infer_and_core(self):
        assert config_from_infer is config

    def test_enumerate_annotates_discrete_sites_only(self):
        tr = get_trace(config(mixed_model, enumerate=True))
        ann = infer_annotations(tr)
        assert ann["z"] == {"enumerate": "parallel"}
        assert "enumerate" not in ann["x"]
        assert ann["obs"] == {}

    def test_marginalize_annotates_gaussian_sites_only(self):
        tr = get_trace(config(mixed_model, marginalize="gaussian"))
        ann = infer_annotations(tr)
        assert ann["x"] == {"marginalize": "gaussian"}
        assert "marginalize" not in ann["z"]
        assert ann["obs"] == {}  # observed sites untouched

    def test_combined_enumerate_and_marginalize(self):
        tr = get_trace(config(mixed_model, enumerate=True, marginalize=True))
        ann = infer_annotations(tr)
        assert ann["z"] == {"enumerate": "parallel"}
        assert ann["x"] == {"marginalize": "gaussian"}

    def test_sites_restricts_annotation(self):
        def two_normals():
            P.sample("a", dist.Normal(0.0, 1.0))
            P.sample("b", dist.Normal(0.0, 1.0))

        tr = get_trace(config(two_normals, marginalize="gaussian", sites=["a"]))
        ann = infer_annotations(tr)
        assert ann["a"] == {"marginalize": "gaussian"}
        assert ann["b"] == {}

    def test_naming_non_gaussian_site_raises(self):
        with pytest.raises(ValueError, match="Gaussian-marginalized"):
            get_trace(config(mixed_model, marginalize="gaussian", sites=["z"]))

    def test_decorator_form(self):
        @config(enumerate=True)
        def model():
            P.sample("z", dist.Categorical(probs=jnp.array([0.5, 0.5])))

        ann = infer_annotations(get_trace(model))
        assert ann["z"] == {"enumerate": "parallel"}

    def test_custom_config_fn_composes(self):
        tr = get_trace(config(
            mixed_model, enumerate=True,
            config_fn=lambda msg: {"tag": msg["name"]},
        ))
        assert tr.nodes["x"]["infer"]["tag"] == "x"
        assert tr.nodes["z"]["infer"]["enumerate"] == "parallel"

    def test_requires_at_least_one_option(self):
        with pytest.raises(ValueError, match="at least one"):
            config(mixed_model)

    def test_unknown_strategies_rejected(self):
        with pytest.raises(NotImplementedError, match="sequential"):
            config(mixed_model, enumerate="sequential")
        with pytest.raises(NotImplementedError, match="laplace"):
            config(mixed_model, marginalize="laplace")

    def test_explicit_site_annotation_wins(self):
        def model():
            P.sample("z", dist.Categorical(probs=jnp.array([0.5, 0.5])),
                     infer={"enumerate": "custom"})

        tr = get_trace(config(model, enumerate=True))
        assert tr.nodes["z"]["infer"]["enumerate"] == "custom"


class TestDeprecatedAliases:
    def test_config_enumerate_warns_and_matches(self):
        with pytest.warns(FutureWarning, match="config_enumerate"):
            legacy = config_enumerate(mixed_model)
        new = config(mixed_model, enumerate=True)
        assert infer_annotations(get_trace(legacy)) == infer_annotations(
            get_trace(new)
        )

    def test_config_gaussian_warns_and_matches(self):
        with pytest.warns(FutureWarning, match="config_gaussian"):
            legacy = config_gaussian(mixed_model)
        new = config(mixed_model, marginalize="gaussian")
        assert infer_annotations(get_trace(legacy)) == infer_annotations(
            get_trace(new)
        )

    def test_alias_decorator_forms(self):
        with pytest.warns(FutureWarning):
            @config_enumerate
            def m1():
                P.sample("z", dist.Categorical(probs=jnp.array([0.5, 0.5])))

        with pytest.warns(FutureWarning):
            @config_gaussian(sites=["x"])
            def m2():
                P.sample("x", dist.Normal(0.0, 1.0))

        assert infer_annotations(get_trace(m1))["z"]["enumerate"] == "parallel"
        assert infer_annotations(get_trace(m2))["x"]["marginalize"] == "gaussian"

    def test_elbo_identical_through_alias_and_new_api(self):
        """The regression that matters: identical traces -> identical ELBO."""
        from repro.infer import SVI, Trace_ELBO, TraceEnum_ELBO, AutoNormal
        from repro import optim

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", FutureWarning)
            legacy = config_enumerate(mixed_model)
        new = config(mixed_model, enumerate=True)
        losses = []
        for model in (legacy, new):
            guide = AutoNormal(lambda: P.sample("x", dist.Normal(0.0, 1.0)))
            svi = SVI(model, guide, optim.Adam(0.1), TraceEnum_ELBO())
            state = svi.init(jax.random.PRNGKey(0))
            losses.append(float(svi.evaluate(state)))
        assert losses[0] == losses[1]
