"""Poutine handler laws: the paper's effect-handler semantics."""
import jax
import jax.numpy as jnp
import pytest

from repro import distributions as dist
from repro.core import primitives as P
from repro.core.handlers import (
    block,
    condition,
    do,
    lift,
    mask,
    replay,
    scale,
    seed,
    substitute,
    trace,
)


def simple_model():
    z = P.sample("z", dist.Normal(0.0, 1.0))
    x = P.sample("x", dist.Normal(z, 1.0))
    return z, x


def test_seed_determinism_and_site_independence():
    tr1 = trace(seed(simple_model, 0)).get_trace()
    tr2 = trace(seed(simple_model, 0)).get_trace()
    assert float(tr1["z"]["value"]) == float(tr2["z"]["value"])
    # per-site fold_in: different sites get different randomness
    assert float(tr1["z"]["value"]) != float(tr1["x"]["value"])


def test_seed_order_independence():
    """Site keys are name-hashed, so adding a site doesn't change others."""
    def m1():
        return P.sample("a", dist.Normal(0.0, 1.0))

    def m2():
        P.sample("extra", dist.Normal(0.0, 1.0))
        return P.sample("a", dist.Normal(0.0, 1.0))

    a1 = seed(m1, 7)()
    a2 = seed(m2, 7)()
    assert float(a1) == float(a2)


def test_trace_records_all_sites():
    tr = trace(seed(simple_model, 1)).get_trace()
    assert set(tr.nodes) == {"z", "x"}
    assert not tr["z"]["is_observed"]


def test_replay_forces_values():
    tr = trace(seed(simple_model, 2)).get_trace()
    tr2 = trace(replay(seed(simple_model, 99), tr)).get_trace()
    assert float(tr2["z"]["value"]) == float(tr["z"]["value"])


def test_condition_marks_observed():
    conditioned = condition(simple_model, data={"x": jnp.asarray(1.5)})
    tr = trace(seed(conditioned, 3)).get_trace()
    assert tr["x"]["is_observed"]
    assert float(tr["x"]["value"]) == 1.5


def test_substitute_vs_condition_observed_flag():
    sub = substitute(simple_model, data={"x": jnp.asarray(1.5)})
    tr = trace(seed(sub, 3)).get_trace()
    assert not tr["x"]["is_observed"]  # substitute does NOT mark observed


def test_do_intervention_blocks_dependence():
    """do(z=c) severs z from the joint: z's log_prob must not contribute."""
    intervened = do(simple_model, data={"z": 10.0})
    tr = trace(seed(intervened, 4)).get_trace()
    lp = tr.log_prob_sum(lambda n, s: n == "z")
    assert float(lp) == 0.0  # Delta at its own value
    assert float(tr["x"]["fn"].loc) == 10.0


def test_block_hides_sites():
    tr = trace(block(seed(simple_model, 5), hide=["z"])).get_trace()
    assert "z" not in tr.nodes and "x" in tr.nodes


def test_scale_multiplies_logprob():
    def m():
        P.sample("x", dist.Normal(0.0, 1.0), obs=jnp.asarray(0.3))

    tr_plain = trace(m).get_trace()
    tr_scaled = trace(scale(m, scale=3.0)).get_trace()
    assert jnp.allclose(tr_scaled.log_prob_sum(), 3.0 * tr_plain.log_prob_sum())


def test_mask_zeroes_logprob():
    def m():
        with P.plate("N", 4):
            P.sample("x", dist.Normal(0.0, 1.0), obs=jnp.ones(4))

    tr = trace(mask(m, mask=jnp.array([True, False, True, False]))).get_trace()
    lp = tr.log_prob_sum()
    expected = 2 * float(dist.Normal(0.0, 1.0).log_prob(1.0))
    assert jnp.allclose(lp, expected)


def test_plate_subsample_scaling():
    def m():
        with P.plate("N", 100, subsample_size=10):
            P.sample("x", dist.Normal(0.0, 1.0), obs=jnp.zeros(10))

    tr = trace(seed(m, 0)).get_trace()
    lp = tr.log_prob_sum()
    expected = 10.0 * float(dist.Normal(0.0, 1.0).log_prob(0.0)) * 10.0  # N/B = 10
    assert jnp.allclose(lp, expected)


def test_nested_plates_allocate_distinct_dims():
    def m():
        with P.plate("outer", 3, dim=-2):
            with P.plate("inner", 4):
                return P.sample("x", dist.Normal(0.0, 1.0))

    x = seed(m, 0)()
    assert x.shape == (3, 4)


def test_lift_param_to_sample():
    def m():
        w = P.param("w", jnp.zeros(3))
        return w

    lifted = lift(m, prior={"w": dist.Normal(jnp.zeros(3), 1.0).to_event(1)})
    tr = trace(seed(lifted, 6)).get_trace()
    assert tr["w"]["type"] == "sample"
    assert not jnp.allclose(tr["w"]["value"], 0.0)


def test_factor_adds_density():
    def m():
        P.factor("penalty", jnp.asarray(-2.5))

    tr = trace(m).get_trace()
    assert jnp.allclose(tr.log_prob_sum(), -2.5)


def test_duplicate_site_raises():
    def m():
        P.sample("x", dist.Normal(0.0, 1.0))
        P.sample("x", dist.Normal(0.0, 1.0))

    with pytest.raises(RuntimeError, match="duplicate"):
        trace(seed(m, 0)).get_trace()


def test_handlers_compose_under_jit():
    """Handlers run at trace time: the whole stack works inside jax.jit."""

    @jax.jit
    def traced_logprob(obs):
        tr = trace(seed(condition(simple_model, data={"x": obs}), 0)).get_trace()
        return tr.log_prob_sum()

    lp = traced_logprob(jnp.asarray(0.7))
    assert jnp.isfinite(lp)


def test_trace_inside_grad():
    def loss(mu):
        def m():
            P.sample("x", dist.Normal(mu, 1.0), obs=jnp.asarray(2.0))

        return -trace(m).get_trace().log_prob_sum()

    g = jax.grad(loss)(0.0)
    assert jnp.allclose(g, -2.0)  # d/dmu [-(x-mu)^2/2] at mu=0, x=2
