"""Inference correctness: SVI on conjugate models (analytic posteriors),
ELBO estimator agreement, autoguides, MCMC, importance sampling."""
import jax
import jax.numpy as jnp
import pytest

from repro import distributions as dist
from repro import optim
from repro.core import primitives as P
from repro.infer import (
    SVI,
    AutoDelta,
    AutoIAFNormal,
    AutoLowRankMultivariateNormal,
    AutoNormal,
    MCMC,
    NUTS,
    HMC,
    RenyiELBO,
    Trace_ELBO,
    TraceMeanField_ELBO,
)

DATA = jnp.asarray([1.0, 2.0, 3.0, 2.5, 1.5])
POST_MEAN = float(DATA.sum() / (len(DATA) + 1 / 100.0))
POST_SD = float((1.0 / (len(DATA) + 0.01)) ** 0.5)


def normal_model(data):
    loc = P.sample("loc", dist.Normal(0.0, 10.0))
    with P.plate("N", data.shape[0]):
        P.sample("obs", dist.Normal(loc, 1.0), obs=data)


@pytest.mark.parametrize("Loss", [Trace_ELBO, TraceMeanField_ELBO])
def test_svi_autonormal_recovers_posterior(Loss):
    guide = AutoNormal(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Loss(num_particles=4))
    state, losses = svi.run(jax.random.PRNGKey(0), 1200, DATA)
    p = svi.get_params(state)
    assert float(p["auto_loc_loc"]) == pytest.approx(POST_MEAN, abs=0.15)
    assert float(jnp.exp(p["auto_loc_scale"])) == pytest.approx(POST_SD, abs=0.12)
    assert losses[-1] < losses[0]


def test_autodelta_map_estimate():
    guide = AutoDelta(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO())
    state, _ = svi.run(jax.random.PRNGKey(0), 800, DATA)
    p = svi.get_params(state)
    assert float(p["auto_loc_loc"]) == pytest.approx(POST_MEAN, abs=0.1)


def test_autolowrank_runs_and_converges():
    def model2(data):
        loc = P.sample("loc", dist.Normal(jnp.zeros(2), 10.0).to_event(1))
        with P.plate("N", data.shape[0]):
            P.sample("obs", dist.Normal(loc[0] + loc[1], 1.0), obs=data)

    guide = AutoLowRankMultivariateNormal(model2, rank=2)
    svi = SVI(model2, guide, optim.Adam(0.05), Trace_ELBO(num_particles=2))
    state, losses = svi.run(jax.random.PRNGKey(1), 600, DATA)
    assert losses[-1] < losses[0]
    med = float(jnp.sum(svi.get_params(state)["auto_loc"]))
    assert med == pytest.approx(POST_MEAN, abs=0.4)


def test_autoiaf_guide_trains():
    def model2(data):
        z = P.sample("z", dist.Normal(jnp.zeros(2), 5.0).to_event(1))
        with P.plate("N", data.shape[0]):
            P.sample("obs", dist.Normal(z[0], jnp.exp(0.2 * z[1])), obs=data)

    guide = AutoIAFNormal(model2, num_flows=1)
    svi = SVI(model2, guide, optim.Adam(0.01), Trace_ELBO(num_particles=2))
    state, losses = svi.run(jax.random.PRNGKey(2), 500, DATA)
    assert jnp.isfinite(losses[-1]) and losses[-1] < losses[0]


def test_beta_bernoulli_conjugate():
    data = jnp.asarray([1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0])

    def model(data):
        p = P.sample("p", dist.Beta(2.0, 2.0))
        with P.plate("N", data.shape[0]):
            P.sample("obs", dist.Bernoulli(probs=p), obs=data)

    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.Adam(0.02), Trace_ELBO(num_particles=8))
    state, _ = svi.run(jax.random.PRNGKey(3), 1500, data)
    # posterior Beta(2+6, 2+2): mean 8/12
    p = svi.get_params(state)
    t = dist.biject_to(dist.constraints.unit_interval)
    post_mean_est = float(t(p["auto_p_loc"]))
    assert post_mean_est == pytest.approx(8 / 12, abs=0.08)


def test_score_function_discrete_guide():
    """Non-reparameterizable guide site exercises the REINFORCE term."""

    def model():
        z = P.sample("z", dist.Bernoulli(probs=0.5))
        P.sample("x", dist.Normal(z * 2.0, 0.5), obs=jnp.asarray(2.1))

    def guide():
        q = P.param("q", jnp.asarray(0.3), constraint=dist.constraints.unit_interval)
        P.sample("z", dist.Bernoulli(probs=q))

    svi = SVI(model, guide, optim.Adam(0.05), Trace_ELBO(num_particles=16))
    state, _ = svi.run(jax.random.PRNGKey(4), 800)
    q = float(svi.get_params(state)["q"])
    assert q > 0.9  # posterior strongly prefers z=1


def test_renyi_elbo_is_tighter():
    guide = AutoNormal(normal_model, init_scale=1.0)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO())
    state, _ = svi.run(jax.random.PRNGKey(5), 300, DATA)
    params = svi.optim.get_params(state.optim_state)
    elbo1 = -float(Trace_ELBO(num_particles=64).loss(
        jax.random.PRNGKey(6), params, normal_model, guide, DATA))
    iwae = -float(RenyiELBO(alpha=0.0, num_particles=64).loss(
        jax.random.PRNGKey(6), params, normal_model, guide, DATA))
    assert iwae >= elbo1 - 0.05  # IWAE bound is at least as tight


@pytest.mark.parametrize("Kernel", [NUTS, HMC])
def test_mcmc_posterior(Kernel):
    mcmc = MCMC(Kernel(normal_model), num_warmup=300, num_samples=400)
    mcmc.run(jax.random.PRNGKey(7), DATA)
    s = mcmc.get_samples()["loc"]
    assert float(s.mean()) == pytest.approx(POST_MEAN, abs=0.15)
    assert float(s.std()) == pytest.approx(POST_SD, abs=0.15)


def test_importance_sampling_evidence():
    from repro.infer.importance import Importance

    def model():
        z = P.sample("z", dist.Normal(0.0, 1.0))
        P.sample("x", dist.Normal(z, 1.0), obs=jnp.asarray(1.0))

    def guide():
        P.sample("z", dist.Normal(0.5, 0.8))

    imp = Importance(model, guide, num_samples=20_000).run(jax.random.PRNGKey(8))
    expected = float(dist.Normal(0.0, jnp.sqrt(2.0)).log_prob(1.0))  # marginal
    assert float(imp.log_evidence()) == pytest.approx(expected, abs=0.02)
    assert float(imp.effective_sample_size()) > 1000


def test_predictive_shapes():
    from repro.infer.predictive import Predictive

    guide = AutoNormal(normal_model)
    svi = SVI(normal_model, guide, optim.Adam(0.05), Trace_ELBO())
    state, _ = svi.run(jax.random.PRNGKey(9), 200, DATA)
    params = svi.optim.get_params(state.optim_state)
    pred = Predictive(normal_model, guide=guide, params=params, num_samples=50)
    out = pred(jax.random.PRNGKey(10), DATA)
    assert out["obs"].shape == (50, len(DATA))
