"""`repro.settings`: the one registry for every REPRO_* environment knob —
typed getters, env-wins semantics, and the docs table that cannot drift.
"""
import pathlib

import pytest

from repro import settings

REPO = pathlib.Path(__file__).resolve().parent.parent


class TestRegistry:
    def test_every_knob_is_documented(self):
        for name, knob in settings.KNOBS.items():
            assert name == knob.name
            assert name.startswith("REPRO_")
            assert knob.effect, f"{name} has no effect description"

    def test_unknown_knob_is_a_keyerror(self):
        with pytest.raises(KeyError, match="unknown settings knob"):
            settings.get_raw("REPRO_NO_SUCH_KNOB")
        with pytest.raises(KeyError, match="unknown settings knob"):
            settings.get_bool("TYPO")

    def test_describe_lists_all_knobs(self):
        desc = settings.describe()
        assert {row["name"] for row in desc} == set(settings.KNOBS)


class TestGetters:
    def test_env_wins_over_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
        assert settings.get_str("REPRO_KERNEL_BACKEND") == "reference"
        monkeypatch.delenv("REPRO_KERNEL_BACKEND")
        assert settings.get_str("REPRO_KERNEL_BACKEND") == "auto"

    def test_read_at_call_time_not_import_time(self, monkeypatch):
        monkeypatch.setenv("REPRO_MCMC_FUSED", "0")
        assert settings.get_bool("REPRO_MCMC_FUSED") is False
        monkeypatch.setenv("REPRO_MCMC_FUSED", "1")
        assert settings.get_bool("REPRO_MCMC_FUSED") is True

    @pytest.mark.parametrize("raw", ["0", "false", "off", "FALSE", "Off"])
    def test_bool_false_spellings(self, monkeypatch, raw):
        monkeypatch.setenv("REPRO_ENUM_PLAN_CACHE", raw)
        assert settings.get_bool("REPRO_ENUM_PLAN_CACHE") is False

    @pytest.mark.parametrize("raw", ["1", "true", "on", "yes", "anything"])
    def test_bool_true_spellings(self, monkeypatch, raw):
        monkeypatch.setenv("REPRO_ENUM_PLAN_CACHE", raw)
        assert settings.get_bool("REPRO_ENUM_PLAN_CACHE") is True

    def test_int_and_float(self, monkeypatch):
        monkeypatch.setenv("REPRO_ENUM_PLAN_CACHE_SIZE", "7")
        assert settings.get_int("REPRO_ENUM_PLAN_CACHE_SIZE") == 7
        monkeypatch.setenv("REPRO_BENCH_TOLERANCE", "0.5")
        assert settings.get_float("REPRO_BENCH_TOLERANCE") == 0.5

    def test_optional_float_unset_is_none(self, monkeypatch):
        monkeypatch.delenv("REPRO_SERVE_DEADLINE_MS", raising=False)
        assert settings.get_optional_float("REPRO_SERVE_DEADLINE_MS") is None
        monkeypatch.setenv("REPRO_SERVE_DEADLINE_MS", "250")
        assert settings.get_optional_float("REPRO_SERVE_DEADLINE_MS") == 250.0

    def test_is_set(self, monkeypatch):
        monkeypatch.delenv("REPRO_COMPILATION_CACHE_DIR", raising=False)
        assert not settings.is_set("REPRO_COMPILATION_CACHE_DIR")
        monkeypatch.setenv("REPRO_COMPILATION_CACHE_DIR", "/tmp/c")
        assert settings.is_set("REPRO_COMPILATION_CACHE_DIR")


class TestDocsTable:
    def test_backends_md_table_matches_registry(self):
        page = (REPO / "docs" / "backends.md").read_text()
        assert settings.documented_env_table(page) == settings.render_env_table()

    def test_render_mentions_every_knob(self):
        table = settings.render_env_table()
        for name in settings.KNOBS:
            assert name in table

    def test_extractor_requires_markers(self):
        with pytest.raises(ValueError, match="settings table markers"):
            settings.documented_env_table("no markers here")


class TestCallSitesUseSettings:
    """The knob consolidation is real: the modules that used to read
    os.environ directly now resolve through `repro.settings`."""

    def test_kernel_backend_knob(self, monkeypatch):
        from repro.kernels import ops

        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
        assert ops.resolve_backend() == "reference"

    def test_serve_deadline_knob(self, monkeypatch):
        from repro.serve import InferenceServer

        monkeypatch.setenv("REPRO_SERVE_DEADLINE_MS", "123.0")
        server = InferenceServer({})
        try:
            assert server.default_deadline_ms == 123.0
        finally:
            server._httpd.server_close()
