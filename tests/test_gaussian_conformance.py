"""Gaussian-semiring VE conformance: closed-form Kalman gate (ISSUE 8).

The acceptance gate for exact continuous marginalization: smoother marginals
from `gaussian_marginals` must match a hand-rolled sequential Kalman filter +
RTS smoother (and the dense joint posterior via scipy / plain numpy linear
algebra) to rtol 1e-5 across T in {1, 2, 64, 512}, under both the
``interpret`` (Pallas bodies) and ``reference`` (pure-jnp oracle) kernel
backends; the O(log T) associative tree must agree with the sequential
information-form fold to float-association tolerance; a switching LDS must
match brute-force path enumeration x dense Gaussian elimination; refitting
the same structure must hit the plan cache.

Robustness rows ride along: |rho| -> 0.999 correlation, near-singular
precisions at the documented conditioning contract (see kernels/gaussian.py),
and the T=1 / T=2 degenerate chains that never reach a scan.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import handlers
from repro.core import primitives as P
from repro.infer import (
    TraceEnum_ELBO,
    clear_plan_cache,
    config_gaussian,
    gaussian_marginals,
    plan_cache_stats,
)
from repro.infer.contract import (
    GaussianFactor,
    affine_gaussian_factor,
    eliminate_gaussian_factors,
    gaussian_marginal_params,
    gaussian_marginalize,
    gaussian_multiply,
)

KEY = jax.random.PRNGKey(0)
GM = {"marginalize": "gaussian"}


# ---------------------------------------------------------------------------
# sequential references (numpy float64 — independent of everything under test)
# ---------------------------------------------------------------------------


def kalman_reference(ys, a, q, r, m0, p0):
    """Textbook scalar Kalman filter + RTS smoother in float64.

    x_0 ~ N(m0, p0); x_t = a x_{t-1} + N(0, q); y_t = x_t + N(0, r).
    Returns (smoothed means, smoothed variances, log marginal likelihood)."""
    T = len(ys)
    fm = np.zeros(T)
    fp = np.zeros(T)
    logz = 0.0
    pm, pp = m0, p0
    for t in range(T):
        if t > 0:
            pm, pp = a * fm[t - 1], a * a * fp[t - 1] + q
        s = pp + r
        logz += -0.5 * ((ys[t] - pm) ** 2 / s + np.log(2 * np.pi * s))
        k = pp / s
        fm[t] = pm + k * (ys[t] - pm)
        fp[t] = (1 - k) * pp
    sm = fm.copy()
    sp = fp.copy()
    for t in range(T - 2, -1, -1):
        pp = a * a * fp[t] + q
        g = a * fp[t] / pp
        sm[t] = fm[t] + g * (sm[t + 1] - a * fm[t])
        sp[t] = fp[t] + g * g * (sp[t + 1] - pp)
    return sm, sp, logz


def dense_joint_posterior(ys, a, q, r, m0, p0):
    """Same model, solved as one dense joint Gaussian in float64: build the
    (T, T) prior-chain precision directly, condition on the observations.
    Returns (posterior mean, posterior cov, log marginal likelihood)."""
    T = len(ys)
    J = np.zeros((T, T))
    h = np.zeros(T)
    J[0, 0] += 1.0 / p0
    h[0] += m0 / p0
    for t in range(1, T):
        J[t, t] += 1.0 / q
        J[t - 1, t - 1] += a * a / q
        J[t, t - 1] -= a / q
        J[t - 1, t] -= a / q
    c = -0.5 * m0 * m0 / p0 - 0.5 * np.log(2 * np.pi * p0) - 0.5 * (T - 1) * np.log(
        2 * np.pi * q
    )
    for t in range(T):
        J[t, t] += 1.0 / r
        h[t] += ys[t] / r
        c += -0.5 * ys[t] ** 2 / r - 0.5 * np.log(2 * np.pi * r)
    cov = np.linalg.inv(J)
    mean = cov @ h
    logz = c + 0.5 * h @ cov @ h + 0.5 * np.linalg.slogdet(2 * np.pi * cov)[1]
    return mean, cov, logz


def scalar_kalman_model(ys, a=0.9, q=0.2, r=0.3, m0=0.5, p0=1.0):
    x = P.sample("x0", dist.Normal(m0, p0**0.5), infer=GM)
    P.sample("y0", dist.Normal(x, r**0.5), obs=ys[0])
    for t in range(1, len(ys)):
        x = P.sample(f"x{t}", dist.Normal(a * x, q**0.5), infer=GM)
        P.sample(f"y{t}", dist.Normal(x, r**0.5), obs=ys[t])


def observations(T, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, 1.0, (T,)).astype(np.float32))


@pytest.fixture(params=["interpret", "reference"])
def backend(request, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", request.param)
    return request.param


# ---------------------------------------------------------------------------
# tentpole gate: smoother marginals vs sequential reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T", [1, 2, 64, 512])
def test_kalman_smoother_vs_sequential_reference(T, backend):
    ys = observations(T)
    # querying all T sites makes the epsilon-Hessian T x T; probe a spread of
    # sites instead so T=512 stays a unit test, not a benchmark
    probe = sorted({0, 1, T // 2, T - 1} & set(range(T)))
    sites = [f"x{t}" for t in probe]
    out = gaussian_marginals(
        lambda: scalar_kalman_model(ys), KEY, sites=sites
    )
    sm, sp, _ = kalman_reference(np.asarray(ys, np.float64), 0.9, 0.2, 0.3, 0.5, 1.0)
    for t in probe:
        m, v = out[f"x{t}"]
        assert np.allclose(float(m), sm[t], rtol=1e-5, atol=1e-6), (t, float(m), sm[t])
        assert np.allclose(float(v), sp[t], rtol=1e-5, atol=1e-6), (t, float(v), sp[t])


@pytest.mark.parametrize("T", [1, 2, 5, 17])
def test_kalman_marginals_vs_dense_joint(T):
    """Full-cov cross-check: every smoother marginal against the dense joint
    posterior (numpy float64 Schur-free solve)."""
    ys = observations(T, seed=1)
    out = gaussian_marginals(lambda: scalar_kalman_model(ys), KEY)
    mean, cov, _ = dense_joint_posterior(
        np.asarray(ys, np.float64), 0.9, 0.2, 0.3, 0.5, 1.0
    )
    for t in range(T):
        m, v = out[f"x{t}"]
        assert np.allclose(float(m), mean[t], rtol=1e-5, atol=1e-6)
        assert np.allclose(float(v), cov[t, t], rtol=1e-5, atol=1e-6)


def test_kalman_logz_vs_reference_all_dispatches(monkeypatch):
    """The eliminated chain's log-normalizer is the exact marginal likelihood
    under pairwise greedy, the default scan lowering, and the forced
    associative-tree lowering (REPRO_ENUM_CHAIN_MIN=2)."""
    T = 24
    ys = observations(T, seed=2)
    ref = kalman_reference(np.asarray(ys, np.float64), 0.9, 0.2, 0.3, 0.5, 1.0)[2]

    def logz():
        elbo = TraceEnum_ELBO(max_plate_nesting=0)
        return -elbo.loss(KEY, {}, lambda: scalar_kalman_model(ys), lambda: None)

    got = {}
    monkeypatch.setenv("REPRO_ENUM_DISPATCH", "pairwise")
    got["pairwise"] = float(logz())
    monkeypatch.delenv("REPRO_ENUM_DISPATCH")
    got["scan"] = float(logz())
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    got["tree"] = float(logz())
    for name, val in got.items():
        assert np.allclose(val, ref, rtol=1e-5, atol=1e-5), (name, val, ref)


def test_tree_matches_sequential_fold(monkeypatch, backend):
    """O(log T) associative tree vs the sequential information-form fold:
    same chain, different association order. Bit-identity is not guaranteed
    in f32; the documented float-association tolerance is."""
    T = 64
    ys = observations(T, seed=3)

    def logz():
        elbo = TraceEnum_ELBO(max_plate_nesting=0)
        return -elbo.loss(KEY, {}, lambda: scalar_kalman_model(ys), lambda: None)

    seq = float(logz())
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    tree = float(logz())
    assert np.allclose(seq, tree, rtol=1e-5, atol=1e-4)


def test_mvn_chain_vs_dense_joint():
    """d=3 MVN chain: smoother mean vectors and full covariance blocks vs a
    dense joint posterior assembled from the same factors via the pairwise
    greedy path, cross-checked against scipy's MVN logpdf."""
    ss = pytest.importorskip("scipy.stats", reason="dense cross-check needs scipy")
    T, d = 5, 3
    rng = np.random.default_rng(4)
    A = jnp.asarray(0.5 * rng.normal(size=(d, d)).astype(np.float32))
    Lq = jnp.asarray(
        np.linalg.cholesky(0.2 * np.eye(d) + 0.05).astype(np.float32)
    )
    Lr = jnp.asarray((0.4 * np.eye(d)).astype(np.float32))
    ys = jnp.asarray(rng.normal(size=(T, d)).astype(np.float32))

    def model():
        x = P.sample(
            "x0",
            dist.MultivariateNormal(jnp.zeros(d), scale_tril=jnp.eye(d)),
            infer=GM,
        )
        P.sample("y0", dist.MultivariateNormal(x, scale_tril=Lr), obs=ys[0])
        for t in range(1, T):
            x = P.sample(
                f"x{t}",
                dist.MultivariateNormal(A @ x, scale_tril=Lq),
                infer=GM,
            )
            P.sample(f"y{t}", dist.MultivariateNormal(x, scale_tril=Lr), obs=ys[t])

    out = gaussian_marginals(model, KEY)

    # dense float64 joint over the stacked (T*d,) state
    An, Lqn, Lrn, yn = (np.asarray(z, np.float64) for z in (A, Lq, Lr, ys))
    Qi = np.linalg.inv(Lqn @ Lqn.T)
    Ri = np.linalg.inv(Lrn @ Lrn.T)
    D = T * d
    J = np.zeros((D, D))
    h = np.zeros(D)
    J[:d, :d] += np.eye(d)
    for t in range(1, T):
        s, p = slice(t * d, (t + 1) * d), slice((t - 1) * d, t * d)
        J[s, s] += Qi
        J[p, p] += An.T @ Qi @ An
        J[s, p] -= Qi @ An
        J[p, s] -= An.T @ Qi
    for t in range(T):
        s = slice(t * d, (t + 1) * d)
        J[s, s] += Ri
        h[s] += Ri @ yn[t]
    cov = np.linalg.inv(J)
    mean = cov @ h
    for t in range(T):
        m, C = out[f"x{t}"]
        s = slice(t * d, (t + 1) * d)
        assert np.allclose(np.asarray(m), mean[s], rtol=1e-4, atol=3e-5)
        assert np.allclose(np.asarray(C), cov[s, s], rtol=1e-4, atol=3e-5)

    # scipy cross-check of the same dense joint's evidence at y
    prior_cov = np.linalg.inv(J - np.kron(np.eye(T), Ri))
    obs_cov = prior_cov + np.kron(np.eye(T), Lrn @ Lrn.T)
    ref_logz = ss.multivariate_normal(np.zeros(D), obs_cov).logpdf(yn.reshape(-1))
    elbo = TraceEnum_ELBO(max_plate_nesting=0)
    got = -float(elbo.loss(KEY, {}, model, lambda: None))
    assert np.allclose(got, ref_logz, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# mixed contraction: switching LDS vs brute force
# ---------------------------------------------------------------------------


def test_switching_lds_vs_brute_force():
    """Discrete enumeration x Gaussian elimination in one contraction: a
    K=2, T=4 switching LDS's evidence and mixture marginals vs explicit
    enumeration of all K^T regime paths, each solved as a dense Gaussian."""
    T, K = 4, 2
    coeff = jnp.asarray([0.9, -0.6])
    probs = jnp.asarray([0.7, 0.3])
    q, r, p0 = 0.2, 0.3, 1.0
    ys = observations(T, seed=5)

    def model():
        x = P.sample("x0", dist.Normal(0.0, p0**0.5), infer=GM)
        P.sample("y0", dist.Normal(x, r**0.5), obs=ys[0])
        for t in range(1, T):
            s = P.sample(
                f"s{t}", dist.Categorical(probs), infer={"enumerate": "parallel"}
            )
            x = P.sample(f"x{t}", dist.Normal(coeff[s] * x, q**0.5), infer=GM)
            P.sample(f"y{t}", dist.Normal(x, r**0.5), obs=ys[t])

    elbo = TraceEnum_ELBO(max_plate_nesting=0)
    got_logz = -float(elbo.loss(KEY, {}, model, lambda: None))
    got_marg = gaussian_marginals(model, KEY)

    # brute force over the K^(T-1) regime paths, float64
    yn = np.asarray(ys, np.float64)
    cn = np.asarray(coeff, np.float64)
    pn = np.asarray(probs, np.float64)
    path_logz, path_mean, path_var = [], [], []
    import itertools

    for path in itertools.product(range(K), repeat=T - 1):
        J = np.zeros((T, T))
        h = np.zeros(T)
        J[0, 0] += 1.0 / p0
        c = -0.5 * np.log(2 * np.pi * p0)
        for t in range(1, T):
            a = cn[path[t - 1]]
            J[t, t] += 1.0 / q
            J[t - 1, t - 1] += a * a / q
            J[t, t - 1] -= a / q
            J[t - 1, t] -= a / q
            c += -0.5 * np.log(2 * np.pi * q)
        for t in range(T):
            J[t, t] += 1.0 / r
            h[t] += yn[t] / r
            c += -0.5 * yn[t] ** 2 / r - 0.5 * np.log(2 * np.pi * r)
        cov = np.linalg.inv(J)
        mean = cov @ h
        lz = c + 0.5 * h @ mean + 0.5 * np.linalg.slogdet(2 * np.pi * cov)[1]
        path_logz.append(lz + sum(np.log(pn[k]) for k in path))
        path_mean.append(mean)
        path_var.append(np.diagonal(cov))
    path_logz = np.asarray(path_logz)
    ref_logz = np.log(np.sum(np.exp(path_logz - path_logz.max()))) + path_logz.max()
    w = np.exp(path_logz - ref_logz)
    mix_mean = np.einsum("p,pt->t", w, np.asarray(path_mean))
    mix_var = np.einsum(
        "p,pt->t", w, np.asarray(path_var) + np.asarray(path_mean) ** 2
    ) - mix_mean**2

    assert np.allclose(got_logz, ref_logz, rtol=1e-5, atol=1e-5)
    for t in range(T):
        m, v = got_marg[f"x{t}"]
        assert np.allclose(float(m), mix_mean[t], rtol=1e-4, atol=1e-5)
        assert np.allclose(float(v), mix_var[t], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# plan cache, gradients, surface checks
# ---------------------------------------------------------------------------


def test_plan_cache_hit_on_refit():
    """Same chain structure, new observation values: the second elimination
    must hit the plan cache, and gaussian/log-semiring fingerprints must not
    collide (the log contraction in the same loss doesn't evict the plan)."""
    clear_plan_cache()
    T = 8

    def logz(ys):
        elbo = TraceEnum_ELBO(max_plate_nesting=0)
        return -elbo.loss(KEY, {}, lambda: scalar_kalman_model(ys), lambda: None)

    logz(observations(T, seed=6))
    before = plan_cache_stats()
    logz(observations(T, seed=7))
    after = plan_cache_stats()
    assert after["hits"] > before["hits"]
    assert after["misses"] == before["misses"]


def test_elbo_grad_matches_finite_differences():
    """jit(grad(loss)) through the Gaussian elimination wrt a guide latent
    feeding the marginalized chain."""
    T = 6
    ys = observations(T, seed=8)

    def model(params):
        z = P.sample("z", dist.Normal(params["mu"], 1.0))
        x = P.sample("x0", dist.Normal(z, 1.0), infer=GM)
        P.sample("y0", dist.Normal(x, 0.5), obs=ys[0])
        for t in range(1, T):
            x = P.sample(f"x{t}", dist.Normal(0.8 * x, 0.5), infer=GM)
            P.sample(f"y{t}", dist.Normal(x, 0.5), obs=ys[t])

    def guide(params):
        P.sample("z", dist.Normal(params["mu"], 0.3))

    elbo = TraceEnum_ELBO(max_plate_nesting=0)
    loss = lambda p: elbo.loss(KEY, {}, model, guide, p)
    g = jax.jit(jax.grad(lambda mu: loss({"mu": mu})))(0.4)
    eps = 1e-2
    fd = (loss({"mu": 0.4 + eps}) - loss({"mu": 0.4 - eps})) / (2 * eps)
    assert np.allclose(float(g), float(fd), rtol=2e-2, atol=2e-3)


def test_config_gaussian_handler():
    """config_gaussian annotates every Gaussian latent (or just the named
    sites) without touching observed or discrete sites."""
    ys = observations(3, seed=9)

    def model():
        x = P.sample("x0", dist.Normal(0.0, 1.0))
        P.sample("y0", dist.Normal(x, 0.5), obs=ys[0])
        P.sample("k", dist.Categorical(jnp.asarray([0.5, 0.5])))

    tr = handlers.trace(handlers.seed(config_gaussian(model), KEY)).get_trace()
    assert tr.nodes["x0"]["infer"].get("marginalize") == "gaussian"
    assert "marginalize" not in tr.nodes["y0"]["infer"]
    assert "marginalize" not in tr.nodes["k"]["infer"]

    out = gaussian_marginals(config_gaussian(lambda: scalar_kalman_model(ys)), KEY)
    ref = gaussian_marginals(lambda: scalar_kalman_model(ys), KEY)
    for n, (m, v) in out.items():
        assert np.allclose(float(m), float(ref[n][0]))
        assert np.allclose(float(v), float(ref[n][1]))


def test_non_gaussian_site_annotation_rejected():
    def model():
        P.sample("k", dist.Categorical(jnp.asarray([0.5, 0.5])), infer=GM)

    with pytest.raises((ValueError, NotImplementedError)):
        gaussian_marginals(model, KEY)


def test_unannotated_model_rejected():
    with pytest.raises(ValueError, match="config_gaussian"):
        gaussian_marginals(lambda: P.sample("x", dist.Normal(0.0, 1.0)), KEY)


# ---------------------------------------------------------------------------
# numerical robustness rows (documented conditioning contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rho", [0.9, 0.99, 0.999])
def test_high_correlation_chain(rho):
    """|rho| -> 0.999: transition variance q = 1 - rho^2 shrinks to 2e-3 and
    the chain precision's condition number climbs to ~2e3 — inside the
    kappa * 1e-7 f32 contract from kernels/gaussian.py, so rtol 1e-5 must
    still hold against the float64 reference."""
    T = 16
    q = 1.0 - rho * rho
    ys = observations(T, seed=10)
    out = gaussian_marginals(
        lambda: scalar_kalman_model(ys, a=rho, q=q, r=0.3, m0=0.0, p0=1.0), KEY
    )
    sm, sp, _ = kalman_reference(np.asarray(ys, np.float64), rho, q, 0.3, 0.0, 1.0)
    for t in range(T):
        m, v = out[f"x{t}"]
        assert np.allclose(float(m), sm[t], rtol=1e-5, atol=1e-5)
        assert np.allclose(float(v), sp[t], rtol=1e-5, atol=1e-5)


def test_near_singular_precision_marginalize():
    """Schur elimination of a nearly-deterministic block (precision 1e6 on
    the dropped variable) stays finite and matches float64."""
    J = jnp.asarray([[1e6, 999.0], [999.0, 2.0]], jnp.float32)
    h = jnp.asarray([3.0, 1.0], jnp.float32)
    f = GaussianFactor(("a", "b"), (1, 1), J, h, jnp.zeros(()))
    g = gaussian_marginalize(f, ["a"])
    Jn = np.asarray(J, np.float64)
    ref_J = Jn[1, 1] - Jn[0, 1] ** 2 / Jn[0, 0]
    ref_h = 1.0 - Jn[0, 1] * 3.0 / Jn[0, 0]
    assert np.isfinite(float(g.log_norm))
    assert np.allclose(float(g.precision[0, 0]), ref_J, rtol=1e-5)
    assert np.allclose(float(g.info_vec[0]), ref_h, rtol=1e-4)


@pytest.mark.parametrize("T", [1, 2])
def test_degenerate_chain_lengths(T, backend):
    """T=1 (no edges at all) and T=2 (a single edge — below every tree/scan
    threshold) exercise the non-chain code paths end to end."""
    ys = observations(T, seed=11)
    out = gaussian_marginals(lambda: scalar_kalman_model(ys), KEY)
    sm, sp, ref_logz = kalman_reference(
        np.asarray(ys, np.float64), 0.9, 0.2, 0.3, 0.5, 1.0
    )
    for t in range(T):
        m, v = out[f"x{t}"]
        assert np.allclose(float(m), sm[t], rtol=1e-5, atol=1e-6)
        assert np.allclose(float(v), sp[t], rtol=1e-5, atol=1e-6)
    elbo = TraceEnum_ELBO(max_plate_nesting=0)
    got = -float(elbo.loss(KEY, {}, lambda: scalar_kalman_model(ys), lambda: None))
    assert np.allclose(got, ref_logz, rtol=1e-5, atol=1e-5)


def test_affine_factor_is_normalized_density():
    """A single lowered conditional must integrate to 1: eliminating its own
    variable from N(x; b, L L^T) leaves log_norm == 0."""
    L = jnp.asarray([[0.7, 0.0], [0.2, 1.1]], jnp.float32)
    f = affine_gaussian_factor(
        ("x",), (2,), {}, -jnp.asarray([0.3, -0.5]), L, "x"
    )
    g = gaussian_marginalize(f, ["x"])
    assert g.vars == ()
    assert np.allclose(float(g.log_norm), 0.0, atol=1e-6)
    mean, cov = gaussian_marginal_params(f)
    assert np.allclose(np.asarray(mean), [0.3, -0.5], atol=1e-6)
    assert np.allclose(np.asarray(cov), np.asarray(L @ L.T), atol=1e-6)


def test_eliminate_factors_enum_lead_batch():
    """Enum-lead batched elimination (K parallel chains in one shot) is
    bit-comparable to K separate eliminations."""
    K, T = 3, 4
    rng = np.random.default_rng(12)
    a = jnp.asarray(rng.uniform(0.3, 0.9, (K,)).astype(np.float32))

    def chain_factors(ak):
        fs = [
            affine_gaussian_factor(
                ("x0",), (1,), {}, jnp.zeros((1,)), jnp.ones((1, 1)), "x0"
            )
        ]
        for t in range(1, T):
            fs.append(
                affine_gaussian_factor(
                    (f"x{t - 1}", f"x{t}"),
                    (1, 1),
                    {f"x{t - 1}": ak.reshape(ak.shape + (1, 1))},
                    jnp.zeros(ak.shape + (1,)),
                    0.5 * jnp.ones((1, 1)),
                    f"x{t}",
                )
            )
        # observe each x_t at 1.0 through unit noise: residual = value - x_t
        for t in range(T):
            fs.append(
                affine_gaussian_factor(
                    (f"x{t}",),
                    (1,),
                    {f"x{t}": jnp.ones((1, 1))},
                    jnp.ones((1,)),
                    jnp.ones((1, 1)),
                    None,
                )
            )
        return fs

    order = [f"x{t}" for t in range(T)]
    batched = sum(eliminate_gaussian_factors(chain_factors(a), order))
    singles = [
        float(sum(eliminate_gaussian_factors(chain_factors(a[k]), order)))
        for k in range(K)
    ]
    assert np.allclose(np.asarray(batched), np.asarray(singles), rtol=1e-6, atol=1e-6)


def test_multiply_then_marginalize_matches_dense():
    """gaussian_multiply + gaussian_marginalize against plain dense algebra
    on a 3-variable star with mixed widths."""

    def rand_factor(vars, widths, seed):
        r = np.random.default_rng(seed)
        D = sum(widths)
        A = r.normal(size=(D, D))
        J = A @ A.T + 0.5 * np.eye(D)
        h = r.normal(size=(D,))
        return GaussianFactor(
            vars,
            widths,
            jnp.asarray(J, jnp.float32),
            jnp.asarray(h, jnp.float32),
            jnp.asarray(r.normal(), jnp.float32),
        )

    f = rand_factor(("a", "b"), (2, 1), 1)
    g = rand_factor(("b", "c"), (1, 3), 2)
    prod = gaussian_multiply(f, g)
    assert prod.vars == ("a", "b", "c")
    marg = gaussian_marginalize(prod, ["b"])

    # dense reference over layout (a, b, c)
    J = np.zeros((6, 6))
    h = np.zeros(6)
    J[:3, :3] += np.asarray(f.precision, np.float64)
    h[:3] += np.asarray(f.info_vec, np.float64)
    J[2:, 2:] += np.asarray(g.precision, np.float64)
    h[2:] += np.asarray(g.info_vec, np.float64)
    keep = [0, 1, 3, 4, 5]
    Jbb = J[2, 2]
    ref_J = J[np.ix_(keep, keep)] - np.outer(J[keep, 2], J[2, keep]) / Jbb
    ref_h = h[keep] - J[keep, 2] * h[2] / Jbb
    ref_c = (
        float(f.log_norm)
        + float(g.log_norm)
        + 0.5 * h[2] ** 2 / Jbb
        - 0.5 * np.log(Jbb)
        + 0.5 * np.log(2 * np.pi)
    )
    assert np.allclose(np.asarray(marg.precision), ref_J, rtol=1e-5, atol=1e-5)
    assert np.allclose(np.asarray(marg.info_vec), ref_h, rtol=1e-5, atol=1e-5)
    assert np.allclose(float(marg.log_norm), ref_c, rtol=1e-5, atol=1e-5)
