"""`repro.retrace`: one `num_traces` contract shared by every compiled
engine — SVI, MCMC, Predictive, CompiledServable, ServableModel."""
import jax
import jax.numpy as jnp
import pytest

from repro import distributions as dist, optim
from repro.core import primitives as P
from repro.infer import SVI, AutoNormal, MCMC, NUTS, Predictive, Trace_ELBO
from repro.retrace import RetraceCounted, assert_num_traces, num_traces
from repro.serve import CompiledServable, ServableModel


def model(x, y=None):
    w = P.sample("w", dist.Normal(jnp.zeros(2), 1.0).to_event(1))
    with P.plate("B", x.shape[0]):
        P.sample("y", dist.Normal(x @ w, 0.1), obs=y)


X = jnp.ones((4, 2))
Y = jnp.zeros(4)


def test_every_engine_satisfies_the_protocol():
    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.Adam(0.1), Trace_ELBO())
    engines = [
        svi,
        MCMC(NUTS(model), num_warmup=1, num_samples=1),
        Predictive(model, guide=guide, params={}, num_samples=1),
        CompiledServable(lambda key, batch: batch, max_batch=4),
        ServableModel("t", lambda key, batch: batch, max_batch=4),
    ]
    for eng in engines:
        assert isinstance(eng, RetraceCounted), type(eng).__name__
        assert num_traces(eng) == 0  # nothing compiled yet


def test_svi_counter_is_the_update_jit_cache():
    svi = SVI(model, AutoNormal(model), optim.Adam(0.1), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0), X, y=Y)
    assert svi.num_traces == 0
    for _ in range(3):
        state, _ = svi.update_jit(state, X, y=Y)
    assert_num_traces(svi, 1, context="same-shaped steps")


def test_assert_num_traces_message():
    svi = SVI(model, AutoNormal(model), optim.Adam(0.1), Trace_ELBO())
    with pytest.raises(AssertionError, match="recompiling"):
        assert_num_traces(svi, 5)


def test_num_traces_validates_type():
    class Broken:
        num_traces = "many"

    with pytest.raises(TypeError, match="non-negative int"):
        num_traces(Broken())
