"""Distribution properties — hypothesis-driven invariants + analytic spot
checks against scipy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.stats as ss

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements.txt)",
)
from hypothesis import given, settings, strategies as st

from repro import distributions as dist
from repro.distributions import biject_to, constraints, kl_divergence

KEY = jax.random.PRNGKey(0)

finite_floats = st.floats(-5, 5, allow_nan=False)
pos_floats = st.floats(0.1, 5, allow_nan=False)


CASES = [
    (lambda a, b: dist.Normal(a, b), lambda a, b: ss.norm(a, b), finite_floats, pos_floats),
    (lambda a, b: dist.Laplace(a, b), lambda a, b: ss.laplace(a, b), finite_floats, pos_floats),
    (lambda a, b: dist.Gamma(a, b), lambda a, b: ss.gamma(a, scale=1 / b), pos_floats, pos_floats),
    (lambda a, b: dist.Beta(a, b), lambda a, b: ss.beta(a, b), pos_floats, pos_floats),
    (lambda a, b: dist.LogNormal(a, b), lambda a, b: ss.lognorm(b, scale=np.exp(a)), finite_floats, pos_floats),
    (lambda a, b: dist.StudentT(3.0, a, b), lambda a, b: ss.t(3.0, a, b), finite_floats, pos_floats),
    (lambda a, b: dist.Cauchy(a, b), lambda a, b: ss.cauchy(a, b), finite_floats, pos_floats),
    (lambda a, b: dist.Uniform(a, a + b), lambda a, b: ss.uniform(a, b), finite_floats, pos_floats),
]


@pytest.mark.parametrize("mk,mk_ref,_,__", CASES, ids=lambda c: getattr(c, "__name__", ""))
def test_logprob_matches_scipy(mk, mk_ref, _, __):
    d = mk(0.7, 1.3)
    ref = mk_ref(0.7, 1.3)
    xs = np.asarray(d.sample(KEY, (64,)))
    assert np.allclose(d.log_prob(jnp.asarray(xs)), ref.logpdf(xs), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(a=finite_floats, b=pos_floats)
def test_normal_sample_moments(a, b):
    d = dist.Normal(a, b)
    xs = d.sample(KEY, (20_000,))
    assert abs(float(xs.mean()) - a) < 0.1 * b + 0.05
    assert abs(float(xs.std()) - b) < 0.1 * b + 0.05


@settings(max_examples=20, deadline=None)
@given(loc=finite_floats, scale=pos_floats, loc2=finite_floats, scale2=pos_floats)
def test_kl_normal_properties(loc, scale, loc2, scale2):
    p = dist.Normal(loc, scale)
    q = dist.Normal(loc2, scale2)
    assert float(kl_divergence(p, p)) == pytest.approx(0.0, abs=1e-5)
    kl = float(kl_divergence(p, q))
    assert kl >= -1e-6
    # analytic
    expected = np.log(scale2 / scale) + (scale**2 + (loc - loc2) ** 2) / (2 * scale2**2) - 0.5
    assert kl == pytest.approx(expected, rel=1e-4, abs=1e-5)


@settings(max_examples=20, deadline=None)
@given(x=st.lists(finite_floats, min_size=2, max_size=6))
def test_biject_roundtrips(x):
    x = jnp.asarray(x)
    for c in (constraints.positive, constraints.unit_interval, constraints.real,
              constraints.softplus_positive if hasattr(constraints, "softplus_positive") else constraints.positive):
        t = biject_to(c)
        y = t(x)
        x2 = t.inv(y)
        assert jnp.allclose(x, x2, atol=1e-4), c


def test_simplex_bijector():
    t = biject_to(constraints.simplex)
    x = jnp.asarray([0.3, -0.7, 1.1])
    y = t(x)
    assert y.shape == (4,)
    assert jnp.allclose(jnp.sum(y), 1.0, atol=1e-5)
    assert jnp.all(y > 0)
    assert jnp.allclose(t.inv(y), x, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(x=st.lists(finite_floats, min_size=3, max_size=5))
def test_transform_jacobian_matches_autodiff(x):
    """log|det J| of scalar transforms == sum log |dy/dx| by autodiff."""
    x = jnp.asarray(x)
    from repro.distributions.transforms import ExpTransform, SigmoidTransform, TanhTransform

    for t in (ExpTransform(), SigmoidTransform(), TanhTransform()):
        y = t(x)
        lad = t.log_abs_det_jacobian(x, y)
        grad = jax.vmap(jax.grad(lambda v: t(v)))(x)
        assert jnp.allclose(lad, jnp.log(jnp.abs(grad)), atol=2e-3, rtol=1e-3), type(t).__name__


def test_categorical_logits_probs_agree():
    logits = jax.random.normal(KEY, (5, 16))
    d1 = dist.Categorical(logits=logits)
    d2 = dist.Categorical(probs=jax.nn.softmax(logits, -1))
    v = jnp.arange(5) % 16
    assert jnp.allclose(d1.log_prob(v), d2.log_prob(v), atol=1e-5)


def test_categorical_normalization():
    logits = jax.random.normal(KEY, (16,)) * 3
    d = dist.Categorical(logits=logits)
    total = jnp.exp(jax.vmap(d.log_prob)(jnp.arange(16))).sum()
    assert jnp.allclose(total, 1.0, atol=1e-5)


def test_bernoulli_sample_mean():
    d = dist.Bernoulli(probs=0.3)
    xs = d.sample(KEY, (50_000,))
    assert abs(float(xs.mean()) - 0.3) < 0.01


def test_independent_reinterprets_batch():
    d = dist.Normal(jnp.zeros((3, 4)), 1.0)
    di = dist.Independent(d, 1)
    x = di.sample(KEY)
    assert di.log_prob(x).shape == (3,)
    assert jnp.allclose(di.log_prob(x), d.log_prob(x).sum(-1))


def test_transformed_distribution_density():
    """TD(Normal, Exp) == LogNormal."""
    from repro.distributions.transforms import ExpTransform

    td = dist.TransformedDistribution(dist.Normal(0.2, 0.8), [ExpTransform()])
    ln = dist.LogNormal(0.2, 0.8)
    x = jnp.asarray([0.5, 1.0, 2.7])
    assert jnp.allclose(td.log_prob(x), ln.log_prob(x), atol=1e-5)
    s = td.sample(KEY, (10,))
    assert jnp.all(s > 0)


def test_mixture_same_family():
    mix = dist.Categorical(probs=jnp.asarray([0.25, 0.75]))
    comp = dist.Normal(jnp.asarray([-2.0, 3.0]), jnp.asarray([0.5, 0.5]))
    d = dist.MixtureSameFamily(mix, comp)
    xs = d.sample(KEY, (30_000,))
    assert abs(float(xs.mean()) - (0.25 * -2 + 0.75 * 3)) < 0.05
    lp = d.log_prob(jnp.asarray(3.0))
    expected = np.log(0.25 * ss.norm(-2, 0.5).pdf(3.0) + 0.75 * ss.norm(3, 0.5).pdf(3.0))
    assert float(lp) == pytest.approx(expected, rel=1e-4)


def test_multivariate_normal_logprob():
    cov = jnp.asarray([[2.0, 0.5], [0.5, 1.0]])
    d = dist.MultivariateNormal(jnp.zeros(2), scale_tril=jnp.linalg.cholesky(cov))
    x = jnp.asarray([0.3, -0.8])
    assert float(d.log_prob(x)) == pytest.approx(
        ss.multivariate_normal(np.zeros(2), np.asarray(cov)).logpdf(np.asarray(x)), rel=1e-4
    )


def test_dirichlet_mean():
    alpha = jnp.asarray([2.0, 3.0, 5.0])
    d = dist.Dirichlet(alpha)
    xs = d.sample(KEY, (20_000,))
    assert np.allclose(xs.mean(0), alpha / alpha.sum(), atol=0.01)


def test_poisson_pmf():
    d = dist.Poisson(3.5)
    ks = jnp.arange(10)
    assert np.allclose(jax.vmap(d.log_prob)(ks), ss.poisson(3.5).logpmf(np.arange(10)), atol=1e-4)


def test_expanded_distribution_broadcast():
    d = dist.Normal(0.0, 1.0).expand((3, 2))
    x = d.sample(KEY)
    assert x.shape == (3, 2)
    assert d.log_prob(x).shape == (3, 2)
