"""Contraction planner + plan cache: the planner/executor split must be a
pure lowering change over the legacy greedy eliminator.

* Tree- and grid-structured factor graphs: the planned contraction agrees
  with ``dispatch="pairwise"`` (bit-identical when the plan degenerates to
  greedy ElimSteps, tight-tolerance when branch-and-bound reorders).
* Scan-rolled chains (length past the cost-model crossover) are
  BIT-IDENTICAL to the unrolled pairwise path — the forward sweep reproduces
  greedy's float-op association exactly.
* The plan cache keys on structure, not values: a second same-shape
  contraction plans zero times; a different shape misses.
* Planner internals: `describe()` inspectability, fingerprint
  stability/knob-sensitivity, the chain-length crossover.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributions as dist
from repro.core import handlers
from repro.core import primitives as P
from repro.infer import (
    clear_plan_cache,
    config_enumerate,
    infer_discrete,
    plan_cache_stats,
)
from repro.infer.contract import (
    ChainStep,
    chain_threshold,
    contract_log_factors,
    factor_structs,
    fingerprint,
    plan_elimination,
    plan_knobs,
    planned_contraction,
)
from repro.infer.traceenum_elbo import _max_op

KEEP = ("REPRO_ENUM_DISPATCH", "REPRO_ENUM_CHAIN_MIN", "REPRO_ENUM_CHAIN_LOWER")


@pytest.fixture(autouse=True)
def _clean_env_and_cache(monkeypatch):
    for var in KEEP:
        monkeypatch.delenv(var, raising=False)
    clear_plan_cache()
    yield
    clear_plan_cache()


# ---------------------------------------------------------------------------
# synthetic factor graphs (the `_collect_factors` layout: right-aligned
# log-tensors, z_i on enum dim -(i+1))
# ---------------------------------------------------------------------------


def embed(t, dims, n_dims):
    """Right-align a small dense tensor onto enum dims `dims` (ascending)."""
    shape = [1] * n_dims
    for d, k in zip(dims, t.shape):
        shape[n_dims + d] = k
    order = np.argsort(dims)  # ascending dims = memory order of axes
    return jnp.reshape(jnp.transpose(t, tuple(order)), shape)


def chain_factors(T, K, seed=0):
    """z_0 -> z_1 -> ... -> z_T with a unary on every node."""
    rng = np.random.default_rng(seed)
    n = T + 1
    factors = [(frozenset(), embed(jnp.asarray(rng.normal(size=K), jnp.float32), (-1,), 1), None)]
    for t in range(1, n):
        pair = jnp.asarray(rng.normal(size=(K, K)), jnp.float32)
        factors.append((frozenset(), embed(pair, (-(t + 1), -t), t + 1), None))
        un = jnp.asarray(rng.normal(size=K), jnp.float32)
        factors.append((frozenset(), embed(un, (-(t + 1),), t + 1), None))
    return factors, frozenset(-(t + 1) for t in range(n))


def tree_factors(K, seed=1):
    """A binary tree of 7 latents (root 0, children 1/2, leaves 3..6)."""
    rng = np.random.default_rng(seed)
    edges = [(0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6)]
    n = 7
    factors = []
    for a, b in edges:
        pair = jnp.asarray(rng.normal(size=(K, K)), jnp.float32)
        da, db = -(a + 1), -(b + 1)
        lo, hi = min(da, db), max(da, db)
        t = pair if da < db else pair.T
        factors.append((frozenset(), embed(t, (lo, hi), -lo), None))
    for v in range(n):
        un = jnp.asarray(rng.normal(size=K), jnp.float32)
        factors.append((frozenset(), embed(un, (-(v + 1),), v + 1), None))
    return factors, frozenset(-(v + 1) for v in range(n))


def grid_factors(rows, cols, K, seed=2):
    """A rows x cols MRF grid — loops, so no chain shortcut applies."""
    rng = np.random.default_rng(seed)
    n = rows * cols
    idx = lambda r, c: r * cols + c  # noqa: E731
    factors = []
    for r in range(rows):
        for c in range(cols):
            for r2, c2 in ((r, c + 1), (r + 1, c)):
                if r2 < rows and c2 < cols:
                    pair = jnp.asarray(rng.normal(size=(K, K)), jnp.float32)
                    da, db = -(idx(r, c) + 1), -(idx(r2, c2) + 1)
                    lo, hi = min(da, db), max(da, db)
                    t = pair if da < db else pair.T
                    factors.append((frozenset(), embed(t, (lo, hi), -lo), None))
    return factors, frozenset(-(v + 1) for v in range(n))


def contract(factors, pool, dispatch, **kw):
    return jnp.ravel(contract_log_factors(factors, {}, pool, dispatch=dispatch, **kw))


# ---------------------------------------------------------------------------
# planner-vs-greedy parity on trees and grids
# ---------------------------------------------------------------------------


def test_tree_parity_bit_identical():
    # every branch is shorter than the scan crossover, so the plan is pure
    # ElimSteps — the exact greedy schedule, bit for bit
    factors, pool = tree_factors(K=4)
    a = contract(factors, pool, "auto")
    p = contract(factors, pool, "pairwise")
    assert jnp.array_equal(a, p)


def test_grid_parity():
    # loops: branch-and-bound may beat the sorted-dim greedy order, so
    # demand tight agreement rather than bit-identity
    factors, pool = grid_factors(3, 3, K=3)
    a = contract(factors, pool, "auto")
    p = contract(factors, pool, "pairwise")
    np.testing.assert_allclose(np.asarray(a), np.asarray(p), rtol=2e-6)


def test_grid_max_semiring_parity():
    factors, pool = grid_factors(3, 3, K=3)
    a = contract(factors, pool, "auto", sum_op=_max_op)
    p = contract(factors, pool, "pairwise", sum_op=_max_op)
    np.testing.assert_allclose(np.asarray(a), np.asarray(p), rtol=2e-6)


# ---------------------------------------------------------------------------
# scan-rolled chains: bit-identical to the unrolled pairwise path
# ---------------------------------------------------------------------------


def test_long_chain_scan_bit_identical():
    T = chain_threshold() + 6  # comfortably past the crossover: scan lowering
    factors, pool = chain_factors(T, K=5)
    plan = planned_contraction([(t, s) for _, t, s in factors], pool, pool)
    chains = [s for s in plan.steps if isinstance(s, ChainStep)]
    assert len(chains) == 1 and chains[0].lower == "scan" and chains[0].absorb
    a = contract(factors, pool, "auto")
    p = contract(factors, pool, "pairwise")
    assert jnp.array_equal(a, p), "scan-rolled chain must match greedy bit-for-bit"


def test_long_chain_scan_bit_identical_max_semiring():
    T = chain_threshold() + 6
    factors, pool = chain_factors(T, K=5)
    a = contract(factors, pool, "auto", sum_op=_max_op)
    p = contract(factors, pool, "pairwise", sum_op=_max_op)
    assert jnp.array_equal(a, p)


def test_long_chain_viterbi_assignments_match():
    T, K = chain_threshold() + 4, 3
    rng = np.random.default_rng(3)
    trans = jnp.asarray(rng.dirichlet(np.ones(K), size=K), jnp.float32)
    init_p = jnp.asarray(rng.dirichlet(np.ones(K)), jnp.float32)
    locs = jnp.linspace(-2.0, 2.0, K)
    obs = jnp.asarray(rng.normal(size=T), jnp.float32)

    @config_enumerate
    def hmm():
        z = P.sample("z_0", dist.Categorical(init_p))
        P.sample("x_0", dist.Normal(locs[z], 1.0), obs=obs[0])
        for t in range(1, T):
            z = P.sample(f"z_{t}", dist.Categorical(trans[z]))
            P.sample(f"x_{t}", dist.Normal(locs[z], 1.0), obs=obs[t])

    def decode(mode):
        os.environ["REPRO_ENUM_DISPATCH"] = mode
        try:
            dec = infer_discrete(hmm, temperature=0, rng_key=jax.random.PRNGKey(2))
            tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(3))).get_trace()
            return [int(tr[f"z_{t}"]["value"]) for t in range(T)]
        finally:
            os.environ.pop("REPRO_ENUM_DISPATCH", None)

    assert decode("auto") == decode("pairwise")


# ---------------------------------------------------------------------------
# plan cache: structural keying, hits, and stats
# ---------------------------------------------------------------------------


def test_plan_cache_hit_on_same_structure():
    factors, pool = chain_factors(chain_threshold() + 2, K=4, seed=7)
    contract(factors, pool, "auto")
    s0 = plan_cache_stats()
    assert s0["misses"] >= 1 and s0["size"] >= 1

    # same structure, different values: the plan must be reused, not rebuilt
    factors2, pool2 = chain_factors(chain_threshold() + 2, K=4, seed=8)
    contract(factors2, pool2, "auto")
    s1 = plan_cache_stats()
    assert s1["misses"] == s0["misses"], "same-structure contraction replanned"
    assert s1["hits"] > s0["hits"]


def test_plan_cache_miss_on_different_structure():
    factors, pool = chain_factors(chain_threshold() + 2, K=4)
    contract(factors, pool, "auto")
    misses = plan_cache_stats()["misses"]
    factors2, pool2 = chain_factors(chain_threshold() + 2, K=5)  # different K
    contract(factors2, pool2, "auto")
    assert plan_cache_stats()["misses"] == misses + 1


def test_plan_cache_disabled(monkeypatch):
    monkeypatch.setenv("REPRO_ENUM_PLAN_CACHE", "0")
    factors, pool = chain_factors(4, K=3)
    before = plan_cache_stats()
    a = contract(factors, pool, "auto")
    p = contract(factors, pool, "pairwise")
    assert jnp.array_equal(a, p)
    assert plan_cache_stats()["size"] == before["size"]


# ---------------------------------------------------------------------------
# planner internals
# ---------------------------------------------------------------------------


def test_plan_describe_inspectable():
    factors, pool = chain_factors(chain_threshold() + 2, K=4)
    plan = planned_contraction([(t, s) for _, t, s in factors], pool, pool)
    text = plan.describe()
    assert "ContractionPlan" in text and "chain[scan]" in text
    assert "absorb front" in text and "outputs:" in text
    assert plan.cost > 0


def test_chain_step_eliminates():
    step = ChainStep(
        path=(-4, -3, -2, -1), edges=((0,), (1,), (2,)),
        folded=((), (3,), (4,), ()), absorbed=(5,), absorb=True,
        lower="scan", out=6,
    )
    assert step.eliminates() == (-4, -3, -2)
    step2 = ChainStep(
        path=(-4, -3, -2, -1), edges=((0,), (1,), (2,)),
        folded=((), (3,), (4,), ()), absorbed=(), absorb=False,
        lower="tree", out=6,
    )
    assert step2.eliminates() == (-3, -2)


def test_chain_threshold_default_and_override(monkeypatch):
    default = chain_threshold()
    assert 10 <= default <= 32  # the cost-model crossover, not a magic constant
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    assert chain_threshold() == 2
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "1")
    assert chain_threshold() == 2  # floor: a 1-edge "chain" is a plain matmul


def test_fingerprint_ignores_values_tracks_structure():
    factors, pool = chain_factors(6, K=3, seed=0)
    factors2, _ = chain_factors(6, K=3, seed=9)
    ts = [(t, s) for _, t, s in factors]
    ts2 = [(t, s) for _, t, s in factors2]
    knobs = plan_knobs()
    f1 = fingerprint(factor_structs(ts, pool), frozenset(pool), "logsumexp", knobs)
    f2 = fingerprint(factor_structs(ts2, pool), frozenset(pool), "logsumexp", knobs)
    assert f1 == f2  # values never enter the key
    f3 = fingerprint(factor_structs(ts, pool), frozenset(pool), "max", knobs)
    assert f3 != f1  # semiring does
    f4 = fingerprint(
        factor_structs(ts, pool), frozenset(pool), "logsumexp",
        ("2",) + tuple(knobs[1:]),
    )
    assert f4 != f1  # and so do the planning knobs


def test_forced_lowering_parity(monkeypatch):
    factors, pool = chain_factors(chain_threshold() + 2, K=4)
    p = contract(factors, pool, "pairwise")
    for lower, rtol in (("scan", 0.0), ("tree", 2e-6), ("folds", 2e-6)):
        monkeypatch.setenv("REPRO_ENUM_CHAIN_LOWER", lower)
        clear_plan_cache()
        a = contract(factors, pool, "auto")
        if rtol == 0.0:
            assert jnp.array_equal(a, p), f"{lower} lowering not bit-identical"
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(p), rtol=rtol)


def test_plan_elimination_pure_structural():
    factors, pool = tree_factors(K=3)
    ts = [(t, s) for _, t, s in factors]
    structs = factor_structs(ts, pool)
    plan1 = plan_elimination(structs, frozenset(pool))
    plan2 = plan_elimination(structs, frozenset(pool))
    assert plan1.steps == plan2.steps and plan1.outputs == plan2.outputs
    assert set(plan1.eliminated) == set(pool)
