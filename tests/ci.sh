#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml: pinned deps + the tier-1 verify
# command on CPU. The suite must never again fail at collection — missing
# optional deps (hypothesis, scipy) skip their modules instead of erroring.
#
# Usage: tests/ci.sh [all|engine|conformance|docs] [extra pytest args...]
#   engine      - core/inference/kernel suites (-p no:randomly for determinism,
#                 --durations=10 to keep slow tests visible)
#   conformance - the distribution conformance + goodness-of-fit suite, run as
#                 its own step so distribution regressions are attributed
#                 distinctly from engine failures
#   docs        - doctested infer/ modules + executable docs/ pages
# Extra args after the step name are forwarded to pytest, e.g.
#   tests/ci.sh engine -k enum -x
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${CI_INSTALL:-0}" == "1" ]]; then
    python -m pip install -r requirements.txt
fi

export JAX_PLATFORMS=cpu
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

STEP="${1:-all}"
if [[ $# -gt 0 ]]; then shift; fi

run_engine() {
    python -m pytest -p no:randomly -q --durations=10 \
        --ignore=tests/test_distributions_conformance.py "$@"
}

run_conformance() {
    python -m pytest -p no:randomly -q --durations=10 \
        tests/test_distributions_conformance.py "$@"
}

run_docs() {
    # docs: the documentation is executable — module docstring examples and
    # the docs/ pages are doctests, and broken example code fails CI
    python -m pytest -q --doctest-modules \
        src/repro/infer/mcmc.py src/repro/infer/diagnostics.py \
        src/repro/infer/predictive.py src/repro/infer/autoguide.py
    python -m doctest docs/inference.md docs/backends.md docs/enumeration.md
}

case "$STEP" in
    engine)      run_engine "$@" ;;
    conformance) run_conformance "$@" ;;
    docs)        run_docs ;;
    all)         run_engine "$@"; run_conformance "$@"; run_docs ;;
    *) echo "unknown step '$STEP' (use all|engine|conformance|docs)" >&2; exit 2 ;;
esac
