#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml: pinned deps + the tier-1 verify
# command on CPU. The suite must never again fail at collection — missing
# optional deps (hypothesis, scipy) skip their modules instead of erroring.
#
# Usage: tests/ci.sh [all|lint|engine|conformance|docs|bench] [extra pytest args...]
#   lint        - ruff check over src/tests/benchmarks + ruff format --check on
#                 the ratchet list below (skips with a warning if ruff is not
#                 installed; CI installs it from requirements.txt)
#   engine      - core/inference/kernel suites (-p no:randomly for determinism,
#                 --durations=10 to keep slow tests visible)
#   conformance - the distribution conformance + goodness-of-fit suite, run as
#                 its own step so distribution regressions are attributed
#                 distinctly from engine failures
#   docs        - doctested infer/ modules + executable docs/ pages
#   bench       - smoke-mode benchmarks; writes BENCH_enum.json (uploaded as a
#                 workflow artifact) and FAILS on any retrace-counter
#                 regression (the counters must stay == 1)
# Extra args after the step name are forwarded to pytest, e.g.
#   tests/ci.sh engine -k enum -x
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${CI_INSTALL:-0}" == "1" ]]; then
    python -m pip install -r requirements.txt
fi

export JAX_PLATFORMS=cpu
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

STEP="${1:-all}"
if [[ $# -gt 0 ]]; then shift; fi

run_lint() {
    if ! command -v ruff >/dev/null 2>&1; then
        echo "WARNING: ruff not installed; skipping lint (pip install -r requirements.txt)" >&2
        return 0
    fi
    ruff check src tests benchmarks
    # format is ratcheted: files (re)written since the lint stage landed must
    # stay formatter-clean; pre-existing modules join as they get touched
    ruff format --check \
        src/repro/kernels/semiring.py \
        benchmarks/enum_ve.py \
        tests/test_enum_dispatch.py
}

run_engine() {
    python -m pytest -p no:randomly -q --durations=10 \
        --ignore=tests/test_distributions_conformance.py "$@"
}

run_conformance() {
    python -m pytest -p no:randomly -q --durations=10 \
        tests/test_distributions_conformance.py "$@"
}

run_docs() {
    # docs: the documentation is executable — module docstring examples and
    # the docs/ pages are doctests, and broken example code fails CI
    python -m pytest -q --doctest-modules \
        src/repro/infer/mcmc.py src/repro/infer/diagnostics.py \
        src/repro/infer/predictive.py src/repro/infer/autoguide.py
    python -m doctest docs/inference.md docs/backends.md docs/enumeration.md \
        docs/kernels.md
}

run_bench() {
    # smoke-mode benchmarks double as regression gates: each asserts its
    # retrace counter stays at 1 and exits nonzero otherwise
    python benchmarks/svi_sharded.py --smoke
    python benchmarks/mcmc_chains.py --smoke
    python benchmarks/enum_ve.py --smoke --json BENCH_enum.json
}

case "$STEP" in
    lint)        run_lint ;;
    engine)      run_engine "$@" ;;
    conformance) run_conformance "$@" ;;
    docs)        run_docs ;;
    bench)       run_bench ;;
    all)         run_lint; run_engine "$@"; run_conformance "$@"; run_docs; run_bench ;;
    *) echo "unknown step '$STEP' (use all|lint|engine|conformance|docs|bench)" >&2; exit 2 ;;
esac
