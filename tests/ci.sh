#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml: pinned deps + the tier-1 verify
# command on CPU. The suite must never again fail at collection — missing
# optional deps (hypothesis, scipy, ruff, pytest-cov) skip their stage/module
# with a warning instead of erroring.
#
# Usage: tests/ci.sh [all|lint|engine|coverage|conformance|docs|examples|bench|bench-gate] [extra pytest args...]
#   lint        - ruff check over src/tests/benchmarks/examples + ratcheted
#                 ruff format --check (skips with a warning if ruff is not
#                 installed; CI installs it from requirements.txt)
#   engine      - core/inference/kernel/serve suites (-p no:randomly for
#                 determinism, --durations=10 to keep slow tests visible);
#                 runs under pytest-cov when available, writing .coverage
#                 for the coverage stage
#   coverage    - coverage floor: per-package report over the engine run's
#                 .coverage data, failing under REPRO_COV_FLOOR percent
#                 (the ratchet; recalibrate with tools/coverage_floor.py
#                 and raise it as suites grow — never lower it to land code)
#   conformance - the distribution conformance + goodness-of-fit suite, run as
#                 its own step so distribution regressions are attributed
#                 distinctly from engine failures
#   docs        - doctested infer/serve modules + executable docs/ pages
#   examples    - paper-reproduction examples at tiny step counts (each
#                 example's own convergence assertions still apply), run
#                 exactly the way users run them (installed package path,
#                 no sys.path hacks)
#   bench       - smoke-mode benchmarks; writes BENCH_enum.json,
#                 BENCH_serve.json, BENCH_mcmc.json, BENCH_gaussian.json and
#                 BENCH_smc.json (uploaded as workflow
#                 artifacts) and FAILS on any retrace-counter regression, if
#                 the bucketed serve path drops under its 5x-vs-naive floor,
#                 if the fused MCMC driver drops under 2x the legacy
#                 sampler's draws/sec at 1024 chains, or if the SMC logZ
#                 estimator stops converging on its exact Kalman target
#   bench-gate  - bench-regression gate: diffs the freshly written
#                 BENCH_*.json steady-state numbers against the committed
#                 (HEAD) baselines; >25% regression fails (tune with
#                 REPRO_BENCH_TOLERANCE for noisy runners)
# Extra args after the step name are forwarded to pytest, e.g.
#   tests/ci.sh engine -k enum -x
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${CI_INSTALL:-0}" == "1" ]]; then
    python -m pip install -r requirements.txt
fi

export JAX_PLATFORMS=cpu
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# Coverage floor (percent). Calibrated with tools/coverage_floor.py on the
# engine suite (76.9% measured at the SMC PR), minus ~5 points of
# margin for coverage.py-vs-estimator methodology and the 3.10/3.12 matrix.
# Ratchet UP as coverage grows; never lower it to land code.
REPRO_COV_FLOOR="${REPRO_COV_FLOOR:-71}"

STEP="${1:-all}"
if [[ $# -gt 0 ]]; then shift; fi

run_lint() {
    if ! command -v ruff >/dev/null 2>&1; then
        echo "WARNING: ruff not installed; skipping lint (pip install -r requirements.txt)" >&2
        return 0
    fi
    ruff check src tests benchmarks examples
    # format is ratcheted: files (re)written since the lint stage landed must
    # stay formatter-clean; pre-existing modules join as they get touched
    # (the contract/ package + planner tests join once formatted on a
    # machine with ruff available)
    ruff format --check \
        src/repro/kernels/semiring.py \
        benchmarks/enum_ve.py \
        tests/test_enum_dispatch.py
}

have_pytest_cov() {
    python -c "import pytest_cov" >/dev/null 2>&1
}

run_engine() {
    local cov_args=()
    if [[ "${REPRO_COV:-1}" == "0" ]]; then
        echo "note: coverage disabled via REPRO_COV=0" >&2
    elif have_pytest_cov; then
        # write .coverage for the coverage stage; the floor is enforced
        # there so failures attribute to the right CI step
        cov_args=(--cov=repro --cov-report= --cov-fail-under=0)
    else
        echo "WARNING: pytest-cov not installed; engine runs without coverage" >&2
    fi
    python -m pytest -p no:randomly -q --durations=10 ${cov_args[@]+"${cov_args[@]}"} \
        --ignore=tests/test_distributions_conformance.py "$@"
}

run_coverage() {
    if [[ "${REPRO_COV:-1}" == "0" ]]; then
        echo "note: coverage disabled via REPRO_COV=0; skipping coverage floor" >&2
        return 0
    fi
    if ! have_pytest_cov; then
        echo "WARNING: pytest-cov not installed; skipping coverage floor" >&2
        return 0
    fi
    if [[ ! -f .coverage ]]; then
        echo "ERROR: no .coverage data — run 'tests/ci.sh engine' first" >&2
        return 1
    fi
    # NB: enforces against whatever .coverage holds — run the full engine
    # stage immediately before (as `all` and the workflow do); a stale or
    # partial-run file (engine -k ...) makes the floor meaningless
    # per-package/file report + the ratcheted floor (equivalent to running
    # the engine step with --cov-fail-under=$REPRO_COV_FLOOR)
    python -m coverage report --fail-under="$REPRO_COV_FLOOR"
}

run_conformance() {
    python -m pytest -p no:randomly -q --durations=10 \
        tests/test_distributions_conformance.py "$@"
}

run_docs() {
    # docs: the documentation is executable — module docstring examples and
    # the docs/ pages are doctests, and broken example code fails CI
    python -m pytest -q --doctest-modules \
        src/repro/infer/mcmc.py src/repro/infer/diagnostics.py \
        src/repro/infer/predictive.py src/repro/infer/autoguide.py \
        src/repro/infer/smc.py \
        src/repro/serve/engine.py src/repro/settings.py
    python -m doctest docs/inference.md docs/backends.md docs/enumeration.md \
        docs/kernels.md docs/serving.md
}

run_examples() {
    # tiny step counts, but every example's own assertions (ELBO improvement,
    # r_hat, MAP accuracy) still gate — the reproductions can't silently rot
    python examples/quickstart.py --steps 60 --batch 64
    python examples/gmm.py --steps 30 --num-points 80
    python examples/eight_schools.py --chains 2 --warmup 300 --samples 300
    python examples/dmm.py --steps 2
    python -m repro.launch.serve posterior --smoke --requests 12
    # streaming service end-to-end: background trainer + hot swaps under
    # live HTTP traffic; exits nonzero if the zero-drop/zero-recompile
    # contract breaks
    python -m repro.launch.stream --smoke --deadline-ms 2000
}

run_bench() {
    # smoke-mode benchmarks double as regression gates: each asserts its
    # retrace counter and (for serve) the 5x-vs-naive floor, exiting nonzero
    # otherwise. The persistent XLA compilation cache is pinned to a repo-
    # local dir (restored across CI runs via actions/cache) so cold-compile
    # numbers measure *our* trace+lowering cost, not XLA re-optimizing
    # unchanged programs.
    export REPRO_COMPILATION_CACHE_DIR="${REPRO_COMPILATION_CACHE_DIR:-$PWD/.xla-cache}"
    python benchmarks/svi_sharded.py --smoke
    python benchmarks/mcmc_chains.py --smoke
    python benchmarks/enum_ve.py --smoke --json BENCH_enum.json
    python benchmarks/serve_bench.py --smoke --json BENCH_serve.json
    python benchmarks/mcmc_bench.py --smoke --json BENCH_mcmc.json
    python benchmarks/gaussian_ve.py --smoke --json BENCH_gaussian.json
    python benchmarks/smc_bench.py --smoke --json BENCH_smc.json
    python - <<'PY'
from repro.launch.compile_cache import compilation_cache_stats
from repro.infer import plan_cache_stats
print("plan cache (this process):", plan_cache_stats())
print("compilation cache:", compilation_cache_stats())
PY
}

run_bench_gate() {
    python benchmarks/check_regression.py BENCH_enum.json BENCH_serve.json BENCH_mcmc.json BENCH_gaussian.json BENCH_smc.json
}

case "$STEP" in
    lint)        run_lint ;;
    engine)      run_engine "$@" ;;
    coverage)    run_coverage ;;
    conformance) run_conformance "$@" ;;
    docs)        run_docs ;;
    examples)    run_examples ;;
    bench)       run_bench ;;
    bench-gate)  run_bench_gate ;;
    all)         run_lint; run_engine "$@"; run_coverage; run_conformance "$@";
                 run_docs; run_examples; run_bench; run_bench_gate ;;
    *) echo "unknown step '$STEP' (use all|lint|engine|coverage|conformance|docs|examples|bench|bench-gate)" >&2; exit 2 ;;
esac
