#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml: pinned deps + the tier-1 verify
# command on CPU. The suite must never again fail at collection — missing
# optional deps (hypothesis) skip their modules instead of erroring.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${CI_INSTALL:-0}" == "1" ]]; then
    python -m pip install -r requirements.txt
fi

JAX_PLATFORMS=cpu PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q "$@"

# docs: the documentation is executable — module docstring examples and the
# docs/ pages are doctests, and broken example code fails CI
JAX_PLATFORMS=cpu PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q --doctest-modules \
    src/repro/infer/mcmc.py src/repro/infer/diagnostics.py \
    src/repro/infer/predictive.py src/repro/infer/autoguide.py
JAX_PLATFORMS=cpu PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m doctest \
    docs/inference.md docs/backends.md
