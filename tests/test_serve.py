"""Serving-path integration: prefill -> decode generation loops, ring-buffer
windows past their capacity, and sampling determinism."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import (
    forward,
    init_cache,
    init_params,
    make_decode_step,
)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-130m", "recurrentgemma-9b"])
def test_generation_loop(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, prompt_len, gen = 2, 8, 8
    total = prompt_len + gen
    prompts = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab)

    cache = init_cache(cfg, B, total)
    logits, cache = forward(cfg, params, prompts, mode="prefill", cache=cache)[0:2]
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    outs = [tok]
    for i in range(gen - 1):
        nxt, cache, lg = decode(params, cache, tok, jax.random.fold_in(key, i))
        assert not bool(jnp.any(jnp.isnan(lg)))
        tok = nxt[:, None].astype(jnp.int32)
        outs.append(tok)
    seq = jnp.concatenate(outs, axis=1)
    assert seq.shape == (B, gen)
    assert bool(jnp.all((seq >= 0) & (seq < cfg.vocab)))


def test_windowed_decode_past_window_capacity():
    """recurrentgemma ring-buffer KV: decoding beyond `window` tokens must
    stay finite and keep matching the full forward pass (which is the
    ground truth for a bounded-window model)."""
    cfg = configs.get_smoke_config("recurrentgemma-9b")  # window=8
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 1, 24  # 3x the window
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full, _, _ = forward(cfg, params, toks)
    cache = init_cache(cfg, B, S)
    _, cache = forward(cfg, params, toks[:, :4], mode="prefill", cache=cache)[0:2]
    outs = []
    for t in range(4, S):
        lg, cache, _ = forward(cfg, params, toks[:, t : t + 1], mode="decode", cache=cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full[:, 4:])))
    assert err < 2e-3, err


def test_decode_sampling_deterministic_under_key():
    cfg = configs.get_smoke_config("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    decode = make_decode_step(cfg)
    cache1 = init_cache(cfg, 1, 8)
    cache2 = init_cache(cfg, 1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    k = jax.random.PRNGKey(42)
    n1 = decode(params, cache1, tok, k)[0]
    n2 = decode(params, cache2, tok, k)[0]
    assert int(n1[0]) == int(n2[0])
