"""Per-architecture smoke tests (assignment requirement: reduced config,
one forward/train step on CPU, shape + no-NaN assertions) plus the deeper
consistency properties: decode==train, MoE path equivalence, PPL==raw."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import (
    ModelConfig,
    forward,
    init_cache,
    init_params,
    lm_program,
    nll_loss,
    make_train_step,
)
from repro.models.frontends import frontend_embed
from repro import optim


def _inputs(cfg, key, B=2, S=16):
    tgt = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.modality == "audio":
        return frontend_embed(cfg, tgt), tgt
    if cfg.modality == "vlm":
        patches = jax.random.normal(key, (B, S, 32))
        return frontend_embed(cfg, patches), tgt
    return tgt, tgt


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    assert sum(x.size for x in jax.tree.leaves(params)) == cfg.param_count()
    inp, tgt = _inputs(cfg, key)
    logits, _, aux = forward(cfg, params, inp)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one train step via the PPL machinery (MLE == SVI with empty guide)
    optimizer = optim.Adam(1e-3)
    step = jax.jit(make_train_step(cfg, optimizer))
    state = optimizer.init(params)
    batch = {"inputs": inp, "targets": tgt} if cfg.modality != "text" else {
        "tokens": inp, "targets": tgt}
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-32b", "mamba2-130m",
                                  "recurrentgemma-9b", "deepseek-v2-lite-16b"])
def test_decode_matches_train(arch):
    cfg = configs.get_smoke_config(arch)
    if cfg.moe:
        cfg = cfg.replace(capacity_factor=8.0)  # dropless for exactness
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    S, B = 12, 2
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full, _, _ = forward(cfg, params, toks)
    half = S // 2
    cache = init_cache(cfg, B, S)
    _, cache = forward(cfg, params, toks[:, :half], mode="prefill", cache=cache)[0:2]
    outs = []
    for t in range(half, S):
        lg, cache, _ = forward(cfg, params, toks[:, t : t + 1], mode="decode", cache=cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec - full[:, half:])) < 2e-3


def test_moe_einsum_matches_sort():
    base = dict(family="moe", n_layers=2, d_model=48, vocab=64, n_heads=4,
                n_kv_heads=2, moe=True, n_experts=4, top_k=2, d_expert=32,
                param_dtype="float32", compute_dtype="float32", remat=False)
    cfg_e = ModelConfig(name="e", capacity_factor=8.0, **base)
    cfg_s = ModelConfig(name="s", moe_impl="sort", **base)
    params = init_params(cfg_e, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 64)
    le = forward(cfg_e, params, toks)[0]
    ls = forward(cfg_s, params, toks)[0]
    assert jnp.allclose(le, ls, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """At capacity_factor -> 0 the einsum path must drop (outputs differ)."""
    base = dict(family="moe", n_layers=1, d_model=32, vocab=64, n_heads=2,
                n_kv_heads=2, moe=True, n_experts=4, top_k=2, d_expert=16,
                param_dtype="float32", compute_dtype="float32", remat=False)
    lo = ModelConfig(name="lo", capacity_factor=0.25, **base)
    hi = ModelConfig(name="hi", capacity_factor=8.0, **base)
    params = init_params(hi, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64)
    assert not jnp.allclose(forward(lo, params, toks)[0], forward(hi, params, toks)[0])


def test_ppl_program_equals_raw_loss():
    cfg = configs.get_smoke_config("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    from repro.infer.util import log_density
    import jax.tree_util as jtu

    flat, _ = jtu.tree_flatten_with_path(params)
    sites = {
        "lm." + ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path): leaf
        for path, leaf in flat
    }
    lp, _ = log_density(lm_program(cfg, params_template=params), (batch,), {}, sites)
    assert jnp.allclose(-lp / toks.size, nll_loss(cfg, params, batch), atol=1e-5)


def test_bayesian_last_layer_via_lift():
    """`lift` turns the head param into a latent: the paper's technique
    applied to an LM (Bayesian last layer)."""
    from repro.core import primitives as P
    from repro.core.handlers import lift, seed, trace
    from repro import distributions as dist

    cfg = configs.get_smoke_config("smollm-135m").replace(tie_embeddings=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prog = lm_program(cfg, params_template=params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    prior = dist.Normal(jnp.zeros(params["lm_head"].shape), 0.02).to_event(2)
    lifted = lift(prog, prior={"lm.lm_head": prior})
    tr = trace(seed(lifted, 0)).get_trace({"tokens": toks, "targets": toks})
    assert tr["lm.lm_head"]["type"] == "sample"
    assert jnp.isfinite(tr.log_prob_sum())


def test_rglru_scan_matches_sequential():
    from repro.models.rglru import rglru_scan
    import numpy as np

    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (2, 16, 8)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 8))
    h = rglru_scan(a, b)
    ref = jnp.zeros((2, 8))
    for t in range(16):
        ref = a[:, t] * ref + b[:, t]
    assert jnp.allclose(h[:, -1], ref, atol=1e-5)


def test_long_context_window_cache_is_bounded():
    """recurrentgemma decode cache must be O(window), not O(seq)."""
    cfg = configs.get_smoke_config("recurrentgemma-9b")
    cache = init_cache(cfg, batch=2, max_len=4096)
    for k, v in cache["scan"].items():
        if "k" in v:  # attention layer cache
            assert v["k"].shape[-2] <= cfg.window
