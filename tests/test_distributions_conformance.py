"""Universal distribution conformance suite (numpyro test_distributions idiom).

One parametrized harness over every distribution in continuous.py/discrete.py:

1. log_prob against scipy.stats (rtol pinned below),
2. sample shape under sample_shape x batch_shape x event_shape broadcasting,
3. mean/variance against 50k-sample Monte Carlo,
4. constraint membership of samples,

plus goodness-of-fit sampling tests (Kolmogorov-Smirnov for continuous,
chi-square for discrete). The whole module is gated on scipy so collection
never hard-fails on a minimal install (same importorskip pattern as the
hypothesis-based property tests).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

ss = pytest.importorskip("scipy.stats", reason="conformance suite needs scipy")

from repro import distributions as dist

KEY = jax.random.PRNGKey(20260728)

# pinned comparison tolerances (float32 end-to-end)
LOGPROB_RTOL = 1e-4
LOGPROB_ATOL = 1e-4
MC_N = 50_000
MC_RTOL = 0.07
MC_ATOL = 0.07
GOF_N = 20_000
GOF_ALPHA = 0.01


class Case:
    """One distribution under test: scalar-param and batched-param factories,
    an optional scipy reference (frozen dist or callable x -> logpdf)."""

    def __init__(
        self,
        name,
        mk,
        ref=None,
        batched_mk=None,
        batch_shape=(),
        event_shape=(),
        skip_mc=None,
        gof="none",  # "ks" | "chisq" | "none"
        gof_support=None,  # inclusive int upper bound for chisq binning
    ):
        self.name = name
        self.mk = mk
        self.ref = ref
        self.batched_mk = batched_mk
        self.batch_shape = batch_shape
        self.event_shape = event_shape
        self.skip_mc = skip_mc
        self.gof = gof
        self.gof_support = gof_support


def _dirichlet_logpdf(alpha):
    def logpdf(xs):
        xs = np.asarray(xs, np.float64)
        xs = xs / xs.sum(-1, keepdims=True)
        return np.array([ss.dirichlet.logpdf(x, alpha) for x in xs])

    return lambda: logpdf


_W = np.array([[0.5, -0.2], [0.1, 0.3], [-0.4, 0.6], [0.2, 0.2]])
_D = np.array([0.5, 1.0, 0.8, 1.2])
_MVN_COV = np.array([[1.0, 0.3, 0.1], [0.3, 0.8, 0.2], [0.1, 0.2, 1.2]])
_PROBS3 = np.array([0.2, 0.5, 0.3])

CASES = [
    Case(
        "Normal",
        lambda: dist.Normal(0.7, 1.3),
        lambda: ss.norm(0.7, 1.3),
        lambda: dist.Normal(jnp.zeros((2, 3)), jnp.asarray([1.0, 2.0, 0.5])),
        (2, 3),
        gof="ks",
    ),
    Case(
        "LogNormal",
        lambda: dist.LogNormal(0.2, 0.6),
        lambda: ss.lognorm(0.6, scale=np.exp(0.2)),
        lambda: dist.LogNormal(jnp.zeros((3,)), 0.6),
        (3,),
        gof="ks",
    ),
    Case(
        "Uniform",
        lambda: dist.Uniform(-1.0, 2.0),
        lambda: ss.uniform(-1.0, 3.0),
        lambda: dist.Uniform(jnp.zeros((4, 1)), 2.0),
        (4, 1),
        gof="ks",
    ),
    Case(
        "Exponential",
        lambda: dist.Exponential(1.7),
        lambda: ss.expon(scale=1 / 1.7),
        lambda: dist.Exponential(jnp.asarray([0.5, 1.0, 2.0])),
        (3,),
        gof="ks",
    ),
    Case(
        "Laplace",
        lambda: dist.Laplace(-0.3, 0.9),
        lambda: ss.laplace(-0.3, 0.9),
        lambda: dist.Laplace(jnp.zeros((2, 2)), 0.9),
        (2, 2),
        gof="ks",
    ),
    Case(
        "Cauchy",
        lambda: dist.Cauchy(0.4, 1.1),
        lambda: ss.cauchy(0.4, 1.1),
        lambda: dist.Cauchy(jnp.zeros((3,)), jnp.asarray([1.0, 2.0, 0.5])),
        (3,),
        skip_mc="Cauchy moments are undefined",
        gof="ks",
    ),
    Case(
        "HalfNormal",
        lambda: dist.HalfNormal(1.4),
        lambda: ss.halfnorm(scale=1.4),
        lambda: dist.HalfNormal(jnp.asarray([0.5, 1.5])),
        (2,),
        gof="ks",
    ),
    Case(
        "HalfCauchy",
        lambda: dist.HalfCauchy(0.8),
        lambda: ss.halfcauchy(scale=0.8),
        lambda: dist.HalfCauchy(jnp.asarray([[0.5], [1.5]])),
        (2, 1),
        skip_mc="HalfCauchy moments are undefined",
        gof="ks",
    ),
    Case(
        "StudentT",
        lambda: dist.StudentT(7.0, 0.5, 1.2),
        lambda: ss.t(7.0, 0.5, 1.2),
        lambda: dist.StudentT(7.0, jnp.zeros((2, 3)), 1.2),
        (2, 3),
        gof="ks",
    ),
    Case(
        "Gamma",
        lambda: dist.Gamma(2.5, 1.5),
        lambda: ss.gamma(2.5, scale=1 / 1.5),
        lambda: dist.Gamma(jnp.asarray([1.0, 2.0]), jnp.asarray([[0.5], [2.0]])),
        (2, 2),
        gof="ks",
    ),
    Case(
        "Chi2",
        lambda: dist.Chi2(5.0),
        lambda: ss.chi2(5.0),
        lambda: dist.Chi2(jnp.asarray([3.0, 5.0, 9.0])),
        (3,),
        gof="ks",
    ),
    Case(
        "InverseGamma",
        lambda: dist.InverseGamma(4.5, 2.0),
        lambda: ss.invgamma(4.5, scale=2.0),
        lambda: dist.InverseGamma(jnp.asarray([3.0, 4.5]), 2.0),
        (2,),
        skip_mc="4th moment too heavy for stable 50k MC variance",
        gof="ks",
    ),
    Case(
        "Beta",
        lambda: dist.Beta(2.0, 3.5),
        lambda: ss.beta(2.0, 3.5),
        lambda: dist.Beta(jnp.asarray([1.0, 2.0, 4.0]), 3.5),
        (3,),
        gof="ks",
    ),
    Case(
        "Dirichlet",
        lambda: dist.Dirichlet(jnp.asarray([2.0, 3.0, 1.5])),
        _dirichlet_logpdf(np.array([2.0, 3.0, 1.5])),
        lambda: dist.Dirichlet(jnp.broadcast_to(jnp.asarray([2.0, 3.0, 1.5]), (4, 3))),
        (4,),
        (3,),
    ),
    Case(
        "MultivariateNormal",
        lambda: dist.MultivariateNormal(
            jnp.asarray([0.5, -0.5, 1.0]), covariance_matrix=jnp.asarray(_MVN_COV)
        ),
        lambda: ss.multivariate_normal(np.array([0.5, -0.5, 1.0]), _MVN_COV),
        lambda: dist.MultivariateNormal(
            jnp.zeros((2, 3)), covariance_matrix=jnp.asarray(_MVN_COV)
        ),
        (2,),
        (3,),
    ),
    Case(
        "LowRankMultivariateNormal",
        lambda: dist.LowRankMultivariateNormal(
            jnp.asarray([0.0, 0.5, -0.5, 1.0]), jnp.asarray(_W), jnp.asarray(_D)
        ),
        lambda: ss.multivariate_normal(
            np.array([0.0, 0.5, -0.5, 1.0]), _W @ _W.T + np.diag(_D)
        ),
        lambda: dist.LowRankMultivariateNormal(
            jnp.zeros((3, 1, 4)), jnp.asarray(_W), jnp.asarray(_D)
        ),
        (3, 1),
        (4,),
    ),
    Case(
        "VonMises",
        lambda: dist.VonMises(0.5, 2.0),
        lambda: ss.vonmises(2.0, loc=0.5),
        lambda: dist.VonMises(jnp.zeros((2,)), jnp.asarray([1.0, 4.0])),
        (2,),
        skip_mc="circular moments need directional statistics",
        gof="ks",
    ),
    Case(
        "Logistic",
        lambda: dist.Logistic(0.3, 0.8),
        lambda: ss.logistic(0.3, 0.8),
        lambda: dist.Logistic(jnp.zeros((5,)), 0.8),
        (5,),
        gof="ks",
    ),
    Case(
        "Weibull",
        lambda: dist.Weibull(1.5, 2.0),
        lambda: ss.weibull_min(2.0, scale=1.5),
        lambda: dist.Weibull(jnp.asarray([1.0, 1.5]), jnp.asarray([[2.0], [0.8]])),
        (2, 2),
        gof="ks",
    ),
    # -- discrete ----------------------------------------------------------
    Case(
        "Bernoulli",
        lambda: dist.Bernoulli(0.3),
        lambda: ss.bernoulli(0.3),
        lambda: dist.Bernoulli(jnp.asarray([[0.2], [0.7]])),
        (2, 1),
        gof="chisq",
        gof_support=1,
    ),
    Case(
        "Categorical",
        lambda: dist.Categorical(jnp.asarray(_PROBS3)),
        lambda: ss.rv_discrete(values=(np.arange(3), _PROBS3)),
        lambda: dist.Categorical(jnp.broadcast_to(jnp.asarray(_PROBS3), (2, 2, 3))),
        (2, 2),
        gof="chisq",
        gof_support=2,
    ),
    Case(
        "OneHotCategorical",
        lambda: dist.OneHotCategorical(jnp.asarray(_PROBS3)),
        lambda: (lambda xs: np.asarray(xs) @ np.log(_PROBS3)),
        lambda: dist.OneHotCategorical(jnp.broadcast_to(jnp.asarray(_PROBS3), (4, 3))),
        (4,),
        (3,),
    ),
    Case(
        "Binomial",
        lambda: dist.Binomial(10, probs=0.35),
        lambda: ss.binom(10, 0.35),
        lambda: dist.Binomial(jnp.asarray([5, 10]), probs=jnp.asarray([[0.3], [0.6]])),
        (2, 2),
        gof="chisq",
        gof_support=10,
    ),
    Case(
        "Multinomial",
        lambda: dist.Multinomial(8, probs=jnp.asarray(_PROBS3)),
        lambda: (lambda xs: ss.multinomial(8, _PROBS3).logpmf(np.asarray(xs))),
        lambda: dist.Multinomial(8, probs=jnp.broadcast_to(jnp.asarray(_PROBS3), (5, 3))),
        (5,),
        (3,),
    ),
    Case(
        "Poisson",
        lambda: dist.Poisson(3.5),
        lambda: ss.poisson(3.5),
        lambda: dist.Poisson(jnp.asarray([1.0, 3.5, 10.0])),
        (3,),
        gof="chisq",
        gof_support=25,
    ),
    Case(
        "Geometric",
        # scipy geom counts trials (support {1,2,...}); ours counts failures
        lambda: dist.Geometric(0.4),
        lambda: ss.geom(0.4, loc=-1),
        lambda: dist.Geometric(jnp.asarray([[0.3], [0.8]])),
        (2, 1),
        gof="chisq",
        gof_support=30,
    ),
    Case(
        "NegativeBinomial",
        # ours: p = per-trial "failure mass" exponent on value; scipy nbinom(r, 1-p)
        lambda: dist.NegativeBinomial(6.0, probs=0.4),
        lambda: ss.nbinom(6.0, 0.6),
        lambda: dist.NegativeBinomial(jnp.asarray([2.0, 6.0]), probs=0.4),
        (2,),
        gof="chisq",
        gof_support=40,
    ),
]

IDS = [c.name for c in CASES]


def _ref_logprob(case, xs):
    ref = case.ref()
    if hasattr(ref, "logpdf"):
        return np.asarray(ref.logpdf(np.asarray(xs)))
    if hasattr(ref, "logpmf"):
        return np.asarray(ref.logpmf(np.asarray(xs)))
    return np.asarray(ref(np.asarray(xs)))  # plain callable reference


# ---------------------------------------------------------------------------
# check 1: log_prob vs scipy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_log_prob_matches_scipy(case):
    if case.ref is None:
        pytest.skip(f"{case.name}: no scipy reference")
    d = case.mk()
    xs = d.sample(KEY, (64,))
    ours = np.asarray(d.log_prob(xs))
    theirs = _ref_logprob(case, xs)
    assert ours.shape == (64,)
    np.testing.assert_allclose(ours, theirs, rtol=LOGPROB_RTOL, atol=LOGPROB_ATOL)


# ---------------------------------------------------------------------------
# check 2: shape semantics under broadcasting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASES, ids=IDS)
@pytest.mark.parametrize("sample_shape", [(), (7,), (2, 3)], ids=repr)
def test_sample_shape(case, sample_shape):
    d = case.mk()
    xs = d.sample(KEY, sample_shape)
    assert xs.shape == sample_shape + case.event_shape
    assert d.batch_shape == ()
    assert d.log_prob(xs).shape == sample_shape

    db = case.batched_mk()
    assert db.batch_shape == case.batch_shape
    assert db.event_shape == case.event_shape
    xb = db.sample(KEY, sample_shape)
    assert xb.shape == sample_shape + case.batch_shape + case.event_shape
    assert db.log_prob(xb).shape == sample_shape + case.batch_shape


# ---------------------------------------------------------------------------
# check 3: mean / variance vs 50k-sample Monte Carlo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_moments_vs_monte_carlo(case):
    if case.skip_mc:
        pytest.skip(f"{case.name}: {case.skip_mc}")
    d = case.mk()
    xs = np.asarray(d.sample(KEY, (MC_N,))).astype(np.float64)
    try:
        mean = np.asarray(d.mean)
        var = np.asarray(d.variance)
    except NotImplementedError:
        pytest.skip(f"{case.name}: no analytic moments")
    np.testing.assert_allclose(xs.mean(0), mean, rtol=MC_RTOL, atol=MC_ATOL)
    np.testing.assert_allclose(xs.var(0), var, rtol=2 * MC_RTOL, atol=2 * MC_ATOL)


# ---------------------------------------------------------------------------
# check 4: constraint membership
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_samples_satisfy_constraint(case):
    for mk in (case.mk, case.batched_mk):
        d = mk()
        xs = d.sample(KEY, (13,))
        ok = np.asarray(d.support.check(xs))
        assert ok.all(), f"{case.name}: samples violate {d.support}"


# ---------------------------------------------------------------------------
# goodness of fit: KS (continuous) / chi-square (discrete)
# ---------------------------------------------------------------------------

KS_CASES = [c for c in CASES if c.gof == "ks"]
CHISQ_CASES = [c for c in CASES if c.gof == "chisq"]


@pytest.mark.parametrize("case", KS_CASES, ids=[c.name for c in KS_CASES])
def test_gof_kolmogorov_smirnov(case):
    d = case.mk()
    xs = np.asarray(d.sample(KEY, (2000,))).astype(np.float64)
    stat = ss.kstest(xs, case.ref().cdf)
    assert stat.pvalue > GOF_ALPHA, f"{case.name}: KS p={stat.pvalue:.2e}"


@pytest.mark.parametrize("case", CHISQ_CASES, ids=[c.name for c in CHISQ_CASES])
def test_gof_chi_square(case):
    d = case.mk()
    ref = case.ref()
    xs = np.asarray(d.sample(KEY, (GOF_N,)), int)
    hi = case.gof_support
    # bin the support at 0..hi with an overflow bin carrying the tail mass
    counts = np.bincount(np.clip(xs, 0, hi + 1), minlength=hi + 2).astype(float)
    probs = ref.pmf(np.arange(hi + 1))
    probs = np.append(probs, max(1.0 - probs.sum(), 0.0))
    keep = probs * GOF_N >= 5  # chi-square validity: expected count >= 5
    other = ~keep
    counts = np.append(counts[keep], counts[other].sum())
    probs = np.append(probs[keep], probs[other].sum())
    if probs[-1] == 0:
        counts, probs = counts[:-1], probs[:-1]
    stat = ss.chisquare(counts, probs * GOF_N)
    assert stat.pvalue > GOF_ALPHA, f"{case.name}: chi2 p={stat.pvalue:.2e}"


# ---------------------------------------------------------------------------
# enumerate_support coverage: every discrete distribution either enumerates
# or raises an actionable NotImplementedError
# ---------------------------------------------------------------------------

DISCRETE_CASES = {
    "Bernoulli": 2,
    "Categorical": 3,
    "OneHotCategorical": 3,
    "Binomial": 11,
    "Multinomial": None,
    "Poisson": None,
    "Geometric": None,
    "NegativeBinomial": None,
}


@pytest.mark.parametrize("name", sorted(DISCRETE_CASES), ids=sorted(DISCRETE_CASES))
def test_enumerate_support_or_actionable_error(name):
    case = next(c for c in CASES if c.name == name)
    d = case.mk()
    cardinality = DISCRETE_CASES[name]
    if cardinality is None:
        assert not d.has_enumerate_support
        with pytest.raises(NotImplementedError) as excinfo:
            d.enumerate_support()
        # actionable: names the distribution's problem AND a workaround
        assert len(str(excinfo.value)) > 60
        assert "Categorical" in str(excinfo.value) or "marginalize" in str(excinfo.value)
        return
    assert d.has_enumerate_support
    expanded = d.enumerate_support(expand=True)
    compact = d.enumerate_support(expand=False)
    assert expanded.shape == (cardinality,) + d.batch_shape + d.event_shape
    assert compact.shape == (cardinality,) + (1,) * len(d.batch_shape) + d.event_shape
    # every enumerated value is in-support and probabilities sum to one
    assert np.asarray(d.support.check(expanded)).all()
    total = jax.scipy.special.logsumexp(d.log_prob(compact), axis=0)
    np.testing.assert_allclose(np.asarray(total), 0.0, atol=1e-5)

    if name == "Binomial":
        # heterogeneous batched counts cannot enumerate — homogeneous ones can
        with pytest.raises(NotImplementedError, match="homogeneous"):
            case.batched_mk().enumerate_support()
        db = dist.Binomial(10, probs=jnp.asarray([[0.3], [0.6]]))
    else:
        db = case.batched_mk()
    eb = db.enumerate_support(expand=True)
    assert eb.shape == (cardinality,) + db.batch_shape + db.event_shape


# ---------------------------------------------------------------------------
# information-form round-trips (Gaussian semiring, ISSUE 8 satellite) —
# regression tests beside the PR 3 broadcasting fixes, since the Gaussian
# lowering is the first consumer of batched MVN covariance/precision views.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch", [(), (4,), (2, 3)])
def test_normal_information_form_round_trip(batch):
    rng = np.random.default_rng(0)
    loc = jnp.asarray(rng.normal(size=batch).astype(np.float32))
    scale = jnp.asarray(rng.uniform(0.5, 2.0, size=batch).astype(np.float32))
    d = dist.Normal(loc, scale)
    prec, info, log_norm = d.to_information_form()
    assert prec.shape == info.shape == log_norm.shape == batch
    np.testing.assert_allclose(np.asarray(prec), 1.0 / np.asarray(scale) ** 2, rtol=1e-6)
    # log_norm is the density's value at x=0 minus the quadratic/linear terms:
    # log N(0; mu, sigma) == c exactly
    np.testing.assert_allclose(
        np.asarray(log_norm),
        np.asarray(d.log_prob(jnp.zeros(batch))),
        rtol=1e-5,
        atol=1e-6,
    )
    d2 = dist.Normal.from_information_form(prec, info)
    np.testing.assert_allclose(np.asarray(d2.loc), np.asarray(loc), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(d2.scale), np.asarray(scale), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("batch", [(), (4,), (2, 3)])
@pytest.mark.parametrize("d_dim", [1, 3])
def test_mvn_information_form_round_trip(batch, d_dim):
    rng = np.random.default_rng(1)
    loc = jnp.asarray(rng.normal(size=batch + (d_dim,)).astype(np.float32))
    A = rng.normal(size=batch + (d_dim, d_dim))
    cov = A @ np.swapaxes(A, -1, -2) + 0.5 * np.eye(d_dim)
    d = dist.MultivariateNormal(loc, covariance_matrix=jnp.asarray(cov, jnp.float32))
    prec, info, log_norm = d.to_information_form()
    assert prec.shape == batch + (d_dim, d_dim)
    assert info.shape == batch + (d_dim,)
    assert log_norm.shape == batch
    np.testing.assert_allclose(
        np.asarray(prec), np.linalg.inv(cov), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(log_norm),
        np.asarray(d.log_prob(jnp.zeros(batch + (d_dim,)))),
        rtol=1e-4,
        atol=1e-5,
    )
    d2 = dist.MultivariateNormal.from_information_form(prec, info)
    np.testing.assert_allclose(np.asarray(d2.loc), np.asarray(loc), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(d2.covariance_matrix), cov, rtol=1e-3, atol=1e-4
    )


def test_mvn_batched_views_broadcast():
    """Regression (PR 3 follow-up): loc-driven batch dims must surface in
    covariance_matrix / precision_matrix even when scale_tril is unbatched,
    and scale_tril must be an array (not the raw argument) after __init__."""
    loc = jnp.zeros((5, 3))
    L = np.tril(np.random.default_rng(2).uniform(0.5, 1.5, (3, 3)))
    d = dist.MultivariateNormal(loc, scale_tril=jnp.asarray(L, jnp.float32))
    assert d.batch_shape == (5,)
    assert isinstance(d.scale_tril, jnp.ndarray)
    assert d.covariance_matrix.shape == (5, 3, 3)
    assert d.precision_matrix.shape == (5, 3, 3)
    np.testing.assert_allclose(
        np.asarray(d.precision_matrix[0] @ d.covariance_matrix[0]),
        np.eye(3),
        atol=1e-5,
    )
    # covariance built from a python-list covariance_matrix also coerces
    d3 = dist.MultivariateNormal(jnp.zeros(2), covariance_matrix=[[2.0, 0.0], [0.0, 3.0]])
    assert isinstance(d3.scale_tril, jnp.ndarray)
