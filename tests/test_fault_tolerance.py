"""distributed/fault_tolerance.py: watchdog thresholding, heartbeat
dead-host detection, and remesh planning — the serve/train restart seams."""
import pytest

from repro.distributed.fault_tolerance import (
    HeartbeatRegistry,
    StepWatchdog,
    plan_remesh,
)

# ---------------------------------------------------------------------------
# StepWatchdog
# ---------------------------------------------------------------------------


def test_watchdog_quiet_during_warmup():
    wd = StepWatchdog(threshold=2.0, warmup=5)
    # a huge spike inside the warmup window is not flagged
    flags = [wd.observe(dt) for dt in (1.0, 1.0, 50.0, 1.0, 1.0)]
    assert flags == [False] * 5
    assert wd.stragglers == []


def test_watchdog_flags_threshold_crossing():
    wd = StepWatchdog(threshold=2.0, alpha=0.5, warmup=2)
    for _ in range(4):
        wd.observe(1.0)  # ewma settles at 1.0
    assert wd.observe(1.9) is False  # below 2x
    assert wd.observe(10.0) is True  # way above 2x
    assert len(wd.stragglers) == 1
    assert wd.stragglers[0][1] == 10.0


def test_watchdog_straggler_not_folded_into_baseline():
    wd = StepWatchdog(threshold=2.0, alpha=0.5, warmup=1)
    wd.observe(1.0)
    wd.observe(1.0)
    ewma_before = wd.ewma
    assert wd.observe(100.0) is True
    assert wd.ewma == ewma_before  # spike excluded from the EWMA
    # normal steps keep adapting
    wd.observe(1.2)
    assert wd.ewma != ewma_before


def test_watchdog_callback_invoked_with_context():
    calls = []
    wd = StepWatchdog(threshold=2.0, alpha=0.5, warmup=1,
                      on_straggler=lambda i, dt, ewma: calls.append((i, dt, ewma)))
    wd.observe(1.0)
    wd.observe(1.0)
    wd.observe(9.0)
    assert len(calls) == 1
    step, dt, ewma = calls[0]
    assert step == 3 and dt == 9.0 and ewma == pytest.approx(1.0)


def test_watchdog_first_observation_seeds_ewma():
    wd = StepWatchdog(warmup=0)
    assert wd.observe(3.0) is False
    assert wd.ewma == 3.0


# ---------------------------------------------------------------------------
# HeartbeatRegistry
# ---------------------------------------------------------------------------


def test_heartbeat_alive_dead_partition():
    reg = HeartbeatRegistry(timeout=60.0)
    reg.beat(0, now=100.0)
    reg.beat(1, now=130.0)
    reg.beat(2, now=159.9)
    assert reg.alive(now=160.0) == [1, 2]
    assert reg.dead(now=160.0) == [0]
    # a fresh beat resurrects the host
    reg.beat(0, now=161.0)
    assert reg.alive(now=165.0) == [0, 1, 2]
    assert reg.dead(now=165.0) == []


def test_heartbeat_boundary_is_dead():
    reg = HeartbeatRegistry(timeout=10.0)
    reg.beat(7, now=0.0)
    assert reg.alive(now=9.999) == [7]
    assert reg.dead(now=10.0) == [7]  # exactly timeout: dead


def test_heartbeat_wall_clock_default():
    reg = HeartbeatRegistry(timeout=60.0)
    reg.beat(3)
    assert reg.alive() == [3]


# ---------------------------------------------------------------------------
# plan_remesh
# ---------------------------------------------------------------------------


def test_plan_remesh_shrinks_data_axis():
    plan = plan_remesh(n_hosts_alive=6, chips_per_host=4, model_parallelism=16)
    # 24 chips, model=16 -> data=1 (data=2 would need 32)
    assert plan["mesh_shape"] == (1, 16)
    assert plan["chips_used"] == 16
    assert plan["chips_idle"] == 8
    assert plan["axes"] == ("data", "model")


def test_plan_remesh_power_of_two_data():
    plan = plan_remesh(n_hosts_alive=40, chips_per_host=4, model_parallelism=16)
    # 160 chips / 16 = 10 replicas -> largest pow2 is 8
    assert plan["mesh_shape"] == (8, 16)
    assert plan["chips_idle"] == 160 - 8 * 16


def test_plan_remesh_infeasible_returns_none():
    assert plan_remesh(n_hosts_alive=3, chips_per_host=4, model_parallelism=16) is None
    assert plan_remesh(n_hosts_alive=0) is None


def test_plan_remesh_mentions_checkpoint_restore_path():
    plan = plan_remesh(n_hosts_alive=8)
    assert "restore" in plan["action"]
