"""SMC engine conformance: exact Kalman gate (ISSUE 10).

The acceptance anchor: filtering means and the log-marginal-likelihood
estimate of the SMC engine on a linear-Gaussian SSM must converge, at
N = 65536 particles within ~3 sigma of their Monte-Carlo error, to the
exact answers — the float64 sequential Kalman filter here, cross-checked
against `gaussian_marginals` (the PR-8 Gaussian semiring) on the same
model. The reference kernel backend carries the 64k row; the interpret
backend (Pallas resampling body, O(N^2) on CPU) runs the same gate at
N = 4096 with proportionally wider tolerance.

Also pinned: sharded == vectorized bit-identity on a 1-device mesh, the
compile-once contract (`num_traces == 1` across re-runs), the multinomial
resampling alternative, the streaming `SMCFilter` against the offline
sweep, `NestedVariational` training, and SMC^2 as a pure composition
(an inner marginal-likelihood population inside the outer carry).
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import (
    SMC,
    SMCFilter,
    SVI,
    NestedVariational,
    gaussian_marginals,
    sequential_pair,
)
from repro.optim import Adam

A, S_TRANS, S_OBS, P0 = 0.9, 0.3, 0.5, 1.0
GM = {"marginalize": "gaussian"}


def kalman_filter_reference(ys):
    """Float64 sequential Kalman filter: per-step filtering means/variances
    and the exact log marginal likelihood (independent of everything under
    test)."""
    T = len(ys)
    fm, fp = np.zeros(T), np.zeros(T)
    pm, pp = 0.0, P0 * P0
    logz = 0.0
    for t in range(T):
        if t > 0:
            pm, pp = A * fm[t - 1], A * A * fp[t - 1] + S_TRANS**2
        s = pp + S_OBS**2
        logz += -0.5 * ((ys[t] - pm) ** 2 / s + np.log(2 * np.pi * s))
        k = pp / s
        fm[t] = pm + k * (ys[t] - pm)
        fp[t] = (1 - k) * pp
    return fm, fp, logz


def model_init(y):
    x = P.sample("x", dist.Normal(0.0, P0))
    P.sample("y", dist.Normal(x, S_OBS), obs=y)
    return {"x": x}


def model_step(carry, y):
    x = P.sample("x", dist.Normal(A * carry["x"], S_TRANS))
    P.sample("y", dist.Normal(x, S_OBS), obs=y)
    return {"x": x}


def observations(T=12, seed=0):
    gen = np.random.default_rng(seed)
    xs = [gen.normal(0.0, P0)]
    for _ in range(T - 1):
        xs.append(A * xs[-1] + gen.normal(0.0, S_TRANS))
    return jnp.asarray([x + gen.normal(0.0, S_OBS) for x in xs], dtype=jnp.float32)


YS = observations()
FM, FP, LOG_Z = kalman_filter_reference(np.asarray(YS, np.float64))


# ---------------------------------------------------------------------------
# tentpole gate: Kalman conformance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "backend,n",
    [("reference", 65536), ("interpret", 4096)],
    ids=["reference-64k", "interpret-4k"],
)
def test_smc_matches_kalman(backend, n, monkeypatch):
    """Filtering means within ~3 sigma of their Monte-Carlo standard error
    at every step, and log Z within a few sigma of the resampling noise.
    The MC error of a weighted mean is ~sqrt(Var/ESS); resampling couples
    particles over time, so the gate uses a conservative 5x floor on the
    iid estimate rather than pretending independence."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
    smc = SMC(model_init, model_step, num_particles=n)
    smc.run(jax.random.PRNGKey(0), YS)

    means = np.asarray(smc.filtering_means()["x"])
    assert smc.result.includes_init and means.shape == (len(YS),)
    for t in range(len(YS)):
        se = 5.0 * math.sqrt(FP[t] / n)
        assert abs(means[t] - FM[t]) < max(3.0 * se, 0.02), (
            t, means[t], FM[t], se
        )
    # logZ: T resampling stages each contribute O(1/sqrt(N)) noise
    tol = max(10.0 * len(YS) / math.sqrt(n), 0.05)
    assert abs(float(smc.log_evidence()) - LOG_Z) < tol, (
        float(smc.log_evidence()), LOG_Z, tol
    )


def test_kalman_reference_agrees_with_gaussian_semiring():
    """The float64 filter above and PR-8's Gaussian semiring compute the
    same posterior: smoother mean == filtering mean at the final step."""

    def marginalized():
        x = P.sample("x0", dist.Normal(0.0, P0), infer=GM)
        P.sample("y0", dist.Normal(x, S_OBS), obs=YS[0])
        for t in range(1, len(YS)):
            x = P.sample(f"x{t}", dist.Normal(A * x, S_TRANS), infer=GM)
            P.sample(f"y{t}", dist.Normal(x, S_OBS), obs=YS[t])

    last = f"x{len(YS) - 1}"
    out = gaussian_marginals(marginalized, jax.random.PRNGKey(0), sites=[last])
    m, v = out[last]
    assert np.isclose(float(m), FM[-1], rtol=1e-4, atol=1e-5)
    assert np.isclose(float(v), FP[-1], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# engine contracts
# ---------------------------------------------------------------------------


def test_num_traces_stays_one_across_reruns():
    smc = SMC(model_init, model_step, num_particles=512)
    for rep in range(3):
        smc.run(jax.random.PRNGKey(rep), YS + 1e-4 * rep)
    assert smc.num_traces == 1


def test_sharded_matches_vectorized_bit_identical():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    vec = SMC(model_init, model_step, num_particles=1024)
    sh = SMC(model_init, model_step, num_particles=1024, mesh=mesh)
    vec.run(jax.random.PRNGKey(1), YS)
    sh.run(jax.random.PRNGKey(1), YS)
    if jax.device_count() == 1:
        assert jnp.array_equal(vec.log_weights, sh.log_weights)
        assert jnp.array_equal(vec.get_samples()["x"], sh.get_samples()["x"])
        assert float(vec.log_evidence()) == float(sh.log_evidence())


def test_get_samples_shapes_and_chain_convention():
    smc = SMC(model_init, model_step, num_particles=256)
    out = smc.run(jax.random.PRNGKey(2), YS)
    assert out["x"].shape == (256,)
    assert smc.get_samples(group_by_chain=True)["x"].shape == (1, 256)
    assert smc.ess_history().shape == (len(YS),)


def test_multinomial_resampling_also_converges():
    smc = SMC(
        model_init, model_step, num_particles=8192, resample_method="multinomial"
    )
    smc.run(jax.random.PRNGKey(3), YS)
    assert abs(float(smc.log_evidence()) - LOG_Z) < 0.3
    assert abs(float(smc.filtering_means()["x"][-1]) - FM[-1]) < 0.05


def test_adaptive_resampling_actually_fires():
    smc = SMC(model_init, model_step, num_particles=1024, ess_threshold=0.5)
    smc.run(jax.random.PRNGKey(4), YS)
    resampled = np.asarray(smc.result.history.resampled)
    assert resampled.any(), "no resample event in a 12-step bootstrap sweep"
    assert not resampled.all(), "resampling every step at threshold 0.5"


def test_never_resample_matches_plain_importance_weights():
    """ess_threshold=0 degenerates SMC to sequential importance sampling:
    log Z must equal the one flush of the final weights."""
    smc = SMC(model_init, model_step, num_particles=512, ess_threshold=0.0)
    smc.run(jax.random.PRNGKey(5), YS)
    lw = smc.log_weights
    flush = float(jax.scipy.special.logsumexp(lw) - jnp.log(512.0))
    assert np.isclose(float(smc.log_evidence()), flush, rtol=1e-6)
    assert not np.asarray(smc.result.history.resampled).any()


# ---------------------------------------------------------------------------
# streaming filter
# ---------------------------------------------------------------------------


def test_smc_filter_streams_with_one_compile():
    f = SMCFilter(model_init, model_step, num_particles=2048)
    state, info = f.init_state(jax.random.PRNGKey(6), YS[0])
    for y in YS[1:]:
        state, info = f.update(state, y)
    assert int(state.t) == len(YS)
    assert f.num_traces == 1 and f.num_init_traces == 1
    # the streamed estimate converges on the same exact targets
    assert abs(float(info["log_evidence"]) - LOG_Z) < 0.5
    assert abs(float(info["means"]["x"]) - FM[-1]) < 0.1


def test_smc_filter_params_hot_swap_no_recompile():
    """`params` rides the traced signature: streaming with swapped param
    values must not retrace (the serve-layer refresh contract)."""

    def q_init(y):
        loc = P.param("q_loc", jnp.float32(0.0))
        return P.sample("x", dist.Normal(loc, P0))

    def q_step(carry, y):
        g = P.param("q_gain", jnp.float32(A))
        return P.sample("x", dist.Normal(g * carry["x"], S_TRANS))

    f = SMCFilter(
        model_init, model_step,
        proposal_init=q_init, proposal_step=q_step, num_particles=256,
    )
    state, _ = f.init_state(
        jax.random.PRNGKey(7), YS[0], params={"q_loc": jnp.float32(0.0),
                                              "q_gain": jnp.float32(A)}
    )
    for i, y in enumerate(YS[1:]):
        state, _ = f.update(
            state, y, params={"q_loc": jnp.float32(0.01 * i),
                              "q_gain": jnp.float32(A + 0.001 * i)}
        )
    assert f.num_traces == 1, f.num_traces


# ---------------------------------------------------------------------------
# nested compositions: variational SMC and SMC^2
# ---------------------------------------------------------------------------


Y1 = jnp.asarray([0.7], dtype=jnp.float32)  # T=1: the sweep degenerates to
# the IWAE bound (no resampling); fixed so the misspecified starting
# proposal below is unambiguously far from the posterior
# posterior for x0 | y0: precision-weighted combination of N(0, P0) and the
# observation; evidence N(y0; 0, sqrt(P0^2 + S_OBS^2))
_POST_VAR = 1.0 / (1.0 / P0**2 + 1.0 / S_OBS**2)
_POST_MEAN = float(_POST_VAR * float(Y1[0]) / S_OBS**2)
_LOG_Z1 = float(
    dist.Normal(0.0, math.sqrt(P0**2 + S_OBS**2)).log_prob(Y1[0])
)


def _q_step_prior(carry, y):
    return {"x": P.sample("x", dist.Normal(A * carry["x"], S_TRANS))}


def test_nested_variational_exact_proposal_is_tight():
    """With the exact posterior as the proposal, every inner particle's
    weight equals log Z exactly — the bound is tight with zero variance,
    for any key. This pins the propose-weight arithmetic end to end."""

    def q_exact(y):
        return {"x": P.sample("x", dist.Normal(_POST_MEAN, math.sqrt(_POST_VAR)))}

    loss = NestedVariational(
        model_init, model_step,
        proposal_init=q_exact, proposal_step=_q_step_prior, num_inner=4,
    )
    vals = [
        float(loss.loss(jax.random.PRNGKey(i), {}, None, None, Y1))
        for i in range(5)
    ]
    assert np.allclose(vals, -_LOG_Z1, atol=1e-5), (vals, -_LOG_Z1)


def test_nested_variational_trains_toward_tight_bound():
    """T=1 keeps the gradient unbiased (no ancestry to stop-gradient
    through): SVI must drive a misspecified proposal location toward the
    posterior mean and the averaged loss down toward -log Z."""

    def q_learn(y):
        loc = P.param("q_loc", jnp.float32(-1.0))
        return {"x": P.sample("x", dist.Normal(loc, math.sqrt(_POST_VAR)))}

    loss = NestedVariational(
        model_init, model_step,
        proposal_init=q_learn, proposal_step=_q_step_prior, num_inner=8,
    )
    svi = SVI(
        sequential_pair(model_init, model_step),
        sequential_pair(q_learn, _q_step_prior),
        Adam(5e-2),
        loss,
    )
    state = svi.init(jax.random.PRNGKey(8), Y1)
    p0 = svi.optim.get_params(state.optim_state)
    for _ in range(300):
        state, val = svi.update_jit(state, Y1)
        assert np.isfinite(float(val))
    pT = svi.optim.get_params(state.optim_state)
    assert svi.num_traces == 1

    def avg_loss(p):
        return float(np.mean([
            float(loss.loss(jax.random.PRNGKey(500 + i), p, None, None, Y1))
            for i in range(16)
        ]))

    assert abs(float(pT["q_loc"]) - _POST_MEAN) < 0.3, float(pT["q_loc"])
    l0, lT = avg_loss(p0), avg_loss(pT)
    assert lT < l0 - 1.0, (l0, lT)  # large, unambiguous improvement
    assert lT < -_LOG_Z1 + 0.2  # near the tight floor
    assert lT > -_LOG_Z1 - 0.2  # and never below it (it IS a bound)


def test_nested_variational_multistep_smoke():
    """The full multi-step sweep (resampling active, biased VSMC gradient)
    must train stably: finite losses, one compile, moving params."""

    def q_init_d(y):
        loc = P.param("q_loc0", jnp.float32(0.0))
        return {"x": P.sample("x", dist.Normal(loc, P0))}

    def q_step_d(carry, y):
        g = P.param("q_gain", jnp.float32(0.5))
        return {"x": P.sample("x", dist.Normal(g * carry["x"], S_TRANS))}

    loss = NestedVariational(
        model_init, model_step,
        proposal_init=q_init_d, proposal_step=q_step_d, num_inner=8,
    )
    svi = SVI(
        sequential_pair(model_init, model_step),
        sequential_pair(q_init_d, q_step_d),
        Adam(5e-3),
        loss,
    )
    state = svi.init(jax.random.PRNGKey(9), YS)
    losses = []
    for _ in range(60):
        state, val = svi.update_jit(state, YS)
        losses.append(float(val))
    assert all(np.isfinite(losses))
    assert svi.num_traces == 1
    pT = svi.optim.get_params(state.optim_state)
    assert float(pT["q_loc0"]) != 0.0 or float(pT["q_gain"]) != 0.5
    # -E[log Zhat] is bounded below by -log Z
    assert np.mean(losses[-10:]) > -LOG_Z - 1.0


def test_smc_squared_as_composition():
    """SMC^2 needs no new machinery: the outer particle's carry holds an
    inner population whose per-step evidence increment enters the outer
    weight through `P.factor` — everything rides the same sweep."""
    from repro.infer import smc_sweep
    from repro.infer.combinators import primitive, resample

    N_INNER = 64

    def outer_init(y):
        # static latent for the outer level: the transition gain
        a = P.sample("a", dist.Uniform(0.5, 1.0))
        # inner population: iid prior x-particles, reweighted by y_0
        with P.plate("inner", N_INNER):
            x = P.sample("x", dist.Normal(0.0, P0))
        lw = dist.Normal(x, S_OBS).log_prob(y)
        incr = jax.scipy.special.logsumexp(lw) - jnp.log(float(N_INNER))
        P.factor("evidence", incr)
        return {"a": a, "x": x, "lw": lw - jax.scipy.special.logsumexp(lw)}

    def outer_step(carry, y):
        a, x, lw = carry["a"], carry["x"], carry["lw"]
        # Rao-Blackwellized inner propagation under gain `a`: transition
        # noise folds into the predictive variance, the inner evidence
        # increment enters the outer weight through the factor site
        x = a * x
        pred_lw = dist.Normal(x, jnp.sqrt(S_TRANS**2 + S_OBS**2)).log_prob(y)
        incr = (
            jax.scipy.special.logsumexp(lw + pred_lw)
            - jax.scipy.special.logsumexp(lw)
        )
        P.factor("evidence", incr)
        lw = lw + pred_lw
        lw = lw - jax.scipy.special.logsumexp(lw)
        return {"a": a, "x": x, "lw": lw}

    step_prog = resample(primitive(outer_step), ess_threshold=0.5)
    result = smc_sweep(
        primitive(outer_init), step_prog,
        jax.random.PRNGKey(9), YS, num_particles=128,
    )
    assert np.isfinite(float(result.log_evidence))
    # the outer weighted posterior over `a` (held in the carry — `a` is
    # sampled once at init, so it is not in the per-step latent history)
    # stays a proper distribution on its prior support
    w = jax.nn.softmax(result.population.log_weights)
    a_mean = float(jnp.sum(w * result.population.carry["a"]))
    assert 0.5 < a_mean < 1.0
    assert float(jnp.sum(w)) == pytest.approx(1.0, rel=1e-5)
