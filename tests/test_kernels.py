"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps per the assignment.

The dispatch layer in kernels/ops.py resolves to the `reference` backend on
CPU, so these tests pin backend="interpret" to keep exercising the actual
Pallas kernel bodies (interpret mode) against the oracles.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (
    categorical_logprob,
    flash_attention,
    hmm_scan,
    semiring_matmul,
    ssd_scan,
)
from repro.kernels.ref import (
    categorical_logprob_ref,
    flash_attention_ref,
    hmm_scan_ref,
    semiring_matmul_ref,
    ssd_scan_ref,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,K,S,d", [
    (1, 4, 4, 128, 32),   # MHA
    (2, 8, 2, 256, 64),   # GQA 4:1
    (1, 8, 1, 128, 64),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, K, S, d, dtype):
    q = jax.random.normal(KEY, (B, H, S, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, K, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, K, S, d), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, backend="interpret")
    ref = flash_attention_ref(q, k, v)
    atol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(out.astype(jnp.float32), ref.astype(jnp.float32), atol=atol)


def test_flash_attention_noncausal():
    q = jax.random.normal(KEY, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 2, 128, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64, backend="interpret")
    ref = flash_attention_ref(q, k, v, causal=False)
    assert jnp.allclose(out, ref, atol=1e-4)


@pytest.mark.parametrize("T,V", [(64, 1000), (100, 5000), (256, 2048), (7, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_categorical_logprob_sweep(T, V, dtype):
    logits = (jax.random.normal(KEY, (T, V)) * 3).astype(dtype)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (T,), 0, V)
    lp = categorical_logprob(logits, toks, block_t=32, block_v=512, backend="interpret")
    ref = categorical_logprob_ref(logits, toks)
    assert jnp.allclose(lp, ref, atol=1e-3)


def test_categorical_logprob_batched_shape():
    logits = jax.random.normal(KEY, (2, 8, 100))
    toks = jax.random.randint(KEY, (2, 8), 0, 100)
    lp = categorical_logprob(logits, toks, backend="interpret")
    assert lp.shape == (2, 8)
    assert jnp.allclose(lp, categorical_logprob_ref(logits, toks), atol=1e-4)


def test_categorical_logprob_extreme_logits():
    """Online LSE must survive large-magnitude logits."""
    logits = jnp.asarray([[1e4, -1e4, 0.0, 500.0]] * 8)
    toks = jnp.asarray([0, 1, 2, 3, 0, 1, 2, 3])
    lp = categorical_logprob(logits, toks, block_t=8, block_v=2, backend="interpret")
    ref = categorical_logprob_ref(logits, toks)
    assert jnp.allclose(lp, ref, atol=1e-3)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 4, 16, 8, 16),
    (1, 128, 3, 32, 16, 32),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 5), (h,)))
    B = jax.random.normal(jax.random.fold_in(KEY, 6), (b, s, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 7), (b, s, n))
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, backend="interpret")
    ref = ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    assert jnp.allclose(y, ref, atol=1e-3)


def test_ssd_scan_matches_naive_recurrence():
    b, s, h, p, n = 1, 24, 2, 4, 4
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 8), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 9), (h,)))
    B = jax.random.normal(jax.random.fold_in(KEY, 10), (b, s, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 11), (b, s, n))
    st = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        st = st * jnp.exp(dt[:, t] * A)[..., None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", B[:, t], dt[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhnp->bhp", C[:, t], st))
    naive = jnp.stack(ys, 1)
    y = ssd_scan(x, dt, A, B, C, chunk=8, backend="interpret")
    assert jnp.allclose(y, naive, atol=1e-3)


# ---------------------------------------------------------------------------
# log-space semiring matmul + hmm_scan (enumeration hot path)
# ---------------------------------------------------------------------------


def _naive_semiring_matmul(a, b, semiring):
    """Brute-force materialized oracle (independent of the shifted-exponential
    rewrite both the kernel and kernels/ref.py use for sum-product)."""
    x = a[..., :, :, None] + b[..., None, :, :]
    if semiring == "max":
        return jnp.max(x, axis=-2)
    return jax.scipy.special.logsumexp(x, axis=-2)


@pytest.mark.parametrize("M,K,N", [
    (4, 4, 4),        # square, sub-block
    (5, 7, 3),        # non-square, odd
    (64, 64, 64),     # exact block multiple
    (33, 100, 17),    # ragged across several K blocks
])
@pytest.mark.parametrize("semiring", ["logsumexp", "max"])
def test_semiring_matmul_interpret_vs_reference(M, K, N, semiring):
    a = jax.random.normal(KEY, (M, K)) * 3
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (K, N)) * 3
    naive = _naive_semiring_matmul(a, b, semiring)
    got_i = semiring_matmul(a, b, semiring=semiring, backend="interpret", block=32)
    got_r = semiring_matmul(a, b, semiring=semiring, backend="reference")
    assert jnp.allclose(got_i, naive, atol=1e-4)
    assert jnp.allclose(got_r, naive, atol=1e-4)
    assert jnp.allclose(got_i, got_r, atol=1e-4)


@pytest.mark.parametrize("semiring", ["logsumexp", "max"])
def test_semiring_matmul_batched_broadcast(semiring):
    """Batch dims broadcast: (2,3,8,6) x (3,6,5) -> (2,3,8,5)."""
    a = jax.random.normal(KEY, (2, 3, 8, 6)) * 2
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 6, 5)) * 2
    naive = _naive_semiring_matmul(a, b, semiring)
    for backend in ("interpret", "reference"):
        got = semiring_matmul(a, b, semiring=semiring, backend=backend, block=16)
        assert got.shape == (2, 3, 8, 5)
        assert jnp.allclose(got, naive, atol=1e-4), backend


def test_semiring_matmul_extreme_magnitudes():
    """The shifted-exponential rewrite must survive large-magnitude logits
    (no exp overflow: the unshifted exp(100+100) would be inf in f32) and
    fully -inf (masked-out) rows without producing nan. Spreads stay inside
    the documented ~88-nat f32 window below the row+col shift bound —
    contributions further down flush to exactly 0, which is the standard
    log-matmul-exp truncation (see semiring.py docstring)."""
    a = jnp.asarray([[100.0, -100.0], [0.0, 50.0], [-jnp.inf, -jnp.inf]])
    b = jnp.asarray([[100.0, 0.0, -50.0], [-100.0, 1.0, 2.0]])
    naive = _naive_semiring_matmul(a[:2], b, "logsumexp")
    for backend in ("interpret", "reference"):
        got = semiring_matmul(a, b, backend=backend, block=8)
        assert bool(jnp.all(jnp.isfinite(got[:2]))), backend
        assert jnp.allclose(got[:2], naive, atol=1e-3), backend
        assert bool(jnp.all(got[2] < -1e20)), backend  # -inf row stays -inf-like


@pytest.mark.parametrize("T", [1, 2, 5, 8, 9])  # odd lengths pad with the identity
@pytest.mark.parametrize("semiring", ["logsumexp", "max"])
def test_hmm_scan_interpret_vs_reference(T, semiring):
    F = jax.random.normal(jax.random.fold_in(KEY, T), (T, 4, 4)) * 2
    want = hmm_scan_ref(F, semiring=semiring)  # sequential O(T) oracle
    for backend in ("interpret", "reference"):
        got = hmm_scan(F, semiring=semiring, backend=backend, block=16)
        assert jnp.allclose(got, want, atol=1e-4), (T, backend)


@pytest.mark.parametrize("semiring", ["logsumexp", "max"])
def test_hmm_scan_batched_and_cumulative(semiring):
    F = jax.random.normal(KEY, (2, 7, 3, 3)) * 2
    want = hmm_scan_ref(F, semiring=semiring)
    for backend in ("interpret", "reference"):
        got = hmm_scan(F, semiring=semiring, backend=backend, block=8)
        assert got.shape == (2, 3, 3)
        assert jnp.allclose(got, want, atol=1e-4), backend
        cum = hmm_scan(F, semiring=semiring, backend=backend, block=8, cumulative=True)
        assert cum.shape == (2, 7, 3, 3)
        # every prefix of the associative scan matches the sequential fold
        for t in range(7):
            assert jnp.allclose(
                cum[:, t], hmm_scan_ref(F[:, : t + 1], semiring=semiring), atol=1e-4
            ), (t, backend)


def test_hmm_scan_chain_marginal_matches_brute_force():
    """End-to-end semantics: the semiring product over a 3-step chain equals
    explicit enumeration of all K^4 paths."""
    K, T = 3, 3
    F = jax.random.normal(KEY, (T, K, K))
    total = semiring_matmul_ref(
        jnp.zeros((1, K)), hmm_scan(F, backend="interpret", block=8)
    )
    brute = -jnp.inf
    import itertools

    for path in itertools.product(range(K), repeat=T + 1):
        lp = sum(F[t, path[t], path[t + 1]] for t in range(T))
        brute = jnp.logaddexp(brute, lp)
    got = jax.scipy.special.logsumexp(total)
    assert jnp.allclose(got, brute, atol=1e-4)


def test_semiring_validation():
    a = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="semiring"):
        semiring_matmul(a, a, semiring="min")
    with pytest.raises(ValueError, match="square"):
        hmm_scan(jnp.zeros((3, 2, 4)))


def test_max_semiring_keeps_true_neg_inf():
    """Structurally impossible transitions (log_prob == -inf) must stay -inf
    through the max-product kernel — a finite floor like NEG_INF would make
    'is this path impossible' checks diverge between backends. Exercises the
    accumulator init, K-padding, and (via odd-length hmm_scan) the semiring
    identity padding."""
    ninf = -jnp.inf
    a = jnp.asarray([[ninf, ninf], [0.0, 1.0]])
    b = jnp.zeros((2, 3))
    want = _naive_semiring_matmul(a, b, "max")  # row 0 all -inf
    for backend in ("interpret", "reference"):
        got = semiring_matmul(a, b, semiring="max", backend=backend, block=8)
        assert jnp.array_equal(jnp.isinf(got), jnp.isinf(want)), backend
        assert jnp.allclose(got[1], want[1]), backend
    # odd-length chain -> identity padding in the tree reduction
    blockedF = jnp.stack([jnp.where(jnp.eye(3, dtype=bool), 0.0, ninf)] * 5)
    for backend in ("interpret", "reference"):
        out = hmm_scan(blockedF, semiring="max", backend=backend, block=8)
        assert bool(jnp.all(jnp.isinf(out) == ~jnp.eye(3, dtype=bool))), backend
        assert bool(jnp.all(out[jnp.eye(3, dtype=bool)] == 0.0)), backend


@pytest.mark.parametrize("semiring", ["logsumexp", "max"])
def test_semiring_matmul_grad_interpret_vs_reference(semiring):
    """The Pallas op carries a custom VJP (reference backward), so gradients
    flow through the kernel backend and match the pure-jnp path — the
    enumeration engine differentiates straight through these contractions."""
    a = jax.random.normal(KEY, (5, 4)) * 2
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 3)) * 2

    def loss(backend):
        return lambda a, b: jnp.sum(
            semiring_matmul(a, b, semiring=semiring, backend=backend, block=8) ** 2
        )

    ga_r, gb_r = jax.grad(loss("reference"), argnums=(0, 1))(a, b)
    ga_i, gb_i = jax.grad(loss("interpret"), argnums=(0, 1))(a, b)
    assert jnp.allclose(ga_r, ga_i, atol=1e-4)
    assert jnp.allclose(gb_r, gb_i, atol=1e-4)


def test_hmm_scan_grad_interpret_vs_reference():
    F = jax.random.normal(KEY, (5, 3, 3))

    def loss(backend):
        return lambda F: jnp.sum(hmm_scan(F, backend=backend, block=8))

    g_r = jax.grad(loss("reference"))(F)
    g_i = jax.grad(loss("interpret"))(F)
    assert bool(jnp.all(jnp.isfinite(g_i)))
    assert jnp.allclose(g_r, g_i, atol=1e-4)
