"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps per the assignment.

The dispatch layer in kernels/ops.py resolves to the `reference` backend on
CPU, so these tests pin backend="interpret" to keep exercising the actual
Pallas kernel bodies (interpret mode) against the oracles.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import categorical_logprob, flash_attention, ssd_scan
from repro.kernels.ref import (
    categorical_logprob_ref,
    flash_attention_ref,
    ssd_scan_ref,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,K,S,d", [
    (1, 4, 4, 128, 32),   # MHA
    (2, 8, 2, 256, 64),   # GQA 4:1
    (1, 8, 1, 128, 64),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, K, S, d, dtype):
    q = jax.random.normal(KEY, (B, H, S, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, K, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, K, S, d), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, backend="interpret")
    ref = flash_attention_ref(q, k, v)
    atol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(out.astype(jnp.float32), ref.astype(jnp.float32), atol=atol)


def test_flash_attention_noncausal():
    q = jax.random.normal(KEY, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 2, 128, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64, backend="interpret")
    ref = flash_attention_ref(q, k, v, causal=False)
    assert jnp.allclose(out, ref, atol=1e-4)


@pytest.mark.parametrize("T,V", [(64, 1000), (100, 5000), (256, 2048), (7, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_categorical_logprob_sweep(T, V, dtype):
    logits = (jax.random.normal(KEY, (T, V)) * 3).astype(dtype)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (T,), 0, V)
    lp = categorical_logprob(logits, toks, block_t=32, block_v=512, backend="interpret")
    ref = categorical_logprob_ref(logits, toks)
    assert jnp.allclose(lp, ref, atol=1e-3)


def test_categorical_logprob_batched_shape():
    logits = jax.random.normal(KEY, (2, 8, 100))
    toks = jax.random.randint(KEY, (2, 8), 0, 100)
    lp = categorical_logprob(logits, toks, backend="interpret")
    assert lp.shape == (2, 8)
    assert jnp.allclose(lp, categorical_logprob_ref(logits, toks), atol=1e-4)


def test_categorical_logprob_extreme_logits():
    """Online LSE must survive large-magnitude logits."""
    logits = jnp.asarray([[1e4, -1e4, 0.0, 500.0]] * 8)
    toks = jnp.asarray([0, 1, 2, 3, 0, 1, 2, 3])
    lp = categorical_logprob(logits, toks, block_t=8, block_v=2, backend="interpret")
    ref = categorical_logprob_ref(logits, toks)
    assert jnp.allclose(lp, ref, atol=1e-3)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 4, 16, 8, 16),
    (1, 128, 3, 32, 16, 32),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 5), (h,)))
    B = jax.random.normal(jax.random.fold_in(KEY, 6), (b, s, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 7), (b, s, n))
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, backend="interpret")
    ref = ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    assert jnp.allclose(y, ref, atol=1e-3)


def test_ssd_scan_matches_naive_recurrence():
    b, s, h, p, n = 1, 24, 2, 4, 4
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 8), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 9), (h,)))
    B = jax.random.normal(jax.random.fold_in(KEY, 10), (b, s, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 11), (b, s, n))
    st = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        st = st * jnp.exp(dt[:, t] * A)[..., None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", B[:, t], dt[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhnp->bhp", C[:, t], st))
    naive = jnp.stack(ys, 1)
    y = ssd_scan(x, dt, A, B, C, chunk=8, backend="interpret")
    assert jnp.allclose(y, naive, atol=1e-3)
