"""Dynamic micro-batcher: coalescing, scatter correctness, determinism,
error propagation, stats, and shutdown semantics."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import CompiledServable, MicroBatcher, ServeStats


def identity_engine(**kwargs):
    """Rows tagged by content so scatter bugs are visible."""

    def fn(key, batch):
        return {"y": batch["x"] * 2.0, "global": jnp.zeros(3)}

    return CompiledServable(fn, **kwargs)


def test_requests_coalesce_into_one_forward():
    eng = identity_engine(max_batch=16)
    with MicroBatcher(eng, max_wait_ms=200.0) as mb:
        futs = [mb.submit({"x": jnp.full((n,), float(n))}) for n in (2, 3, 4)]
        results = [f.result(timeout=30) for f in futs]
    for n, r in zip((2, 3, 4), results):
        np.testing.assert_array_equal(np.asarray(r["y"]), np.full(n, 2.0 * n))
        assert r["global"].shape == (3,)
    # all three coalesced within the wait window: one batch, one compile
    assert mb.stats.batches == 1
    assert mb.stats.requests == 3
    assert eng.num_traces == 1


def test_scatter_matches_direct_engine_call():
    """Batcher output == engine output on the hand-coalesced batch with the
    batcher's own key (fold_in(base, 0) for the first batch)."""
    eng = identity_engine(max_batch=16)
    base = jax.random.PRNGKey(42)
    xs = [jnp.arange(float(n)) + 10.0 * n for n in (2, 3)]
    with MicroBatcher(eng, max_wait_ms=500.0, rng_key=base) as mb:
        futs = [mb.submit({"x": x}) for x in xs]
        results = [f.result(timeout=30) for f in futs]
    direct = eng(jax.random.fold_in(base, 0), {"x": jnp.concatenate(xs)})
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([r["y"] for r in results])),
        np.asarray(direct["y"]),
    )


def test_oversized_request_rejected_and_batch_split():
    eng = identity_engine(max_batch=4)
    with MicroBatcher(eng, max_wait_ms=100.0) as mb:
        with pytest.raises(ValueError, match="exceeds max_batch"):
            mb.submit({"x": jnp.zeros(5)})
        # 3 + 3 rows > max_batch 4: must split into two forwards
        futs = [mb.submit({"x": jnp.full(3, 1.0)}), mb.submit({"x": jnp.full(3, 2.0)})]
        r1, r2 = [f.result(timeout=30) for f in futs]
    np.testing.assert_array_equal(np.asarray(r1["y"]), np.full(3, 2.0))
    np.testing.assert_array_equal(np.asarray(r2["y"]), np.full(3, 4.0))
    assert mb.stats.batches == 2


def test_exception_propagates_to_all_futures():
    def bad_fn(key, batch):
        raise RuntimeError("kaboom")

    eng = CompiledServable(bad_fn, max_batch=8)
    with MicroBatcher(eng, max_wait_ms=100.0) as mb:
        futs = [mb.submit({"x": jnp.zeros(2)}) for _ in range(2)]
        for f in futs:
            with pytest.raises(RuntimeError, match="kaboom"):
                f.result(timeout=30)


def test_concurrent_clients_all_complete():
    eng = identity_engine(max_batch=8)
    results = {}

    with MicroBatcher(eng, max_wait_ms=2.0) as mb:

        def client(cid):
            out = mb.predict({"x": jnp.full(2, float(cid))}, timeout=60)
            results[cid] = np.asarray(out["y"])

        threads = [threading.Thread(target=client, args=(c,)) for c in range(12)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(results) == 12
    for cid, y in results.items():
        np.testing.assert_array_equal(y, np.full(2, 2.0 * cid))
    assert mb.stats.requests == 12
    # compile contract survives concurrency: compiles bounded by buckets
    assert eng.num_traces == len(eng.buckets_touched)


def test_close_drains_pending_requests():
    eng = identity_engine(max_batch=8)
    mb = MicroBatcher(eng, max_wait_ms=50.0)
    futs = [mb.submit({"x": jnp.full(1, float(i))}) for i in range(5)]
    mb.close()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result(timeout=5)["y"]), [2.0 * i])
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit({"x": jnp.zeros(1)})
    mb.close()  # idempotent


def test_stats_summary_shape():
    eng = identity_engine(max_batch=8)
    with MicroBatcher(eng, max_wait_ms=5.0) as mb:
        for _ in range(4):
            mb.predict({"x": jnp.zeros(2)}, timeout=30)
        s = mb.stats.summary()
    assert s["requests"] == 4
    assert s["batches"] >= 1
    assert s["p50_ms"] > 0 and s["p99_ms"] >= s["p50_ms"]
    assert s["requests_per_sec"] > 0
    assert 0.0 <= s["pad_waste"] <= 1.0
    assert s["max_queue_depth"] >= 0


def test_stats_percentiles_and_window():
    st = ServeStats(window=8)
    st.record_batch(n_requests=3, n_rows=6, bucket=8, queue_depth=2,
                    latencies_ms=[1.0, 2.0, 3.0])
    st.record_batch(n_requests=1, n_rows=2, bucket=2, queue_depth=5,
                    latencies_ms=[10.0])
    s = st.summary()
    assert s["requests"] == 4 and s["batches"] == 2
    assert s["max_queue_depth"] == 5
    assert s["p99_ms"] == 10.0
    assert s["pad_waste"] == pytest.approx(2 / 10)
    # rolling window truncates
    st.record_batch(1, 1, 1, 0, latencies_ms=list(range(20)))
    assert len(st.latencies_ms) <= 8


def test_deadline_fires_without_full_batch():
    """A lone request must not wait forever for co-batchers."""
    eng = identity_engine(max_batch=64)
    with MicroBatcher(eng, max_wait_ms=5.0) as mb:
        t0 = time.perf_counter()
        mb.predict({"x": jnp.zeros(1)}, timeout=30)
        # generous bound: the point is "returns promptly", not exact timing
        assert time.perf_counter() - t0 < 20.0
