"""VE semiring-kernel dispatch: the chain/matmul rewrite must be a pure
lowering change — same semantics as the legacy pairwise greedy path.

`dispatch="pairwise"` (or REPRO_ENUM_DISPATCH=pairwise) forces the pre-rewrite
path, so every test here compares before/after on the same fixtures:

* GMM (no chain structure): the dispatch must leave the contraction entirely
  untouched — results are bit-identical, not merely close.
* HMM (chain structure): the chain is re-associated into an O(log T) semiring
  tree, so float results agree to tight tolerance while *discrete* outputs
  (Viterbi MAP assignments) stay bit-identical.
* Pending-scale and masked-site (-log K) semantics ride through the kernels
  unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributions as dist
from repro.core import handlers
from repro.core import primitives as P
from repro.infer import TraceEnum_ELBO, config_enumerate, discrete_marginals, infer_discrete
from repro.infer.traceenum_elbo import (
    _dispatch_mode,
    _from_matrix,
    _to_matrix,
    contract_log_factors,
)

DATA = jnp.asarray([-1.2, -0.8, 1.9, 2.2, 2.0])
WEIGHTS = jnp.asarray([0.4, 0.6])
LOCS = jnp.asarray([-1.0, 2.0])


def gmm(data):
    with P.plate("N", data.shape[0]):
        z = P.sample("z", dist.Categorical(WEIGHTS), infer={"enumerate": "parallel"})
        P.sample("obs", dist.Normal(LOCS[z], 0.5), obs=data)


def make_hmm(T, K, seed=0):
    rng = np.random.default_rng(seed)
    trans = jnp.asarray(rng.dirichlet(np.ones(K), size=K), jnp.float32)
    init_p = jnp.asarray(rng.dirichlet(np.ones(K)), jnp.float32)
    locs = jnp.linspace(-2.0, 2.0, K)
    obs = jnp.asarray(rng.normal(size=T), jnp.float32)

    @config_enumerate
    def hmm(obs_seq):
        z = P.sample("z_0", dist.Categorical(init_p))
        P.sample("x_0", dist.Normal(locs[z], 1.0), obs=obs_seq[0])
        for t in range(1, T):
            z = P.sample(f"z_{t}", dist.Categorical(trans[z]))
            P.sample(f"x_{t}", dist.Normal(locs[z], 1.0), obs=obs_seq[t])

    return hmm, obs


def loss_with(model, data, mode):
    """Loss under a forced dispatch mode, with the chain-length threshold
    dropped to 2 so the small fixtures here actually exercise the kernels."""
    elbo = TraceEnum_ELBO()
    import os

    old = os.environ.get("REPRO_ENUM_DISPATCH")
    old_min = os.environ.get("REPRO_ENUM_CHAIN_MIN")
    os.environ["REPRO_ENUM_DISPATCH"] = mode
    os.environ["REPRO_ENUM_CHAIN_MIN"] = "2"
    try:
        return float(elbo.loss(jax.random.PRNGKey(0), {}, model, lambda *a: None, data))
    finally:
        for var, val in [("REPRO_ENUM_DISPATCH", old), ("REPRO_ENUM_CHAIN_MIN", old_min)]:
            if val is None:
                os.environ.pop(var, None)
            else:
                os.environ[var] = val


# ---------------------------------------------------------------------------
# before/after equivalence on the existing fixtures
# ---------------------------------------------------------------------------


def test_gmm_loss_bit_identical_across_dispatch():
    """No chain structure -> the dispatch must not rewrite anything: the two
    paths execute the same ops and the losses are bit-identical."""
    assert loss_with(gmm, DATA, "pairwise") == loss_with(gmm, DATA, "auto")


@pytest.mark.parametrize("T,K", [(4, 3), (9, 2), (12, 5)])
def test_hmm_loss_matches_across_dispatch(T, K):
    """Chain contraction re-associates the logsumexp tree, so demand tight
    float agreement (the answers are ~1e2 in magnitude)."""
    hmm, obs = make_hmm(T, K)
    np.testing.assert_allclose(
        loss_with(hmm, obs, "pairwise"), loss_with(hmm, obs, "auto"), rtol=2e-6
    )


@pytest.mark.parametrize("T,K", [(4, 3), (9, 4)])
def test_viterbi_decode_bit_identical_across_dispatch(T, K, monkeypatch):
    """MAP decoding produces integers: re-association must not change them."""
    hmm, obs = make_hmm(T, K, seed=1)
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    paths = {}
    for mode in ("pairwise", "auto"):
        monkeypatch.setenv("REPRO_ENUM_DISPATCH", mode)
        dec = infer_discrete(hmm, temperature=0, rng_key=jax.random.PRNGKey(2))
        tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(3))).get_trace(obs)
        paths[mode] = [int(tr[f"z_{t}"]["value"]) for t in range(T)]
    assert paths["pairwise"] == paths["auto"]


def test_marginals_match_across_dispatch(monkeypatch):
    """Also covers differentiating *through* the dispatch: discrete_marginals
    takes jax.grad of logZ, so the chain path must be AD-transparent."""
    hmm, obs = make_hmm(6, 3, seed=2)
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    out = {}
    for mode in ("pairwise", "auto"):
        monkeypatch.setenv("REPRO_ENUM_DISPATCH", mode)
        out[mode] = discrete_marginals(hmm, jax.random.PRNGKey(0), obs)
    for name in out["pairwise"]:
        np.testing.assert_allclose(
            np.asarray(out["pairwise"][name]),
            np.asarray(out["auto"][name]),
            atol=1e-5,
        )


# ---------------------------------------------------------------------------
# semantics that must ride through the kernels unchanged
# ---------------------------------------------------------------------------


def test_chain_under_subsample_scale_across_dispatch():
    """Pending scales resolve after the chain contraction exactly as the
    greedy path resolves them: scale OUTSIDE the marginalizing logsumexp."""
    T, K = 5, 3
    rng = np.random.default_rng(3)
    trans = jnp.asarray(rng.dirichlet(np.ones(K), size=K), jnp.float32)
    locs = jnp.linspace(-1.0, 1.0, K)
    obs = jnp.asarray(rng.normal(size=T), jnp.float32)

    def chain_scaled(obs_seq):
        with handlers.scale(scale=2.5):
            z = P.sample(
                "z_0",
                dist.Categorical(jnp.ones(K) / K),
                infer={"enumerate": "parallel"},
            )
            P.sample("x_0", dist.Normal(locs[z], 1.0), obs=obs_seq[0])
            for t in range(1, T):
                z = P.sample(
                    f"z_{t}", dist.Categorical(trans[z]), infer={"enumerate": "parallel"}
                )
                P.sample(f"x_{t}", dist.Normal(locs[z], 1.0), obs=obs_seq[t])

    np.testing.assert_allclose(
        loss_with(chain_scaled, obs, "pairwise"),
        loss_with(chain_scaled, obs, "auto"),
        rtol=2e-6,
    )


def test_masked_chain_site_neutral_across_dispatch():
    """A masked-out enumerated chain site must contribute exactly 0 (-log K
    fill) through the kernel path too."""
    K = 3
    trans = jnp.asarray(np.random.default_rng(4).dirichlet(np.ones(K), size=K), jnp.float32)

    def masked_chain(_):
        with handlers.mask(mask=False):
            z = P.sample(
                "z_0", dist.Categorical(jnp.ones(K) / K), infer={"enumerate": "parallel"}
            )
            for t in range(1, 4):
                z = P.sample(
                    f"z_{t}", dist.Categorical(trans[z]), infer={"enumerate": "parallel"}
                )

    for mode in ("pairwise", "auto"):
        assert abs(loss_with(masked_chain, DATA, mode)) < 1e-5, mode


def test_mixed_scales_in_chain_still_raise():
    """Heterogeneous scales meeting inside one enumerated contraction (a
    plate-local elimination, where scales are still pending) must keep
    raising the actionable error: the dispatch skips such chains and the
    greedy path raises exactly as before. At root level the final stage
    resolves pending scales before eliminating, so no error there — also
    unchanged."""
    K = 2

    def mixed_in_plate(_):
        with P.plate("N", 3):
            z0 = P.sample(
                "z_0", dist.Categorical(jnp.ones(K) / K), infer={"enumerate": "parallel"}
            )
            with handlers.scale(scale=3.0):
                z1 = P.sample(
                    "z_1",
                    dist.Categorical(jnp.asarray([[0.7, 0.3], [0.2, 0.8]])[z0]),
                    infer={"enumerate": "parallel"},
                )
            with handlers.scale(scale=7.0):
                P.sample(
                    "z_2",
                    dist.Categorical(jnp.asarray([[0.6, 0.4], [0.1, 0.9]])[z1]),
                    infer={"enumerate": "parallel"},
                )

    for mode in ("pairwise", "auto"):
        with pytest.raises(NotImplementedError, match="scale"):
            loss_with(mixed_in_plate, DATA, mode)


# ---------------------------------------------------------------------------
# plumbing units
# ---------------------------------------------------------------------------


def test_to_from_matrix_roundtrip():
    """_to_matrix/_from_matrix are inverses for chain factors with plates."""
    K1, K2, Pn = 3, 4, 5
    # dims -4 (row) and -3 (col), one plate axis of size Pn at -1
    t = jax.random.normal(jax.random.PRNGKey(0), (K1, K2, 1, Pn))
    m = _to_matrix(t, -4, -3)
    assert m.shape == (Pn, K1, K2)
    back = _from_matrix(m, -4, -3)
    assert back.shape == (K1, K2, 1, Pn)
    assert bool(jnp.array_equal(back, t))
    # reversed orientation transposes
    m2 = _to_matrix(t, -3, -4)
    assert m2.shape == (Pn, K2, K1)
    assert bool(jnp.array_equal(jnp.swapaxes(m2, -1, -2), m))
    back2 = _from_matrix(m2, -3, -4)
    assert bool(jnp.array_equal(back2, t))


def test_short_chains_stay_on_greedy_by_default(monkeypatch):
    """Below the planner's chain crossover (~18 edges; REPRO_ENUM_CHAIN_MIN
    overrides) the greedy backward pass is both cheaper per step and
    near-instant to compile, so the dispatch must leave short chains alone:
    auto == pairwise bit-for-bit there."""
    monkeypatch.delenv("REPRO_ENUM_CHAIN_MIN", raising=False)
    hmm, obs = make_hmm(6, 3)
    elbo = TraceEnum_ELBO()
    import os

    os.environ["REPRO_ENUM_DISPATCH"] = "auto"
    try:
        auto = float(elbo.loss(jax.random.PRNGKey(0), {}, hmm, lambda o: None, obs))
        os.environ["REPRO_ENUM_DISPATCH"] = "pairwise"
        pair = float(
            TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, hmm, lambda o: None, obs)
        )
    finally:
        os.environ.pop("REPRO_ENUM_DISPATCH", None)
    assert auto == pair  # identical ops, not merely close


def test_svi_gradients_through_kernel_backend(monkeypatch):
    """TraceEnum_ELBO training differentiates through the dispatched chain;
    with the kernel (interpret) backend that exercises the custom VJP on the
    Pallas op — gradients must match the reference backend."""
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    T, K = 5, 3
    rng = np.random.default_rng(7)
    trans = jnp.asarray(rng.dirichlet(np.ones(K), size=K), jnp.float32)
    obs = jnp.asarray(rng.normal(size=T), jnp.float32)

    def hmm_param(locs, obs_seq):
        @config_enumerate
        def model(obs_seq):
            z = P.sample("z_0", dist.Categorical(jnp.ones(K) / K))
            P.sample("x_0", dist.Normal(locs[z], 1.0), obs=obs_seq[0])
            for t in range(1, T):
                z = P.sample(f"z_{t}", dist.Categorical(trans[z]))
                P.sample(f"x_{t}", dist.Normal(locs[z], 1.0), obs=obs_seq[t])

        return TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, lambda o: None, obs_seq)

    locs0 = jnp.linspace(-1.0, 1.0, K)
    grads = {}
    for backend in ("reference", "interpret"):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", backend)
        grads[backend] = jax.grad(hmm_param)(locs0, obs)
    assert bool(jnp.all(jnp.isfinite(grads["interpret"])))
    np.testing.assert_allclose(
        np.asarray(grads["reference"]), np.asarray(grads["interpret"]), atol=1e-4
    )


def test_dispatch_mode_validation(monkeypatch):
    assert _dispatch_mode() == "auto"
    assert _dispatch_mode("pairwise") == "pairwise"
    monkeypatch.setenv("REPRO_ENUM_DISPATCH", "pairwise")
    assert _dispatch_mode() == "pairwise"
    monkeypatch.setenv("REPRO_ENUM_DISPATCH", "fused")
    with pytest.raises(ValueError, match="dispatch"):
        _dispatch_mode()


def test_contract_dispatch_kwarg_overrides_env(monkeypatch):
    """The explicit dispatch= argument wins over REPRO_ENUM_DISPATCH."""
    monkeypatch.setenv("REPRO_ENUM_DISPATCH", "pairwise")
    monkeypatch.setenv("REPRO_ENUM_CHAIN_MIN", "2")
    K = 3
    pool = frozenset({-1, -2, -3})
    f01 = jax.random.normal(jax.random.PRNGKey(0), (K, K, 1))  # dims -3, -2
    f12 = jax.random.normal(jax.random.PRNGKey(1), (K, K))  # dims -2, -1
    f23 = jax.random.normal(jax.random.PRNGKey(2), (K,))  # dim -1
    factors = [(frozenset(), f01, None), (frozenset(), f12, None), (frozenset(), f23, None)]
    a = contract_log_factors(factors, {}, pool, dispatch="auto")
    p = contract_log_factors(factors, {}, pool)  # env says pairwise
    np.testing.assert_allclose(float(jnp.squeeze(a)), float(jnp.squeeze(p)), rtol=1e-6)
