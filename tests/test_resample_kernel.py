"""Systematic-resampling kernel conformance (ISSUE 10).

`ops.resample` dispatches the sorted-uniform/cumsum counting kernel
(`kernels/resample.py`) against the pure-jnp searchsorted oracle
(`kernels/ref.systematic_resample_ref`): the interpret backend (Pallas body
on CPU) must be bit-identical to the reference backend at every size, the
semantics must be the textbook systematic resampler (sorted ancestors, grid
(u0+i)/N against the weight cumsum), and the documented edge cases — equal
weights, one surviving particle, all-(-inf) log-weights — must hit their
specified outputs exactly. The custom VJP is pinned to zero (ancestor
selection is piecewise constant — the standard VSMC stop-gradient).

The counting kernel is O(N^2) under the interpret backend (the whole grid
runs unrolled on CPU), so interpret-backend rows stay at N <= 4096; the
reference backend carries the large-N conformance in tests/test_smc.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref


@pytest.fixture(params=["interpret", "reference"])
def backend(request, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", request.param)
    return request.param


def random_log_weights(n, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, (n,)).astype(np.float32))


# ---------------------------------------------------------------------------
# backend parity + oracle equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 3, 64, 257, 1000, 4096])
def test_interpret_matches_reference_bit_identical(n, monkeypatch):
    lw = random_log_weights(n, seed=n)
    u0 = 0.37
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
    a_ref = ops.resample(lw, u0)
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    a_int = ops.resample(lw, u0)
    assert a_ref.dtype == a_int.dtype == jnp.int32
    assert jnp.array_equal(a_ref, a_int)


@pytest.mark.parametrize("n", [2, 17, 512])
def test_matches_pure_oracle(n, backend):
    lw = random_log_weights(n, seed=n + 1)
    u0 = 0.61
    ancestors = ops.resample(lw, u0)
    oracle = ref.systematic_resample_ref(lw, jnp.float32(u0))
    assert jnp.array_equal(ancestors, oracle)


def test_ancestors_sorted_and_in_range(backend):
    lw = random_log_weights(513, seed=7)
    a = np.asarray(ops.resample(lw, 0.25))
    assert (np.diff(a) >= 0).all()  # systematic ancestors are sorted
    assert a.min() >= 0 and a.max() < 513


def test_counts_match_weights_statistically(backend):
    """Offspring counts of the systematic resampler are within 1 of N*w_i
    (the defining low-variance property: floor(Nw) <= count <= ceil(Nw))."""
    n = 256
    lw = random_log_weights(n, seed=3, scale=1.5)
    w = np.asarray(jax.nn.softmax(lw))
    a = np.asarray(ops.resample(lw, 0.5))
    counts = np.bincount(a, minlength=n)
    assert (counts >= np.floor(n * w)).all()
    assert (counts <= np.ceil(n * w)).all()


# ---------------------------------------------------------------------------
# specified edge cases
# ---------------------------------------------------------------------------


def test_equal_weights_identity(backend):
    """Equal weights: every particle gets exactly one offspring — the
    systematic resampler is the identity permutation."""
    n = 512
    lw = jnp.zeros(n)
    a = ops.resample(lw, 0.5)
    assert jnp.array_equal(a, jnp.arange(n, dtype=jnp.int32))


def test_one_surviving_particle(backend):
    """One particle with all the mass: every ancestor is that index."""
    lw = jnp.full(64, -jnp.inf).at[7].set(0.0)
    a = ops.resample(lw, 0.123)
    assert jnp.array_equal(a, jnp.full(64, 7, dtype=jnp.int32))


def test_all_neg_inf_falls_back_to_uniform(backend):
    """Degenerate -inf weights (a dead population) fall back to uniform
    weights rather than NaN: the identity permutation comes back."""
    n = 32
    lw = jnp.full(n, -jnp.inf)
    a = ops.resample(lw, 0.5)
    assert jnp.array_equal(a, jnp.arange(n, dtype=jnp.int32))


def test_zero_weight_particles_never_selected(backend):
    n = 128
    lw = random_log_weights(n, seed=9)
    dead = [0, 5, 77, 127]
    lw = lw.at[jnp.asarray(dead)].set(-jnp.inf)
    a = np.asarray(ops.resample(lw, 0.5))
    assert not np.isin(a, dead).any()


def test_u0_endpoints(backend):
    """u0 in [0, 1): both endpoints produce valid indices (u0=0 puts the
    first grid point at exactly 0; the count is clipped into range)."""
    lw = random_log_weights(100, seed=11)
    for u0 in (0.0, 0.999999):
        a = np.asarray(ops.resample(lw, u0))
        assert a.min() >= 0 and a.max() < 100


# ---------------------------------------------------------------------------
# gradient + validation contracts
# ---------------------------------------------------------------------------


def test_custom_vjp_zero_gradient(backend):
    """Ancestor selection is piecewise constant in the weights: the custom
    VJP returns exactly zero, so VSMC losses get the standard biased
    stop-gradient-through-ancestry estimator instead of a trace error."""
    lw = random_log_weights(32, seed=13)

    def loss(lw):
        a = ops.resample(lw, 0.5)
        return jnp.sum(a.astype(jnp.float32)) + jnp.sum(lw)

    g = jax.grad(loss)(lw)
    assert jnp.array_equal(g, jnp.ones_like(lw))  # only the direct term


def test_validates_rank_and_size(backend):
    with pytest.raises(ValueError):
        ops.resample(jnp.zeros((4, 4)), 0.5)
    with pytest.raises(ValueError):
        ops.resample(jnp.zeros((0,)), 0.5)


def test_jit_and_vmap_compatible(backend):
    lw = random_log_weights(64, seed=17)
    direct = ops.resample(lw, 0.5)
    jitted = jax.jit(lambda w: ops.resample(w, 0.5))(lw)
    assert jnp.array_equal(direct, jitted)
    batch = jnp.stack([lw, lw + 1.0])  # +const leaves normalized weights alone
    vmapped = jax.vmap(lambda w: ops.resample(w, 0.5))(batch)
    assert jnp.array_equal(vmapped[0], direct)
    assert jnp.array_equal(vmapped[1], direct)
