"""checkpoint/store.py: atomic manifests, roundtrips (template/shardings),
max_keep GC, latest-step resolution, and AsyncCheckpointer ordering —
load-bearing for serve warm-start."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def tree_example():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)},
        "step": jnp.asarray(5, jnp.int32),
    }


def assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------


def test_roundtrip_without_template(tmp_path):
    tree = tree_example()
    step_dir = store.save(str(tmp_path), 3, tree)
    assert os.path.isdir(step_dir)
    step, out = store.restore(str(tmp_path))
    assert step == 3
    assert_tree_equal(out, tree)


def test_roundtrip_with_template_validates(tmp_path):
    tree = tree_example()
    store.save(str(tmp_path), 1, tree)
    step, out = store.restore(str(tmp_path), template=tree)
    assert step == 1
    assert_tree_equal(out, tree)
    # template with a mismatched shape fails loudly
    bad = {
        "params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(3)},
        "step": jnp.asarray(0, jnp.int32),
    }
    with pytest.raises(ValueError, match="shape mismatch"):
        store.restore(str(tmp_path), template=bad)
    # template with an extra leaf the checkpoint lacks fails loudly
    extra = dict(tree, extra=jnp.zeros(2))
    with pytest.raises(KeyError, match="missing leaf"):
        store.restore(str(tmp_path), template=extra)


def test_restore_with_shardings_places_leaves(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import default_mesh

    tree = tree_example()
    store.save(str(tmp_path), 2, tree)
    mesh = default_mesh()
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    step, out = store.restore(str(tmp_path), template=tree, shardings=shardings)
    assert step == 2
    assert_tree_equal(out, tree)
    for leaf in jax.tree_util.tree_leaves(out):
        assert leaf.sharding.mesh.shape == mesh.shape


def test_restore_latest_and_explicit_step(tmp_path):
    t1, t2 = tree_example(), {"x": jnp.ones(2)}
    store.save(str(tmp_path), 1, t1, max_keep=None)
    store.save(str(tmp_path), 9, t2, max_keep=None)
    assert store.latest_step(str(tmp_path)) == 9
    step, out = store.restore_latest(str(tmp_path))
    assert step == 9
    assert_tree_equal(out, t2)
    step, out = store.restore(str(tmp_path), 1)
    assert step == 1
    assert_tree_equal(out, t1)


def test_restore_empty_directory_raises(tmp_path):
    assert store.latest_step(str(tmp_path)) is None
    assert store.latest_step(str(tmp_path / "missing")) is None
    with pytest.raises(FileNotFoundError, match="no committed checkpoint"):
        store.restore(str(tmp_path))


def test_uncommitted_step_is_garbage(tmp_path):
    """A step dir without manifest.json (crashed writer) must be invisible."""
    store.save(str(tmp_path), 1, {"x": jnp.ones(1)})
    fake = tmp_path / "step_000000005"
    fake.mkdir()  # no manifest: not committed
    assert store.latest_step(str(tmp_path)) == 1
    step, _ = store.restore_latest(str(tmp_path))
    assert step == 1


def test_save_overwrites_existing_step(tmp_path):
    store.save(str(tmp_path), 4, {"x": jnp.zeros(2)})
    store.save(str(tmp_path), 4, {"x": jnp.ones(2)})
    _, out = store.restore(str(tmp_path), 4)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(2))


# ---------------------------------------------------------------------------
# GC
# ---------------------------------------------------------------------------


def test_max_keep_gc_keeps_newest(tmp_path):
    for s in range(6):
        store.save(str(tmp_path), s, {"x": jnp.full(1, float(s))}, max_keep=3)
    kept = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert kept == [3, 4, 5]
    _, out = store.restore_latest(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(out["x"]), [5.0])


def test_max_keep_none_keeps_everything(tmp_path):
    for s in range(5):
        store.save(str(tmp_path), s, {"x": jnp.zeros(1)}, max_keep=None)
    kept = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
    assert len(kept) == 5


# ---------------------------------------------------------------------------
# AsyncCheckpointer
# ---------------------------------------------------------------------------


def test_async_checkpointer_ordering(tmp_path):
    """save_async admits one outstanding save; a burst of saves lands them
    all, in order, with GC applied."""
    ck = store.AsyncCheckpointer(str(tmp_path), max_keep=2)
    for s in range(5):
        ck.save_async(s, {"x": jnp.full(2, float(s))})
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 4
    kept = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert kept == [3, 4]
    _, out = store.restore_latest(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(out["x"]), [4.0, 4.0])


def test_async_checkpointer_snapshot_isolated_from_donation(tmp_path):
    """The host snapshot happens on the caller thread: mutating (or deleting)
    the source tree after save_async must not corrupt the checkpoint."""
    ck = store.AsyncCheckpointer(str(tmp_path))
    x = np.ones(3, np.float32)
    ck.save_async(0, {"x": x})
    x *= 100.0  # simulates a donated/reused buffer
    ck.wait()
    _, out = store.restore_latest(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(3))


def test_async_checkpointer_error_propagates_on_wait(tmp_path):
    blocker = tmp_path / "ckpt"
    blocker.write_text("not a directory")  # save must fail
    ck = store.AsyncCheckpointer(str(blocker))
    ck.save_async(0, {"x": jnp.zeros(1)})
    with pytest.raises(BaseException):
        ck.wait()
    # the error is cleared after being raised once
    ck.wait()


def test_async_checkpointer_concurrent_saves_and_waits(tmp_path):
    """Hammer save_async/wait from several threads: the one-outstanding-save
    contract plus join() must leave a committed, readable latest step."""
    ck = store.AsyncCheckpointer(str(tmp_path), max_keep=None)
    errors = []

    def worker(tid):
        try:
            for i in range(3):
                ck.save_async(tid * 10 + i, {"x": jnp.full(1, float(tid))})
                ck.wait()
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ck.wait()
    assert not errors
    assert store.latest_step(str(tmp_path)) == 32
    step, out = store.restore_latest(str(tmp_path))
    assert out["x"].shape == (1,)
