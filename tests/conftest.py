import os

# Smoke tests and benches must see ONE device — the 512-device flag belongs
# to launch/dryrun.py exclusively (assignment spec).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
