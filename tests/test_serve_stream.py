"""Streaming inference service: prefetching pipeline, background trainer,
hot-swap-under-traffic contract, deadline load shedding, and the HTTP
front end. The tentpole property — refresh under live traffic drops zero
requests and never recompiles — is asserted end-to-end here.
"""
import json
import tempfile
import threading
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint.store import AsyncCheckpointer, restore_latest
from repro.data.pipeline import (
    Prefetcher,
    RegressionStream,
    RegressionStreamConfig,
)
from repro.infer import SVI, AutoDelta, Trace_ELBO
from repro.launch.stream import _stream_model
from repro.retrace import assert_num_traces
from repro.serve import (
    CompiledServable,
    InferenceServer,
    LoadShedError,
    MicroBatcher,
    ServableModel,
    StreamingTrainer,
    hot_swap_on_commit,
)

DIM = 4


def make_stream(drift=0.0, batch=32, max_steps=None):
    return RegressionStream(
        RegressionStreamConfig(dim=DIM, batch=batch, drift=drift),
        max_steps=max_steps,
    )


def make_svi_servable(name="stream-test", max_batch=16, steps=3):
    """A small trained artifact: (svi, state, servable) triple."""
    stream = make_stream()
    guide = AutoDelta(_stream_model)
    svi = SVI(_stream_model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0), stream.batch(0))
    for i in range(steps):
        state, _ = svi.update_jit(state, stream.batch(i))
    params = svi.optim.get_params(state.optim_state)
    servable = ServableModel.from_svi(
        name, _stream_model, guide, params,
        num_samples=1, return_sites=["mu"], max_batch=max_batch,
    )
    return svi, state, servable


def expected_mu(params, x):
    """AutoDelta serving is deterministic: mu == x @ w_loc + b_loc."""
    return np.asarray(x) @ np.asarray(params["auto_w_loc"]) + np.asarray(
        params["auto_b_loc"]
    )


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestRegressionStream:
    def test_deterministic_per_step(self):
        a, b = make_stream(drift=0.01), make_stream(drift=0.01)
        for step in (0, 3, 17):
            np.testing.assert_array_equal(a.batch(step)["x"], b.batch(step)["x"])
            np.testing.assert_array_equal(a.batch(step)["y"], b.batch(step)["y"])

    def test_shapes_and_dtypes(self):
        batch = make_stream(batch=8).batch(0)
        assert batch["x"].shape == (8, DIM) and batch["x"].dtype == jnp.float32
        assert batch["y"].shape == (8,) and batch["y"].dtype == jnp.float32

    def test_drift_rotates_true_weights(self):
        s = make_stream(drift=0.05)
        w0, w100 = s.true_weights(0), s.true_weights(100)
        assert not np.allclose(w0, w100)
        # rotation: norm preserved, untouched coords identical
        assert np.linalg.norm(w0) == pytest.approx(np.linalg.norm(w100), rel=1e-5)
        np.testing.assert_array_equal(w0[2:], w100[2:])

    def test_zero_drift_is_stationary(self):
        s = make_stream(drift=0.0)
        np.testing.assert_array_equal(s.true_weights(0), s.true_weights(500))

    def test_finite_iteration(self):
        assert len(list(make_stream(max_steps=5))) == 5


class TestPrefetcher:
    def test_yields_everything_in_order(self):
        with Prefetcher(range(20), prefetch=3) as pf:
            assert list(pf) == list(range(20))

    def test_bounded_buffer_backpressures(self):
        produced = []

        def source():
            for i in range(100):
                produced.append(i)
                yield i

        pf = Prefetcher(source(), prefetch=2)
        time.sleep(0.3)
        # producer blocked on the bounded queue, not 100 items deep
        assert len(produced) <= 4
        pf.close()

    def test_source_exception_reraises_on_consumer(self):
        def bad():
            yield 1
            raise RuntimeError("stream died")

        pf = Prefetcher(bad(), prefetch=2)
        assert next(pf) == 1
        with pytest.raises(RuntimeError, match="stream died"):
            next(pf)

    def test_close_unblocks_full_producer(self):
        pf = Prefetcher(iter(int, 1), prefetch=1)  # infinite zeros
        time.sleep(0.1)
        pf.close()  # must not hang
        with pytest.raises(StopIteration):
            next(pf)

    def test_prefetch_must_be_positive(self):
        with pytest.raises(ValueError, match="prefetch"):
            Prefetcher([1], prefetch=0)


# ---------------------------------------------------------------------------
# deadline-aware load shedding
# ---------------------------------------------------------------------------


def identity_engine(**kwargs):
    def fn(key, batch):
        return {"y": batch["x"] * 2.0}

    return CompiledServable(fn, **kwargs)


class TestLoadShedding:
    def test_cold_queue_never_sheds(self):
        with MicroBatcher(identity_engine(max_batch=8), max_wait_ms=5.0) as mb:
            assert mb.projected_wait_ms() == 0.0
            out = mb.predict({"x": jnp.zeros(2)}, timeout=30, deadline_ms=0.001)
            assert out["y"].shape == (2,)
        assert mb.stats.shed == 0

    def test_sheds_when_projected_wait_exceeds_deadline(self):
        mb = MicroBatcher(identity_engine(max_batch=8), max_wait_ms=5.0)
        try:
            # simulate a hot, backed-up batcher: 1s per batch, 32 rows queued
            with mb._submit_lock:
                mb._ewma_batch_s = 1.0
                mb._pending_rows = 32
            with pytest.raises(LoadShedError) as exc:
                mb.submit({"x": jnp.zeros(2)}, deadline_ms=100.0)
            err = exc.value
            assert err.projected_wait_ms > err.deadline_ms == 100.0
            assert err.retry_after_ms >= 1.0
            assert mb.stats.shed == 1
            assert mb.stats.summary()["shed_rate"] > 0
            # no deadline -> always admitted, even under the same projection
            with mb._submit_lock:
                mb._pending_rows = 32  # reset (submit above didn't enqueue)
            fut = mb.submit({"x": jnp.zeros(2)})
            with mb._submit_lock:
                mb._pending_rows = 2  # let the worker's accounting converge
            assert fut.result(timeout=30)["y"].shape == (2,)
        finally:
            mb.close()

    def test_projected_wait_scales_with_pending_rows(self):
        mb = MicroBatcher(identity_engine(max_batch=8), max_wait_ms=2.0)
        try:
            with mb._submit_lock:
                mb._ewma_batch_s = 0.1
                mb._pending_rows = 8
            low = mb.projected_wait_ms(1)
            with mb._submit_lock:
                mb._pending_rows = 80
            high = mb.projected_wait_ms(1)
            assert high > low > 0
        finally:
            with mb._submit_lock:
                mb._pending_rows = 0
            mb.close()

    def test_pending_rows_return_to_zero_after_traffic(self):
        with MicroBatcher(identity_engine(max_batch=8), max_wait_ms=2.0) as mb:
            futs = [mb.submit({"x": jnp.zeros(3)}) for _ in range(6)]
            for f in futs:
                f.result(timeout=30)
        assert mb._pending_rows == 0

    def test_pending_rows_released_on_engine_error(self):
        def bad(key, batch):
            raise RuntimeError("kaboom")

        with MicroBatcher(CompiledServable(bad, max_batch=8), max_wait_ms=2.0) as mb:
            fut = mb.submit({"x": jnp.zeros(2)})
            with pytest.raises(RuntimeError, match="kaboom"):
                fut.result(timeout=30)
            deadline = time.perf_counter() + 5.0
            while mb._pending_rows and time.perf_counter() < deadline:
                time.sleep(0.01)
        assert mb._pending_rows == 0


# ---------------------------------------------------------------------------
# async checkpoint commit callback
# ---------------------------------------------------------------------------


class TestOnCommit:
    def test_fires_after_commit_with_step(self):
        committed = []
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d)

            def on_commit(step):
                # the manifest rename happened strictly before this runs
                got_step, tree = restore_latest(d)
                committed.append((step, got_step, float(tree["v"])))

            ck.save_async(7, {"v": jnp.float32(1.5)}, on_commit=on_commit)
            ck.wait()
        assert committed == [(7, 7, 1.5)]

    def test_callback_error_surfaces_on_wait(self):
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d)

            def explode(step):
                raise RuntimeError("commit hook failed")

            ck.save_async(1, {"v": jnp.zeros(2)}, on_commit=explode)
            with pytest.raises(RuntimeError, match="commit hook failed"):
                ck.wait()


# ---------------------------------------------------------------------------
# streaming trainer
# ---------------------------------------------------------------------------


class TestStreamingTrainer:
    def test_finite_stream_trains_and_commits(self):
        svi, state, servable = make_svi_servable()
        with tempfile.TemporaryDirectory() as d:
            trainer = StreamingTrainer(
                svi, make_stream(max_steps=25), state=state,
                directory=d, ckpt_every=10,
            )
            trainer.start()
            trainer.join(timeout=120)
            assert trainer.steps_done == 25
            assert trainer.last_loss is not None
            # final partial window checkpointed too
            step, tree = restore_latest(d)
            assert step == 25
            assert "params" in tree and "auto_w_loc" in tree["params"]
            assert trainer.last_committed_step == 25
        # the hot loop compiled exactly once across all 25 steps
        assert_num_traces(svi, 1, context="trainer hot loop")

    def test_hot_swap_on_commit_refreshes_servable(self):
        svi, state, servable = make_svi_servable()
        x = np.ones((2, DIM), np.float32)
        before = servable(jax.random.PRNGKey(0), {"x": jnp.asarray(x)})
        with tempfile.TemporaryDirectory() as d:
            trainer = StreamingTrainer(
                svi, make_stream(max_steps=20), state=state, directory=d,
                ckpt_every=10, on_commit=hot_swap_on_commit(servable, d),
            )
            trainer.start()
            committed = trainer.wait_for_commit(timeout=60)
            assert committed >= 10
            trainer.join(timeout=60)
            assert servable.restored_step == 20
            # served output now reflects the *trained* params exactly
            _, tree = restore_latest(d)
            after = servable(jax.random.PRNGKey(0), {"x": jnp.asarray(x)})
            np.testing.assert_allclose(
                np.asarray(after["mu"])[0], expected_mu(tree["params"], x),
                rtol=1e-5,
            )
            assert not np.allclose(np.asarray(after["mu"]), np.asarray(before["mu"]))

    def test_stop_mid_stream_checkpoints_final_state(self):
        svi, state, _ = make_svi_servable()
        with tempfile.TemporaryDirectory() as d:
            trainer = StreamingTrainer(
                svi, Prefetcher(make_stream(), prefetch=2), state=state,
                directory=d, ckpt_every=10_000,  # never on cadence
            )
            with trainer:
                deadline = time.perf_counter() + 30
                while trainer.steps_done < 3 and time.perf_counter() < deadline:
                    time.sleep(0.01)
            assert trainer.steps_done >= 3
            step, _ = restore_latest(d)
            assert step == trainer.steps_done

    def test_stream_error_raises_on_join(self):
        svi, state, _ = make_svi_servable()

        def bad():
            yield make_stream().batch(0)
            raise RuntimeError("pipeline died")

        with tempfile.TemporaryDirectory() as d:
            trainer = StreamingTrainer(svi, bad(), state=state, directory=d)
            trainer.start()
            with pytest.raises(RuntimeError, match="pipeline died"):
                trainer.join(timeout=60)

    def test_wait_for_commit_timeout(self):
        svi, state, _ = make_svi_servable()
        with tempfile.TemporaryDirectory() as d:
            trainer = StreamingTrainer(
                svi, make_stream(max_steps=0), state=state, directory=d,
            )
            with pytest.raises(TimeoutError):
                trainer.wait_for_commit(timeout=0.05)

    def test_ckpt_every_validated(self):
        svi, state, _ = make_svi_servable()
        with pytest.raises(ValueError, match="ckpt_every"):
            StreamingTrainer(svi, [], state=state, directory="/tmp/x", ckpt_every=0)


# ---------------------------------------------------------------------------
# THE tentpole property: refresh under live traffic
# ---------------------------------------------------------------------------


class TestRefreshUnderTraffic:
    def test_bucket_sized_weak_typed_batch_does_not_retrace(self):
        """A request exactly at bucket size skips the pad copy; pad_leading
        must still canonicalize its dtype (jnp.pad drops weak_type) so the
        bucket's aval never depends on whether padding occurred."""
        sv = CompiledServable(lambda key, batch: batch["x"] * 2.0, max_batch=8)
        sv(jax.random.PRNGKey(0), {"x": jnp.ones((3, 2))})  # padded to bucket 4
        assert sv.num_traces == 1
        # weak-typed (python-scalar fill) batch already at bucket size
        sv(jax.random.PRNGKey(1), {"x": jnp.full((4, 2), 7.0)})
        assert_num_traces(sv, 1, context="weak-typed bucket-sized batch")

    def test_zero_drops_zero_recompiles_and_new_params_serve(self):
        """Concurrent clients hammer the batcher while refresh() hot-swaps
        params mid-stream. Contract: every request completes (no drops, no
        errors), nothing recompiles (num_traces is unchanged), and requests
        after the swap serve the NEW posterior."""
        _, _, servable = make_svi_servable(max_batch=16)
        old_params = dict(servable.engine.state["params"])
        new_params = {
            "auto_w_loc": jnp.asarray(np.arange(DIM, dtype=np.float32)),
            "auto_b_loc": jnp.float32(-3.0),
        }
        x = np.eye(DIM, dtype=np.float32)[:2]  # 2 rows, rank-revealing
        mu_old = expected_mu(old_params, x)
        mu_new = expected_mu(new_params, x)
        assert not np.allclose(mu_old, mu_new)

        n_clients, n_requests = 6, 12
        results = [[None] * n_requests for _ in range(n_clients)]
        errors = []
        swapped = threading.Event()

        with MicroBatcher(servable, max_wait_ms=1.0) as mb:
            # warm every bucket the traffic can touch before the clock starts
            for rows in range(1, n_clients * 2 + 1):
                mb.predict({"x": jnp.zeros((rows, DIM))}, timeout=60)
            traces_before = servable.num_traces

            def client(cid):
                for i in range(n_requests):
                    try:
                        out = mb.predict({"x": jnp.asarray(x)}, timeout=60)
                        results[cid][i] = (swapped.is_set(), np.asarray(out["mu"])[0])
                    except Exception as e:  # noqa: BLE001 — contract: none
                        errors.append(e)

            threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
            for t in threads:
                t.start()
            time.sleep(0.05)  # let traffic build up mid-flight
            servable.refresh(params=new_params)
            swapped.set()
            for t in threads:
                t.join()

        assert errors == []
        flat = [r for row in results for r in row]
        assert all(r is not None for r in flat)  # zero drops
        # zero recompiles across the swap
        assert_num_traces(servable, traces_before, context="hot swap")
        assert servable.num_traces == len(servable.buckets_touched)
        # every response is exactly one of the two posteriors (never torn),
        # and responses provably *after* the swap are the new one
        for after_swap, mu in flat:
            is_old = np.allclose(mu, mu_old, atol=1e-5)
            is_new = np.allclose(mu, mu_new, atol=1e-5)
            assert is_old or is_new
        post_swap = [mu for after_swap, mu in flat if after_swap]
        assert post_swap, "no requests observed after the swap"
        np.testing.assert_allclose(post_swap[-1], mu_new, atol=1e-5)

    def test_refresh_rejects_unknown_state_key(self):
        _, _, servable = make_svi_servable()
        with pytest.raises(KeyError, match="unknown state key"):
            servable.refresh(samples={})


# ---------------------------------------------------------------------------
# concurrent tracing (the bug the thread-local handler stack fixes)
# ---------------------------------------------------------------------------


class TestConcurrentTracing:
    def test_parallel_model_traces_do_not_interleave(self):
        """Regression: with a process-global handler stack, concurrent
        traces corrupt each other ("duplicate site name" errors). Each
        thread must get its own Poutine stack."""
        from repro.core import handlers

        errors = []
        barrier = threading.Barrier(8)

        def worker(seed):
            try:
                barrier.wait(timeout=30)
                for i in range(20):
                    batch = make_stream().batch(i % 3)
                    tr = handlers.trace(
                        handlers.seed(_stream_model, jax.random.PRNGKey(seed))
                    ).get_trace(batch)
                    assert set(tr.nodes) >= {"w", "b", "mu", "y"}
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []


# ---------------------------------------------------------------------------
# HTTP front end
# ---------------------------------------------------------------------------


def http_post(address, path, payload, timeout=60.0):
    req = urllib.request.Request(
        address + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST",
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read()), dict(resp.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}"), dict(e.headers)


def http_get(address, path, timeout=30.0):
    try:
        with urllib.request.urlopen(address + path, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}")


@pytest.fixture(scope="module")
def live_server():
    _, _, servable = make_svi_servable(name="reg", max_batch=16)
    server = InferenceServer({"reg": servable}, max_wait_ms=1.0)
    with server:
        yield server, servable


class TestInferenceServer:
    def test_healthz_and_registry(self, live_server):
        server, _ = live_server
        status, body = http_get(server.address, "/healthz")
        assert status == 200 and body["ok"] and body["models"] == ["reg"]
        status, body = http_get(server.address, "/v1/models")
        assert status == 200
        (info,) = body["models"]
        assert info["name"] == "reg" and info["kind"] == "svi"
        assert info["num_traces"] == len(info["buckets"]) or info["num_traces"] >= 0

    def test_predict_roundtrip_deterministic(self, live_server):
        server, servable = live_server
        x = np.eye(DIM, dtype=np.float32)[:3]
        status, body, _ = http_post(
            server.address, "/v1/models/reg:predict", {"inputs": {"x": x.tolist()}}
        )
        assert status == 200
        mu = np.asarray(body["outputs"]["mu"])[0]
        np.testing.assert_allclose(
            mu, expected_mu(servable.engine.state["params"], x), rtol=1e-5
        )

    def test_predict_bad_requests(self, live_server):
        server, _ = live_server
        status, body, _ = http_post(server.address, "/v1/models/reg:predict", {})
        assert status == 400 and "inputs" in body["error"]
        status, body, _ = http_post(
            server.address, "/v1/models/nope:predict", {"inputs": [[0.0] * DIM]}
        )
        assert status == 404
        # rows > max_batch -> split-client-side ValueError -> 400
        big = np.zeros((64, DIM)).tolist()
        status, body, _ = http_post(
            server.address, "/v1/models/reg:predict", {"inputs": {"x": big}}
        )
        assert status == 400 and "max_batch" in body["error"]

    def test_stats_route(self, live_server):
        server, _ = live_server
        status, body = http_get(server.address, "/v1/models/reg/stats")
        assert status == 200
        for key in ("requests", "p50_ms", "shed", "shed_rate", "num_traces",
                    "projected_wait_ms"):
            assert key in body

    def test_deadline_shed_maps_to_429_with_retry_after(self, live_server):
        server, _ = live_server
        mb = server.batchers["reg"]
        with mb._submit_lock:
            saved = (mb._ewma_batch_s, mb._pending_rows)
            mb._ewma_batch_s, mb._pending_rows = 5.0, 64
        try:
            status, body, headers = http_post(
                server.address, "/v1/models/reg:predict",
                {"inputs": {"x": [[0.0] * DIM]}, "deadline_ms": 10.0},
            )
        finally:
            with mb._submit_lock:
                mb._ewma_batch_s, mb._pending_rows = saved
        assert status == 429
        assert body["projected_wait_ms"] > body["deadline_ms"] == 10.0
        assert int(headers["Retry-After"]) >= 1

    def test_refresh_endpoint_hot_swaps_from_checkpoint(self, live_server):
        server, servable = live_server
        new_params = {
            "auto_w_loc": jnp.ones(DIM) * 2.0,
            "auto_b_loc": jnp.float32(1.0),
        }
        traces_before = servable.num_traces
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d)
            ck.save_async(42, {"params": new_params})
            ck.wait()
            status, body, _ = http_post(
                server.address, "/admin/models/reg/refresh", {"directory": d}
            )
        assert status == 200
        assert body["restored_step"] == 42
        assert body["recompiled"] is False
        assert body["num_traces"] == traces_before
        x = np.ones((1, DIM), np.float32)
        status, out, _ = http_post(
            server.address, "/v1/models/reg:predict", {"inputs": {"x": x.tolist()}}
        )
        np.testing.assert_allclose(
            np.asarray(out["outputs"]["mu"])[0], expected_mu(new_params, x), rtol=1e-5
        )

    def test_refresh_endpoint_empty_dir_is_409(self, live_server):
        server, _ = live_server
        with tempfile.TemporaryDirectory() as d:
            status, body, _ = http_post(
                server.address, "/admin/models/reg/refresh", {"directory": d}
            )
        assert status == 409

    def test_device_loss_plan_and_507(self, live_server):
        server, _ = live_server
        status, body, _ = http_post(
            server.address, "/admin/device-loss",
            {"n_hosts_alive": 2, "chips_per_host": 4, "model_parallelism": 1},
        )
        assert status == 200
        assert body["plan"]["chips_used"] <= 8
        assert body["models"] == ["reg"]
        # model parallelism wider than the survivors' chips: no viable mesh
        status, body, _ = http_post(
            server.address, "/admin/device-loss",
            {"n_hosts_alive": 1, "chips_per_host": 2, "model_parallelism": 4},
        )
        assert status == 507
        status, body, _ = http_post(server.address, "/admin/device-loss", {})
        assert status == 400

    def test_unknown_route_404(self, live_server):
        server, _ = live_server
        assert http_get(server.address, "/nope")[0] == 404
        assert http_post(server.address, "/nope", {})[0] == 404
