"""The unified `InferenceEngine` surface + FutureWarning aliases (ISSUE 10).

One protocol for the sample-producing engines — ``run(key, *args)``,
``get_samples(group_by_chain=...)``, ``num_traces`` — and the kwarg
reconciliation behind it: `mesh=` is the canonical sharding spelling
everywhere (the legacy `MCMC(chain_method=...)` warns), `num_particles`
the canonical particle count (the legacy `Importance(num_samples=...)`
warns). Every alias is pinned to produce bit-identical results through the
old and the new spelling (the PR-9 config-alias playbook).
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import distributions as dist
from repro.core import primitives as P
from repro.infer import (
    HMC,
    MCMC,
    SMC,
    Importance,
    ImportanceSampling,
    InferenceEngine,
    Predictive,
    SVI,
)
from repro.retrace import RetraceCounted

DATA = jnp.asarray([0.3, -0.2, 0.5, 0.1])


def normal_model(y):
    loc = P.sample("loc", dist.Normal(0.0, 1.0))
    P.sample("obs", dist.Normal(loc, 1.0), obs=y)


def ssm_init(y):
    x = P.sample("x", dist.Normal(0.0, 1.0))
    P.sample("y", dist.Normal(x, 0.5), obs=y)
    return {"x": x}


def ssm_step(carry, y):
    x = P.sample("x", dist.Normal(0.9 * carry["x"], 0.3))
    P.sample("y", dist.Normal(x, 0.5), obs=y)
    return {"x": x}


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------


def test_engines_satisfy_protocol_structurally():
    engines = [
        MCMC(HMC(normal_model), num_warmup=10, num_samples=10),
        SMC(ssm_init, ssm_step, num_particles=32),
        ImportanceSampling(normal_model, num_particles=32),
    ]
    for eng in engines:
        assert isinstance(eng, InferenceEngine), type(eng).__name__
        assert isinstance(eng, RetraceCounted), type(eng).__name__


def test_uniform_run_get_samples_surface():
    """The same three calls drive every engine; group_by_chain=True always
    prepends the chain/population axis."""
    ys = jnp.asarray([0.4, 0.2, 0.1])
    cases = [
        (MCMC(HMC(normal_model), num_warmup=30, num_samples=20), (DATA,), "loc"),
        (SMC(ssm_init, ssm_step, num_particles=64), (ys,), "x"),
        (ImportanceSampling(normal_model, num_particles=64), (DATA,), "loc"),
    ]
    for eng, args, site in cases:
        eng.run(jax.random.PRNGKey(0), *args)
        flat = eng.get_samples()[site]
        chained = eng.get_samples(group_by_chain=True)[site]
        assert chained.ndim == flat.ndim + 1, type(eng).__name__
        assert chained.shape[0] * chained.shape[1] == flat.shape[0] or (
            chained.shape[1:] == flat.shape  # particle engines: 1 x N
        ), type(eng).__name__
        assert eng.num_traces >= 1


# ---------------------------------------------------------------------------
# Importance -> ImportanceSampling alias
# ---------------------------------------------------------------------------


def test_importance_warns_futurewarning():
    with pytest.warns(FutureWarning, match="ImportanceSampling"):
        Importance(normal_model, num_samples=8)


def test_importance_alias_bit_parity():
    """Old and new spellings must produce bit-identical weights and samples
    from the same key (same key structure, same log-prob filter order)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        old = Importance(normal_model, num_samples=256)
    new = ImportanceSampling(normal_model, num_particles=256)
    old.run(jax.random.PRNGKey(1), DATA)
    new.run(jax.random.PRNGKey(1), DATA)
    assert jnp.array_equal(old.log_weights, new.log_weights)
    assert jnp.array_equal(old.get_samples()["loc"], new.get_samples()["loc"])
    assert old.num_samples == old.num_particles == 256


def test_importance_alias_with_guide_bit_parity():
    def guide(y):
        P.sample("loc", dist.Normal(0.2, 0.7))

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        old = Importance(normal_model, guide, num_samples=128)
    new = ImportanceSampling(normal_model, guide, num_particles=128)
    old.run(jax.random.PRNGKey(2), DATA)
    new.run(jax.random.PRNGKey(2), DATA)
    assert jnp.array_equal(old.log_weights, new.log_weights)


# ---------------------------------------------------------------------------
# MCMC chain_method -> mesh alias
# ---------------------------------------------------------------------------


def test_chain_method_warns_futurewarning():
    with pytest.warns(FutureWarning, match="mesh="):
        MCMC(HMC(normal_model), 10, 10, chain_method="vectorized")


@pytest.mark.parametrize(
    "old_kw,new_kw",
    [
        ({"chain_method": "vectorized"}, {"mesh": None}),
        ({"chain_method": "sharded"}, {"mesh": "auto"}),
    ],
    ids=["vectorized", "sharded"],
)
def test_chain_method_alias_bit_parity(old_kw, new_kw):
    runs = {}
    for label, kw in (("old", old_kw), ("new", new_kw)):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", FutureWarning)
            mcmc = MCMC(
                HMC(normal_model), num_warmup=40, num_samples=30,
                num_chains=2, **kw,
            )
        mcmc.run(jax.random.PRNGKey(3), DATA)
        runs[label] = mcmc
    assert runs["old"].chain_method == runs["new"].chain_method
    assert jnp.array_equal(
        runs["old"].get_samples(group_by_chain=True)["loc"],
        runs["new"].get_samples(group_by_chain=True)["loc"],
    )


def test_mesh_auto_resolves_to_default_mesh():
    mcmc = MCMC(HMC(normal_model), 10, 10, mesh="auto")
    assert mcmc.mesh is not None
    assert mcmc.chain_method == "sharded"
    assert MCMC(HMC(normal_model), 10, 10).mesh is None


def test_explicit_mesh_object_accepted():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    mcmc = MCMC(HMC(normal_model), 10, 10, mesh=mesh)
    assert mcmc.mesh is mesh
    assert mcmc.chain_method == "sharded"


def test_bad_mesh_string_rejected():
    with pytest.raises(ValueError, match="mesh must be"):
        MCMC(HMC(normal_model), 10, 10, mesh="tpu")


def test_chain_method_sharded_with_explicit_mesh_keeps_it():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        mcmc = MCMC(HMC(normal_model), 10, 10, chain_method="sharded", mesh=mesh)
    assert mcmc.mesh is mesh


# ---------------------------------------------------------------------------
# canonical spellings elsewhere (no aliases needed — pinned so they don't
# drift apart again)
# ---------------------------------------------------------------------------


def test_predictive_num_samples_is_canonical():
    pred = Predictive(normal_model, num_samples=7)
    out = pred(jax.random.PRNGKey(4), DATA)
    assert out["obs"].shape[0] == 7


def test_particle_engines_share_mesh_kwarg():
    """`mesh=` means the same thing on every engine: constrain the
    parallel axis (chains or particles) onto the mesh."""
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    ys = jnp.asarray([0.4, 0.2])
    for eng in (
        SMC(ssm_init, ssm_step, num_particles=32, mesh=mesh),
        ImportanceSampling(normal_model, num_particles=32, mesh=mesh),
    ):
        eng.run(jax.random.PRNGKey(5), *((ys,) if isinstance(eng, SMC) else (DATA,)))
        assert np.isfinite(float(jnp.sum(eng.log_weights)))
