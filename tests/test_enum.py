"""Enumeration subsystem tests (ISSUE 3 acceptance list).

Hand-computed 2-component GMM marginal likelihood == TraceEnum_ELBO loss;
infer_discrete recovers the exact posterior over assignments; one compiled
trace across SVI steps (retrace counter == 1); mesh-sharded particles
bit-identical to the unsharded path; plate-aware contraction on global
latents, nested plates, and Markov chains vs brute force.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributions as dist
from repro import optim
from repro.core import handlers
from repro.core import primitives as P
from repro.infer import (
    SVI,
    AutoNormal,
    Trace_ELBO,
    TraceEnum_ELBO,
    config_enumerate,
    discrete_marginals,
    infer_discrete,
)

DATA = jnp.asarray([-1.2, -0.8, 1.9, 2.2, 2.0])
WEIGHTS = jnp.asarray([0.4, 0.6])
LOCS = jnp.asarray([-1.0, 2.0])
SCALE = 0.5


def gmm(data):
    with P.plate("N", data.shape[0]):
        z = P.sample("z", dist.Categorical(WEIGHTS), infer={"enumerate": "parallel"})
        P.sample("obs", dist.Normal(LOCS[z], SCALE), obs=data)


def empty_guide(data):
    pass


def _component_logprobs(data):
    """(N, K) log p(z=k) + log p(x_n | z=k) — the hand-computed joint."""
    return dist.Normal(LOCS, SCALE).log_prob(data[:, None]) + jnp.log(WEIGHTS)


# ---------------------------------------------------------------------------
# hand-computed marginal likelihood == TraceEnum_ELBO loss
# ---------------------------------------------------------------------------


def test_gmm_loss_matches_hand_marginal():
    loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, gmm, empty_guide, DATA)
    hand = -jnp.sum(jax.scipy.special.logsumexp(_component_logprobs(DATA), -1))
    assert abs(float(loss) - float(hand)) < 1e-5


def test_gmm_loss_matches_hand_marginalized_trace_elbo():
    """Enumeration == marginalizing by hand with MixtureSameFamily."""

    def marginalized(data):
        with P.plate("N", data.shape[0]):
            P.sample(
                "obs",
                dist.MixtureSameFamily(
                    dist.Categorical(WEIGHTS), dist.Normal(LOCS, SCALE)
                ),
                obs=data,
            )

    enum_loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, gmm, empty_guide, DATA)
    hand_loss = Trace_ELBO().loss(
        jax.random.PRNGKey(0), {}, marginalized, empty_guide, DATA
    )
    assert abs(float(enum_loss) - float(hand_loss)) < 1e-5


def test_traceenum_equals_trace_elbo_without_enumeration():
    def plain(data):
        loc = P.sample("loc", dist.Normal(0.0, 10.0))
        with P.plate("N", data.shape[0]):
            P.sample("obs", dist.Normal(loc, 1.0), obs=data)

    guide = AutoNormal(plain)
    svi = SVI(plain, guide, optim.Adam(0.01), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(0), DATA)
    params = svi.optim.get_params(state.optim_state)
    l1 = Trace_ELBO().loss(jax.random.PRNGKey(7), params, plain, guide, DATA)
    l2 = TraceEnum_ELBO().loss(jax.random.PRNGKey(7), params, plain, guide, DATA)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_global_latent_shared_across_plate():
    """Sum over the global latent must happen OUTSIDE the plate product."""

    def model(data):
        c = P.sample("c", dist.Bernoulli(0.3), infer={"enumerate": "parallel"})
        loc = jnp.where(c > 0, 2.0, -1.0)
        with P.plate("N", data.shape[0]):
            P.sample("obs", dist.Normal(loc, 1.0), obs=data)

    loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, empty_guide, DATA)
    lp0 = jnp.sum(dist.Normal(-1.0, 1.0).log_prob(DATA)) + jnp.log(0.7)
    lp1 = jnp.sum(dist.Normal(2.0, 1.0).log_prob(DATA)) + jnp.log(0.3)
    assert abs(float(loss) + float(jnp.logaddexp(lp0, lp1))) < 1e-5


def test_nested_plates_and_per_row_mixture():
    rng = np.random.default_rng(3)
    dat = jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)
    w, locs = jnp.asarray([0.3, 0.7]), jnp.asarray([-1.0, 1.0])

    @config_enumerate
    def rowmix(dat):
        with P.plate("rows", 3, dim=-2):
            c = P.sample("c", dist.Categorical(w))
            with P.plate("cols", 4, dim=-1):
                P.sample("x", dist.Normal(locs[c], 1.0), obs=dat)

    loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, rowmix, lambda dat: None, dat)
    row_lp = jnp.sum(dist.Normal(locs, 1.0).log_prob(dat[..., None]), axis=1)
    hand = -jnp.sum(jax.scipy.special.logsumexp(row_lp + jnp.log(w), -1))
    assert abs(float(loss) - float(hand)) < 1e-5


def test_markov_chain_matches_brute_force():
    T, K = 4, 3
    rng = np.random.default_rng(0)
    trans = jnp.asarray(rng.dirichlet(np.ones(K), size=K))
    init_p = jnp.asarray(rng.dirichlet(np.ones(K)))
    locs = jnp.asarray([-2.0, 0.0, 2.0])
    obs = jnp.asarray([-1.8, 0.2, 1.9, 2.1])

    @config_enumerate
    def hmm(obs):
        z = P.sample("z_0", dist.Categorical(init_p))
        P.sample("x_0", dist.Normal(locs[z], 1.0), obs=obs[0])
        for t in range(1, T):
            z = P.sample(f"z_{t}", dist.Categorical(trans[z]))
            P.sample(f"x_{t}", dist.Normal(locs[z], 1.0), obs=obs[t])

    loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, hmm, lambda obs: None, obs)
    total = -jnp.inf
    best, best_lp = None, -np.inf
    for zs in itertools.product(range(K), repeat=T):
        lp = jnp.log(init_p[zs[0]]) + dist.Normal(locs[zs[0]], 1.0).log_prob(obs[0])
        for t in range(1, T):
            lp = lp + jnp.log(trans[zs[t - 1], zs[t]])
            lp = lp + dist.Normal(locs[zs[t]], 1.0).log_prob(obs[t])
        total = jnp.logaddexp(total, lp)
        if float(lp) > best_lp:
            best, best_lp = list(zs), float(lp)
    assert abs(float(loss) + float(total)) < 1e-4

    # MAP decoding == brute-force Viterbi
    dec = infer_discrete(hmm, temperature=0, rng_key=jax.random.PRNGKey(2))
    tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(3))).get_trace(obs)
    assert [int(tr[f"z_{t}"]["value"]) for t in range(T)] == best


# ---------------------------------------------------------------------------
# infer_discrete: exact posterior over assignments
# ---------------------------------------------------------------------------


def test_discrete_marginals_exact():
    margs = discrete_marginals(gmm, jax.random.PRNGKey(1), DATA)
    hand = jax.nn.log_softmax(_component_logprobs(DATA), -1)
    np.testing.assert_allclose(np.asarray(margs["z"]), np.asarray(hand), atol=1e-6)


def test_marginals_with_global_local_coupling():
    """Marginal of a plate-local site must weight the global latent by the
    evidence from ALL slices (dice-factor gradient identity), not just its
    own slice."""
    n = 4
    data = jnp.asarray([-1.5, 0.3, 1.8, -0.2])
    pc = jnp.asarray([0.35, 0.65])
    pz_c = jnp.asarray([[0.8, 0.2], [0.3, 0.7]])
    locs = jnp.asarray([-1.0, 1.5])

    @config_enumerate
    def model(data):
        c = P.sample("c", dist.Categorical(pc))
        with P.plate("N", n):
            z = P.sample("z", dist.Categorical(pz_c[c]))
            P.sample("x", dist.Normal(locs[z], 1.0), obs=data)

    m = discrete_marginals(model, jax.random.PRNGKey(0), data)
    hand_c = jnp.asarray(
        [
            jnp.log(pc[c])
            + jnp.sum(
                jax.scipy.special.logsumexp(
                    jnp.log(pz_c[c]) + dist.Normal(locs, 1.0).log_prob(data[:, None]),
                    -1,
                )
            )
            for c in range(2)
        ]
    )
    hand_c = jax.nn.log_softmax(hand_c)
    np.testing.assert_allclose(np.asarray(m["c"]), np.asarray(hand_c), atol=1e-6)
    hand_z = sum(
        jnp.exp(hand_c[c])
        * jax.nn.softmax(
            jnp.log(pz_c[c]) + dist.Normal(locs, 1.0).log_prob(data[:, None]), -1
        )
        for c in range(2)
    )
    np.testing.assert_allclose(np.exp(np.asarray(m["z"])), np.asarray(hand_z), atol=1e-6)


def test_infer_discrete_map_assignments():
    dec = infer_discrete(gmm, temperature=0, rng_key=jax.random.PRNGKey(2))
    tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(3))).get_trace(DATA)
    expected = jnp.argmax(_component_logprobs(DATA), -1)
    np.testing.assert_array_equal(np.asarray(tr["z"]["value"]), np.asarray(expected))


def test_infer_discrete_sampling_frequencies():
    """temperature=1 draws from the exact posterior: empirical assignment
    frequencies converge to the hand posterior (vmapped over keys)."""

    def draw(key):
        dec = infer_discrete(gmm, temperature=1, rng_key=key)
        tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(0))).get_trace(DATA)
        return tr["z"]["value"]

    zs = jax.vmap(draw)(jax.random.split(jax.random.PRNGKey(4), 2000))
    freq1 = np.asarray((zs == 1).mean(0))
    post1 = np.exp(np.asarray(jax.nn.log_softmax(_component_logprobs(DATA), -1))[:, 1])
    np.testing.assert_allclose(freq1, post1, atol=0.05)


# ---------------------------------------------------------------------------
# jit stability + sharding
# ---------------------------------------------------------------------------


def gmm_learnable(data):
    w = P.param("w", jnp.asarray([0.5, 0.5]), constraint=dist.constraints.simplex)
    locs = P.param("locs", jnp.asarray([-0.5, 0.5]))
    with P.plate("N", data.shape[0]):
        z = P.sample("z", dist.Categorical(w), infer={"enumerate": "parallel"})
        P.sample("obs", dist.Normal(locs[z], SCALE), obs=data)


def test_compiles_exactly_once_across_steps():
    elbo = TraceEnum_ELBO()
    svi = SVI(gmm_learnable, empty_guide, optim.Adam(0.05), elbo)
    state = svi.init(jax.random.PRNGKey(0), DATA)
    elbo.num_traces = 0
    for i in range(12):
        # fresh same-shape data each step must reuse the compiled executable
        state, loss = svi.update_jit(state, DATA + 0.01 * i)
    assert elbo.num_traces == 1
    assert np.isfinite(float(loss))


def test_sharded_particles_bit_identical():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    guide = AutoNormal(latent_gmm)

    def run(elbo, svi_mesh):
        svi = SVI(latent_gmm, guide, optim.Adam(0.05), elbo, mesh=svi_mesh)
        state = svi.init(jax.random.PRNGKey(0), DATA)
        for _ in range(5):
            state, loss = svi.update_jit(state, DATA)
        return float(loss)

    loss_plain = run(TraceEnum_ELBO(num_particles=4), None)
    loss_shard = run(
        TraceEnum_ELBO(num_particles=4, mesh=mesh, particle_axis="data"), mesh
    )
    assert loss_plain == loss_shard  # bit-identical on a 1-device mesh


def latent_gmm(data):
    locs = P.sample("locs", dist.Normal(0.0, 5.0).expand((2,)).to_event(1))
    with P.plate("N", data.shape[0]):
        z = P.sample("z", dist.Categorical(WEIGHTS), infer={"enumerate": "parallel"})
        P.sample("obs", dist.Normal(locs[z], SCALE), obs=data)


def test_svi_with_autoguide_learns_gmm():
    """AutoNormal skips enumerated sites; TraceEnum_ELBO marginalizes them."""
    rng = np.random.default_rng(1)
    data = jnp.concatenate(
        [
            jnp.asarray(rng.normal(-1.0, 0.5, 30), jnp.float32),
            jnp.asarray(rng.normal(2.0, 0.5, 60), jnp.float32),
        ]
    )
    guide = AutoNormal(latent_gmm)
    svi = SVI(latent_gmm, guide, optim.Adam(0.05), TraceEnum_ELBO(num_particles=2))
    state = svi.init(jax.random.PRNGKey(0), data)
    first = None
    for i in range(150):
        state, loss = svi.update_jit(state, data)
        if first is None:
            first = float(loss)
    assert float(loss) < first
    locs = sorted(np.asarray(svi.get_params(state)["auto_locs_loc"]).tolist())
    assert abs(locs[0] - (-1.0)) < 0.4 and abs(locs[1] - 2.0) < 0.4


# ---------------------------------------------------------------------------
# messenger mechanics + error paths
# ---------------------------------------------------------------------------


def test_enum_messenger_allocates_dims_left_of_plates():
    with handlers.enum(first_available_dim=-2):
        tr = handlers.trace(handlers.seed(gmm, jax.random.PRNGKey(0))).get_trace(DATA)
    site = tr["z"]
    assert site["infer"]["_enumerate_dim"] == -2
    assert site["infer"]["_enumerate_cardinality"] == 2
    assert site["value"].shape == (2, 1)  # enum dim left of the plate dim
    assert tr["obs"]["fn"].log_prob(tr["obs"]["value"]).shape == (2, 5)


def test_config_enumerate_annotates_discrete_only():
    def model():
        P.sample("z", dist.Bernoulli(0.5))
        P.sample("x", dist.Normal(0.0, 1.0))
        P.sample("y", dist.Bernoulli(0.5), infer={"enumerate": "sequential"})

    tr = handlers.trace(
        handlers.seed(config_enumerate(model), jax.random.PRNGKey(0))
    ).get_trace()
    assert tr["z"]["infer"]["enumerate"] == "parallel"
    assert "enumerate" not in tr["x"]["infer"]
    assert tr["y"]["infer"]["enumerate"] == "sequential"  # explicit wins


def test_infinite_support_raises_actionable_error():
    def model(data):
        P.sample("g", dist.Geometric(0.5), infer={"enumerate": "parallel"})

    with pytest.raises(NotImplementedError, match="truncate"):
        TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, empty_guide, DATA)


def test_guide_side_enumeration_raises():
    def model(data):
        P.sample("z", dist.Bernoulli(0.5), infer={"enumerate": "parallel"})

    def guide(data):
        P.sample("z", dist.Bernoulli(0.5), infer={"enumerate": "parallel"})

    with pytest.raises(NotImplementedError, match="guide"):
        TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, guide, DATA)


def test_sequential_strategy_raises():
    def model(data):
        P.sample("z", dist.Bernoulli(0.5), infer={"enumerate": "sequential"})

    with pytest.raises(NotImplementedError, match="parallel"):
        TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, empty_guide, DATA)


def test_subsample_scale_outside_enum_logsumexp():
    """Minibatch scale must multiply the marginalized per-slice density:
    s*logsumexp(lp), never logsumexp(s*lp)."""
    data = jnp.asarray([-0.4, 0.1, 0.5, -0.2, 0.3, 0.0, -0.1, 0.6])
    w, locs = jnp.asarray([0.4, 0.6]), jnp.asarray([-0.5, 0.5])

    def gmm_sub(data):
        with P.plate("N", 8, subsample_size=4) as idx:
            z = P.sample("z", dist.Categorical(w), infer={"enumerate": "parallel"})
            P.sample("obs", dist.Normal(locs[z], 1.0), obs=data[idx])

    model = handlers.substitute(gmm_sub, data={"N": jnp.arange(4)})
    loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, empty_guide, data)
    comp = dist.Normal(locs, 1.0).log_prob(data[:4, None]) + jnp.log(w)
    correct = -2.0 * jnp.sum(jax.scipy.special.logsumexp(comp, -1))
    wrong = -jnp.sum(jax.scipy.special.logsumexp(2.0 * comp, -1))
    assert abs(float(correct) - float(wrong)) > 0.1  # forms genuinely differ here
    assert abs(float(loss) - float(correct)) < 1e-5


def test_masked_enumerated_site_contributes_zero():
    """A masked-out enumerated site is neutral (0), not +log K."""

    def fully_masked(data):
        with handlers.mask(mask=False):
            P.sample("z", dist.Bernoulli(0.5), infer={"enumerate": "parallel"})

    loss = TraceEnum_ELBO().loss(
        jax.random.PRNGKey(0), {}, fully_masked, empty_guide, DATA
    )
    assert abs(float(loss)) < 1e-7

    def masked_obs(data):
        with P.plate("N", 4):
            z = P.sample("z", dist.Categorical(WEIGHTS), infer={"enumerate": "parallel"})
            with handlers.mask(mask=False):
                P.sample("obs", dist.Normal(LOCS[z], 0.5), obs=data[:4])

    loss = TraceEnum_ELBO().loss(
        jax.random.PRNGKey(0), {}, masked_obs, empty_guide, DATA
    )
    assert abs(float(loss)) < 1e-6  # z marginalizes to exactly 1


def test_masked_distribution_wrapper_is_neutral_too():
    """.mask() on an enumerated site must behave like handlers.mask: a
    masked-out slice contributes 0, not +log K."""
    m = jnp.asarray([True, False, True, True])

    locs = jnp.asarray([-1.0, 2.0])

    def model(data):
        with P.plate("N", 4):
            z = P.sample(
                "z", dist.Bernoulli(0.4).mask(m), infer={"enumerate": "parallel"}
            )
            loc = locs[jnp.asarray(z, jnp.int32)]
            P.sample("obs", dist.Normal(loc, 0.5).mask(m), obs=data[:4])

    def via_handler(data):
        with P.plate("N", 4):
            with handlers.mask(mask=m):
                z = P.sample("z", dist.Bernoulli(0.4), infer={"enumerate": "parallel"})
                loc = locs[jnp.asarray(z, jnp.int32)]
                P.sample("obs", dist.Normal(loc, 0.5), obs=data[:4])

    l1 = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, empty_guide, DATA)
    l2 = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, via_handler, empty_guide, DATA)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_plain_elbos_reject_unconsumed_enumerate_annotation():
    """An enumerate-annotated model latent absent from the guide must fail
    loudly under Trace_ELBO-family estimators instead of silently training a
    wrong (prior-sampled) objective."""

    def model(data):
        P.sample("z", dist.Bernoulli(0.5), infer={"enumerate": "parallel"})

    with pytest.raises(ValueError, match="TraceEnum_ELBO"):
        Trace_ELBO().loss(jax.random.PRNGKey(0), {}, model, empty_guide, DATA)


def test_infer_discrete_fresh_draws_from_ambient_seed():
    """Without an explicit rng_key, the decode keys off the enclosing seed
    handler — different seeds give different posterior draws."""

    def gmm_wide(data):
        with P.plate("N", data.shape[0]):
            z = P.sample("z", dist.Categorical(WEIGHTS), infer={"enumerate": "parallel"})
            P.sample("obs", dist.Normal(LOCS[z], 1.5), obs=data)

    dec = infer_discrete(gmm_wide, temperature=1)
    draws = set()
    for s in range(8):
        tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(s))).get_trace(
            jnp.zeros(5)
        )
        draws.add(tuple(int(v) for v in tr["z"]["value"]))
    assert len(draws) > 1


def test_shared_infer_dict_across_sites():
    """A single infer= dict reused by several sites must not alias the
    per-site enum dim bookkeeping (make_message copies it)."""
    cfg = {"enumerate": "parallel"}

    def shared():
        a = P.sample("a", dist.Bernoulli(0.3), infer=cfg)
        b = P.sample("b", dist.Bernoulli(0.9), infer=cfg)
        P.sample("obs", dist.Normal(a + 2 * b, 0.5), obs=2.0)

    def literal():
        a = P.sample("a", dist.Bernoulli(0.3), infer={"enumerate": "parallel"})
        b = P.sample("b", dist.Bernoulli(0.9), infer={"enumerate": "parallel"})
        P.sample("obs", dist.Normal(a + 2 * b, 0.5), obs=2.0)

    l1 = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, shared, lambda: None)
    l2 = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, literal, lambda: None)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-7)
    assert "enumerate" in cfg and "_enumerate_dim" not in cfg


def test_infer_discrete_pins_free_continuous_latents():
    """The replayed execution must be one coherent joint draw: discrete
    decodes are conditioned on the SAME continuous values the caller sees."""

    def model():
        mu = P.sample("mu", dist.Normal(0.0, 10.0))
        z = P.sample(
            "z", dist.Bernoulli(jax.nn.sigmoid(mu)), infer={"enumerate": "parallel"}
        )
        P.sample("obs", dist.Normal(z * mu, 0.1), obs=0.0)

    dec = infer_discrete(model, temperature=0, rng_key=jax.random.PRNGKey(0))
    tr = handlers.trace(handlers.seed(dec, jax.random.PRNGKey(7))).get_trace()
    mu, z = float(tr["mu"]["value"]), float(tr["z"]["value"])
    lp1 = float(jax.nn.log_sigmoid(jnp.asarray(mu)) + dist.Normal(mu, 0.1).log_prob(0.0))
    lp0 = float(jax.nn.log_sigmoid(jnp.asarray(-mu)) + dist.Normal(0.0, 0.1).log_prob(0.0))
    assert z == float(lp1 > lp0)  # MAP given the RETURNED mu, not a stale draw


def test_binomial_enumeration():
    """Binomial's finite support enumerates: marginal over {0..3} by hand."""
    p_z, p_obs = 0.3, jnp.asarray([0.1, 0.3, 0.6, 0.9])

    def model():
        z = P.sample("z", dist.Binomial(3, probs=p_z), infer={"enumerate": "parallel"})
        P.sample("obs", dist.Bernoulli(p_obs[jnp.asarray(z, jnp.int32)]), obs=1.0)

    loss = TraceEnum_ELBO().loss(jax.random.PRNGKey(0), {}, model, lambda: None)
    zs = jnp.arange(4.0)
    hand = jax.scipy.special.logsumexp(
        dist.Binomial(3, probs=p_z).log_prob(zs) + jnp.log(p_obs)
    )
    assert abs(float(loss) + float(hand)) < 1e-6
