"""Posterior-serving engine: bucketing, compile-once-per-bucket, padding
neutrality, batch-axis discovery, Predictive's jit cache, the ServableModel
registry, and mesh parity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributions as dist, optim
from repro.core import primitives as P
from repro.infer import SVI, AutoNormal, Trace_ELBO, Predictive
from repro.serve import (
    CompiledServable,
    ServableModel,
    bucket_for,
    clear_registry,
    default_buckets,
    get_servable,
    list_servables,
    register,
    unregister,
)

DIM = 3


def regression_model(x, y=None):
    w = P.sample("w", dist.Normal(jnp.zeros(DIM), 1.0).to_event(1))
    b = P.sample("b", dist.Normal(0.0, 1.0))
    with P.plate("B", x.shape[0]):
        mu = P.deterministic("mu", x @ w + b)
        P.sample("y", dist.Normal(mu, 0.1), obs=y)


@pytest.fixture(scope="module")
def artifact():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, DIM))
    y = x @ jnp.arange(1.0, DIM + 1.0) + 0.5
    guide = AutoNormal(regression_model)
    svi = SVI(regression_model, guide, optim.Adam(0.05), Trace_ELBO())
    state, _ = svi.run(jax.random.PRNGKey(1), 30, x, y=y)
    params = svi.optim.get_params(state.optim_state)
    return guide, params


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_default_buckets_powers_of_two():
    assert default_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    assert default_buckets(1) == (1,)
    assert default_buckets(24) == (1, 2, 4, 8, 16, 24)  # non-pow2 max kept


def test_bucket_for_picks_smallest_fit():
    buckets = (1, 2, 4, 8)
    assert bucket_for(1, buckets) == 1
    assert bucket_for(3, buckets) == 4
    assert bucket_for(8, buckets) == 8
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        bucket_for(9, buckets)


def test_default_buckets_rejects_nonpositive():
    with pytest.raises(ValueError):
        default_buckets(0)


# ---------------------------------------------------------------------------
# CompiledServable
# ---------------------------------------------------------------------------


def test_compiles_bounded_by_buckets_not_request_sizes():
    def fn(key, batch):
        return {"y": batch["x"] * 2.0}

    eng = CompiledServable(fn, max_batch=16)
    for n in (1, 3, 4, 5, 6, 7, 2, 8, 3, 5):  # 8 distinct sizes, 4 buckets
        eng(jax.random.PRNGKey(n), {"x": jnp.arange(float(n))})
    assert sorted(eng.buckets_touched) == [1, 2, 4, 8]
    assert eng.num_traces == len(eng.buckets_touched) == 4


def test_padding_is_invisible_to_callers():
    """Result rows of a padded batch == result of the exact-size batch."""

    def fn(key, batch):
        return {"y": jnp.cumsum(batch["x"]) * 0 + batch["x"] * 3.0}

    eng = CompiledServable(fn, buckets=[8])
    x = jnp.arange(5.0)
    out = eng(jax.random.PRNGKey(0), {"x": x})
    np.testing.assert_allclose(np.asarray(out["y"]), np.asarray(x * 3.0))
    assert out["y"].shape == (5,)


def test_global_output_leaves_returned_whole():
    def fn(key, batch):
        return {"rows": batch["x"] + 1.0, "global": jnp.full(7, 2.0)}

    eng = CompiledServable(fn, max_batch=8)
    out = eng(jax.random.PRNGKey(0), {"x": jnp.zeros((3, 2))})
    assert out["rows"].shape == (3, 2)
    assert out["global"].shape == (7,)  # not sliced


def test_non_leading_batch_axis_discovered():
    """Outputs whose batch axis is not axis 0 (e.g. (draws, batch)) slice on
    the right axis."""

    def fn(key, batch):
        return {"draws": jnp.zeros((5,))[:, None] + batch["x"][None, :]}

    eng = CompiledServable(fn, max_batch=8)
    out = eng(jax.random.PRNGKey(0), {"x": jnp.arange(3.0)})
    assert out["draws"].shape == (5, 3)


def test_explicit_out_batch_axes_override():
    def fn(key, batch):
        return {"y": batch["x"]}

    eng = CompiledServable(fn, max_batch=4, out_batch_axes={"y": 0})
    out = eng(jax.random.PRNGKey(0), {"x": jnp.arange(3.0)})
    assert out["y"].shape == (3,)


def test_mismatched_leading_dims_rejected():
    eng = CompiledServable(lambda k, b: b, max_batch=4)
    with pytest.raises(ValueError, match="disagree"):
        eng(jax.random.PRNGKey(0), {"a": jnp.zeros(2), "b": jnp.zeros(3)})


def test_oversized_batch_rejected():
    eng = CompiledServable(lambda k, b: b, max_batch=4)
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        eng(jax.random.PRNGKey(0), {"x": jnp.zeros(5)})


def test_same_bucket_same_key_rows_bit_identical():
    """Within one bucket, a request's rows don't depend on the co-padded
    row count: bucket shape fixes the randomness layout."""

    def fn(key, batch):
        noise = jax.random.normal(key, batch["x"].shape)
        return {"y": batch["x"] + noise}

    eng = CompiledServable(fn, buckets=[4])
    key = jax.random.PRNGKey(3)
    a = eng(key, {"x": jnp.ones(2)})["y"]
    b = eng(key, {"x": jnp.ones(3)})["y"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b[:2]))


# ---------------------------------------------------------------------------
# Predictive compile-once
# ---------------------------------------------------------------------------


def test_predictive_jit_cache_stable(artifact):
    guide, params = artifact
    pred = Predictive(regression_model, guide=guide, params=params, num_samples=5)
    x = jnp.ones((4, DIM))
    assert pred.num_traces == 0
    out1 = pred(jax.random.PRNGKey(0), x)
    for i in range(5):  # fresh same-shape data: no retrace
        pred(jax.random.PRNGKey(i), x + i)
    assert pred.num_traces == 1
    pred(jax.random.PRNGKey(9), jnp.ones((6, DIM)))  # new shape: one more
    assert pred.num_traces == 2
    assert out1["mu"].shape == (5, 4)


def test_predictive_jit_matches_eager(artifact):
    guide, params = artifact
    x = jnp.ones((4, DIM))
    key = jax.random.PRNGKey(42)
    jitted = Predictive(regression_model, guide=guide, params=params, num_samples=3)
    eager = Predictive(regression_model, guide=guide, params=params, num_samples=3,
                       jit_compile=False)
    o1, o2 = jitted(key, x), eager(key, x)
    assert eager.num_traces == 0
    for k in o1:
        np.testing.assert_allclose(
            np.asarray(o1[k]), np.asarray(o2[k]), rtol=1e-6, atol=1e-6
        )


def test_predictive_static_scalar_args_stay_concrete():
    """Non-array args (plate-size ints) must stay static under the jit —
    the pre-review regression was a TracerBoolConversionError here."""

    def model_n(n):
        with P.plate("N", n):
            P.sample("obs", dist.Normal(0.0, 1.0))

    pred = Predictive(model_n, num_samples=2)
    out = pred(jax.random.PRNGKey(0), 4)
    assert out["obs"].shape == (2, 4)
    pred(jax.random.PRNGKey(1), 4)
    assert pred.num_traces == 1
    pred(jax.random.PRNGKey(2), 5)  # changed static value: one fresh trace
    assert pred.num_traces == 2


def test_predictive_params_refresh_no_retrace():
    """Updating pred.params (a checkpoint refresh) must take effect on the
    next call WITHOUT retracing — params ride the traced signature."""

    def model(x=None):
        w = P.param("w", jnp.asarray(0.0))
        P.sample("y", dist.Normal(w, 0.01))

    pred = Predictive(model, guide=lambda x=None: None,
                      params={"w": jnp.asarray(1.0)}, num_samples=3)
    o1 = pred(jax.random.PRNGKey(0))
    pred.params = {"w": jnp.asarray(100.0)}
    o2 = pred(jax.random.PRNGKey(0))
    assert abs(float(o1["y"][0]) - 1.0) < 0.5
    assert abs(float(o2["y"][0]) - 100.0) < 0.5
    assert pred.num_traces == 1


def test_predictive_varying_float_arg_no_cache_growth():
    """Python floats are DATA: a per-request temperature must ride the
    traced signature, not mint one executable per value."""

    def model(scale):
        P.sample("y", dist.Normal(0.0, scale))

    pred = Predictive(model, num_samples=2)
    for s in (0.5, 1.0, 2.0, 3.5):
        pred(jax.random.PRNGKey(0), s)
    assert pred.num_traces == 1


def test_zero_row_request_rejected_cleanly():
    eng = CompiledServable(lambda k, b: b, max_batch=4)
    with pytest.raises(ValueError, match="0 rows"):
        eng(jax.random.PRNGKey(0), {"x": jnp.zeros((0, 3))})


def test_predictive_posterior_samples_jitted():
    def model(data=None):
        loc = P.sample("loc", dist.Normal(0.0, 1.0))
        with P.plate("N", 3):
            P.sample("obs", dist.Normal(loc, 1.0), obs=data)

    post = {"loc": jnp.linspace(-1, 1, 5)}
    pred = Predictive(model, posterior_samples=post)
    out = pred(jax.random.PRNGKey(0))
    assert out["obs"].shape == (5, 3)
    pred(jax.random.PRNGKey(1))
    assert pred.num_traces == 1


# ---------------------------------------------------------------------------
# ServableModel + registry
# ---------------------------------------------------------------------------


def test_from_svi_matches_direct_predictive(artifact):
    guide, params = artifact
    sm = ServableModel.from_svi("m", regression_model, guide, params,
                                num_samples=4, buckets=[4])
    x = jax.random.normal(jax.random.PRNGKey(5), (4, DIM))
    key = jax.random.PRNGKey(6)
    served = sm.predict(key, x)
    direct = Predictive(regression_model, guide=guide, params=params,
                        num_samples=4)(key, x)
    for k in direct:
        np.testing.assert_allclose(
            np.asarray(served[k]), np.asarray(direct[k]), rtol=1e-6, atol=1e-6
        )


def test_from_mcmc_chain_shaped():
    """Chain-grouped MCMC samples fan out per request row; the sample store
    itself is a global (unsliced) output leaf."""

    def reg(x):
        loc = P.sample("loc", dist.Normal(0.0, 1.0))
        with P.plate("B", x.shape[0]):
            P.sample("obs", dist.Normal(loc + x, 1.0))

    sm = ServableModel.from_mcmc("mc", reg, {"loc": jnp.zeros((2, 5))},
                                 batch_ndims=2, max_batch=4)
    out = sm.predict(jax.random.PRNGKey(1), jnp.arange(3.0))
    assert out["obs"].shape == (2, 5, 3)
    assert out["loc"].shape == (2, 5)  # global leaf: not sliced
    sm.predict(jax.random.PRNGKey(2), jnp.arange(4.0))  # same bucket
    assert sm.num_traces == 1


def test_from_discrete_decoder_gmm():
    locs = jnp.asarray([-2.0, 3.0])

    def gmm(data):
        with P.plate("N", data.shape[0]):
            z = P.sample("z", dist.Categorical(jnp.asarray([0.5, 0.5])),
                         infer={"enumerate": "parallel"})
            P.sample("obs", dist.Normal(locs[z], 0.5), obs=data)

    sm = ServableModel.from_discrete("dec", gmm, temperature=0, max_batch=8)
    data = jnp.asarray([-2.1, -1.9, 3.2, 2.8, -2.0])
    out = sm.predict(jax.random.PRNGKey(0), data)
    np.testing.assert_array_equal(np.asarray(out["z"]), [0, 0, 1, 1, 0])
    # compile-once: one more size in the same bucket
    sm.predict(jax.random.PRNGKey(1), data[:4])
    assert sm.num_traces == len(sm.buckets_touched)


def test_from_checkpoint_warm_start(artifact, tmp_path):
    from repro.checkpoint import store

    guide, params = artifact
    store.save(str(tmp_path), 7, {"params": params})
    sm = ServableModel.from_checkpoint(
        "warm", regression_model, str(tmp_path),
        guide=AutoNormal(regression_model), num_samples=4, buckets=[4],
        # fresh autoguide: show it the model in TRAINING configuration (y
        # observed) via dummy args, or it would treat y as a latent
        guide_args=(jnp.zeros((1, DIM)),),
        guide_kwargs={"y": jnp.zeros(1)},
    )
    assert sm.restored_step == 7
    assert sm.kind == "checkpoint"
    x = jax.random.normal(jax.random.PRNGKey(5), (4, DIM))
    key = jax.random.PRNGKey(6)
    served = sm.predict(key, x)
    direct = ServableModel.from_svi("direct", regression_model, guide, params,
                                    num_samples=4, buckets=[4]).predict(key, x)
    for k in direct:
        np.testing.assert_allclose(
            np.asarray(served[k]), np.asarray(direct[k]), rtol=1e-5, atol=1e-5
        )


def test_refresh_hot_swaps_artifact_without_recompile(artifact):
    """A same-shaped params refresh must change served outputs immediately
    while keeping compiles == buckets (state rides the jit signature, it is
    not baked into the bucket executables)."""
    guide, params = artifact
    sm = ServableModel.from_svi("hot", regression_model, guide, params,
                                num_samples=4, buckets=[4])
    x = jnp.ones((3, DIM))
    key = jax.random.PRNGKey(0)
    before = sm.predict(key, x)
    shifted = jax.tree.map(lambda p: p + 1.0, params)
    sm.refresh(params=shifted)
    after = sm.predict(key, x)
    assert sm.num_traces == 1  # refresh did not recompile
    assert not np.allclose(np.asarray(before["mu"]), np.asarray(after["mu"]))
    with pytest.raises(KeyError, match="unknown state key"):
        sm.refresh(samples={})
    stateless = ServableModel("raw", lambda k, b: {"y": b}, buckets=[4])
    with pytest.raises(ValueError, match="no artifact state"):
        stateless.refresh(params={})


def test_registry_roundtrip(artifact):
    guide, params = artifact
    clear_registry()
    sm = ServableModel.from_svi("reg-a", regression_model, guide, params)
    register(sm)
    assert get_servable("reg-a") is sm
    assert list_servables() == ["reg-a"]
    with pytest.raises(ValueError, match="already registered"):
        register(ServableModel.from_svi("reg-a", regression_model, guide, params))
    register(ServableModel.from_svi("reg-a", regression_model, guide, params),
             replace=True)
    with pytest.raises(KeyError, match="no servable"):
        get_servable("nope")
    unregister("reg-a")
    assert list_servables() == []


# ---------------------------------------------------------------------------
# mesh parity
# ---------------------------------------------------------------------------


def test_sharded_serving_bit_identical_on_one_device(artifact):
    from repro.distributed.sharding import default_mesh

    guide, params = artifact
    x = jax.random.normal(jax.random.PRNGKey(7), (6, DIM))
    key = jax.random.PRNGKey(8)
    plain = ServableModel.from_svi("p", regression_model, guide, params,
                                   num_samples=4, max_batch=8)
    sharded = ServableModel.from_svi("s", regression_model, guide, params,
                                     num_samples=4, max_batch=8,
                                     mesh=default_mesh())
    o1, o2 = plain.predict(key, x), sharded.predict(key, x)
    for a, b in zip(jax.tree_util.tree_leaves(o1), jax.tree_util.tree_leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_donate_false_on_cpu_by_default():
    eng = CompiledServable(lambda k, b: b, max_batch=4)
    assert eng.donate == (jax.default_backend() != "cpu")
    if os.environ.get("JAX_PLATFORMS", "cpu") == "cpu":
        assert not eng.donate
    # forced donation still returns correct results (pad copy is engine-owned)
    eng2 = CompiledServable(lambda k, b: {"y": b["x"] + 1}, buckets=[4], donate=True)
    x = jnp.arange(4.0)  # exact bucket size: pad copy must still protect x
    out = eng2(jax.random.PRNGKey(0), {"x": x})
    np.testing.assert_array_equal(np.asarray(x), np.arange(4.0))
    assert out["y"].shape == (4,)
