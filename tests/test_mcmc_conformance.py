"""Sampler conformance suite: statistical correctness of the fused HMC/NUTS
engine on closed-form targets, under BOTH kernel backends.

A raw-speed rewrite of a sampler is only trustworthy if its *distribution* is
pinned, not just its wall clock. This suite is the sampler analogue of the
scipy distribution-conformance suite from the enumeration PR:

* exact single/multi-step fused-vs-reference leapfrog parity (the kernel
  computes the same trajectory as the independent pure-jnp oracle);
* Kolmogorov–Smirnov tests of sampled marginals against the exact CDFs;
* moment checks against closed-form means/variances/covariances;
* split-R̂ / ESS thresholds so a sampler that is "correct but mixing
  pathologically" still fails.

Every sampling test runs once per kernel backend (``reference`` = pure jnp,
``interpret`` = the Pallas kernel body executed as XLA ops), so the fused
Pallas path and its oracle both face the same statistical bar. Seeds are
fixed; thresholds are set with enough slack that the suite is deterministic,
but tight enough that a sign error, a wrong half-step, or a broken
mass-matrix freeze fails loudly.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.stats

from repro.infer import HMC, MCMC, NUTS, effective_sample_size, split_rhat
from repro.kernels import ops

BACKENDS = ["reference", "interpret"]


@pytest.fixture(params=BACKENDS)
def backend(request, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", request.param)
    return request.param


# ---------------------------------------------------------------------------
# kernel-level parity: fused Pallas leapfrog vs the pure-jnp oracle
# ---------------------------------------------------------------------------


def _quadratic_pe():
    # anisotropic quadratic with a captured-constant data term: exercises the
    # closure-conversion path (consts become kernel inputs)
    data = jnp.asarray([0.3, -1.2, 0.7])

    def pe(z):
        return 0.5 * jnp.sum(jnp.square(z) * jnp.arange(1.0, z.shape[0] + 1)) + jnp.sum(
            data
        ) * jnp.sum(z) * 0.01

    return pe


def test_leapfrog_single_step_parity():
    """One leapfrog step, fused (interpret) vs reference, tight tolerance —
    the integrator algebra itself, no Metropolis randomness in the way."""
    pe = _quadratic_pe()
    C, D = 5, 4
    z = jax.random.normal(jax.random.PRNGKey(0), (C, D))
    r = jax.random.normal(jax.random.PRNGKey(1), (C, D))
    inv_mass = jnp.full((C, D), 0.7)
    eps = jnp.full((C,), 0.1)
    n = jnp.ones((C,), jnp.int32)
    out_ref = ops.leapfrog(z, r, inv_mass, eps, n, pe, max_steps=4, backend="reference")
    out_int = ops.leapfrog(z, r, inv_mass, eps, n, pe, max_steps=4, backend="interpret")
    for a, b in zip(out_ref, out_int):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_leapfrog_multi_step_parity_with_masks():
    """Ragged per-chain step counts (including frozen chains and a negative
    step size) agree between backends; frozen chains pass through exactly."""
    pe = _quadratic_pe()
    C, D = 6, 4
    z = jax.random.normal(jax.random.PRNGKey(2), (C, D))
    r = jax.random.normal(jax.random.PRNGKey(3), (C, D))
    inv_mass = jnp.ones((C, D))
    eps = jnp.full((C,), 0.05).at[2].set(-0.05)
    n = jnp.asarray([7, 0, 3, 1, 5, 2], jnp.int32)
    out_ref = ops.leapfrog(z, r, inv_mass, eps, n, pe, max_steps=8, backend="reference")
    out_int = ops.leapfrog(z, r, inv_mass, eps, n, pe, max_steps=8, backend="interpret")
    for a, b in zip(out_ref, out_int):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    # frozen chain (n == 0): position/momentum unchanged bit-for-bit
    assert jnp.array_equal(out_int[0][1], z[1])
    assert jnp.array_equal(out_int[1][1], r[1])


def test_leapfrog_energy_conservation():
    """A small-step trajectory on a quadratic potential conserves the
    Hamiltonian to O(eps^2) — the classic symplectic-integrator check; a
    misplaced half-kick breaks it immediately."""
    def pe(z):
        return 0.5 * jnp.sum(jnp.square(z))

    C, D = 4, 3
    z = jax.random.normal(jax.random.PRNGKey(4), (C, D))
    r = jax.random.normal(jax.random.PRNGKey(5), (C, D))
    inv_mass = jnp.ones((C, D))
    e0 = jax.vmap(pe)(z) + 0.5 * jnp.sum(r * r, axis=-1)
    z1, r1, pe1 = ops.leapfrog(
        z, r, inv_mass, jnp.full((C,), 0.01), jnp.full((C,), 100, jnp.int32),
        pe, max_steps=128, backend="interpret",
    )
    e1 = pe1 + 0.5 * jnp.sum(r1 * r1, axis=-1)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-3)


# ---------------------------------------------------------------------------
# closed-form targets
# ---------------------------------------------------------------------------


def _run(kernel, num_warmup, num_samples, num_chains, seed, init):
    mcmc = MCMC(
        kernel, num_warmup=num_warmup, num_samples=num_samples,
        num_chains=num_chains, fused=True,
    )
    mcmc.run(jax.random.PRNGKey(seed), init_params=init)
    return mcmc


def _ks_normal(draws, loc=0.0, scale=1.0, subsample=4):
    """KS test against N(loc, scale) on a thinned slice (KS assumes iid;
    MCMC draws carry some autocorrelation, so test every `subsample`-th)."""
    flat = np.asarray(draws).reshape(-1)[::subsample]
    return scipy.stats.kstest(flat, "norm", args=(loc, scale)).pvalue


def test_standard_normal_hmc(backend):
    def pe(z):
        return 0.5 * jnp.sum(jnp.square(z["x"]))

    kern = HMC(potential_fn=pe, adapt_trajectory_length=True, max_num_steps=64)
    mcmc = _run(kern, 300, 400, 4, seed=0, init={"x": jnp.zeros(2)})
    x = mcmc.get_samples(group_by_chain=True)["x"]  # (4, 400, 2)
    assert float(jnp.abs(x.mean())) < 0.1
    assert abs(float(x.std()) - 1.0) < 0.1
    for d in range(2):
        assert _ks_normal(x[..., d]) > 1e-3
        assert float(split_rhat(x[..., d])) < 1.05
        assert float(effective_sample_size(x[..., d])) > 100
    assert int(mcmc.get_extra_fields()["diverging"].sum()) == 0


def test_standard_normal_nuts(backend):
    def pe(z):
        return 0.5 * jnp.sum(jnp.square(z["x"]))

    kern = NUTS(potential_fn=pe, max_tree_depth=5)
    mcmc = _run(kern, 200, 300, 4, seed=1, init={"x": jnp.zeros(2)})
    x = mcmc.get_samples(group_by_chain=True)["x"]
    assert float(jnp.abs(x.mean())) < 0.1
    assert abs(float(x.std()) - 1.0) < 0.1
    for d in range(2):
        assert _ks_normal(x[..., d]) > 1e-3
        assert float(split_rhat(x[..., d])) < 1.05
    assert float(effective_sample_size(x[..., 0])) > 100


def test_correlated_mvn_hmc(backend):
    """2-D zero-mean Gaussian with corr 0.8: exact covariance is known, and
    each marginal is standard normal (KS-testable)."""
    rho = 0.8
    prec = jnp.linalg.inv(jnp.asarray([[1.0, rho], [rho, 1.0]]))

    def pe(z):
        x = z["x"]
        return 0.5 * x @ prec @ x

    kern = HMC(potential_fn=pe, adapt_trajectory_length=True, max_num_steps=64)
    mcmc = _run(kern, 400, 500, 4, seed=2, init={"x": jnp.zeros(2)})
    x = mcmc.get_samples(group_by_chain=True)["x"]
    flat = np.asarray(x).reshape(-1, 2)
    cov = np.cov(flat.T)
    np.testing.assert_allclose(cov, [[1.0, rho], [rho, 1.0]], atol=0.15)
    for d in range(2):
        assert _ks_normal(x[..., d]) > 1e-3
        assert float(split_rhat(x[..., d])) < 1.05


def test_funnel_like_hierarchical_nuts(backend):
    """Mild funnel: v ~ N(0,1), x_i | v ~ N(0, exp(v/2)) for i<2. The exact
    marginal of v is N(0,1) (KS-testable) and E[x^2] = E[e^v] = e^{1/2} —
    the hierarchical geometry NUTS's adaptive trajectories are for."""
    def pe(z):
        v, x = z["v"], z["x"]
        # -log p: prior on v + per-component N(0, exp(v/2)) on x
        return 0.5 * v * v + jnp.sum(0.5 * x * x * jnp.exp(-v) + 0.5 * v)

    kern = NUTS(potential_fn=pe, max_tree_depth=6, target_accept_prob=0.9)
    mcmc = _run(kern, 400, 600, 4, seed=3, init={"v": jnp.zeros(()), "x": jnp.zeros(2)})
    v = mcmc.get_samples(group_by_chain=True)["v"]
    x = mcmc.get_samples(group_by_chain=True)["x"]
    assert _ks_normal(v, subsample=6) > 1e-3
    assert float(jnp.abs(v.mean())) < 0.15
    assert abs(float(v.std()) - 1.0) < 0.2
    assert abs(float(jnp.mean(jnp.square(x))) - float(np.exp(0.5))) < 0.5
    assert float(split_rhat(v)) < 1.1
    assert float(effective_sample_size(v)) > 50
    # divergences allowed in a funnel, but not rampant
    div = mcmc.get_extra_fields()["diverging"]
    assert float(div.mean()) < 0.05


def test_fused_backend_marginals_agree(backend):
    """The backend knob changes the execution path, not the distribution:
    posterior moments from this backend match the exact values used above,
    and the resolved backend really is the one requested."""
    assert ops.resolve_backend(None) == backend
    assert os.environ["REPRO_KERNEL_BACKEND"] == backend
