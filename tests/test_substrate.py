"""Substrate tests: checkpoint atomicity/resume/gc, data determinism +
host sharding, watchdog, gradient compression, elastic re-mesh planning,
sharding rule resolution."""
import os

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements.txt)",
)
from hypothesis import given, settings, strategies as st

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataConfig, SyntheticTokens
from repro.distributed import (
    StepWatchdog,
    HeartbeatRegistry,
    plan_remesh,
    quantize_int8,
    dequantize_int8,
    compress_error_feedback,
)


# ------------------------------ checkpoint --------------------------------


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save(str(tmp_path), 7, tree)
    step, out = restore(str(tmp_path), template=tree)
    assert step == 7
    assert jnp.allclose(out["a"], tree["a"])
    assert out["b"]["c"].dtype == jnp.int32


def test_checkpoint_latest_and_gc(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, tree, max_keep=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_000000004", "step_000000005"]


def test_checkpoint_uncommitted_is_invisible(tmp_path):
    tree = _tree()
    save(str(tmp_path), 1, tree)
    # a crashed writer leaves a tmp dir: must not be visible
    os.makedirs(tmp_path / "step_000000002.tmp-999")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones(4, jnp.int32)}}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(str(tmp_path), template=bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save_async(10, _tree())
    ck.wait()
    assert latest_step(str(tmp_path)) == 10


def test_checkpoint_topology_independent_restore(tmp_path):
    """Restore with explicit shardings (1-device 'new mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree()
    save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    step, out = restore(str(tmp_path), template=tree, shardings=sh)
    assert jnp.allclose(out["a"], tree["a"])


# --------------------------------- data -----------------------------------


def test_data_deterministic():
    cfg = DataConfig(vocab=1024, seq_len=32, global_batch=8, seed=5)
    a = SyntheticTokens(cfg).global_batch(3)
    b = SyntheticTokens(cfg).global_batch(3)
    assert jnp.array_equal(a["tokens"], b["tokens"])


def test_data_host_slices_tile_global_batch():
    cfg = DataConfig(vocab=1024, seq_len=16, global_batch=8, seed=1)
    pipe = SyntheticTokens(cfg)
    full = pipe.global_batch(0)["tokens"]
    parts = [pipe.host_batch_slice(0, h, 4)["tokens"] for h in range(4)]
    assert jnp.array_equal(jnp.concatenate(parts), full)


def test_data_targets_shifted():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=2, seed=2)
    b = SyntheticTokens(cfg).global_batch(0)
    assert b["tokens"].shape == b["targets"].shape == (2, 16)


# ------------------------------- watchdog ---------------------------------


def test_watchdog_flags_stragglers():
    events = []
    wd = StepWatchdog(threshold=2.0, warmup=3,
                      on_straggler=lambda i, dt, e: events.append(i))
    for _ in range(10):
        wd.observe(0.1)
    assert not events
    assert wd.observe(0.5) is True
    assert events
    # baseline unpolluted: next normal step is not flagged
    assert wd.observe(0.1) is False


def test_heartbeats_and_remesh_plan():
    reg = HeartbeatRegistry(timeout=10.0)
    for h in range(8):
        reg.beat(h, now=100.0)
    assert reg.dead(now=105.0) == []
    reg.last_seen[3] = 50.0  # host 3 went silent
    assert 3 in reg.dead(now=105.0)
    assert 3 not in reg.alive(now=105.0)
    plan = plan_remesh(n_hosts_alive=7, chips_per_host=4, model_parallelism=16)
    assert plan["mesh_shape"] == (1, 16)
    assert plan_remesh(n_hosts_alive=3, chips_per_host=4, model_parallelism=16) is None
    big = plan_remesh(n_hosts_alive=64, chips_per_host=4, model_parallelism=16)
    assert big["mesh_shape"] == (16, 16)


# ------------------------------ compression --------------------------------


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.01, 100, allow_nan=False))
def test_quantize_int8_bounded_error(scale):
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * scale
    q, s = quantize_int8(x, jax.random.PRNGKey(1))
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) + 1e-6  # one quantization step


def test_quantize_int8_unbiased():
    """Stochastic rounding: E[q*scale] == x."""
    x = jnp.full((8,), 0.3)
    outs = []
    for i in range(2000):
        q, s = quantize_int8(x, jax.random.PRNGKey(i))
        outs.append(dequantize_int8(q, s))
    mean = jnp.stack(outs).mean()
    assert abs(float(mean) - 0.3) < 2e-3


def test_error_feedback_conserves_signal():
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64,))}
    residual = jax.tree.map(jnp.zeros_like, g)
    q, scales, new_res = compress_error_feedback(g, residual, jax.random.PRNGKey(3))
    from repro.distributed import dequantize_tree

    recon = dequantize_tree(q, scales)
    # transmitted + residual == original (exactly, by construction)
    assert jnp.allclose(recon["w"] + new_res["w"], g["w"], atol=1e-6)


# ------------------------------- sharding ----------------------------------


def test_param_sharding_rules_resolve():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import param_shardings, batch_shardings
    from repro import configs
    from repro.models import init_params

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_smoke_config("dbrx-132b")
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    sh = param_shardings(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    by_name = {
        ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path): s
        for path, s in flat
    }
    assert by_name["embed"].spec == P("model", "data")
    we_g = [v for k, v in by_name.items() if k.endswith("we_g")][0]
    assert we_g.spec == P(None, "model", "data", None)  # stacked + EP + FSDP
    ln = [v for k, v in by_name.items() if k.endswith("ln1")][0]
    assert ln.spec == P(None, None) or ln.spec == P(None)


def test_batch_sharding_small_batch_replicates():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import batch_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = batch_shardings({"x": jax.ShapeDtypeStruct((1, 8), jnp.int32)}, mesh)
    assert sh["x"].spec in (P(), P("data", None))  # 1 % 1 == 0 -> either fine


def test_divisibility_guard_drops_axis():
    """9 heads on a 16-way model axis must fall back to replication, not fail."""
    from repro.distributed.sharding import _divisible
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = _divisible(P("model"), (9,), mesh)
    assert spec == P("model")  # 9 % 1 == 0 on the degenerate mesh
