"""Calibrate the CI coverage floor without coverage.py.

Measures line coverage of ``src/repro`` under the engine suite using
`sys.settrace` (stdlib only — the dev container has no pytest-cov), then
prints per-file and total percentages. The CI floor (`REPRO_COV_FLOOR` in
tests/ci.sh) is ratcheted to a few points below the TOTAL this reports:
the margin absorbs the small methodological differences between this
estimator and coverage.py (docstring/constant-line accounting, version-
gated branches across the CI python matrix).

Denominator: executable lines are taken from `dis.findlinestarts` over the
compiled code objects of every file under src/repro — files the suite
never imports still count in full, matching pytest-cov's ``--cov=repro``
behavior.

Run: PYTHONPATH=src python tools/coverage_floor.py [pytest args...]
     (defaults to the engine-suite selection used by tests/ci.sh)
"""
import dis
import os
import pathlib
import sys
import threading

REPO = pathlib.Path(__file__).resolve().parent.parent
PREFIX = str(REPO / "src" / "repro") + os.sep

covered = {}


def _local(frame, event, arg):
    if event == "line":
        covered[frame.f_code.co_filename].add(frame.f_lineno)
    return _local


def _global(frame, event, arg):
    fn = frame.f_code.co_filename
    if fn.startswith(PREFIX):
        covered.setdefault(fn, set())
        return _local
    return None


def code_lines(co):
    lines = {line for _, line in dis.findlinestarts(co) if line is not None}
    for const in co.co_consts:
        if hasattr(const, "co_code"):
            lines |= code_lines(const)
    return lines


def main(argv):
    import pytest

    args = argv or [
        "-p", "no:randomly", "-q",
        "--ignore=tests/test_distributions_conformance.py",
    ]
    sys.settrace(_global)
    threading.settrace(_global)
    rc = pytest.main(args)
    sys.settrace(None)
    threading.settrace(None)
    if rc != 0:
        print(f"WARNING: pytest exited {rc}; coverage below reflects a failing run")

    total_lines = total_hit = 0
    print(f"\n{'file':<58} {'cover':>12}")
    for f in sorted((REPO / "src" / "repro").rglob("*.py")):
        co = compile(f.read_text(), str(f), "exec")
        lines = code_lines(co)
        hit = covered.get(str(f), set()) & lines
        total_lines += len(lines)
        total_hit += len(hit)
        pct = 100.0 * len(hit) / max(len(lines), 1)
        print(f"{str(f.relative_to(REPO)):<58} {len(hit):>4}/{len(lines):<4} {pct:5.1f}%")
    print(f"\nTOTAL {total_hit}/{total_lines} = {100.0 * total_hit / total_lines:.2f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
