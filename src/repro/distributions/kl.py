"""Analytic KL divergences (TraceMeanField_ELBO uses these; the paper notes
Pyro uses Monte-Carlo KL estimates — we provide both, MC as the faithful
default and analytic as a beyond-paper variance-reduction option)."""
from __future__ import annotations


import jax.numpy as jnp
from jax.scipy import special as jsp

from .continuous import Beta, Dirichlet, Gamma, LogNormal, MultivariateNormal, Normal
from .discrete import Bernoulli, Categorical
from .distribution import Distribution
from .util import clamp_probs, sum_rightmost
from .wrappers import Delta, Independent, MaskedDistribution

_KL_REGISTRY = {}


def register_kl(p_cls, q_cls):
    def deco(fn):
        _KL_REGISTRY[(p_cls, q_cls)] = fn
        return fn

    return deco


def kl_divergence(p: Distribution, q: Distribution):
    for (p_cls, q_cls), fn in _KL_REGISTRY.items():
        if isinstance(p, p_cls) and isinstance(q, q_cls):
            return fn(p, q)
    raise NotImplementedError(f"no KL registered for ({type(p).__name__}, {type(q).__name__})")


@register_kl(Independent, Independent)
def _kl_independent(p, q):
    if p.reinterpreted_batch_ndims != q.reinterpreted_batch_ndims:
        raise NotImplementedError
    return sum_rightmost(kl_divergence(p.base_dist, q.base_dist), p.reinterpreted_batch_ndims)


@register_kl(Normal, Normal)
def _kl_normal_normal(p, q):
    var_ratio = (p.scale / q.scale) ** 2
    t1 = ((p.loc - q.loc) / q.scale) ** 2
    return 0.5 * (var_ratio + t1 - 1 - jnp.log(var_ratio))


@register_kl(LogNormal, LogNormal)
def _kl_lognormal(p, q):
    return _kl_normal_normal(Normal(p.loc, p.scale), Normal(q.loc, q.scale))


@register_kl(Bernoulli, Bernoulli)
def _kl_bernoulli(p, q):
    pp = clamp_probs(p.probs)
    qp = clamp_probs(q.probs)
    return pp * (jnp.log(pp) - jnp.log(qp)) + (1 - pp) * (jnp.log1p(-pp) - jnp.log1p(-qp))


@register_kl(Categorical, Categorical)
def _kl_categorical(p, q):
    import jax

    p_logp = jax.nn.log_softmax(p.logits, -1)
    q_logp = jax.nn.log_softmax(q.logits, -1)
    return jnp.sum(jnp.exp(p_logp) * (p_logp - q_logp), -1)


@register_kl(Gamma, Gamma)
def _kl_gamma(p, q):
    a1, b1, a2, b2 = p.concentration, p.rate, q.concentration, q.rate
    return (
        (a1 - a2) * jsp.digamma(a1)
        - jsp.gammaln(a1)
        + jsp.gammaln(a2)
        + a2 * (jnp.log(b1) - jnp.log(b2))
        + a1 * (b2 / b1 - 1.0)
    )


@register_kl(Beta, Beta)
def _kl_beta(p, q):
    a1, b1 = p.concentration1, p.concentration0
    a2, b2 = q.concentration1, q.concentration0
    t1 = jsp.gammaln(a1 + b1) - jsp.gammaln(a1) - jsp.gammaln(b1)
    t2 = jsp.gammaln(a2 + b2) - jsp.gammaln(a2) - jsp.gammaln(b2)
    return (
        t1
        - t2
        + (a1 - a2) * jsp.digamma(a1)
        + (b1 - b2) * jsp.digamma(b1)
        + (a2 - a1 + b2 - b1) * jsp.digamma(a1 + b1)
    )


@register_kl(Dirichlet, Dirichlet)
def _kl_dirichlet(p, q):
    a, b = p.concentration, q.concentration
    a0 = a.sum(-1, keepdims=True)
    return (
        jsp.gammaln(a0[..., 0])
        - jnp.sum(jsp.gammaln(a), -1)
        - jsp.gammaln(b.sum(-1))
        + jnp.sum(jsp.gammaln(b), -1)
        + jnp.sum((a - b) * (jsp.digamma(a) - jsp.digamma(a0)), -1)
    )


@register_kl(MultivariateNormal, MultivariateNormal)
def _kl_mvn(p, q):
    import jax

    d = p.event_shape[0]
    p_tril, q_tril = p.scale_tril, q.scale_tril
    half_logdet = lambda L: jnp.sum(jnp.log(jnp.diagonal(L, axis1=-2, axis2=-1)), -1)
    term_logdet = half_logdet(q_tril) - half_logdet(p_tril)
    m = jax.scipy.linalg.solve_triangular(q_tril, p_tril, lower=True)
    term_tr = 0.5 * jnp.sum(m ** 2, axis=(-2, -1))
    diff = q.loc - p.loc
    y = jax.scipy.linalg.solve_triangular(q_tril, diff[..., None], lower=True)[..., 0]
    term_maha = 0.5 * jnp.sum(y ** 2, -1)
    return term_logdet + term_tr + term_maha - 0.5 * d


@register_kl(Delta, Distribution)
def _kl_delta_any(p, q):
    return p.log_density - q.log_prob(p.v)


@register_kl(MaskedDistribution, MaskedDistribution)
def _kl_masked(p, q):
    kl = kl_divergence(p.base_dist, q.base_dist)
    return jnp.where(p._mask, kl, 0.0)
