"""Bijective transforms + the biject_to registry (paper §3: the distributions
library the Pyro authors upstreamed includes constraints/transforms; IAF is the
flow used in the Fig-4 DMM experiment).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import constraints
from .util import clamp_probs, sum_rightmost


class Transform:
    domain: constraints.Constraint = constraints.real
    codomain: constraints.Constraint = constraints.real

    @property
    def event_dim(self) -> int:
        return self.codomain.event_dim

    def __call__(self, x):
        raise NotImplementedError

    def inv(self, y):
        raise NotImplementedError

    def log_abs_det_jacobian(self, x, y):
        raise NotImplementedError

    def forward_shape(self, shape):
        return shape

    def inverse_shape(self, shape):
        return shape


class IdentityTransform(Transform):
    def __call__(self, x):
        return x

    def inv(self, y):
        return y

    def log_abs_det_jacobian(self, x, y):
        return jnp.zeros_like(x)


class ExpTransform(Transform):
    codomain = constraints.positive

    def __call__(self, x):
        return jnp.exp(x)

    def inv(self, y):
        return jnp.log(y)

    def log_abs_det_jacobian(self, x, y):
        return x


class SoftplusTransform(Transform):
    codomain = constraints.positive

    def __call__(self, x):
        return jax.nn.softplus(x)

    def inv(self, y):
        # log(exp(y) - 1), stable
        return y + jnp.log(-jnp.expm1(-y))

    def log_abs_det_jacobian(self, x, y):
        return -jax.nn.softplus(-x)


class SigmoidTransform(Transform):
    codomain = constraints.unit_interval

    def __call__(self, x):
        return clamp_probs(jax.nn.sigmoid(x))

    def inv(self, y):
        y = clamp_probs(y)
        return jnp.log(y) - jnp.log1p(-y)

    def log_abs_det_jacobian(self, x, y):
        return -jax.nn.softplus(x) - jax.nn.softplus(-x)


class TanhTransform(Transform):
    codomain = constraints.interval(-1.0, 1.0)

    def __call__(self, x):
        return jnp.tanh(x)

    def inv(self, y):
        return jnp.arctanh(jnp.clip(y, -1 + 1e-6, 1 - 1e-6))

    def log_abs_det_jacobian(self, x, y):
        return 2.0 * (jnp.log(2.0) - x - jax.nn.softplus(-2.0 * x))


class AffineTransform(Transform):
    def __init__(self, loc, scale, domain=constraints.real):
        self.loc = loc
        self.scale = scale
        self.domain = domain

    @property
    def codomain(self):
        if self.domain is constraints.real:
            return constraints.real
        if isinstance(self.domain, constraints._GreaterThan):
            return constraints.greater_than(self(self.domain.lower_bound))
        if isinstance(self.domain, constraints._Interval):
            return constraints.interval(self(self.domain.lower_bound), self(self.domain.upper_bound))
        return constraints.real

    def __call__(self, x):
        return self.loc + self.scale * x

    def inv(self, y):
        return (y - self.loc) / self.scale

    def log_abs_det_jacobian(self, x, y):
        return jnp.broadcast_to(jnp.log(jnp.abs(self.scale)), jnp.shape(x))


class PowerTransform(Transform):
    domain = constraints.positive
    codomain = constraints.positive

    def __init__(self, exponent):
        self.exponent = exponent

    def __call__(self, x):
        return x ** self.exponent

    def inv(self, y):
        return y ** (1.0 / self.exponent)

    def log_abs_det_jacobian(self, x, y):
        return jnp.log(jnp.abs(self.exponent * y / x))


class StickBreakingTransform(Transform):
    """R^{K-1} -> K-simplex via stick breaking (Stan's simplex bijector)."""

    domain = constraints.real_vector
    codomain = constraints.simplex

    def __call__(self, x):
        k = x.shape[-1]
        offset = jnp.log(jnp.arange(k, 0, -1.0))
        z = jax.nn.sigmoid(x - offset)
        z_cumprod = jnp.cumprod(1 - z, axis=-1)
        pad = jnp.ones(x.shape[:-1] + (1,), x.dtype)
        probs = jnp.concatenate([z, pad], -1) * jnp.concatenate([pad, z_cumprod], -1)
        return probs

    def inv(self, y):
        y_crop = y[..., :-1]
        k = y_crop.shape[-1]
        offset = jnp.log(jnp.arange(k, 0, -1.0))
        remainder = jnp.clip(1 - jnp.cumsum(y_crop, axis=-1) + y_crop, 1e-30)
        z = jnp.clip(y_crop / remainder, 1e-30, 1 - 1e-7)
        return jnp.log(z) - jnp.log1p(-z) + offset

    def log_abs_det_jacobian(self, x, y):
        k = x.shape[-1]
        offset = jnp.log(jnp.arange(k, 0, -1.0))
        z = jax.nn.sigmoid(x - offset)
        # |dy/dx| = prod sigma'(x - off) * remainder
        remainder = jnp.concatenate(
            [jnp.ones(x.shape[:-1] + (1,), x.dtype), jnp.cumprod(1 - z, axis=-1)[..., :-1]], -1
        )
        lad = jnp.log(z) + jnp.log1p(-z) + jnp.log(remainder)
        return lad.sum(-1)

    def forward_shape(self, shape):
        return shape[:-1] + (shape[-1] + 1,)

    def inverse_shape(self, shape):
        return shape[:-1] + (shape[-1] - 1,)


class LowerCholeskyTransform(Transform):
    """Unconstrained vector of size n(n+1)/2 -> lower Cholesky factor."""

    domain = constraints.real_vector
    codomain = constraints.lower_cholesky

    @staticmethod
    def _dim(n_flat):
        # solve n(n+1)/2 = n_flat
        import math

        n = int((math.sqrt(8 * n_flat + 1) - 1) / 2)
        assert n * (n + 1) // 2 == n_flat, "invalid flattened cholesky size"
        return n

    def __call__(self, x):
        n = self._dim(x.shape[-1])
        idx = jnp.tril_indices(n)
        mat = jnp.zeros(x.shape[:-1] + (n, n), x.dtype).at[..., idx[0], idx[1]].set(x)
        diag = jnp.exp(jnp.diagonal(mat, axis1=-2, axis2=-1))
        return mat - jnp.diagflat(jnp.diagonal(mat, axis1=-2, axis2=-1)) * jnp.eye(n) + diag[..., None] * jnp.eye(n)

    def inv(self, y):
        n = y.shape[-1]
        diag = jnp.log(jnp.diagonal(y, axis1=-2, axis2=-1))
        mat = y - jnp.diagonal(y, axis1=-2, axis2=-1)[..., None] * jnp.eye(n) + diag[..., None] * jnp.eye(n)
        idx = jnp.tril_indices(n)
        return mat[..., idx[0], idx[1]]

    def log_abs_det_jacobian(self, x, y):
        return jnp.sum(jnp.log(jnp.diagonal(y, axis1=-2, axis2=-1)), -1)

    def forward_shape(self, shape):
        n = self._dim(shape[-1])
        return shape[:-1] + (n, n)

    def inverse_shape(self, shape):
        n = shape[-1]
        return shape[:-2] + (n * (n + 1) // 2,)


class PermuteTransform(Transform):
    domain = constraints.real_vector
    codomain = constraints.real_vector

    def __init__(self, permutation):
        self.permutation = jnp.asarray(permutation)

    def __call__(self, x):
        return x[..., self.permutation]

    def inv(self, y):
        inv_perm = jnp.argsort(self.permutation)
        return y[..., inv_perm]

    def log_abs_det_jacobian(self, x, y):
        return jnp.zeros(x.shape[:-1], x.dtype)


class ComposeTransform(Transform):
    def __init__(self, parts: Sequence[Transform]):
        self.parts = list(parts)

    @property
    def domain(self):
        return self.parts[0].domain if self.parts else constraints.real

    @property
    def codomain(self):
        return self.parts[-1].codomain if self.parts else constraints.real

    def __call__(self, x):
        for p in self.parts:
            x = p(x)
        return x

    def inv(self, y):
        for p in reversed(self.parts):
            y = p.inv(y)
        return y

    def log_abs_det_jacobian(self, x, y):
        result = 0.0
        event_dim = self.event_dim
        for p in self.parts:
            y_p = p(x)
            lad = p.log_abs_det_jacobian(x, y_p)
            result = result + sum_rightmost(lad, event_dim - p.event_dim)
            x = y_p
        return result

    def forward_shape(self, shape):
        for p in self.parts:
            shape = p.forward_shape(shape)
        return shape

    def inverse_shape(self, shape):
        for p in reversed(self.parts):
            shape = p.inverse_shape(shape)
        return shape


class IndependentTransform(Transform):
    """Reinterpret batch dims of a transform as event dims."""

    def __init__(self, base: Transform, reinterpreted_batch_ndims: int):
        self.base = base
        self.reinterpreted_batch_ndims = reinterpreted_batch_ndims

    @property
    def event_dim(self):
        return self.base.event_dim + self.reinterpreted_batch_ndims

    def __call__(self, x):
        return self.base(x)

    def inv(self, y):
        return self.base.inv(y)

    def log_abs_det_jacobian(self, x, y):
        return sum_rightmost(self.base.log_abs_det_jacobian(x, y), self.reinterpreted_batch_ndims)


# ---------------------------------------------------------------------------
# MADE + Inverse Autoregressive Flow (Kingma et al. 2016) — used by the DMM
# experiment (paper Fig. 4) and AutoIAFNormal.
# ---------------------------------------------------------------------------


def made_masks(input_dim: int, hidden_dims: Sequence[int], key=None):
    """Sequential-degree MADE masks for an autoregressive MLP."""
    degrees = [jnp.arange(input_dim)]
    for h in hidden_dims:
        degrees.append(jnp.arange(h) % max(1, input_dim - 1))
    degrees.append(jnp.arange(input_dim))
    masks = []
    for d_in, d_out in zip(degrees[:-1], degrees[1:-1]):
        masks.append((d_out[:, None] >= d_in[None, :]).astype(jnp.float32))
    # output mask is strict: output i depends only on inputs < i
    masks.append((degrees[-1][:, None] > degrees[-2][None, :]).astype(jnp.float32))
    return masks


def init_made_params(key, input_dim: int, hidden_dims: Sequence[int], n_outputs: int = 2):
    """Initialize MADE weights; returns a pytree dict."""
    dims = [input_dim] + list(hidden_dims) + [input_dim * n_outputs]
    params = {}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        w = jax.random.normal(keys[i], (d_out, d_in)) * (1.0 / jnp.sqrt(d_in))
        params[f"w{i}"] = w
        params[f"b{i}"] = jnp.zeros((d_out,))
    return params


def made_apply(params, masks, x, n_outputs: int = 2):
    """Run the masked MLP; returns (out_0, ..., out_{n-1}) each of shape x."""
    h = x
    n_layers = len(masks)
    for i in range(n_layers - 1):
        w = params[f"w{i}"] * masks[i]
        h = jnp.tanh(h @ w.T + params[f"b{i}"])
    w = params[f"w{n_layers - 1}"]
    mask = jnp.tile(masks[n_layers - 1], (n_outputs, 1))
    out = h @ (w * mask).T + params[f"b{n_layers - 1}"]
    outs = jnp.split(out, n_outputs, axis=-1)
    return outs


class InverseAutoregressiveTransform(Transform):
    """IAF: y = x * sigma(s) + (1 - sigma(s)) * m with (m, s) = MADE(x).

    Forward (sampling) is one parallel pass; inverse is sequential (we provide a
    fixed-point iteration usable for testing). `params`/`masks` are provided by
    the guide via the `param` primitive, keeping the flow learnable.
    """

    domain = constraints.real_vector
    codomain = constraints.real_vector

    def __init__(self, params, masks, log_scale_min_clip=-5.0, log_scale_max_clip=3.0):
        self.params = params
        self.masks = masks
        self.clip = (log_scale_min_clip, log_scale_max_clip)

    def _net(self, x):
        m, s = made_apply(self.params, self.masks, x, n_outputs=2)
        s = jnp.clip(s, *self.clip)
        return m, s

    def __call__(self, x):
        m, s = self._net(x)
        gate = jax.nn.sigmoid(s)
        return gate * x + (1 - gate) * m

    def inv(self, y):
        # autoregressive inversion: D sequential passes solve exactly
        def body(x, _):
            m, s = self._net(x)
            gate = jax.nn.sigmoid(s)
            x_new = (y - (1 - gate) * m) / jnp.clip(gate, 1e-8)
            return x_new, None

        x0 = jnp.zeros_like(y)
        x, _ = jax.lax.scan(body, x0, None, length=y.shape[-1])
        return x

    def log_abs_det_jacobian(self, x, y):
        _, s = self._net(x)
        return jnp.sum(jax.nn.log_sigmoid(s), axis=-1)


# ---------------------------------------------------------------------------
# biject_to registry: constraint -> Transform from unconstrained space
# ---------------------------------------------------------------------------


def biject_to(constraint: constraints.Constraint) -> Transform:
    if constraint is constraints.real or constraint is constraints.real_vector:
        return IdentityTransform()
    if constraint is constraints.positive or constraint is constraints.nonnegative:
        return ExpTransform()
    if constraint is constraints.unit_interval:
        return SigmoidTransform()
    if constraint is constraints.simplex:
        return StickBreakingTransform()
    if constraint is constraints.lower_cholesky:
        return LowerCholeskyTransform()
    if constraint is constraints.circular:
        return ComposeTransform([TanhTransform(), AffineTransform(0.0, jnp.pi)])
    if isinstance(constraint, constraints._Interval):
        scale = constraint.upper_bound - constraint.lower_bound
        return ComposeTransform(
            [SigmoidTransform(), AffineTransform(constraint.lower_bound, scale)]
        )
    if isinstance(constraint, constraints._GreaterThan):
        return ComposeTransform([ExpTransform(), AffineTransform(constraint.lower_bound, 1.0)])
    if isinstance(constraint, constraints._LessThan):
        return ComposeTransform([ExpTransform(), AffineTransform(constraint.upper_bound, -1.0)])
    raise NotImplementedError(f"no bijector registered for constraint {constraint}")
