"""Meta-distributions: Delta, Unit, Independent, Masked, Expanded, Transformed,
MixtureSameFamily. These are the combinators the handler stack relies on
(`scale`/`mask` handlers rewrite sites into Masked dists, `plate` uses expand)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import constraints
from .distribution import Distribution
from .transforms import Transform
from .util import broadcast_shapes, sum_rightmost


class Delta(Distribution):
    """Point mass; AutoDelta guides (MAP/MLE training) are built from these."""

    arg_constraints = {"v": constraints.real, "log_density": constraints.real}
    support = constraints.real
    has_rsample = True

    def __init__(self, v=0.0, log_density=0.0, event_dim=0):
        v = jnp.asarray(v)
        if event_dim > v.ndim:
            raise ValueError("event_dim exceeds value rank")
        batch_shape = v.shape[: v.ndim - event_dim]
        event_shape = v.shape[v.ndim - event_dim :]
        self.v = v
        self.log_density = log_density
        super().__init__(batch_shape, event_shape)

    def sample(self, key, sample_shape=()):
        return jnp.broadcast_to(self.v, self.shape(sample_shape))

    def log_prob(self, value):
        lp = jnp.where(value == self.v, 0.0, -jnp.inf)
        return sum_rightmost(lp, len(self.event_shape)) + self.log_density

    @property
    def mean(self):
        return self.v

    @property
    def variance(self):
        return jnp.zeros_like(self.v)


class Unit(Distribution):
    """Trivial nonnormalized distribution over the empty set; carries a
    log_factor — implements the `factor` primitive."""

    arg_constraints = {"log_factor": constraints.real}
    support = constraints.real

    def __init__(self, log_factor):
        self.log_factor = jnp.asarray(log_factor)
        super().__init__(self.log_factor.shape, (0,))

    def sample(self, key, sample_shape=()):
        return jnp.empty(self.shape(sample_shape))

    def log_prob(self, value=None):
        return self.log_factor


class Independent(Distribution):
    def __init__(self, base_dist: Distribution, reinterpreted_batch_ndims: int):
        if reinterpreted_batch_ndims > len(base_dist.batch_shape):
            raise ValueError("reinterpreted dims exceed batch rank")
        self.base_dist = base_dist
        self.reinterpreted_batch_ndims = reinterpreted_batch_ndims
        shape = base_dist.batch_shape + base_dist.event_shape
        event_dim = reinterpreted_batch_ndims + len(base_dist.event_shape)
        super().__init__(shape[: len(shape) - event_dim], shape[len(shape) - event_dim :])

    @property
    def has_rsample(self):
        return self.base_dist.has_rsample

    @property
    def is_discrete(self):
        return self.base_dist.is_discrete

    @property
    def support(self):
        return self.base_dist.support

    def enumerate_support(self, expand=True):
        raise NotImplementedError(
            "Independent cannot enumerate_support: values along reinterpreted "
            "batch dims would need a joint (exponential) enumeration. Keep the "
            "dims as batch dims inside a plate and enumerate the base instead."
        )

    def sample(self, key, sample_shape=()):
        return self.base_dist.sample(key, sample_shape)

    def log_prob(self, value):
        return sum_rightmost(self.base_dist.log_prob(value), self.reinterpreted_batch_ndims)

    def entropy(self):
        return sum_rightmost(self.base_dist.entropy(), self.reinterpreted_batch_ndims)

    @property
    def mean(self):
        return self.base_dist.mean

    @property
    def variance(self):
        return self.base_dist.variance


class MaskedDistribution(Distribution):
    def __init__(self, base_dist: Distribution, mask):
        self.base_dist = base_dist
        self._mask = mask
        batch_shape = broadcast_shapes(jnp.shape(mask), base_dist.batch_shape)
        super().__init__(batch_shape, base_dist.event_shape)

    @property
    def has_rsample(self):
        return self.base_dist.has_rsample

    @property
    def is_discrete(self):
        return self.base_dist.is_discrete

    @property
    def support(self):
        return self.base_dist.support

    @property
    def has_enumerate_support(self):
        return self.base_dist.has_enumerate_support

    def enumerate_support(self, expand=True):
        return _wrapped_enumerate_support(self, expand)

    def sample(self, key, sample_shape=()):
        return self.base_dist.sample(key, sample_shape)

    def log_prob(self, value):
        lp = self.base_dist.log_prob(value)
        return jnp.where(self._mask, lp, 0.0)


def _wrapped_enumerate_support(dist: Distribution, expand: bool):
    """Shared enumerate_support for wrappers: re-align the base support to the
    wrapper's (possibly wider) batch rank."""
    values = dist.base_dist.enumerate_support(expand=False)
    k = values.shape[0]
    values = values.reshape((k,) + (1,) * len(dist.batch_shape) + dist.event_shape)
    if expand:
        values = jnp.broadcast_to(values, (k,) + dist.batch_shape + dist.event_shape)
    return values


class ExpandedDistribution(Distribution):
    def __init__(self, base_dist: Distribution, batch_shape):
        self.base_dist = base_dist
        # sanity: must broadcast
        broadcast_shapes(batch_shape, base_dist.batch_shape)
        super().__init__(tuple(batch_shape), base_dist.event_shape)

    @property
    def has_rsample(self):
        return self.base_dist.has_rsample

    @property
    def is_discrete(self):
        return self.base_dist.is_discrete

    @property
    def support(self):
        return self.base_dist.support

    @property
    def has_enumerate_support(self):
        return self.base_dist.has_enumerate_support

    def enumerate_support(self, expand=True):
        return _wrapped_enumerate_support(self, expand)

    def sample(self, key, sample_shape=()):
        n_extra = len(self.batch_shape) - len(self.base_dist.batch_shape)
        interstitial = tuple(self.batch_shape[:n_extra])
        # draw with the expanded batch as part of sample_shape, broadcasting base
        samples = self.base_dist.sample(key, tuple(sample_shape) + interstitial)
        target = tuple(sample_shape) + self.shape()
        return jnp.broadcast_to(samples, target)

    def log_prob(self, value):
        lp = self.base_dist.log_prob(value)
        return jnp.broadcast_to(lp, broadcast_shapes(jnp.shape(lp), self.batch_shape))

    @property
    def mean(self):
        return jnp.broadcast_to(self.base_dist.mean, self.batch_shape + self.event_shape)

    @property
    def variance(self):
        return jnp.broadcast_to(self.base_dist.variance, self.batch_shape + self.event_shape)


class TransformedDistribution(Distribution):
    def __init__(self, base_distribution: Distribution, transforms):
        if isinstance(transforms, Transform):
            transforms = [transforms]
        self.base_dist = base_distribution
        self.transforms = list(transforms)
        base_shape = base_distribution.shape()
        forward_shape = base_shape
        for t in self.transforms:
            forward_shape = t.forward_shape(forward_shape)
        event_dim = max(
            [len(base_distribution.event_shape)]
            + [t.event_dim for t in self.transforms]
        )
        cut = len(forward_shape) - event_dim
        super().__init__(forward_shape[:cut], forward_shape[cut:])

    @property
    def has_rsample(self):
        return self.base_dist.has_rsample

    @property
    def support(self):
        return self.transforms[-1].codomain if self.transforms else self.base_dist.support

    def sample(self, key, sample_shape=()):
        x = self.base_dist.sample(key, sample_shape)
        for t in self.transforms:
            x = t(x)
        return x

    def sample_with_intermediates(self, key, sample_shape=()):
        x = self.base_dist.sample(key, sample_shape)
        xs = [x]
        for t in self.transforms:
            x = t(x)
            xs.append(x)
        return x, xs

    def log_prob(self, value):
        event_dim = len(self.event_shape)
        lp = 0.0
        y = value
        for t in reversed(self.transforms):
            x = t.inv(y)
            lad = t.log_abs_det_jacobian(x, y)
            lp = lp - sum_rightmost(lad, event_dim - t.event_dim)
            y = x
        lp = lp + sum_rightmost(
            self.base_dist.log_prob(y), event_dim - len(self.base_dist.event_shape)
        )
        return lp


class MixtureSameFamily(Distribution):
    def __init__(self, mixing_distribution, component_distribution):
        self.mixing_distribution = mixing_distribution  # Categorical over K
        self.component_distribution = component_distribution  # batch (..., K)
        k = component_distribution.batch_shape[-1]
        if mixing_distribution.num_categories != k:
            raise ValueError("component count mismatch")
        super().__init__(
            component_distribution.batch_shape[:-1], component_distribution.event_shape
        )

    @property
    def is_discrete(self):
        return self.component_distribution.is_discrete

    def sample(self, key, sample_shape=()):
        k1, k2 = jax.random.split(key)
        idx = self.mixing_distribution.sample(k1, sample_shape)  # (*s, *batch)
        comps = self.component_distribution.sample(k2, sample_shape)  # (*s, *batch, K, *event)
        idx_exp = idx[(...,) + (None,) * (1 + len(self.event_shape))]
        idx_exp = jnp.broadcast_to(
            idx_exp, idx.shape + (1,) + self.event_shape
        )
        return jnp.take_along_axis(comps, idx_exp, axis=len(idx.shape)).squeeze(len(idx.shape))

    def log_prob(self, value):
        value_exp = jnp.expand_dims(value, -1 - len(self.event_shape))
        comp_lp = self.component_distribution.log_prob(value_exp)
        mix_logp = jax.nn.log_softmax(self.mixing_distribution.logits, -1)
        return jax.scipy.special.logsumexp(comp_lp + mix_logp, axis=-1)

    @property
    def mean(self):
        probs = self.mixing_distribution.probs
        probs = probs.reshape(probs.shape + (1,) * len(self.event_shape))
        return jnp.sum(probs * self.component_distribution.mean, axis=-1 - len(self.event_shape))
