"""Discrete distributions."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy import special as jsp

from . import constraints
from .distribution import Distribution
from .util import (
    binary_cross_entropy_with_logits,
    broadcast_shapes,
    clamp_probs,
    lazy_property,
    logits_to_probs,
    probs_to_logits,
)


class Bernoulli(Distribution):
    support = constraints.boolean
    is_discrete = True
    has_enumerate_support = True

    def __init__(self, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("pass exactly one of probs / logits")
        self._probs = probs
        self._logits = logits
        shape = jnp.shape(probs if probs is not None else logits)
        super().__init__(shape)

    @lazy_property
    def probs(self):
        return self._probs if self._probs is not None else logits_to_probs(self._logits, True)

    @lazy_property
    def logits(self):
        return self._logits if self._logits is not None else probs_to_logits(self._probs, True)

    def sample(self, key, sample_shape=()):
        return jax.random.bernoulli(key, self.probs, self.shape(sample_shape)).astype(jnp.float32)

    def log_prob(self, value):
        return -binary_cross_entropy_with_logits(self.logits, value)

    @property
    def mean(self):
        return self.probs

    @property
    def variance(self):
        return self.probs * (1 - self.probs)

    def entropy(self):
        p = clamp_probs(self.probs)
        return -(p * jnp.log(p) + (1 - p) * jnp.log1p(-p))

    def enumerate_support(self, expand=True):
        values = jnp.arange(2.0).reshape((2,) + (1,) * len(self.batch_shape))
        if expand:
            values = jnp.broadcast_to(values, (2,) + self.batch_shape)
        return values


class Categorical(Distribution):
    is_discrete = True
    has_enumerate_support = True

    def __init__(self, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("pass exactly one of probs / logits")
        self._probs = probs
        self._logits = logits
        shape = jnp.shape(probs if probs is not None else logits)
        self.num_categories = shape[-1]
        super().__init__(shape[:-1])
        self.support = constraints.integer_interval(0, self.num_categories - 1)

    @lazy_property
    def probs(self):
        return self._probs if self._probs is not None else logits_to_probs(self._logits)

    @lazy_property
    def logits(self):
        return self._logits if self._logits is not None else probs_to_logits(self._probs)

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        return jax.random.categorical(key, self.logits, shape=shape)

    def log_prob(self, value):
        # normalized logits gathered at value — THE hot path for LM observe
        # sites; the Pallas kernel in kernels/categorical_logprob fuses this.
        # value and batch dims are broadcast against each other first, so
        # enumerated values (extra leading dims from the enum messenger)
        # gather correctly against plate-expanded logits.
        logits = self.logits
        norm = jsp.logsumexp(logits, axis=-1)
        value = jnp.asarray(value, jnp.int32)
        batch = broadcast_shapes(jnp.shape(value), jnp.shape(logits)[:-1])
        logits = jnp.broadcast_to(logits, batch + jnp.shape(logits)[-1:])
        value = jnp.broadcast_to(value, batch)
        picked = jnp.take_along_axis(logits, value[..., None], axis=-1)[..., 0]
        return picked - norm

    @property
    def mean(self):
        return jnp.sum(self.probs * jnp.arange(self.num_categories), -1)

    @property
    def variance(self):
        second_moment = jnp.sum(self.probs * jnp.arange(self.num_categories) ** 2, -1)
        return second_moment - self.mean ** 2

    def entropy(self):
        logp = jax.nn.log_softmax(self.logits, -1)
        return -jnp.sum(jnp.exp(logp) * logp, -1)

    def enumerate_support(self, expand=True):
        values = jnp.arange(self.num_categories).reshape(
            (self.num_categories,) + (1,) * len(self.batch_shape)
        )
        if expand:
            values = jnp.broadcast_to(values, (self.num_categories,) + self.batch_shape)
        return values


class OneHotCategorical(Categorical):
    def __init__(self, probs=None, logits=None):
        super().__init__(probs=probs, logits=logits)
        self._event_shape = (self.num_categories,)
        self.support = constraints.simplex  # loosely: one-hot vectors

    def sample(self, key, sample_shape=()):
        idx = jax.random.categorical(
            key, self.logits, shape=tuple(sample_shape) + self.batch_shape
        )
        return jax.nn.one_hot(idx, self.num_categories)

    def log_prob(self, value):
        logp = jax.nn.log_softmax(self.logits, -1)
        return jnp.sum(logp * value, -1)

    @property
    def mean(self):
        return self.probs

    @property
    def variance(self):
        return self.probs * (1 - self.probs)

    def enumerate_support(self, expand=True):
        n = self.num_categories
        values = jnp.eye(n).reshape((n,) + (1,) * len(self.batch_shape) + (n,))
        if expand:
            values = jnp.broadcast_to(values, (n,) + self.batch_shape + (n,))
        return values


class Binomial(Distribution):
    is_discrete = True
    has_enumerate_support = True

    def __init__(self, total_count=1, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("pass exactly one of probs / logits")
        self._probs = probs
        self._logits = logits
        self.total_count = total_count
        shape = broadcast_shapes(
            jnp.shape(total_count), jnp.shape(probs if probs is not None else logits)
        )
        super().__init__(shape)
        self.support = constraints.integer_interval(0, total_count)

    @lazy_property
    def probs(self):
        return self._probs if self._probs is not None else logits_to_probs(self._logits, True)

    @lazy_property
    def logits(self):
        return self._logits if self._logits is not None else probs_to_logits(self._probs, True)

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        n_max = int(jnp.max(jnp.asarray(self.total_count)))
        p = jnp.broadcast_to(self.probs, shape)
        counts = jnp.arange(n_max) < jnp.expand_dims(jnp.broadcast_to(jnp.asarray(self.total_count), shape), -1)
        draws = jax.random.uniform(key, shape + (n_max,)) < p[..., None]
        return jnp.sum(draws & counts, -1).astype(jnp.float32)

    def log_prob(self, value):
        n = self.total_count
        log_binom = jsp.gammaln(n + 1) - jsp.gammaln(value + 1) - jsp.gammaln(n - value + 1)
        return (
            log_binom
            + value * jax.nn.log_sigmoid(self.logits)
            + (n - value) * jax.nn.log_sigmoid(-self.logits)
        )

    @property
    def mean(self):
        return jnp.broadcast_to(self.total_count * self.probs, self.batch_shape)

    @property
    def variance(self):
        return jnp.broadcast_to(
            self.total_count * self.probs * (1 - self.probs), self.batch_shape
        )

    def enumerate_support(self, expand=True):
        try:
            counts = np.asarray(self.total_count)
        except Exception as e:  # total_count is a jax tracer
            raise NotImplementedError(
                "Binomial.enumerate_support needs a static (non-traced) "
                "total_count — pass it as a python int, not a jit argument."
            ) from e
        if counts.size > 1 and not (counts == counts.flat[0]).all():
            raise NotImplementedError(
                "Binomial.enumerate_support requires a homogeneous total_count "
                f"(got varying counts {counts.ravel()[:5]}...); split the site "
                "per count or pad all counts to a common value with masking."
            )
        n = int(counts.flat[0]) if counts.size else int(counts)
        values = jnp.arange(n + 1.0).reshape((n + 1,) + (1,) * len(self.batch_shape))
        if expand:
            values = jnp.broadcast_to(values, (n + 1,) + self.batch_shape)
        return values


class Multinomial(Distribution):
    is_discrete = True

    def __init__(self, total_count=1, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("pass exactly one of probs / logits")
        self._probs = probs
        self._logits = logits
        self.total_count = total_count
        shape = jnp.shape(probs if probs is not None else logits)
        # batch shape must broadcast total_count against the parameter batch
        # dims (a batched total_count used to be silently dropped)
        super().__init__(
            broadcast_shapes(jnp.shape(total_count), shape[:-1]), shape[-1:]
        )

    @lazy_property
    def probs(self):
        return self._probs if self._probs is not None else logits_to_probs(self._logits)

    @lazy_property
    def logits(self):
        return self._logits if self._logits is not None else probs_to_logits(self._probs)

    def sample(self, key, sample_shape=()):
        shape = tuple(sample_shape) + self.batch_shape
        if jnp.ndim(self.total_count) > 0:
            raise NotImplementedError(
                "Multinomial.sample needs a scalar total_count; "
                "got a batched array — sample per count instead."
            )
        n = int(self.total_count)
        idx = jax.random.categorical(key, self.logits, shape=(n,) + shape)
        k = self.event_shape[0]
        return jnp.sum(jax.nn.one_hot(idx, k), axis=0)

    def log_prob(self, value):
        logp = jax.nn.log_softmax(self.logits, -1)
        log_factorial_n = jsp.gammaln(value.sum(-1) + 1)
        log_factorial_xs = jsp.gammaln(value + 1).sum(-1)
        return log_factorial_n - log_factorial_xs + jnp.sum(value * logp, -1)

    @property
    def mean(self):
        n = jnp.asarray(self.total_count)[..., None]
        return jnp.broadcast_to(n * self.probs, self.batch_shape + self.event_shape)

    @property
    def variance(self):
        n = jnp.asarray(self.total_count)[..., None]
        return jnp.broadcast_to(
            n * self.probs * (1 - self.probs), self.batch_shape + self.event_shape
        )

    def enumerate_support(self, expand=True):
        raise NotImplementedError(
            "Multinomial support is combinatorially large (C(n+k-1, k-1) "
            "states) and cannot be enumerated; model the per-trial draws with "
            "a plated Categorical instead, or — for sequential latents — "
            "marginalize by sampling with `repro.infer.SMC` (particle "
            "filtering does not need an enumerable support)."
        )


class Poisson(Distribution):
    arg_constraints = {"rate": constraints.positive}
    support = constraints.nonnegative_integer
    is_discrete = True

    def __init__(self, rate):
        self.rate = rate
        super().__init__(jnp.shape(rate))

    def sample(self, key, sample_shape=()):
        return jax.random.poisson(key, self.rate, self.shape(sample_shape)).astype(jnp.float32)

    def log_prob(self, value):
        return value * jnp.log(self.rate) - self.rate - jsp.gammaln(value + 1)

    @property
    def mean(self):
        return self.rate

    @property
    def variance(self):
        return self.rate

    def enumerate_support(self, expand=True):
        raise NotImplementedError(
            "Poisson has countably infinite support and cannot be enumerated; "
            "truncate it to a Categorical over {0..N} (pick N from the rate's "
            "tail mass), or marginalize by sampling — `repro.infer.SMC` "
            "handles sequential discrete latents without enumeration."
        )


class Geometric(Distribution):
    support = constraints.nonnegative_integer
    is_discrete = True

    def __init__(self, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("pass exactly one of probs / logits")
        self._probs = probs
        self._logits = logits
        super().__init__(jnp.shape(probs if probs is not None else logits))

    @lazy_property
    def probs(self):
        return self._probs if self._probs is not None else logits_to_probs(self._logits, True)

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), minval=1e-7, maxval=1 - 1e-7)
        p = clamp_probs(self.probs)
        return jnp.floor(jnp.log1p(-u) / jnp.log1p(-p))

    def log_prob(self, value):
        p = clamp_probs(self.probs)
        return value * jnp.log1p(-p) + jnp.log(p)

    @property
    def mean(self):
        return (1 - self.probs) / self.probs

    @property
    def variance(self):
        return (1 - self.probs) / self.probs ** 2

    def enumerate_support(self, expand=True):
        raise NotImplementedError(
            "Geometric has countably infinite support {0, 1, 2, ...} and "
            "cannot be enumerated; truncate it to a Categorical over {0..N} "
            "(N chosen so (1-p)^N is negligible), or marginalize by sampling "
            "— `repro.infer.SMC` handles sequential discrete latents "
            "without enumeration."
        )


class NegativeBinomial(Distribution):
    support = constraints.nonnegative_integer
    is_discrete = True

    def __init__(self, total_count, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("pass exactly one of probs / logits")
        self.total_count = total_count
        self._probs = probs
        self._logits = logits
        shape = broadcast_shapes(
            jnp.shape(total_count), jnp.shape(probs if probs is not None else logits)
        )
        super().__init__(shape)

    @lazy_property
    def probs(self):
        return self._probs if self._probs is not None else logits_to_probs(self._logits, True)

    def sample(self, key, sample_shape=()):
        k1, k2 = jax.random.split(key)
        shape = self.shape(sample_shape)
        p = clamp_probs(jnp.broadcast_to(self.probs, shape))
        r = jnp.broadcast_to(jnp.asarray(self.total_count, jnp.float32), shape)
        lam = jax.random.gamma(k1, r) * p / (1 - p)
        return jax.random.poisson(k2, lam).astype(jnp.float32)

    def log_prob(self, value):
        r = jnp.asarray(self.total_count, jnp.float32)
        p = clamp_probs(self.probs)
        return (
            jsp.gammaln(value + r)
            - jsp.gammaln(r)
            - jsp.gammaln(value + 1)
            + r * jnp.log1p(-p)
            + value * jnp.log(p)
        )

    @property
    def mean(self):
        r = jnp.asarray(self.total_count, jnp.float32)
        return jnp.broadcast_to(r * self.probs / (1 - self.probs), self.batch_shape)

    @property
    def variance(self):
        r = jnp.asarray(self.total_count, jnp.float32)
        return jnp.broadcast_to(
            r * self.probs / (1 - self.probs) ** 2, self.batch_shape
        )

    def enumerate_support(self, expand=True):
        raise NotImplementedError(
            "NegativeBinomial has countably infinite support and cannot be "
            "enumerated; truncate it to a Categorical over {0..N}, or "
            "marginalize by sampling — `repro.infer.SMC` handles sequential "
            "discrete latents without enumeration."
        )
