"""Distribution base class (the library the Pyro authors upstreamed to their
substrate — here rebuilt natively on jnp so it composes with jit/pjit/vmap)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import constraints
from .util import broadcast_shapes


class Distribution:
    arg_constraints: dict = {}
    support: constraints.Constraint = constraints.real
    has_rsample: bool = False  # reparametrized sampling available
    is_discrete: bool = False
    has_enumerate_support: bool = False  # finite support usable by enum/TraceEnum_ELBO

    def __init__(self, batch_shape: Tuple[int, ...] = (), event_shape: Tuple[int, ...] = ()):
        self._batch_shape = tuple(batch_shape)
        self._event_shape = tuple(event_shape)

    # -- shapes ------------------------------------------------------------
    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return self._batch_shape

    @property
    def event_shape(self) -> Tuple[int, ...]:
        return self._event_shape

    @property
    def event_dim(self) -> int:
        return len(self._event_shape)

    def shape(self, sample_shape=()) -> Tuple[int, ...]:
        return tuple(sample_shape) + self._batch_shape + self._event_shape

    # -- core API ----------------------------------------------------------
    def sample(self, key: jax.Array, sample_shape: Tuple[int, ...] = ()) -> jax.Array:
        raise NotImplementedError

    def rsample(self, key, sample_shape=()):
        if self.has_rsample:
            return self.sample(key, sample_shape)
        raise NotImplementedError(f"{type(self).__name__} has no rsample")

    def log_prob(self, value) -> jax.Array:
        raise NotImplementedError

    @property
    def mean(self):
        raise NotImplementedError

    @property
    def variance(self):
        raise NotImplementedError

    def entropy(self):
        raise NotImplementedError

    def cdf(self, value):
        raise NotImplementedError

    def icdf(self, value):
        raise NotImplementedError

    def enumerate_support(self, expand: bool = True):
        """Enumerate a finite support as values stacked along a new leading
        dim: shape ``(cardinality,) + batch_shape + event_shape`` when
        ``expand=True``, or with batch dims kept at 1 when ``expand=False``
        (the broadcast-friendly form the `enum` messenger uses)."""
        if self.is_discrete:
            raise NotImplementedError(
                f"{type(self).__name__} has no enumerate_support: its support is "
                "countably infinite or combinatorially large. Bound it explicitly "
                "(e.g. a Categorical over a truncated range, or Binomial with a "
                "finite total_count) or marginalize this site by hand."
            )
        raise NotImplementedError(
            f"{type(self).__name__} is continuous and cannot be enumerated; "
            "parallel enumeration only applies to discrete sites — use a "
            "reparameterized sample (SVI) or MCMC for this site instead."
        )

    # -- combinators ---------------------------------------------------------
    def to_event(self, reinterpreted_batch_ndims: Optional[int] = None):
        from .wrappers import Independent

        if reinterpreted_batch_ndims is None:
            reinterpreted_batch_ndims = len(self._batch_shape)
        if reinterpreted_batch_ndims == 0:
            return self
        return Independent(self, reinterpreted_batch_ndims)

    def mask(self, mask):
        from .wrappers import MaskedDistribution

        return MaskedDistribution(self, mask)

    def expand(self, batch_shape):
        from .wrappers import ExpandedDistribution

        batch_shape = tuple(batch_shape)
        if batch_shape == self.batch_shape:
            return self
        return ExpandedDistribution(self, batch_shape)

    def expand_by(self, sample_shape):
        return self.expand(tuple(sample_shape) + self.batch_shape)

    # -- SVI helpers -----------------------------------------------------------
    def score_function_term(self, value):
        """log_prob used for REINFORCE terms on non-reparam sites."""
        return self.log_prob(value)

    def sample_with_intermediates(self, key, sample_shape=()):
        return self.sample(key, sample_shape), []

    def __repr__(self):
        return f"{type(self).__name__}(batch_shape={self.batch_shape}, event_shape={self.event_shape})"


def validate_sample_shape(dist: Distribution, value) -> None:
    expected = dist.batch_shape + dist.event_shape
    got = jnp.shape(value)
    try:
        broadcast_shapes(got, expected)
    except ValueError as e:
        raise ValueError(
            f"value shape {got} incompatible with distribution shape {expected}"
        ) from e
