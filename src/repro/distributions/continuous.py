"""Continuous distributions."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp

from . import constraints
from .distribution import Distribution
from .util import broadcast_shapes, promote_shapes, von_mises_centered


class Normal(Distribution):
    arg_constraints = {"loc": constraints.real, "scale": constraints.positive}
    support = constraints.real
    has_rsample = True

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = promote_shapes(loc, scale)
        batch_shape = broadcast_shapes(jnp.shape(loc), jnp.shape(scale))
        super().__init__(batch_shape)

    def sample(self, key, sample_shape=()):
        eps = jax.random.normal(key, self.shape(sample_shape), jnp.result_type(self.loc, float))
        return self.loc + self.scale * eps

    def log_prob(self, value):
        var = self.scale ** 2
        log_scale = jnp.log(self.scale)
        return -((value - self.loc) ** 2) / (2 * var) - log_scale - 0.5 * math.log(2 * math.pi)

    @property
    def mean(self):
        return jnp.broadcast_to(self.loc, self.batch_shape)

    @property
    def variance(self):
        return jnp.broadcast_to(self.scale ** 2, self.batch_shape)

    def entropy(self):
        return jnp.broadcast_to(0.5 + 0.5 * math.log(2 * math.pi) + jnp.log(self.scale), self.batch_shape)

    def cdf(self, value):
        return 0.5 * (1 + jsp.erf((value - self.loc) / (self.scale * math.sqrt(2))))

    def icdf(self, q):
        return self.loc + self.scale * math.sqrt(2) * jsp.erfinv(2 * q - 1)

    def to_information_form(self):
        """Natural parameters ``(precision, info_vec, log_normalizer)`` of
        the density as a quadratic in the value:

            log p(x) = -1/2 precision x^2 + info_vec x + log_normalizer

        with precision = 1/σ², info_vec = μ/σ², every leaf broadcast to
        `batch_shape` — the scalar seed the Gaussian-semiring VE engine
        builds its factors from."""
        prec = jnp.broadcast_to(self.scale ** -2.0, self.batch_shape)
        loc = jnp.broadcast_to(self.loc, self.batch_shape)
        info = prec * loc
        log_norm = (
            -0.5 * info * loc
            - jnp.broadcast_to(jnp.log(self.scale), self.batch_shape)
            - 0.5 * math.log(2 * math.pi)
        )
        return prec, info, log_norm

    @classmethod
    def from_information_form(cls, precision, info_vec):
        """Inverse of `to_information_form` (the log-normalizer is implied
        by normalization): N(info_vec / precision, precision**-0.5)."""
        precision = jnp.asarray(precision)
        return cls(loc=info_vec / precision, scale=precision ** -0.5)


class LogNormal(Distribution):
    arg_constraints = {"loc": constraints.real, "scale": constraints.positive}
    support = constraints.positive
    has_rsample = True

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = promote_shapes(loc, scale)
        super().__init__(broadcast_shapes(jnp.shape(loc), jnp.shape(scale)))

    def sample(self, key, sample_shape=()):
        return jnp.exp(Normal(self.loc, self.scale).sample(key, sample_shape))

    def log_prob(self, value):
        return Normal(self.loc, self.scale).log_prob(jnp.log(value)) - jnp.log(value)

    @property
    def mean(self):
        return jnp.exp(self.loc + self.scale ** 2 / 2)

    @property
    def variance(self):
        return (jnp.exp(self.scale ** 2) - 1) * jnp.exp(2 * self.loc + self.scale ** 2)


class Uniform(Distribution):
    has_rsample = True

    def __init__(self, low=0.0, high=1.0):
        self.low, self.high = promote_shapes(low, high)
        super().__init__(broadcast_shapes(jnp.shape(low), jnp.shape(high)))
        self.support = constraints.interval(low, high)

    arg_constraints = {"low": constraints.real, "high": constraints.real}

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape))
        return self.low + (self.high - self.low) * u

    def log_prob(self, value):
        inside = (value >= self.low) & (value <= self.high)
        lp = -jnp.log(self.high - self.low)
        return jnp.where(inside, lp, -jnp.inf)

    @property
    def mean(self):
        return (self.low + self.high) / 2

    @property
    def variance(self):
        return (self.high - self.low) ** 2 / 12


class Exponential(Distribution):
    arg_constraints = {"rate": constraints.positive}
    support = constraints.positive
    has_rsample = True

    def __init__(self, rate=1.0):
        self.rate = rate
        super().__init__(jnp.shape(rate))

    def sample(self, key, sample_shape=()):
        return jax.random.exponential(key, self.shape(sample_shape)) / self.rate

    def log_prob(self, value):
        return jnp.log(self.rate) - self.rate * value

    @property
    def mean(self):
        return 1.0 / self.rate

    @property
    def variance(self):
        return 1.0 / self.rate ** 2


class Laplace(Distribution):
    arg_constraints = {"loc": constraints.real, "scale": constraints.positive}
    support = constraints.real
    has_rsample = True

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = promote_shapes(loc, scale)
        super().__init__(broadcast_shapes(jnp.shape(loc), jnp.shape(scale)))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), minval=-0.5 + 1e-7, maxval=0.5)
        return self.loc - self.scale * jnp.sign(u) * jnp.log1p(-2 * jnp.abs(u))

    def log_prob(self, value):
        return -jnp.abs(value - self.loc) / self.scale - jnp.log(2 * self.scale)

    @property
    def mean(self):
        return jnp.broadcast_to(self.loc, self.batch_shape)

    @property
    def variance(self):
        return jnp.broadcast_to(2 * self.scale ** 2, self.batch_shape)


class Cauchy(Distribution):
    arg_constraints = {"loc": constraints.real, "scale": constraints.positive}
    support = constraints.real
    has_rsample = True

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = promote_shapes(loc, scale)
        super().__init__(broadcast_shapes(jnp.shape(loc), jnp.shape(scale)))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), minval=1e-7, maxval=1 - 1e-7)
        return self.loc + self.scale * jnp.tan(jnp.pi * (u - 0.5))

    def log_prob(self, value):
        z = (value - self.loc) / self.scale
        return -math.log(math.pi) - jnp.log(self.scale) - jnp.log1p(z ** 2)


class HalfNormal(Distribution):
    arg_constraints = {"scale": constraints.positive}
    support = constraints.positive
    has_rsample = True

    def __init__(self, scale=1.0):
        self.scale = scale
        super().__init__(jnp.shape(scale))

    def sample(self, key, sample_shape=()):
        return jnp.abs(Normal(0.0, self.scale).sample(key, sample_shape))

    def log_prob(self, value):
        return Normal(0.0, self.scale).log_prob(value) + math.log(2.0)

    @property
    def mean(self):
        return self.scale * math.sqrt(2 / math.pi)

    @property
    def variance(self):
        return self.scale ** 2 * (1 - 2 / math.pi)


class HalfCauchy(Distribution):
    arg_constraints = {"scale": constraints.positive}
    support = constraints.positive
    has_rsample = True

    def __init__(self, scale=1.0):
        self.scale = scale
        super().__init__(jnp.shape(scale))

    def sample(self, key, sample_shape=()):
        return jnp.abs(Cauchy(0.0, self.scale).sample(key, sample_shape))

    def log_prob(self, value):
        return Cauchy(0.0, self.scale).log_prob(value) + math.log(2.0)


class StudentT(Distribution):
    arg_constraints = {
        "df": constraints.positive,
        "loc": constraints.real,
        "scale": constraints.positive,
    }
    support = constraints.real
    has_rsample = True

    def __init__(self, df, loc=0.0, scale=1.0):
        self.df, self.loc, self.scale = promote_shapes(df, loc, scale)
        super().__init__(broadcast_shapes(jnp.shape(df), jnp.shape(loc), jnp.shape(scale)))

    def sample(self, key, sample_shape=()):
        key_n, key_g = jax.random.split(key)
        shape = self.shape(sample_shape)
        z = jax.random.normal(key_n, shape)
        g = jax.random.gamma(key_g, jnp.broadcast_to(self.df / 2, shape))
        return self.loc + self.scale * z * jnp.sqrt(self.df / (2 * g))

    def log_prob(self, value):
        z = (value - self.loc) / self.scale
        lp = (
            jsp.gammaln((self.df + 1) / 2)
            - jsp.gammaln(self.df / 2)
            - 0.5 * jnp.log(self.df * math.pi)
            - jnp.log(self.scale)
            - (self.df + 1) / 2 * jnp.log1p(z ** 2 / self.df)
        )
        return lp

    @property
    def mean(self):
        # defined for df > 1
        return jnp.broadcast_to(
            jnp.where(jnp.asarray(self.df) > 1, self.loc, jnp.nan), self.batch_shape
        )

    @property
    def variance(self):
        # defined for df > 2 (infinite for 1 < df <= 2)
        df = jnp.asarray(self.df, jnp.result_type(float))
        var = jnp.asarray(self.scale) ** 2 * df / (df - 2)
        var = jnp.where(df > 2, var, jnp.where(df > 1, jnp.inf, jnp.nan))
        return jnp.broadcast_to(var, self.batch_shape)


class Gamma(Distribution):
    arg_constraints = {"concentration": constraints.positive, "rate": constraints.positive}
    support = constraints.positive
    has_rsample = True  # jax.random.gamma is reparametrized (implicit grads)

    def __init__(self, concentration, rate=1.0):
        self.concentration, self.rate = promote_shapes(concentration, rate)
        super().__init__(broadcast_shapes(jnp.shape(concentration), jnp.shape(rate)))

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        return jax.random.gamma(key, jnp.broadcast_to(self.concentration, shape)) / self.rate

    def log_prob(self, value):
        return (
            self.concentration * jnp.log(self.rate)
            + (self.concentration - 1) * jnp.log(value)
            - self.rate * value
            - jsp.gammaln(self.concentration)
        )

    @property
    def mean(self):
        return self.concentration / self.rate

    @property
    def variance(self):
        return self.concentration / self.rate ** 2


class Chi2(Gamma):
    def __init__(self, df):
        self.df = df
        super().__init__(df / 2, 0.5)


class InverseGamma(Distribution):
    arg_constraints = {"concentration": constraints.positive, "rate": constraints.positive}
    support = constraints.positive
    has_rsample = True

    def __init__(self, concentration, rate=1.0):
        self.concentration, self.rate = promote_shapes(concentration, rate)
        super().__init__(broadcast_shapes(jnp.shape(concentration), jnp.shape(rate)))

    def sample(self, key, sample_shape=()):
        return 1.0 / Gamma(self.concentration, self.rate).sample(key, sample_shape)

    def log_prob(self, value):
        return Gamma(self.concentration, self.rate).log_prob(1 / value) - 2 * jnp.log(value)

    @property
    def mean(self):
        # defined for concentration > 1
        a = jnp.asarray(self.concentration, jnp.result_type(float))
        return jnp.broadcast_to(
            jnp.where(a > 1, self.rate / (a - 1), jnp.inf), self.batch_shape
        )

    @property
    def variance(self):
        # defined for concentration > 2
        a = jnp.asarray(self.concentration, jnp.result_type(float))
        var = jnp.asarray(self.rate) ** 2 / ((a - 1) ** 2 * (a - 2))
        return jnp.broadcast_to(jnp.where(a > 2, var, jnp.inf), self.batch_shape)


class Beta(Distribution):
    arg_constraints = {
        "concentration1": constraints.positive,
        "concentration0": constraints.positive,
    }
    support = constraints.unit_interval
    has_rsample = True

    def __init__(self, concentration1, concentration0):
        self.concentration1, self.concentration0 = promote_shapes(concentration1, concentration0)
        super().__init__(
            broadcast_shapes(jnp.shape(concentration1), jnp.shape(concentration0))
        )

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        k1, k2 = jax.random.split(key)
        g1 = jax.random.gamma(k1, jnp.broadcast_to(self.concentration1, shape))
        g2 = jax.random.gamma(k2, jnp.broadcast_to(self.concentration0, shape))
        return g1 / (g1 + g2)

    def log_prob(self, value):
        a, b = self.concentration1, self.concentration0
        return (
            (a - 1) * jnp.log(value)
            + (b - 1) * jnp.log1p(-value)
            + jsp.gammaln(a + b)
            - jsp.gammaln(a)
            - jsp.gammaln(b)
        )

    @property
    def mean(self):
        return self.concentration1 / (self.concentration1 + self.concentration0)

    @property
    def variance(self):
        a, b = self.concentration1, self.concentration0
        return a * b / ((a + b) ** 2 * (a + b + 1))


class Dirichlet(Distribution):
    arg_constraints = {"concentration": constraints.positive}
    support = constraints.simplex
    has_rsample = True

    def __init__(self, concentration):
        self.concentration = jnp.asarray(concentration)
        super().__init__(self.concentration.shape[:-1], self.concentration.shape[-1:])

    def sample(self, key, sample_shape=()):
        shape = tuple(sample_shape) + self.batch_shape + self.event_shape
        g = jax.random.gamma(key, jnp.broadcast_to(self.concentration, shape))
        return g / g.sum(-1, keepdims=True)

    def log_prob(self, value):
        a = self.concentration
        return (
            jnp.sum((a - 1) * jnp.log(value), -1)
            + jsp.gammaln(a.sum(-1))
            - jnp.sum(jsp.gammaln(a), -1)
        )

    @property
    def mean(self):
        return self.concentration / self.concentration.sum(-1, keepdims=True)

    @property
    def variance(self):
        a = self.concentration
        a0 = a.sum(-1, keepdims=True)
        return a * (a0 - a) / (a0 ** 2 * (a0 + 1))


class MultivariateNormal(Distribution):
    arg_constraints = {"loc": constraints.real_vector}
    support = constraints.real_vector
    has_rsample = True

    def __init__(self, loc, covariance_matrix=None, scale_tril=None):
        loc = jnp.asarray(loc)
        if scale_tril is None:
            if covariance_matrix is None:
                raise ValueError("need covariance_matrix or scale_tril")
            scale_tril = jnp.linalg.cholesky(jnp.asarray(covariance_matrix))
        self.loc = loc
        self.scale_tril = jnp.asarray(scale_tril)
        batch_shape = broadcast_shapes(loc.shape[:-1], self.scale_tril.shape[:-2])
        super().__init__(batch_shape, loc.shape[-1:])

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        eps = jax.random.normal(key, shape)
        return self.loc + jnp.einsum("...ij,...j->...i", self.scale_tril, eps)

    def log_prob(self, value):
        d = value.shape[-1]
        diff = value - self.loc
        # solve_triangular does NOT broadcast batch dims (sample dims of the
        # value vs parameter batch) — align both operands explicitly
        batch = broadcast_shapes(diff.shape[:-1], self.scale_tril.shape[:-2])
        tril = jnp.broadcast_to(self.scale_tril, batch + self.scale_tril.shape[-2:])
        diff = jnp.broadcast_to(diff, batch + diff.shape[-1:])
        y = jax.scipy.linalg.solve_triangular(tril, diff[..., None], lower=True)[..., 0]
        half_log_det = jnp.sum(jnp.log(jnp.diagonal(self.scale_tril, axis1=-2, axis2=-1)), -1)
        return -0.5 * jnp.sum(y ** 2, -1) - half_log_det - 0.5 * d * math.log(2 * math.pi)

    @property
    def mean(self):
        return jnp.broadcast_to(self.loc, self.batch_shape + self.event_shape)

    @property
    def variance(self):
        var = jnp.sum(self.scale_tril ** 2, -1)
        return jnp.broadcast_to(var, self.batch_shape + self.event_shape)

    @property
    def covariance_matrix(self):
        # broadcast to the full batch shape: loc-driven batch dims must show
        # up even though the covariance itself only carries scale_tril's
        cov = self.scale_tril @ jnp.swapaxes(self.scale_tril, -1, -2)
        return jnp.broadcast_to(cov, self.batch_shape + cov.shape[-2:])

    @property
    def precision_matrix(self):
        eye = jnp.eye(self.event_shape[0], dtype=self.scale_tril.dtype)
        tril = jnp.broadcast_to(
            self.scale_tril, self.batch_shape + self.scale_tril.shape[-2:]
        )
        inv_tril = jax.scipy.linalg.solve_triangular(
            tril, jnp.broadcast_to(eye, tril.shape), lower=True
        )
        return jnp.swapaxes(inv_tril, -1, -2) @ inv_tril

    def to_information_form(self):
        """Natural parameters ``(precision, info_vec, log_normalizer)`` of
        the density as a quadratic in the value:

            log p(x) = -1/2 x^T precision x + info_vec^T x + log_normalizer

        with precision = Σ⁻¹ and info_vec = Σ⁻¹μ. All leaves broadcast to
        the full `batch_shape` — loc-only and scale_tril-only batch dims
        both surface, so batched parameters round-trip exactly."""
        d = self.event_shape[0]
        prec = self.precision_matrix                      # (*batch, d, d)
        loc = jnp.broadcast_to(self.loc, self.batch_shape + (d,))
        info = (prec @ loc[..., None])[..., 0]
        half_log_det = jnp.broadcast_to(
            jnp.sum(jnp.log(jnp.diagonal(self.scale_tril, axis1=-2, axis2=-1)), -1),
            self.batch_shape,
        )
        log_norm = (
            -0.5 * jnp.sum(info * loc, -1)
            - half_log_det
            - 0.5 * d * math.log(2 * math.pi)
        )
        return prec, info, log_norm

    @classmethod
    def from_information_form(cls, precision, info_vec):
        """Inverse of `to_information_form` (the log-normalizer is implied
        by normalization): MVN(Σ info_vec, Σ = precision⁻¹). Batch dims of
        the two operands broadcast."""
        precision = jnp.asarray(precision)
        info_vec = jnp.asarray(info_vec)
        cov = jnp.linalg.inv(precision)
        cov = 0.5 * (cov + jnp.swapaxes(cov, -1, -2))
        batch = broadcast_shapes(cov.shape[:-2], info_vec.shape[:-1])
        loc = (
            jnp.broadcast_to(cov, batch + cov.shape[-2:])
            @ jnp.broadcast_to(info_vec, batch + info_vec.shape[-1:])[..., None]
        )[..., 0]
        return cls(loc=loc, covariance_matrix=cov)


class LowRankMultivariateNormal(Distribution):
    """MVN with covariance = cov_factor @ cov_factor^T + diag(cov_diag)."""

    support = constraints.real_vector
    has_rsample = True

    def __init__(self, loc, cov_factor, cov_diag):
        self.loc = jnp.asarray(loc)
        self.cov_factor = jnp.asarray(cov_factor)  # (..., D, K)
        self.cov_diag = jnp.asarray(cov_diag)  # (..., D)
        d = self.loc.shape[-1]
        if self.cov_factor.shape[-2] != d or self.cov_diag.shape[-1] != d:
            raise ValueError(
                f"event size mismatch: loc has D={d}, cov_factor "
                f"{self.cov_factor.shape[-2:]}, cov_diag {self.cov_diag.shape[-1:]}"
            )
        # batch shape must broadcast ALL three parameter batches (batched
        # cov_factor/cov_diag with scalar-batch loc used to be dropped)
        batch_shape = broadcast_shapes(
            self.loc.shape[:-1], self.cov_factor.shape[:-2], self.cov_diag.shape[:-1]
        )
        super().__init__(batch_shape, self.loc.shape[-1:])

    def sample(self, key, sample_shape=()):
        k1, k2 = jax.random.split(key)
        k_dim = self.cov_factor.shape[-1]
        shape = tuple(sample_shape) + self.batch_shape
        eps_w = jax.random.normal(k1, shape + (k_dim,))
        eps_d = jax.random.normal(k2, shape + self.event_shape)
        return (
            self.loc
            + jnp.einsum("...dk,...k->...d", self.cov_factor, eps_w)
            + jnp.sqrt(self.cov_diag) * eps_d
        )

    def log_prob(self, value):
        # Woodbury + matrix determinant lemma
        d = self.loc.shape[-1]
        w = self.cov_factor
        k_dim = w.shape[-1]
        diff = value - self.loc
        dinv = 1.0 / self.cov_diag
        wt_dinv = jnp.swapaxes(w, -1, -2) * dinv[..., None, :]
        capacitance = jnp.eye(k_dim) + wt_dinv @ w
        chol = jnp.linalg.cholesky(capacitance)
        # mahalanobis via woodbury; align batch dims — solve_triangular does
        # not broadcast the value's sample dims against the parameter batch
        wt_dinv_diff = jnp.einsum("...kd,...d->...k", wt_dinv, diff)
        batch = broadcast_shapes(wt_dinv_diff.shape[:-1], chol.shape[:-2])
        chol_b = jnp.broadcast_to(chol, batch + chol.shape[-2:])
        wt_dinv_diff = jnp.broadcast_to(wt_dinv_diff, batch + wt_dinv_diff.shape[-1:])
        y = jax.scipy.linalg.solve_triangular(chol_b, wt_dinv_diff[..., None], lower=True)[..., 0]
        maha = jnp.sum(diff ** 2 * dinv, -1) - jnp.sum(y ** 2, -1)
        log_det = (
            jnp.sum(jnp.log(self.cov_diag), -1)
            + 2 * jnp.sum(jnp.log(jnp.diagonal(chol, axis1=-2, axis2=-1)), -1)
        )
        return -0.5 * (d * math.log(2 * math.pi) + log_det + maha)

    @property
    def mean(self):
        return jnp.broadcast_to(self.loc, self.batch_shape + self.event_shape)

    @property
    def variance(self):
        var = self.cov_diag + jnp.sum(self.cov_factor ** 2, -1)
        return jnp.broadcast_to(var, self.batch_shape + self.event_shape)

    @property
    def covariance_matrix(self):
        return self.cov_factor @ jnp.swapaxes(self.cov_factor, -1, -2) + jnp.vectorize(
            jnp.diag, signature="(d)->(d,d)"
        )(jnp.broadcast_to(self.cov_diag, self.batch_shape + self.event_shape))


class VonMises(Distribution):
    arg_constraints = {"loc": constraints.real, "concentration": constraints.positive}
    support = constraints.circular

    def __init__(self, loc, concentration):
        self.loc, self.concentration = promote_shapes(loc, concentration)
        super().__init__(broadcast_shapes(jnp.shape(loc), jnp.shape(concentration)))

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        centered = von_mises_centered(key, self.concentration, shape)
        return (centered + self.loc + jnp.pi) % (2 * jnp.pi) - jnp.pi

    def log_prob(self, value):
        return (
            self.concentration * jnp.cos(value - self.loc)
            - math.log(2 * math.pi)
            - jnp.log(jsp.i0e(self.concentration))
            - self.concentration
        )

    @property
    def mean(self):
        return jnp.broadcast_to(self.loc, self.batch_shape)

    @property
    def variance(self):
        # circular variance: 1 - I1(k)/I0(k)
        k = self.concentration
        return jnp.broadcast_to(1.0 - jsp.i1e(k) / jsp.i0e(k), self.batch_shape)


class Logistic(Distribution):
    arg_constraints = {"loc": constraints.real, "scale": constraints.positive}
    support = constraints.real
    has_rsample = True

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = promote_shapes(loc, scale)
        super().__init__(broadcast_shapes(jnp.shape(loc), jnp.shape(scale)))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), minval=1e-7, maxval=1 - 1e-7)
        return self.loc + self.scale * (jnp.log(u) - jnp.log1p(-u))

    def log_prob(self, value):
        z = (value - self.loc) / self.scale
        return -z - 2 * jax.nn.softplus(-z) - jnp.log(self.scale)

    @property
    def mean(self):
        return jnp.broadcast_to(self.loc, self.batch_shape)

    @property
    def variance(self):
        return jnp.broadcast_to(
            jnp.asarray(self.scale) ** 2 * math.pi ** 2 / 3, self.batch_shape
        )


class Weibull(Distribution):
    arg_constraints = {"scale": constraints.positive, "concentration": constraints.positive}
    support = constraints.positive
    has_rsample = True

    def __init__(self, scale, concentration):
        self.scale, self.concentration = promote_shapes(scale, concentration)
        super().__init__(broadcast_shapes(jnp.shape(scale), jnp.shape(concentration)))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), minval=1e-7, maxval=1 - 1e-7)
        return self.scale * (-jnp.log1p(-u)) ** (1 / self.concentration)

    def log_prob(self, value):
        k = self.concentration
        return (
            jnp.log(k / self.scale)
            + (k - 1) * (jnp.log(value) - jnp.log(self.scale))
            - (value / self.scale) ** k
        )

    @property
    def mean(self):
        k = self.concentration
        return self.scale * jnp.exp(jsp.gammaln(1 + 1 / k))

    @property
    def variance(self):
        k = self.concentration
        g1 = jnp.exp(jsp.gammaln(1 + 1 / k))
        g2 = jnp.exp(jsp.gammaln(1 + 2 / k))
        return jnp.asarray(self.scale) ** 2 * (g2 - g1 ** 2)
