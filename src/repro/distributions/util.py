"""Shape/broadcast utilities shared by the distributions library."""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def lazy_property(fn):
    attr = "_lazy_" + fn.__name__

    @property
    def wrapped(self):
        if not hasattr(self, attr):
            object.__setattr__(self, attr, fn(self))
        return getattr(self, attr)

    return wrapped


def broadcast_shapes(*shapes: Sequence[int]) -> Tuple[int, ...]:
    return tuple(np.broadcast_shapes(*[tuple(s) for s in shapes]))


def promote_shapes(*args, shape=()):
    """Left-pad arrays so they broadcast against each other (and `shape`)."""
    if len(args) < 2 and not shape:
        return args
    shapes = [jnp.shape(a) for a in args]
    num_dims = len(broadcast_shapes(shape, *shapes))
    return [
        a if len(s) == num_dims else jnp.reshape(a, (1,) * (num_dims - len(s)) + s)
        for a, s in zip(args, shapes)
    ]


def sum_rightmost(x: jax.Array, dim: int) -> jax.Array:
    """Sum the rightmost `dim` dimensions of `x` (dim may be 0)."""
    if dim == 0:
        return x
    return jnp.sum(x, axis=tuple(range(-dim, 0)))


def safe_log(x):
    return jnp.log(jnp.clip(x, a_min=jnp.finfo(jnp.result_type(float)).tiny))


def clamp_probs(probs):
    finfo = jnp.finfo(jnp.result_type(probs, float))
    return jnp.clip(probs, finfo.tiny, 1.0 - finfo.eps)


def binary_cross_entropy_with_logits(logits, targets):
    # -targets * log sigmoid(logits) - (1-targets) * log(1 - sigmoid(logits)).
    # NOTE: the classic max(l,0)+log1p(exp(-|l|))-l*t form has a kinked,
    # WRONG subgradient at exactly logits==0 (i.e. p=0.5 — the standard
    # init!), which biased score-function ELBO gradients (caught by
    # tests/test_infer_extra.py). log_sigmoid is smooth and equally stable.
    return -(targets * jax.nn.log_sigmoid(logits)
             + (1.0 - targets) * jax.nn.log_sigmoid(-logits))


def logits_to_probs(logits, is_binary=False):
    if is_binary:
        return jax.nn.sigmoid(logits)
    return jax.nn.softmax(logits, axis=-1)


def probs_to_logits(probs, is_binary=False):
    probs = clamp_probs(probs)
    if is_binary:
        return jnp.log(probs) - jnp.log1p(-probs)
    return jnp.log(probs)


def multigammaln(a, d):
    constant = 0.25 * d * (d - 1) * math.log(math.pi)
    res = jnp.sum(jax.scipy.special.gammaln(a[..., None] - 0.5 * jnp.arange(d)), axis=-1)
    return res + constant


def is_prng_key(key) -> bool:
    try:
        if isinstance(key, jax.Array):
            return jnp.issubdtype(key.dtype, jax.dtypes.prng_key) or (
                key.dtype == jnp.uint32 and key.shape[-1:] == (2,)
            )
    except Exception:
        pass
    return False


def von_mises_centered(key, concentration, shape, dtype=jnp.float64):
    """Best-Fisher rejection sampling for VonMises(0, concentration).

    Implemented with a fixed 32-round loop (accept-first) so it is jittable.
    """
    conc = jnp.broadcast_to(concentration, shape).astype(jnp.float32)
    r = 1.0 + jnp.sqrt(1.0 + 4.0 * conc ** 2)
    rho = (r - jnp.sqrt(2.0 * r)) / (2.0 * conc)
    s_ = (1.0 + rho ** 2) / (2.0 * rho)
    small = conc < 1e-4  # fall back to uniform for tiny concentration

    def body(i, carry):
        out, done, k = carry
        k, k1, k2, k3 = jax.random.split(k, 4)
        u1 = jax.random.uniform(k1, shape)
        u2 = jax.random.uniform(k2, shape)
        u3 = jax.random.uniform(k3, shape)
        z = jnp.cos(jnp.pi * u1)
        f = (1.0 + s_ * z) / (s_ + z)
        c = conc * (s_ - f)
        accept = (c * (2.0 - c) - u2 > 0) | (jnp.log(c / jnp.clip(u2, 1e-37)) + 1.0 - c >= 0)
        sample = jnp.sign(u3 - 0.5) * jnp.arccos(jnp.clip(f, -1.0, 1.0))
        out = jnp.where(done | ~accept, out, sample)
        done = done | accept
        return out, done, k

    init = (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, bool), key)
    out, _, _ = jax.lax.fori_loop(0, 32, body, init)
    uniform = jax.random.uniform(key, shape, minval=-jnp.pi, maxval=jnp.pi)
    return jnp.where(small, uniform, out).astype(dtype)
