"""Support constraints, mirroring torch.distributions.constraints (paper §3).

Each constraint knows how to `check` a value; `biject_to` (in transforms.py)
maps a constraint to a bijector from unconstrained space — the mechanism
autoguides and HMC use to work in R^n.
"""
from __future__ import annotations

import jax.numpy as jnp


class Constraint:
    is_discrete = False
    event_dim = 0

    def __call__(self, x):
        return self.check(x)

    def check(self, value):
        raise NotImplementedError

    def __repr__(self):
        return self.__class__.__name__[1:].strip("_")


class _Real(Constraint):
    def check(self, value):
        return jnp.isfinite(value)


class _RealVector(Constraint):
    event_dim = 1

    def check(self, value):
        return jnp.all(jnp.isfinite(value), axis=-1)


class _Positive(Constraint):
    def check(self, value):
        return value > 0


class _Nonnegative(Constraint):
    def check(self, value):
        return value >= 0


class _UnitInterval(Constraint):
    def check(self, value):
        return (value >= 0) & (value <= 1)


class _Interval(Constraint):
    def __init__(self, lower, upper):
        self.lower_bound = lower
        self.upper_bound = upper

    def check(self, value):
        return (value >= self.lower_bound) & (value <= self.upper_bound)

    def __repr__(self):
        return f"interval(lower_bound={self.lower_bound}, upper_bound={self.upper_bound})"


class _GreaterThan(Constraint):
    def __init__(self, lower):
        self.lower_bound = lower

    def check(self, value):
        return value > self.lower_bound


class _LessThan(Constraint):
    def __init__(self, upper):
        self.upper_bound = upper

    def check(self, value):
        return value < self.upper_bound


class _Boolean(Constraint):
    is_discrete = True

    def check(self, value):
        return (value == 0) | (value == 1)


class _IntegerInterval(Constraint):
    is_discrete = True

    def __init__(self, lower, upper):
        self.lower_bound = lower
        self.upper_bound = upper

    def check(self, value):
        return (value >= self.lower_bound) & (value <= self.upper_bound) & (value == jnp.floor(value))


class _NonnegativeInteger(Constraint):
    is_discrete = True

    def check(self, value):
        return (value >= 0) & (value == jnp.floor(value))


class _Simplex(Constraint):
    event_dim = 1

    def check(self, value):
        return jnp.all(value >= 0, axis=-1) & (jnp.abs(value.sum(-1) - 1.0) < 1e-6)


class _LowerCholesky(Constraint):
    event_dim = 2

    def check(self, value):
        tril = jnp.tril(value)
        lower = jnp.all((tril == value).reshape(value.shape[:-2] + (-1,)), axis=-1)
        positive_diag = jnp.all(jnp.diagonal(value, axis1=-2, axis2=-1) > 0, axis=-1)
        return lower & positive_diag


class _PositiveDefinite(Constraint):
    event_dim = 2

    def check(self, value):
        symmetric = jnp.all(
            jnp.isclose(value, jnp.swapaxes(value, -1, -2)).reshape(value.shape[:-2] + (-1,)),
            axis=-1,
        )
        eigvals = jnp.linalg.eigvalsh(value)
        return symmetric & jnp.all(eigvals > 0, axis=-1)


class _Circular(Constraint):
    def check(self, value):
        return (value >= -jnp.pi) & (value <= jnp.pi)


class _Dependent(Constraint):
    def check(self, value):
        raise ValueError("Cannot check a dependent constraint")


real = _Real()
real_vector = _RealVector()
positive = _Positive()
nonnegative = _Nonnegative()
unit_interval = _UnitInterval()
interval = _Interval
greater_than = _GreaterThan
less_than = _LessThan
boolean = _Boolean()
integer_interval = _IntegerInterval
nonnegative_integer = _NonnegativeInteger()
simplex = _Simplex()
lower_cholesky = _LowerCholesky()
positive_definite = _PositiveDefinite()
circular = _Circular()
dependent = _Dependent()
