"""repro.distributions — a jnp-native distributions library (paper §3).

Mirrors the torch.distributions API the Pyro authors upstreamed: shape
semantics (batch_shape / event_shape), constraints, transforms, and KL
registry, rebuilt functionally so every object composes with jit/pjit/vmap.
"""
from . import constraints, transforms
from .continuous import (
    Beta,
    Cauchy,
    Chi2,
    Dirichlet,
    Exponential,
    Gamma,
    HalfCauchy,
    HalfNormal,
    InverseGamma,
    Laplace,
    Logistic,
    LogNormal,
    LowRankMultivariateNormal,
    MultivariateNormal,
    Normal,
    StudentT,
    Uniform,
    VonMises,
    Weibull,
)
from .discrete import (
    Bernoulli,
    Binomial,
    Categorical,
    Geometric,
    Multinomial,
    NegativeBinomial,
    OneHotCategorical,
    Poisson,
)
from .distribution import Distribution
from .kl import kl_divergence, register_kl
from .transforms import (
    AffineTransform,
    ComposeTransform,
    ExpTransform,
    IdentityTransform,
    IndependentTransform,
    InverseAutoregressiveTransform,
    LowerCholeskyTransform,
    PermuteTransform,
    PowerTransform,
    SigmoidTransform,
    SoftplusTransform,
    StickBreakingTransform,
    TanhTransform,
    Transform,
    biject_to,
    init_made_params,
    made_apply,
    made_masks,
)
from .wrappers import (
    Delta,
    ExpandedDistribution,
    Independent,
    MaskedDistribution,
    MixtureSameFamily,
    TransformedDistribution,
    Unit,
)

__all__ = [
    "constraints",
    "transforms",
    "Distribution",
    "kl_divergence",
    "register_kl",
    "biject_to",
    # continuous
    "Beta",
    "Cauchy",
    "Chi2",
    "Dirichlet",
    "Exponential",
    "Gamma",
    "HalfCauchy",
    "HalfNormal",
    "InverseGamma",
    "Laplace",
    "Logistic",
    "LogNormal",
    "LowRankMultivariateNormal",
    "MultivariateNormal",
    "Normal",
    "StudentT",
    "Uniform",
    "VonMises",
    "Weibull",
    # discrete
    "Bernoulli",
    "Binomial",
    "Categorical",
    "Geometric",
    "Multinomial",
    "NegativeBinomial",
    "OneHotCategorical",
    "Poisson",
    # wrappers
    "Delta",
    "ExpandedDistribution",
    "Independent",
    "MaskedDistribution",
    "MixtureSameFamily",
    "TransformedDistribution",
    "Unit",
]
