"""Chunked, topology-independent checkpointing with atomic manifests and
async save (DESIGN.md §6).

Layout:
    <dir>/step_000123/
        manifest.json        # written LAST (atomic rename) => commit point
        shard_00000.npz      # leaf chunks (one file per writer process)
Design properties:
  * topology-independent: leaves are saved as full logical arrays (gathered
    per-leaf), so a restart may use a different mesh/process count and
    simply reshards on restore (elastic re-mesh);
  * atomic: a step directory without manifest.json is garbage; writers
    stage to `.tmp-*` and rename;
  * async: `save_async` snapshots device arrays to host then writes in a
    background thread, overlapping I/O with the next training steps;
  * self-describing: the manifest stores the pytree structure + dtypes +
    shapes, so restore needs no template (but can validate against one).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_names(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [
        ("/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path), leaf)
        for path, leaf in flat
    ]
    return named, treedef


def save(directory: str, step: int, tree: Any, *, max_keep: Optional[int] = 3) -> str:
    """Synchronous save. Returns the committed step directory."""
    named, _ = _flatten_with_names(tree)
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + f".tmp-{os.getpid()}"
    os.makedirs(tmp_dir, exist_ok=True)

    arrays = {}
    meta = {}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        arrays[key] = arr
        meta[name] = {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp_dir, "shard_00000.npz"), **arrays)
    with open(os.path.join(tmp_dir, _MANIFEST), "w") as f:
        json.dump({"step": step, "leaves": meta, "time": time.time()}, f)
    if os.path.isdir(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)  # commit
    if max_keep is not None:
        _gc(directory, max_keep)
    return step_dir


class AsyncCheckpointer:
    """Snapshot-to-host on the caller thread (cheap), file I/O off-thread.
    Thread-safe: concurrent `save_async`/`wait` callers serialize on an
    internal lock, preserving the one-outstanding-save contract."""

    def __init__(self, directory: str, max_keep: int = 3):
        self.directory = directory
        self.max_keep = max_keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()

    def save_async(self, step: int, tree: Any, *,
                   on_commit: Optional[Any] = None) -> None:
        """Snapshot `tree` to host now, write it in the background.

        ``on_commit(step)`` — if given — runs on the writer thread *after*
        the manifest rename commits the step. This is the streaming-service
        hot-swap hook: the trainer passes a callback that restores the step
        and `servable.refresh()`-es the server, so a swap can never observe
        a half-written checkpoint. A callback exception is surfaced by the
        next `save_async`/`wait`, like a write error."""
        with self._lock:
            self._wait_locked()  # one outstanding save at a time
            # copy=True: device_get of a host-resident (numpy / CPU-jax) leaf
            # returns a VIEW of the caller's buffer — without the copy, a
            # donated or in-place-updated buffer corrupts the checkpoint
            # mid-write.
            host_tree = jax.tree.map(
                lambda x: np.array(jax.device_get(x), copy=True), tree
            )

            def work():
                try:
                    save(self.directory, step, host_tree, max_keep=self.max_keep)
                    if on_commit is not None:
                        on_commit(step)
                except BaseException as e:  # pragma: no cover
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _wait_locked(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def wait(self) -> None:
        with self._lock:
            self._wait_locked()


def latest_step(directory: str) -> Optional[int]:
    """Highest committed (manifest-bearing) step, or None."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, _MANIFEST)):
            best = max(best or 0, int(m.group(1)))
    return best


def restore(directory: str, step: Optional[int] = None, *, template: Any = None,
            shardings: Any = None) -> Tuple[int, Any]:
    """Restore (step, tree). With `template`, the result follows the template
    treedef (validated); with `shardings`, leaves are device_put to the new
    topology (elastic re-mesh restore path)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(step_dir, "shard_00000.npz")) as z:
        by_name = {
            name: z[m["key"]] for name, m in manifest["leaves"].items()
        }

    if template is None:
        # build a nested dict from names
        tree: Dict[str, Any] = {}
        for name, arr in by_name.items():
            node = tree
            parts = name.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(arr)
        return step, tree

    named, treedef = _flatten_with_names(template)
    leaves = []
    for name, t_leaf in named:
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf '{name}'")
        arr = by_name[name]
        expected = tuple(getattr(t_leaf, "shape", arr.shape))
        if tuple(arr.shape) != expected:
            raise ValueError(f"shape mismatch at '{name}': {arr.shape} vs {expected}")
        leaves.append(arr)
    flat_shardings = None
    if shardings is not None:
        flat_shardings = [s for _, s in _flatten_with_names(shardings)[0]]
    out = []
    for i, arr in enumerate(leaves):
        if flat_shardings is not None:
            out.append(jax.device_put(arr, flat_shardings[i]))
        else:
            out.append(jnp.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(directory: str, *, template: Any = None,
                   shardings: Any = None) -> Tuple[int, Any]:
    """Restore the newest committed step — the server warm-start entry point
    (`repro.serve.ServableModel.from_checkpoint` boots through this, with
    ``shardings`` from the serving mesh for elastic re-mesh restore)."""
    return restore(directory, None, template=template, shardings=shardings)


def _gc(directory: str, max_keep: int) -> None:
    steps = sorted(
        int(m.group(1))
        for name in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", name))
        and os.path.exists(os.path.join(directory, name, _MANIFEST))
    )
    for s in steps[:-max_keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
