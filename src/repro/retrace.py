"""The `num_traces` retrace-counter contract.

Every compiled engine in this codebase exposes a ``num_traces`` property:
the number of distinct XLA executables behind its hot path. Recompilation
is the silent performance killer on accelerators — an engine that retraces
per step is 100–1000x slower than one that compiled once — so benches and
CI assert retrace stability *uniformly* through this contract instead of
each site inventing its own convention:

* `SVI.num_traces` — size of the `update_jit` cache (1 after any number of
  same-shape steps);
* `MCMC.num_traces` — trace-time counter on the fused/vmap driver (1 per
  (chains, shape) signature);
* `Predictive.num_traces` — size of the forward jit cache (1 per static
  partition);
* `CompiledServable.num_traces` — size of the padded-forward jit cache
  (``== len(buckets_touched)`` for a healthy server — one executable per
  shape bucket, never one per request).

`RetraceCounted` is the structural protocol (``isinstance`` works via
``runtime_checkable``); `assert_num_traces` is the shared test/bench
helper that failure-messages consistently.

`InferenceEngine` extends the contract to the sample-producing engines
(MCMC, SMC, ImportanceSampling): one surface — ``run(key, *args)`` to
execute, ``get_samples(group_by_chain=...)`` to read draws with a uniform
(chains/populations, draws, ...) axis convention, ``num_traces`` to assert
compile stability — so drivers, benches, and serving adapters can treat
"an inference engine" as a type instead of special-casing each algorithm.
The canonical kwarg spellings shared across engines (PR-9 config
playbook): ``num_samples`` counts posterior draws, ``num_particles``
counts i.i.d. particle replications, and ``mesh=``/``particle_axis=``
name the sharding; legacy spellings (`Importance(num_samples=...)` as a
particle count, `MCMC(chain_method=...)`) survive as FutureWarning
aliases with parity-pinned tests.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable


@runtime_checkable
class RetraceCounted(Protocol):
    """Anything with a ``num_traces`` retrace counter."""

    @property
    def num_traces(self) -> int: ...


@runtime_checkable
class InferenceEngine(Protocol):
    """A sample-producing inference engine: run it, read draws, audit its
    compile stability. Structural — MCMC, SMC, and ImportanceSampling all
    satisfy it without inheriting anything."""

    @property
    def num_traces(self) -> int: ...

    def run(self, rng_key, *args, **kwargs) -> Any: ...

    def get_samples(self, group_by_chain: bool = False) -> Any: ...


def num_traces(obj: RetraceCounted) -> int:
    """The retrace counter, validated to be a non-negative int."""
    n = obj.num_traces
    if not isinstance(n, int) or n < 0:
        raise TypeError(
            f"{type(obj).__name__}.num_traces must be a non-negative int, "
            f"got {n!r}"
        )
    return n


def assert_num_traces(obj: RetraceCounted, expected: int, context: str = "") -> None:
    """Assert the engine compiled exactly `expected` executables. Used by
    tests and benches so every retrace regression fails with the same
    message shape."""
    actual = num_traces(obj)
    if actual != expected:
        where = f" [{context}]" if context else ""
        raise AssertionError(
            f"{type(obj).__name__} retraced{where}: num_traces == {actual}, "
            f"expected {expected} — the hot path is recompiling"
        )
