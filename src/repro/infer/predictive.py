"""Posterior/prior predictive sampling (paper §2: inference in Pyro yields
objects that "can be used to form predictive distributions" — `Predictive`
replays posterior draws, guide samples, or the prior through the model and
collects the resulting sample/deterministic sites, fully vectorized with
`vmap` rather than a Python loop per draw).

`posterior_samples` may be flat ``(num_draws, ...)`` arrays (the default,
``batch_ndims=1``) or chain-grouped ``(num_chains, num_draws, ...)`` arrays
straight from ``MCMC.get_samples(group_by_chain=True)`` with
``batch_ndims=2`` — the predictive fan-out then nests one `vmap` per batch
dim, so multi-chain posterior-predictive sampling stays a single compiled
call with ``(chain, draw, ...)``-shaped outputs.

Example — prior predictive, then chain-shaped posterior predictive::

    >>> import jax, jax.numpy as jnp
    >>> from repro import distributions as dist
    >>> from repro.core import primitives as P
    >>> from repro.infer import Predictive
    >>> def model(data=None):
    ...     loc = P.sample("loc", dist.Normal(0.0, 1.0))
    ...     with P.plate("N", 3):
    ...         P.sample("obs", dist.Normal(loc, 1.0), obs=data)
    >>> prior = Predictive(model, num_samples=7)(jax.random.PRNGKey(0))
    >>> prior["obs"].shape
    (7, 3)
    >>> post = {"loc": jnp.zeros((2, 5))}   # (chain, draw) from MCMC
    >>> out = Predictive(model, posterior_samples=post, batch_ndims=2)(
    ...     jax.random.PRNGKey(1))
    >>> out["obs"].shape
    (2, 5, 3)
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.handlers import seed, substitute, trace
from .util import substitute_params


class Predictive:
    """Vectorized predictive distribution.

    posterior_samples: dict site -> (num_draws, ...) arrays (or, with
    ``batch_ndims=2``, (num_chains, num_draws, ...) arrays from multi-chain
    MCMC), or None to sample from the prior / guide.
    """

    def __init__(
        self,
        model: Callable,
        posterior_samples: Optional[Dict] = None,
        guide: Optional[Callable] = None,
        params: Optional[Dict] = None,
        num_samples: Optional[int] = None,
        return_sites: Optional[list] = None,
        batch_ndims: int = 1,
    ):
        if posterior_samples is not None and guide is not None:
            raise ValueError("pass either posterior_samples or guide, not both")
        if batch_ndims not in (1, 2):
            raise ValueError(f"batch_ndims must be 1 or 2, got {batch_ndims}")
        self.model = model
        self.posterior_samples = posterior_samples
        self.guide = guide
        self.params = params or {}
        self.batch_ndims = batch_ndims
        self.num_samples = num_samples or (
            len(jax.tree_util.tree_leaves(posterior_samples)[0]) if posterior_samples else 1
        )
        self.return_sites = return_sites

    def __call__(self, rng_key, *args, **kwargs):
        def single(key, sample):
            model = substitute_params(self.model, self.params)
            if self.guide is not None:
                key_g, key = jax.random.split(key)
                guide_tr = trace(
                    seed(substitute_params(self.guide, self.params), key_g)
                ).get_trace(*args, **kwargs)
                sample = {
                    n: guide_tr[n]["value"] for n in guide_tr.stochastic_nodes()
                }
            if sample:
                model = substitute(model, data=sample)
            tr = trace(seed(model, key)).get_trace(*args, **kwargs)
            sites = self.return_sites or [
                n for n, s in tr.nodes.items() if s["type"] in ("sample", "deterministic")
            ]
            return {n: tr[n]["value"] for n in sites if n in tr.nodes}

        if self.posterior_samples is not None:
            lead = jax.tree_util.tree_leaves(self.posterior_samples)[0].shape[
                : self.batch_ndims
            ]
            keys = jax.random.split(rng_key, math.prod(lead))
            keys = keys.reshape(lead + keys.shape[1:])
            fn = single
            for _ in range(self.batch_ndims):
                fn = jax.vmap(fn)
            return fn(keys, self.posterior_samples)
        keys = jax.random.split(rng_key, self.num_samples)
        return jax.vmap(lambda k: single(k, {}))(keys)
