"""Posterior/prior predictive sampling."""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.handlers import seed, substitute, trace
from .util import substitute_params


class Predictive:
    """Vectorized predictive distribution.

    posterior_samples: dict site -> (N, ...) arrays (e.g. from MCMC), or None
    to sample from the prior / guide.
    """

    def __init__(
        self,
        model: Callable,
        posterior_samples: Optional[Dict] = None,
        guide: Optional[Callable] = None,
        params: Optional[Dict] = None,
        num_samples: Optional[int] = None,
        return_sites: Optional[list] = None,
    ):
        if posterior_samples is not None and guide is not None:
            raise ValueError("pass either posterior_samples or guide, not both")
        self.model = model
        self.posterior_samples = posterior_samples
        self.guide = guide
        self.params = params or {}
        self.num_samples = num_samples or (
            len(jax.tree_util.tree_leaves(posterior_samples)[0]) if posterior_samples else 1
        )
        self.return_sites = return_sites

    def __call__(self, rng_key, *args, **kwargs):
        def single(key, sample):
            model = substitute_params(self.model, self.params)
            if self.guide is not None:
                key_g, key = jax.random.split(key)
                guide_tr = trace(
                    seed(substitute_params(self.guide, self.params), key_g)
                ).get_trace(*args, **kwargs)
                sample = {
                    n: guide_tr[n]["value"] for n in guide_tr.stochastic_nodes()
                }
            if sample:
                model = substitute(model, data=sample)
            tr = trace(seed(model, key)).get_trace(*args, **kwargs)
            sites = self.return_sites or [
                n for n, s in tr.nodes.items() if s["type"] in ("sample", "deterministic")
            ]
            return {n: tr[n]["value"] for n in sites if n in tr.nodes}

        keys = jax.random.split(rng_key, self.num_samples)
        if self.posterior_samples is not None:
            return jax.vmap(single)(keys, self.posterior_samples)
        return jax.vmap(lambda k: single(k, {}))(keys)
