"""Posterior/prior predictive sampling (paper §2: inference in Pyro yields
objects that "can be used to form predictive distributions" — `Predictive`
replays posterior draws, guide samples, or the prior through the model and
collects the resulting sample/deterministic sites, fully vectorized with
`vmap` rather than a Python loop per draw).

`posterior_samples` may be flat ``(num_draws, ...)`` arrays (the default,
``batch_ndims=1``) or chain-grouped ``(num_chains, num_draws, ...)`` arrays
straight from ``MCMC.get_samples(group_by_chain=True)`` with
``batch_ndims=2`` — the predictive fan-out then nests one `vmap` per batch
dim, so multi-chain posterior-predictive sampling stays a single compiled
call with ``(chain, draw, ...)``-shaped outputs.

Calls are compiled: each `Predictive` owns one `jax.jit` cache shared by
every invocation, so repeated calls with same-shaped inputs never re-trace
(the serving hot path — `repro.serve` builds its shape-bucketed endpoints
on top of this). Array and Python-float args/kwargs, the posterior
samples, and ``self.params`` ride the traced signature — updating
``pred.params`` after a checkpoint refresh, or varying a per-request float
(a temperature, a noise scale), never retraces — while the remaining
non-array leaves (plate-size ints, flags, ``None``) stay static, so
models that branch or shape on them keep working (a changed static value
triggers exactly one fresh trace; an int that varies per request grows
the cache per value — pass it as a jnp scalar if it is data, not shape). The `num_traces` property reports how many
distinct executables the cache holds; a steady-traffic server should see
it equal the number of distinct input shapes, never the number of
requests. Pass ``jit_compile=False`` to recover the legacy eager
re-vmap-per-call behavior (models with Python control flow on *array*
values, or unhashable non-array args).

Example — prior predictive, then chain-shaped posterior predictive::

    >>> import jax, jax.numpy as jnp
    >>> from repro import distributions as dist
    >>> from repro.core import primitives as P
    >>> from repro.infer import Predictive
    >>> def model(data=None):
    ...     loc = P.sample("loc", dist.Normal(0.0, 1.0))
    ...     with P.plate("N", 3):
    ...         P.sample("obs", dist.Normal(loc, 1.0), obs=data)
    >>> prior = Predictive(model, num_samples=7)(jax.random.PRNGKey(0))
    >>> prior["obs"].shape
    (7, 3)
    >>> post = {"loc": jnp.zeros((2, 5))}   # (chain, draw) from MCMC
    >>> pred = Predictive(model, posterior_samples=post, batch_ndims=2)
    >>> out = pred(jax.random.PRNGKey(1))
    >>> out["obs"].shape
    (2, 5, 3)
    >>> _ = pred(jax.random.PRNGKey(2))     # same shapes: no re-trace
    >>> pred.num_traces
    1
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional

import jax

from ..core.handlers import seed, substitute, trace
from .util import substitute_params


class _Dynamic:
    """Sentinel marking a traced (array) leaf inside the static blob."""

    def __repr__(self):  # pragma: no cover
        return "<dynamic>"


_DYNAMIC = _Dynamic()


class Predictive:
    """Vectorized predictive distribution.

    posterior_samples: dict site -> (num_draws, ...) arrays (or, with
    ``batch_ndims=2``, (num_chains, num_draws, ...) arrays from multi-chain
    MCMC), or None to sample from the prior / guide.
    """

    def __init__(
        self,
        model: Callable,
        posterior_samples: Optional[Dict] = None,
        guide: Optional[Callable] = None,
        params: Optional[Dict] = None,
        num_samples: Optional[int] = None,
        return_sites: Optional[list] = None,
        batch_ndims: int = 1,
        jit_compile: bool = True,
    ):
        if posterior_samples is not None and guide is not None:
            raise ValueError("pass either posterior_samples or guide, not both")
        if batch_ndims not in (1, 2):
            raise ValueError(f"batch_ndims must be 1 or 2, got {batch_ndims}")
        self.model = model
        self.posterior_samples = posterior_samples
        self.guide = guide
        self.params = params or {}
        self.batch_ndims = batch_ndims
        self.num_samples = num_samples or (
            len(jax.tree_util.tree_leaves(posterior_samples)[0]) if posterior_samples else 1
        )
        self.return_sites = return_sites
        # One jit per Predictive: samples/params and the array leaves of
        # args/kwargs ride the traced signature (so same-shape calls share
        # one executable and a checkpoint refresh of `self.params` takes
        # effect without retracing), while non-array leaves (plate-size
        # ints, flags, None) stay static so models may branch/shape on them.
        self._jitted = (
            jax.jit(self._vectorized, static_argnames=("static_blob",))
            if jit_compile
            else None
        )

    @property
    def num_traces(self) -> int:
        """Distinct compiled executables (one per input-shape signature);
        0 before the first call and always 0 for ``jit_compile=False``."""
        return self._jitted._cache_size() if self._jitted is not None else 0

    def _vectorized(self, rng_key, samples, params, dyn_leaves, *, static_blob):
        treedef, static_leaves = static_blob
        leaves = [
            dyn if stat is _DYNAMIC else stat
            for dyn, stat in zip(dyn_leaves, static_leaves)
        ]
        args, kwargs = jax.tree_util.tree_unflatten(treedef, leaves)

        def single(key, sample):
            model = substitute_params(self.model, params)
            if self.guide is not None:
                key_g, key = jax.random.split(key)
                guide_tr = trace(
                    seed(substitute_params(self.guide, params), key_g)
                ).get_trace(*args, **kwargs)
                sample = {
                    n: guide_tr[n]["value"] for n in guide_tr.stochastic_nodes()
                }
            if sample:
                model = substitute(model, data=sample)
            tr = trace(seed(model, key)).get_trace(*args, **kwargs)
            sites = self.return_sites or [
                n for n, s in tr.nodes.items() if s["type"] in ("sample", "deterministic")
            ]
            return {n: tr[n]["value"] for n in sites if n in tr.nodes}

        if samples:
            lead = jax.tree_util.tree_leaves(samples)[0].shape[: self.batch_ndims]
            keys = jax.random.split(rng_key, math.prod(lead))
            keys = keys.reshape(lead + keys.shape[1:])
            fn = single
            for _ in range(self.batch_ndims):
                fn = jax.vmap(fn)
            return fn(keys, samples)
        keys = jax.random.split(rng_key, self.num_samples)
        return jax.vmap(lambda k: single(k, {}))(keys)

    @staticmethod
    def _partition(args, kwargs):
        """Split (args, kwargs) leaves into traced values and a hashable
        static blob. Arrays AND Python floats are traced (floats are data —
        a per-request temperature must not grow the jit cache); ints, bools
        and other non-array leaves are static (they determine structure:
        plate sizes, flags — a changed value is a legitimate fresh trace)."""
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
        is_dyn = [
            (hasattr(leaf, "shape") and hasattr(leaf, "dtype"))
            or (isinstance(leaf, float) and not isinstance(leaf, bool))
            for leaf in leaves
        ]
        dyn = [leaf if d else None for leaf, d in zip(leaves, is_dyn)]
        static = tuple(_DYNAMIC if d else leaf for leaf, d in zip(leaves, is_dyn))
        return dyn, (treedef, static)

    def call_with(self, rng_key, params, posterior_samples, *args, **kwargs):
        """Like ``__call__`` but with params / posterior samples passed
        explicitly and NO jit of its own — the serving engine threads the
        artifact state through *its* jit signature via this entry point, so
        a checkpoint refresh neither retraces nor bakes constants into the
        per-bucket executables."""
        samples = posterior_samples if posterior_samples is not None else {}
        dyn, blob = self._partition(args, kwargs)
        return self._vectorized(rng_key, samples, params or {}, dyn, static_blob=blob)

    def __call__(self, rng_key, *args, **kwargs):
        samples = self.posterior_samples if self.posterior_samples is not None else {}
        dyn, blob = self._partition(args, kwargs)
        if self._jitted is not None:
            return self._jitted(rng_key, samples, self.params, dyn, static_blob=blob)
        return self._vectorized(rng_key, samples, self.params, dyn, static_blob=blob)
