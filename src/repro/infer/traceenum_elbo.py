"""TraceEnum_ELBO + infer_discrete: exact parallel enumeration of discrete
latents (paper §2's flagship example of composable custom inference).

Enumeration reduces to broadcast-then-contract over named dims (funsor,
Obermeyer et al. 2019): the `enum` messenger gives every annotated discrete
site its full support along a fresh negative batch dim left of all plate
dims, and the contraction below sum-eliminates those dims out of the joint
log-density with logsumexp (sum-product) or max (max-product for MAP
decoding), *respecting plate structure*: a plate is a product over
independent slices, so enum dims local to a plate are eliminated before the
plate's log-factors are summed over the plate axis, while enum dims shared
with enclosing ordinals survive the plate sum (the classic "global mixture
component observed across a data plate" pattern).

Everything here is trace-time Python: under `jax.jit` the handler stack and
the contraction schedule run while XLA traces, so a compiled SVI step with
enumeration contains only the einsum-style broadcast/reduce ops —
`TraceEnum_ELBO` plugs into the shared `ELBO` engine from PR 1 and inherits
particle vectorization, `mesh=` sharding, and the compile-once `update_jit`
path unchanged (`num_traces` counts retraces the same way `mcmc.num_traces`
does).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, FrozenSet, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core.handlers import block, enum, replay, seed, substitute, trace
from ..core.primitives import prng_key
from ..distributions.continuous import MultivariateNormal, Normal
from .contract.gaussian import (
    GaussianFactor,
    affine_gaussian_factor,
    color_sites,
    eliminate_gaussian_factors,
    jaxpr_dependencies,
)
from .contract import (
    _dispatch_mode,
    _from_matrix,
    _logsumexp_op,
    _max_op,
    _to_matrix,
    _ve_eliminate,
    clear_plan_cache,
    contract_log_factors,
    plan_cache_stats,
)
from .contract.structure import (
    _add_all,
    _enum_dims,
    _reduce_dims,
    _scaled,
    _uniform_scale,
)
from .elbo import ELBO, _apply_scale_mask
from .util import substitute_params

# The contraction engine (planner, plan cache, executor) lives in
# `repro.infer.contract`; the helpers above are re-exported here because this
# module is the historical home of the contraction API.
__all__ = [
    "TraceEnum_ELBO",
    "contract_log_factors",
    "discrete_marginals",
    "gaussian_marginals",
    "infer_discrete",
    "plan_cache_stats",
    "clear_plan_cache",
    "_add_all",
    "_dispatch_mode",
    "_enum_dims",
    "_from_matrix",
    "_logsumexp_op",
    "_max_op",
    "_reduce_dims",
    "_scaled",
    "_to_matrix",
    "_uniform_scale",
    "_ve_eliminate",
]

# ---------------------------------------------------------------------------
# log-factor collection
# ---------------------------------------------------------------------------


def _max_plate_nesting(*traces) -> int:
    """Deepest plate dim used by any site across the given traces."""
    mpn = 0
    for tr in traces:
        for site in tr.nodes.values():
            for frame in site.get("cond_indep_stack", ()):
                mpn = max(mpn, -frame.dim)
    return mpn


def _collect_factors(model_tr, skip: FrozenSet[str] = frozenset()):
    """Extract (ordinal, log_prob, pending_scale) triples from a model trace,
    plus the frame->nesting-depth map used to order plate elimination and the
    pool of dims the enum messenger allocated. The ordinal of a factor is the
    frozenset of plate frames enclosing its site. Sites named in ``skip`` are
    excluded entirely — used for Gaussian-entangled sites, whose densities
    enter through the eliminated Gaussian factors' log-normalizers instead.

    Scale handling: a site scale (plate subsampling's size/subsample_size, or
    handlers.scale) is an exponent on probabilities — for factors entangled
    with enum dims it must multiply the *marginalized* per-slice log-density,
    i.e. apply AFTER logsumexp, not before (s*logsumexp(lp), never
    logsumexp(s*lp)). Factors free of enum dims get their scale applied here;
    the rest carry it as `pending` until the contraction finishes their local
    eliminations. Masking: a masked-out slice of an enumerated site fills with
    -log(K) (so its logsumexp contributes exactly 0), while every other
    factor fills with 0 as usual."""
    factors: List[Tuple[FrozenSet, jax.Array, Any]] = []
    depth: Dict = {}
    enum_dim_pool = set()
    for name, site in model_tr.nodes.items():
        if site["type"] != "sample" or name in skip:
            continue
        enum_dim = site["infer"].get("_enumerate_dim")
        if enum_dim is not None:
            enum_dim_pool.add(enum_dim)
        lp = site["fn"].log_prob(site["value"])
        mask = site["mask"]
        if enum_dim is not None:
            # distribution-level masks (.mask()) zero-fill inside log_prob,
            # which is wrong across an enum dim — fold them into the site
            # mask so the -log K neutral fill below covers both paths
            fn, dist_mask = site["fn"], None
            while fn is not None:
                m = getattr(fn, "_mask", None)
                if m is not None:
                    dist_mask = m if dist_mask is None else dist_mask & m
                fn = getattr(fn, "base_dist", None)
            if dist_mask is not None:
                mask = dist_mask if mask is None else mask & dist_mask
        if mask is not None:
            neutral = (
                -jnp.log(site["infer"]["_enumerate_cardinality"])
                if enum_dim is not None
                else 0.0
            )
            lp = jnp.where(mask, lp, neutral)
        frames = site["cond_indep_stack"]
        # cond_indep_stack is ordered outermost -> innermost
        for i, f in enumerate(frames):
            depth[f] = max(depth.get(f, 0), i)
        factors.append((frozenset(frames), lp, site["scale"]))
    pool = frozenset(enum_dim_pool)
    # scales on enum-free factors commute with everything downstream
    factors = [
        (o, lp, s) if _enum_dims(lp, pool) else (o, _scaled(lp, s), None)
        for o, lp, s in factors
    ]
    return factors, depth, pool


# ---------------------------------------------------------------------------
# Gaussian-site lowering (exact marginalization of linear-Gaussian latents)
# ---------------------------------------------------------------------------


def _gaussian_sites(model_tr) -> List[str]:
    """Non-observed sites annotated ``infer={"marginalize": "gaussian"}``,
    in trace order (which becomes the elimination order)."""
    return [
        name
        for name, site in model_tr.nodes.items()
        if site["type"] == "sample"
        and not site["is_observed"]
        and site["infer"].get("marginalize") == "gaussian"
    ]


def _check_gaussian_site(name, site, *, marginalized: bool):
    role = "marginalized" if marginalized else "Gaussian-entangled"
    if not isinstance(site["fn"], (Normal, MultivariateNormal)):
        raise NotImplementedError(
            f"{role} site '{name}' has distribution "
            f"{type(site['fn']).__name__}; Gaussian marginalization supports "
            "Normal and MultivariateNormal sites only"
        )
    if site["cond_indep_stack"]:
        raise NotImplementedError(
            f"{role} site '{name}' is inside a plate; plate-local Gaussian "
            "marginalization is not implemented — write time/feature "
            "structure as separate sites (or an MVN event dim) instead"
        )
    if site["scale"] is not None or site["mask"] is not None:
        raise NotImplementedError(
            f"{role} site '{name}' carries a scale or mask; neither commutes "
            "with exact Gaussian elimination"
        )


def _check_gaussian_lead(name, lead, pool):
    for i, s in enumerate(lead):
        d = i - len(lead)
        if s > 1 and d not in pool:
            raise NotImplementedError(
                f"Gaussian-entangled site '{name}' has a non-enumeration "
                f"batch axis of size {s} at dim {d}; only enum dims may "
                "batch Gaussian factors (vectorized/plated Gaussian sites "
                "are unsupported — use separate sites or an MVN event dim)"
            )


class _GaussianLowering(NamedTuple):
    factors: List[GaussianFactor]       # one per entangled site
    order: List[str]                    # marginalized sites, trace order
    entangled: FrozenSet[str]           # sites the factors' densities own
    widths: Dict[str, int]
    event_shapes: Dict[str, Tuple[int, ...]]


def _lower_gaussian_trace(make_trace, model_tr, pool, *, fixed: FrozenSet[str]):
    """Lower every ``marginalize="gaussian"`` site (and each site whose
    location depends on one) to an information-form `GaussianFactor`.

    Dependence structure is discovered with `jax.linearize` of a model
    retrace that substitutes candidate values, plus a conservative jaxpr
    dataflow walk (`contract.gaussian.jaxpr_dependencies`) — both work under
    `jax.jit`. The affine coefficients A in loc_s = Σ_p A_sp x_p + b_s come
    from JVP basis pushes, batched with a greedy conflict coloring
    (`color_sites`) so a T-step chain costs 2 vectorized pushes, not T.
    Anything non-linear-Gaussian in the entangled set raises
    `NotImplementedError`: a dependent non-Gaussian site, a covariance
    depending on a marginalized value, or (checked numerically when tracing
    eagerly; skipped under jit) a non-affine location.

    ``fixed`` names latents whose values are legitimately pinned (guide
    draws); an entangled free latent that is neither fixed, observed, nor
    itself marginalized is an error rather than a silent conditioning."""
    marg = _gaussian_sites(model_tr)
    if not marg:
        return None
    marg_set = set(marg)
    for name in marg:
        _check_gaussian_site(name, model_tr.nodes[name], marginalized=True)

    widths: Dict[str, int] = {}
    event_shapes: Dict[str, Tuple[int, ...]] = {}

    def register(name):
        fn = model_tr.nodes[name]["fn"]
        ev = tuple(fn.event_shape) if isinstance(fn, MultivariateNormal) else ()
        event_shapes[name] = ev
        widths[name] = int(ev[0]) if ev else 1

    for name in marg:
        register(name)
    protos = {n: jnp.zeros(event_shapes[n], jnp.float32) for n in marg}

    sample_names = [n for n, s in model_tr.nodes.items() if s["type"] == "sample"]

    def retrace(values):
        tr = make_trace(values)
        outs = {}
        for n in sample_names:
            site = tr.nodes[n]
            fn = site["fn"]
            if isinstance(fn, Normal):
                outs[("loc", n)] = jnp.asarray(fn.loc, jnp.float32)
                outs[("scale", n)] = jnp.asarray(fn.scale, jnp.float32)
            elif isinstance(fn, MultivariateNormal):
                outs[("loc", n)] = jnp.asarray(fn.loc, jnp.float32)
                outs[("scale", n)] = jnp.asarray(fn.scale_tril, jnp.float32)
            else:
                outs[("lp", n)] = fn.log_prob(site["value"])
        return outs

    primal, jvp = jax.linearize(retrace, protos)
    in_names = sorted(protos)           # dict flatten order == sorted keys
    out_keys = sorted(primal)
    dep_idx = jaxpr_dependencies(retrace, protos)
    deps = {
        k: frozenset(in_names[i] for i in dep_idx[j]) & marg_set
        for j, k in enumerate(out_keys)
    }

    for (kind, n), ds in sorted(deps.items()):
        if not ds or kind == "loc":
            continue
        if kind == "scale":
            raise NotImplementedError(
                f"the scale/covariance of site '{n}' depends on marginalized "
                f"sites {sorted(ds)}; only locations may depend on "
                "Gaussian-marginalized latents (linear-Gaussian structure)"
            )
        raise NotImplementedError(
            f"site '{n}' depends on marginalized sites {sorted(ds)} but is "
            "not Normal/MultivariateNormal; every site downstream of a "
            "marginalized latent must be linear-Gaussian"
        )

    entangled = [
        n for n in sample_names
        if n in marg_set or deps.get(("loc", n), frozenset())
    ]
    for n in entangled:
        site = model_tr.nodes[n]
        if n not in marg_set:
            _check_gaussian_site(n, site, marginalized=False)
            if not site["is_observed"] and n not in fixed:
                raise NotImplementedError(
                    f"site '{n}' depends on marginalized sites but is a free "
                    "latent; annotate it for marginalization too, or sample "
                    "it in the guide"
                )
            register(n)

    # numeric affine-ness check: only possible on concrete values (eager
    # tracing); under jit the primal is a tracer and the check is skipped —
    # the structural guards above still hold, linearity is trusted.
    if not any(isinstance(x, jax.core.Tracer) for x in jax.tree_util.tree_leaves(primal)):
        delta = {n: jnp.full(event_shapes[n], 0.7357, jnp.float32) for n in marg}
        lhs = retrace(delta)
        tang = jvp(delta)
        for n in entangled:
            want = primal[("loc", n)] + tang[("loc", n)]
            if not np.allclose(lhs[("loc", n)], want, rtol=1e-3, atol=1e-4):
                raise NotImplementedError(
                    f"the location of site '{n}' is not affine in the "
                    "marginalized sites; exact Gaussian elimination requires "
                    "linear-Gaussian dependence"
                )

    # Jacobian blocks via color-batched JVP basis pushes
    dependents_map = {
        ("loc", n): deps.get(("loc", n), frozenset()) for n in entangled
    }
    jac: Dict[Tuple[str, str], jax.Array] = {}
    for group in color_sites(marg, dependents_map):
        group = [
            p for p in group
            if any(p in deps.get(("loc", n), ()) for n in entangled)
        ]
        if not group:
            continue
        wmax = max(widths[p] for p in group)

        def basis(p, i):
            z = jnp.zeros(event_shapes[p], jnp.float32)
            if p not in group or i >= widths[p]:
                return z
            return z.at[i].set(1.0) if event_shapes[p] else z + 1.0

        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[{p: basis(p, i) for p in protos} for i in range(wmax)],
        )
        pushed = jax.vmap(jvp)(stacked)
        for n in entangled:
            for p in group:
                if p not in deps.get(("loc", n), ()):
                    continue
                col = jnp.moveaxis(pushed[("loc", n)][: widths[p]], 0, -1)
                if not event_shapes[n]:
                    col = col[..., None, :]         # scalar site: (*lead, 1, w_p)
                jac[(n, p)] = col

    # one information-form factor per entangled site
    factors: List[GaussianFactor] = []
    for n in entangled:
        site = model_tr.nodes[n]
        is_mvn = bool(event_shapes[n])
        loc = jnp.asarray(primal[("loc", n)], jnp.float32)
        scale = jnp.asarray(primal[("scale", n)], jnp.float32)
        if is_mvn:
            lead = jnp.broadcast_shapes(loc.shape[:-1], scale.shape[:-2])
            locb = jnp.broadcast_to(loc, lead + loc.shape[-1:])
            L = jnp.broadcast_to(scale, lead + scale.shape[-2:])
        else:
            lead = jnp.broadcast_shapes(loc.shape, scale.shape)
            locb = jnp.broadcast_to(loc, lead)[..., None]
            L = jnp.broadcast_to(scale, lead)[..., None, None]
        _check_gaussian_lead(n, lead, pool)
        parents = sorted(deps.get(("loc", n), frozenset()), key=marg.index)
        if n in marg_set:
            vars_ = (n,) + tuple(p for p in parents if p != n)
            m0, own = -locb, n
        else:
            vars_ = tuple(parents)
            value = jnp.asarray(site["value"], jnp.float32)
            m0 = (value if is_mvn else value[..., None]) - locb
            own = None
        factors.append(
            affine_gaussian_factor(
                vars_,
                tuple(widths[v] for v in vars_),
                {p: jac[(n, p)] for p in vars_ if p != n},
                m0,
                L,
                own,
            )
        )
    return _GaussianLowering(factors, marg, frozenset(entangled), widths, event_shapes)


# ---------------------------------------------------------------------------
# TraceEnum_ELBO
# ---------------------------------------------------------------------------


class TraceEnum_ELBO(ELBO):
    """ELBO with exact parallel marginalization of enumerated discrete model
    sites. Annotate sites with ``infer={"enumerate": "parallel"}`` (or wrap
    the model in ``config(enumerate=True)``); the guide must not sample them.

    Plugs into the shared `ELBO` engine: `num_particles`, `mesh=` particle
    sharding, and SVI's compile-once `update_jit` all work unchanged.
    `max_plate_nesting` is detected from a prototype trace when not given;
    pass it explicitly when the model's shapes depend on rarely-exercised
    branches. `num_traces` counts XLA retraces (jit-stability assertion hook,
    same idiom as `mcmc.num_traces`).
    """

    def __init__(
        self,
        num_particles: int = 1,
        max_plate_nesting: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        particle_axis: Union[str, Tuple[str, ...], None] = None,
    ):
        super().__init__(num_particles, mesh=mesh, particle_axis=particle_axis)
        self.max_plate_nesting = max_plate_nesting
        self.num_traces = 0

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        self.num_traces += 1  # trace-time side effect (retrace detector)
        key_guide, key_model = jax.random.split(rng_key)
        seeded_guide = seed(substitute_params(guide, params), key_guide)
        guide_tr = trace(seeded_guide).get_trace(*args, **kwargs)
        for name, site in guide_tr.nodes.items():
            if (
                site["type"] == "sample"
                and not site["is_observed"]
                and site["infer"].get("enumerate")
            ):
                raise NotImplementedError(
                    f"guide site '{name}' requests enumeration; guide-side "
                    "enumeration is not implemented — annotate the model site "
                    "and remove it from the guide so TraceEnum_ELBO can "
                    "marginalize it exactly"
                )
        seeded_model = seed(substitute_params(model, params), key_model)
        if self.max_plate_nesting is None:
            # one extra prototype trace (trace-time only), then cached
            proto_tr = trace(replay(seeded_model, guide_tr)).get_trace(*args, **kwargs)
            self.max_plate_nesting = _max_plate_nesting(guide_tr, proto_tr)
        mpn = self.max_plate_nesting
        with enum(first_available_dim=-1 - mpn):
            model_tr = trace(replay(seeded_model, guide_tr)).get_trace(*args, **kwargs)

        guide_latents = frozenset(
            name
            for name, site in guide_tr.nodes.items()
            if site["type"] == "sample" and not site["is_observed"]
        )
        for name in _gaussian_sites(model_tr):
            if name in guide_latents:
                raise NotImplementedError(
                    f"guide samples site '{name}' which the model marks for "
                    "Gaussian marginalization; remove it from the guide so "
                    "TraceEnum_ELBO can integrate it out exactly"
                )
        pool = frozenset(
            s["infer"]["_enumerate_dim"]
            for s in model_tr.nodes.values()
            if s["type"] == "sample" and s["infer"].get("_enumerate_dim") is not None
        )

        def make_trace(values):
            with enum(first_available_dim=-1 - mpn):
                return trace(
                    substitute(replay(seeded_model, guide_tr), data=values)
                ).get_trace(*args, **kwargs)

        gauss = _lower_gaussian_trace(make_trace, model_tr, pool, fixed=guide_latents)
        skip = gauss.entangled if gauss else frozenset()
        factors, depth, pool = _collect_factors(model_tr, skip=skip)
        if gauss:
            # the eliminated factors' log-normalizers are ordinary enum-lead
            # log-factors at the root ordinal (plates on entangled sites are
            # rejected in the lowering), completing the mixed contraction
            for t in eliminate_gaussian_factors(gauss.factors, gauss.order):
                factors.append((frozenset(), t, None))
        elbo = jnp.sum(contract_log_factors(factors, depth, pool))
        score_logq = 0.0  # REINFORCE factor for non-reparam guide sites
        for site in guide_tr.nodes.values():
            if site["type"] != "sample" or site["is_observed"]:
                continue
            lq = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
            elbo = elbo - jnp.sum(lq)
            if not site["fn"].has_rsample:
                score_logq = score_logq + jnp.sum(lq)
        surrogate = elbo + jax.lax.stop_gradient(elbo) * (
            score_logq - jax.lax.stop_gradient(score_logq)
        )
        return elbo, surrogate


# ---------------------------------------------------------------------------
# infer_discrete: posterior decoding of enumerated sites
# ---------------------------------------------------------------------------


def _index_factor(t: jax.Array, dim: int, idx: jax.Array) -> jax.Array:
    """Condition a right-aligned log-factor on idx along enum dim `dim`
    (idx is right-aligned with a size-1 slot at `dim`)."""
    axis = jnp.ndim(t) + dim
    if axis < 0 or jnp.shape(t)[axis] == 1:
        return t  # factor does not carry this dim
    if jnp.ndim(idx) > jnp.ndim(t):
        t = jnp.reshape(t, (1,) * (jnp.ndim(idx) - jnp.ndim(t)) + jnp.shape(t))
        axis = jnp.ndim(t) + dim
    elif jnp.ndim(idx) < jnp.ndim(t):
        idx = jnp.reshape(idx, (1,) * (jnp.ndim(t) - jnp.ndim(idx)) + jnp.shape(idx))
    return jnp.take_along_axis(t, idx.astype(jnp.int32), axis=axis)


def _enum_trace(model, rng_key, args, kwargs, first_available_dim):
    """Run the hidden enumeration pass: seed, auto-detect max_plate_nesting
    (unless first_available_dim pins it), and trace under `enum`. Shared by
    discrete_marginals and _decode_discrete."""
    with block():  # hide the enumeration pass from enclosing handlers
        seeded = seed(model, jnp.asarray(rng_key))
        if first_available_dim is None:
            proto_tr = trace(seeded).get_trace(*args, **kwargs)
            mpn = _max_plate_nesting(proto_tr)
        else:
            mpn = -first_available_dim - 1
        with enum(first_available_dim=-1 - mpn):
            tr = trace(seeded).get_trace(*args, **kwargs)
    return tr


def discrete_marginals(
    model: Callable,
    rng_key,
    *args,
    first_available_dim: Optional[int] = None,
    **kwargs,
) -> Dict[str, jax.Array]:
    """Exact posterior marginals of every enumerated site, as normalized
    log-probabilities with the site's support on the LAST axis (preceded by
    the site's plate dims). Condition/substitute the model beforehand.

    Uses the dice-factor identity: d logZ / d (site's log-factor) is the
    posterior marginal of that factor's indices, which stays exact even when
    a global enumerated variable couples plate slices (a per-site contraction
    would drop the other slices' evidence about the global)."""
    if rng_key is None:
        rng_key = prng_key()  # ambient seed handler, if any
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    tr = _enum_trace(model, rng_key, args, kwargs, first_available_dim)
    factors, depth, pool = _collect_factors(tr)

    enum_sites = {
        name: site
        for name, site in tr.nodes.items()
        if site["type"] == "sample" and "_enumerate_dim" in site["infer"]
    }
    sample_names = [
        name for name, site in tr.nodes.items() if site["type"] == "sample"
    ]

    def log_z(perturbs: Dict[str, jax.Array]) -> jax.Array:
        perturbed = [
            (o, t + perturbs[name], s) if name in perturbs else (o, t, s)
            for name, (o, t, s) in zip(sample_names, factors)
        ]
        return jnp.sum(contract_log_factors(perturbed, depth, pool))

    zero = {
        name: jnp.zeros_like(factors[sample_names.index(name)][1])
        for name in enum_sites
    }
    joint_probs = jax.grad(log_z)(zero)

    marginals: Dict[str, jax.Array] = {}
    for name, site in enum_sites.items():
        d = site["infer"]["_enumerate_dim"]
        probs = joint_probs[name]
        # sum joint posterior over everything but this site's own enum dim
        # and its plate dims (per-slice marginals)
        keep = {d} | {f.dim for f in site["cond_indep_stack"]}
        drop = tuple(a for a in range(-jnp.ndim(probs), 0) if a not in keep)
        probs = jnp.sum(probs, axis=drop, keepdims=True) if drop else probs
        logits = jnp.moveaxis(jnp.log(probs), jnp.ndim(probs) + d, -1)
        target_rank = max([-f.dim for f in site["cond_indep_stack"]], default=0)
        marginals[name] = _squeeze_to_rank(
            jax.nn.log_softmax(logits, -1), target_rank + 1
        )
    return marginals


def _squeeze_to_rank(x: jax.Array, rank: int) -> jax.Array:
    """Drop leading size-1 axes until `x` has `rank` dims."""
    while jnp.ndim(x) > rank and jnp.shape(x)[0] == 1:
        x = x[0]
    return x


def gaussian_marginals(
    model: Callable,
    rng_key,
    *args,
    sites: Optional[List[str]] = None,
    first_available_dim: Optional[int] = None,
    **kwargs,
) -> Dict[str, Tuple[jax.Array, jax.Array]]:
    """Exact posterior (mean, covariance) of every Gaussian-marginalized
    site — the smoother marginals of a Kalman model, conjugate posteriors of
    a Bayesian linear regression, or the moment-matched mixture marginals of
    a switching LDS (discrete enum and Gaussian elimination run in one mixed
    contraction). Condition/substitute observations into the model first,
    the way `discrete_marginals` expects.

    Returns ``{site: (mean, cov)}``: scalar mean and variance for `Normal`
    sites, ``(D,)`` mean and ``(D, D)`` covariance for `MultivariateNormal`
    sites. ``sites`` restricts the query (covariances scale cubically with
    total queried width).

    Uses the cumulant trick — the Gaussian analogue of `discrete_marginals`'
    dice-factor identity: appending a zero-precision perturbation factor
    with info_vec ε to a site makes ∇_ε log Z the posterior mean and the
    ε-Hessian the posterior covariance, both exact (and mixture-exact under
    enumeration, since log Z sums over the discrete support)."""
    if rng_key is None:
        rng_key = prng_key()
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    tr = _enum_trace(model, rng_key, args, kwargs, first_available_dim)
    mpn = (
        -first_available_dim - 1
        if first_available_dim is not None
        else _max_plate_nesting(tr)
    )
    seeded = seed(model, jnp.asarray(rng_key))

    def make_trace(values):
        with block():
            with enum(first_available_dim=-1 - mpn):
                return trace(substitute(seeded, data=values)).get_trace(*args, **kwargs)

    pool = frozenset(
        s["infer"]["_enumerate_dim"]
        for s in tr.nodes.values()
        if s["type"] == "sample" and s["infer"].get("_enumerate_dim") is not None
    )
    gauss = _lower_gaussian_trace(make_trace, tr, pool, fixed=frozenset())
    if gauss is None:
        raise ValueError(
            "no sites are annotated for Gaussian marginalization; wrap the "
            'model in config(marginalize="gaussian") (formerly '
            "config_gaussian) or annotate sites with "
            'infer={"marginalize": "gaussian"}'
        )
    factors, depth, _ = _collect_factors(tr, skip=gauss.entangled)
    query = list(gauss.order) if sites is None else list(sites)
    for n in query:
        if n not in gauss.order:
            raise ValueError(
                f"site '{n}' is not Gaussian-marginalized "
                f"(marginalized sites: {gauss.order})"
            )

    def log_z(eps: Dict[str, jax.Array]) -> jax.Array:
        gfs = list(gauss.factors)
        for n, e in eps.items():
            w = gauss.widths[n]
            gfs.append(
                GaussianFactor(
                    (n,), (w,),
                    jnp.zeros((w, w), jnp.float32), e, jnp.zeros((), jnp.float32),
                )
            )
        extra = [
            (frozenset(), t, None)
            for t in eliminate_gaussian_factors(gfs, gauss.order)
        ]
        return jnp.sum(contract_log_factors(factors + extra, depth, pool))

    zero = {n: jnp.zeros((gauss.widths[n],), jnp.float32) for n in query}
    means = jax.grad(log_z)(zero)
    covs = jax.jacfwd(jax.grad(log_z))(zero)
    out: Dict[str, Tuple[jax.Array, jax.Array]] = {}
    for n in query:
        m, C = means[n], covs[n][n]
        out[n] = (m, C) if gauss.event_shapes[n] else (m[0], C[0, 0])
    return out


def _decode_discrete(model, rng_key, args, kwargs, first_available_dim, temperature):
    """Decode enumerated sites: temperature=1 -> exact joint posterior sample
    (sequential conditioning = chain rule); 0 -> exact joint MAP (max-product
    elimination + sequential argmax)."""
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    key_trace, key_sample = jax.random.split(jnp.asarray(rng_key))
    sum_op = _max_op if temperature == 0 else _logsumexp_op
    tr = _enum_trace(model, key_trace, args, kwargs, first_available_dim)
    factors, depth, pool = _collect_factors(tr)

    enum_sites = [
        (name, site)
        for name, site in tr.nodes.items()
        if site["type"] == "sample" and "_enumerate_dim" in site["infer"]
    ]
    # allocation order == execution order == decreasing dim
    enum_sites.sort(key=lambda ns: -ns[1]["infer"]["_enumerate_dim"])

    values: Dict[str, jax.Array] = {}
    for i, (name, site) in enumerate(enum_sites):
        d = site["infer"]["_enumerate_dim"]
        ordinal = frozenset(site["cond_indep_stack"])
        marg = contract_log_factors(
            factors, depth, pool, keep_dims=frozenset([d]), keep_frames=ordinal,
            sum_op=sum_op,
        )
        logits = jnp.moveaxis(marg, jnp.ndim(marg) + d, -1)  # (*plates, K)
        # the decoded value's batch rank comes from the site's plate context
        # (the enum-trace fn.batch_shape is polluted by parent enum dims)
        target_rank = max([-f.dim for f in site["cond_indep_stack"]], default=0)
        if temperature == 0:
            idx = jnp.argmax(logits, -1)
        else:
            idx = jax.random.categorical(jax.random.fold_in(key_sample, i), logits)
        # condition the remaining factors on the decoded value (chain rule)
        idx_r = jnp.expand_dims(idx, d)
        factors = [(o, _index_factor(t, d, idx_r), s) for o, t, s in factors]
        # map index -> support value, shaped like an ordinary draw at the site
        support = site["fn"].enumerate_support(expand=False)
        event_shape = site["fn"].event_shape
        support_flat = jnp.reshape(support, (jnp.shape(support)[0],) + event_shape)
        val = jnp.take(support_flat, idx, axis=0)
        values[name] = _squeeze_to_rank(val, target_rank + len(event_shape))

    # pin every free (non-enumerated) latent to its decode-pass draw: the
    # discrete sites were decoded AGAINST those values, so re-sampling them in
    # the replay pass would return an inconsistent (continuous, discrete) pair
    for name, site in tr.nodes.items():
        if (
            site["type"] == "sample"
            and not site["is_observed"]
            and name not in values
        ):
            values[name] = site["value"]
    return values


class _InferDiscrete:
    """Callable wrapper produced by `infer_discrete`."""

    def __init__(self, fn, first_available_dim, temperature, rng_key):
        self.fn = fn
        self.first_available_dim = first_available_dim
        self.temperature = temperature
        self.rng_key = rng_key
        functools.update_wrapper(self, fn, updated=[])

    def __call__(self, *args, **kwargs):
        # no explicit key -> draw one from the ambient seed handler, so each
        # seeded call of the wrapper yields a fresh posterior draw instead of
        # silently repeating one fixed decode
        rng_key = self.rng_key
        if rng_key is None:
            rng_key = prng_key()
        values = _decode_discrete(
            self.fn,
            rng_key,
            args,
            kwargs,
            self.first_available_dim,
            self.temperature,
        )
        return substitute(self.fn, data=values)(*args, **kwargs)


def infer_discrete(
    fn: Optional[Callable] = None,
    *,
    first_available_dim: Optional[int] = None,
    temperature: int = 1,
    rng_key=None,
) -> Callable:
    """Posterior decoding of enumerated discrete sites (Pyro's
    `infer_discrete`): returns a model whose annotated discrete sites take
    exact joint posterior samples (``temperature=1``, sequential conditioning
    via the chain rule) or the exact joint MAP assignment (``temperature=0``,
    max-product elimination), given the observations/conditioning baked into
    the model. Any free continuous latents are drawn once (keyed by
    ``rng_key``) and pinned across the decode and replay passes, so the
    returned execution is one coherent joint draw — but their posterior is
    NOT inferred here. Continuous posteriors go in first — substitute
    SVI/MCMC draws into the model, then decode:

        guide_draws = {...}                      # from SVI or MCMC
        decoded = infer_discrete(
            handlers.substitute(config(model, enumerate=True), data=guide_draws),
            temperature=0, rng_key=key)
        tr = handlers.trace(decoded).get_trace(data)
        assignments = tr["z"]["value"]
    """
    if fn is None:
        return functools.partial(
            infer_discrete,
            first_available_dim=first_available_dim,
            temperature=temperature,
            rng_key=rng_key,
        )
    if temperature not in (0, 1):
        raise ValueError(f"temperature must be 0 (MAP) or 1 (sample), got {temperature}")
    return _InferDiscrete(fn, first_available_dim, temperature, rng_key)
