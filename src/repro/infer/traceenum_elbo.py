"""TraceEnum_ELBO + infer_discrete: exact parallel enumeration of discrete
latents (paper §2's flagship example of composable custom inference).

Enumeration reduces to broadcast-then-contract over named dims (funsor,
Obermeyer et al. 2019): the `enum` messenger gives every annotated discrete
site its full support along a fresh negative batch dim left of all plate
dims, and the contraction below sum-eliminates those dims out of the joint
log-density with logsumexp (sum-product) or max (max-product for MAP
decoding), *respecting plate structure*: a plate is a product over
independent slices, so enum dims local to a plate are eliminated before the
plate's log-factors are summed over the plate axis, while enum dims shared
with enclosing ordinals survive the plate sum (the classic "global mixture
component observed across a data plate" pattern).

Everything here is trace-time Python: under `jax.jit` the handler stack and
the contraction schedule run while XLA traces, so a compiled SVI step with
enumeration contains only the einsum-style broadcast/reduce ops —
`TraceEnum_ELBO` plugs into the shared `ELBO` engine from PR 1 and inherits
particle vectorization, `mesh=` sharding, and the compile-once `update_jit`
path unchanged (`num_traces` counts retraces the same way `mcmc.num_traces`
does).
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp
from jax.sharding import Mesh

from ..core.handlers import block, enum, replay, seed, substitute, trace
from ..core.primitives import prng_key
from ..kernels import ops as kernel_ops
from .elbo import ELBO, _apply_scale_mask
from .util import substitute_params

# ---------------------------------------------------------------------------
# log-factor collection
# ---------------------------------------------------------------------------


def _max_plate_nesting(*traces) -> int:
    """Deepest plate dim used by any site across the given traces."""
    mpn = 0
    for tr in traces:
        for site in tr.nodes.values():
            for frame in site.get("cond_indep_stack", ()):
                mpn = max(mpn, -frame.dim)
    return mpn


def _collect_factors(model_tr):
    """Extract (ordinal, log_prob, pending_scale) triples from a model trace,
    plus the frame->nesting-depth map used to order plate elimination and the
    pool of dims the enum messenger allocated. The ordinal of a factor is the
    frozenset of plate frames enclosing its site.

    Scale handling: a site scale (plate subsampling's size/subsample_size, or
    handlers.scale) is an exponent on probabilities — for factors entangled
    with enum dims it must multiply the *marginalized* per-slice log-density,
    i.e. apply AFTER logsumexp, not before (s*logsumexp(lp), never
    logsumexp(s*lp)). Factors free of enum dims get their scale applied here;
    the rest carry it as `pending` until the contraction finishes their local
    eliminations. Masking: a masked-out slice of an enumerated site fills with
    -log(K) (so its logsumexp contributes exactly 0), while every other
    factor fills with 0 as usual."""
    factors: List[Tuple[FrozenSet, jax.Array, Any]] = []
    depth: Dict = {}
    enum_dim_pool = set()
    for site in model_tr.nodes.values():
        if site["type"] != "sample":
            continue
        enum_dim = site["infer"].get("_enumerate_dim")
        if enum_dim is not None:
            enum_dim_pool.add(enum_dim)
        lp = site["fn"].log_prob(site["value"])
        mask = site["mask"]
        if enum_dim is not None:
            # distribution-level masks (.mask()) zero-fill inside log_prob,
            # which is wrong across an enum dim — fold them into the site
            # mask so the -log K neutral fill below covers both paths
            fn, dist_mask = site["fn"], None
            while fn is not None:
                m = getattr(fn, "_mask", None)
                if m is not None:
                    dist_mask = m if dist_mask is None else dist_mask & m
                fn = getattr(fn, "base_dist", None)
            if dist_mask is not None:
                mask = dist_mask if mask is None else mask & dist_mask
        if mask is not None:
            neutral = (
                -jnp.log(site["infer"]["_enumerate_cardinality"])
                if enum_dim is not None
                else 0.0
            )
            lp = jnp.where(mask, lp, neutral)
        frames = site["cond_indep_stack"]
        # cond_indep_stack is ordered outermost -> innermost
        for i, f in enumerate(frames):
            depth[f] = max(depth.get(f, 0), i)
        factors.append((frozenset(frames), lp, site["scale"]))
    pool = frozenset(enum_dim_pool)
    # scales on enum-free factors commute with everything downstream
    factors = [
        (o, lp, s) if _enum_dims(lp, pool) else (o, _scaled(lp, s), None)
        for o, lp, s in factors
    ]
    return factors, depth, pool


def _enum_dims(t: jax.Array, pool: FrozenSet[int]) -> FrozenSet[int]:
    """Allocated enum dims actually present (size > 1) in a right-aligned
    log-factor. Only dims the enum messenger allocated count — ordinary
    batch dims are never contracted."""
    return frozenset(
        d for d in pool if jnp.ndim(t) >= -d and jnp.shape(t)[jnp.ndim(t) + d] > 1
    )


def _reduce_dims(t: jax.Array, dims, sum_op) -> jax.Array:
    axes = tuple(jnp.ndim(t) + d for d in dims)
    return sum_op(t, axes) if axes else t


def _logsumexp_op(t, axes):
    return jsp.logsumexp(t, axis=axes, keepdims=True)


def _max_op(t, axes):
    return jnp.max(t, axis=axes, keepdims=True)


def _add_all(ts: List[jax.Array]) -> jax.Array:
    total = ts[0]
    for t in ts[1:]:
        total = total + t
    return total


def _scaled(t: jax.Array, scale) -> jax.Array:
    return t if scale is None else t * scale


def _uniform_scale(scales):
    """The single pending scale shared by a contraction group (None == 1)."""
    distinct = []
    for s in scales:
        if not any(s is d or (isinstance(s, (int, float)) and s == d) for d in distinct):
            distinct.append(s)
    if len(distinct) > 1:
        raise NotImplementedError(
            "factors with different log_prob scales meet inside one enumerated "
            f"contraction (scales {distinct}); apply the same plate/scale "
            "context to every site entangled with an enumerated variable"
        )
    return distinct[0]


_DISPATCH_MODES = ("auto", "pairwise")
_DEFAULT_CHAIN_MIN = 16


def _dispatch_mode(override: Optional[str] = None) -> str:
    """How `_ve_eliminate` routes contractions: ``auto`` (default) recognizes
    matmul- and chain-shaped eliminations and hands them to the fused semiring
    kernels in `kernels/ops.py`; ``pairwise`` forces the legacy one-dim-at-a-
    time greedy path. Explicit argument > ``REPRO_ENUM_DISPATCH`` env var."""
    mode = override or os.environ.get("REPRO_ENUM_DISPATCH", "auto")
    if mode not in _DISPATCH_MODES:
        raise ValueError(
            f"unknown enum dispatch mode {mode!r}; expected one of {_DISPATCH_MODES}"
        )
    return mode


def _chain_min_edges() -> int:
    """Minimum chain length (in binary factors) the auto dispatch lowers to
    the semiring kernels; shorter chains keep the greedy backward pass.

    The kernel path's win is trace/compile time — the greedy path's unrolled
    graph compiles superlinearly in T (seconds by T~32, minutes by T~512) —
    while its per-step cost is higher: the O(log T)-depth tree does
    O(T K^3) matrix-matrix work where the greedy backward pass does O(T K^2)
    matrix-vector work. Below the threshold, greedy compiles in well under a
    second and every SVI step is cheaper, so greedy wins outright.
    ``REPRO_ENUM_CHAIN_MIN`` overrides (2 = always lower; tests use this to
    exercise the kernel path on small fixtures)."""
    return max(2, int(os.environ.get("REPRO_ENUM_CHAIN_MIN", _DEFAULT_CHAIN_MIN)))


def _to_matrix(t: jax.Array, d_row: int, d_col: int) -> jax.Array:
    """View a right-aligned log-factor carrying enum dims (d_row, d_col) as a
    batched matrix (batch..., K_row, K_col), where the batch is the factor's
    (right-aligned) plate shape.

    Enum dims live in deep negative slots, so a long chain's factors have
    ranks up to T — transposing at that rank is exactly what blows up XLA
    compile time. Every axis other than the two enum axes and the trailing
    plate block is size 1, so one order-preserving reshape drops to a small
    rank first and the transpose happens there."""
    nd = jnp.ndim(t)
    shape = jnp.shape(t)
    ar, ac = nd + d_row, nd + d_col
    hi = max(ar, ac)
    plate_rank = 0
    for i in range(nd - 1, hi, -1):
        if shape[i] != 1:
            plate_rank = nd - i  # extend the kept block to this axis
    if any(
        shape[i] != 1
        for i in range(nd - plate_rank)
        if i not in (ar, ac)
    ):  # unexpected non-plate batch axis: fall back to the generic transpose
        m = jnp.moveaxis(t, (ar, ac), (-2, -1))
        lead = 0
        while lead < jnp.ndim(m) - 2 and jnp.shape(m)[lead] == 1:
            lead += 1
        return jnp.reshape(m, jnp.shape(m)[lead:]) if lead else m
    plates = shape[nd - plate_rank:] if plate_rank else ()
    first, second = (ar, ac) if ar < ac else (ac, ar)
    m = jnp.reshape(t, (shape[first], shape[second]) + tuple(plates))
    m = jnp.moveaxis(m, (0, 1), (-2, -1))  # (plates..., K_first, K_second)
    if ar > ac:  # row axis came second in memory order
        m = jnp.swapaxes(m, -1, -2)
    return m


def _from_matrix(m: jax.Array, d_row: int, d_col: int) -> jax.Array:
    """Inverse of `_to_matrix` for a contraction result: re-embed a batched
    matrix into right-aligned form with the row/col axes at enum slots
    (d_row, d_col) and the batch (plate) axes back at the right edge. The
    transpose happens at the small rank; the lift to full rank is a single
    size-1-inserting reshape."""
    L = jnp.ndim(m) - 2
    R = max(-d_row, -d_col, L + 2)
    ar, ac = R + d_row, R + d_col
    if ac >= R - L or ar >= R - L:  # enum slot would collide with the plate block
        m = jnp.reshape(m, (1,) * (R - L - 2) + jnp.shape(m))
        return jnp.moveaxis(m, (R - 2, R - 1), (ar, ac))
    x = jnp.moveaxis(m, (-2, -1) if ar < ac else (-1, -2), (0, 1))
    shape = [1] * R
    first, second = (ar, ac) if ar < ac else (ac, ar)
    shape[first], shape[second] = x.shape[0], x.shape[1]
    shape[R - L:] = x.shape[2:]
    return jnp.reshape(x, tuple(shape))


def _find_chains(edges, dims, blocked, min_edges):
    """Maximal simple paths through the factor graph whose edges are binary
    (two-enum-dim) factors. A dim may be chain-*interior* only if it is
    eliminable, touched by exactly two binary factors, and untouched by any
    higher-arity factor; every other dim terminates a path. Paths shorter
    than `min_edges` are discarded (see `_chain_min_edges`). Returns a list
    of dim sequences [D_0, ..., D_m] (edge t connects D_t, D_{t+1})."""
    adj: Dict[int, List[int]] = {}
    for i, (pair, _, _) in enumerate(edges):
        for d in pair:
            adj.setdefault(d, []).append(i)

    def interior(d):
        return d in dims and d not in blocked and len(adj.get(d, ())) == 2

    chains = []
    used = set()
    for i0 in range(len(edges)):
        if i0 in used:
            continue
        a, b = sorted(edges[i0][0])
        seq_edges, seq_dims = [i0], [a, b]
        for front in (True, False):
            while True:
                end = seq_dims[0] if front else seq_dims[-1]
                if not interior(end):
                    break
                nxt = next((j for j in adj[end] if j not in seq_edges), None)
                if nxt is None or nxt in used:
                    break
                (far,) = edges[nxt][0] - {end}
                if front:
                    seq_edges.insert(0, nxt)
                    seq_dims.insert(0, far)
                else:
                    seq_edges.append(nxt)
                    seq_dims.append(far)
        # need >= 1 interior dim to eliminate, no cycle closure, and enough
        # length that the kernel path's compile-time win outweighs its extra
        # per-step arithmetic
        if len(seq_edges) >= max(2, min_edges) and seq_dims[0] != seq_dims[-1]:
            used.update(seq_edges)
            chains.append((seq_edges, seq_dims))
    return chains


def _dispatch_chains(ts, dims, pool: FrozenSet[int], sum_op, mode: str):
    """Recognize matmul-/chain-shaped contractions and hand them to the fused
    semiring kernels (`ops.semiring_matmul` / `ops.hmm_scan`) before the
    greedy loop runs. A chain z_{t-1} -> z_t of binary log-factors becomes a
    stack of K x K matrices whose ordered semiring product eliminates every
    interior dim in O(log T) depth — replacing T sequential pairwise
    logsumexp eliminations AND the O(T^2) trace-time bookkeeping the greedy
    loop spends rediscovering the chain one dim at a time. Returns the
    (possibly rewritten) factor list and the dims still left to eliminate;
    semantics (pending scales, masked-site fills) are exactly the greedy
    path's — anything irregular simply falls through untouched."""
    if mode == "pairwise" or not dims:
        return ts, dims
    if sum_op is _logsumexp_op:
        semiring = "logsumexp"
    elif sum_op is _max_op:
        semiring = "max"
    else:  # custom sum_op: no kernel equivalent, keep the generic path
        return ts, dims

    entries = [(t, s, _enum_dims(t, pool)) for t, s in ts]
    blocked = set()
    for _, _, ds in entries:
        if len(ds) > 2:
            blocked |= ds
    # binary factors are the graph edges; merge parallel ones (same dim pair,
    # same pending scale — a log-space product is a sum) so the graph is simple
    by_pair: Dict[FrozenSet[int], List[int]] = {}
    for i, (_, _, ds) in enumerate(entries):
        if len(ds) == 2:
            by_pair.setdefault(frozenset(ds), []).append(i)
    edges = []  # (pair, tensor, scale); originals tracked for clean fallback
    edge_sources = []
    for pair, idxs in by_pair.items():
        try:
            sc = _uniform_scale([entries[i][1] for i in idxs])
        except NotImplementedError:
            blocked |= pair  # let the greedy path raise its usual error
            continue
        edges.append((pair, _add_all([entries[i][0] for i in idxs]), sc))
        edge_sources.append(idxs)
    unary_by_dim: Dict[int, List[int]] = {}
    for i, (_, _, ds) in enumerate(entries):
        if len(ds) == 1:
            (d,) = ds
            unary_by_dim.setdefault(d, []).append(i)

    consumed: set = set()
    new_factors = []
    remaining = set(dims)
    for seq_edges, seq_dims in _find_chains(edges, remaining, blocked, _chain_min_edges()):
        interior = seq_dims[1:-1]
        folded = [i for d in interior for i in unary_by_dim.get(d, ())]
        scales = [edges[e][2] for e in seq_edges] + [entries[i][1] for i in folded]
        try:
            chain_scale = _uniform_scale(scales)
        except NotImplementedError:
            continue  # mixed scales meet in this chain: greedy raises properly
        mats = []
        for t_idx, e in enumerate(seq_edges):
            tensor = edges[e][1]
            col = seq_dims[t_idx + 1]
            if col in interior:  # interior unaries fold into the edge entering them
                for i in unary_by_dim.get(col, ()):
                    tensor = tensor + entries[i][0]
            mats.append(_to_matrix(tensor, seq_dims[t_idx], col))
        sizes = {m.shape[-2:] for m in mats}
        if len(sizes) == 1 and len(mats) >= 3:
            batch = jnp.broadcast_shapes(*[m.shape[:-2] for m in mats])
            stacked = jnp.stack(
                [jnp.broadcast_to(m, batch + m.shape[-2:]) for m in mats], axis=-3
            )
            res = kernel_ops.hmm_scan(stacked, semiring=semiring)
        else:  # matmul-shaped (one interior dim) or ragged cardinalities
            res = mats[0]
            for m in mats[1:]:
                res = kernel_ops.semiring_matmul(res, m, semiring=semiring)
        new_factors.append((_from_matrix(res, seq_dims[0], seq_dims[-1]), chain_scale))
        remaining -= set(interior)
        consumed.update(folded)
        for e in seq_edges:
            consumed.update(edge_sources[e])

    if not new_factors:
        return ts, dims
    ts = [p for i, p in enumerate(ts) if i not in consumed] + new_factors
    return ts, remaining


def _ve_eliminate(ts, dims, pool: FrozenSet[int], sum_op, dispatch: Optional[str] = None):
    """Variable elimination over (tensor, pending_scale) pairs. Chain- and
    matmul-shaped sub-contractions are first handed to the fused semiring
    kernels (see `_dispatch_chains`); whatever remains falls to the greedy
    loop: drop each enum dim by combining only the factors that carry it,
    most-negative (= last-allocated) dim first. For a sequentially-sampled
    chain z_1 -> ... -> z_T the greedy loop alone is the backward algorithm —
    O(T K^2) work but O(T) sequential XLA ops and O(T^2) trace-time Python;
    the chain dispatch collapses that to one `hmm_scan` op. A group's pending
    scale resolves (multiplies) as soon as its result carries no more enum
    dims."""
    ts, dims = _dispatch_chains(ts, dims, pool, sum_op, _dispatch_mode(dispatch))
    for d in sorted(dims):
        group = [(t, s) for t, s in ts if d in _enum_dims(t, pool)]
        rest = [(t, s) for t, s in ts if d not in _enum_dims(t, pool)]
        if not group:
            continue
        scale = _uniform_scale([s for _, s in group])
        t = _reduce_dims(_add_all([t for t, _ in group]), (d,), sum_op)
        if scale is not None and not _enum_dims(t, pool):
            t, scale = t * scale, None
        ts = rest + [(t, scale)]
    return ts


def contract_log_factors(
    factors: List[Tuple[FrozenSet, jax.Array, Any]],
    depth: Dict,
    pool: FrozenSet[int],
    keep_dims: FrozenSet[int] = frozenset(),
    keep_frames: FrozenSet = frozenset(),
    sum_op=_logsumexp_op,
    dispatch: Optional[str] = None,
) -> jax.Array:
    """Plate-aware tensor variable elimination in log space.

    Eliminates every enum dim not in `keep_dims` (via `sum_op`, keepdims) and
    sums out every plate frame not in `keep_frames`, processing ordinals
    innermost-first so that each enum dim is eliminated at the shallowest
    ordinal where it still appears — i.e. inside its own plate context but
    outside any plate it is shared across. Pending site scales resolve after
    their factor's local eliminations (see `_collect_factors`); a factor
    still pending at its plate sum carries only dims shared with enclosing
    ordinals, where scale-inside is the correct minibatch estimator of the
    full-data inner sum. Returns a single right-aligned log-factor (all
    reduced axes kept at size 1).

    `dispatch` controls how eliminations are lowered: ``"auto"`` (default;
    also via the ``REPRO_ENUM_DISPATCH`` env var) routes matmul-/chain-shaped
    sub-contractions through the fused semiring kernels in `kernels/ops.py`,
    ``"pairwise"`` forces the legacy greedy path everywhere.
    """
    groups: Dict[FrozenSet, List[Tuple[jax.Array, Any]]] = {}
    for ordinal, t, s in factors:
        groups.setdefault(ordinal, []).append((t, s))

    while True:
        pending = [o for o, ts in groups.items() if ts and (o - keep_frames)]
        if not pending:
            break
        # innermost first: the ordinal whose deepest pending frame nests deepest
        o = max(pending, key=lambda o: max(depth[f] for f in (o - keep_frames)))
        ts = groups.pop(o)
        other_dims: set = set()
        for ts2 in groups.values():
            for t2, _ in ts2:
                other_dims |= _enum_dims(t2, pool)
        local = set()
        for t, _ in ts:
            local |= _enum_dims(t, pool)
        local -= other_dims
        local -= keep_dims
        if local:
            ts = _ve_eliminate(ts, local, pool, sum_op, dispatch)
        # the plate is a product over slices: sum the slice log-factor over
        # the innermost pending frame's axis, then hand the result to the
        # enclosing ordinal
        f = max(o - keep_frames, key=lambda fr: depth[fr])
        t = _add_all([_scaled(t, s) for t, s in ts])
        if jnp.ndim(t) >= -f.dim:
            t = jnp.sum(t, axis=jnp.ndim(t) + f.dim, keepdims=True)
        groups.setdefault(o - {f}, []).append((t, None))

    ts = [p for tl in groups.values() for p in tl]
    if not ts:
        return jnp.zeros(())
    ts = [(_scaled(t, s), None) for t, s in ts]
    leftover = set()
    for t, _ in ts:
        leftover |= _enum_dims(t, pool)
    ts = _ve_eliminate(ts, leftover - keep_dims, pool, sum_op, dispatch)
    return _add_all([t for t, _ in ts])


# ---------------------------------------------------------------------------
# TraceEnum_ELBO
# ---------------------------------------------------------------------------


class TraceEnum_ELBO(ELBO):
    """ELBO with exact parallel marginalization of enumerated discrete model
    sites. Annotate sites with ``infer={"enumerate": "parallel"}`` (or wrap
    the model in `config_enumerate`); the guide must not sample them.

    Plugs into the shared `ELBO` engine: `num_particles`, `mesh=` particle
    sharding, and SVI's compile-once `update_jit` all work unchanged.
    `max_plate_nesting` is detected from a prototype trace when not given;
    pass it explicitly when the model's shapes depend on rarely-exercised
    branches. `num_traces` counts XLA retraces (jit-stability assertion hook,
    same idiom as `mcmc.num_traces`).
    """

    def __init__(
        self,
        num_particles: int = 1,
        max_plate_nesting: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        particle_axis: Union[str, Tuple[str, ...], None] = None,
    ):
        super().__init__(num_particles, mesh=mesh, particle_axis=particle_axis)
        self.max_plate_nesting = max_plate_nesting
        self.num_traces = 0

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        self.num_traces += 1  # trace-time side effect (retrace detector)
        key_guide, key_model = jax.random.split(rng_key)
        seeded_guide = seed(substitute_params(guide, params), key_guide)
        guide_tr = trace(seeded_guide).get_trace(*args, **kwargs)
        for name, site in guide_tr.nodes.items():
            if (
                site["type"] == "sample"
                and not site["is_observed"]
                and site["infer"].get("enumerate")
            ):
                raise NotImplementedError(
                    f"guide site '{name}' requests enumeration; guide-side "
                    "enumeration is not implemented — annotate the model site "
                    "and remove it from the guide so TraceEnum_ELBO can "
                    "marginalize it exactly"
                )
        seeded_model = seed(substitute_params(model, params), key_model)
        if self.max_plate_nesting is None:
            # one extra prototype trace (trace-time only), then cached
            proto_tr = trace(replay(seeded_model, guide_tr)).get_trace(*args, **kwargs)
            self.max_plate_nesting = _max_plate_nesting(guide_tr, proto_tr)
        mpn = self.max_plate_nesting
        with enum(first_available_dim=-1 - mpn):
            model_tr = trace(replay(seeded_model, guide_tr)).get_trace(*args, **kwargs)

        factors, depth, pool = _collect_factors(model_tr)
        elbo = jnp.sum(contract_log_factors(factors, depth, pool))
        score_logq = 0.0  # REINFORCE factor for non-reparam guide sites
        for site in guide_tr.nodes.values():
            if site["type"] != "sample" or site["is_observed"]:
                continue
            lq = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
            elbo = elbo - jnp.sum(lq)
            if not site["fn"].has_rsample:
                score_logq = score_logq + jnp.sum(lq)
        surrogate = elbo + jax.lax.stop_gradient(elbo) * (
            score_logq - jax.lax.stop_gradient(score_logq)
        )
        return elbo, surrogate


# ---------------------------------------------------------------------------
# infer_discrete: posterior decoding of enumerated sites
# ---------------------------------------------------------------------------


def _index_factor(t: jax.Array, dim: int, idx: jax.Array) -> jax.Array:
    """Condition a right-aligned log-factor on idx along enum dim `dim`
    (idx is right-aligned with a size-1 slot at `dim`)."""
    axis = jnp.ndim(t) + dim
    if axis < 0 or jnp.shape(t)[axis] == 1:
        return t  # factor does not carry this dim
    if jnp.ndim(idx) > jnp.ndim(t):
        t = jnp.reshape(t, (1,) * (jnp.ndim(idx) - jnp.ndim(t)) + jnp.shape(t))
        axis = jnp.ndim(t) + dim
    elif jnp.ndim(idx) < jnp.ndim(t):
        idx = jnp.reshape(idx, (1,) * (jnp.ndim(t) - jnp.ndim(idx)) + jnp.shape(idx))
    return jnp.take_along_axis(t, idx.astype(jnp.int32), axis=axis)


def _enum_trace(model, rng_key, args, kwargs, first_available_dim):
    """Run the hidden enumeration pass: seed, auto-detect max_plate_nesting
    (unless first_available_dim pins it), and trace under `enum`. Shared by
    discrete_marginals and _decode_discrete."""
    with block():  # hide the enumeration pass from enclosing handlers
        seeded = seed(model, jnp.asarray(rng_key))
        if first_available_dim is None:
            proto_tr = trace(seeded).get_trace(*args, **kwargs)
            mpn = _max_plate_nesting(proto_tr)
        else:
            mpn = -first_available_dim - 1
        with enum(first_available_dim=-1 - mpn):
            tr = trace(seeded).get_trace(*args, **kwargs)
    return tr


def discrete_marginals(
    model: Callable,
    rng_key,
    *args,
    first_available_dim: Optional[int] = None,
    **kwargs,
) -> Dict[str, jax.Array]:
    """Exact posterior marginals of every enumerated site, as normalized
    log-probabilities with the site's support on the LAST axis (preceded by
    the site's plate dims). Condition/substitute the model beforehand.

    Uses the dice-factor identity: d logZ / d (site's log-factor) is the
    posterior marginal of that factor's indices, which stays exact even when
    a global enumerated variable couples plate slices (a per-site contraction
    would drop the other slices' evidence about the global)."""
    if rng_key is None:
        rng_key = prng_key()  # ambient seed handler, if any
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    tr = _enum_trace(model, rng_key, args, kwargs, first_available_dim)
    factors, depth, pool = _collect_factors(tr)

    enum_sites = {
        name: site
        for name, site in tr.nodes.items()
        if site["type"] == "sample" and "_enumerate_dim" in site["infer"]
    }
    sample_names = [
        name for name, site in tr.nodes.items() if site["type"] == "sample"
    ]

    def log_z(perturbs: Dict[str, jax.Array]) -> jax.Array:
        perturbed = [
            (o, t + perturbs[name], s) if name in perturbs else (o, t, s)
            for name, (o, t, s) in zip(sample_names, factors)
        ]
        return jnp.sum(contract_log_factors(perturbed, depth, pool))

    zero = {
        name: jnp.zeros_like(factors[sample_names.index(name)][1])
        for name in enum_sites
    }
    joint_probs = jax.grad(log_z)(zero)

    marginals: Dict[str, jax.Array] = {}
    for name, site in enum_sites.items():
        d = site["infer"]["_enumerate_dim"]
        probs = joint_probs[name]
        # sum joint posterior over everything but this site's own enum dim
        # and its plate dims (per-slice marginals)
        keep = {d} | {f.dim for f in site["cond_indep_stack"]}
        drop = tuple(a for a in range(-jnp.ndim(probs), 0) if a not in keep)
        probs = jnp.sum(probs, axis=drop, keepdims=True) if drop else probs
        logits = jnp.moveaxis(jnp.log(probs), jnp.ndim(probs) + d, -1)
        target_rank = max([-f.dim for f in site["cond_indep_stack"]], default=0)
        marginals[name] = _squeeze_to_rank(
            jax.nn.log_softmax(logits, -1), target_rank + 1
        )
    return marginals


def _squeeze_to_rank(x: jax.Array, rank: int) -> jax.Array:
    """Drop leading size-1 axes until `x` has `rank` dims."""
    while jnp.ndim(x) > rank and jnp.shape(x)[0] == 1:
        x = x[0]
    return x


def _decode_discrete(model, rng_key, args, kwargs, first_available_dim, temperature):
    """Decode enumerated sites: temperature=1 -> exact joint posterior sample
    (sequential conditioning = chain rule); 0 -> exact joint MAP (max-product
    elimination + sequential argmax)."""
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    key_trace, key_sample = jax.random.split(jnp.asarray(rng_key))
    sum_op = _max_op if temperature == 0 else _logsumexp_op
    tr = _enum_trace(model, key_trace, args, kwargs, first_available_dim)
    factors, depth, pool = _collect_factors(tr)

    enum_sites = [
        (name, site)
        for name, site in tr.nodes.items()
        if site["type"] == "sample" and "_enumerate_dim" in site["infer"]
    ]
    # allocation order == execution order == decreasing dim
    enum_sites.sort(key=lambda ns: -ns[1]["infer"]["_enumerate_dim"])

    values: Dict[str, jax.Array] = {}
    for i, (name, site) in enumerate(enum_sites):
        d = site["infer"]["_enumerate_dim"]
        ordinal = frozenset(site["cond_indep_stack"])
        marg = contract_log_factors(
            factors, depth, pool, keep_dims=frozenset([d]), keep_frames=ordinal,
            sum_op=sum_op,
        )
        logits = jnp.moveaxis(marg, jnp.ndim(marg) + d, -1)  # (*plates, K)
        # the decoded value's batch rank comes from the site's plate context
        # (the enum-trace fn.batch_shape is polluted by parent enum dims)
        target_rank = max([-f.dim for f in site["cond_indep_stack"]], default=0)
        if temperature == 0:
            idx = jnp.argmax(logits, -1)
        else:
            idx = jax.random.categorical(jax.random.fold_in(key_sample, i), logits)
        # condition the remaining factors on the decoded value (chain rule)
        idx_r = jnp.expand_dims(idx, d)
        factors = [(o, _index_factor(t, d, idx_r), s) for o, t, s in factors]
        # map index -> support value, shaped like an ordinary draw at the site
        support = site["fn"].enumerate_support(expand=False)
        event_shape = site["fn"].event_shape
        support_flat = jnp.reshape(support, (jnp.shape(support)[0],) + event_shape)
        val = jnp.take(support_flat, idx, axis=0)
        values[name] = _squeeze_to_rank(val, target_rank + len(event_shape))

    # pin every free (non-enumerated) latent to its decode-pass draw: the
    # discrete sites were decoded AGAINST those values, so re-sampling them in
    # the replay pass would return an inconsistent (continuous, discrete) pair
    for name, site in tr.nodes.items():
        if (
            site["type"] == "sample"
            and not site["is_observed"]
            and name not in values
        ):
            values[name] = site["value"]
    return values


class _InferDiscrete:
    """Callable wrapper produced by `infer_discrete`."""

    def __init__(self, fn, first_available_dim, temperature, rng_key):
        self.fn = fn
        self.first_available_dim = first_available_dim
        self.temperature = temperature
        self.rng_key = rng_key
        functools.update_wrapper(self, fn, updated=[])

    def __call__(self, *args, **kwargs):
        # no explicit key -> draw one from the ambient seed handler, so each
        # seeded call of the wrapper yields a fresh posterior draw instead of
        # silently repeating one fixed decode
        rng_key = self.rng_key
        if rng_key is None:
            rng_key = prng_key()
        values = _decode_discrete(
            self.fn,
            rng_key,
            args,
            kwargs,
            self.first_available_dim,
            self.temperature,
        )
        return substitute(self.fn, data=values)(*args, **kwargs)


def infer_discrete(
    fn: Optional[Callable] = None,
    *,
    first_available_dim: Optional[int] = None,
    temperature: int = 1,
    rng_key=None,
) -> Callable:
    """Posterior decoding of enumerated discrete sites (Pyro's
    `infer_discrete`): returns a model whose annotated discrete sites take
    exact joint posterior samples (``temperature=1``, sequential conditioning
    via the chain rule) or the exact joint MAP assignment (``temperature=0``,
    max-product elimination), given the observations/conditioning baked into
    the model. Any free continuous latents are drawn once (keyed by
    ``rng_key``) and pinned across the decode and replay passes, so the
    returned execution is one coherent joint draw — but their posterior is
    NOT inferred here. Continuous posteriors go in first — substitute
    SVI/MCMC draws into the model, then decode:

        guide_draws = {...}                      # from SVI or MCMC
        decoded = infer_discrete(
            handlers.substitute(config_enumerate(model), data=guide_draws),
            temperature=0, rng_key=key)
        tr = handlers.trace(decoded).get_trace(data)
        assignments = tr["z"]["value"]
    """
    if fn is None:
        return functools.partial(
            infer_discrete,
            first_available_dim=first_available_dim,
            temperature=temperature,
            rng_key=rng_key,
        )
    if temperature not in (0, 1):
        raise ValueError(f"temperature must be 0 (MAP) or 1 (sample), got {temperature}")
    return _InferDiscrete(fn, first_available_dim, temperature, rng_key)
