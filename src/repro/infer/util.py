"""Shared inference utilities: constrained<->unconstrained bridging,
log-density evaluation, initialization strategies."""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.handlers import Trace, seed, substitute, trace
from ..distributions import biject_to, constraints


def log_density(
    model: Callable, args: tuple, kwargs: dict, params: Dict[str, Any]
) -> Tuple[jax.Array, Trace]:
    """Joint log-density of `model` at substituted values (constrained space)."""
    model = substitute(model, data=params)
    tr = trace(model).get_trace(*args, **kwargs)
    return tr.log_prob_sum(), tr


def _param_substitute_fn(params: Dict[str, Any], msg: Dict[str, Any]):
    """Substitute fn that maps *unconstrained* optimizer params into
    constrained space at each `param` site."""
    if msg["type"] != "param":
        return None
    name = msg["name"]
    if name not in params:
        return None
    constraint = msg["kwargs"].get("constraint") or constraints.real
    transform = biject_to(constraint)
    return transform(params[name])


def substitute_params(fn: Callable, params: Dict[str, Any]):
    """Wrap `fn` so its param sites read (transformed) values from `params`."""
    return substitute(fn, substitute_fn=partial(_param_substitute_fn, params))


def transform_fn(transforms: Dict[str, Any], params: Dict[str, Any], invert=False):
    """Apply per-site bijectors to a dict of values."""
    out = {}
    for name, value in params.items():
        t = transforms.get(name)
        if t is None:
            out[name] = value
        else:
            out[name] = t.inv(value) if invert else t(value)
    return out


def constrain_fn(
    model: Callable, args: tuple, kwargs: dict, transforms: Dict[str, Any], unconstrained: Dict[str, Any]
) -> Dict[str, Any]:
    return transform_fn(transforms, unconstrained)


def potential_energy(
    model: Callable,
    args: tuple,
    kwargs: dict,
    transforms: Dict[str, Any],
    unconstrained_params: Dict[str, Any],
) -> jax.Array:
    """-log p(constrain(z), obs) - log|J| : the HMC/NUTS target."""
    constrained = {}
    log_jac = 0.0
    for name, z in unconstrained_params.items():
        t = transforms.get(name)
        if t is None:
            constrained[name] = z
        else:
            x = t(z)
            constrained[name] = x
            lad = t.log_abs_det_jacobian(z, x)
            log_jac = log_jac + jnp.sum(lad)
    lp, _ = log_density(model, args, kwargs, constrained)
    return -(lp + log_jac)


def get_model_transforms(
    rng_key, model: Callable, args: tuple = (), kwargs: Optional[dict] = None
) -> Tuple[Dict[str, Any], Dict[str, Any], Trace]:
    """Trace the model once to find latent sites, their supports, and initial
    values; returns (transforms, initial unconstrained values, trace)."""
    kwargs = kwargs or {}
    tr = trace(seed(model, rng_key)).get_trace(*args, **kwargs)
    transforms, inits = {}, {}
    for name, site in tr.nodes.items():
        if site["type"] == "sample" and not site["is_observed"]:
            support = site["fn"].support
            if getattr(site["fn"], "is_discrete", False):
                raise ValueError(
                    f"site '{name}' is discrete; HMC/NUTS requires continuous latents "
                    "(marginalize or use SVI with enumeration)"
                )
            t = biject_to(support)
            transforms[name] = t
            inits[name] = t.inv(site["value"])
    return transforms, inits, tr


def initialize_model(
    rng_key, model: Callable, args: tuple = (), kwargs: Optional[dict] = None
) -> Tuple[Callable, Dict[str, Any], Dict[str, Any]]:
    """Trace `model` once and build everything HMC/NUTS needs: returns
    (potential_fn over unconstrained space, per-site bijectors, unconstrained
    initial values). The potential_fn is pure and jit/vmap-safe; the
    multi-chain MCMC driver calls this exactly once per run."""
    kwargs = kwargs or {}
    transforms, inits, _ = get_model_transforms(rng_key, model, args, kwargs)
    pe = partial(potential_energy, model, args, kwargs, transforms)
    return pe, transforms, inits


def init_to_uniform(rng_key, inits: Dict[str, Any], radius: float = 2.0) -> Dict[str, Any]:
    out = {}
    for i, (name, v) in enumerate(sorted(inits.items())):
        k = jax.random.fold_in(rng_key, i)
        out[name] = jax.random.uniform(k, jnp.shape(v), minval=-radius, maxval=radius)
    return out


def log_mean_exp(x, axis=0):
    n = x.shape[axis] if hasattr(x, "shape") and x.ndim else 1
    return jax.scipy.special.logsumexp(x, axis=axis) - jnp.log(n)
