"""Deprecated alias: importance sampling lives in `infer.combinators` now.

`Importance` was the standalone engine; it is exactly the degenerate
one-step `propose` of the combinator calculus, so the implementation moved
to `combinators.ImportanceSampling` (same key structure, same weights,
bit-for-bit — tests/test_engine_api.py pins the parity). This entry point
survives as a FutureWarning alias; its `num_samples` kwarg maps onto the
canonical `num_particles` spelling shared by the ELBOs and SMC.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

from .combinators import ImportanceSampling


class Importance(ImportanceSampling):
    """Deprecated — use `repro.infer.ImportanceSampling`."""

    def __init__(
        self,
        model: Callable,
        guide: Optional[Callable] = None,
        num_samples: int = 100,
        **kwargs,
    ):
        warnings.warn(
            "Importance is deprecated; use repro.infer.ImportanceSampling"
            "(model, guide, num_particles=...) — the one-step `propose` "
            "combinator (see docs/inference.md).",
            FutureWarning,
            stacklevel=2,
        )
        super().__init__(model, guide, num_particles=num_samples, **kwargs)

    @property
    def num_samples(self) -> int:
        return self.num_particles
