"""Importance sampling with arbitrary guide proposals (paper §2: "Some
inference algorithms in Pyro, such as SVI and importance sampling, can use
arbitrary Pyro programs (called guides) as ... proposal distributions")."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core.handlers import replay, seed, trace
from .util import log_mean_exp, substitute_params


class Importance:
    def __init__(self, model: Callable, guide: Optional[Callable] = None, num_samples: int = 100):
        self.model = model
        self.guide = guide
        self.num_samples = num_samples

    def _single_weight(self, rng_key, params, args, kwargs):
        if self.guide is not None:
            key_g, key_m = jax.random.split(rng_key)
            guide_tr = trace(seed(substitute_params(self.guide, params), key_g)).get_trace(
                *args, **kwargs
            )
            model_tr = trace(
                replay(seed(substitute_params(self.model, params), key_m), guide_tr)
            ).get_trace(*args, **kwargs)
            log_w = model_tr.log_prob_sum() - guide_tr.log_prob_sum(
                lambda n, s: not s["is_observed"]
            )
        else:  # prior proposal: weight = likelihood
            model_tr = trace(seed(substitute_params(self.model, params), rng_key)).get_trace(
                *args, **kwargs
            )
            log_w = model_tr.log_prob_sum(lambda n, s: s["is_observed"])
        latents = {
            n: model_tr[n]["value"]
            for n in model_tr.stochastic_nodes()
        }
        return log_w, latents

    def run(self, rng_key, *args, params=None, **kwargs):
        params = params or {}
        keys = jax.random.split(rng_key, self.num_samples)
        log_weights, latents = jax.vmap(
            lambda k: self._single_weight(k, params, args, kwargs)
        )(keys)
        self.log_weights = log_weights
        self.latents = latents
        return self

    def log_evidence(self):
        return log_mean_exp(self.log_weights)

    def effective_sample_size(self):
        log_norm = jax.scipy.special.logsumexp(self.log_weights)
        w = jnp.exp(self.log_weights - log_norm)
        return 1.0 / jnp.sum(w ** 2)

    def resample(self, rng_key, num: int):
        idx = jax.random.categorical(rng_key, self.log_weights, shape=(num,))
        return jax.tree_util.tree_map(lambda x: x[idx], self.latents)
