"""ChEES-style cross-chain trajectory-length adaptation (Hoffman, Radul &
Sountsov 2021, "An Adaptive MCMC Scheme for Setting Trajectory Lengths in
Hamiltonian Monte Carlo").

NUTS picks a trajectory length per chain per draw by building a tree — robust
but control-flow heavy. ChEES instead tunes ONE shared trajectory length from
statistics pooled *across* chains, which is exactly the information the fused
batched driver (`infer/mcmc.py`) has on hand: every transition sees all C
proposals at once. The criterion is the Change in the Estimator of the
Expected Square of the centered second moment,

    ChEES = (1/4) E[ (||z' - E z'||^2 - ||z - E z||^2)^2 ],

whose gradient with respect to the trajectory *time* t has the per-chain
single-sample estimator

    g_c = (||z'_c - z̄'||^2 - ||z_c - z̄||^2) · ⟨z'_c - z̄', v'_c⟩,

with v' = M⁻¹ r' the end-point velocity. Chains are weighted by their
Metropolis accept probability (a proposal that will be rejected carries no
information about where the chain is going), trajectories are jittered by a
Halton sequence (u_i · tau with u_i the radical-inverse of the step index —
low-discrepancy, so no RNG pressure and no resonance with periodic targets),
and log(tau) follows the gradient through Adam. Everything here is shared
across chains — per the compile-once contract the state is a handful of
scalars, and with a single chain the centered moments vanish so adaptation
degrades gracefully to a no-op (use NUTS or a fixed `trajectory_length`
there).

The driver freezes the state after warmup exactly like dual averaging and
the Welford mass-matrix accumulator (`mcmc.HMC._fused_adapt`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Adam hyperparameters from the reference implementation (tensorflow
# probability's ChEES criterion uses the same learning rate).
_ADAM_LR = 0.025
_ADAM_B1 = 0.9
_ADAM_B2 = 0.999
_ADAM_EPS = 1e-8

# Static safety band on log(tau). Realizability (at least one, at most
# `max_num_steps` leapfrog steps) is enforced by the DRIVER when it converts
# tau to a step count — clipping the *state* against the still-adapting step
# size would let one early tiny-eps iteration collapse tau to eps, and Adam
# at lr 0.025 cannot climb back within a normal warmup.
_LOG_TAU_MIN = jnp.log(1e-3)
_LOG_TAU_MAX = jnp.log(1e3)


class ChEESState(NamedTuple):
    log_tau: jax.Array  # () log trajectory length (time units, not steps)
    m: jax.Array        # () Adam first moment
    v: jax.Array        # () Adam second moment
    t: jax.Array        # () Adam step count


def chees_init(trajectory_length: float) -> ChEESState:
    return ChEESState(
        jnp.log(jnp.asarray(trajectory_length, jnp.float32)),
        jnp.zeros(()),
        jnp.zeros(()),
        jnp.zeros(()),
    )


def halton_jitter(i, nbits: int = 16):
    """u_i ∈ (0, 1): the base-2 radical inverse (van der Corput / 1-D Halton
    sequence) of step index i — deterministic low-discrepancy jitter for the
    trajectory length. Static 16-bit unroll, jit-friendly."""
    n = (jnp.asarray(i, jnp.uint32) + 1) & jnp.uint32((1 << nbits) - 1)
    u = jnp.zeros((), jnp.float32)
    f = 0.5
    for _ in range(nbits):
        u = u + f * (n & 1)
        n = n >> 1
        f = f * 0.5
    return jnp.maximum(u, 2.0 ** -nbits)


def chees_update(
    state: ChEESState,
    z0: jax.Array,           # (C, D) positions before the transition
    z1: jax.Array,           # (C, D) PROPOSED end points (not post-accept)
    r1: jax.Array,           # (C, D) proposed end-point momenta
    accept_prob: jax.Array,  # (C,) Metropolis accept probabilities
    inv_mass: jax.Array,     # (C, D) or (D,) diagonal inverse mass
    jitter: jax.Array,       # () the u_i this transition's length was scaled by
) -> ChEESState:
    """One cross-chain Adam ascent step on log(tau). Pure; the caller gates
    it on `i < warmup_len` and freezes the state afterwards."""
    d0 = z0 - jnp.mean(z0, axis=0)
    d1 = z1 - jnp.mean(z1, axis=0)
    change = jnp.sum(d1 * d1, axis=-1) - jnp.sum(d0 * d0, axis=-1)  # (C,)
    v1 = inv_mass * r1
    per_chain = change * jnp.sum(d1 * v1, axis=-1)                  # (C,)
    w = jnp.maximum(accept_prob, 0.0)
    # d/dt of the criterion, estimated across chains; t = u·tau so the
    # chain rule to log tau multiplies by u·tau
    grad_t = jnp.sum(w * per_chain) / jnp.maximum(jnp.sum(w), 1e-10)
    grad = grad_t * jitter * jnp.exp(state.log_tau)

    t = state.t + 1.0
    m = _ADAM_B1 * state.m + (1.0 - _ADAM_B1) * grad
    v = _ADAM_B2 * state.v + (1.0 - _ADAM_B2) * grad * grad
    m_hat = m / (1.0 - _ADAM_B1 ** t)
    v_hat = v / (1.0 - _ADAM_B2 ** t)
    log_tau = state.log_tau + _ADAM_LR * m_hat / (jnp.sqrt(v_hat) + _ADAM_EPS)
    log_tau = jnp.clip(log_tau, _LOG_TAU_MIN, _LOG_TAU_MAX)
    return ChEESState(log_tau, m, v, t)
