"""Inference combinators: models and proposals as first-class values.

The combinator calculus of Stites & Zimmermann ("Learning proposals for
probabilistic programs with inference combinators", 2103.00668, PAPERS.md)
treats inference programs as *values* that compose, each carrying a properly
weighted sample. Here a program has one-particle semantics

    program.run(key, params, *args, **kwargs) -> Run(trace, output, log_weight)

where ``log_weight`` is the incremental importance weight of the particle the
run produced, and population semantics (``run_population``) obtained by
vmapping ``run`` over the shared particle-sharding path the ELBOs use
(`elbo.shard_particles` — particles ride a ``mesh=`` exactly like
multi-particle ELBO estimates).

Combinators::

    primitive(f)      lift a repro model: sample latents from the prior,
                      score observations (log_weight = observed log-prob).
    compose(f2, f1)   proposal composition: run f1, feed its output to f2,
                      union the traces, add the weights.
    extend(p, f)      target extension: auxiliary sites appended to target
                      p by kernel f (same mechanics as compose, opposite
                      role — f enlarges the *numerator* of a later propose).
    propose(p, q)     importance step: draw from proposal q, rescore under
                      target p (replaying q's choices), weight
                      p(replayed sites + observations) / q(latent sites).
    resample(prog)    population-level: when the incoming population's ESS
                      drops below threshold*N, draw ancestors with
                      `kernels.ops.resample` (systematic) or categorical
                      (multinomial), reset the weights, bank the marginal-
                      likelihood increment — then run ``prog``.

`infer.smc.SMC` is a scan of ``resample(propose(...))`` steps; one-step
``propose`` with no time loop is importance sampling (`ImportanceSampling`
below, which the legacy `infer.importance.Importance` now aliases); SMC² is
an outer sweep whose step programs `P.factor` an inner sweep's log-evidence
increment (see `smc.smc_sweep`).
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .. import settings
from ..core.handlers import replay, seed, trace
from ..kernels import ops
from .elbo import shard_particles
from .util import log_mean_exp, substitute_params

RESAMPLE_METHODS = ("systematic", "multinomial")


class Run(NamedTuple):
    """One particle's worth of a program: its trace, its return value (the
    carry fed to downstream programs), and its incremental log-weight."""

    trace: Any
    output: Any
    log_weight: jax.Array


class Population(NamedTuple):
    """A particle population: vmapped carries plus persistent log-weights."""

    carry: Any
    log_weights: jax.Array


class StepAux(NamedTuple):
    """Per-step diagnostics a population step emits (the SMC history row).
    `log_weights` are the post-reweight population weights — what filtering
    expectations at that step weight by; `ess` is their Kish ESS."""

    latents: Dict[str, jax.Array]
    incr_log_weight: jax.Array
    log_weights: jax.Array
    ess: jax.Array
    resampled: jax.Array
    log_z_incr: jax.Array


def effective_sample_size(log_weights: jax.Array) -> jax.Array:
    """Kish ESS of unnormalized log-weights: (Σw)²/Σw² after normalization.
    Equal weights give exactly N (the no-resample fixed point of the
    ``ess < threshold * N`` trigger at threshold=1)."""
    norm = jax.scipy.special.logsumexp(log_weights, axis=-1, keepdims=True)
    finite = jnp.isfinite(norm)
    w = jnp.where(
        finite,
        jnp.exp(log_weights - jnp.where(finite, norm, 0.0)),
        1.0 / log_weights.shape[-1],
    )
    return 1.0 / jnp.sum(jnp.square(w), axis=-1)


class Program:
    """Base combinator. Subclasses implement `run` (one particle); the
    population path below is shared."""

    def run(self, rng_key, params, *args, replay_from=None, **kwargs) -> Run:
        raise NotImplementedError

    def run_population(
        self,
        rng_key,
        params,
        population: Population,
        args: Tuple = (),
        kwargs: Optional[dict] = None,
        *,
        mesh=None,
        particle_axis=None,
    ) -> Tuple[Population, StepAux]:
        """Propagate + reweight every particle: vmap `run` over per-particle
        keys (sharded across `mesh` exactly like ELBO particles), feeding
        each particle its own carry, and add the incremental weights."""
        kwargs = kwargs or {}
        n = population.log_weights.shape[0]
        keys = shard_particles(jax.random.split(rng_key, n), mesh, particle_axis)

        def one(k, carry):
            r = self.run(k, params, carry, *args, **kwargs)
            latents = {nm: r.trace[nm]["value"] for nm in r.trace.stochastic_nodes()}
            return r.output, jnp.asarray(r.log_weight, jnp.float32), latents

        out, incr, latents = jax.vmap(one)(keys, population.carry)
        lw = population.log_weights + incr
        return Population(out, lw), StepAux(
            latents=latents,
            incr_log_weight=incr,
            log_weights=lw,
            ess=effective_sample_size(lw),
            resampled=jnp.asarray(False),
            log_z_incr=jnp.float32(0.0),
        )

    def init_population(
        self,
        rng_key,
        params,
        num_particles: int,
        args: Tuple = (),
        kwargs: Optional[dict] = None,
        *,
        mesh=None,
        particle_axis=None,
    ) -> Tuple[Population, StepAux]:
        """Spawn a fresh population (no incoming carry): the t=0 step."""
        kwargs = kwargs or {}
        keys = shard_particles(
            jax.random.split(rng_key, num_particles), mesh, particle_axis
        )

        def one(k):
            r = self.run(k, params, *args, **kwargs)
            latents = {nm: r.trace[nm]["value"] for nm in r.trace.stochastic_nodes()}
            return r.output, jnp.asarray(r.log_weight, jnp.float32), latents

        out, lw, latents = jax.vmap(one)(keys)
        return Population(out, lw), StepAux(
            latents=latents,
            incr_log_weight=lw,
            log_weights=lw,
            ess=effective_sample_size(lw),
            resampled=jnp.asarray(False),
            log_z_incr=jnp.float32(0.0),
        )


class Primitive(Program):
    """A repro model as a combinator value. Sampling semantics: latents from
    the prior (or replayed from ``replay_from``), observations scored —
    log_weight is the observed-site log-prob (likelihood weighting)."""

    def __init__(self, fn: Callable):
        self.fn = fn

    def run(self, rng_key, params, *args, replay_from=None, **kwargs) -> Run:
        seeded = seed(substitute_params(self.fn, params), rng_key)
        if replay_from is not None:
            seeded = replay(seeded, replay_from)
        handler = trace(seeded)
        # call through the handler (not get_trace) so the model's return
        # value — the carry downstream programs consume — is kept
        out = handler(*args, **kwargs)
        t = handler.trace
        lw = t.log_prob_sum(lambda n, s: s["is_observed"])
        return Run(t, out, lw)


class Compose(Program):
    """``compose(f2, f1)``: run f1, pipe its output into f2 (as f2's first
    positional argument), union the traces, add the weights. Site names must
    be disjoint — a clash raises at trace time."""

    role = "composed"

    def __init__(self, f2: Program, f1: Program):
        self.f2 = f2
        self.f1 = f1

    def run(self, rng_key, params, *args, replay_from=None, **kwargs) -> Run:
        k1, k2 = jax.random.split(rng_key)
        r1 = self.f1.run(k1, params, *args, replay_from=replay_from, **kwargs)
        r2 = self.f2.run(k2, params, r1.output, replay_from=replay_from)
        merged = r1.trace.copy()
        for name, site in r2.trace.nodes.items():
            merged.add_node(name, site)  # raises on duplicates
        return Run(merged, r2.output, r1.log_weight + r2.log_weight)


class Extend(Compose):
    """``extend(p, f)``: target extension. Mechanically `compose(f, p)` —
    the kernel f runs *after* the target p, on p's output — but the role
    differs: f's sites belong to the extended target, so a later `propose`
    scores them in the numerator (auxiliary-variable targets, Stites &
    Zimmermann §3.2)."""

    role = "extended"

    def __init__(self, p: Program, f: Program):
        super().__init__(f, p)


class Propose(Program):
    """``propose(p, q)``: properly weighted importance step. The proposal q
    runs free; the target p replays q's choices and scores them plus its
    observations. Weight: q's own weight, plus target density over replayed
    + observed sites, minus proposal density over its latent sites. Target
    sites the proposal does not cover are prior-sampled and cancel out of
    the weight (their density appears in neither term)."""

    def __init__(self, p: Program, q: Program):
        self.p = p
        self.q = q

    def run(self, rng_key, params, *args, replay_from=None, **kwargs) -> Run:
        key_q, key_p = jax.random.split(rng_key)
        rq = self.q.run(key_q, params, *args, replay_from=replay_from, **kwargs)
        rp = self.p.run(key_p, params, *args, replay_from=rq.trace, **kwargs)
        lp = rp.trace.log_prob_sum(
            lambda n, s: s["is_observed"] or n in rq.trace
        )
        lq = rq.trace.log_prob_sum(lambda n, s: not s["is_observed"])
        return Run(rp.trace, rp.output, rq.log_weight + lp - lq)


class Resample(Program):
    """``resample(prog)``: population-level combinator. Before ``prog``
    propagates, check the incoming population's ESS; below
    ``ess_threshold * N``, draw ancestors (`ops.resample` systematic kernel
    by default, `REPRO_SMC_RESAMPLE` / ``method=`` to override), gather the
    carries, bank ``logsumexp(W) - log N`` into the marginal-likelihood
    accumulator and reset the weights. The decision is data-dependent but
    shape-stable: both branches are computed and selected with `jnp.where`,
    so the step stays scan- and shard-compatible."""

    def __init__(
        self,
        inner: Program,
        ess_threshold: float = 0.5,
        method: Optional[str] = None,
    ):
        if not 0.0 <= ess_threshold <= 1.0:
            raise ValueError(
                f"ess_threshold must be in [0, 1], got {ess_threshold}"
            )
        if method is not None and method not in RESAMPLE_METHODS:
            raise ValueError(
                f"unknown resample method {method!r}; expected one of "
                f"{RESAMPLE_METHODS}"
            )
        self.inner = inner
        self.ess_threshold = ess_threshold
        self.method = method

    def _resolved_method(self) -> str:
        method = self.method or settings.get_str("REPRO_SMC_RESAMPLE")
        if method not in RESAMPLE_METHODS:
            raise ValueError(
                f"REPRO_SMC_RESAMPLE={method!r}; expected one of "
                f"{RESAMPLE_METHODS}"
            )
        return method

    def run(self, rng_key, params, *args, replay_from=None, **kwargs) -> Run:
        raise TypeError(
            "resample is population-level: it permutes particles across the "
            "population and has no single-particle semantics. Run it through "
            "run_population (or the SMC engine)."
        )

    def run_population(
        self,
        rng_key,
        params,
        population: Population,
        args: Tuple = (),
        kwargs: Optional[dict] = None,
        *,
        mesh=None,
        particle_axis=None,
    ) -> Tuple[Population, StepAux]:
        key_r, key_s = jax.random.split(rng_key)
        lw = population.log_weights
        n = lw.shape[0]
        ess_in = effective_sample_size(lw)
        do = ess_in < self.ess_threshold * n
        if self._resolved_method() == "systematic":
            u0 = jax.random.uniform(key_r)
            ancestors = ops.resample(lw, u0)
        else:
            ancestors = jnp.sort(jax.random.categorical(key_r, lw, shape=(n,)))
        idx = jnp.where(do, ancestors, jnp.arange(n))
        carry = jax.tree.map(lambda x: x[idx], population.carry)
        log_z_incr = jnp.where(
            do, jax.scipy.special.logsumexp(lw) - jnp.log(jnp.float32(n)), 0.0
        )
        new_lw = jnp.where(do, jnp.zeros_like(lw), lw)
        pop, aux = self.inner.run_population(
            key_s,
            params,
            Population(carry, new_lw),
            args,
            kwargs,
            mesh=mesh,
            particle_axis=particle_axis,
        )
        return pop, aux._replace(resampled=do, log_z_incr=log_z_incr)


def primitive(fn: Union[Callable, Program]) -> Program:
    """Lift a repro model into a combinator program (idempotent)."""
    return fn if isinstance(fn, Program) else Primitive(fn)


def compose(f2: Union[Callable, Program], f1: Union[Callable, Program]) -> Program:
    return Compose(primitive(f2), primitive(f1))


def extend(p: Union[Callable, Program], f: Union[Callable, Program]) -> Program:
    return Extend(primitive(p), primitive(f))


def propose(p: Union[Callable, Program], q: Union[Callable, Program]) -> Program:
    return Propose(primitive(p), primitive(q))


def resample(
    prog: Union[Callable, Program],
    ess_threshold: float = 0.5,
    method: Optional[str] = None,
) -> Program:
    return Resample(primitive(prog), ess_threshold=ess_threshold, method=method)


# ---------------------------------------------------------------------------
# importance sampling: the degenerate one-step propose
# ---------------------------------------------------------------------------


class ImportanceSampling:
    """Self-normalized importance sampling as a combinator composition: one
    `propose(primitive(model), primitive(guide))` step over a particle
    population (guide-less, the bare likelihood-weighting `primitive`).

    This is the canonical engine behind the legacy `infer.Importance` (now a
    FutureWarning alias): same key structure, same weights, bit-for-bit.
    Implements the `InferenceEngine` protocol — `run`, `get_samples`,
    `num_traces` (which counts vmap traces: one per `run`, since the sweep
    is a single eager vmap, not a cached jit)."""

    def __init__(
        self,
        model: Callable,
        guide: Optional[Callable] = None,
        num_particles: int = 100,
        *,
        mesh=None,
        particle_axis=None,
    ):
        if num_particles < 1:
            raise ValueError(f"num_particles must be >= 1, got {num_particles}")
        self.model = model
        self.guide = guide
        self.num_particles = num_particles
        self.mesh = mesh
        self.particle_axis = particle_axis
        self.program = (
            propose(primitive(model), primitive(guide))
            if guide is not None
            else primitive(model)
        )
        self.num_traces = 0
        self.log_weights = None
        self.latents = None

    def run(self, rng_key, *args, params=None, **kwargs):
        params = params or {}
        keys = shard_particles(
            jax.random.split(rng_key, self.num_particles),
            self.mesh,
            self.particle_axis,
        )

        def one(k):
            self.num_traces += 1  # trace-time side effect (vmap traces once)
            r = self.program.run(k, params, *args, **kwargs)
            latents = {n: r.trace[n]["value"] for n in r.trace.stochastic_nodes()}
            return r.log_weight, latents

        self.log_weights, self.latents = jax.vmap(one)(keys)
        return self

    def get_samples(self, group_by_chain: bool = False):
        """Latent draws with the particle axis leading — pair with
        `log_weights` (these are weighted draws, not posterior samples).
        ``group_by_chain=True`` adds a singleton chain axis, matching the
        MCMC convention of (chains, draws, ...)."""
        if self.latents is None:
            raise RuntimeError("no samples yet — call .run(rng_key, ...) first")
        if group_by_chain:
            return jax.tree.map(lambda x: x[None], self.latents)
        return self.latents

    def log_evidence(self):
        return log_mean_exp(self.log_weights)

    def effective_sample_size(self):
        return effective_sample_size(self.log_weights)

    def resample(self, rng_key, num: int):
        """Draw `num` equally weighted latents (multinomial, with
        replacement) from the weighted population."""
        idx = jax.random.categorical(rng_key, self.log_weights, shape=(num,))
        return jax.tree.map(lambda x: x[idx], self.latents)
