"""TraceGraph_ELBO — Pyro's variance-reduced score-function estimator.

For non-reparameterizable guide sites, the naive REINFORCE surrogate
multiplies each site's score by the WHOLE downstream ELBO. Pyro's
TraceGraph_ELBO uses the plate structure to Rao-Blackwellize: each score
term is weighted only by the cost terms *inside the same plates*
(dependency-broken terms cancel in expectation), plus an optional running
baseline per site. We implement the plate-based decomposition and a
decaying-average baseline.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..core.handlers import replay, seed, trace
from .elbo import ELBO, _apply_scale_mask, check_no_enumerate_sites
from .util import substitute_params


def _site_plates(site) -> frozenset:
    return frozenset(f.name for f in site["cond_indep_stack"])


class TraceGraph_ELBO(ELBO):
    """Plate-aware score-function ELBO on the shared particle engine;
    baselines are exponential moving averages maintained OUTSIDE jit (pass
    `baselines=` dict and update with the returned new values)."""

    def __init__(self, num_particles: int = 1, baseline_decay: float = 0.9, **engine_kwargs):
        super().__init__(num_particles, **engine_kwargs)
        self.baseline_decay = baseline_decay

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        key_g, key_m = jax.random.split(rng_key)
        guide_tr = trace(seed(substitute_params(guide, params), key_g)).get_trace(
            *args, **kwargs
        )
        model_tr = trace(
            replay(seed(substitute_params(model, params), key_m), guide_tr)
        ).get_trace(*args, **kwargs)
        check_no_enumerate_sites(model_tr, guide_tr, "TraceGraph_ELBO")

        # cost terms: every model log_prob and negated guide log_prob,
        # kept as ARRAYS with their plate frames (per-element weighting
        # is the Rao-Blackwellization — summing first collapses back to
        # the naive estimator)
        costs = []  # (frames dict name->dim, lp_array)
        for name, site in model_tr.nodes.items():
            if site["type"] != "sample":
                continue
            lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
            costs.append(({f.name: f.dim for f in site["cond_indep_stack"]}, lp))
        for name, site in guide_tr.nodes.items():
            if site["type"] != "sample" or site["is_observed"]:
                continue
            lq = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
            costs.append(({f.name: f.dim for f in site["cond_indep_stack"]}, -lq))

        elbo = sum(jnp.sum(c) for _, c in costs)

        # score terms: each non-reparam guide site's per-element score is
        # weighted by the per-element downstream cost inside its plates
        surrogate = elbo
        for name, site in guide_tr.nodes.items():
            if site["type"] != "sample" or site["is_observed"]:
                continue
            if site["fn"].has_rsample:
                continue
            lq = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
            s_frames = {f.name: f.dim for f in site["cond_indep_stack"]}
            downstream = jnp.zeros_like(lq)
            for c_frames, c in costs:
                if not set(s_frames).issubset(c_frames):
                    continue
                # sum the cost over plate dims the site does not share
                extra = [d for n, d in c_frames.items() if n not in s_frames]
                red = jnp.sum(c, axis=tuple(extra)) if extra else c
                downstream = downstream + jnp.broadcast_to(
                    red, jnp.broadcast_shapes(red.shape, lq.shape)
                )
            w = jax.lax.stop_gradient(downstream)
            surrogate = surrogate + jnp.sum(
                w * (lq - jax.lax.stop_gradient(lq))
            )
        return elbo, surrogate
