"""MCMC convergence diagnostics (paper §2 frames sampling-based inference as
a first-class citizen next to SVI; production use of "the No U-turn Sampler"
requires knowing when chains have converged, so this module implements the
modern split-R̂ / ESS toolkit of Vehtari, Gelman, Simpson, Carpenter & Bürkner,
"Rank-normalization, folding, and localization: An improved R̂ for assessing
convergence of MCMC" (2021), as used by Stan and ArviZ).

All functions take draws shaped ``(num_chains, num_draws, *event)`` — the
layout of ``MCMC.get_samples(group_by_chain=True)`` — and return per-event
arrays:

* :func:`split_rhat` — classic split-chain potential scale reduction factor
  (Gelman & Rubin 1992, split form): each chain is halved so within-chain
  non-stationarity shows up as between-chain variance. R̂ ≈ 1 at
  convergence; > 1.01 is suspect.
* :func:`effective_sample_size` — computed on split chains like R̂;
  ``kind="bulk"`` rank-normalizes the draws then estimates ESS from
  chain-averaged autocorrelations truncated by Geyer's initial monotone
  positive sequence; ``kind="tail"`` is the minimum ESS of the 5% / 95%
  quantile indicator functions (tail exploration).
* :func:`summary` / :func:`print_summary` — per-site mean/std/median/credible
  interval + the diagnostics above, plus the divergence count when MCMC
  extra fields are given.

Example — diagnostics on synthetic chains::

    >>> import jax, jax.numpy as jnp
    >>> from repro.infer.diagnostics import effective_sample_size, split_rhat
    >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 500))  # iid draws
    >>> bool(jnp.abs(split_rhat(x) - 1.0) < 0.02)
    True
    >>> shifted = x + 10.0 * jnp.arange(4.0)[:, None]  # disjoint chains
    >>> bool(split_rhat(shifted) > 3.0)
    True
    >>> ess = effective_sample_size(x)
    >>> bool(0.5 * 2000 < ess <= 1.1 * 2000)  # iid: ESS ~ total draws
    True
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

__all__ = [
    "split_rhat",
    "effective_sample_size",
    "summary",
    "print_summary",
]


# ---------------------------------------------------------------------------
# core estimators on (..., num_chains, num_draws) batches
# ---------------------------------------------------------------------------


def _as_batched(x: jnp.ndarray) -> jnp.ndarray:
    """(chains, draws, *event) -> (K, chains, draws) with K = prod(event)."""
    x = jnp.asarray(x)
    if x.ndim < 2:
        raise ValueError(
            f"expected (num_chains, num_draws, ...) draws, got shape {x.shape}"
        )
    m, n = x.shape[:2]
    return jnp.moveaxis(x.reshape(m, n, -1), -1, 0)


def _split_chains(x: jnp.ndarray) -> jnp.ndarray:
    """Halve each chain along draws: (..., m, n) -> (..., 2m, n//2)."""
    n = x.shape[-1]
    half = n // 2
    first = x[..., :half]
    second = x[..., n - half:]
    return jnp.concatenate([first, second], axis=-2)


def _rhat_batched(x: jnp.ndarray) -> jnp.ndarray:
    """Split-R̂ on (..., m, n): sqrt(var+ / W)."""
    x = _split_chains(x)
    n = x.shape[-1]
    chain_mean = x.mean(-1)
    chain_var = x.var(-1, ddof=1)
    w = chain_var.mean(-1)
    b = n * chain_mean.var(-1, ddof=1)
    var_plus = (n - 1) / n * w + b / n
    # w == 0 means every chain is constant: R̂ is +inf when the chains sit at
    # different values (maximally unconverged) and NaN when ALL draws are one
    # value (no variance to compare — documented NaN, not a crash). NaN draws
    # make w NaN, which falls through both branches to NaN.
    return jnp.where(
        w > 0,
        jnp.sqrt(var_plus / jnp.where(w > 0, w, 1.0)),
        jnp.where(b > 0, jnp.inf, jnp.nan),
    )


def _autocov(x: jnp.ndarray) -> jnp.ndarray:
    """Biased autocovariance along the last axis via FFT: (..., n) -> (..., n)."""
    n = x.shape[-1]
    x = x - x.mean(-1, keepdims=True)
    size = 1
    while size < 2 * n:
        size *= 2
    f = jnp.fft.rfft(x, size)
    acov = jnp.fft.irfft(f * jnp.conj(f), size)[..., :n]
    return acov / n


def _ess_batched(x: jnp.ndarray) -> jnp.ndarray:
    """ESS on (..., m, n) raw draws (no rank-normalization), after Stan:
    chain-averaged autocorrelations, Geyer initial monotone positive
    sequence truncation."""
    m, n = x.shape[-2], x.shape[-1]
    acov = _autocov(x)  # (..., m, n)
    chain_var = acov[..., 0] * n / (n - 1.0)  # unbiased per-chain variance
    w = chain_var.mean(-1)  # (...,)
    mean_acov = acov.mean(-2)  # (..., n)
    if m > 1:
        chain_mean = x.mean(-1)
        b_over_n = chain_mean.var(-1, ddof=1)
        var_plus = (n - 1.0) / n * w + b_over_n
    else:
        var_plus = (n - 1.0) / n * w
    # guard constant chains (e.g. an all-zero tail indicator): report ESS=mn
    safe = var_plus > 0
    var_plus_s = jnp.where(safe, var_plus, 1.0)
    rho = 1.0 - (w[..., None] - mean_acov) / var_plus_s[..., None]  # (..., n)
    rho = rho.at[..., 0].set(1.0)
    # Geyer pair sums P_k = rho_{2k} + rho_{2k+1}
    n_pairs = n // 2
    p = rho[..., 0 : 2 * n_pairs : 2] + rho[..., 1 : 2 * n_pairs : 2]
    # initial positive sequence: keep pairs up to the first non-positive one
    positive = jnp.cumprod(p > 0, axis=-1).astype(p.dtype)
    # initial monotone sequence: running minimum over the kept prefix
    p_mono = jax.lax.associative_scan(jnp.minimum, jnp.clip(p, 0.0), axis=-1)
    tau = -1.0 + 2.0 * jnp.sum(p_mono * positive, axis=-1)
    tau = jnp.maximum(tau, 1.0 / jnp.log10(jnp.asarray(float(m * n))))
    ess = m * n / tau
    ess = jnp.minimum(ess, m * n * jnp.log10(jnp.asarray(float(m * n))))
    return jnp.where(safe, ess, float(m * n))


def _rank_normalize(x: jnp.ndarray) -> jnp.ndarray:
    """Rank-normalize draws across all chains jointly: (..., m, n) -> same
    shape, values replaced by normal scores of their ranks (Blom offsets)."""
    shape = x.shape
    flat = x.reshape(shape[:-2] + (-1,))
    total = flat.shape[-1]
    order = jnp.argsort(flat, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    u = (ranks + 1.0 - 0.375) / (total + 0.25)
    return ndtri(u).reshape(shape)


# ---------------------------------------------------------------------------
# public API on (num_chains, num_draws, *event) arrays
# ---------------------------------------------------------------------------


def split_rhat(x: jnp.ndarray) -> jnp.ndarray:
    """Split-chain R̂ of draws shaped (num_chains, num_draws, *event);
    returns an array shaped like the event (scalar for scalar sites).

    Degenerate inputs give documented values instead of raising or emitting
    garbage: fewer than 4 draws per chain → NaN (the split halves can't both
    carry a variance); all-constant draws → NaN; constant chains at distinct
    values → +inf; any NaN draw → NaN.
    """
    batched = _as_batched(x)
    if jnp.shape(x)[1] < 4:
        return jnp.full(jnp.shape(x)[2:], jnp.nan)
    out = _rhat_batched(batched)
    return out.reshape(jnp.shape(x)[2:])


def effective_sample_size(x: jnp.ndarray, kind: str = "bulk") -> jnp.ndarray:
    """Effective sample size of draws shaped (num_chains, num_draws, *event).

    ``kind="bulk"`` (default) follows Vehtari et al. 2021: ESS of the
    rank-normalized draws. ``kind="tail"`` is the minimum ESS of the
    I(x <= q05) and I(x <= q95) indicator chains. ``kind="raw"`` skips
    rank-normalization (classic autocorrelation ESS). All kinds operate on
    *split* chains (as Stan/ArviZ do), so within-chain drift deflates the
    estimate instead of hiding in the within-chain variance.

    Degenerate inputs: fewer than 4 draws per chain → NaN; constant draws →
    the total draw count m·n (zero autocorrelation information, documented in
    `_ess_batched`); any NaN draw → NaN. The NaN guard is explicit because
    both rank-normalization (argsort) and the tail indicators (comparisons)
    would otherwise silently convert NaN draws into *finite* — and therefore
    trustworthy-looking — ESS values.
    """
    if kind not in ("bulk", "raw", "tail"):
        raise ValueError(f"kind must be 'bulk', 'tail' or 'raw', got {kind!r}")
    batched = _as_batched(x)
    if jnp.shape(x)[1] < 4:
        return jnp.full(jnp.shape(x)[2:], jnp.nan)
    batched = _split_chains(batched)  # (K, 2m, n//2)
    if kind == "bulk":
        out = _ess_batched(_rank_normalize(batched))
    elif kind == "raw":
        out = _ess_batched(batched)
    else:  # tail
        q = jnp.quantile(batched, jnp.asarray([0.05, 0.95]), axis=(-2, -1))  # (2, K)
        lo = (batched <= q[0][..., None, None]).astype(jnp.float32)
        hi = (batched <= q[1][..., None, None]).astype(jnp.float32)
        out = jnp.minimum(_ess_batched(lo), _ess_batched(hi))
    # constant draws: rank-normalization would fabricate variation out of
    # arbitrary tie-breaking (argsort of equal values), so pin the documented
    # ESS = total draws before the transforms can launder it
    m2, n2 = batched.shape[-2], batched.shape[-1]
    const = batched.max(axis=(-2, -1)) == batched.min(axis=(-2, -1))
    out = jnp.where(const, float(m2 * n2), out)
    bad = jnp.isnan(batched).any(axis=(-2, -1))
    out = jnp.where(bad, jnp.nan, out)
    return out.reshape(jnp.shape(x)[2:])


def summary(
    samples: Dict[str, jnp.ndarray], prob: float = 0.9
) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Per-site statistics of ``{site: (num_chains, num_draws, *event)}``:
    mean, std, median, the central `prob` credible interval, bulk/tail ESS
    and split-R̂ (each shaped like the site's event shape)."""
    lo_q, hi_q = 0.5 - prob / 2.0, 0.5 + prob / 2.0
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    for name, x in samples.items():
        x = jnp.asarray(x)
        out[name] = {
            "mean": x.mean((0, 1)),
            "std": x.std((0, 1)),
            "median": jnp.quantile(x, 0.5, axis=(0, 1)),
            f"{lo_q * 100:.1f}%": jnp.quantile(x, lo_q, axis=(0, 1)),
            f"{hi_q * 100:.1f}%": jnp.quantile(x, hi_q, axis=(0, 1)),
            "n_eff": effective_sample_size(x, kind="bulk"),
            "ess_tail": effective_sample_size(x, kind="tail"),
            "r_hat": split_rhat(x),
        }
    return out


def print_summary(
    samples: Dict[str, jnp.ndarray],
    extra_fields: Optional[Dict[str, jnp.ndarray]] = None,
    prob: float = 0.9,
    file=None,
) -> None:
    """Render :func:`summary` as an aligned table (one row per scalar site
    element), plus the total divergence count when `extra_fields` carries
    the MCMC driver's per-draw ``diverging`` flags."""
    stats = summary(samples, prob=prob)
    cols = list(next(iter(stats.values())).keys()) if stats else []
    rows = []
    for name, st in stats.items():
        event_shape = jnp.shape(st["mean"])
        size = 1
        for d in event_shape:
            size *= d
        for flat_i in range(size):
            idx = jnp.unravel_index(flat_i, event_shape) if event_shape else ()
            label = name
            if event_shape:
                label += "[" + ",".join(str(int(i)) for i in idx) + "]"
            rows.append(
                [label] + [float(jnp.asarray(st[c])[tuple(idx)] if event_shape else st[c]) for c in cols]
            )
    widths = [max([len("site")] + [len(r[0]) for r in rows])] + [
        max(9, len(c)) for c in cols
    ]
    header = ["site"] + cols
    line = "  ".join(h.rjust(w) for h, w in zip(header, widths))
    print(line, file=file)
    for r in rows:
        cells = [r[0].rjust(widths[0])]
        for v, w in zip(r[1:], widths[1:]):
            cells.append(f"{v:>{w}.2f}")
        print("  ".join(cells), file=file)
    if extra_fields is not None and "diverging" in extra_fields:
        n_div = int(jnp.asarray(extra_fields["diverging"]).sum())
        print(f"\nNumber of divergences: {n_div}", file=file)
