"""Plan executor + the ordinal-level contraction driver.

`_ve_eliminate` is the planner/executor seam: in ``auto`` dispatch it
fingerprints the factor graph, fetches (or builds) a `ContractionPlan` from
the plan cache, and executes it; ``dispatch="pairwise"`` bypasses planning
entirely and runs the legacy greedy loop — kept verbatim so the pre-planner
path stays reachable and bit-identical.

Chain segments lower three ways (chosen by the planner's cost model):

* ``scan``  — a plan-level `jax.lax.scan` over the stacked edge matrices.
  The traced graph is O(1) in chain length (one stack + one scan op), and
  with an absorbed terminal the carry is a K-vector, so the steady-state
  work is the same O(T K^2) matvec stream as the greedy backward pass —
  without its superlinear compile-time pathology.
* ``tree``  — `ops.hmm_scan`, the O(log T)-depth associative semiring tree
  (parallel hardware / cumulative marginals).
* ``folds`` — sequential `ops.semiring_matmul` folds (ragged cardinalities
  or 2-edge chains).

`ElimStep`s execute exactly one greedy elimination each, so a plan with no
chain steps performs the same ops as the greedy loop in the same order.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp

from ...kernels import ops as kernel_ops
from .cache import PLAN_CACHE
from .planner import ChainStep, ContractionPlan, plan_elimination, plan_knobs
from .structure import (
    _add_all,
    _dispatch_mode,
    _enum_dims,
    _from_matrix,
    _from_vector,
    _logsumexp_op,
    _reduce_dims,
    _scaled,
    _to_matrix,
    _to_vector,
    _uniform_scale,
    factor_structs,
    fingerprint,
    semiring_of,
)

# ---------------------------------------------------------------------------
# legacy greedy path (dispatch="pairwise") — bit-identical to the pre-planner
# eliminator
# ---------------------------------------------------------------------------


def greedy_eliminate(ts, dims, pool: FrozenSet[int], sum_op):
    """Variable elimination over (tensor, pending_scale) pairs: drop each
    enum dim by combining only the factors that carry it, most-negative
    (= last-allocated) dim first. For a sequentially-sampled chain
    z_1 -> ... -> z_T this is the backward algorithm — O(T K^2) work but
    O(T) sequential XLA ops and O(T^2) trace-time Python. A group's pending
    scale resolves (multiplies) as soon as its result carries no more enum
    dims."""
    for d in sorted(dims):
        group = [(t, s) for t, s in ts if d in _enum_dims(t, pool)]
        rest = [(t, s) for t, s in ts if d not in _enum_dims(t, pool)]
        if not group:
            continue
        scale = _uniform_scale([s for _, s in group])
        t = _reduce_dims(_add_all([t for t, _ in group]), (d,), sum_op)
        if scale is not None and not _enum_dims(t, pool):
            t, scale = t * scale, None
        ts = rest + [(t, scale)]
    return ts


# ---------------------------------------------------------------------------
# chain-segment lowerings
# ---------------------------------------------------------------------------


def _stack_bcast(xs: List[jax.Array], event_rank: int, axis: int) -> jax.Array:
    batch = jnp.broadcast_shapes(*[x.shape[: x.ndim - event_rank] for x in xs])
    return jnp.stack(
        [jnp.broadcast_to(x, batch + x.shape[x.ndim - event_rank:]) for x in xs],
        axis=axis,
    )


def _run_scan(step: ChainStep, factors, semiring: str) -> jax.Array:
    """Roll the ordered semiring product of a chain's edge matrices through
    one forward `lax.scan`, so the traced graph stays O(1) in chain length.

    The sweep reproduces the greedy loop's float-op association exactly:
    edge t's matrix is pre-folded with the unaries of its ROW dim D_t (the
    same `(edge + unary) + carry` add order the greedy group uses), and each
    step reduces over D_t — greedy's most-negative-first elimination. With
    `absorb`, edge 0 also folds D_0's unaries and is reduced OUTSIDE the
    scan (no `+ zeros-carry` in the first step), so a scan-lowered uniform
    chain is bit-identical to ``dispatch="pairwise"``, with a vector carry —
    the O(T K^2) backward pass. Without `absorb` a matrix carry keeps D_0
    alive. Assembly is vectorized — edge matrices are stacked once and the
    unary folds become ONE stacked row-vector add — because at steady state
    a chain of T small matvecs is dominated by op dispatch, not flops."""
    red = jnp.max if semiring == "max" else jsp.logsumexp
    mats = [
        _to_matrix(_add_all([factors[i][0] for i in ids]), step.path[t], step.path[t + 1])
        for t, ids in enumerate(step.edges)
    ]
    stacked = _stack_bcast(mats, 2, axis=0)  # (m, batch..., K, K)
    # fold each dim's unaries into the edge leaving it, on the row side:
    # M_t[i, j] + u_t[i], as one stacked broadcast add
    rows = []
    any_unary = False
    for t in range(len(step.edges)):
        ids = list(step.absorbed) if t == 0 else list(step.folded[t])
        if ids:
            any_unary = True
            rows.append(
                _to_vector(_add_all([factors[i][0] for i in ids]), step.path[t])
            )
        else:
            rows.append(jnp.zeros(stacked.shape[-2:-1], stacked.dtype))
    if any_unary:
        stacked = stacked + _stack_bcast(rows, 1, axis=0)[..., :, None]
    unroll = 8 if len(mats) >= 9 else 1
    if step.absorb:
        # c_{t+1}[j] = ⊕_i M_t[i, j] + c_t[i]; the first reduction runs
        # outside the scan so no zero-carry add perturbs bit-identity
        init = red(stacked[0], axis=-2)

        def body(c, m):
            return red(m + c[..., :, None], axis=-2), None

        c, _ = jax.lax.scan(body, init, stacked[1:], unroll=unroll)
        return c  # (batch..., K_m)
    # matrix carry: C_{t+1} = C_t ⊗ M_t (semiring matmul in the scan body)
    init = stacked[0]

    def body(c, m):
        return red(c[..., :, :, None] + m[..., None, :, :], axis=-2), None

    c, _ = jax.lax.scan(body, init, stacked[1:], unroll=unroll)
    return c  # (batch..., K_0, K_m)


def _run_chain(step: ChainStep, factors, sum_op, semiring: str):
    """Execute one chain segment: assemble edge matrices (merging parallel
    factors and folding interior unaries exactly as the greedy path would
    add them), lower, and re-embed the result into right-aligned form."""
    consumed = [i for ids in step.edges for i in ids]
    consumed += [i for ids in step.folded for i in ids]
    consumed += list(step.absorbed)
    scale = _uniform_scale([factors[i][1] for i in consumed])

    if step.lower == "scan":
        res = _run_scan(step, factors, semiring)
        if step.absorb:
            return _from_vector(res, step.path[-1]), scale
        return _from_matrix(res, step.path[0], step.path[-1]), scale

    # tree/folds lowerings keep the legacy per-edge column-side folds
    # (bit-compatible with the pre-planner kernel dispatch); the planner
    # never emits absorb for them
    assert not step.absorb, "terminal absorption is a scan-only lowering"
    mats = []
    for t, ids in enumerate(step.edges):
        tensor = _add_all([factors[i][0] for i in ids])
        for u in step.folded[t + 1]:  # interior unaries fold into the entering edge
            tensor = tensor + factors[u][0]
        mats.append(_to_matrix(tensor, step.path[t], step.path[t + 1]))
    if step.lower == "tree" and len(mats) >= 3:
        res = kernel_ops.hmm_scan(_stack_bcast(mats, 2, axis=-3), semiring=semiring)
    else:  # matmul-shaped (one interior dim) or ragged cardinalities
        res = mats[0]
        for m in mats[1:]:
            res = kernel_ops.semiring_matmul(res, m, semiring=semiring)
    return _from_matrix(res, step.path[0], step.path[-1]), scale


def execute_plan(
    plan: ContractionPlan, ts, pool: FrozenSet[int], sum_op, semiring: str
):
    """Run a `ContractionPlan` against concrete (tensor, pending_scale)
    factors. Factor ids index the growing list: inputs first, then one
    appended result per step. Returns the surviving factors in id order —
    the same order the greedy loop leaves them in."""
    factors: List[Optional[Tuple]] = list(ts)
    for step in plan.steps:
        if isinstance(step, ChainStep):
            t, scale = _run_chain(step, factors, sum_op, semiring)
        else:
            group = [factors[i] for i in step.group]
            scale = _uniform_scale([s for _, s in group])
            t = _reduce_dims(_add_all([t for t, _ in group]), (step.dim,), sum_op)
            if scale is not None and not _enum_dims(t, pool):
                t, scale = t * scale, None
        assert step.out == len(factors), "plan ids out of sync with executor"
        factors.append((t, scale))
    return [factors[i] for i in plan.outputs]


# ---------------------------------------------------------------------------
# the planner/executor seam
# ---------------------------------------------------------------------------


def _ve_eliminate(ts, dims, pool: FrozenSet[int], sum_op, dispatch: Optional[str] = None):
    """Eliminate `dims` from (tensor, pending_scale) factors. ``auto``
    dispatch plans (or fetches a cached plan for) the contraction and
    executes it; ``pairwise`` — or a custom `sum_op` with no semiring
    lowering — runs the legacy greedy loop."""
    if not dims:
        return ts
    mode = _dispatch_mode(dispatch)
    semiring = semiring_of(sum_op)
    if mode == "pairwise" or semiring is None:
        return greedy_eliminate(ts, dims, pool, sum_op)
    structs = factor_structs(ts, pool)
    knobs = plan_knobs()
    key = fingerprint(structs, frozenset(dims), semiring, knobs)
    plan = PLAN_CACHE.get_or_plan(
        key,
        lambda: plan_elimination(
            structs, frozenset(dims), semiring=semiring, knobs=knobs
        ),
    )
    return execute_plan(plan, ts, pool, sum_op, semiring)


def planned_contraction(
    ts, dims, pool: FrozenSet[int], semiring: str = "logsumexp"
) -> ContractionPlan:
    """Plan (without executing) the elimination of `dims` — the inspection
    entry point: `planned_contraction(...).describe()` shows the schedule
    the auto dispatch would run."""
    structs = factor_structs(ts, pool)
    return plan_elimination(
        structs, frozenset(dims), semiring=semiring, knobs=plan_knobs()
    )


# ---------------------------------------------------------------------------
# ordinal-level driver (plate-aware tensor variable elimination)
# ---------------------------------------------------------------------------


def contract_log_factors(
    factors: List[Tuple[FrozenSet, jax.Array, object]],
    depth: Dict,
    pool: FrozenSet[int],
    keep_dims: FrozenSet[int] = frozenset(),
    keep_frames: FrozenSet = frozenset(),
    sum_op=_logsumexp_op,
    dispatch: Optional[str] = None,
) -> jax.Array:
    """Plate-aware tensor variable elimination in log space.

    Eliminates every enum dim not in `keep_dims` (via `sum_op`, keepdims) and
    sums out every plate frame not in `keep_frames`, processing ordinals
    innermost-first so that each enum dim is eliminated at the shallowest
    ordinal where it still appears — i.e. inside its own plate context but
    outside any plate it is shared across. Pending site scales resolve after
    their factor's local eliminations (see `_collect_factors`); a factor
    still pending at its plate sum carries only dims shared with enclosing
    ordinals, where scale-inside is the correct minibatch estimator of the
    full-data inner sum. Returns a single right-aligned log-factor (all
    reduced axes kept at size 1).

    `dispatch` controls how eliminations are lowered: ``"auto"`` (default;
    also via the ``REPRO_ENUM_DISPATCH`` env var) runs each elimination
    through the cost-based contraction planner (plan-cached on the factor
    graph's structural fingerprint; chain/tree segments lower to the fused
    semiring kernels or a `lax.scan` roll), ``"pairwise"`` forces the legacy
    greedy path everywhere.
    """
    groups: Dict[FrozenSet, List[Tuple[jax.Array, object]]] = {}
    for ordinal, t, s in factors:
        groups.setdefault(ordinal, []).append((t, s))

    while True:
        pending = [o for o, ts in groups.items() if ts and (o - keep_frames)]
        if not pending:
            break
        # innermost first: the ordinal whose deepest pending frame nests deepest
        o = max(pending, key=lambda o: max(depth[f] for f in (o - keep_frames)))
        ts = groups.pop(o)
        other_dims: set = set()
        for ts2 in groups.values():
            for t2, _ in ts2:
                other_dims |= _enum_dims(t2, pool)
        local = set()
        for t, _ in ts:
            local |= _enum_dims(t, pool)
        local -= other_dims
        local -= keep_dims
        if local:
            ts = _ve_eliminate(ts, local, pool, sum_op, dispatch)
        # the plate is a product over slices: sum the slice log-factor over
        # the innermost pending frame's axis, then hand the result to the
        # enclosing ordinal
        f = max(o - keep_frames, key=lambda fr: depth[fr])
        t = _add_all([_scaled(t, s) for t, s in ts])
        if jnp.ndim(t) >= -f.dim:
            t = jnp.sum(t, axis=jnp.ndim(t) + f.dim, keepdims=True)
        groups.setdefault(o - {f}, []).append((t, None))

    ts = [p for tl in groups.values() for p in tl]
    if not ts:
        return jnp.zeros(())
    ts = [(_scaled(t, s), None) for t, s in ts]
    leftover = set()
    for t, _ in ts:
        leftover |= _enum_dims(t, pool)
    ts = _ve_eliminate(ts, leftover - keep_dims, pool, sum_op, dispatch)
    return _add_all([t for t, _ in ts])
