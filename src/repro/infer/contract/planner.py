"""Cost-based contraction planner: turn one elimination problem (factor
hypergraph + dims to eliminate) into an explicit, inspectable
`ContractionPlan` — a compiler artifact that is computed once per factor-
graph *structure* and cached (see `cache.py`), instead of being rediscovered
greedily at every trace.

The plan is a sequence of steps over factor ids (inputs ``0..n-1``, each
step appends its result as the next id):

* `ChainStep` — a maximal path of binary log-factors through the factor
  graph, lowered as one fused segment: a plan-level `lax.scan` roll (O(1)
  trace size in chain length, O(T K^2) work when a terminal is absorbed),
  the O(log T)-depth `ops.hmm_scan` tree (parallel hardware), or sequential
  `ops.semiring_matmul` folds (ragged cardinalities). Chains are extracted
  repeatedly until a fixpoint, so trees and polytrees of chains collapse
  branch by branch — each contracted branch becomes a new unary/binary
  factor that can seed the next round.
* `ElimStep` — eliminate a single dim by combining the factors that carry
  it (the greedy backward-pass step). The *order* of these steps comes from
  a branch-and-bound search over elimination orders (optimal for small dim
  counts, opt-einsum style) with a greedy min-cost fallback above
  ``REPRO_ENUM_PLAN_BB`` dims or past the node budget.

The cost model also owns the chain-lowering crossover that used to be the
fixed ``REPRO_ENUM_CHAIN_MIN`` edge count: short chains stay on the unrolled
greedy path (bit-identical to ``dispatch="pairwise"``, cheapest steady-state,
trivial compile), long chains roll into a scan/tree whose compile cost is
O(1)/O(log T) where the unrolled graph's grows superlinearly. Setting
``REPRO_ENUM_CHAIN_MIN`` still overrides the crossover (tests use ``2`` to
force kernel lowering on small fixtures), and ``REPRO_ENUM_CHAIN_LOWER``
pins the lowering strategy (``scan`` / ``tree`` / ``folds``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import jax

from ... import settings
from .structure import FactorStruct

# ---------------------------------------------------------------------------
# plan representation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChainStep:
    """Contract a path of binary factors D_0 - D_1 - ... - D_m in one fused
    segment, eliminating every interior dim (and D_0 too when `absorb`).

    The path is oriented ascending (D_0 = most negative dim), matching the
    greedy loop's most-negative-first elimination order: the scan lowering
    sweeps the segment front-to-back with the same per-step float-add
    association as the greedy backward pass, which is what keeps it
    bit-identical to ``dispatch="pairwise"`` on uniform chains. `folded` is
    aligned with `path` — folded[p] are the unary factor ids on interior dim
    D_p; the scan lowering folds them into the edge *leaving* D_p (row side,
    greedy association), the tree/folds lowerings into the edge *entering*
    D_p (column side, legacy kernel-dispatch association)."""

    path: Tuple[int, ...]                # dim sequence D_0..D_m
    edges: Tuple[Tuple[int, ...], ...]   # edge t: parallel binary factor ids
    folded: Tuple[Tuple[int, ...], ...]  # per path dim: unary ids (interior only)
    absorbed: Tuple[int, ...]            # unary ids on D_0 summed into the segment
    absorb: bool                         # eliminate D_0 inside the segment
    lower: str                           # "scan" | "tree" | "folds"
    out: int                             # id of the result factor

    def eliminates(self) -> Tuple[int, ...]:
        dims = self.path[1:-1]
        return (self.path[0],) + dims if self.absorb else dims


@dataclass(frozen=True)
class ElimStep:
    """Eliminate `dim` by combining the factors that carry it."""

    dim: int
    group: Tuple[int, ...]               # factor ids carrying dim, in id order
    out: int


@dataclass(frozen=True)
class ContractionPlan:
    """An explicit contraction schedule: steps over a growing factor list."""

    n_inputs: int
    steps: Tuple
    outputs: Tuple[int, ...]             # surviving factor ids, in id order
    eliminated: Tuple[int, ...]          # dims removed by this plan
    cost: float = 0.0                    # estimated element-ops (relative)
    meta: Dict = field(default_factory=dict, compare=False)

    def describe(self) -> str:
        """Human-readable schedule (the 'inspectable' part of the contract)."""
        lines = [
            f"ContractionPlan: {self.n_inputs} inputs, {len(self.steps)} steps, "
            f"eliminates {len(self.eliminated)} dims, est cost {self.cost:.3g}"
        ]
        for s in self.steps:
            if isinstance(s, ChainStep):
                ab = ", absorb front" if s.absorb else ""
                lines.append(
                    f"  chain[{s.lower}] dims {s.path[0]}..{s.path[-1]} "
                    f"({len(s.edges)} edges{ab}) -> f{s.out}"
                )
            else:
                ids = ",".join(f"f{i}" for i in s.group)
                lines.append(f"  elim {s.dim}: {ids} -> f{s.out}")
        lines.append("  outputs: " + ",".join(f"f{i}" for i in self.outputs))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# cost model + env knobs
# ---------------------------------------------------------------------------

# Unrolled greedy elimination compiles superlinearly in chain length (XLA
# sees m sequential reduce ops over rank-m tensors plus O(m^2) trace-time
# Python); empirically ~quadratic on CPU at ~4ms/edge^2. A scan roll pays a
# roughly constant trace+compile cost instead. The crossover
# m* = sqrt(scan_cost / unroll_coeff) lands at ~18 edges; below it the
# unrolled path also wins steady-state (XLA fuses the short backward pass
# more tightly than a loop), so short chains stay bit-identical to pairwise.
_UNROLL_COMPILE_S_PER_EDGE2 = 4e-3
_SCAN_LOWER_COST_S = 1.2

_LOWERINGS = ("auto", "scan", "tree", "folds")


def chain_threshold(env_val: Optional[str] = None) -> int:
    """Minimum chain length (binary-factor edges) worth lowering to a fused
    segment. ``REPRO_ENUM_CHAIN_MIN`` overrides the cost-model crossover."""
    if env_val is None:
        env_val = settings.get_raw("REPRO_ENUM_CHAIN_MIN")
    if env_val is not None:
        return max(2, int(env_val))
    return max(2, math.ceil(math.sqrt(_SCAN_LOWER_COST_S / _UNROLL_COMPILE_S_PER_EDGE2)))


def plan_knobs() -> Tuple:
    """Environment/platform knobs that change planning decisions — part of
    the plan-cache fingerprint so flipping one never serves a stale plan."""
    lower = settings.get_str("REPRO_ENUM_CHAIN_LOWER")
    if lower not in _LOWERINGS:
        raise ValueError(
            f"unknown chain lowering {lower!r} (REPRO_ENUM_CHAIN_LOWER); "
            f"expected one of {_LOWERINGS}"
        )
    return (
        settings.get_raw("REPRO_ENUM_CHAIN_MIN"),
        lower,
        settings.get_int("REPRO_ENUM_PLAN_BB"),
        jax.default_backend(),
    )


def _chain_lowering(m: int, uniform: bool, knobs: Tuple) -> str:
    """Pick how a recognized chain executes. Ragged cardinalities can only
    fold; uniform chains roll into a `lax.scan` off-accelerator (O(1) trace,
    matvec work) or the `hmm_scan` log-depth tree on TPU. When the legacy
    ``REPRO_ENUM_CHAIN_MIN`` override is set, keep the tree lowering those
    callers (and the kernel test fixtures) were written against."""
    chain_min_env, lower_env, _, backend = knobs
    if not uniform or m < 3:
        return "folds"
    if lower_env != "auto":
        return lower_env
    if backend == "tpu" or chain_min_env is not None:
        return "tree"
    return "scan"


# ---------------------------------------------------------------------------
# elimination-order search (opt-einsum style)
# ---------------------------------------------------------------------------

_BB_NODE_BUDGET = 50_000


def _elim_cost(d: int, dimsets: Sequence[FrozenSet[int]], sizes: Dict[int, int]) -> Tuple[float, FrozenSet[int]]:
    """Cost of eliminating `d` now: the element count of the broadcast
    product of every factor carrying it (enum dims only — plate axes scale
    every candidate equally). Returns (cost, dims of the result factor)."""
    union: Set[int] = set()
    for ds in dimsets:
        if d in ds:
            union |= ds
    if not union:
        return 0.0, frozenset()
    cost = 1.0
    for u in union:
        cost *= sizes[u]
    return cost, frozenset(union - {d})


def _apply_elim(d: int, dimsets: List[FrozenSet[int]], new_dims: FrozenSet[int]) -> List[FrozenSet[int]]:
    return [ds for ds in dimsets if d not in ds] + [new_dims]


def _greedy_order(dimsets: List[FrozenSet[int]], sizes: Dict[int, int], dims: List[int]) -> List[int]:
    """Min-cost-first ordering; ties break toward the most negative (last
    allocated) dim — the legacy greedy order, so plans degrade gracefully."""
    order: List[int] = []
    remaining = list(dims)
    cur = list(dimsets)
    while remaining:
        best = min(remaining, key=lambda d: (_elim_cost(d, cur, sizes)[0], d))
        _, new_dims = _elim_cost(best, cur, sizes)
        cur = _apply_elim(best, cur, new_dims)
        order.append(best)
        remaining.remove(best)
    return order


def elimination_order(
    dimsets: Sequence[FrozenSet[int]],
    sizes: Dict[int, int],
    dims: FrozenSet[int],
    bb_max: int,
) -> List[int]:
    """Order the remaining single-dim eliminations. Small problems get a
    branch-and-bound search over all orders (total intermediate size, the
    opt-einsum 'optimal' objective); larger ones fall back to greedy
    min-cost. Candidate dims are explored most-negative-first and the
    incumbent is only replaced on *strict* improvement, so when the legacy
    sorted order is already optimal (chains, single dims) the plan
    reproduces it exactly — bit-identical to the pairwise path."""
    todo = sorted(d for d in dims if any(d in ds for ds in dimsets))
    if not todo:
        return []
    start = [ds for ds in dimsets if ds]
    if len(todo) > bb_max:
        return _greedy_order(start, sizes, todo)

    best_order: List[int] = []
    best_cost = [math.inf]
    nodes = [0]

    def dfs(cur: List[FrozenSet[int]], remaining: List[int], acc: float, prefix: List[int]) -> bool:
        nodes[0] += 1
        if nodes[0] > _BB_NODE_BUDGET:
            return False  # budget blown: keep the incumbent
        if not remaining:
            if acc < best_cost[0]:
                best_cost[0] = acc
                best_order[:] = prefix
            return True
        for d in remaining:
            cost, new_dims = _elim_cost(d, cur, sizes)
            if acc + cost >= best_cost[0]:
                continue
            ok = dfs(
                _apply_elim(d, cur, new_dims),
                [r for r in remaining if r != d],
                acc + cost,
                prefix + [d],
            )
            if not ok:
                return False
        return True

    dfs(start, todo, 0.0, [])
    if not best_order:  # budget blown before any complete order
        return _greedy_order(start, sizes, todo)
    return best_order


# ---------------------------------------------------------------------------
# chain extraction (paths / trees / polytrees of binary factors)
# ---------------------------------------------------------------------------


def _find_chains(edges, eliminable: Set[int], blocked: Set[int], min_edges: int):
    """Maximal simple paths through the factor graph whose edges are
    (merged) binary factors. A dim may be chain-*interior* only if it is
    eliminable, touched by exactly two binary edges, and untouched by any
    higher-arity factor; every other dim terminates a path. Paths shorter
    than `min_edges` are discarded. Returns a list of (edge-index sequence,
    dim sequence) pairs; edge t connects dims t and t+1 of the sequence."""
    adj: Dict[int, List[int]] = {}
    for i, (pair, _, _) in enumerate(edges):
        for d in pair:
            adj.setdefault(d, []).append(i)

    def interior(d):
        return d in eliminable and d not in blocked and len(adj.get(d, ())) == 2

    chains = []
    used: Set[int] = set()
    for i0 in range(len(edges)):
        if i0 in used:
            continue
        a, b = sorted(edges[i0][0])
        seq_edges, seq_dims = [i0], [a, b]
        for front in (True, False):
            while True:
                end = seq_dims[0] if front else seq_dims[-1]
                if not interior(end):
                    break
                nxt = next((j for j in adj[end] if j not in seq_edges), None)
                if nxt is None or nxt in used:
                    break
                (far,) = edges[nxt][0] - {end}
                if front:
                    seq_edges.insert(0, nxt)
                    seq_dims.insert(0, far)
                else:
                    seq_edges.append(nxt)
                    seq_dims.append(far)
        # need >= 1 interior dim to eliminate, no cycle closure, and enough
        # length that the fused segment's compile-time win outweighs its
        # bookkeeping
        if len(seq_edges) >= max(2, min_edges) and seq_dims[0] != seq_dims[-1]:
            used.update(seq_edges)
            chains.append((seq_edges, seq_dims))
    return chains


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def plan_elimination(
    structs: Sequence[FactorStruct],
    dims: FrozenSet[int],
    *,
    semiring: str = "logsumexp",
    knobs: Optional[Tuple] = None,
) -> ContractionPlan:
    """Build a `ContractionPlan` eliminating `dims` from the factor graph
    described by `structs`. Purely structural — safe to cache on the
    `structure.fingerprint` of its inputs."""
    if knobs is None:
        knobs = plan_knobs()
    min_edges = chain_threshold(knobs[0])
    bb_max = knobs[2]

    alive: Dict[int, FactorStruct] = dict(enumerate(structs))
    sizes: Dict[int, int] = {}
    for f in structs:
        for d, k in zip(f.dims, f.sizes):
            sizes[d] = max(sizes.get(d, 1), k)
    steps: List = []
    remaining: Set[int] = set(dims)
    next_id = len(structs)
    total_cost = 0.0

    def new_struct(dims_t: Tuple[int, ...], batch: Tuple[int, ...], scale_id: int) -> FactorStruct:
        return FactorStruct(
            dims_t, tuple(sizes[d] for d in dims_t), batch, scale_id
        )

    # -- phase 1: extract chains to fixpoint (trees collapse branch by branch)
    progressed = True
    while progressed and remaining:
        progressed = False
        blocked: Set[int] = set()
        for f in alive.values():
            if len(f.dims) > 2:
                blocked |= set(f.dims)
        by_pair: Dict[FrozenSet[int], List[int]] = {}
        unary_by_dim: Dict[int, List[int]] = {}
        for i, f in alive.items():
            if len(f.dims) == 2:
                by_pair.setdefault(frozenset(f.dims), []).append(i)
            elif len(f.dims) == 1:
                unary_by_dim.setdefault(f.dims[0], []).append(i)
        edges = []  # (pair, member ids, scale_id)
        for pair, idxs in sorted(by_pair.items(), key=lambda kv: sorted(kv[0])):
            sids = {alive[i].scale_id for i in idxs}
            if len(sids) > 1:
                # parallel factors with different scales can't merge into one
                # edge; leave the pair to the greedy steps (which raise the
                # actionable mixed-scale error at execution)
                blocked |= set(pair)
                continue
            edges.append((pair, tuple(idxs), sids.pop()))

        for seq_edges, seq_dims in _find_chains(edges, remaining, blocked, min_edges):
            if seq_dims[0] > seq_dims[-1]:
                # canonical ascending orientation: D_0 is the most negative
                # (first-eliminated-by-greedy) terminal
                seq_edges, seq_dims = seq_edges[::-1], seq_dims[::-1]
            interior = seq_dims[1:-1]
            edge_ids = tuple(edges[e][1] for e in seq_edges)
            folded = tuple(
                tuple(unary_by_dim.get(d, ())) if d in interior else ()
                for d in seq_dims
            )
            member_ids = [i for ids in edge_ids for i in ids]
            folded_ids = [i for ids in folded for i in ids]
            ks = {sizes[d] for d in seq_dims}
            lower = _chain_lowering(len(seq_edges), len(ks) == 1, knobs)
            # front-terminal absorption: D_0 can be eliminated inside the
            # segment when it is eliminable and nothing outside the segment
            # touches it — it is the greedy loop's first elimination, so the
            # scan sweep reproduces greedy's float-op order exactly.
            # Scan-only: folding terminal unaries into the first edge would
            # reorder additions inside a tree/fold product, and those
            # lowerings are pinned bit-compatible with their legacy forms.
            d_first = seq_dims[0]
            absorbed: Tuple[int, ...] = ()
            absorb = False
            if lower == "scan" and d_first in remaining:
                touchers = [
                    i for i, f in alive.items() if d_first in f.dims
                ]
                first_edge = set(edge_ids[0])
                unaries_first = tuple(unary_by_dim.get(d_first, ()))
                if set(touchers) <= first_edge | set(unaries_first):
                    absorbed, absorb = unaries_first, True
            scale_ids = {alive[i].scale_id for i in member_ids + folded_ids + list(absorbed)}
            if len(scale_ids) > 1:
                continue  # mixed scales meet in this chain: greedy raises properly
            sid = scale_ids.pop()
            batch = tuple(sorted(
                {b for i in member_ids + folded_ids + list(absorbed) for b in alive[i].batch}
            ))
            out_dims = (
                (seq_dims[-1],) if absorb else tuple(sorted((d_first, seq_dims[-1])))
            )
            step = ChainStep(
                path=tuple(seq_dims),
                edges=edge_ids,
                folded=folded,
                absorbed=absorbed,
                absorb=absorb,
                lower=lower,
                out=next_id,
            )
            steps.append(step)
            for i in member_ids + folded_ids + list(absorbed):
                del alive[i]
            alive[next_id] = new_struct(out_dims, batch, sid)
            next_id += 1
            remaining -= set(step.eliminates())
            k = max(ks)
            total_cost += len(seq_edges) * (k * k if absorb else k * k * k)
            progressed = True

    # -- phase 2: order the remaining single-dim eliminations by cost
    dimsets = [frozenset(alive[i].dims) for i in sorted(alive)]
    eliminated: Set[int] = set(dims) - remaining
    for d in elimination_order(dimsets, sizes, frozenset(remaining), bb_max):
        group = tuple(i for i in sorted(alive) if d in alive[i].dims)
        if not group:
            continue
        eliminated.add(d)
        cost, new_dims = _elim_cost(
            d, [frozenset(alive[i].dims) for i in sorted(alive)], sizes
        )
        total_cost += cost
        sids = {alive[i].scale_id for i in group}
        sid = sids.pop() if len(sids) == 1 else min(sids)  # mixed raises at exec
        out_dims = tuple(sorted(new_dims))
        batch = tuple(sorted({b for i in group for b in alive[i].batch}))
        if not out_dims:
            sid = -1  # scale resolves as soon as no enum dims remain
        steps.append(ElimStep(dim=d, group=group, out=next_id))
        for i in group:
            del alive[i]
        alive[next_id] = new_struct(out_dims, batch, sid)
        next_id += 1

    return ContractionPlan(
        n_inputs=len(structs),
        steps=tuple(steps),
        outputs=tuple(sorted(alive)),
        eliminated=tuple(sorted(eliminated)),
        cost=total_cost,
        meta={"semiring": semiring, "knobs": knobs},
    )
