"""Gaussian semiring for the VE engine: exact marginalization of
linear-Gaussian continuous latents through the same planner/executor/cache
machinery that eliminates discrete enum dims.

A `GaussianFactor` is an information-form Gaussian potential over a tuple of
named flat variables x = (x_v1, ..., x_vk):

    log F(x) = -1/2 x^T J x + h^T x + c

with ``precision`` J (..., D, D), ``info_vec`` h (..., D), ``log_norm`` c
(...), D = sum of variable widths. The leading batch dims are *enum lead*
axes — discrete enumeration dims right-aligned in log-prob batch space (a
switching LDS carries one factor per discrete assignment) — and broadcast
against each other exactly like log-factor batch dims do.

The semiring structure mirrors the log semiring one-to-one:

* ⊗ (product) = embed into the union variable layout and ADD (J, h, c) —
  `gaussian_multiply`.
* ⊕ (marginalize a variable out) = Schur complement of its block —
  `gaussian_marginalize`. Exact for Gaussians: no sampling, no quadrature.

`eliminate_gaussian_factors` is the planner seam: continuous variables map
to negative int ids in trace order (first site most negative, matching the
greedy most-negative-first order to a *forward* Kalman filter sweep), each
factor becomes a `FactorStruct` whose sizes are variable widths, and the
shared `plan_elimination` recognizes linear-Gaussian chains structurally —
its `ChainStep`s lower here to a sequential `lax.scan` Kalman fold, the
O(log T) `ops.gaussian_scan` associative tree, or pairwise
`ops.gaussian_combine` folds. Plans are cached in the shared `PLAN_CACHE`
under ``semiring="gaussian"`` fingerprints, so Gaussian and log-semiring
plans for the same shapes never collide.

Cost-model caveat: `plan_elimination`'s objective multiplies dim sizes
(right for tensor contractions, an underestimate for the cubic dense
algebra here). Orders stay valid — elimination order never changes the
result, only the flop count — and chains, the case that matters, are
recognized structurally, so the shared planner is reused unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ...kernels import ops as kernel_ops
from ...kernels import ref as kernel_ref
from .cache import PLAN_CACHE
from .planner import ChainStep, plan_elimination, plan_knobs
from .structure import FactorStruct, _dispatch_mode, fingerprint

_LOG_2PI = math.log(2.0 * math.pi)


# ---------------------------------------------------------------------------
# the factor
# ---------------------------------------------------------------------------


@dataclass
class GaussianFactor:
    """Information-form Gaussian potential over named flat variables.

    ``vars``/``widths`` define the flat layout: variable ``vars[i]`` owns the
    contiguous index block of width ``widths[i]``, in order. Arrays carry
    broadcastable enum-lead batch dims in front."""

    vars: Tuple[str, ...]
    widths: Tuple[int, ...]
    precision: jax.Array    # (..., D, D)
    info_vec: jax.Array     # (..., D)
    log_norm: jax.Array     # (...)

    @property
    def width(self) -> int:
        return sum(self.widths)

    def width_of(self, var: str) -> int:
        return self.widths[self.vars.index(var)]

    def _flat_idx(self, names: Sequence[str]) -> np.ndarray:
        """Static flat indices of the given variables' blocks, in layout
        order of `names` (numpy, so every gather below is trace-static)."""
        offs = {}
        off = 0
        for v, w in zip(self.vars, self.widths):
            offs[v] = off
            off += w
        return np.concatenate(
            [np.arange(offs[v], offs[v] + self.width_of(v)) for v in names]
        ) if names else np.zeros((0,), np.int64)


def _bt(x) -> jax.Array:
    return jnp.swapaxes(x, -1, -2)


def gaussian_multiply(f: GaussianFactor, g: GaussianFactor) -> GaussianFactor:
    """⊗: pointwise product of two potentials — embed both into the union
    variable layout (f's variables first, then g's new ones in g's order)
    and add (J, h, c). Batch dims broadcast."""
    new = [v for v in g.vars if v not in f.vars]
    vars_u = f.vars + tuple(new)
    widths_u = f.widths + tuple(g.width_of(v) for v in new)
    D = sum(widths_u)
    offs = {}
    off = 0
    for v, w in zip(vars_u, widths_u):
        offs[v] = off
        off += w
    idx_f = np.concatenate(
        [np.arange(offs[v], offs[v] + w) for v, w in zip(f.vars, f.widths)]
    )
    idx_g = np.concatenate(
        [np.arange(offs[v], offs[v] + w) for v, w in zip(g.vars, g.widths)]
    )
    batch = jnp.broadcast_shapes(
        f.precision.shape[:-2], g.precision.shape[:-2],
        f.info_vec.shape[:-1], g.info_vec.shape[:-1],
        jnp.shape(f.log_norm), jnp.shape(g.log_norm),
    )
    J = jnp.zeros(batch + (D, D), jnp.float32)
    h = jnp.zeros(batch + (D,), jnp.float32)
    J = J.at[..., idx_f[:, None], idx_f[None, :]].add(f.precision)
    J = J.at[..., idx_g[:, None], idx_g[None, :]].add(g.precision)
    h = h.at[..., idx_f].add(f.info_vec)
    h = h.at[..., idx_g].add(g.info_vec)
    c = jnp.asarray(f.log_norm) + g.log_norm
    return GaussianFactor(vars_u, widths_u, J, h, jnp.broadcast_to(c, batch))


def gaussian_marginalize(f: GaussianFactor, drop: Sequence[str]) -> GaussianFactor:
    """⊕: integrate the given variables out — the Schur complement of their
    block. With x = (a, b), b the dropped block of total width d_b:

        J' = J_aa - J_ab J_bb⁻¹ J_ba        h' = h_a - J_ab J_bb⁻¹ h_b
        c' = c + 1/2 h_b^T J_bb⁻¹ h_b - 1/2 log|J_bb| + (d_b/2) log 2π

    Exact when J_bb is positive definite — true whenever the dropped
    variables' conditionals entered as genuine densities (see the
    conditioning contract in `kernels/gaussian.py`)."""
    drop_set = set(drop)
    keep = [v for v in f.vars if v not in drop_set]
    gone = [v for v in f.vars if v in drop_set]
    if not gone:
        return f
    ia = f._flat_idx(keep)
    ib = f._flat_idx(gone)
    db = len(ib)
    Jaa = f.precision[..., ia[:, None], ia[None, :]]
    Jab = f.precision[..., ia[:, None], ib[None, :]]
    Jbb = f.precision[..., ib[:, None], ib[None, :]]
    ha = f.info_vec[..., ia]
    hb = f.info_vec[..., ib]
    S = jnp.linalg.solve(Jbb, _bt(Jab))              # J_bb⁻¹ J_ba
    Mih = jnp.linalg.solve(Jbb, hb[..., None])[..., 0]
    J = Jaa - Jab @ S
    J = 0.5 * (J + _bt(J))
    h = ha - (Jab @ Mih[..., None])[..., 0]
    _, logdet = jnp.linalg.slogdet(Jbb)
    c = (
        f.log_norm + 0.5 * jnp.sum(hb * Mih, -1)
        - 0.5 * logdet + 0.5 * db * _LOG_2PI
    )
    widths = tuple(f.width_of(v) for v in keep)
    return GaussianFactor(tuple(keep), widths, J, h, c)


def gaussian_marginal_params(f: GaussianFactor) -> Tuple[jax.Array, jax.Array]:
    """(mean, cov) of the normalized density a factor encodes: mean = J⁻¹h,
    cov = J⁻¹ — per batch element."""
    cov = jnp.linalg.inv(f.precision)
    cov = 0.5 * (cov + _bt(cov))
    mean = (cov @ f.info_vec[..., None])[..., 0]
    return mean, cov


def affine_gaussian_factor(
    vars: Tuple[str, ...],
    widths: Tuple[int, ...],
    coeffs: Dict[str, jax.Array],
    m0: jax.Array,
    scale_tril: jax.Array,
    own: Optional[str],
) -> GaussianFactor:
    """Lower one conditional density N(value; Σ_p A_p x_p + b, L L^T) to an
    information-form factor over its entangled variables.

    The residual is affine in the stacked variables, r = M x + m0: the
    site's own block (when the site itself is marginalized, ``own``) gets
    M_own = I, each parent p gets M_p = -A_p (``coeffs[p]``, shaped
    (..., w_site, w_p)), and m0 is -b (marginalized) or value - b (observed /
    replayed). Then with W = L⁻¹M and u = L⁻¹m0:

        J = W^T W    h = -W^T u    c = -1/2 u^T u - Σ log diag L - (w/2) log 2π

    so the factor integrates to the site's exact conditional log-density —
    normalized, which is what lets eliminated chains produce the true
    marginal likelihood."""
    w_site = scale_tril.shape[-1]
    blocks = []
    for v, w in zip(vars, widths):
        if v == own:
            blocks.append(
                jnp.broadcast_to(jnp.eye(w_site, dtype=jnp.float32), m0.shape[:-1] + (w_site, w_site))
            )
        else:
            blocks.append(-coeffs[v])
    batch = jnp.broadcast_shapes(
        *[b.shape[:-2] for b in blocks], m0.shape[:-1], scale_tril.shape[:-2]
    )
    M = jnp.concatenate(
        [jnp.broadcast_to(b, batch + b.shape[-2:]) for b in blocks], axis=-1
    )
    m0 = jnp.broadcast_to(m0, batch + m0.shape[-1:])
    L = jnp.broadcast_to(scale_tril, batch + scale_tril.shape[-2:])
    W = jax.scipy.linalg.solve_triangular(L, M, lower=True)
    u = jax.scipy.linalg.solve_triangular(L, m0[..., None], lower=True)[..., 0]
    J = _bt(W) @ W
    J = 0.5 * (J + _bt(J))
    h = -(_bt(W) @ u[..., None])[..., 0]
    c = (
        -0.5 * jnp.sum(u * u, -1)
        - jnp.sum(jnp.log(jnp.diagonal(L, axis1=-2, axis2=-1)), -1)
        - 0.5 * w_site * _LOG_2PI
    )
    return GaussianFactor(vars, widths, J, h, c)


# ---------------------------------------------------------------------------
# edge-factor plumbing for chain lowerings
# ---------------------------------------------------------------------------

# event rank per edge 6-tuple leaf (J11, J12, J22, h1, h2, c)
_EDGE_EVENT_RANKS = (2, 2, 2, 1, 1, 0)


def _edge_tuple(f: GaussianFactor, u: str, v: str):
    """Extract the ordered (u, v) edge 6-tuple from a binary factor."""
    iu = f._flat_idx([u])
    iv = f._flat_idx([v])
    J = f.precision
    return (
        J[..., iu[:, None], iu[None, :]],
        J[..., iu[:, None], iv[None, :]],
        J[..., iv[:, None], iv[None, :]],
        f.info_vec[..., iu],
        f.info_vec[..., iv],
        jnp.asarray(f.log_norm, jnp.float32),
    )


def _fold_unary(edge, f: GaussianFactor, side: str):
    """Add a unary factor's (J, h, c) into one side of an edge tuple."""
    J11, J12, J22, h1, h2, c = edge
    if side == "left":
        return (J11 + f.precision, J12, J22, h1 + f.info_vec, h2, c + f.log_norm)
    return (J11, J12, J22 + f.precision, h1, h2 + f.info_vec, c + f.log_norm)


def _stack_edges(edges):
    """Stack edge tuples along a new chain axis (at -3/-2/-1 per leaf),
    broadcasting every leaf to ONE common lead batch first — scan carries
    must keep an invariant shape, so partial per-leaf batches can't ride
    along the chain axis."""
    leaves = [
        [jnp.asarray(e[li], jnp.float32) for e in edges]
        for li in range(6)
    ]
    batch = jnp.broadcast_shapes(
        *[x.shape[: x.ndim - er] for xs, er in zip(leaves, _EDGE_EVENT_RANKS) for x in xs]
    )
    out = []
    for xs, er in zip(leaves, _EDGE_EVENT_RANKS):
        xs = [jnp.broadcast_to(x, batch + x.shape[x.ndim - er:]) for x in xs]
        out.append(jnp.stack(xs, axis=len(batch)))
    return tuple(out)


def _marginalize_left(edge):
    """Integrate an edge tuple's LEFT variable out, returning the unary
    (J, h, c) on its right variable — one Kalman predict+update in
    information form."""
    J11, J12, J22, h1, h2, c = edge
    d1 = J11.shape[-1]
    S = jnp.linalg.solve(J11, J12)                    # J11⁻¹ J12
    Mih = jnp.linalg.solve(J11, h1[..., None])[..., 0]
    J = J22 - _bt(J12) @ S
    J = 0.5 * (J + _bt(J))
    h = h2 - (_bt(J12) @ Mih[..., None])[..., 0]
    _, logdet = jnp.linalg.slogdet(J11)
    c = c + 0.5 * jnp.sum(h1 * Mih, -1) - 0.5 * logdet + 0.5 * d1 * _LOG_2PI
    return J, h, c


def _run_gaussian_scan(step: ChainStep, edges, path_vars):
    """Roll a uniform Gaussian chain through one forward `lax.scan` — the
    sequential information-form Kalman fold. With `absorb` the carry is the
    unary filtered potential on the frontier variable (O(T d³) total, the
    textbook filter); otherwise the carry is the edge factor linking D_0 to
    the frontier. Edge 0 resolves outside the scan (mirroring
    `executor._run_scan`), so T=1 segments never pay a scan op."""
    stacked = _stack_edges(edges)
    # scan iterates the leading axis: move each leaf's chain axis to front
    stacked = tuple(
        jnp.moveaxis(x, x.ndim - er - 1, 0)
        for x, er in zip(stacked, _EDGE_EVENT_RANKS)
    )
    rest = tuple(x[1:] for x in stacked)
    first = tuple(x[0] for x in stacked)
    if step.absorb:
        init = _marginalize_left(first)

        def body(carry, edge):
            J, h, c = carry
            J11, J12, J22, h1, h2, ec = edge
            out = _marginalize_left((J11 + J, J12, J22, h1 + h, h2, ec + c))
            return out, None

        (J, h, c), _ = jax.lax.scan(body, init, rest)
        return GaussianFactor((path_vars[-1],), (J.shape[-1],), J, h, c)

    def body(carry, edge):
        return kernel_ref.gaussian_combine_ref(carry, edge), None

    out, _ = jax.lax.scan(body, first, rest)
    return _edge_factor(out, path_vars[0], path_vars[-1])


def _edge_factor(edge, u: str, v: str) -> GaussianFactor:
    """Reassemble an edge 6-tuple into a binary `GaussianFactor` over (u, v)."""
    J11, J12, J22, h1, h2, c = edge
    d1, d2 = J11.shape[-1], J22.shape[-1]
    top = jnp.concatenate([J11, J12], axis=-1)
    bot = jnp.concatenate([_bt(J12), J22], axis=-1)
    J = jnp.concatenate([top, bot], axis=-2)
    h = jnp.concatenate([h1, h2], axis=-1)
    return GaussianFactor((u, v), (d1, d2), J, h, c)


def _run_gaussian_chain(step: ChainStep, factors, dim_to_var) -> GaussianFactor:
    """Execute one `ChainStep` over Gaussian factors: assemble oriented edge
    tuples (merging parallel binaries, folding interior unaries — left side
    for the scan sweep, right side for tree/folds, mirroring the log
    executor's association), then lower."""
    path_vars = [dim_to_var[d] for d in step.path]
    edges = []
    for t, ids in enumerate(step.edges):
        f = factors[ids[0]]
        for i in ids[1:]:
            f = gaussian_multiply(f, factors[i])
        edges.append(_edge_tuple(f, path_vars[t], path_vars[t + 1]))

    if step.lower == "scan":
        for t in range(len(edges)):
            ids = list(step.absorbed) if t == 0 else list(step.folded[t])
            for i in ids:
                edges[t] = _fold_unary(edges[t], factors[i], "left")
        return _run_gaussian_scan(step, edges, path_vars)

    assert not step.absorb, "terminal absorption is a scan-only lowering"
    for t in range(len(edges)):
        for i in step.folded[t + 1]:   # interior unaries fold into the entering edge
            edges[t] = _fold_unary(edges[t], factors[i], "right")
    if step.lower == "tree" and len(edges) >= 3:
        out = kernel_ops.gaussian_scan(_stack_edges(edges))
    else:
        out = edges[0]
        for e in edges[1:]:
            out = kernel_ops.gaussian_combine(out, e)
    return _edge_factor(out, path_vars[0], path_vars[-1])


# ---------------------------------------------------------------------------
# the planner seam
# ---------------------------------------------------------------------------


def _gaussian_structs(
    factors: Sequence[GaussianFactor], var_to_dim: Dict[str, int]
) -> List[FactorStruct]:
    structs = []
    for f in factors:
        order = sorted(f.vars, key=lambda v: var_to_dim[v])
        dims = tuple(var_to_dim[v] for v in order)
        sizes = tuple(f.width_of(v) for v in order)
        lead = jnp.shape(f.log_norm)
        batch = tuple(i - len(lead) for i, s in enumerate(lead) if s > 1)
        structs.append(FactorStruct(dims, sizes, batch, -1))
    return structs


def greedy_eliminate_gaussians(
    factors: Sequence[GaussianFactor], order: Sequence[str]
) -> List[jax.Array]:
    """Legacy-shaped greedy path (``dispatch="pairwise"``): eliminate one
    variable at a time in trace order — the dense sequential reference the
    planned path is conformance-tested against."""
    fs = list(factors)
    for var in order:
        group = [f for f in fs if var in f.vars]
        rest = [f for f in fs if var not in f.vars]
        if not group:
            continue
        f = group[0]
        for g in group[1:]:
            f = gaussian_multiply(f, g)
        fs = rest + [gaussian_marginalize(f, [var])]
    for f in fs:
        if f.vars:
            raise RuntimeError(f"variables {f.vars} survived greedy elimination")
    return [f.log_norm for f in fs]


def execute_gaussian_plan(plan, factors, dim_to_var) -> List[jax.Array]:
    """Run a `ContractionPlan` against Gaussian factors: `ChainStep`s lower
    to the fused Kalman sweeps, `ElimStep`s to one multiply+Schur each.
    Returns the surviving factors' log-normalizer tensors (every planned
    variable eliminated)."""
    fs: List[Optional[GaussianFactor]] = list(factors)
    for step in plan.steps:
        if isinstance(step, ChainStep):
            out = _run_gaussian_chain(step, fs, dim_to_var)
        else:
            group = [fs[i] for i in step.group]
            f = group[0]
            for g in group[1:]:
                f = gaussian_multiply(f, g)
            out = gaussian_marginalize(f, [dim_to_var[step.dim]])
        assert step.out == len(fs), "plan ids out of sync with gaussian executor"
        fs.append(out)
    outs = [fs[i] for i in plan.outputs]
    for f in outs:
        if f.vars:
            raise RuntimeError(
                f"variables {f.vars} survived the planned elimination"
            )
    return [f.log_norm for f in outs]


def eliminate_gaussian_factors(
    factors: Sequence[GaussianFactor],
    order: Sequence[str],
    dispatch: Optional[str] = None,
) -> List[jax.Array]:
    """Integrate every variable out of a Gaussian factor graph, returning
    the per-factor log-normalizer tensors (enum-lead batched, right-aligned
    — ready to enter the discrete contraction as ordinary log-factors).

    ``order`` is the variables' trace order: the first site maps to the most
    negative planner id, so the planner's greedy most-negative-first
    tie-break sweeps chains front-to-back (a forward Kalman filter). In
    ``auto`` dispatch the elimination is planned through the shared
    `plan_elimination` (plan-cached under a ``semiring="gaussian"``
    fingerprint — same cache, disjoint keys from log-semiring plans);
    ``pairwise`` runs the dense greedy reference path."""
    if not factors:
        return []
    order = list(order)
    n = len(order)
    var_to_dim = {v: i - n for i, v in enumerate(order)}
    for f in factors:
        for v in f.vars:
            if v not in var_to_dim:
                raise ValueError(f"factor variable {v!r} missing from order {order}")
    if _dispatch_mode(dispatch) == "pairwise":
        return greedy_eliminate_gaussians(factors, order)
    structs = _gaussian_structs(factors, var_to_dim)
    dims = frozenset(var_to_dim.values())
    knobs = plan_knobs()
    key = fingerprint(structs, dims, "gaussian", knobs)
    plan = PLAN_CACHE.get_or_plan(
        key,
        lambda: plan_elimination(structs, dims, semiring="gaussian", knobs=knobs),
    )
    dim_to_var = {d: v for v, d in var_to_dim.items()}
    return execute_gaussian_plan(plan, factors, dim_to_var)


# ---------------------------------------------------------------------------
# structural dependence analysis (works under jit)
# ---------------------------------------------------------------------------


def jaxpr_dependencies(fn: Callable, protos) -> List[FrozenSet[int]]:
    """Which input leaves each output leaf of ``fn`` structurally depends
    on, via a conservative dataflow walk of the jaxpr.

    ``protos`` is a pytree of abstract-value prototypes (typically a dict of
    zero arrays); returns one frozenset of flat *input-leaf indices* per flat
    *output leaf*, both in `jax.tree_util` flatten order. Conservative:
    equations with sub-jaxprs (scan/cond/pjit) propagate the union of all
    their inputs to all their outputs, so dependence is only ever
    over-reported — an over-reported edge densifies a factor, never drops
    one. Works on tracers, which is what makes marginalization structure
    discoverable inside `jax.jit`."""
    closed = jax.make_jaxpr(fn)(protos)
    jaxpr = closed.jaxpr
    deps: Dict = {}
    for i, v in enumerate(jaxpr.invars):
        deps[v] = frozenset([i])
    for v in jaxpr.constvars:
        deps[v] = frozenset()
    for eqn in jaxpr.eqns:
        ins: FrozenSet[int] = frozenset()
        for v in eqn.invars:
            if hasattr(v, "val"):       # Literal: no dependence
                continue
            ins = ins | deps.get(v, frozenset())
        for o in eqn.outvars:
            deps[o] = ins
    out: List[FrozenSet[int]] = []
    for v in jaxpr.outvars:
        if hasattr(v, "val"):
            out.append(frozenset())
        else:
            out.append(deps.get(v, frozenset()))
    return out


def color_sites(
    sites: Sequence[str], dependents: Dict[str, Set[str]]
) -> List[List[str]]:
    """Greedy conflict coloring for Jacobian probing: two sites conflict
    when some output depends on both, so sites within one color class can
    share a JVP basis push and still be disentangled (each output sees at
    most one active parent per push). A Markov chain 2-colors; the number
    of pushes is colors × max width — O(1) in chain length."""
    conflicts: Dict[str, Set[str]] = {s: set() for s in sites}
    for parents in dependents.values():
        ps = [p for p in sites if p in parents]
        for a in ps:
            for b in ps:
                if a != b:
                    conflicts[a].add(b)
    colors: List[List[str]] = []
    assigned: Dict[str, int] = {}
    for s in sites:
        used = {assigned[o] for o in conflicts[s] if o in assigned}
        c = next(i for i in range(len(colors) + 1) if i not in used)
        if c == len(colors):
            colors.append([])
        colors[c].append(s)
        assigned[s] = c
    return colors
