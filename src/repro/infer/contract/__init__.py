"""Planner/executor contraction engine for tensor variable elimination.

Split out of `repro.infer.traceenum_elbo` so the contraction *plan* is an
explicit compiler artifact: `planner.plan_elimination` turns the structural
view of a factor graph into an inspectable `ContractionPlan`, `cache` keys
plans on a structural fingerprint (shapes + incidence, never values), and
`executor` lowers plan segments to the fused semiring kernels or a
plan-level `lax.scan`. `executor.contract_log_factors` is the ordinal-level
entry point every enumeration engine calls.
"""
from .cache import PLAN_CACHE, clear_plan_cache, plan_cache_stats
from .gaussian import (
    GaussianFactor,
    affine_gaussian_factor,
    eliminate_gaussian_factors,
    execute_gaussian_plan,
    gaussian_marginal_params,
    gaussian_marginalize,
    gaussian_multiply,
    greedy_eliminate_gaussians,
    jaxpr_dependencies,
)
from .executor import (
    _ve_eliminate,
    contract_log_factors,
    execute_plan,
    greedy_eliminate,
    planned_contraction,
)
from .planner import (
    ChainStep,
    ContractionPlan,
    ElimStep,
    chain_threshold,
    plan_elimination,
    plan_knobs,
)
from .structure import (
    FactorStruct,
    _dispatch_mode,
    _from_matrix,
    _from_vector,
    _logsumexp_op,
    _max_op,
    _to_matrix,
    factor_structs,
    fingerprint,
    semiring_of,
)

__all__ = [
    "PLAN_CACHE",
    "ChainStep",
    "ContractionPlan",
    "ElimStep",
    "FactorStruct",
    "GaussianFactor",
    "affine_gaussian_factor",
    "chain_threshold",
    "clear_plan_cache",
    "contract_log_factors",
    "eliminate_gaussian_factors",
    "execute_gaussian_plan",
    "execute_plan",
    "factor_structs",
    "fingerprint",
    "gaussian_marginal_params",
    "gaussian_marginalize",
    "gaussian_multiply",
    "greedy_eliminate",
    "greedy_eliminate_gaussians",
    "jaxpr_dependencies",
    "plan_cache_stats",
    "plan_elimination",
    "plan_knobs",
    "planned_contraction",
    "semiring_of",
    "_dispatch_mode",
    "_from_matrix",
    "_from_vector",
    "_logsumexp_op",
    "_max_op",
    "_to_matrix",
    "_ve_eliminate",
]
