"""Plan cache: contraction plans are compiler artifacts keyed on the
*structural* fingerprint of the factor graph (dim sizes + factor incidence +
scale grouping — never array values), so repeated shapes — every SVI step's
retrace, every serve bucket, every same-structure model instantiation —
skip planning entirely.

Hit/miss/time stats are surfaced via `plan_cache_stats()` (printed by the
bench stage and asserted by the plan-cache tests). ``REPRO_ENUM_PLAN_CACHE=0``
disables caching (every elimination replans); ``REPRO_ENUM_PLAN_CACHE_SIZE``
bounds the cache (default 256 plans, FIFO eviction).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Tuple

from ... import settings
from .planner import ContractionPlan


class PlanCache:
    """Thread-safe structural-fingerprint -> `ContractionPlan` cache."""

    def __init__(self) -> None:
        self._plans: "OrderedDict[Tuple, ContractionPlan]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.plan_time_s = 0.0

    @staticmethod
    def _enabled() -> bool:
        return settings.get_bool("REPRO_ENUM_PLAN_CACHE")

    @staticmethod
    def _maxsize() -> int:
        return max(1, settings.get_int("REPRO_ENUM_PLAN_CACHE_SIZE"))

    def get_or_plan(self, key: Tuple, build: Callable[[], ContractionPlan]) -> ContractionPlan:
        if self._enabled():
            with self._lock:
                plan = self._plans.get(key)
                if plan is not None:
                    self.hits += 1
                    return plan
        t0 = time.perf_counter()
        plan = build()
        dt = time.perf_counter() - t0
        with self._lock:
            self.misses += 1
            self.plan_time_s += dt
            if self._enabled():
                self._plans[key] = plan
                while len(self._plans) > self._maxsize():
                    self._plans.popitem(last=False)
        return plan

    def stats(self) -> Dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "size": len(self._plans),
                "plan_time_s": round(self.plan_time_s, 6),
            }

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = 0
            self.misses = 0
            self.plan_time_s = 0.0


PLAN_CACHE = PlanCache()


def plan_cache_stats() -> Dict:
    """Hit/miss/size/planning-time counters of the global plan cache."""
    return PLAN_CACHE.stats()


def clear_plan_cache() -> None:
    """Drop all cached plans and reset the counters (tests, benchmarks)."""
    PLAN_CACHE.clear()
