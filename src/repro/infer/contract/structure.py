"""Structural view of a log-factor contraction: the metadata the planner
reasons about, and the shared low-level helpers both the planner's executor
and the legacy greedy path use.

A *factor* at this layer is a right-aligned log-density tensor plus an
optional pending scale (see `traceenum_elbo._collect_factors` for where the
pending-scale discipline comes from). The planner never looks at array
values — it sees each factor as a `FactorStruct`: which enum dims it
carries (with their cardinalities), which non-enum axes are non-trivial
(the plate/batch pattern), and which scale-equivalence class it belongs to.
That structural view is also what the plan cache keys on, so two traces of
the same model shape plan exactly once.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, FrozenSet, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp

from ... import settings

# ---------------------------------------------------------------------------
# semiring reduction ops (shared with traceenum_elbo)
# ---------------------------------------------------------------------------


def _logsumexp_op(t, axes):
    return jsp.logsumexp(t, axis=axes, keepdims=True)


def _max_op(t, axes):
    return jnp.max(t, axis=axes, keepdims=True)


def semiring_of(sum_op) -> Optional[str]:
    """Kernel-lowerable semiring name for a reduction op (None = custom op,
    which only the generic greedy path can execute)."""
    if sum_op is _logsumexp_op:
        return "logsumexp"
    if sum_op is _max_op:
        return "max"
    return None


def _enum_dims(t: jax.Array, pool: FrozenSet[int]) -> FrozenSet[int]:
    """Allocated enum dims actually present (size > 1) in a right-aligned
    log-factor. Only dims the enum messenger allocated count — ordinary
    batch dims are never contracted."""
    return frozenset(
        d for d in pool if jnp.ndim(t) >= -d and jnp.shape(t)[jnp.ndim(t) + d] > 1
    )


def _reduce_dims(t: jax.Array, dims, sum_op) -> jax.Array:
    axes = tuple(jnp.ndim(t) + d for d in dims)
    return sum_op(t, axes) if axes else t


def _add_all(ts: List[jax.Array]) -> jax.Array:
    total = ts[0]
    for t in ts[1:]:
        total = total + t
    return total


def _scaled(t: jax.Array, scale) -> jax.Array:
    return t if scale is None else t * scale


def _uniform_scale(scales):
    """The single pending scale shared by a contraction group (None == 1)."""
    distinct = []
    for s in scales:
        if not any(s is d or (isinstance(s, (int, float)) and s == d) for d in distinct):
            distinct.append(s)
    if len(distinct) > 1:
        raise NotImplementedError(
            "factors with different log_prob scales meet inside one enumerated "
            f"contraction (scales {distinct}); apply the same plate/scale "
            "context to every site entangled with an enumerated variable"
        )
    return distinct[0]


# ---------------------------------------------------------------------------
# dispatch mode
# ---------------------------------------------------------------------------

_DISPATCH_MODES = ("auto", "pairwise")


def _dispatch_mode(override: Optional[str] = None) -> str:
    """How eliminations are routed: ``auto`` (default) runs the cost-based
    contraction planner, which recognizes matmul-, chain-, and tree-shaped
    eliminations and lowers them to the fused semiring kernels or a
    `lax.scan` roll; ``pairwise`` forces the legacy one-dim-at-a-time greedy
    path everywhere. Explicit argument > ``REPRO_ENUM_DISPATCH`` env var."""
    mode = override or settings.get_str("REPRO_ENUM_DISPATCH")
    if mode not in _DISPATCH_MODES:
        raise ValueError(
            f"unknown enum dispatch mode {mode!r}; expected one of {_DISPATCH_MODES}"
        )
    return mode


# ---------------------------------------------------------------------------
# matrix/vector re-embedding between right-aligned and batched-matrix layouts
# ---------------------------------------------------------------------------


def _to_matrix(t: jax.Array, d_row: int, d_col: int) -> jax.Array:
    """View a right-aligned log-factor carrying enum dims (d_row, d_col) as a
    batched matrix (batch..., K_row, K_col), where the batch is the factor's
    (right-aligned) plate shape.

    Enum dims live in deep negative slots, so a long chain's factors have
    ranks up to T — transposing at that rank is exactly what blows up XLA
    compile time. Every axis other than the two enum axes and the trailing
    plate block is size 1, so one order-preserving reshape drops to a small
    rank first and the transpose happens there."""
    nd = jnp.ndim(t)
    shape = jnp.shape(t)
    ar, ac = nd + d_row, nd + d_col
    hi = max(ar, ac)
    plate_rank = 0
    for i in range(nd - 1, hi, -1):
        if shape[i] != 1:
            plate_rank = nd - i  # extend the kept block to this axis
    if any(
        shape[i] != 1
        for i in range(nd - plate_rank)
        if i not in (ar, ac)
    ):  # unexpected non-plate batch axis: fall back to the generic transpose
        m = jnp.moveaxis(t, (ar, ac), (-2, -1))
        lead = 0
        while lead < jnp.ndim(m) - 2 and jnp.shape(m)[lead] == 1:
            lead += 1
        return jnp.reshape(m, jnp.shape(m)[lead:]) if lead else m
    plates = shape[nd - plate_rank:] if plate_rank else ()
    first, second = (ar, ac) if ar < ac else (ac, ar)
    m = jnp.reshape(t, (shape[first], shape[second]) + tuple(plates))
    m = jnp.moveaxis(m, (0, 1), (-2, -1))  # (plates..., K_first, K_second)
    if ar > ac:  # row axis came second in memory order
        m = jnp.swapaxes(m, -1, -2)
    return m


def _from_matrix(m: jax.Array, d_row: int, d_col: int) -> jax.Array:
    """Inverse of `_to_matrix` for a contraction result: re-embed a batched
    matrix into right-aligned form with the row/col axes at enum slots
    (d_row, d_col) and the batch (plate) axes back at the right edge. The
    transpose happens at the small rank; the lift to full rank is a single
    size-1-inserting reshape."""
    L = jnp.ndim(m) - 2
    R = max(-d_row, -d_col, L + 2)
    ar, ac = R + d_row, R + d_col
    if ac >= R - L or ar >= R - L:  # enum slot would collide with the plate block
        m = jnp.reshape(m, (1,) * (R - L - 2) + jnp.shape(m))
        return jnp.moveaxis(m, (R - 2, R - 1), (ar, ac))
    x = jnp.moveaxis(m, (-2, -1) if ar < ac else (-1, -2), (0, 1))
    shape = [1] * R
    first, second = (ar, ac) if ar < ac else (ac, ar)
    shape[first], shape[second] = x.shape[0], x.shape[1]
    shape[R - L:] = x.shape[2:]
    return jnp.reshape(x, tuple(shape))


def _to_vector(t: jax.Array, d: int) -> jax.Array:
    """View a right-aligned log-factor carrying the single enum dim `d` as a
    batched vector (batch..., K) — the unary analogue of `_to_matrix`, with
    the same reshape-first trick so no transpose happens at chain rank."""
    nd = jnp.ndim(t)
    shape = jnp.shape(t)
    a = nd + d
    plate_rank = 0
    for i in range(nd - 1, a, -1):
        if shape[i] != 1:
            plate_rank = nd - i
    if any(shape[i] != 1 for i in range(nd - plate_rank) if i != a):
        v = jnp.moveaxis(t, a, -1)  # unexpected batch axis: generic fallback
        lead = 0
        while lead < jnp.ndim(v) - 1 and jnp.shape(v)[lead] == 1:
            lead += 1
        return jnp.reshape(v, jnp.shape(v)[lead:]) if lead else v
    plates = shape[nd - plate_rank:] if plate_rank else ()
    v = jnp.reshape(t, (shape[a],) + tuple(plates))
    return jnp.moveaxis(v, 0, -1)  # (plates..., K)


def _from_vector(v: jax.Array, d: int) -> jax.Array:
    """Re-embed a batched vector (batch..., K) into right-aligned form with
    the K axis at enum slot `d` and the batch axes back at the right edge
    (the vector analogue of `_from_matrix`, used by scan-rolled chains that
    absorb a terminal)."""
    L = jnp.ndim(v) - 1
    R = max(-d, L + 1)
    a = R + d
    if a >= R - L:  # enum slot collides with the plate block
        v = jnp.reshape(v, (1,) * (R - L - 1) + jnp.shape(v))
        return jnp.moveaxis(v, R - 1, a)
    x = jnp.moveaxis(v, -1, 0)
    shape = [1] * R
    shape[a] = x.shape[0]
    shape[R - L:] = x.shape[1:]
    return jnp.reshape(x, tuple(shape))


# ---------------------------------------------------------------------------
# structural factor view + fingerprint
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FactorStruct:
    """Shape-level view of one log-factor: everything the planner (and the
    plan-cache key) needs, nothing value-dependent."""

    dims: Tuple[int, ...]        # enum dims present (sorted ascending)
    sizes: Tuple[int, ...]       # cardinality of each dim, aligned with `dims`
    batch: Tuple[int, ...]       # non-enum right-aligned axes with size > 1
    scale_id: int                # scale-equivalence class (-1 = no scale)

    def size_of(self, d: int) -> int:
        return self.sizes[self.dims.index(d)]


def _scale_ids(scales: Sequence[Any]) -> List[int]:
    """Map each pending scale to a small equivalence-class id using the same
    distinctness rule as `_uniform_scale` (identity, or numeric equality for
    plain Python numbers). None maps to -1. Array/tracer scales compare by
    identity only — exactly the grouping the executor's scale checks see."""
    ids: List[int] = []
    reps: List[Any] = []
    for s in scales:
        if s is None:
            ids.append(-1)
            continue
        for j, r in enumerate(reps):
            if s is r or (isinstance(s, (int, float)) and s == r):
                ids.append(j)
                break
        else:
            reps.append(s)
            ids.append(len(reps) - 1)
    return ids


def factor_structs(ts, pool: FrozenSet[int]) -> List[FactorStruct]:
    """Build the structural view of a (tensor, pending_scale) factor list."""
    scale_ids = _scale_ids([s for _, s in ts])
    structs = []
    for (t, _), sid in zip(ts, scale_ids):
        nd = jnp.ndim(t)
        shape = jnp.shape(t)
        dims = tuple(sorted(d for d in pool if nd >= -d and shape[nd + d] > 1))
        sizes = tuple(shape[nd + d] for d in dims)
        batch = tuple(
            i - nd
            for i in range(nd)
            if shape[i] > 1 and (i - nd) not in dims
        )
        structs.append(FactorStruct(dims, sizes, batch, sid))
    return structs


def fingerprint(
    structs: Sequence[FactorStruct],
    dims: FrozenSet[int],
    semiring: str,
    knobs: Tuple,
) -> Tuple:
    """Hashable structural fingerprint of one elimination problem: factor
    incidence + dim cardinalities + plate patterns + scale grouping + the
    dims to eliminate + the semiring + any env knobs that change planning.
    Array *values* never enter the key — every SVI step and every serve
    bucket with the same shapes shares one plan."""
    return (
        tuple((f.dims, f.sizes, f.batch, f.scale_id) for f in structs),
        tuple(sorted(dims)),
        semiring,
        knobs,
    )
