from .autoguide import (
    AutoDelta,
    AutoGuide,
    AutoIAFNormal,
    AutoLowRankMultivariateNormal,
    AutoNormal,
)
from ..core.handlers import config, config_enumerate, config_gaussian
from .elbo import ELBO, RenyiELBO, Trace_ELBO, TraceMeanField_ELBO, vectorize_particles
from .contract import clear_plan_cache, plan_cache_stats
from .traceenum_elbo import (
    TraceEnum_ELBO,
    discrete_marginals,
    gaussian_marginals,
    infer_discrete,
)
from .tracegraph_elbo import TraceGraph_ELBO
from .importance import Importance
from .combinators import (
    ImportanceSampling,
    compose,
    extend,
    primitive,
    propose,
    resample,
)
from .diagnostics import effective_sample_size, print_summary, split_rhat, summary
from .mcmc import HMC, MCMC, NUTS
from .predictive import Predictive
from .smc import SMC, NestedVariational, SMCFilter, sequential_pair, smc_sweep
from .svi import SVI, SVIRunner, SVIState
from .util import initialize_model, log_density, potential_energy, substitute_params
from ..retrace import InferenceEngine

__all__ = [
    "AutoDelta",
    "AutoGuide",
    "AutoIAFNormal",
    "AutoLowRankMultivariateNormal",
    "AutoNormal",
    "ELBO",
    "RenyiELBO",
    "Trace_ELBO",
    "TraceEnum_ELBO",
    "TraceGraph_ELBO",
    "TraceMeanField_ELBO",
    "clear_plan_cache",
    "config",
    "config_enumerate",
    "config_gaussian",
    "discrete_marginals",
    "gaussian_marginals",
    "plan_cache_stats",
    "infer_discrete",
    "Importance",
    "ImportanceSampling",
    "InferenceEngine",
    "HMC",
    "MCMC",
    "NUTS",
    "NestedVariational",
    "Predictive",
    "SMC",
    "SMCFilter",
    "compose",
    "extend",
    "primitive",
    "propose",
    "resample",
    "sequential_pair",
    "smc_sweep",
    "SVI",
    "SVIRunner",
    "SVIState",
    "effective_sample_size",
    "initialize_model",
    "log_density",
    "potential_energy",
    "print_summary",
    "split_rhat",
    "substitute_params",
    "summary",
    "vectorize_particles",
]
