from .autoguide import (
    AutoDelta,
    AutoGuide,
    AutoIAFNormal,
    AutoLowRankMultivariateNormal,
    AutoNormal,
)
from .elbo import ELBO, RenyiELBO, Trace_ELBO, TraceMeanField_ELBO, vectorize_particles
from .tracegraph_elbo import TraceGraph_ELBO
from .importance import Importance
from .mcmc import HMC, MCMC, NUTS
from .predictive import Predictive
from .svi import SVI, SVIRunner, SVIState
from .util import log_density, potential_energy, substitute_params

__all__ = [
    "AutoDelta",
    "AutoGuide",
    "AutoIAFNormal",
    "AutoLowRankMultivariateNormal",
    "AutoNormal",
    "ELBO",
    "RenyiELBO",
    "Trace_ELBO",
    "TraceGraph_ELBO",
    "TraceMeanField_ELBO",
    "Importance",
    "HMC",
    "MCMC",
    "NUTS",
    "Predictive",
    "SVI",
    "SVIRunner",
    "SVIState",
    "log_density",
    "potential_energy",
    "substitute_params",
    "vectorize_particles",
]
