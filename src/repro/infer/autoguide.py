"""Automatic guide construction (Pyro's `pyro.infer.autoguide`; paper §2
describes guides as "arbitrary Pyro programs" paired with a model for SVI,
and Fig. 4 extends a mean-field guide with inverse autoregressive flows —
autoguides synthesize those guide programs from the model's trace).

AutoDelta  -> MAP / MLE (this is how the big LM configs train: SVI with a
              Delta guide over weights == maximum likelihood, making the PPL
              machinery the *training loop* of the framework).
AutoNormal -> mean-field ADVI.
AutoLowRankMVN -> low-rank multivariate normal posterior.
AutoIAFNormal -> normalizing-flow guide (paper Fig. 4's IAF extension).

Every autoguide traces the model lazily, registers its variational
parameters in *unconstrained* space, and bijects samples back to each
site's support — so it composes with the sharded SVI engine's `mesh=` and
explicit-subsample machinery unchanged.

Example — mean-field ADVI on a conjugate model::

    >>> import jax, jax.numpy as jnp
    >>> from repro import distributions as dist, optim
    >>> from repro.core import primitives as P
    >>> from repro.infer import SVI, AutoNormal, Trace_ELBO
    >>> def model(data):
    ...     loc = P.sample("loc", dist.Normal(0.0, 10.0))
    ...     with P.plate("N", data.shape[0]):
    ...         P.sample("obs", dist.Normal(loc, 1.0), obs=data)
    >>> guide = AutoNormal(model)
    >>> svi = SVI(model, guide, optim.Adam(0.1), Trace_ELBO())
    >>> state, losses = svi.run(jax.random.PRNGKey(0), 100, jnp.ones(5))
    >>> sorted(svi.get_params(state))
    ['auto_loc_loc', 'auto_loc_scale']
    >>> bool(losses[-1] < losses[0])
    True
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..core import primitives
from ..core.handlers import block, seed, trace
from ..distributions import (
    Delta,
    Independent,
    LowRankMultivariateNormal,
    Normal,
    TransformedDistribution,
    biject_to,
    constraints,
)
from ..distributions.transforms import (
    InverseAutoregressiveTransform,
    PermuteTransform,
    init_made_params,
    made_masks,
)


def init_to_sample(site_name, value, unconstrained):
    """Initialize a latent at its prototype (prior) sample."""
    return value


def init_to_feasible(site_name, value, unconstrained):
    """Initialize a latent at 0 in unconstrained space (NumPyro-style robust
    default: far prior samples make SVI take thousands of warmup steps)."""
    return jnp.zeros_like(unconstrained)


def init_to_median(site_name, value, unconstrained):  # alias of feasible here
    return jnp.zeros_like(unconstrained)


class AutoGuide:
    """Base: traces the model once (lazily) to discover latent sites."""

    def __init__(self, model: Callable, prefix: str = "auto", init_loc_fn=init_to_feasible):
        self.model = model
        self.prefix = prefix
        self.init_loc_fn = init_loc_fn
        self._prototype: Optional[Dict] = None

    def _setup_prototype(self, *args, **kwargs):
        key = kwargs.pop("_proto_key", jax.random.PRNGKey(0))
        # hide the prototype run from any enclosing handlers (outer trace/seed)
        with block():
            tr = trace(seed(self.model, key)).get_trace(*args, **kwargs)
        proto = {}
        for name, site in tr.nodes.items():
            if site["type"] == "sample" and not site["is_observed"]:
                if getattr(site["fn"], "is_discrete", False):
                    if site["infer"].get("enumerate") == "parallel":
                        # marginalized exactly by TraceEnum_ELBO — not a guide latent
                        continue
                    raise ValueError(
                        f"autoguides require continuous latents; '{name}' is discrete. "
                        "Annotate it with infer={'enumerate': 'parallel'} (or wrap the "
                        "model in config(enumerate=True)) and train with TraceEnum_ELBO "
                        "to marginalize it exactly."
                    )
                t = biject_to(site["fn"].support)
                u0 = t.inv(site["value"])
                init_u = self.init_loc_fn(name, site["value"], u0)
                proto[name] = {
                    "value": t(init_u),
                    "support": site["fn"].support,
                    "event_dim": len(site["fn"].event_shape),
                    "shape": jnp.shape(site["value"]),
                }
        self._prototype = proto
        return proto

    def __call__(self, *args, **kwargs):
        raise NotImplementedError


class AutoDelta(AutoGuide):
    """MAP/MLE guide: a learnable point mass per latent site."""

    def __call__(self, *args, **kwargs):
        proto = self._prototype or self._setup_prototype(*args, **kwargs)
        values = {}
        for name, site in proto.items():
            loc = primitives.param(
                f"{self.prefix}_{name}_loc", site["value"], constraint=site["support"]
            )
            values[name] = primitives.sample(
                name, Delta(loc, event_dim=loc.ndim)
            )
        return values


class AutoNormal(AutoGuide):
    """Mean-field normal in unconstrained space, bijected to each support."""

    def __init__(self, model, prefix="auto", init_scale: float = 0.1, init_loc_fn=init_to_feasible):
        super().__init__(model, prefix, init_loc_fn=init_loc_fn)
        self.init_scale = init_scale

    def __call__(self, *args, **kwargs):
        proto = self._prototype or self._setup_prototype(*args, **kwargs)
        values = {}
        for name, site in proto.items():
            transform = biject_to(site["support"])
            init_u = transform.inv(site["value"])
            loc = primitives.param(f"{self.prefix}_{name}_loc", init_u)
            log_scale = primitives.param(
                f"{self.prefix}_{name}_scale",
                jnp.full(jnp.shape(init_u), jnp.log(self.init_scale)),
            )
            base = Independent(Normal(loc, jnp.exp(log_scale)), jnp.ndim(init_u))
            from ..distributions.transforms import IdentityTransform

            if isinstance(transform, IdentityTransform):
                # keep the bare Normal so analytic KL registry applies
                values[name] = primitives.sample(name, base)
            else:
                values[name] = primitives.sample(
                    name, TransformedDistribution(base, [transform])
                )
        return values

    # posterior access helpers
    def median(self, params: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        proto = self._prototype
        out = {}
        for name, site in proto.items():
            t = biject_to(site["support"])
            out[name] = t(params[f"{self.prefix}_{name}_loc"])
        return out


class AutoLowRankMultivariateNormal(AutoGuide):
    """Joint low-rank MVN over all flattened unconstrained latents."""

    def __init__(self, model, prefix="auto", rank: int = 8, init_scale: float = 0.1, init_loc_fn=init_to_feasible):
        super().__init__(model, prefix, init_loc_fn=init_loc_fn)
        self.rank = rank
        self.init_scale = init_scale

    def __call__(self, *args, **kwargs):
        proto = self._prototype or self._setup_prototype(*args, **kwargs)
        sizes, inits, transforms = {}, {}, {}
        total = 0
        for name, site in proto.items():
            t = biject_to(site["support"])
            u = t.inv(site["value"])
            transforms[name] = t
            inits[name] = u
            sizes[name] = int(jnp.size(u))
            total += sizes[name]
        flat_init = (
            jnp.concatenate([inits[n].reshape(-1) for n in proto]) if total else jnp.zeros(0)
        )
        loc = primitives.param(f"{self.prefix}_loc", flat_init)
        cov_factor = primitives.param(
            f"{self.prefix}_cov_factor", jnp.zeros((total, self.rank))
        )
        cov_diag_raw = primitives.param(
            f"{self.prefix}_cov_diag",
            jnp.full((total,), self.init_scale),
            constraint=constraints.positive,
        )
        joint = LowRankMultivariateNormal(loc, cov_factor, cov_diag_raw)
        flat = primitives.sample("_auto_latent", joint)
        values, offset = {}, 0
        for name, site in proto.items():
            n = sizes[name]
            chunk = flat[..., offset : offset + n].reshape(site["shape"])
            offset += n
            value = transforms[name](chunk)
            values[name] = primitives.sample(
                name, Delta(value, event_dim=len(site["shape"]))
            )
        return values


class AutoIAFNormal(AutoGuide):
    """Normalizing-flow guide: diag-normal base pushed through `num_flows`
    IAF layers with permutations (Kingma et al. 2016; paper Fig. 4)."""

    def __init__(self, model, prefix="auto", num_flows: int = 2, hidden_factor: int = 2, init_loc_fn=init_to_feasible):
        super().__init__(model, prefix, init_loc_fn=init_loc_fn)
        self.num_flows = num_flows
        self.hidden_factor = hidden_factor

    def __call__(self, *args, **kwargs):
        proto = self._prototype or self._setup_prototype(*args, **kwargs)
        sizes, transforms = {}, {}
        total = 0
        for name, site in proto.items():
            t = biject_to(site["support"])
            transforms[name] = t
            sizes[name] = int(jnp.size(site["value"]))
            total += sizes[name]
        if total < 2:
            raise ValueError("AutoIAFNormal needs >= 2 latent dims")
        hidden = [total * self.hidden_factor]
        masks = made_masks(total, hidden)
        loc = primitives.param(f"{self.prefix}_loc", jnp.zeros(total))
        log_scale = primitives.param(f"{self.prefix}_log_scale", jnp.zeros(total))
        parts = []
        for i in range(self.num_flows):
            made_init = init_made_params(jax.random.PRNGKey(17 + i), total, hidden)
            made = {
                k: primitives.param(f"{self.prefix}_iaf{i}_{k}", v)
                for k, v in made_init.items()
            }
            parts.append(InverseAutoregressiveTransform(made, masks))
            if i != self.num_flows - 1:
                parts.append(PermuteTransform(jnp.arange(total)[::-1]))
        base = Independent(Normal(loc, jnp.exp(log_scale)), 1)
        flat = primitives.sample("_auto_latent", TransformedDistribution(base, parts))
        values, offset = {}, 0
        for name, site in proto.items():
            n = sizes[name]
            chunk = flat[..., offset : offset + n].reshape(site["shape"])
            offset += n
            value = transforms[name](chunk)
            values[name] = primitives.sample(name, Delta(value, event_dim=len(site["shape"])))
        return values
