"""Stochastic variational inference driver (paper Fig. 1: `pyro.infer.SVI`).

Functional API designed for pjit: `init` traces model+guide to discover
param sites (storing them *unconstrained*), and `update` is a pure function
(state, rng, batch) -> (state, loss) suitable for jax.jit / pjit with sharded
optimizer state.

Scale path (ROADMAP north star):

* `update_jit` is a single `jax.jit` of `update` created once per SVI —
  `run`, `SVIRunner`, benchmarks and user code all share one compile cache,
  so steady-state steps never re-trace.
* `mesh=` turns on SPMD: optimizer state is placed via the distributed
  sharding rules (replicated where no rule matches), minibatch args are
  constrained onto the data axes, and the ELBO's particle axis is sharded
  across the mesh (see `infer.elbo.vectorize_particles`).
* plate subsample indices can be passed explicitly via `update(...,
  subsample={"plate_name": idx})` — they become traced arguments of the pure
  update signature, so drawing a fresh minibatch each step reuses the same
  compiled executable.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

import copy

from ..core.handlers import collect_params, replay, seed, trace
from ..core.messenger import Messenger
from ..distributions import biject_to, constraints
from ..optim.optimizers import Optimizer
from .elbo import ELBO, Trace_ELBO


class _with_subsample(Messenger):
    """Fix plate subsample indices from a dict, recording which keys bound.
    Only `plate` messages match — a key colliding with a sample/param site
    name cannot corrupt that site."""

    def __init__(self, fn, indices, seen: set):
        self.indices = indices
        self.seen = seen
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "plate" and msg["name"] in self.indices:
            msg["value"] = self.indices[msg["name"]]
            self.seen.add(msg["name"])


def _bind_subsample(model, guide, subsample):
    """Wrap model+guide so their plates read indices from `subsample`;
    returns (model, guide, check) where check() raises on keys that bound no
    plate (typo'd plate names would otherwise silently train on the plate's
    own random indices)."""
    indices = dict(subsample)
    seen: set = set()
    model = _with_subsample(model, indices, seen)
    guide = _with_subsample(guide, indices, seen)

    def check():
        missing = set(indices) - seen
        if missing:
            raise KeyError(
                f"subsample keys {sorted(missing)} match no plate in model or guide"
            )

    return model, guide, check


class SVIState(NamedTuple):
    optim_state: Any
    rng_key: jax.Array
    step: jax.Array


class SVI:
    def __init__(
        self,
        model: Callable,
        guide: Callable,
        optim: Optimizer,
        loss: Optional[ELBO] = None,
        mesh=None,
        shard_args: bool = True,
    ):
        self.model = model
        self.guide = guide
        self.optim = optim
        self.loss = loss or Trace_ELBO()
        self.mesh = mesh
        self.shard_args = shard_args
        if mesh is not None and getattr(self.loss, "mesh", None) is None:
            # shallow-copy so the caller's estimator isn't mutated (it may be
            # shared with another SVI or used standalone under no mesh)
            self.loss = copy.copy(self.loss)
            self.loss.mesh = mesh
        self._constraints: Dict[str, Any] = {}
        # The compile-once entry point: one jit cache shared by run(),
        # SVIRunner and direct callers (same-shape steps never re-trace).
        self.update_jit = jax.jit(self.update)

    @property
    def num_traces(self) -> int:
        """XLA retrace counter (the shared `repro.retrace` contract): how
        many distinct executables back `update_jit`. 1 after any number of
        same-shape steps; growth means the hot loop is recompiling."""
        return self.update_jit._cache_size()

    # -- param discovery -----------------------------------------------------
    def _find_params(self, rng_key, *args, **kwargs) -> Dict[str, Any]:
        """Trace guide then model, collecting `param` sites (guide first, so
        guide-owned params win name clashes, as in Pyro's param store)."""
        key_g, key_m = jax.random.split(rng_key)
        with collect_params() as cp_g:
            with trace() as tr_g:
                seed(self.guide, key_g)(*args, **kwargs)
        with collect_params() as cp_m:
            # replay latents so the model sees guide values (cheap + robust)
            with trace():
                replay(seed(self.model, key_m), tr_g)(*args, **kwargs)
        merged = {**cp_m.params, **cp_g.params}
        self._constraints = {**cp_m.constraints, **cp_g.constraints}
        # store unconstrained
        unconstrained = {}
        for name, value in merged.items():
            c = self._constraints.get(name) or constraints.real
            unconstrained[name] = biject_to(c).inv(value)
        return unconstrained

    def init(self, rng_key, *args, **kwargs) -> SVIState:
        key_init, key_state = jax.random.split(rng_key)
        params = self._find_params(key_init, *args, **kwargs)
        optim_state = self.optim.init(params)
        state = SVIState(optim_state, key_state, jnp.zeros((), jnp.int32))
        # canonicalize leaves (python-float inits stay python/weak-typed up to
        # here) so the first update_jit call traces the same signature as
        # every later one — no step-1 recompile
        def _canon(x):
            x = jnp.asarray(x)
            return jax.lax.convert_element_type(x, x.dtype)

        state = jax.tree.map(_canon, state)
        if self.mesh is not None:
            from ..distributed.sharding import param_shardings

            # rule-matched leaves shard FSDP/TP-style; the rest (guide params,
            # rng, step) replicate — optimizer moments follow their params.
            state = jax.device_put(state, param_shardings(state, self.mesh))
        return state

    # -- pure update (jit/pjit this) ------------------------------------------
    def update(
        self, state: SVIState, *args, subsample: Optional[Dict[str, Any]] = None, **kwargs
    ) -> Tuple[SVIState, jax.Array]:
        rng_key, rng_step = jax.random.split(state.rng_key)
        params = self.optim.get_params(state.optim_state)
        model, guide = self.model, self.guide
        if subsample:
            # plate indices ride the pure signature as traced arrays: a fresh
            # minibatch per step hits the same compiled executable.
            model, guide, check_subsample = _bind_subsample(model, guide, subsample)
        if self.mesh is not None and self.shard_args:
            # heuristic: any array arg whose leading dim divides the DP world
            # size is treated as batched (see sharding.shard_batch); pass
            # shard_args=False when non-batch args would be caught by it
            from ..distributed.sharding import shard_batch

            args, kwargs = shard_batch((args, kwargs), self.mesh)

        def loss_fn(p):
            loss, surrogate = self.loss.loss_with_surrogate(
                rng_step, p, model, guide, *args, **kwargs
            )
            return surrogate, loss

        grads, loss = jax.grad(loss_fn, has_aux=True)(params)
        if subsample:
            check_subsample()  # trace-time: typo'd plate names fail loudly
        optim_state = self.optim.update(grads, state.optim_state)
        new_state = SVIState(optim_state, rng_key, state.step + 1)
        if self.mesh is not None:
            from ..distributed.sharding import param_shardings

            # keep the output state on the same shardings as init() placed it,
            # so state stays distributed and steady-state calls never re-trace
            new_state = jax.tree.map(
                jax.lax.with_sharding_constraint,
                new_state,
                param_shardings(new_state, self.mesh),
            )
        return new_state, loss

    def evaluate(
        self, state: SVIState, *args, subsample: Optional[Dict[str, Any]] = None, **kwargs
    ) -> jax.Array:
        params = self.optim.get_params(state.optim_state)
        model, guide = self.model, self.guide
        if subsample:
            model, guide, check_subsample = _bind_subsample(model, guide, subsample)
        loss = self.loss.loss(state.rng_key, params, model, guide, *args, **kwargs)
        if subsample:
            check_subsample()
        return loss

    # -- params in constrained space -----------------------------------------
    def get_params(self, state: SVIState) -> Dict[str, Any]:
        unconstrained = self.optim.get_params(state.optim_state)
        out = {}
        for name, value in unconstrained.items():
            c = self._constraints.get(name) or constraints.real
            out[name] = biject_to(c)(value)
        return out

    # -- Pyro-style stateful convenience ---------------------------------------
    def run(self, rng_key, num_steps: int, *args, progress: bool = False, **kwargs):
        state = self.init(rng_key, *args, **kwargs)
        losses = []
        for i in range(num_steps):
            state, loss = self.update_jit(state, *args, **kwargs)
            losses.append(loss)
        return state, jnp.stack(losses)


class SVIRunner:
    """Stateful wrapper mirroring the paper's `svi.step(batch)` usage."""

    def __init__(self, svi: SVI, rng_key, *args, **kwargs):
        self.svi = svi
        self.state = svi.init(rng_key, *args, **kwargs)

    def step(self, *args, **kwargs) -> float:
        self.state, loss = self.svi.update_jit(self.state, *args, **kwargs)
        return float(loss)

    @property
    def params(self):
        return self.svi.get_params(self.state)
