"""Stochastic variational inference driver (paper Fig. 1: `pyro.infer.SVI`).

Functional API designed for pjit: `init` traces model+guide to discover
param sites (storing them *unconstrained*), and `update` is a pure function
(state, rng, batch) -> (state, loss) suitable for jax.jit / pjit with sharded
optimizer state. A thin stateful wrapper mirrors Pyro's `svi.step(batch)`.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.handlers import collect_params, seed, substitute, trace
from ..distributions import biject_to, constraints
from ..optim.optimizers import Optimizer
from .elbo import Trace_ELBO
from .util import substitute_params


class SVIState(NamedTuple):
    optim_state: Any
    rng_key: jax.Array
    step: jax.Array


class SVI:
    def __init__(
        self,
        model: Callable,
        guide: Callable,
        optim: Optimizer,
        loss: Optional[Trace_ELBO] = None,
    ):
        self.model = model
        self.guide = guide
        self.optim = optim
        self.loss = loss or Trace_ELBO()
        self._constraints: Dict[str, Any] = {}

    # -- param discovery -----------------------------------------------------
    def _find_params(self, rng_key, *args, **kwargs) -> Dict[str, Any]:
        """Trace guide then model, collecting `param` sites (guide first, so
        guide-owned params win name clashes, as in Pyro's param store)."""
        params: Dict[str, Any] = {}
        key_g, key_m = jax.random.split(rng_key)
        with collect_params() as cp_g:
            with trace() as tr_g:
                seed(self.guide, key_g)(*args, **kwargs)
        with collect_params() as cp_m:
            # replay latents so the model sees guide values (cheap + robust)
            from ..core.handlers import replay

            with trace():
                replay(seed(self.model, key_m), tr_g)(*args, **kwargs)
        merged = {**cp_m.params, **cp_g.params}
        self._constraints = {**cp_m.constraints, **cp_g.constraints}
        # store unconstrained
        unconstrained = {}
        for name, value in merged.items():
            c = self._constraints.get(name) or constraints.real
            unconstrained[name] = biject_to(c).inv(value)
        return unconstrained

    def init(self, rng_key, *args, **kwargs) -> SVIState:
        key_init, key_state = jax.random.split(rng_key)
        params = self._find_params(key_init, *args, **kwargs)
        optim_state = self.optim.init(params)
        return SVIState(optim_state, key_state, jnp.zeros((), jnp.int32))

    # -- pure update (jit/pjit this) ------------------------------------------
    def update(self, state: SVIState, *args, **kwargs) -> Tuple[SVIState, jax.Array]:
        rng_key, rng_step = jax.random.split(state.rng_key)
        params = self.optim.get_params(state.optim_state)

        def loss_fn(p):
            loss, surrogate = self.loss.loss_with_surrogate(
                rng_step, p, self.model, self.guide, *args, **kwargs
            )
            return surrogate, loss

        grads, loss = jax.grad(loss_fn, has_aux=True)(params)
        optim_state = self.optim.update(grads, state.optim_state)
        return SVIState(optim_state, rng_key, state.step + 1), loss

    def evaluate(self, state: SVIState, *args, **kwargs) -> jax.Array:
        params = self.optim.get_params(state.optim_state)
        return self.loss.loss(state.rng_key, params, self.model, self.guide, *args, **kwargs)

    # -- params in constrained space -----------------------------------------
    def get_params(self, state: SVIState) -> Dict[str, Any]:
        unconstrained = self.optim.get_params(state.optim_state)
        out = {}
        for name, value in unconstrained.items():
            c = self._constraints.get(name) or constraints.real
            out[name] = biject_to(c)(value)
        return out

    # -- Pyro-style stateful convenience ---------------------------------------
    def run(self, rng_key, num_steps: int, *args, progress: bool = False, **kwargs):
        state = self.init(rng_key, *args, **kwargs)
        update = jax.jit(lambda s: self.update(s, *args, **kwargs))
        losses = []
        for i in range(num_steps):
            state, loss = update(state)
            losses.append(loss)
        return state, jnp.stack(losses)


class SVIRunner:
    """Stateful wrapper mirroring the paper's `svi.step(batch)` usage."""

    def __init__(self, svi: SVI, rng_key, *args, **kwargs):
        self.svi = svi
        self.state = svi.init(rng_key, *args, **kwargs)
        self._update = jax.jit(svi.update)

    def step(self, *args, **kwargs) -> float:
        self.state, loss = self._update(self.state, *args, **kwargs)
        return float(loss)

    @property
    def params(self):
        return self.svi.get_params(self.state)
