"""Sequential Monte Carlo over the sharded-particle substrate.

The particle filter is a *composition* of the combinators in
`infer.combinators`: the engine's one-step program is
``resample(propose(primitive(step), primitive(proposal)))`` (or the bare
``primitive(step)`` bootstrap filter when no proposal is given), and the
sweep is a `lax.scan` of its population semantics. Particles ride the same
`shard_particles`/``mesh=`` path the multi-particle ELBOs use — on a
1-device mesh the sharded sweep is bit-for-bit the vectorized one.

Model contract (the bootstrap-filter shape Pyro's SMCFilter uses):

    init(xs_0, *args)        -> carry     # t = 0: prior + first observation
    step(carry, xs_t, *args) -> carry     # t >= 1: transition + observation

Both are ordinary repro programs; the returned carry (any array pytree) is
the particle's state. Site names may repeat across time — every step runs
in a fresh trace. Observations enter via ``obs=`` sites (their log-prob is
the incremental weight) or explicit `P.factor` sites.

Marginal likelihood: log Ẑ accumulates ``logsumexp(W) - log N`` at each
resample event (where weights reset) plus a final flush, the standard
adaptive-resampling estimator — unbiased in Ẑ for any ESS threshold.

`NestedVariational` turns the same sweep into an SVI objective (maximize
E[log Ẑ] over proposal parameters — the variational-SMC bound); SMC² needs
no new machinery: keep an inner population in the outer carry and
`P.factor` its per-step evidence increment (see tests/test_smc.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from ..core.messenger import Messenger
from .combinators import (
    Population,
    Program,
    StepAux,
    effective_sample_size,
    primitive,
    propose,
    resample,
)
from .elbo import ELBO


class SMCResult(NamedTuple):
    """One sweep's outcome: the final population, the marginal-likelihood
    estimate, and the per-step history (a `StepAux` stacked over time —
    leading axis T when the init row stacks with the step rows, else T-1
    with ``includes_init=False``)."""

    population: Population
    log_evidence: jax.Array
    history: StepAux
    includes_init: bool


def _build_programs(
    model_init,
    model_step,
    proposal_init,
    proposal_step,
    ess_threshold,
    resample_method,
) -> Tuple[Program, Program]:
    init_prog = (
        propose(primitive(model_init), primitive(proposal_init))
        if proposal_init is not None
        else primitive(model_init)
    )
    inner = (
        propose(primitive(model_step), primitive(proposal_step))
        if proposal_step is not None
        else primitive(model_step)
    )
    step_prog = resample(inner, ess_threshold=ess_threshold, method=resample_method)
    return init_prog, step_prog


def smc_sweep(
    init_prog: Program,
    step_prog: Program,
    rng_key,
    xs,
    params=None,
    args: Tuple = (),
    *,
    num_particles: int,
    mesh=None,
    particle_axis=None,
) -> SMCResult:
    """One full filtering sweep as a pure function (jit/vmap/grad-safe):
    init on ``xs[0]``, then a `lax.scan` of the step program's population
    semantics over ``xs[1:]``. Reused by the `SMC` engine, `SMCFilter`'s
    offline path, and `NestedVariational`'s inner estimate."""
    params = params or {}
    leaves = jax.tree.leaves(xs)
    if not leaves:
        raise ValueError("xs must contain at least one observation array")
    T = leaves[0].shape[0]
    key_init, key_scan = jax.random.split(rng_key)
    xs0 = jax.tree.map(lambda x: x[0], xs)
    pop, aux0 = init_prog.init_population(
        key_init,
        params,
        num_particles,
        (xs0,) + tuple(args),
        mesh=mesh,
        particle_axis=particle_axis,
    )

    def body(carry, inp):
        pop, log_z = carry
        t, xs_t = inp
        k = jax.random.fold_in(key_scan, t)
        pop, aux = step_prog.run_population(
            k,
            params,
            pop,
            (xs_t,) + tuple(args),
            mesh=mesh,
            particle_axis=particle_axis,
        )
        return (pop, log_z + aux.log_z_incr), aux

    ts = jnp.arange(1, T)
    xs_rest = jax.tree.map(lambda x: x[1:], xs)
    (pop, log_z), steps = jax.lax.scan(body, (pop, jnp.float32(0.0)), (ts, xs_rest))
    log_evidence = (
        log_z
        + jax.scipy.special.logsumexp(pop.log_weights)
        - jnp.log(jnp.float32(num_particles))
    )
    try:
        # stack the t=0 row onto the scanned history when the init program
        # produced the same latent structure as the steps (the bootstrap
        # common case); heterogeneous inits keep a step-only history
        history = jax.tree.map(lambda a, h: jnp.concatenate([a[None], h]), aux0, steps)
        includes_init = True
    except (ValueError, TypeError):
        history, includes_init = steps, False
    return SMCResult(pop, log_evidence, history, includes_init)


def _weighted_means(latents, log_weights):
    w = jax.nn.softmax(log_weights, axis=-1)

    def mean(x):
        # weights broadcast over trailing event dims: (..., N) x (..., N, E)
        wx = w.reshape(w.shape + (1,) * (x.ndim - w.ndim)) * x
        return jnp.sum(wx, axis=w.ndim - 1)

    return jax.tree.map(mean, latents)


class SMC:
    """Particle-filter engine over the combinator calculus.

    Parameters
    ----------
    model_init / model_step: the target programs (contract above).
    proposal_init / proposal_step: optional learned/hand-built proposals;
        each step becomes a `propose` instead of bootstrap prior sampling.
    num_particles: population size N.
    ess_threshold: resample when ESS < threshold * N (1.0 = always resample
        on any weight imbalance — equal weights sit exactly at ESS == N and
        never trigger; 0.0 = never resample).
    resample_method: "systematic" (`ops.resample` kernel) or "multinomial";
        default from the `REPRO_SMC_RESAMPLE` knob.
    mesh / particle_axis: shard the particle axis like the ELBOs do.

    The sweep compiles once (`num_traces == 1` across warmup + filtering for
    same-shape observations — the MCMC/SVI retrace contract). Implements the
    `InferenceEngine` protocol: `.run(key, xs, *args)` returns final-step
    latent draws, `.get_samples(group_by_chain=...)` re-reads them, and the
    weighted posterior lives in `.log_weights` / `.filtering_means()`.
    """

    def __init__(
        self,
        model_init: Callable,
        model_step: Callable,
        *,
        proposal_init: Optional[Callable] = None,
        proposal_step: Optional[Callable] = None,
        num_particles: int = 1000,
        ess_threshold: float = 0.5,
        resample_method: Optional[str] = None,
        mesh=None,
        particle_axis=None,
    ):
        if num_particles < 1:
            raise ValueError(f"num_particles must be >= 1, got {num_particles}")
        self.num_particles = num_particles
        self.mesh = mesh
        self.particle_axis = particle_axis
        self._init_prog, self._step_prog = _build_programs(
            model_init, model_step, proposal_init, proposal_step,
            ess_threshold, resample_method,
        )
        self.num_traces = 0
        self._result: Optional[SMCResult] = None

        def _sweep(key, xs, params, args):
            self.num_traces += 1  # trace-time side effect (retrace detector)
            return smc_sweep(
                self._init_prog,
                self._step_prog,
                key,
                xs,
                params,
                args,
                num_particles=self.num_particles,
                mesh=self.mesh,
                particle_axis=self.particle_axis,
            )

        self._exec = jax.jit(_sweep)

    def run(self, rng_key, xs, *args, params=None):
        """Filter the observation sequence ``xs`` (pytree, leading axis T).
        Returns `get_samples()` — final-step latent draws, particle axis
        leading. Extra ``*args`` are forwarded to every program call and
        must be jit-able (arrays / scalars)."""
        self._result = self._exec(rng_key, xs, params or {}, tuple(args))
        return self.get_samples()

    # -- results -------------------------------------------------------------
    @property
    def result(self) -> SMCResult:
        if self._result is None:
            raise RuntimeError("no sweep yet — call .run(rng_key, xs) first")
        return self._result

    @property
    def log_weights(self):
        """Final-population log-weights (pair with `get_samples`)."""
        return self.result.population.log_weights

    def get_samples(self, group_by_chain: bool = False):
        """Final-step latent draws, shaped (N, ...) — or (1, N, ...) with
        ``group_by_chain=True`` (the particle axis as the draw axis of a
        single 'chain', matching MCMC's convention). These are *weighted*
        draws; weight by `log_weights` or resample for unweighted ones."""
        latents = jax.tree.map(lambda x: x[-1], self.result.history.latents)
        if group_by_chain:
            return jax.tree.map(lambda x: x[None], latents)
        return latents

    def log_evidence(self):
        return self.result.log_evidence

    def effective_sample_size(self):
        return effective_sample_size(self.log_weights)

    def filtering_means(self):
        """Per-step posterior filtering means: E[site_t | y_{0..t}] for every
        latent site, weighted by that step's post-reweight weights. Leading
        axis T (or T-1 when the init row could not be stacked)."""
        h = self.result.history
        return _weighted_means(h.latents, h.log_weights)

    def ess_history(self):
        return self.result.history.ess


# ---------------------------------------------------------------------------
# streaming filter (the serve-layer session object)
# ---------------------------------------------------------------------------


class FilterState(NamedTuple):
    population: Population
    log_z: jax.Array
    t: jax.Array
    rng_key: jax.Array


class SMCFilter:
    """Online particle filter: `init_state` once, then one `update` per
    arriving observation — both compiled once, with the filter state an
    explicit device-resident pytree (what a serving session holds between
    requests). ``params`` is a traced argument of both, so a hot-swapped
    checkpoint never recompiles (the serve-layer refresh contract).

    >>> import jax, jax.numpy as jnp
    >>> from repro.core import primitives as P
    >>> from repro import distributions as dist
    >>> def init(y):
    ...     x = P.sample("x", dist.Normal(0.0, 1.0))
    ...     P.sample("y", dist.Normal(x, 0.5), obs=y)
    ...     return {"x": x}
    >>> def step(carry, y):
    ...     x = P.sample("x", dist.Normal(0.9 * carry["x"], 0.3))
    ...     P.sample("y", dist.Normal(x, 0.5), obs=y)
    ...     return {"x": x}
    >>> f = SMCFilter(init, step, num_particles=256)
    >>> state, info = f.init_state(jax.random.PRNGKey(0), jnp.float32(0.4))
    >>> for y in (0.5, 0.1, -0.2):
    ...     state, info = f.update(state, jnp.float32(y))
    >>> int(state.t), f.num_traces  # 4 observations in, one compile
    (4, 1)
    >>> bool(abs(info["means"]["x"]) < 1.0)
    True
    """

    def __init__(
        self,
        model_init: Callable,
        model_step: Callable,
        *,
        proposal_init: Optional[Callable] = None,
        proposal_step: Optional[Callable] = None,
        num_particles: int = 1000,
        ess_threshold: float = 0.5,
        resample_method: Optional[str] = None,
        mesh=None,
        particle_axis=None,
    ):
        self.num_particles = num_particles
        self.mesh = mesh
        self.particle_axis = particle_axis
        self._init_prog, self._step_prog = _build_programs(
            model_init, model_step, proposal_init, proposal_step,
            ess_threshold, resample_method,
        )
        self.num_traces = 0  # update-path retraces (the streaming hot loop)
        self.num_init_traces = 0

        def _init(key, y, params, args):
            self.num_init_traces += 1
            key_step, key0 = jax.random.split(key)
            pop, aux = self._init_prog.init_population(
                key0,
                params,
                self.num_particles,
                (y,) + tuple(args),
                mesh=self.mesh,
                particle_axis=self.particle_axis,
            )
            state = FilterState(pop, jnp.float32(0.0), jnp.int32(1), key_step)
            return state, self._info(state, aux)

        def _update(state, y, params, args):
            self.num_traces += 1
            k = jax.random.fold_in(state.rng_key, state.t)
            pop, aux = self._step_prog.run_population(
                k,
                params,
                state.population,
                (y,) + tuple(args),
                mesh=self.mesh,
                particle_axis=self.particle_axis,
            )
            state = FilterState(
                pop, state.log_z + aux.log_z_incr, state.t + 1, state.rng_key
            )
            return state, self._info(state, aux)

        self._init_exec = jax.jit(_init)
        self._update_exec = jax.jit(_update)

    def _info(self, state: FilterState, aux: StepAux) -> dict:
        lw = state.population.log_weights
        return {
            "means": _weighted_means(aux.latents, aux.log_weights),
            "ess": aux.ess,
            "resampled": aux.resampled,
            "log_evidence": state.log_z
            + jax.scipy.special.logsumexp(lw)
            - jnp.log(jnp.float32(self.num_particles)),
        }

    def init_state(self, rng_key, y0, *args, params=None):
        return self._init_exec(rng_key, y0, params or {}, tuple(args))

    def update(self, state: FilterState, y, *args, params=None):
        """Advance one observation: (state, y) -> (state', info) with info =
        {means, ess, resampled, log_evidence}."""
        return self._update_exec(state, y, params or {}, tuple(args))


# ---------------------------------------------------------------------------
# nested variational objective (learned proposals)
# ---------------------------------------------------------------------------


class _scope(Messenger):
    """Prefix sample-site names (params untouched) — lets `sequential_pair`
    run init and step in one trace without site-name collisions."""

    def __init__(self, fn, prefix: str):
        self.prefix = prefix
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "sample":
            msg["name"] = self.prefix + msg["name"]


def sequential_pair(init: Callable, step: Callable) -> Callable:
    """Fuse an (init, step) pair into one plain repro program running t=0
    and t=1 with scoped site names. `SVI` traces it to discover `P.param`
    sites (`NestedVariational` itself runs the real sweep from the pair it
    was constructed with); also handy for prior simulation smoke checks."""

    def fn(xs, *args, **kwargs):
        leaves = jax.tree.leaves(xs)
        T = leaves[0].shape[0] if leaves else 1
        carry = _scope(init, "t0/")(
            jax.tree.map(lambda x: x[0], xs), *args, **kwargs
        )
        if T > 1:
            carry = _scope(step, "t1/")(
                carry, jax.tree.map(lambda x: x[1], xs), *args, **kwargs
            )
        return carry

    return fn


class NestedVariational(ELBO):
    """Variational SMC: the loss is ``-E[log Ẑ]`` where Ẑ is an inner
    ``num_inner``-particle sweep with the learned proposals — a lower bound
    on log Z that tightens as the proposals approach the locally optimal
    ones (Naesseth et al.; the nested-variational composition of Stites &
    Zimmermann §4). Reuses the shared `ELBO` engine: ``num_particles``
    outer replications ride `vectorize_particles`/``mesh=``, and SVI's
    compile-once `update_jit` keeps ``num_traces == 1``.

    Construct with the target/proposal pairs; give `SVI` the fused
    `sequential_pair` programs (param discovery only):

        loss = NestedVariational(init, step, proposal_init=pi, proposal_step=ps)
        svi = SVI(sequential_pair(init, step), sequential_pair(pi, ps), optim, loss)
        state = svi.init(key, xs)        # xs: (T, ...) observations

    Gradients flow through reparameterized proposal draws; ancestor
    selection is zero-derivative by `ops.resample`'s custom VJP (the
    standard biased-resampling VSMC gradient). Score-function terms for
    non-reparameterizable proposal sites are not added — use reparameterized
    proposals."""

    def __init__(
        self,
        model_init: Callable,
        model_step: Callable,
        *,
        proposal_init: Optional[Callable] = None,
        proposal_step: Optional[Callable] = None,
        num_inner: int = 8,
        ess_threshold: float = 0.5,
        resample_method: Optional[str] = None,
        num_particles: int = 1,
        mesh=None,
        particle_axis=None,
    ):
        super().__init__(num_particles, mesh=mesh, particle_axis=particle_axis)
        if num_inner < 1:
            raise ValueError(f"num_inner must be >= 1, got {num_inner}")
        self.num_inner = num_inner
        self._init_prog, self._step_prog = _build_programs(
            model_init, model_step, proposal_init, proposal_step,
            ess_threshold, resample_method,
        )

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        # model/guide are SVI's discovery programs; the sweep runs the
        # combinator programs this loss was constructed with
        del model, guide, kwargs
        xs, extra = args[0], tuple(args[1:])
        result = smc_sweep(
            self._init_prog,
            self._step_prog,
            rng_key,
            xs,
            params,
            extra,
            num_particles=self.num_inner,
        )
        return result.log_evidence, result.log_evidence
