"""Hamiltonian Monte Carlo + No-U-Turn Sampler (paper §2: "Pyro implements
several generic probabilistic inference algorithms, including the No U-turn
Sampler ... a variant of Hamiltonian Monte Carlo").

Fully jittable: leapfrog, Welford diagonal mass adaptation, and dual-averaging
step size run inside `lax` control flow. NUTS uses iterative progressive
doubling with multinomial sampling along the trajectory and a subtree U-turn
check at each doubling (Hoffman & Gelman 2014; iterative form after Phan et
al. 2019).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .util import get_model_transforms, init_to_uniform, potential_energy, transform_fn

# ---------------------------------------------------------------------------
# pytree-of-arrays helpers
# ---------------------------------------------------------------------------


def _tree_dot(a, b):
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.sum(x * y) for x, y in zip(leaves_a, leaves_b))


def _tree_axpy(alpha, x, y):
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def _tree_scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


# ---------------------------------------------------------------------------
# Dual averaging + Welford variance (mass matrix) adaptation
# ---------------------------------------------------------------------------


class DAState(NamedTuple):
    log_step: jax.Array
    log_step_avg: jax.Array
    h_avg: jax.Array
    mu: jax.Array
    t: jax.Array


def da_init(step_size: float) -> DAState:
    return DAState(
        jnp.log(step_size),
        jnp.log(step_size),
        jnp.zeros(()),
        jnp.log(10.0 * step_size),
        jnp.zeros(()),
    )


def da_update(state: DAState, accept_prob: jax.Array, target: float = 0.8) -> DAState:
    t = state.t + 1
    kappa, gamma, t0 = 0.75, 0.05, 10.0
    h = (1 - 1 / (t + t0)) * state.h_avg + (target - accept_prob) / (t + t0)
    log_step = state.mu - jnp.sqrt(t) / gamma * h
    eta = t ** (-kappa)
    log_avg = eta * log_step + (1 - eta) * state.log_step_avg
    return DAState(log_step, log_avg, h, state.mu, t)


class WelfordState(NamedTuple):
    mean: Any
    m2: Any
    n: jax.Array


def welford_init(proto) -> WelfordState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, proto)
    return WelfordState(zeros, zeros, jnp.zeros(()))


def welford_update(state: WelfordState, sample) -> WelfordState:
    n = state.n + 1
    delta = jax.tree_util.tree_map(lambda s, m: s - m, sample, state.mean)
    mean = jax.tree_util.tree_map(lambda m, d: m + d / n, state.mean, delta)
    delta2 = jax.tree_util.tree_map(lambda s, m: s - m, sample, mean)
    m2 = jax.tree_util.tree_map(lambda a, d, d2: a + d * d2, state.m2, delta, delta2)
    return WelfordState(mean, m2, n)


def welford_variance(state: WelfordState, regularize: bool = True):
    def var(m2):
        v = m2 / jnp.maximum(state.n - 1, 1)
        if regularize:  # Stan's shrinkage toward unit
            v = (state.n / (state.n + 5.0)) * v + 1e-3 * (5.0 / (state.n + 5.0))
        return v

    return jax.tree_util.tree_map(var, state.m2)


# ---------------------------------------------------------------------------
# Leapfrog
# ---------------------------------------------------------------------------


def leapfrog(potential_fn, z, r, inv_mass, step_size, n_steps):
    grad_fn = jax.grad(potential_fn)

    def body(carry, _):
        z, r = carry
        r = _tree_axpy(-0.5 * step_size, grad_fn(z), r)
        z = jax.tree_util.tree_map(lambda zi, ri, mi: zi + step_size * mi * ri, z, r, inv_mass)
        r = _tree_axpy(-0.5 * step_size, grad_fn(z), r)
        return (z, r), None

    (z, r), _ = jax.lax.scan(body, (z, r), None, length=n_steps)
    return z, r


def _kinetic(r, inv_mass):
    return 0.5 * sum(
        jnp.sum(m * jnp.square(ri))
        for ri, m in zip(jax.tree_util.tree_leaves(r), jax.tree_util.tree_leaves(inv_mass))
    )


def _sample_momentum(key, proto, inv_mass):
    leaves, treedef = jax.tree_util.tree_flatten(proto)
    keys = jax.random.split(key, len(leaves))
    inv_leaves = treedef.flatten_up_to(inv_mass)
    rs = [
        jax.random.normal(k, x.shape, jnp.float32) / jnp.sqrt(jnp.clip(m, 1e-10))
        for k, x, m in zip(keys, leaves, inv_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, rs)


# ---------------------------------------------------------------------------
# HMC
# ---------------------------------------------------------------------------


class HMCState(NamedTuple):
    z: Any
    potential: jax.Array
    rng_key: jax.Array
    step_size: jax.Array
    inv_mass: Any
    da: DAState
    welford: Any
    i: jax.Array
    accept_prob: jax.Array
    num_steps: jax.Array  # leapfrog steps taken (diagnostics)


class HMC:
    def __init__(
        self,
        model: Optional[Callable] = None,
        potential_fn: Optional[Callable] = None,
        step_size: float = 0.1,
        trajectory_length: float = 2 * math.pi,
        adapt_step_size: bool = True,
        adapt_mass_matrix: bool = True,
        target_accept_prob: float = 0.8,
        max_tree_depth: int = 10,
        max_num_steps: int = 1024,
    ):
        if (model is None) == (potential_fn is None):
            raise ValueError("pass exactly one of model / potential_fn")
        self.model = model
        self._potential_fn = potential_fn
        self.step_size = step_size
        self.trajectory_length = trajectory_length
        self.adapt_step_size = adapt_step_size
        self.adapt_mass_matrix = adapt_mass_matrix
        self.target_accept = target_accept_prob
        self.max_tree_depth = max_tree_depth
        self.max_num_steps = max_num_steps
        self._transforms = None

    # -- setup ---------------------------------------------------------------
    def _setup(self, rng_key, *args, **kwargs):
        if self._potential_fn is not None:
            return self._potential_fn, kwargs.pop("init_params")
        transforms, inits, _ = get_model_transforms(rng_key, self.model, args, kwargs)
        self._transforms = transforms
        pe = partial(potential_energy, self.model, args, kwargs, transforms)
        init = init_to_uniform(rng_key, inits)
        return pe, init

    def init(self, rng_key, *args, **kwargs) -> Tuple[HMCState, Callable]:
        key_setup, key_state = jax.random.split(rng_key)
        pe_fn, z0 = self._setup(key_setup, *args, **kwargs)
        z0 = jax.tree_util.tree_map(lambda x: jnp.asarray(x, jnp.float32), z0)
        inv_mass = jax.tree_util.tree_map(jnp.ones_like, z0)
        state = HMCState(
            z0,
            pe_fn(z0),
            key_state,
            jnp.asarray(self.step_size, jnp.float32),
            inv_mass,
            da_init(self.step_size),
            welford_init(z0),
            jnp.zeros((), jnp.int32),
            jnp.zeros(()),
            jnp.zeros((), jnp.int32),
        )
        return state, pe_fn

    # -- one transition (jittable) --------------------------------------------
    def sample_step(self, state: HMCState, pe_fn, warmup_len: int = 0) -> HMCState:
        key, key_mom, key_accept = jax.random.split(state.rng_key, 3)
        r = _sample_momentum(key_mom, state.z, state.inv_mass)
        energy0 = state.potential + _kinetic(r, state.inv_mass)
        n_steps = jnp.clip(
            (self.trajectory_length / state.step_size).astype(jnp.int32), 1, self.max_num_steps
        )
        # fixed upper bound for scan; mask extra steps
        max_steps = self.max_num_steps

        grad_fn = jax.grad(pe_fn)

        def body(carry, i):
            z, r = carry
            do = i < n_steps

            def step(zr):
                z, r = zr
                r = _tree_axpy(-0.5 * state.step_size, grad_fn(z), r)
                z = jax.tree_util.tree_map(
                    lambda zi, ri, mi: zi + state.step_size * mi * ri, z, r, state.inv_mass
                )
                r = _tree_axpy(-0.5 * state.step_size, grad_fn(z), r)
                return z, r

            z, r = jax.lax.cond(do, step, lambda zr: zr, (z, r))
            return (z, r), None

        (z_new, r_new), _ = jax.lax.scan(body, (state.z, r), jnp.arange(max_steps))
        pe_new = pe_fn(z_new)
        energy1 = pe_new + _kinetic(r_new, state.inv_mass)
        delta = energy0 - energy1
        delta = jnp.where(jnp.isnan(delta), -jnp.inf, delta)
        accept_prob = jnp.minimum(1.0, jnp.exp(delta))
        accept = jax.random.uniform(key_accept) < accept_prob
        z = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), z_new, state.z
        )
        potential = jnp.where(accept, pe_new, state.potential)
        # adaptation (only effective during warmup; caller freezes after)
        da = da_update(state.da, accept_prob, self.target_accept) if self.adapt_step_size else state.da
        in_warmup = state.i < warmup_len
        step_size = jnp.where(
            in_warmup & self.adapt_step_size, jnp.exp(da.log_step), jnp.exp(da.log_step_avg)
        ) if self.adapt_step_size else state.step_size
        welford = welford_update(state.welford, z) if self.adapt_mass_matrix else state.welford
        return HMCState(
            z, potential, key, step_size, state.inv_mass, da, welford,
            state.i + 1, accept_prob, n_steps,
        )

    def finalize_warmup(self, state: HMCState) -> HMCState:
        if self.adapt_mass_matrix:
            inv_mass = welford_variance(state.welford)
        else:
            inv_mass = state.inv_mass
        step_size = jnp.exp(state.da.log_step_avg) if self.adapt_step_size else state.step_size
        return state._replace(inv_mass=inv_mass, step_size=step_size)


# ---------------------------------------------------------------------------
# NUTS: iterative progressive doubling with multinomial trajectory sampling
# ---------------------------------------------------------------------------


class _TreeState(NamedTuple):
    z_left: Any
    r_left: Any
    z_right: Any
    r_right: Any
    z_proposal: Any
    pe_proposal: jax.Array
    log_weight: jax.Array  # log sum of exp(-energy) over trajectory
    turning: jax.Array
    diverging: jax.Array
    sum_accept: jax.Array
    n_leapfrog: jax.Array


class NUTS(HMC):
    """No-U-Turn sampler. At each doubling j we extend the trajectory by 2^j
    leapfrog steps in a random direction, multinomially sampling a proposal
    within the new subtree (progressive sampling), and stop on a U-turn
    between trajectory endpoints or on divergence."""

    def sample_step(self, state: HMCState, pe_fn, warmup_len: int = 0) -> HMCState:
        key, key_mom, key_dirs, key_accept = jax.random.split(state.rng_key, 4)
        r0 = _sample_momentum(key_mom, state.z, state.inv_mass)
        energy0 = state.potential + _kinetic(r0, state.inv_mass)
        grad_fn = jax.grad(pe_fn)
        step_size = state.step_size
        inv_mass = state.inv_mass
        max_delta = 1000.0

        def one_leapfrog(z, r, direction):
            eps = step_size * direction
            r = _tree_axpy(-0.5 * eps, grad_fn(z), r)
            z = jax.tree_util.tree_map(lambda zi, ri, mi: zi + eps * mi * ri, z, r, inv_mass)
            r = _tree_axpy(-0.5 * eps, grad_fn(z), r)
            return z, r

        def is_turning(z_left, r_left, z_right, r_right):
            dz = jax.tree_util.tree_map(lambda a, b: a - b, z_right, z_left)
            v_left = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_left)
            v_right = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_right)
            return (_tree_dot(dz, v_left) < 0) | (_tree_dot(dz, v_right) < 0)

        def extend_subtree(carry_key, tree: _TreeState, depth_j, direction):
            """Take 2^depth_j leapfrog steps from the chosen end, doing
            progressive multinomial proposal updates step-by-step."""
            n_steps = 2 ** depth_j

            def body(carry, i):
                key, z_end, r_end, z_prop, pe_prop, log_w, turning, diverging, sum_acc, z_sub_first, r_sub_first, started = carry
                do = (i < n_steps) & ~turning & ~diverging

                def step(args):
                    (key, z_end, r_end, z_prop, pe_prop, log_w, turning, diverging,
                     sum_acc, z_first, r_first, started) = args
                    z_new, r_new = one_leapfrog(z_end, r_end, direction)
                    pe_new = pe_fn(z_new)
                    energy_new = pe_new + _kinetic(r_new, inv_mass)
                    delta = energy_new - energy0
                    delta = jnp.where(jnp.isnan(delta), jnp.inf, delta)
                    diverging2 = delta > max_delta
                    log_w_new = -delta  # weight relative to initial energy
                    log_w2 = jnp.logaddexp(log_w, log_w_new)
                    key, key_u = jax.random.split(key)
                    take = jax.random.uniform(key_u) < jnp.exp(log_w_new - log_w2)
                    z_prop2 = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(take, a, b), z_new, z_prop
                    )
                    pe_prop2 = jnp.where(take, pe_new, pe_prop)
                    sum_acc2 = sum_acc + jnp.minimum(1.0, jnp.exp(-delta))
                    # record subtree start for the U-turn check
                    z_first2 = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(started, a, b), z_first, z_new
                    )
                    r_first2 = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(started, a, b), r_first, r_new
                    )
                    # direction-normalized U-turn check: dz always points
                    # "forward" along the trajectory regardless of direction
                    dz = jax.tree_util.tree_map(
                        lambda a, b: direction * (a - b), z_new, z_first2
                    )
                    v_first = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_first2)
                    v_new = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_new)
                    turning2 = (
                        (_tree_dot(dz, v_first) < 0) | (_tree_dot(dz, v_new) < 0)
                    ) & started  # need at least 2 pts
                    return (key, z_new, r_new, z_prop2, pe_prop2, log_w2, turning2,
                            diverging2, sum_acc2, z_first2, r_first2, jnp.asarray(True))

                carry2 = jax.lax.cond(do, step, lambda a: a,
                                      (key, z_end, r_end, z_prop, pe_prop, log_w, turning,
                                       diverging, sum_acc, z_sub_first, r_sub_first, started))
                return carry2, None

            z_end = jax.lax.cond(direction > 0, lambda: tree.z_right, lambda: tree.z_left)
            r_end = jax.lax.cond(direction > 0, lambda: tree.r_right, lambda: tree.r_left)
            init = (carry_key, z_end, r_end, tree.z_proposal, tree.pe_proposal,
                    -jnp.inf, jnp.asarray(False), jnp.asarray(False), jnp.zeros(()),
                    z_end, r_end, jnp.asarray(False))
            out, _ = jax.lax.scan(body, init, jnp.arange(2 ** self.max_tree_depth))
            (key, z_end, r_end, z_prop, pe_prop, log_w_sub, turning, diverging,
             sum_acc, _, _, _) = out
            return key, z_end, r_end, z_prop, pe_prop, log_w_sub, turning, diverging, sum_acc

        # -- progressive doubling loop (unrolled over max_tree_depth) -------
        tree = _TreeState(
            state.z, r0, state.z, r0, state.z, state.potential,
            jnp.zeros(()),  # initial point has weight exp(0)
            jnp.asarray(False), jnp.asarray(False), jnp.zeros(()), jnp.zeros((), jnp.int32),
        )
        key_loop = key_dirs
        for j in range(self.max_tree_depth):
            key_loop, key_dir, key_swap = jax.random.split(key_loop, 3)
            direction = jnp.where(jax.random.bernoulli(key_dir), 1.0, -1.0)
            stop = tree.turning | tree.diverging
            (key_loop, z_end, r_end, z_prop_sub, pe_prop_sub, log_w_sub, turning_sub,
             diverging_sub, sum_acc) = extend_subtree(key_loop, tree, j, direction)
            # biased progressive sampling between old tree and new subtree
            total = jnp.logaddexp(tree.log_weight, log_w_sub)
            take_new = (jax.random.uniform(key_swap) < jnp.exp(log_w_sub - total)) & ~turning_sub & ~diverging_sub
            z_proposal = jax.tree_util.tree_map(
                lambda a, b: jnp.where(take_new & ~stop, a, b), z_prop_sub, tree.z_proposal
            )
            pe_proposal = jnp.where(take_new & ~stop, pe_prop_sub, tree.pe_proposal)
            z_left = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction < 0) & ~stop, new, old), z_end, tree.z_left
            )
            r_left = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction < 0) & ~stop, new, old), r_end, tree.r_left
            )
            z_right = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction > 0) & ~stop, new, old), z_end, tree.z_right
            )
            r_right = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction > 0) & ~stop, new, old), r_end, tree.r_right
            )
            turning_full = is_turning(z_left, r_left, z_right, r_right)
            tree = _TreeState(
                z_left, r_left, z_right, r_right, z_proposal, pe_proposal,
                jnp.where(stop, tree.log_weight, total),
                tree.turning | turning_sub | turning_full,
                tree.diverging | diverging_sub,
                tree.sum_accept + jnp.where(stop, 0.0, sum_acc),
                tree.n_leapfrog + jnp.where(stop, 0, 2 ** j),
            )

        accept_prob = tree.sum_accept / jnp.maximum(tree.n_leapfrog, 1)
        da = da_update(state.da, accept_prob, self.target_accept) if self.adapt_step_size else state.da
        in_warmup = state.i < warmup_len
        step_size = jnp.where(
            in_warmup & self.adapt_step_size, jnp.exp(da.log_step), jnp.exp(da.log_step_avg)
        ) if self.adapt_step_size else state.step_size
        welford = welford_update(state.welford, tree.z_proposal) if self.adapt_mass_matrix else state.welford
        return HMCState(
            tree.z_proposal, tree.pe_proposal, key, step_size, state.inv_mass, da,
            welford, state.i + 1, accept_prob, tree.n_leapfrog,
        )


# ---------------------------------------------------------------------------
# MCMC driver
# ---------------------------------------------------------------------------


class MCMC:
    def __init__(self, kernel: HMC, num_warmup: int, num_samples: int, thinning: int = 1):
        self.kernel = kernel
        self.num_warmup = num_warmup
        self.num_samples = num_samples
        self.thinning = thinning
        self._samples = None

    def run(self, rng_key, *args, **kwargs):
        state, pe_fn = self.kernel.init(rng_key, *args, **kwargs)
        warmup_len = self.num_warmup

        step = jax.jit(partial(self.kernel.sample_step, pe_fn=pe_fn, warmup_len=warmup_len))

        # mass-matrix adaptation windows: re-estimate twice during warmup
        win = max(1, warmup_len // 2)
        for i in range(warmup_len):
            state = step(state)
            if self.kernel.adapt_mass_matrix and (i + 1) % win == 0:
                state = state._replace(
                    inv_mass=welford_variance(state.welford),
                    welford=welford_init(state.z),
                )
        state = self.kernel.finalize_warmup(state)

        collected = []
        for i in range(self.num_samples * self.thinning):
            state = step(state)
            if i % self.thinning == 0:
                collected.append(state.z)
        self._samples = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *collected)
        # constrain if we built from a model
        if self.kernel._transforms is not None:
            self._samples = transform_fn(self.kernel._transforms, self._samples)
        return self._samples

    def get_samples(self):
        return self._samples
