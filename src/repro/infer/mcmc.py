"""Hamiltonian Monte Carlo + No-U-Turn Sampler with a multi-chain driver
(paper §2: Pyro "implement[s] several generic probabilistic inference
algorithms, including ... the No U-turn Sampler, a variant of Hamiltonian
Monte Carlo"; §1 positions inference as "scalable": built on GPU-accelerated
tensor math, which here means the whole run compiles to a constant number of
XLA calls).

Kernels are fully jittable: leapfrog, Welford diagonal mass adaptation, and
dual-averaging step size run inside `lax` control flow. NUTS uses iterative
progressive doubling with multinomial sampling along the trajectory and a
subtree U-turn check at each doubling (Hoffman & Gelman 2014; iterative form
after Phan et al. 2019). Step-size and mass-matrix adaptation freeze once
`state.i` passes the warmup length, so collection draws come from a fixed
transition kernel.

The `MCMC` driver runs `num_chains` chains initialized from split PRNG keys.
Warmup (with windowed mass-matrix re-estimation) and collection each run
inside a single `lax.scan`, so one `MCMC.run` issues a constant number of
compiled calls regardless of `num_warmup`/`num_samples`
(`benchmarks/mcmc_chains.py` asserts this). Passing `mesh=` (a Mesh, or
``"auto"`` for the default 1-D device mesh) additionally constrains the
chain axis onto the mesh's data axes via `distributed.sharding.shard_chains`,
which is a no-op transformation of the math — on a 1-device mesh the output
is bit-for-bit identical to the local-vmap default (`mesh=None`). The legacy
`chain_method="vectorized"/"sharded"` spelling survives as a FutureWarning
alias.

Two interiors implement that contract. The default **fused** driver ravels
all chains into one (num_chains, D) matrix and steps them together through
the backend-dispatched `ops.leapfrog` kernel — a shared-gradient integrator
costing n + 1 potential gradients per trajectory (not the textbook 2n) and
only the steps actually taken (not the `max_num_steps` cap). Adaptation is
pooled across chains: one dual-averaged step size from the mean accept
probability, one diagonal mass matrix from a cross-chain Welford
accumulator, and (`HMC(adapt_trajectory_length=True)`) one ChEES-adapted
trajectory length (see `infer/chees.py`). NUTS builds its trees batched:
iterative doubling with per-chain active masks, no per-chain control flow.
The **legacy** per-chain vmap sampler — `REPRO_MCMC_FUSED=0` or
`MCMC(..., fused=False)` — is retained as the benchmark baseline;
`benchmarks/mcmc_bench.py` holds fused to >= 2x its draws/sec at 1024
chains, and `tests/test_mcmc_conformance.py` pins the fused distribution
against closed-form targets under both kernel backends.

Example — two HMC chains on a conjugate model, grouped samples::

    >>> import jax, jax.numpy as jnp
    >>> from repro import distributions as dist
    >>> from repro.core import primitives as P
    >>> from repro.infer import HMC, MCMC
    >>> def model(data):
    ...     loc = P.sample("loc", dist.Normal(0.0, 10.0))
    ...     with P.plate("N", data.shape[0]):
    ...         P.sample("obs", dist.Normal(loc, 1.0), obs=data)
    >>> data = jnp.asarray([1.0, 2.0, 3.0])
    >>> mcmc = MCMC(HMC(model, max_num_steps=16), num_warmup=100,
    ...             num_samples=100, num_chains=2)
    >>> samples = mcmc.run(jax.random.PRNGKey(0), data)
    >>> samples["loc"].shape            # chains flattened by default
    (200,)
    >>> mcmc.get_samples(group_by_chain=True)["loc"].shape
    (2, 100)
    >>> bool(mcmc.get_extra_fields()["diverging"].sum() >= 0)
    True
"""
from __future__ import annotations

import math
import warnings
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from .. import settings
from ..kernels import ops
from .chees import ChEESState, chees_init, chees_update, halton_jitter
from .util import init_to_uniform, initialize_model, potential_energy, transform_fn

# ---------------------------------------------------------------------------
# pytree-of-arrays helpers
# ---------------------------------------------------------------------------


def _tree_dot(a, b):
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.sum(x * y) for x, y in zip(leaves_a, leaves_b))


def _tree_axpy(alpha, x, y):
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def _tree_scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def _tree_where(pred, a, b):
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


# ---------------------------------------------------------------------------
# Dual averaging + Welford variance (mass matrix) adaptation
# ---------------------------------------------------------------------------


class DAState(NamedTuple):
    log_step: jax.Array
    log_step_avg: jax.Array
    h_avg: jax.Array
    mu: jax.Array
    t: jax.Array


def da_init(step_size: float) -> DAState:
    return DAState(
        jnp.log(step_size),
        jnp.log(step_size),
        jnp.zeros(()),
        jnp.log(10.0 * step_size),
        jnp.zeros(()),
    )


def da_update(state: DAState, accept_prob: jax.Array, target: float = 0.8) -> DAState:
    t = state.t + 1
    kappa, gamma, t0 = 0.75, 0.05, 10.0
    h = (1 - 1 / (t + t0)) * state.h_avg + (target - accept_prob) / (t + t0)
    log_step = state.mu - jnp.sqrt(t) / gamma * h
    eta = t ** (-kappa)
    log_avg = eta * log_step + (1 - eta) * state.log_step_avg
    return DAState(log_step, log_avg, h, state.mu, t)


class WelfordState(NamedTuple):
    mean: Any
    m2: Any
    n: jax.Array


def welford_init(proto) -> WelfordState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, proto)
    return WelfordState(zeros, zeros, jnp.zeros(()))


def welford_update(state: WelfordState, sample) -> WelfordState:
    n = state.n + 1
    delta = jax.tree_util.tree_map(lambda s, m: s - m, sample, state.mean)
    mean = jax.tree_util.tree_map(lambda m, d: m + d / n, state.mean, delta)
    delta2 = jax.tree_util.tree_map(lambda s, m: s - m, sample, mean)
    m2 = jax.tree_util.tree_map(lambda a, d, d2: a + d * d2, state.m2, delta, delta2)
    return WelfordState(mean, m2, n)


def welford_variance(state: WelfordState, regularize: bool = True):
    def var(m2):
        v = m2 / jnp.maximum(state.n - 1, 1)
        if regularize:  # Stan's shrinkage toward unit
            v = (state.n / (state.n + 5.0)) * v + 1e-3 * (5.0 / (state.n + 5.0))
        return v

    return jax.tree_util.tree_map(var, state.m2)


def welford_update_batch(mean, m2, n, x):
    """Fold a whole (C, D) batch into a pooled (D,)-per-dim Welford
    accumulator in one shot (Chan et al.'s parallel combine) — the fused
    driver feeds all chains' draws to ONE cross-chain mass-matrix estimate
    per transition instead of C independent ones."""
    c = x.shape[0]
    bmean = jnp.mean(x, axis=0)
    bm2 = jnp.sum(jnp.square(x - bmean), axis=0)
    delta = bmean - mean
    tot = n + c
    mean_new = mean + delta * (c / tot)
    m2_new = m2 + bm2 + jnp.square(delta) * (n * c / tot)
    return mean_new, m2_new, tot


def pooled_variance(m2, n, regularize: bool = True):
    """Variance of a pooled accumulator, with Stan's shrinkage toward unit
    (same regularizer as `welford_variance`, n counted across chains)."""
    v = m2 / jnp.maximum(n - 1.0, 1.0)
    if regularize:
        v = (n / (n + 5.0)) * v + 1e-3 * (5.0 / (n + 5.0))
    return v


# ---------------------------------------------------------------------------
# Leapfrog
# ---------------------------------------------------------------------------


def leapfrog(potential_fn, z, r, inv_mass, step_size, n_steps):
    grad_fn = jax.grad(potential_fn)

    def body(carry, _):
        z, r = carry
        r = _tree_axpy(-0.5 * step_size, grad_fn(z), r)
        z = jax.tree_util.tree_map(lambda zi, ri, mi: zi + step_size * mi * ri, z, r, inv_mass)
        r = _tree_axpy(-0.5 * step_size, grad_fn(z), r)
        return (z, r), None

    (z, r), _ = jax.lax.scan(body, (z, r), None, length=n_steps)
    return z, r


def _kinetic(r, inv_mass):
    return 0.5 * sum(
        jnp.sum(m * jnp.square(ri))
        for ri, m in zip(jax.tree_util.tree_leaves(r), jax.tree_util.tree_leaves(inv_mass))
    )


def _sample_momentum(key, proto, inv_mass):
    leaves, treedef = jax.tree_util.tree_flatten(proto)
    keys = jax.random.split(key, len(leaves))
    inv_leaves = treedef.flatten_up_to(inv_mass)
    rs = [
        jax.random.normal(k, x.shape, jnp.float32) / jnp.sqrt(jnp.clip(m, 1e-10))
        for k, x, m in zip(keys, leaves, inv_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, rs)


# ---------------------------------------------------------------------------
# HMC
# ---------------------------------------------------------------------------


class HMCState(NamedTuple):
    z: Any
    potential: jax.Array
    rng_key: jax.Array
    step_size: jax.Array
    inv_mass: Any
    da: DAState
    welford: Any
    i: jax.Array
    accept_prob: jax.Array
    num_steps: jax.Array  # leapfrog steps taken (diagnostics)
    diverging: jax.Array  # this transition hit an energy error > threshold


class FlatHMCState(NamedTuple):
    """State of the fused batched driver: ALL chains in one struct, positions
    raveled to a (C, D) matrix so the hot loop is dense batched linear
    algebra (and `ops.leapfrog` kernel calls) instead of a vmap of pytree
    traversals. Adaptation state is cross-chain: one step size, one diagonal
    mass matrix, one pooled Welford accumulator, one ChEES trajectory length
    — shared by every chain, which is what lets thousands of short chains
    warm up from each other's statistics."""

    z: jax.Array            # (C, D) unconstrained positions
    potential: jax.Array    # (C,)
    rng_key: jax.Array      # single PRNG key; per-step keys fold in `i`
    step_size: jax.Array    # () shared across chains
    inv_mass: jax.Array     # (D,) shared diagonal inverse mass
    da: DAState             # shared dual-averaging state (scalars)
    wf_mean: jax.Array      # (D,) pooled Welford mean
    wf_m2: jax.Array        # (D,) pooled Welford sum of squared deviations
    wf_n: jax.Array         # () pooled sample count (counts chain-draws)
    chees: ChEESState       # shared trajectory-length adaptation (scalars)
    i: jax.Array            # () transition counter
    accept_prob: jax.Array  # (C,) last accept probabilities
    num_steps: jax.Array    # (C,) int32 leapfrog steps (diagnostics)
    diverging: jax.Array    # (C,) bool divergence flags


class HMC:
    def __init__(
        self,
        model: Optional[Callable] = None,
        potential_fn: Optional[Callable] = None,
        step_size: float = 0.1,
        trajectory_length: float = 2 * math.pi,
        adapt_step_size: bool = True,
        adapt_mass_matrix: bool = True,
        target_accept_prob: float = 0.8,
        max_tree_depth: int = 10,
        max_num_steps: int = 1024,
        adapt_trajectory_length: bool = False,
    ):
        if (model is None) == (potential_fn is None):
            raise ValueError("pass exactly one of model / potential_fn")
        self.model = model
        self._potential_fn = potential_fn
        self.step_size = step_size
        self.trajectory_length = trajectory_length
        self.adapt_step_size = adapt_step_size
        self.adapt_mass_matrix = adapt_mass_matrix
        self.target_accept = target_accept_prob
        self.max_tree_depth = max_tree_depth
        self.max_num_steps = max_num_steps
        # ChEES cross-chain trajectory tuning (fused driver only; needs >= 2
        # chains to carry information — see infer/chees.py). NUTS ignores it:
        # the tree IS its trajectory adaptation.
        self.adapt_trajectory_length = adapt_trajectory_length
        self._transforms = None

    # -- setup ---------------------------------------------------------------
    def setup(self, rng_key, *args, **kwargs):
        """Trace the model once (host-side): returns (potential_fn, dict of
        unconstrained init prototypes). For `potential_fn` kernels the
        prototype is the caller-supplied `init_params`."""
        if self._potential_fn is not None:
            return self._potential_fn, kwargs.pop("init_params")
        pe, transforms, inits = initialize_model(rng_key, self.model, args, kwargs)
        self._transforms = transforms
        return pe, inits

    def init_state(self, rng_key, pe_fn, z0) -> HMCState:
        """Build the kernel state at position `z0`. Pure in (rng_key, z0):
        the multi-chain driver vmaps this over split keys."""
        z0 = jax.tree_util.tree_map(lambda x: jnp.asarray(x, jnp.float32), z0)
        inv_mass = jax.tree_util.tree_map(jnp.ones_like, z0)
        return HMCState(
            z0,
            pe_fn(z0),
            rng_key,
            jnp.asarray(self.step_size, jnp.float32),
            inv_mass,
            da_init(self.step_size),
            welford_init(z0),
            jnp.zeros((), jnp.int32),
            jnp.zeros(()),
            jnp.zeros((), jnp.int32),
            jnp.asarray(False),
        )

    def init(self, rng_key, *args, **kwargs) -> Tuple[HMCState, Callable]:
        key_setup, key_state = jax.random.split(rng_key)
        pe_fn, z0 = self.setup(key_setup, *args, **kwargs)
        if self.model is not None:
            z0 = init_to_uniform(key_setup, z0)
        return self.init_state(key_state, pe_fn, z0), pe_fn

    # -- adaptation bookkeeping shared by HMC and NUTS ------------------------
    def _adapt(self, state: HMCState, accept_prob, z_next, warmup_len):
        """Advance dual-averaging / Welford state while `state.i <
        warmup_len`, freezing both afterwards so collection uses a fixed
        kernel. Returns (da, step_size, welford)."""
        in_warmup = state.i < warmup_len
        if self.adapt_step_size:
            da_new = da_update(state.da, accept_prob, self.target_accept)
            da = _tree_where(in_warmup, da_new, state.da)
            step_size = jnp.where(
                in_warmup, jnp.exp(da.log_step), jnp.exp(da.log_step_avg)
            )
        else:
            da, step_size = state.da, state.step_size
        if self.adapt_mass_matrix:
            wf_new = welford_update(state.welford, z_next)
            welford = _tree_where(in_warmup, wf_new, state.welford)
        else:
            welford = state.welford
        return da, step_size, welford

    # -- one transition (jittable) --------------------------------------------
    def sample_step(self, state: HMCState, pe_fn, warmup_len: int = 0) -> HMCState:
        key, key_mom, key_accept = jax.random.split(state.rng_key, 3)
        r = _sample_momentum(key_mom, state.z, state.inv_mass)
        energy0 = state.potential + _kinetic(r, state.inv_mass)
        n_steps = jnp.clip(
            (self.trajectory_length / state.step_size).astype(jnp.int32), 1, self.max_num_steps
        )
        # fixed upper bound for scan; mask extra steps
        max_steps = self.max_num_steps

        grad_fn = jax.grad(pe_fn)

        def body(carry, i):
            z, r = carry
            do = i < n_steps

            def step(zr):
                z, r = zr
                r = _tree_axpy(-0.5 * state.step_size, grad_fn(z), r)
                z = jax.tree_util.tree_map(
                    lambda zi, ri, mi: zi + state.step_size * mi * ri, z, r, state.inv_mass
                )
                r = _tree_axpy(-0.5 * state.step_size, grad_fn(z), r)
                return z, r

            z, r = jax.lax.cond(do, step, lambda zr: zr, (z, r))
            return (z, r), None

        (z_new, r_new), _ = jax.lax.scan(body, (state.z, r), jnp.arange(max_steps))
        pe_new = pe_fn(z_new)
        energy1 = pe_new + _kinetic(r_new, state.inv_mass)
        delta = energy0 - energy1
        delta = jnp.where(jnp.isnan(delta), -jnp.inf, delta)
        diverging = -delta > 1000.0
        accept_prob = jnp.minimum(1.0, jnp.exp(delta))
        accept = jax.random.uniform(key_accept) < accept_prob
        z = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), z_new, state.z
        )
        potential = jnp.where(accept, pe_new, state.potential)
        da, step_size, welford = self._adapt(state, accept_prob, z, warmup_len)
        return HMCState(
            z, potential, key, step_size, state.inv_mass, da, welford,
            state.i + 1, accept_prob, n_steps, diverging,
        )

    def finalize_warmup(self, state: HMCState) -> HMCState:
        inv_mass = state.inv_mass
        if self.adapt_mass_matrix:
            # only trust the estimate once the current window has >= 2 draws
            # (a freshly reset Welford accumulator would otherwise collapse
            # the mass matrix to the regularizer floor)
            var = welford_variance(state.welford)
            ok = state.welford.n > 1
            inv_mass = _tree_where(ok, var, inv_mass)
        step_size = jnp.exp(state.da.log_step_avg) if self.adapt_step_size else state.step_size
        return state._replace(inv_mass=inv_mass, step_size=step_size)

    # -- fused batched path (all chains at once, ops.leapfrog hot loop) ------
    def fused_init_state(self, rng_key, z_flat, potential) -> FlatHMCState:
        """State for the fused driver: z_flat (C, D), potential (C,)."""
        C, D = z_flat.shape
        return FlatHMCState(
            z_flat,
            potential,
            rng_key,
            jnp.asarray(self.step_size, jnp.float32),
            jnp.ones((D,), jnp.float32),
            da_init(self.step_size),
            jnp.zeros((D,), jnp.float32),
            jnp.zeros((D,), jnp.float32),
            jnp.zeros(()),
            chees_init(self.trajectory_length),
            jnp.zeros((), jnp.int32),
            jnp.zeros((C,)),
            jnp.zeros((C,), jnp.int32),
            jnp.zeros((C,), bool),
        )

    def _fused_adapt(self, state: FlatHMCState, accept_prob, z_batch, warmup_len):
        """Cross-chain analogue of `_adapt`: dual averaging on the MEAN
        accept probability across chains, pooled Welford over the whole
        (C, D) batch of draws. Frozen once `state.i` passes warmup."""
        in_warmup = state.i < warmup_len
        if self.adapt_step_size:
            da_new = da_update(state.da, jnp.mean(accept_prob), self.target_accept)
            da = _tree_where(in_warmup, da_new, state.da)
            step_size = jnp.where(
                in_warmup, jnp.exp(da.log_step), jnp.exp(da.log_step_avg)
            )
        else:
            da, step_size = state.da, state.step_size
        if self.adapt_mass_matrix:
            wf_new = welford_update_batch(
                state.wf_mean, state.wf_m2, state.wf_n, z_batch
            )
            wf = _tree_where(in_warmup, wf_new, (state.wf_mean, state.wf_m2, state.wf_n))
        else:
            wf = (state.wf_mean, state.wf_m2, state.wf_n)
        return da, step_size, wf

    def fused_sample_step(
        self, state: FlatHMCState, pe_flat, warmup_len: int = 0,
        backend: Optional[str] = None,
    ) -> FlatHMCState:
        """One batched HMC transition for all C chains via `ops.leapfrog`.
        The trajectory length is shared across chains — fixed at
        `trajectory_length`, or Halton-jittered and ChEES-adapted during
        warmup when `adapt_trajectory_length` (see infer/chees.py)."""
        C, D = state.z.shape
        key = jax.random.fold_in(state.rng_key, state.i)
        key_mom, key_accept = jax.random.split(key)
        inv_b = jnp.broadcast_to(state.inv_mass, (C, D))
        r = jax.random.normal(key_mom, (C, D)) / jnp.sqrt(jnp.clip(inv_b, 1e-10))
        energy0 = state.potential + 0.5 * jnp.sum(inv_b * r * r, axis=-1)
        if self.adapt_trajectory_length:
            u = halton_jitter(state.i)
            traj = u * jnp.exp(state.chees.log_tau)
        else:
            u = jnp.ones(())
            traj = jnp.asarray(self.trajectory_length, jnp.float32)
        n = jnp.clip(
            (traj / state.step_size).astype(jnp.int32), 1, self.max_num_steps
        )
        eps_c = jnp.broadcast_to(state.step_size, (C,)).astype(jnp.float32)
        n_c = jnp.broadcast_to(n, (C,)).astype(jnp.int32)
        z_new, r_new, pe_new = ops.leapfrog(
            state.z, r, inv_b, eps_c, n_c, pe_flat,
            max_steps=self.max_num_steps, backend=backend,
        )
        energy1 = pe_new + 0.5 * jnp.sum(inv_b * r_new * r_new, axis=-1)
        delta = energy0 - energy1
        delta = jnp.where(jnp.isnan(delta), -jnp.inf, delta)
        diverging = -delta > 1000.0
        accept_prob = jnp.minimum(1.0, jnp.exp(delta))
        accept = jax.random.uniform(key_accept, (C,)) < accept_prob
        z = jnp.where(accept[:, None], z_new, state.z)
        potential = jnp.where(accept, pe_new, state.potential)
        da, step_size, (wf_mean, wf_m2, wf_n) = self._fused_adapt(
            state, accept_prob, z, warmup_len
        )
        chees = state.chees
        if self.adapt_trajectory_length:
            chees_new = chees_update(
                state.chees, state.z, z_new, r_new, accept_prob, inv_b, u,
            )
            chees = _tree_where(state.i < warmup_len, chees_new, state.chees)
        return FlatHMCState(
            z, potential, state.rng_key, step_size, state.inv_mass, da,
            wf_mean, wf_m2, wf_n, chees, state.i + 1, accept_prob, n_c,
            diverging,
        )

    def fused_finalize_warmup(self, state: FlatHMCState) -> FlatHMCState:
        inv_mass = state.inv_mass
        if self.adapt_mass_matrix:
            ok = state.wf_n > 1
            var = pooled_variance(state.wf_m2, state.wf_n)
            inv_mass = jnp.where(ok, var, inv_mass)
        step_size = (
            jnp.exp(state.da.log_step_avg)
            if self.adapt_step_size
            else state.step_size
        )
        return state._replace(inv_mass=inv_mass, step_size=step_size)


# ---------------------------------------------------------------------------
# NUTS: iterative progressive doubling with multinomial trajectory sampling
# ---------------------------------------------------------------------------


class _TreeState(NamedTuple):
    z_left: Any
    r_left: Any
    z_right: Any
    r_right: Any
    z_proposal: Any
    pe_proposal: jax.Array
    log_weight: jax.Array  # log sum of exp(-energy) over trajectory
    turning: jax.Array
    diverging: jax.Array
    sum_accept: jax.Array
    n_leapfrog: jax.Array


class NUTS(HMC):
    """No-U-Turn sampler. At each doubling j we extend the trajectory by 2^j
    leapfrog steps in a random direction, multinomially sampling a proposal
    within the new subtree (progressive sampling), and stop on a U-turn
    between trajectory endpoints or on divergence."""

    def sample_step(self, state: HMCState, pe_fn, warmup_len: int = 0) -> HMCState:
        key, key_mom, key_dirs, key_accept = jax.random.split(state.rng_key, 4)
        r0 = _sample_momentum(key_mom, state.z, state.inv_mass)
        energy0 = state.potential + _kinetic(r0, state.inv_mass)
        grad_fn = jax.grad(pe_fn)
        step_size = state.step_size
        inv_mass = state.inv_mass
        max_delta = 1000.0

        def one_leapfrog(z, r, direction):
            eps = step_size * direction
            r = _tree_axpy(-0.5 * eps, grad_fn(z), r)
            z = jax.tree_util.tree_map(lambda zi, ri, mi: zi + eps * mi * ri, z, r, inv_mass)
            r = _tree_axpy(-0.5 * eps, grad_fn(z), r)
            return z, r

        def is_turning(z_left, r_left, z_right, r_right):
            dz = jax.tree_util.tree_map(lambda a, b: a - b, z_right, z_left)
            v_left = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_left)
            v_right = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_right)
            return (_tree_dot(dz, v_left) < 0) | (_tree_dot(dz, v_right) < 0)

        def extend_subtree(carry_key, tree: _TreeState, depth_j, direction):
            """Take 2^depth_j leapfrog steps from the chosen end, doing
            progressive multinomial proposal updates step-by-step."""
            n_steps = 2 ** depth_j

            def body(carry, i):
                key, z_end, r_end, z_prop, pe_prop, log_w, turning, diverging, sum_acc, z_sub_first, r_sub_first, started = carry
                do = (i < n_steps) & ~turning & ~diverging

                def step(args):
                    (key, z_end, r_end, z_prop, pe_prop, log_w, turning, diverging,
                     sum_acc, z_first, r_first, started) = args
                    z_new, r_new = one_leapfrog(z_end, r_end, direction)
                    pe_new = pe_fn(z_new)
                    energy_new = pe_new + _kinetic(r_new, inv_mass)
                    delta = energy_new - energy0
                    delta = jnp.where(jnp.isnan(delta), jnp.inf, delta)
                    diverging2 = delta > max_delta
                    log_w_new = -delta  # weight relative to initial energy
                    log_w2 = jnp.logaddexp(log_w, log_w_new)
                    key, key_u = jax.random.split(key)
                    take = jax.random.uniform(key_u) < jnp.exp(log_w_new - log_w2)
                    z_prop2 = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(take, a, b), z_new, z_prop
                    )
                    pe_prop2 = jnp.where(take, pe_new, pe_prop)
                    sum_acc2 = sum_acc + jnp.minimum(1.0, jnp.exp(-delta))
                    # record subtree start for the U-turn check
                    z_first2 = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(started, a, b), z_first, z_new
                    )
                    r_first2 = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(started, a, b), r_first, r_new
                    )
                    # direction-normalized U-turn check: dz always points
                    # "forward" along the trajectory regardless of direction
                    dz = jax.tree_util.tree_map(
                        lambda a, b: direction * (a - b), z_new, z_first2
                    )
                    v_first = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_first2)
                    v_new = jax.tree_util.tree_map(lambda m, r: m * r, inv_mass, r_new)
                    turning2 = (
                        (_tree_dot(dz, v_first) < 0) | (_tree_dot(dz, v_new) < 0)
                    ) & started  # need at least 2 pts
                    return (key, z_new, r_new, z_prop2, pe_prop2, log_w2, turning2,
                            diverging2, sum_acc2, z_first2, r_first2, jnp.asarray(True))

                carry2 = jax.lax.cond(do, step, lambda a: a,
                                      (key, z_end, r_end, z_prop, pe_prop, log_w, turning,
                                       diverging, sum_acc, z_sub_first, r_sub_first, started))
                return carry2, None

            z_end = jax.lax.cond(direction > 0, lambda: tree.z_right, lambda: tree.z_left)
            r_end = jax.lax.cond(direction > 0, lambda: tree.r_right, lambda: tree.r_left)
            init = (carry_key, z_end, r_end, tree.z_proposal, tree.pe_proposal,
                    -jnp.inf, jnp.asarray(False), jnp.asarray(False), jnp.zeros(()),
                    z_end, r_end, jnp.asarray(False))
            out, _ = jax.lax.scan(body, init, jnp.arange(2 ** self.max_tree_depth))
            (key, z_end, r_end, z_prop, pe_prop, log_w_sub, turning, diverging,
             sum_acc, _, _, _) = out
            return key, z_end, r_end, z_prop, pe_prop, log_w_sub, turning, diverging, sum_acc

        # -- progressive doubling loop (unrolled over max_tree_depth) -------
        tree = _TreeState(
            state.z, r0, state.z, r0, state.z, state.potential,
            jnp.zeros(()),  # initial point has weight exp(0)
            jnp.asarray(False), jnp.asarray(False), jnp.zeros(()), jnp.zeros((), jnp.int32),
        )
        key_loop = key_dirs
        for j in range(self.max_tree_depth):
            key_loop, key_dir, key_swap = jax.random.split(key_loop, 3)
            direction = jnp.where(jax.random.bernoulli(key_dir), 1.0, -1.0)
            stop = tree.turning | tree.diverging
            (key_loop, z_end, r_end, z_prop_sub, pe_prop_sub, log_w_sub, turning_sub,
             diverging_sub, sum_acc) = extend_subtree(key_loop, tree, j, direction)
            # biased progressive sampling between old tree and new subtree
            total = jnp.logaddexp(tree.log_weight, log_w_sub)
            take_new = (jax.random.uniform(key_swap) < jnp.exp(log_w_sub - total)) & ~turning_sub & ~diverging_sub
            z_proposal = jax.tree_util.tree_map(
                lambda a, b: jnp.where(take_new & ~stop, a, b), z_prop_sub, tree.z_proposal
            )
            pe_proposal = jnp.where(take_new & ~stop, pe_prop_sub, tree.pe_proposal)
            z_left = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction < 0) & ~stop, new, old), z_end, tree.z_left
            )
            r_left = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction < 0) & ~stop, new, old), r_end, tree.r_left
            )
            z_right = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction > 0) & ~stop, new, old), z_end, tree.z_right
            )
            r_right = jax.tree_util.tree_map(
                lambda new, old: jnp.where((direction > 0) & ~stop, new, old), r_end, tree.r_right
            )
            turning_full = is_turning(z_left, r_left, z_right, r_right)
            tree = _TreeState(
                z_left, r_left, z_right, r_right, z_proposal, pe_proposal,
                jnp.where(stop, tree.log_weight, total),
                tree.turning | turning_sub | turning_full,
                tree.diverging | diverging_sub,
                tree.sum_accept + jnp.where(stop, 0.0, sum_acc),
                tree.n_leapfrog + jnp.where(stop, 0, 2 ** j),
            )

        accept_prob = tree.sum_accept / jnp.maximum(tree.n_leapfrog, 1)
        da, step_size, welford = self._adapt(
            state, accept_prob, tree.z_proposal, warmup_len
        )
        return HMCState(
            tree.z_proposal, tree.pe_proposal, key, step_size, state.inv_mass, da,
            welford, state.i + 1, accept_prob, tree.n_leapfrog, tree.diverging,
        )

    # -- fused batched path: tree building vectorized across the chain axis --
    def fused_sample_step(
        self, state: FlatHMCState, pe_flat, warmup_len: int = 0,
        backend: Optional[str] = None,
    ) -> FlatHMCState:
        """One batched NUTS transition: the iterative doubling loop runs ONCE
        for the whole (C, D) batch with per-chain direction draws and active
        masks, so every leapfrog step in the trajectory is a single
        `ops.leapfrog` call over all chains (`num_steps` 1 where the chain is
        still growing its tree, 0 where it has stopped) — the chain batch
        never leaves the mesh, and the doubling-j subtree is a scan of
        exactly 2^j steps instead of the per-chain path's fixed
        2^max_tree_depth bound."""
        C, D = state.z.shape
        key = jax.random.fold_in(state.rng_key, state.i)
        key_mom, key_loop = jax.random.split(key)
        inv_b = jnp.broadcast_to(state.inv_mass, (C, D))
        r0 = jax.random.normal(key_mom, (C, D)) / jnp.sqrt(jnp.clip(inv_b, 1e-10))
        energy0 = state.potential + 0.5 * jnp.sum(inv_b * r0 * r0, axis=-1)
        eps = jnp.broadcast_to(state.step_size, (C,)).astype(jnp.float32)
        max_delta = 1000.0

        def row_dot(a, b):
            return jnp.sum(a * b, axis=-1)

        # trajectory state, one row per chain
        z_left = z_right = z_prop = state.z
        r_left = r_right = r0
        pe_prop = state.potential
        log_w = jnp.zeros((C,))           # initial point has weight exp(0)
        turning = jnp.zeros((C,), bool)
        diverging = jnp.zeros((C,), bool)
        sum_acc = jnp.zeros((C,))
        n_leap = jnp.zeros((C,), jnp.int32)

        for j in range(self.max_tree_depth):
            key_j = jax.random.fold_in(key_loop, j)
            key_dir, key_swap, key_in = jax.random.split(key_j, 3)
            dirs = jnp.where(jax.random.bernoulli(key_dir, 0.5, (C,)), 1.0, -1.0)
            stop = turning | diverging  # chains whose tree is finished
            fwd = (dirs > 0)[:, None]
            z_end = jnp.where(fwd, z_right, z_left)
            r_end = jnp.where(fwd, r_right, r_left)

            def body(carry, t, dirs=dirs, stop=stop, key_in=key_in):
                (z_e, r_e, z_p, pe_p, lw, s_turn, s_div, s_acc,
                 z_f, r_f, started, taken) = carry
                active = ~stop & ~s_turn & ~s_div
                z_n, r_n, pe_n = ops.leapfrog(
                    z_e, r_e, inv_b, eps * dirs, active.astype(jnp.int32),
                    pe_flat, max_steps=1, backend=backend,
                )
                e_n = pe_n + 0.5 * jnp.sum(inv_b * r_n * r_n, axis=-1)
                delta = e_n - energy0
                delta = jnp.where(jnp.isnan(delta), jnp.inf, delta)
                div_n = delta > max_delta
                lw_n = -delta
                lw2 = jnp.logaddexp(lw, lw_n)
                take = (
                    jax.random.uniform(jax.random.fold_in(key_in, t), (C,))
                    < jnp.exp(lw_n - lw2)
                )
                upd = active
                sel = upd & take
                z_p = jnp.where(sel[:, None], z_n, z_p)
                pe_p = jnp.where(sel, pe_n, pe_p)
                s_acc = s_acc + jnp.where(upd, jnp.minimum(1.0, jnp.exp(-delta)), 0.0)
                first = upd & ~started
                z_f = jnp.where(first[:, None], z_n, z_f)
                r_f = jnp.where(first[:, None], r_n, r_f)
                # direction-normalized U-turn within the growing subtree
                dz = dirs[:, None] * (z_n - z_f)
                turn_n = (
                    (row_dot(dz, inv_b * r_f) < 0)
                    | (row_dot(dz, inv_b * r_n) < 0)
                ) & started  # need >= 2 points in the subtree
                s_turn = s_turn | (upd & turn_n)
                s_div = s_div | (upd & div_n)
                lw = jnp.where(upd, lw2, lw)
                z_e = jnp.where(upd[:, None], z_n, z_e)
                r_e = jnp.where(upd[:, None], r_n, r_e)
                started = started | upd
                taken = taken + upd.astype(jnp.int32)
                return (z_e, r_e, z_p, pe_p, lw, s_turn, s_div, s_acc,
                        z_f, r_f, started, taken), None

            init = (
                z_end, r_end, z_prop, pe_prop, jnp.full((C,), -jnp.inf),
                jnp.zeros((C,), bool), jnp.zeros((C,), bool), jnp.zeros((C,)),
                z_end, r_end, jnp.zeros((C,), bool), jnp.zeros((C,), jnp.int32),
            )
            (z_end, r_end, z_ps, pe_ps, lw_sub, turn_sub, div_sub, acc_sub,
             _, _, _, taken), _ = jax.lax.scan(body, init, jnp.arange(2 ** j))

            # biased progressive sampling between the old tree and the subtree
            total = jnp.logaddexp(log_w, lw_sub)
            take_new = (
                (jax.random.uniform(key_swap, (C,)) < jnp.exp(lw_sub - total))
                & ~turn_sub & ~div_sub & ~stop
            )
            z_prop = jnp.where(take_new[:, None], z_ps, z_prop)
            pe_prop = jnp.where(take_new, pe_ps, pe_prop)
            move = ~stop
            grow_l = ((dirs < 0) & move)[:, None]
            grow_r = ((dirs > 0) & move)[:, None]
            z_left = jnp.where(grow_l, z_end, z_left)
            r_left = jnp.where(grow_l, r_end, r_left)
            z_right = jnp.where(grow_r, z_end, z_right)
            r_right = jnp.where(grow_r, r_end, r_right)
            dzf = z_right - z_left
            turn_full = (
                (row_dot(dzf, inv_b * r_left) < 0)
                | (row_dot(dzf, inv_b * r_right) < 0)
            )
            log_w = jnp.where(move, total, log_w)
            turning = turning | turn_sub | (move & turn_full)
            diverging = diverging | div_sub
            sum_acc = sum_acc + acc_sub  # already masked per chain
            n_leap = n_leap + taken

        accept_prob = sum_acc / jnp.maximum(n_leap, 1)
        da, step_size, (wf_mean, wf_m2, wf_n) = self._fused_adapt(
            state, accept_prob, z_prop, warmup_len
        )
        return FlatHMCState(
            z_prop, pe_prop, state.rng_key, step_size, state.inv_mass, da,
            wf_mean, wf_m2, wf_n, state.chees, state.i + 1, accept_prob,
            n_leap, diverging,
        )


# ---------------------------------------------------------------------------
# MCMC driver: multi-chain, scan-based, optionally mesh-sharded
# ---------------------------------------------------------------------------


class MCMC:
    """Multi-chain MCMC engine.

    `run` initializes `num_chains` kernel states from split PRNG keys, runs
    warmup (with windowed mass-matrix re-estimation) and sample collection
    inside `lax.scan` — the entire run is ONE jit-compiled call, so the
    number of XLA dispatches is constant in `num_warmup` and `num_samples`.

    fused:
      * ``True`` (the default; env override ``REPRO_MCMC_FUSED=0``) — all
        chains step together as one (num_chains, D) batch through the
        backend-dispatched `ops.leapfrog` kernel, with adaptation pooled
        across chains (shared step size / mass matrix / optional ChEES
        trajectory length). The raw-speed path, >= 2x legacy draws/sec at
        1024 chains (`benchmarks/mcmc_bench.py`).
      * ``False`` — the legacy interior: the per-chain program is vmapped
        over the chain axis, each chain adapts independently. Kept as the
        benchmark baseline.

    mesh (the canonical sharding knob, shared with the ELBOs and SMC):
      * ``None`` — chains ride a plain local `vmap` (default);
      * ``"auto"`` — identical computation, but the chain axis is
        constrained onto the data axes of a default 1-D mesh over all
        local devices via the PR-1 sharding rules, distributing chains
        across devices. On a 1-device mesh this is bit-for-bit identical
        to ``mesh=None``;
      * a `jax.sharding.Mesh` — same, on the given mesh.

    chain_method (deprecated):
      the pre-unification spelling. ``chain_method="vectorized"`` means
      ``mesh=None``; ``chain_method="sharded"`` means ``mesh="auto"``
      (or the explicitly passed mesh). Passing it emits a FutureWarning;
      `self.chain_method` remains readable either way.

    Samples come back as ``{site: (num_chains, num_samples, ...)}`` via
    ``get_samples(group_by_chain=True)`` (flattened to
    ``(num_chains * num_samples, ...)`` by default); per-draw diagnostics
    (accept prob, divergences, step counts, energies) via
    ``get_extra_fields``.
    """

    def __init__(
        self,
        kernel: HMC,
        num_warmup: int,
        num_samples: int,
        num_chains: int = 1,
        thinning: int = 1,
        chain_method: Optional[str] = None,
        mesh=None,
        fused: Optional[bool] = None,
    ):
        if chain_method is not None:
            warnings.warn(
                "MCMC(chain_method=...) is deprecated; pass mesh= instead "
                "(mesh=None for the local vmap, mesh='auto' or a "
                "jax.sharding.Mesh to shard chains across devices).",
                FutureWarning,
                stacklevel=2,
            )
            if chain_method not in ("vectorized", "sharded"):
                raise ValueError(
                    f"chain_method must be 'vectorized' or 'sharded', got {chain_method!r}"
                )
            if chain_method == "sharded":
                mesh = "auto" if mesh is None else mesh
            else:
                # vectorized historically ignored any mesh argument
                mesh = None
        if isinstance(mesh, str):
            if mesh != "auto":
                raise ValueError(
                    f"mesh must be None, 'auto', or a jax.sharding.Mesh, got {mesh!r}"
                )
            from ..distributed.sharding import default_mesh

            mesh = default_mesh()
        if num_chains < 1:
            raise ValueError("num_chains must be >= 1")
        if fused is None:
            # default ON; REPRO_MCMC_FUSED=0 keeps the per-chain vmap path
            # (the pre-fused baseline benchmarks compare against)
            fused = settings.get_bool("REPRO_MCMC_FUSED")
        self.fused = fused
        self.kernel = kernel
        self.num_warmup = num_warmup
        self.num_samples = num_samples
        self.num_chains = num_chains
        self.thinning = thinning
        self.mesh = mesh
        self.chain_method = "sharded" if mesh is not None else "vectorized"
        self._samples = None  # {site: (C, S, ...)} constrained space
        self._extra_fields = None  # {field: (C, S)}
        self._last_state = None
        # incremented each time the fused driver is *traced*; the benchmark
        # asserts this stays at 1 per run regardless of num_samples, and that
        # a second run with the same arg shapes reuses the executable
        self.num_traces = 0
        self._exec = None  # cached jitted driver
        self._exec_key = None

    # -- the legacy per-chain driver -----------------------------------------
    def _build_driver(self, randomize: bool, treedef, is_dyn, static_leaves):
        """Build the legacy (init -> warmup -> collect) program. Model args
        ride the traced signature (array leaves in `is_dyn` positions) so
        repeat runs with fresh keys/data of the same shapes reuse one
        compiled executable; non-array leaves are baked in statically."""
        kernel = self.kernel
        transforms = kernel._transforms
        W, S, T = self.num_warmup, self.num_samples, self.thinning
        win = max(1, W // 2)
        mesh = self.mesh
        adapt_mm = kernel.adapt_mass_matrix
        if mesh is not None:
            from ..distributed.sharding import shard_chains

        def make_pe(dyn_leaves):
            if kernel.model is None:
                return kernel._potential_fn
            it = iter(dyn_leaves)
            merged = [next(it) if d else s for d, s in zip(is_dyn, static_leaves)]
            margs, mkwargs = jax.tree_util.tree_unflatten(treedef, merged)
            return partial(potential_energy, kernel.model, margs, mkwargs, transforms)

        def one_chain(state, pe_fn):
            def warmup_body(s, i):
                s = kernel.sample_step(s, pe_fn, W)
                if adapt_mm:
                    # windowed re-estimation: swap in the current Welford
                    # variance and restart the accumulator at each interior
                    # window boundary; the final window feeds finalize_warmup
                    do = ((i + 1) % win == 0) & (i + 1 < W)
                    s = jax.lax.cond(
                        do,
                        lambda s: s._replace(
                            inv_mass=welford_variance(s.welford),
                            welford=welford_init(s.z),
                        ),
                        lambda s: s,
                        s,
                    )
                return s, None

            if W > 0:
                state, _ = jax.lax.scan(warmup_body, state, jnp.arange(W))
            state = kernel.finalize_warmup(state)

            def collect_body(s, _):
                if T > 1:
                    # a divergence anywhere in the thinned block must surface,
                    # not just one on the kept draw — OR the flags through
                    def thin_step(carry, _):
                        s, div = carry
                        s = kernel.sample_step(s, pe_fn, W)
                        return (s, div | s.diverging), None

                    (s, diverging), _ = jax.lax.scan(
                        thin_step, (s, jnp.asarray(False)), None, length=T
                    )
                else:
                    s = kernel.sample_step(s, pe_fn, W)
                    diverging = s.diverging
                extras = {
                    "accept_prob": s.accept_prob,
                    "diverging": diverging,
                    "num_steps": s.num_steps,
                    "potential_energy": s.potential,
                    "step_size": s.step_size,
                }
                return s, (s.z, extras)

            state, (z, extras) = jax.lax.scan(collect_body, state, None, length=S)
            return state, z, extras

        def driver(chain_keys, proto, dyn_leaves):
            self.num_traces += 1  # trace-time side effect (retrace detector)
            pe_fn = make_pe(dyn_leaves)

            def init_one(key, z0):
                if randomize:
                    z0 = init_to_uniform(key, z0)
                return kernel.init_state(key, pe_fn, z0)

            states = jax.vmap(init_one)(chain_keys, proto)
            if mesh is not None:
                states = shard_chains(states, mesh)
            states, z, extras = jax.vmap(partial(one_chain, pe_fn=pe_fn))(states)
            if mesh is not None:
                z = shard_chains(z, mesh)
                extras = shard_chains(extras, mesh)
            return states, z, extras

        return driver

    def _build_fused_driver(
        self, randomize: bool, treedef, is_dyn, static_leaves, backend: str
    ):
        """The fused batched program: positions raveled to one (C, D) matrix,
        transitions stepped for ALL chains at once through `ops.leapfrog` on
        the resolved kernel backend, adaptation pooled across chains. Same
        external contract as `_build_driver` (one trace per run, samples as
        {site: (C, S, ...)}), different interior: no per-chain vmap, so
        cross-chain statistics (shared dual averaging, pooled Welford, ChEES)
        are ordinary batch reductions."""
        kernel = self.kernel
        transforms = kernel._transforms
        W, S, T, C = self.num_warmup, self.num_samples, self.thinning, self.num_chains
        win = max(1, W // 2)
        mesh = self.mesh
        adapt_mm = kernel.adapt_mass_matrix
        if mesh is not None:
            from ..distributed.sharding import shard_chains

        def make_pe(dyn_leaves):
            if kernel.model is None:
                return kernel._potential_fn
            it = iter(dyn_leaves)
            merged = [next(it) if d else s for d, s in zip(is_dyn, static_leaves)]
            margs, mkwargs = jax.tree_util.tree_unflatten(treedef, merged)
            return partial(potential_energy, kernel.model, margs, mkwargs, transforms)

        def shard_state(s: FlatHMCState) -> FlatHMCState:
            # only the chain-major leaves ride the mesh's data axes — the
            # shared adaptation scalars/vectors are replicated by definition
            if mesh is None:
                return s
            batch = {
                "z": s.z, "potential": s.potential, "accept_prob": s.accept_prob,
                "num_steps": s.num_steps, "diverging": s.diverging,
            }
            batch = shard_chains(batch, mesh)
            return s._replace(**batch)

        def driver(chain_keys, proto, dyn_leaves):
            self.num_traces += 1  # trace-time side effect (retrace detector)
            pe_fn = make_pe(dyn_leaves)
            z0 = proto
            if randomize:
                z0 = jax.vmap(init_to_uniform)(chain_keys, z0)
            _, unravel = ravel_pytree(
                jax.tree_util.tree_map(lambda x: x[0], proto)
            )
            flat = jax.vmap(lambda t: ravel_pytree(t)[0])(z0)  # (C, D)

            def pe_flat(zvec):
                return pe_fn(unravel(zvec))

            state = kernel.fused_init_state(
                chain_keys[0], flat, jax.vmap(pe_flat)(flat)
            )
            state = shard_state(state)

            def step(s):
                return kernel.fused_sample_step(s, pe_flat, W, backend=backend)

            def warmup_body(s, i):
                s = step(s)
                if adapt_mm:
                    do = ((i + 1) % win == 0) & (i + 1 < W)
                    s = jax.lax.cond(
                        do,
                        lambda s: s._replace(
                            inv_mass=pooled_variance(s.wf_m2, s.wf_n),
                            wf_mean=jnp.zeros_like(s.wf_mean),
                            wf_m2=jnp.zeros_like(s.wf_m2),
                            wf_n=jnp.zeros_like(s.wf_n),
                        ),
                        lambda s: s,
                        s,
                    )
                return s, None

            if W > 0:
                state, _ = jax.lax.scan(warmup_body, state, jnp.arange(W))
            state = kernel.fused_finalize_warmup(state)

            def collect_body(s, _):
                if T > 1:
                    def thin_step(carry, _):
                        s, div = carry
                        s = step(s)
                        return (s, div | s.diverging), None

                    (s, diverging), _ = jax.lax.scan(
                        thin_step, (s, jnp.zeros((C,), bool)), None, length=T
                    )
                else:
                    s = step(s)
                    diverging = s.diverging
                extras = {
                    "accept_prob": s.accept_prob,
                    "diverging": diverging,
                    "num_steps": s.num_steps,
                    "potential_energy": s.potential,
                    "step_size": jnp.broadcast_to(s.step_size, (C,)),
                }
                return s, (s.z, extras)

            state, (zs, extras) = jax.lax.scan(collect_body, state, None, length=S)
            zs = jnp.swapaxes(zs, 0, 1)  # (S, C, D) -> (C, S, D)
            extras = jax.tree_util.tree_map(
                lambda x: jnp.swapaxes(x, 0, 1), extras
            )
            z = jax.vmap(jax.vmap(unravel))(zs)  # {site: (C, S, ...)}
            if mesh is not None:
                z = shard_chains(z, mesh)
                extras = shard_chains(extras, mesh)
            return state, z, extras

        return driver

    # -- public API ----------------------------------------------------------
    def run(self, rng_key, *args, init_params=None, **kwargs):
        """Run all chains; returns `get_samples()` (flattened across chains).

        `init_params`, when given, is an *unbatched* pytree of unconstrained
        initial values broadcast to every chain (chains still decorrelate
        through their momenta/keys). Required for `potential_fn` kernels.
        """
        key_setup, key_init = jax.random.split(rng_key)
        kernel = self.kernel
        if kernel.model is not None:
            _, proto = kernel.setup(key_setup, *args, **kwargs)
            randomize = init_params is None
            if init_params is not None:
                proto = init_params
        else:
            if init_params is None:
                raise ValueError("potential_fn kernels require init_params=")
            proto, randomize = init_params, False

        C = self.num_chains
        proto = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (C,) + jnp.shape(x)),
            proto,
        )
        chain_keys = jax.random.split(key_init, C)

        # static/dynamic partition of model args: arrays are traced (a fresh
        # dataset of the same shape reuses the executable), everything else
        # (plate sizes, flags) stays static so model control flow is unchanged
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
        is_dyn = tuple(isinstance(l, (jax.Array, np.ndarray)) for l in leaves)
        dyn_leaves = [l for l, d in zip(leaves, is_dyn) if d]
        static_leaves = tuple(None if d else l for l, d in zip(leaves, is_dyn))
        # the kernel backend is a trace-time constant of the fused driver, so
        # it joins the cache key (flipping REPRO_KERNEL_BACKEND between runs
        # recompiles instead of silently reusing the old backend)
        backend = ops.resolve_backend(None) if self.fused else None
        exec_key = (randomize, treedef, is_dyn, static_leaves, self.fused, backend)
        if self._exec is None or self._exec_key != exec_key:
            if self.fused:
                driver = self._build_fused_driver(
                    randomize, treedef, is_dyn, static_leaves, backend
                )
            else:
                driver = self._build_driver(randomize, treedef, is_dyn, static_leaves)
            self._exec = jax.jit(driver)
            self._exec_key = exec_key
        states, z, extras = self._exec(chain_keys, proto, dyn_leaves)
        self._last_state = states
        self._extra_fields = extras
        if kernel._transforms:
            z = transform_fn(kernel._transforms, z)
        self._samples = z
        return self.get_samples()

    def get_samples(self, group_by_chain: bool = False):
        """Posterior samples in constrained space: ``(chain, draw, ...)`` when
        `group_by_chain`, else flattened to ``(chain * draw, ...)``."""
        if self._samples is None:
            return None
        if group_by_chain:
            return self._samples
        return jax.tree_util.tree_map(
            lambda x: x.reshape((-1,) + x.shape[2:]), self._samples
        )

    def get_extra_fields(self, group_by_chain: bool = True):
        """Per-draw diagnostics: accept_prob, diverging, num_steps,
        potential_energy, step_size — each ``(chain, draw)`` when
        `group_by_chain` (default), else flattened."""
        if self._extra_fields is None:
            return None
        if group_by_chain:
            return self._extra_fields
        return jax.tree_util.tree_map(
            lambda x: x.reshape((-1,) + x.shape[2:]), self._extra_fields
        )

    def summary(self, prob: float = 0.9, print_table: bool = True):
        """Per-site posterior statistics + convergence diagnostics (split-R̂,
        bulk/tail ESS, divergence count). Prints the table unless
        `print_table=False`; returns the stats as ``{site: {stat: array}}``."""
        from .diagnostics import print_summary, summary as _summary

        if self._samples is None:
            raise RuntimeError("no samples available; call MCMC.run(...) first")
        if print_table:
            print_summary(self._samples, extra_fields=self._extra_fields, prob=prob)
        return _summary(self._samples, prob=prob)
