"""ELBO estimators (paper §2: "the primary inference algorithm is
gradient-based stochastic variational inference").

* Trace_ELBO — the paper's default: Monte-Carlo estimate of
  E_q[log p - log q]; score-function (REINFORCE) terms added automatically
  for non-reparameterizable guide sites.
* TraceMeanField_ELBO — beyond-paper variance reduction: analytic KL where a
  registered closed form exists (the paper explicitly notes Pyro uses MC
  estimates "rather than exact analytic expressions"; we provide both and
  benchmark the difference).
* RenyiELBO — importance-weighted (IWAE-style) alpha-divergence bound.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from ..core.handlers import replay, seed, trace
from ..distributions import kl_divergence
from ..distributions.util import sum_rightmost
from .util import log_mean_exp, substitute_params


def _apply_scale_mask(lp, site):
    if site["mask"] is not None:
        lp = jnp.where(site["mask"], lp, 0.0)
    if site["scale"] is not None:
        lp = lp * site["scale"]
    return lp


def _single_particle_elbo(rng_key, params, model, guide, args, kwargs):
    """One MC sample of the ELBO with a reparameterized/score-function split."""
    key_guide, key_model = jax.random.split(rng_key)
    seeded_guide = seed(substitute_params(guide, params), key_guide)
    guide_tr = trace(seeded_guide).get_trace(*args, **kwargs)
    seeded_model = seed(substitute_params(model, params), key_model)
    model_tr = trace(replay(seeded_model, guide_tr)).get_trace(*args, **kwargs)

    elbo = 0.0
    score_logq = 0.0  # sum of log q at non-reparam sites (REINFORCE factor)
    for name, site in model_tr.nodes.items():
        if site["type"] != "sample":
            continue
        lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
        elbo = elbo + jnp.sum(lp)
    for name, site in guide_tr.nodes.items():
        if site["type"] != "sample" or site["is_observed"]:
            continue
        lq = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
        elbo = elbo - jnp.sum(lq)
        if not site["fn"].has_rsample:
            score_logq = score_logq + jnp.sum(lq)
    # surrogate so that grad(surrogate) is an unbiased ELBO gradient:
    #   d/dtheta [elbo + stop_grad(elbo) * score_logq]
    surrogate = elbo + jax.lax.stop_gradient(elbo) * (
        score_logq - jax.lax.stop_gradient(score_logq)
    )
    return elbo, surrogate


class Trace_ELBO:
    """Monte-Carlo ELBO (paper default). `num_particles` vectorized via vmap."""

    def __init__(self, num_particles: int = 1):
        self.num_particles = num_particles

    def loss(self, rng_key, params, model, guide, *args, **kwargs):
        return self.loss_with_surrogate(rng_key, params, model, guide, *args, **kwargs)[0]

    def loss_with_surrogate(self, rng_key, params, model, guide, *args, **kwargs):
        if self.num_particles == 1:
            elbo, surrogate = _single_particle_elbo(rng_key, params, model, guide, args, kwargs)
            return -elbo, -surrogate
        keys = jax.random.split(rng_key, self.num_particles)
        elbos, surrogates = jax.vmap(
            lambda k: _single_particle_elbo(k, params, model, guide, args, kwargs)
        )(keys)
        return -jnp.mean(elbos), -jnp.mean(surrogates)


class TraceMeanField_ELBO(Trace_ELBO):
    """Analytic-KL ELBO: uses registered closed-form KL(q||p) at latent sites
    where available (mean-field assumption: guide sites independent given
    upstream), falling back to the MC estimate elsewhere."""

    def loss_with_surrogate(self, rng_key, params, model, guide, *args, **kwargs):
        def single(key):
            key_guide, key_model = jax.random.split(key)
            guide_tr = trace(seed(substitute_params(guide, params), key_guide)).get_trace(
                *args, **kwargs
            )
            model_tr = trace(
                replay(seed(substitute_params(model, params), key_model), guide_tr)
            ).get_trace(*args, **kwargs)
            elbo = 0.0
            for name, site in model_tr.nodes.items():
                if site["type"] != "sample":
                    continue
                if site["is_observed"]:
                    lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
                    elbo = elbo + jnp.sum(lp)
                else:
                    guide_site = guide_tr.nodes[name]
                    try:
                        kl = kl_divergence(guide_site["fn"], site["fn"])
                        kl = _apply_scale_mask(kl, site)
                        elbo = elbo - jnp.sum(kl)
                    except NotImplementedError:
                        lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
                        lq = _apply_scale_mask(
                            guide_site["fn"].log_prob(guide_site["value"]), guide_site
                        )
                        elbo = elbo + jnp.sum(lp) - jnp.sum(lq)
            return elbo

        if self.num_particles == 1:
            elbo = single(rng_key)
        else:
            elbo = jnp.mean(jax.vmap(single)(jax.random.split(rng_key, self.num_particles)))
        return -elbo, -elbo


class RenyiELBO:
    """Renyi alpha-divergence bound (alpha=0 -> IWAE)."""

    def __init__(self, alpha: float = 0.0, num_particles: int = 2):
        if num_particles < 2:
            raise ValueError("RenyiELBO needs num_particles >= 2")
        self.alpha = alpha
        self.num_particles = num_particles

    def loss(self, rng_key, params, model, guide, *args, **kwargs):
        return self.loss_with_surrogate(rng_key, params, model, guide, *args, **kwargs)[0]

    def loss_with_surrogate(self, rng_key, params, model, guide, *args, **kwargs):
        def single(key):
            elbo, _ = _single_particle_elbo(key, params, model, guide, args, kwargs)
            return elbo

        keys = jax.random.split(rng_key, self.num_particles)
        log_weights = jax.vmap(single)(keys)  # (K,)
        scaled = (1.0 - self.alpha) * log_weights
        bound = log_mean_exp(scaled) / (1.0 - self.alpha)
        # surrogate: self-normalized importance weighting
        w = jax.nn.softmax(jax.lax.stop_gradient(scaled))
        surrogate = jnp.sum(w * log_weights)
        return -bound, -surrogate
