"""ELBO estimators (paper §2: "the primary inference algorithm is
gradient-based stochastic variational inference").

* Trace_ELBO — the paper's default: Monte-Carlo estimate of
  E_q[log p - log q]; score-function (REINFORCE) terms added automatically
  for non-reparameterizable guide sites.
* TraceMeanField_ELBO — beyond-paper variance reduction: analytic KL where a
  registered closed form exists (the paper explicitly notes Pyro uses MC
  estimates "rather than exact analytic expressions"; we provide both and
  benchmark the difference).
* RenyiELBO — importance-weighted (IWAE-style) alpha-divergence bound.

All estimators share one particle-vectorization engine (`ELBO` +
`vectorize_particles`): a subclass defines `_single_particle` (one MC draw ->
(elbo, surrogate)) and `_reduce` (collapse the particle axis). The engine
handles the num_particles == 1 fast path uniformly and, when a device `mesh`
is supplied, shards the particle axis across it so multi-particle estimates
run data-parallel instead of serially on one device. On a 1-device mesh the
sharded path is bit-for-bit identical to the local vmap path.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core.handlers import replay, seed, trace
from ..distributions import kl_divergence
from .util import log_mean_exp, substitute_params


def _apply_scale_mask(lp, site):
    if site["mask"] is not None:
        lp = jnp.where(site["mask"], lp, 0.0)
    if site["scale"] is not None:
        lp = lp * site["scale"]
    return lp


# ---------------------------------------------------------------------------
# the shared particle-vectorization path
# ---------------------------------------------------------------------------


def shard_particles(
    keys: jax.Array, mesh: Optional[Mesh], axis: Union[str, Tuple[str, ...], None]
) -> jax.Array:
    """Constrain the leading (particle) dim of `keys` onto a mesh axis so XLA
    SPMD splits the vmapped particle computation across devices. Falls back to
    replication when no mesh is given or the particle count does not divide
    the axis size (correctness over parallelism)."""
    if mesh is None:
        return keys
    from ..distributed.sharding import constrain_leading_dim  # lazy: keeps infer light

    return constrain_leading_dim(keys, mesh, axis)


def vectorize_particles(
    fn: Callable,
    rng_key: jax.Array,
    num_particles: int,
    mesh: Optional[Mesh] = None,
    particle_axis: Union[str, Tuple[str, ...], None] = None,
):
    """Run `fn(key)` for `num_particles` MC particles. One particle calls `fn`
    directly; more are vmapped over split keys, with the particle axis
    sharded across `mesh` when provided. Returns a pytree of stacked outputs
    with leading dim `num_particles`."""
    if num_particles == 1:
        # add the particle axis explicitly (atleast_1d would leave non-scalar
        # outputs without one, breaking axis-0 reductions like RenyiELBO's)
        return jax.tree.map(lambda x: jnp.asarray(x)[None], fn(rng_key))
    keys = shard_particles(jax.random.split(rng_key, num_particles), mesh, particle_axis)
    return jax.vmap(fn)(keys)


class ELBO:
    """Base estimator: the engine every concrete ELBO plugs into.

    Parameters
    ----------
    num_particles: MC particles per loss/gradient evaluation.
    mesh: optional `jax.sharding.Mesh`; when set, particles are split across
        `particle_axis` (default: the 'data' axis) instead of all running on
        every device.
    particle_axis: mesh axis (or tuple of axes) to shard particles over.
    """

    def __init__(
        self,
        num_particles: int = 1,
        mesh: Optional[Mesh] = None,
        particle_axis: Union[str, Tuple[str, ...], None] = None,
    ):
        if num_particles < 1:
            raise ValueError(f"num_particles must be >= 1, got {num_particles}")
        self.num_particles = num_particles
        self.mesh = mesh
        self.particle_axis = particle_axis

    def loss(self, rng_key, params, model, guide, *args, **kwargs):
        return self.loss_with_surrogate(rng_key, params, model, guide, *args, **kwargs)[0]

    def loss_with_surrogate(self, rng_key, params, model, guide, *args, **kwargs):
        elbos, surrogates = vectorize_particles(
            lambda key: self._single_particle(key, params, model, guide, args, kwargs),
            rng_key,
            self.num_particles,
            mesh=self.mesh,
            particle_axis=self.particle_axis,
        )
        return self._reduce(elbos, surrogates)

    # -- subclass hooks ------------------------------------------------------
    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        """One MC draw -> (elbo, surrogate), both scalars."""
        raise NotImplementedError

    def _reduce(self, elbos, surrogates):
        """Collapse the (num_particles,) axis to (-loss, -surrogate)."""
        return -jnp.mean(elbos), -jnp.mean(surrogates)


def check_no_enumerate_sites(model_tr, guide_tr, estimator: str) -> None:
    """Reject model latents annotated for enumeration that neither the guide
    samples nor this estimator can marginalize — they would silently be drawn
    from the prior and train a wrong objective."""
    for name, site in model_tr.nodes.items():
        if (
            site["type"] == "sample"
            and not site["is_observed"]
            and site["infer"].get("enumerate")
            and name not in guide_tr.nodes
        ):
            raise ValueError(
                f"model site '{name}' is annotated infer={{'enumerate': ...}} "
                f"but {estimator} cannot marginalize it — train with "
                "TraceEnum_ELBO (or sample the site in the guide and drop the "
                "annotation)"
            )


def _single_particle_elbo(rng_key, params, model, guide, args, kwargs):
    """One MC sample of the ELBO with a reparameterized/score-function split."""
    key_guide, key_model = jax.random.split(rng_key)
    seeded_guide = seed(substitute_params(guide, params), key_guide)
    guide_tr = trace(seeded_guide).get_trace(*args, **kwargs)
    seeded_model = seed(substitute_params(model, params), key_model)
    model_tr = trace(replay(seeded_model, guide_tr)).get_trace(*args, **kwargs)
    check_no_enumerate_sites(model_tr, guide_tr, "Trace_ELBO")

    elbo = 0.0
    score_logq = 0.0  # sum of log q at non-reparam sites (REINFORCE factor)
    for name, site in model_tr.nodes.items():
        if site["type"] != "sample":
            continue
        lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
        elbo = elbo + jnp.sum(lp)
    for name, site in guide_tr.nodes.items():
        if site["type"] != "sample" or site["is_observed"]:
            continue
        lq = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
        elbo = elbo - jnp.sum(lq)
        if not site["fn"].has_rsample:
            score_logq = score_logq + jnp.sum(lq)
    # surrogate so that grad(surrogate) is an unbiased ELBO gradient:
    #   d/dtheta [elbo + stop_grad(elbo) * score_logq]
    surrogate = elbo + jax.lax.stop_gradient(elbo) * (
        score_logq - jax.lax.stop_gradient(score_logq)
    )
    return elbo, surrogate


class Trace_ELBO(ELBO):
    """Monte-Carlo ELBO (paper default), vectorized by the shared engine."""

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        return _single_particle_elbo(rng_key, params, model, guide, args, kwargs)


class TraceMeanField_ELBO(Trace_ELBO):
    """Analytic-KL ELBO: uses registered closed-form KL(q||p) at latent sites
    where available (mean-field assumption: guide sites independent given
    upstream), falling back to the MC estimate elsewhere."""

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        key_guide, key_model = jax.random.split(rng_key)
        guide_tr = trace(seed(substitute_params(guide, params), key_guide)).get_trace(
            *args, **kwargs
        )
        model_tr = trace(
            replay(seed(substitute_params(model, params), key_model), guide_tr)
        ).get_trace(*args, **kwargs)
        check_no_enumerate_sites(model_tr, guide_tr, "TraceMeanField_ELBO")
        elbo = 0.0
        for name, site in model_tr.nodes.items():
            if site["type"] != "sample":
                continue
            if site["is_observed"]:
                lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
                elbo = elbo + jnp.sum(lp)
            else:
                guide_site = guide_tr.nodes[name]
                try:
                    kl = kl_divergence(guide_site["fn"], site["fn"])
                    kl = _apply_scale_mask(kl, site)
                    elbo = elbo - jnp.sum(kl)
                except NotImplementedError:
                    lp = _apply_scale_mask(site["fn"].log_prob(site["value"]), site)
                    lq = _apply_scale_mask(
                        guide_site["fn"].log_prob(guide_site["value"]), guide_site
                    )
                    elbo = elbo + jnp.sum(lp) - jnp.sum(lq)
        return elbo, elbo


class RenyiELBO(ELBO):
    """Renyi alpha-divergence bound (alpha=0 -> IWAE). Uses the shared
    particle path; with num_particles == 1 the bound degenerates to the
    plain single-sample ELBO (same guard pattern as the other estimators)."""

    def __init__(
        self,
        alpha: float = 0.0,
        num_particles: int = 2,
        mesh: Optional[Mesh] = None,
        particle_axis: Union[str, Tuple[str, ...], None] = None,
    ):
        if alpha == 1.0:
            raise ValueError("RenyiELBO is undefined at alpha=1 (use Trace_ELBO)")
        super().__init__(num_particles, mesh=mesh, particle_axis=particle_axis)
        self.alpha = alpha

    def _single_particle(self, rng_key, params, model, guide, args, kwargs):
        elbo, _ = _single_particle_elbo(rng_key, params, model, guide, args, kwargs)
        return elbo, elbo

    def _reduce(self, log_weights, _surrogates):
        scaled = (1.0 - self.alpha) * log_weights
        bound = log_mean_exp(scaled) / (1.0 - self.alpha)
        # surrogate: self-normalized importance weighting
        w = jax.nn.softmax(jax.lax.stop_gradient(scaled))
        surrogate = jnp.sum(w * log_weights)
        return -bound, -surrogate
