"""Persistent XLA compilation cache: amortize cold starts across runs.

The contraction planner cuts what a cold trace *builds*; this module makes
the XLA compile of what remains a one-time cost per (program, jaxlib,
backend) by pointing ``jax_compilation_cache_dir`` at a directory that
survives the process — locally under the user's cache dir, in CI via
``actions/cache``. The second run of the same launch/serve/bench program
then deserializes executables instead of recompiling them.

Knobs (all optional):

* ``REPRO_COMPILATION_CACHE_DIR`` — cache directory. ``0``/``off`` disables
  persistence entirely; unset falls back to
  ``$XDG_CACHE_HOME/repro/xla-cache`` (or ``~/.cache/repro/xla-cache``).
* ``REPRO_COMPILATION_CACHE_MIN_COMPILE_S`` — only persist programs whose
  compile took at least this long (default ``0.5``; tiny programs aren't
  worth the disk round-trip).
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Dict, Optional

import jax

from .. import settings

_OFF = ("0", "false", "off", "none")


def cache_dir() -> Optional[Path]:
    """Resolved compilation-cache directory, or None when disabled."""
    if settings.is_set("REPRO_COMPILATION_CACHE_DIR"):
        env = settings.get_str("REPRO_COMPILATION_CACHE_DIR")
        if env.strip().lower() in _OFF:
            return None
        return Path(env).expanduser()
    base = os.environ.get("XDG_CACHE_HOME")
    root = Path(base).expanduser() if base else Path.home() / ".cache"
    return root / "repro" / "xla-cache"


def enable_compilation_cache() -> Optional[Path]:
    """Point JAX's persistent compilation cache at `cache_dir()`.

    Idempotent and safe to call before any JAX computation (launch mains call
    it right after argument parsing). Returns the directory in use, or None
    when persistence is disabled. Never raises: an unwritable directory just
    means cold compiles stay cold."""
    path = cache_dir()
    if path is None:
        return None
    try:
        path.mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(path))
        # persist anything that took real compile time; leave trivial
        # executables out so the cache stays small and the hit path hot
        jax.config.update(
            "jax_persistent_cache_min_compile_time_secs",
            settings.get_float("REPRO_COMPILATION_CACHE_MIN_COMPILE_S"),
        )
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception as exc:  # pragma: no cover - depends on fs/jax build
        print(f"warning: persistent compilation cache disabled ({exc})")
        return None
    return path


def compilation_cache_stats() -> Dict:
    """Entry count + on-disk bytes of the persistent cache directory (the
    bench stage prints this so the warm path is visibly exercised)."""
    path = cache_dir()
    if path is None or not path.is_dir():
        return {"dir": str(path) if path else None, "entries": 0, "bytes": 0}
    files = [p for p in path.rglob("*") if p.is_file()]
    return {
        "dir": str(path),
        "entries": len(files),
        "bytes": sum(p.stat().st_size for p in files),
    }
