"""Training driver: SVI-as-training-loop with checkpoint/auto-resume,
async saves, the step watchdog, and (multi-pod) compressed cross-pod
gradient reduction.

CPU-runnable end-to-end (reduced configs):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

from .. import configs
from ..checkpoint import AsyncCheckpointer, latest_step, restore
from ..data import DataConfig, SyntheticTokens
from ..distributed import StepWatchdog
from ..distributed.sharding import activation_sharding_scope
from ..models import init_params, make_train_step
from ..models.frontends import frontend_embed
from ..optim import AdamW
from ..optim.schedules import warmup_cosine
from .compile_cache import enable_compilation_cache
from .mesh import make_host_mesh


def build(cfg, *, lr: float = 3e-4, steps: int = 1000, clip: float = 1.0):
    optimizer = AdamW(warmup_cosine(lr, min(100, steps // 10 + 1), steps),
                      clip_norm=clip, weight_decay=0.01)
    step_fn = make_train_step(cfg, optimizer)
    return optimizer, step_fn


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--config", choices=["full", "mid", "smoke"], default=None,
                    help="full = exact assigned config; mid = ~25M CPU-trainable; "
                         "smoke = tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", choices=["auto", "never"], default="auto")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cache = enable_compilation_cache()
    if cache is not None:
        print(f"compilation cache: {cache}")
    tier = args.config or ("smoke" if args.smoke else "full")
    if tier == "smoke":
        cfg = configs.get_smoke_config(args.arch)
    elif tier == "mid":
        cfg = configs.get_config(args.arch).replace(
            n_layers=12, d_model=384, n_heads=6, n_kv_heads=2, d_ff=1024,
            vocab=8192, param_dtype="float32", compute_dtype="float32",
            remat=False,
        )
    else:
        cfg = configs.get_config(args.arch)
    mesh = make_host_mesh()
    optimizer, step_fn = build(cfg, lr=args.lr, steps=args.steps)

    data = SyntheticTokens(DataConfig(cfg.vocab, args.seq, args.batch, seed=args.seed))

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} steps={args.steps} "
          f"batch={args.batch} seq={args.seq}")
    opt_state = optimizer.init(params)
    start_step = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume == "auto" and latest_step(args.ckpt_dir) is not None:
        start_step, opt_state = restore(args.ckpt_dir, template=opt_state)
        print(f"resumed from step {start_step}")

    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    watchdog = StepWatchdog(
        on_straggler=lambda i, dt, ewma: print(
            f"  [watchdog] step {i} straggler: {dt*1e3:.0f}ms vs EWMA {ewma*1e3:.0f}ms"
        )
    )

    losses = []
    with mesh, activation_sharding_scope(mesh):
        for step in range(start_step, args.steps):
            batch = data.global_batch(step)
            if cfg.modality == "audio":
                batch = {"inputs": frontend_embed(cfg, batch["tokens"]),
                         "targets": batch["targets"]}
            elif cfg.modality == "vlm":
                key = jax.random.fold_in(jax.random.PRNGKey(args.seed), step)
                patches = jax.random.normal(key, batch["tokens"].shape + (32,))
                batch = {"inputs": frontend_embed(cfg, patches),
                         "targets": batch["targets"]}
            t0 = time.time()
            opt_state, metrics = jit_step(opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            watchdog.observe(dt)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, opt_state)
    if ckpt:
        ckpt.save_async(args.steps, opt_state)
        ckpt.wait()
    if losses:
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    else:
        # resume landed at/after --steps: nothing to train, nothing to print
        print(f"no steps to run (resumed at {start_step}, --steps {args.steps})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
