"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, dump memory/cost analysis and roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json

The 512 placeholder host devices exist ONLY here (the env-var assignment
below must run before any jax import — do not import this module from
tests)."""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402 — before any jax import

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import configs
from ..distributed.sharding import (
    activation_sharding_scope,
    batch_shardings,
    cache_shardings,
    param_shardings,
    replicated,
)
from ..models import (
    init_cache,
    init_params,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from ..optim import Adam
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh
from .roofline import Roofline, model_flops


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg, shape_spec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    B, S = shape_spec.global_batch, shape_spec.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.modality in ("audio", "vlm"):
        emb = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        batch = {"inputs": emb, "targets": tok}
        one = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    else:
        batch = {"tokens": tok, "targets": tok}
        one = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return {"batch": batch, "one_token": one}


def abstract_params(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def _analyze(lowered, compiled, *, label: str, verbose: bool = True) -> Tuple[dict, dict]:
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    if verbose:
        print(f"  [{label}] memory_analysis: {mem_d}")
        print(f"  [{label}] cost_analysis: flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}")
    return cost, mem_d


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    verbose: bool = True,
    hlo_dir: Optional[str] = None,
    cfg_override=None,
    baseline: bool = False,
) -> dict:
    """Lower+compile one (arch, shape, mesh) cell; return the record.
    baseline=True reproduces the paper-faithful pre-hillclimb configuration
    (dots remat policy, XLA-default attention VJP, rank-sharded MLA cache,
    unpinned prefill cache shardings) for the §Perf before/after table."""
    spec = configs.SHAPES[shape]
    cfg = cfg_override or configs.get_config(arch)
    if baseline:
        cfg = cfg.replace(remat_policy="dots", attn_impl="blockwise", seq_parallel=False)
    elif cfg_override is None:
        # beyond-paper default (§Perf). Fine-grained MoE (>=64 experts) is
        # excluded: S-sharded residuals inflate its dispatch all-to-alls
        # more than they save in HBM (measured: deepseek-v2-lite train
        # frac 0.049 -> 0.039 with SP on; see EXPERIMENTS §Perf).
        cfg = cfg.replace(seq_parallel=not (cfg.moe and cfg.n_experts >= 64))
    mla_mode = "rank" if baseline else "seq"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()

    params_a = abstract_params(cfg)
    p_shard = param_shardings(params_a, mesh)

    if spec.kind == "train":
        optimizer = Adam(3e-4)
        state_a = jax.eval_shape(optimizer.init, params_a)
        # optimizer moments shard exactly like params; step counter replicated
        from ..optim.optimizers import OptState

        s_shard = OptState(replicated(mesh), p_shard, p_shard, p_shard)
        batch_a = input_specs(cfg, spec)["batch"]
        b_shard = batch_shardings(batch_a, mesh)
        step = make_train_step(cfg, optimizer)
        jitted = jax.jit(
            step,
            in_shardings=(s_shard, b_shard),
            out_shardings=(s_shard, replicated(mesh)),
            donate_argnums=(0,),
        )
        with mesh, activation_sharding_scope(mesh):
            lowered = jitted.lower(state_a, batch_a)
    elif spec.kind == "prefill":
        batch_a = input_specs(cfg, spec)
        tokens_a = batch_a["batch"].get("tokens", batch_a["batch"].get("inputs"))
        t_shard = batch_shardings(tokens_a, mesh)
        step = make_prefill_step(cfg)
        # out_shardings MUST pin the returned cache: leaving it unspecified
        # lets XLA replicate the KV cache — a ~TB-scale all-gather
        # (the deepseek-coder prefill hillclimb finding, EXPERIMENTS §Perf)
        if baseline:
            jitted = jax.jit(step, in_shardings=(p_shard, t_shard))
        else:
            cache_a = jax.eval_shape(step, params_a, tokens_a)[1]
            c_shard = cache_shardings(cache_a, cfg, mesh, mla_mode=mla_mode)
            last_shard = batch_shardings(
                jax.ShapeDtypeStruct((spec.global_batch, cfg.vocab), jnp.float32), mesh
            )
            jitted = jax.jit(step, in_shardings=(p_shard, t_shard),
                             out_shardings=(last_shard, c_shard))
        with mesh, activation_sharding_scope(mesh):
            lowered = jitted.lower(params_a, tokens_a)
    else:  # decode
        cache_a = abstract_cache(cfg, spec.global_batch, spec.seq_len)
        c_shard = cache_shardings(cache_a, cfg, mesh, mla_mode=mla_mode)
        one_a = input_specs(cfg, spec)["one_token"]
        o_shard = batch_shardings(one_a, mesh)
        rng_a = jax.ShapeDtypeStruct((2,), jnp.uint32)
        step = make_decode_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, o_shard, replicated(mesh)),
            out_shardings=(
                batch_shardings(jax.ShapeDtypeStruct((spec.global_batch,), jnp.int32), mesh),
                c_shard,
                batch_shardings(
                    jax.ShapeDtypeStruct((spec.global_batch, cfg.vocab), jnp.float32), mesh
                ),
            ),
            donate_argnums=(1,),
        )
        with mesh, activation_sharding_scope(mesh):
            lowered = jitted.lower(params_a, cache_a, one_a, rng_a)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost, mem_d = _analyze(lowered, compiled, label=f"{arch}/{shape}/{mesh_name}",
                           verbose=verbose)
    hlo = compiled.as_text()
    # trip-count-aware walker (XLA's cost_analysis counts while bodies once)
    hc = analyze_hlo(hlo)
    coll = {k: v for k, v in hc.collectives.items()}
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, f"{arch}_{shape}_{mesh_name}.hlo"), "w") as f:
            f.write(hlo)

    rf = Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=hc.flops,
        bytes_per_device=hc.bytes,
        collective_bytes_per_device=hc.collective_bytes,
        model_flops=model_flops(cfg, spec.seq_len, spec.global_batch, spec.kind),
    )
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "variant": "baseline" if baseline else "optimized",
        "kind": spec.kind,
        "chips": chips,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "memory": mem_d,
        "collectives": coll,
        "roofline": rf.to_dict(),
    }
    if verbose:
        print(f"  [{arch}/{shape}/{mesh_name}] collectives: "
              f"{ {k: f'{v/1e9:.2f}GB' for k, v in coll.items() if v} }")
        print(f"  [{arch}/{shape}/{mesh_name}] roofline: "
              f"compute={rf.t_compute*1e3:.1f}ms memory={rf.t_memory*1e3:.1f}ms "
              f"collective={rf.t_collective*1e3:.1f}ms -> {rf.bottleneck}-bound, "
              f"useful={rf.useful_flops_ratio:.2f} frac={rf.roofline_fraction:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful pre-hillclimb configuration")
    args = ap.parse_args(argv)

    if args.all:
        cell_list = configs.cells()
    else:
        archs = [args.arch] if args.arch else list(configs.ARCHS)
        shapes = [args.shape] if args.shape else list(configs.SHAPES)
        cell_list = [
            (a, s) for a in archs for s in shapes if configs.shape_applicable(a, s)
        ]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    failures = 0
    for arch, shape in cell_list:
        for mp in meshes:
            try:
                records.append(run_cell(arch, shape, multi_pod=mp,
                                        hlo_dir=args.hlo_dir, baseline=args.baseline))
            except Exception as e:
                failures += 1
                print(f"FAIL {arch}/{shape}/{'multi' if mp else 'single'}: {e!r}")
                records.append({"arch": arch, "shape": shape,
                                "mesh": "multi" if mp else "single",
                                "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out} ({len(records)} records, {failures} failures)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
