"""Trip-count-aware cost model over post-optimization HLO text.

XLA's built-in `compiled.cost_analysis()` visits each called computation
ONCE — a 30-iteration `while` (scan-over-layers) is counted as a single
iteration, silently under-reporting flops/bytes/collectives by ~L× for
scanned models. This walker re-derives the three roofline inputs from the
HLO text, multiplying `while` bodies by their `known_trip_count`:

    flops            — dot ops: 2 * prod(result) * contracted-size
    bytes accessed   — per top-level op: operand bytes + result bytes
                       (fusions count their external operands/results only,
                       matching post-fusion HBM traffic)
    collective bytes — result bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute

All quantities are per-device (shapes in SPMD-partitioned HLO are local).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|calls|branch_computations)=\{?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "rng-get-and-update-state",
}


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    result_text: str
    op: str
    rest: str  # everything after the open paren (operands + attrs)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # value name -> result text


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.collectives:
            self.collectives[k] += other.collectives.get(k, 0.0)
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.collectives.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation headers start at column 0 and end with '{'
        # (instructions are indented; nested-tuple parameter lists make a
        # full-grammar regex fragile)
        if not raw.startswith(" ") and stripped.endswith("{"):
            is_entry = stripped.startswith("ENTRY")
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m and m.group(1) != "HloModule":
                current = Computation(m.group(1))
                comps[current.name] = current
                if is_entry:
                    entry = current.name
            continue
        if stripped == "}" or stripped.startswith("} "):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            # parameters in canonical text: "%p = f32[..] parameter(0)" is
            # matched above; anything else (attrs continuation) is skipped
            continue
        name, result_text, op, rest = m.groups()
        current.instrs.append(Instr(name, result_text, op, rest))
        current.shapes[name] = result_text
    return comps, entry


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _operands(instr: Instr, comp: Computation) -> List[str]:
    """Operand result-texts (resolved through the computation's symbols).
    Only scans the operand list — the text up to the closing paren depth 0."""
    depth = 1
    ops_txt = []
    for ch in instr.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        ops_txt.append(ch)
    txt = "".join(ops_txt)
    out = []
    for nm in _OPERAND_RE.findall(txt):
        if nm in comp.shapes:
            out.append(comp.shapes[nm])
    return out


def _dot_flops(instr: Instr, comp: Computation) -> float:
    result_elems = 1
    shapes = _parse_shapes(instr.result_text)
    if not shapes:
        return 0.0
    for d in shapes[0][1]:
        result_elems *= d
    ops = _operands(instr, comp)
    if not ops:
        return 0.0
    lhs = _parse_shapes(ops[0])
    if not lhs:
        return 0.0
    lhs_shape = lhs[0][1]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contracted = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            contracted *= lhs_shape[int(d)] if int(d) < len(lhs_shape) else 1
    return 2.0 * result_elems * contracted


def _conv_flops(instr: Instr, comp: Computation) -> float:
    shapes = _parse_shapes(instr.result_text)
    ops = _operands(instr, comp)
    if not shapes or len(ops) < 2:
        return 0.0
    out_elems = 1
    for d in shapes[0][1]:
        out_elems *= d
    ker = _parse_shapes(ops[1])
    k_elems = 1
    if ker:
        for d in ker[0][1]:
            k_elems *= d
        # divide by output-feature dim (approx: per-output flops = 2*prod(kernel)/O)
        if ker[0][1]:
            k_elems //= max(ker[0][1][-1], 1)
    return 2.0 * out_elems * max(k_elems, 1)


def cost_of(comp_name: str, comps: Dict[str, Computation],
            memo: Optional[Dict[str, Cost]] = None) -> Cost:
    memo = memo if memo is not None else {}
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    total = Cost()
    if comp is None:
        return total
    memo[comp_name] = total  # break cycles defensively
    for ins in comp.instrs:
        if ins.op == "while":
            trips = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trips = int(mt.group(1))
            called = _CALLED_RE.findall(ins.rest)
            for c in called:
                total += cost_of(c, comps, memo).scaled(trips)
            continue
        if ins.op in ("fusion", "call", "conditional", "map", "custom-call",
                      "reduce", "reduce-window", "sort", "scatter", "select-and-scatter",
                      "all-reduce", "reduce-scatter"):
            # recurse for flops of called computations (fusion bodies hold
            # the dots); bytes counted at this (fused) level only
            for c in _CALLED_RE.findall(ins.rest):
                sub = cost_of(c, comps, memo)
                total.flops += sub.flops
        if ins.op == "dot":
            total.flops += _dot_flops(ins, comp)
        elif ins.op == "convolution":
            total.flops += _conv_flops(ins, comp)
        # ---- collectives ----
        for k in _COLLECTIVES:
            if ins.op == k or ins.op.startswith(k + "-"):
                if not ins.op.endswith("-done"):
                    total.collectives[k] += _bytes_of(ins.result_text)
                break
        # ---- bytes ----
        if ins.op in _SKIP_BYTES:
            continue
        rb = _bytes_of(ins.result_text)
        if ins.op in ("dynamic-slice", "slice", "gather"):
            total.bytes += 2 * rb
        elif ins.op == "dynamic-update-slice":
            ops = _operands(ins, comp)
            upd = _bytes_of(ops[1]) if len(ops) > 1 else rb
            total.bytes += 2 * upd
        elif ins.op == "fusion":
            total.bytes += _fusion_bytes(ins, comp, comps)
        else:
            ob = sum(_bytes_of(t) for t in _operands(ins, comp))
            total.bytes += rb + ob
    memo[comp_name] = total
    return total


_PARAM_IDX_RE = re.compile(r"^(\d+)\)")


def _fusion_bytes(ins: Instr, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM traffic of a fusion, aware of internal dataflow:
      * an operand consumed only via dynamic-slice costs the slice (x2);
      * an operand that is the in-place target of a root dynamic-update-slice
        costs 2 x update-size, and the (aliased) result costs nothing;
      * everything else costs its full size (read) + result (write).
    This matches XLA's aliasing of scan-carry accumulators — without it, a
    (L, B, S, D) stacked buffer updated once per layer is charged L x full
    size instead of L x slice."""
    operand_texts = _operands(ins, comp)
    called = _CALLED_RE.findall(ins.rest)
    inner = comps.get(called[0]) if called else None
    rb = _bytes_of(ins.result_text)
    if inner is None:
        return rb + sum(_bytes_of(t) for t in operand_texts)

    # map inner parameter name -> operand index
    param_of: Dict[str, int] = {}
    for ii in inner.instrs:
        if ii.op == "parameter":
            m = _PARAM_IDX_RE.match(ii.rest)
            if m:
                param_of[ii.name] = int(m.group(1))
    # usage classification per parameter
    SLICED, ALIASED, FULL = 1, 2, 3
    usage: Dict[int, int] = {}
    root = inner.instrs[-1] if inner.instrs else None
    for ii in inner.instrs:
        if ii.op == "parameter":
            continue
        # operand list = text up to the closing paren at depth 0
        depth = 1
        buf = []
        for ch in ii.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        inner_ops = _OPERAND_RE.findall("".join(buf))
        for pos, nm in enumerate(inner_ops):
            if nm not in param_of:
                continue
            idx = param_of[nm]
            if ii.op == "dynamic-slice" and pos == 0:
                usage[idx] = max(usage.get(idx, 0), SLICED)
            elif ii.op == "dynamic-update-slice" and pos == 0 and ii is root:
                usage[idx] = max(usage.get(idx, 0), ALIASED)
            else:
                usage[idx] = FULL

    bytes_total = 0.0
    root_is_dus = root is not None and root.op == "dynamic-update-slice"
    if root_is_dus:
        r_ops = _OPERAND_RE.findall(root.rest.split("), ")[0])
        upd = inner.shapes.get(r_ops[1]) if len(r_ops) > 1 else None
        bytes_total += 2 * (_bytes_of(upd) if upd else 0)
    else:
        bytes_total += rb
    for i, t in enumerate(operand_texts):
        u = usage.get(i, FULL)
        if u == ALIASED and root_is_dus:
            continue  # accounted as the update write/read
        if u == SLICED:
            # slice size: find the inner dynamic-slice result for this param
            sz = 0
            for ii in inner.instrs:
                if ii.op == "dynamic-slice":
                    sz = max(sz, _bytes_of(ii.result_text))
            bytes_total += 2 * sz
        else:
            bytes_total += _bytes_of(t)
    return bytes_total


def analyze_hlo(hlo: str) -> Cost:
    comps, entry = parse_module(hlo)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    # memoized costs are PER CALL; fusions called from while bodies are
    # handled by the recursion, so just walk the entry
    return cost_of(entry, comps, {})
