"""Production mesh construction (assignment spec).

Kept as functions — importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis
    extends data parallelism across the DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
