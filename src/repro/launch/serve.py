"""Serving driver: batched prefill + decode with a static KV/SSM cache.

CPU-runnable (reduced configs):
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \\
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import init_cache, init_params, make_decode_step, forward


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    if cfg.modality != "text":
        print(f"note: serving the {cfg.modality} backbone over token ids "
              "(frontend stubs are for training shapes)")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    total = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    cache = init_cache(cfg, args.batch, total)
    t0 = time.time()
    logits, cache, _ = forward(cfg, params, prompts, mode="prefill", cache=cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        nxt, cache, _ = decode(params, cache, tok, jax.random.fold_in(key, i))
        tok = nxt[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.0f} ms  decode: {t_decode*1e3/max(args.gen-1,1):.1f} ms/tok")
    print("sample token ids:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
