"""Serving CLI: posterior endpoints (`repro.serve`) and LM decode.

Subcommands:

``posterior`` — the production posterior-serving path. Trains (or
warm-starts from a `checkpoint.store` directory) a Bayesian regression
artifact, registers it as a `ServableModel`, and drives synthetic traffic
through the dynamic micro-batcher, printing latency / throughput /
queue-depth stats and the compile-per-bucket retrace contract::

    PYTHONPATH=src python -m repro.launch.serve posterior --smoke
    PYTHONPATH=src python -m repro.launch.serve posterior \\
        --checkpoint /tmp/ckpt --requests 200 --max-batch 32 --mesh

``lm`` — batched prefill + decode with a static KV/SSM cache over the
model zoo (CPU-runnable at reduced configs)::

    PYTHONPATH=src python -m repro.launch.serve lm --arch mamba2-130m \\
        --smoke --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from .compile_cache import enable_compilation_cache

# ---------------------------------------------------------------------------
# posterior serving
# ---------------------------------------------------------------------------

_DIM = 4


def _regression_model(x, y=None):
    """Demo artifact: Bayesian linear regression with a learned noise scale."""
    from .. import distributions as dist
    from ..core import primitives as P

    w = P.sample("w", dist.Normal(jnp.zeros(_DIM), 1.0).to_event(1))
    b = P.sample("b", dist.Normal(0.0, 1.0))
    with P.plate("B", x.shape[0]):
        mu = P.deterministic("mu", x @ w + b)
        P.sample("y", dist.Normal(mu, 0.1), obs=y)


def _train_artifact(steps: int, seed: int):
    """Fit the demo model with SVI; returns (guide, unconstrained params)."""
    from .. import optim
    from ..infer import SVI, AutoNormal, Trace_ELBO

    key = jax.random.PRNGKey(seed)
    k_x, k_w, k_y, k_svi = jax.random.split(key, 4)
    x = jax.random.normal(k_x, (256, _DIM))
    w_true = jax.random.normal(k_w, (_DIM,))
    y = x @ w_true + 0.7 + 0.1 * jax.random.normal(k_y, (256,))

    guide = AutoNormal(_regression_model)
    svi = SVI(_regression_model, guide, optim.Adam(0.05), Trace_ELBO())
    state, losses = svi.run(k_svi, steps, x, y=y)
    params = svi.optim.get_params(state.optim_state)
    return guide, params, float(losses[-1])


def serve_posterior(args) -> int:
    from ..checkpoint import store
    from ..infer import AutoNormal
    from ..serve import MicroBatcher, ServableModel, register

    mesh = None
    if args.mesh:
        from ..distributed.sharding import default_mesh

        mesh = default_mesh()

    t0 = time.time()
    ckpt_step = store.latest_step(args.checkpoint) if args.checkpoint else None
    if ckpt_step is not None:
        # warm start: boot the endpoint from the latest committed checkpoint
        servable = ServableModel.from_checkpoint(
            "regression", _regression_model, args.checkpoint,
            guide=AutoNormal(_regression_model), num_samples=args.num_samples,
            max_batch=args.max_batch, mesh=mesh,
            # dummy training-shaped call so the fresh autoguide's prototype
            # covers exactly the latents the checkpoint has params for
            guide_args=(jnp.zeros((1, _DIM)),),
            guide_kwargs={"y": jnp.zeros(1)},
        )
        print(f"warm start: restored step {servable.restored_step} from "
              f"{args.checkpoint} in {time.time() - t0:.2f}s")
    else:
        guide, params, last_loss = _train_artifact(args.train_steps, args.seed)
        print(f"trained artifact: {args.train_steps} SVI steps "
              f"(final loss {last_loss:.2f}) in {time.time() - t0:.2f}s")
        if args.checkpoint:
            store.save(args.checkpoint, 0, {"params": params})
            print(f"saved artifact to {args.checkpoint} (step 0); rerun to warm-start")
        servable = ServableModel.from_svi(
            "regression", _regression_model, guide, params,
            num_samples=args.num_samples, max_batch=args.max_batch, mesh=mesh,
        )
    register(servable, replace=True)

    # synthetic traffic: bursts of concurrent variable-size requests
    rng = jax.random.PRNGKey(args.seed + 1)
    sizes = jax.random.randint(
        rng, (args.requests,), 1, max(args.max_request, 2)
    ).tolist()
    print(f"serving {args.requests} requests (sizes 1..{args.max_request - 1}, "
          f"bursts of {args.concurrency}, max_wait {args.max_wait_ms}ms, "
          f"mesh={'1d-data' if mesh is not None else 'none'})")

    t_serve = time.time()
    with MicroBatcher(
        servable.engine, max_wait_ms=args.max_wait_ms,
        rng_key=jax.random.PRNGKey(args.seed + 2),
    ) as mb:
        done = 0
        while done < len(sizes):
            burst = sizes[done : done + args.concurrency]
            futs = []
            for i, n in enumerate(burst):
                x = jax.random.normal(jax.random.fold_in(rng, done + i), (n, _DIM))
                futs.append(mb.submit(x))
            for f in futs:
                f.result(timeout=120)
            done += len(burst)
        summary = mb.stats.summary()
    t_serve = time.time() - t_serve

    print(f"\n-- stats ({t_serve:.2f}s wall) " + "-" * 40)
    for k in ("requests", "batches", "requests_per_sec", "rows_per_sec",
              "p50_ms", "p99_ms", "mean_batch_rows", "max_queue_depth", "pad_waste"):
        print(f"  {k:>18}: {summary[k]}")
    buckets = sorted(servable.buckets_touched)
    print(f"  {'buckets_touched':>18}: {buckets}")
    print(f"  {'compiles':>18}: {servable.num_traces} (contract: == {len(buckets)})")
    if servable.num_traces != len(buckets):
        print("RETRACE REGRESSION: compiles != shape buckets", file=sys.stderr)
        return 1
    return 0


# ---------------------------------------------------------------------------
# LM decode serving (the model-zoo driver, unchanged semantics)
# ---------------------------------------------------------------------------


def serve_lm(args) -> int:
    from .. import configs
    from ..models import forward, init_cache, init_params, make_decode_step

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    if cfg.modality != "text":
        print(f"note: serving the {cfg.modality} backbone over token ids "
              "(frontend stubs are for training shapes)")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    total = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    cache = init_cache(cfg, args.batch, total)
    t0 = time.time()
    logits, cache, _ = forward(cfg, params, prompts, mode="prefill", cache=cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        nxt, cache, _ = decode(params, cache, tok, jax.random.fold_in(key, i))
        tok = nxt[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.0f} ms  decode: {t_decode*1e3/max(args.gen-1,1):.1f} ms/tok")
    print("sample token ids:", gen[0, :16].tolist())
    return 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    pp = sub.add_parser("posterior", help="serve a posterior artifact")
    pp.add_argument("--smoke", action="store_true", help="CI-sized run")
    pp.add_argument("--checkpoint", default=None,
                    help="checkpoint dir: warm-start if it has a committed "
                         "step, else train + save there")
    pp.add_argument("--train-steps", type=int, default=200)
    pp.add_argument("--num-samples", type=int, default=8,
                    help="posterior draws per request")
    pp.add_argument("--requests", type=int, default=200)
    pp.add_argument("--max-request", type=int, default=8,
                    help="request sizes are drawn uniform from [1, this)")
    pp.add_argument("--max-batch", type=int, default=32)
    pp.add_argument("--max-wait-ms", type=float, default=2.0)
    pp.add_argument("--concurrency", type=int, default=8)
    pp.add_argument("--mesh", action="store_true",
                    help="shard the batch axis over all local devices")
    pp.add_argument("--seed", type=int, default=0)

    lp = sub.add_parser("lm", help="LM prefill+decode driver")
    lp.add_argument("--arch", default="smollm-135m")
    lp.add_argument("--smoke", action="store_true")
    lp.add_argument("--batch", type=int, default=4)
    lp.add_argument("--prompt-len", type=int, default=32)
    lp.add_argument("--gen", type=int, default=32)
    lp.add_argument("--seed", type=int, default=0)
    lp.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    cache = enable_compilation_cache()
    if cache is not None:
        print(f"compilation cache: {cache}")
    if args.cmd == "posterior":
        if args.smoke:
            args.train_steps = min(args.train_steps, 30)
            args.requests = min(args.requests, 40)
            args.max_batch = min(args.max_batch, 16)
        return serve_posterior(args)
    return serve_lm(args)


if __name__ == "__main__":
    sys.exit(main())
