"""Streaming inference service CLI: posteriors that never go stale.

Boots the full production loop in one process and drives it with live
HTTP traffic::

    PYTHONPATH=src python -m repro.launch.stream --smoke
    PYTHONPATH=src python -m repro.launch.stream \\
        --requests 400 --clients 8 --ckpt-every 25 --deadline-ms 250

What runs:

* a `data.pipeline.RegressionStream` (drifting true weights) behind a
  host-side `Prefetcher`;
* a `serve.StreamingTrainer` running incremental SVI steps on a
  background thread, checkpointing via `save_async` and hot-swapping the
  live servable on every commit (`hot_swap_on_commit`);
* a `serve.InferenceServer` (stdlib HTTP) exposing a multi-model registry
  — the streaming endpoint plus a frozen boot-time snapshot — with
  deadline-aware load shedding and the simulated device-loss remesh
  endpoint;
* concurrent HTTP clients hammering ``:predict`` throughout.

Exit is non-zero if the hard serving contract breaks: any dropped/errored
request, any recompile across hot swaps (``num_traces`` must stay ==
buckets touched), or zero completed swaps.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
import urllib.error
import urllib.request
from typing import Dict, List

import jax
import jax.numpy as jnp

from .compile_cache import enable_compilation_cache

_DIM = 4


def _stream_model(batch):
    """Bayesian linear regression over streaming batches. One positional
    arg (the serving contract); ``y`` present = training, absent = serving."""
    from .. import distributions as dist
    from ..core import primitives as P

    x = batch["x"]
    y = batch.get("y")
    w = P.sample("w", dist.Normal(jnp.zeros(_DIM), 1.0).to_event(1))
    b = P.sample("b", dist.Normal(0.0, 1.0))
    with P.plate("B", x.shape[0]):
        mu = P.deterministic("mu", x @ w + b)
        P.sample("y", dist.Normal(mu, 0.1), obs=y)


def _post(address: str, path: str, payload: Dict, timeout: float = 60.0):
    req = urllib.request.Request(
        address + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST",
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}")


def _get(address: str, path: str, timeout: float = 10.0):
    with urllib.request.urlopen(address + path, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.stream", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--dir", default=None,
                    help="checkpoint dir (default: a fresh temp dir)")
    ap.add_argument("--requests", type=int, default=200,
                    help="total HTTP predict requests")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-request", type=int, default=8,
                    help="request sizes drawn uniform from [1, this)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (shed with 429 beyond it)")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--batch-rows", type=int, default=64,
                    help="training rows per stream step")
    ap.add_argument("--step-interval-ms", type=float, default=5.0,
                    help="pace the trainer so it doesn't starve serving "
                         "(0 = train flat out)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 60)
        args.max_batch = min(args.max_batch, 16)
        args.ckpt_every = min(args.ckpt_every, 10)
        args.batch_rows = min(args.batch_rows, 32)

    cache = enable_compilation_cache()
    if cache is not None:
        print(f"compilation cache: {cache}")

    import tempfile

    from .. import optim
    from ..data.pipeline import Prefetcher, RegressionStream, RegressionStreamConfig
    from ..infer import SVI, AutoDelta, Trace_ELBO
    from ..serve import (
        InferenceServer, ServableModel, StreamingTrainer, hot_swap_on_commit,
        register,
    )

    directory = args.dir or tempfile.mkdtemp(prefix="repro-stream-")

    # -- artifact boot: a few eager SVI steps so the servable starts sane ----
    stream = RegressionStream(
        RegressionStreamConfig(dim=_DIM, batch=args.batch_rows,
                               seed=args.seed, drift=0.002)
    )
    guide = AutoDelta(_stream_model)
    svi = SVI(_stream_model, guide, optim.Adam(0.05), Trace_ELBO())
    state = svi.init(jax.random.PRNGKey(args.seed), stream.batch(0))
    for warm in range(5):
        state, loss = svi.update_jit(state, stream.batch(warm))
    params0 = svi.optim.get_params(state.optim_state)
    print(f"boot artifact: 5 warmup steps, loss {float(loss):.2f}, "
          f"svi.num_traces={svi.num_traces}")

    # -- multi-model registry: the live streaming endpoint + a frozen twin ---
    servable = register(ServableModel.from_svi(
        "regression-stream", _stream_model, guide, params0,
        num_samples=1, return_sites=["mu"], max_batch=args.max_batch,
    ), replace=True)
    servable.meta["directory"] = directory
    frozen = register(ServableModel.from_svi(
        "regression-frozen", _stream_model, guide,
        jax.tree.map(lambda x: x, params0),
        num_samples=1, return_sites=["mu"], max_batch=args.max_batch,
    ), replace=True)

    swaps: List[int] = []
    swap_log = hot_swap_on_commit(servable, directory)

    def on_commit(step: int) -> None:
        swap_log(step)
        swaps.append(step)

    def paced(source):
        # the trainer would otherwise monopolize the CPU the server shares
        interval = args.step_interval_ms / 1e3
        for item in source:
            yield item
            if interval > 0:
                time.sleep(interval)

    trainer = StreamingTrainer(
        svi, Prefetcher(paced(iter(stream)), prefetch=4), state=state,
        directory=directory, ckpt_every=args.ckpt_every, on_commit=on_commit,
    )

    server = InferenceServer(
        {"regression-stream": servable, "regression-frozen": frozen},
        default_deadline_ms=args.deadline_ms, max_wait_ms=args.max_wait_ms,
        rng_key=jax.random.PRNGKey(args.seed + 1),
    )

    results = {"ok": 0, "shed": 0, "error": 0}
    error_samples: List[tuple] = []
    results_lock = threading.Lock()

    def client(cid: int, n: int) -> None:
        rng = jax.random.PRNGKey(1000 + cid)
        for i in range(n):
            rows = int(jax.random.randint(
                jax.random.fold_in(rng, i), (), 1, max(args.max_request, 2)))
            x = jax.random.normal(jax.random.fold_in(rng, 10_000 + i), (rows, _DIM))
            name = "regression-stream" if (i % 4) else "regression-frozen"
            try:
                status, payload = _post(
                    server.address, f"/v1/models/{name}:predict",
                    {"inputs": {"x": x.tolist()}},
                )
            except (urllib.error.URLError, OSError, json.JSONDecodeError) as e:
                status, payload = 599, {"client_error": repr(e)}
            with results_lock:
                if status == 200 and "outputs" in payload:
                    results["ok"] += 1
                elif status == 429:
                    results["shed"] += 1
                else:
                    results["error"] += 1
                    if len(error_samples) < 8:
                        error_samples.append((status, payload))

    with server, trainer:
        print(f"serving at {server.address} "
              f"(deadline {args.deadline_ms or 'none'} ms); trainer running, "
              f"checkpoint every {args.ckpt_every} steps -> {directory}")
        # traffic epoch starts only after the buckets are warm, so the
        # num_traces assertion below isolates *swap*-caused recompiles
        per = args.requests // args.clients
        threads = [
            threading.Thread(target=client, args=(c, per), daemon=True)
            for c in range(args.clients)
        ]
        t0 = time.time()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.time() - t0
        # warm the post-swap probe's 1-row bucket: under load the coalescer
        # may never have produced it, and its first compile would otherwise
        # be misread below as a swap-caused recompile
        _post(server.address, "/v1/models/regression-stream:predict",
              {"inputs": {"x": [[0.1] * _DIM]}})
        traces_after_traffic = servable.num_traces
        # ensure at least one hot swap happened while the server is live
        trainer.wait_for_commit(timeout=60.0)
        status, _ = _post(server.address,
                          "/v1/models/regression-stream:predict",
                          {"inputs": {"x": [[0.1] * _DIM]}})
        post_swap_ok = status == 200

        _, stats = _get(server.address, "/v1/models/regression-stream/stats")
        _, registry = _get(server.address, "/v1/models")
        _, remesh = _post(server.address, "/admin/device-loss",
                          {"n_hosts_alive": 2, "chips_per_host": 4,
                           "model_parallelism": 1})

    print(f"\n-- traffic ({wall:.2f}s wall) " + "-" * 40)
    for k, v in results.items():
        print(f"  {k:>18}: {v}")
    for k in ("requests_per_sec", "p50_ms", "p99_ms", "shed_rate",
              "num_traces"):
        print(f"  {k:>18}: {stats.get(k)}")
    print(f"  {'models':>18}: "
          f"{[m['name'] for m in registry['models']]}")
    print(f"  {'trainer_steps':>18}: {trainer.steps_done} "
          f"(loss {trainer.last_loss:.2f}, svi.num_traces={svi.num_traces})")
    swap_preview = swaps if len(swaps) <= 8 else swaps[:4] + ["..."] + swaps[-3:]
    print(f"  {'hot_swaps':>18}: {len(swaps)} at steps {swap_preview}")
    print(f"  {'remesh_plan':>18}: {remesh.get('plan')}")

    buckets = sorted(servable.buckets_touched)
    failures = []
    if results["error"]:
        failures.append(f"{results['error']} dropped/errored requests")
    if not post_swap_ok:
        failures.append("post-swap probe failed")
    if not swaps:
        failures.append("no hot swap committed during the run")
    if servable.num_traces != traces_after_traffic:
        failures.append(
            f"hot swap recompiled: {traces_after_traffic} -> {servable.num_traces}"
        )
    if servable.num_traces != len(buckets):
        failures.append(
            f"compiles {servable.num_traces} != buckets touched {len(buckets)}"
        )
    if svi.num_traces != 1:
        failures.append(f"trainer hot loop retraced: svi.num_traces={svi.num_traces}")
    if failures:
        for status, payload in error_samples:
            print(f"  errored request: status={status} payload={payload}",
                  file=sys.stderr)
        print("STREAMING CONTRACT VIOLATED: " + "; ".join(failures),
              file=sys.stderr)
        return 1
    print("\nstreaming contract OK: zero drops, zero recompiles across "
          f"{len(swaps)} hot swap(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
