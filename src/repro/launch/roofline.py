"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (assignment constants, TPU v5e-class):
    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link

Terms (per step, in seconds):
    compute    = HLO_FLOPs_total    / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_total    / (chips * HBM_BW)
    collective = collective_bytes   / (chips * ICI_BW)

`cost_analysis()` on an SPMD-compiled executable reports *per-device*
numbers; we multiply by `chips` to get totals, so the two conventions
cancel and the terms above are just per_device / peak. collective_bytes is
parsed from the post-optimization HLO text (sum of result-shape bytes of
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9       # bytes/s per chip
ICI_BW = 50e9        # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[shape] literal in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes from post-optimization HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # result lines look like: "%name = bf16[..] all-reduce(", or start
        # directly with the shape for top-level instructions
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\s*([a-z0-9-]+)\(", line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        # normalize op names like all-reduce-start / all-gather-done
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        out[base] += _shape_bytes(shape_txt)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float  # 6*N(_active)*D tokens-based estimate

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at the
        bound: useful_FLOPs / (chips * peak * max_term)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def model_flops(cfg, seq_len: int, global_batch: int, kind: str) -> float:
    """6·N·D (train) / 2·N·D (forward-only) with N = active params."""
    n = cfg.param_count(active_only=True)
    tokens = seq_len * global_batch if kind != "decode" else global_batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
