"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the paper's GPU SSD kernel (arXiv:2405.21060): each chunk
is a dense (Q x Q) masked quadratic form that runs on the MXU, and the
inter-chunk state recurrence is carried in VMEM scratch across the
*sequential* chunk grid dimension (no warp-level primitives needed — the
TPU grid's sequential-innermost semantics replace the GPU's block-level
state exchange).

Layouts (prepared by ops.ssd_scan):
    x   (B, H, C, Q, P)   head inputs, chunked
    dA  (B, H, C, Q)      dt * A  (negative)
    dt  (B, H, C, Q)
    Bm  (B, C, Q, N)      input  projection (shared across heads)
    Cm  (B, C, Q, N)      output projection (shared across heads)
    out (B, H, C, Q, P)
State scratch: (N, P) float32 per (batch, head), reset at chunk 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dA_ref, dt_ref, b_ref, c_ref, o_ref, state_ref, *, Q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)    # (Q, P)
    dA = dA_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (Q, N)

    cum = jnp.cumsum(dA)  # (Q,)

    # --- intra-chunk: (L o C B^T) (dt*x) on the MXU ---
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(row >= col, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    M = CB * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,P)

    # --- inter-chunk: y += (C * exp(cum)) @ state_in ---
    state_in = state_ref[...]
    y = y + jax.lax.dot_general(Cm * jnp.exp(cum)[:, None], state_in,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # --- state update: state = exp(cum_Q) * state_in + B^T (dt * decay * x) ---
    decay_to_end = jnp.exp(cum[-1] - cum)  # (Q,)
    wx = x * (dt * decay_to_end)[:, None]  # (Q,P)
    new_state = jnp.exp(cum[-1]) * state_in + jax.lax.dot_general(
        Bm, wx, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N,P)
    state_ref[...] = new_state
    o_ref[0, 0, 0] = y.astype(o_ref.dtype)


def ssd_scan_chunked(
    x: jax.Array,   # (B, H, C, Q, P)
    dA: jax.Array,  # (B, H, C, Q)
    dt: jax.Array,  # (B, H, C, Q)
    Bm: jax.Array,  # (B, C, Q, N)
    Cm: jax.Array,  # (B, C, Q, N)
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, C, Q, P = x.shape
    N = Bm.shape[-1]
    grid = (B, H, C)  # C innermost => sequential state carry per (B,H)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dA, dt, Bm, Cm)
