"""Causal GQA flash attention — Pallas TPU kernel.

TPU adaptation (DESIGN.md §7): online-softmax with q/kv BlockSpec tiling
sized for VMEM (q-block x kv-block tiles feed the 128x128 MXU); the kv loop
is the innermost *sequential* grid dimension, with running (m, l, acc)
carried in VMEM scratch — the standard TPU flash schedule (cf.
jax.experimental.pallas.ops.tpu.flash_attention), rebuilt here explicitly.

GQA layout: the wrapper reshapes q to (B*K, g, Sq, d) so each kv head's
block is loaded once and shared by its g query heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_kv: int, causal: bool, sm_scale: float):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)     # (bk, d)
    v = v_ref[0].astype(jnp.float32)     # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale  # (bq,bk)
    if causal:
        iq = pl.program_id(2)
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_gqa(
    q: jax.Array,  # (BK, g, Sq, d)
    k: jax.Array,  # (BK, Skv, d)
    v: jax.Array,  # (BK, Skv, d)
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BK, g, Sq, d = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    n_kv = Skv // bk
    grid = (BK, g, Sq // bq, n_kv)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
        sm_scale=1.0 / (d ** 0.5),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, h, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, h, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
