"""Fused log-space semiring matmul — Pallas TPU kernel.

The enumeration hot path (tensor variable elimination in
`infer/traceenum_elbo.py`) is a chain of *semiring* contractions: with
``⊕ = logsumexp`` (sum-product) or ``⊕ = max`` (max-product / Viterbi) and
``⊗ = +``, eliminating a discrete latent shared by two log-factors is exactly

    out[i, j] = ⊕_k  a[i, k] + b[k, j]

i.e. a matmul over the (⊕, +) semiring. The naive jnp path materializes the
(M, K, N) broadcast sum in HBM before reducing; this kernel streams (bm, bk)
x (bk, bn) tiles through VMEM with an online-logsumexp accumulator, and the
sum-product inner block is rewritten as a *real* MXU matmul via the shifted
exponential identity

    logsumexp_k(a[i,k] + b[k,j]) = m[i,j] + log( exp(a - am) @ exp(b - bm) )
    with am = max_k a[i,:],  bm = max_k b[:,j],  m = am + bm

(the flash-attention trick applied to the probabilistic-programming layer's
contraction), so nothing (M, K, N)-sized ever exists and the MACs run on the
MXU instead of the VPU. The max-product variant keeps the broadcast form per
tile (max-plus has no MXU identity) but still never leaves VMEM.

Precision note (standard log-matmul-exp tradeoff): the shift bound
``am[i] + bm[j]`` can exceed the true entry-wise max when the row max and
column max come from different k, so terms more than ~88 nats (the f32 exp
underflow point) below the bound flush to exactly 0. For ⊕-marginalization
this is benign — a contribution e^-88 below the dominant term is far past
f32 resolution anyway — but an entry whose *entire* sum lies that far below
the bound returns -inf rather than its (astronomically negative) true value.
The max-product semiring takes no shortcut and is exact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite -inf stand-in: exp(NEG_INF - anything_real) == 0 in f32

SEMIRINGS = ("logsumexp", "max")


def _semiring_matmul_kernel(a_ref, b_ref, o_ref, m_ref, s_ref, *, nk: int, semiring: str):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        # logsumexp keeps a finite floor (m feeds exp-rescale arithmetic);
        # max-plus must start at the true ⊕-identity or fully -inf entries
        # (structurally impossible transitions) would clamp to NEG_INF and
        # break exactness vs the reference backend
        init = NEG_INF if semiring == "logsumexp" else -jnp.inf
        m_ref[...] = jnp.full_like(m_ref, init)
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[...].astype(jnp.float32)  # (bm, bk)
    b = b_ref[...].astype(jnp.float32)  # (bk, bn)

    if semiring == "logsumexp":
        am = jnp.max(a, axis=1, keepdims=True)  # (bm, 1)
        bm = jnp.max(b, axis=0, keepdims=True)  # (1, bn)
        # guard fully-masked (-inf) rows/cols: exp(-inf - -inf) would be nan
        am_s = jnp.where(jnp.isfinite(am), am, 0.0)
        bm_s = jnp.where(jnp.isfinite(bm), bm, 0.0)
        p = jnp.dot(
            jnp.exp(a - am_s), jnp.exp(b - bm_s), preferred_element_type=jnp.float32
        )
        m_cur = am_s + bm_s  # (bm, bn) tile max bound
        m_prev, s_prev = m_ref[...], s_ref[...]
        m_new = jnp.maximum(m_prev, m_cur)
        s_ref[...] = s_prev * jnp.exp(m_prev - m_new) + p * jnp.exp(m_cur - m_new)
        m_ref[...] = m_new
    else:  # max-plus: out = max_k a[i,k] + b[k,j]
        x = a[:, :, None] + b[None, :, :]  # (bm, bk, bn) — VMEM-resident only
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(x, axis=1))

    @pl.when(ik == nk - 1)
    def _finalize():
        if semiring == "logsumexp":
            o_ref[...] = m_ref[...] + jnp.log(s_ref[...])
        else:
            o_ref[...] = m_ref[...]


def semiring_matmul_tiled(
    a: jax.Array,  # (M, K) log-factor
    b: jax.Array,  # (K, N) log-factor
    *,
    semiring: str = "logsumexp",
    block_m: int = 64,
    block_n: int = 64,
    block_k: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """out[i, j] = ⊕_k a[i, k] + b[k, j] over the (⊕, +) log-space semiring.

    2-D only; `kernels/ops.semiring_matmul` adds batch dims and backend
    dispatch. K-padding uses NEG_INF (the ⊕ identity), so ragged shapes are
    exact, not approximately masked.
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; expected one of {SEMIRINGS}")
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"contracting dims disagree: a is {a.shape}, b is {b.shape}")
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    Mp, Np, Kp = -(-M // bm) * bm, -(-N // bn) * bn, -(-K // bk) * bk
    # K-padding must be the exact ⊕-identity: -inf for max-plus (NEG_INF would
    # leak a finite floor into fully -inf entries); the finite stand-in is fine
    # for logsumexp, whose shifted exp underflows it to exactly 0 either way
    pad = NEG_INF if semiring == "logsumexp" else -jnp.inf
    if (Mp, Kp) != (M, K):
        a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)), constant_values=pad)
    if (Kp, Np) != (K, N):
        b = jnp.pad(b, ((0, Kp - K), (0, Np - N)), constant_values=pad)
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    out = pl.pallas_call(
        functools.partial(_semiring_matmul_kernel, nk=nk, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, jn, ik: (ik, jn)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),  # running ⊕-max
            pltpu.VMEM((bm, bn), jnp.float32),  # running shifted sum (logsumexp only)
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
