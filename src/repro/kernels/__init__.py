from .ops import categorical_logprob, flash_attention, ssd_scan

__all__ = ["categorical_logprob", "flash_attention", "ssd_scan"]
