from .ops import (
    categorical_logprob,
    flash_attention,
    hmm_scan,
    semiring_matmul,
    ssd_scan,
)

__all__ = [
    "categorical_logprob",
    "flash_attention",
    "hmm_scan",
    "semiring_matmul",
    "ssd_scan",
]
