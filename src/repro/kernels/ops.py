"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with interpret=True (Pallas executes
the kernel body in Python for correctness); on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the default platform check) to get
the compiled Mosaic kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .categorical_logprob import categorical_logprob_flat
from .flash_attention import flash_attention_gqa
from .ssd_scan import ssd_scan_chunked


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256, block_k: int = 512):
    """q: (B, H, Sq, d); k/v: (B, K, Skv, d), H % K == 0. Returns (B,H,Sq,d)."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    g = H // K
    qr = q.reshape(B, K, g, Sq, d).reshape(B * K, g, Sq, d)
    kr = k.reshape(B * K, Skv, d)
    vr = v.reshape(B * K, Skv, d)
    out = flash_attention_gqa(
        qr, kr, vr, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_interpret(),
    )
    return out.reshape(B, K, g, Sq, d).reshape(B, H, Sq, d)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v"))
def categorical_logprob(logits, tokens, *, block_t: int = 256, block_v: int = 2048):
    """logits: (..., V); tokens: (...). Returns per-token log p, f32."""
    V = logits.shape[-1]
    batch_shape = logits.shape[:-1]
    out = categorical_logprob_flat(
        logits.reshape(-1, V), tokens.reshape(-1).astype(jnp.int32),
        block_t=block_t, block_v=block_v, interpret=_interpret(),
    )
    return out.reshape(batch_shape)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128):
    """Mamba-2 SSD. x: (b,s,h,p), dt: (b,s,h), A: (h,), B/C: (b,s,n).
    Returns y: (b,s,h,p) float32. s must be a multiple of `chunk`
    (models/ssm.ssd_block pads)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = chunk
    C_ = s // Q
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, C_, Q, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, C_, Q).astype(jnp.float32)
    dAr = dtr * A[None, :, None, None]
    Br = B.reshape(b, C_, Q, n)
    Cr = C.reshape(b, C_, Q, n)
    y = ssd_scan_chunked(xr, dAr, dtr, Br, Cr, interpret=_interpret())
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
