"""Backend-dispatched public wrappers around the Pallas kernels.

Every op resolves a *kernel backend* and routes to one of three
implementations, so the hot log-prob paths work on every platform CI runs on:

  ``tpu``        compiled Mosaic kernels (requires a TPU jax backend)
  ``interpret``  Pallas interpret mode — the kernel body executed as XLA ops,
                 correct on any platform (what kernel tests exercise on CPU)
  ``reference``  the pure-jnp oracles in `kernels/ref.py` (fastest off-TPU)

Resolution precedence: explicit ``backend=`` argument > the
``REPRO_KERNEL_BACKEND`` env var (``tpu`` / ``interpret`` / ``reference`` /
``auto``) > the legacy ``REPRO_PALLAS_INTERPRET`` flag > platform default
(``tpu`` on TPU, ``reference`` everywhere else). The resolved backend is a
static argument of the underlying jit, so switching backends compiles a
separate executable instead of clobbering one cache entry.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .categorical_logprob import categorical_logprob_flat
from .flash_attention import flash_attention_gqa
from .ssd_scan import ssd_scan_chunked

BACKENDS = ("tpu", "interpret", "reference")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve an explicit/env/platform kernel-backend choice to one of
    `BACKENDS`. See module docstring for precedence."""
    if backend is None:
        backend = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
    if backend == "ref":  # convenience alias
        backend = "reference"
    if backend in BACKENDS:
        return backend
    if backend != "auto":
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS + ('auto',)}"
        )
    legacy = os.environ.get("REPRO_PALLAS_INTERPRET")
    if legacy is not None:
        return "tpu" if legacy in ("0", "false", "False") else "interpret"
    return "tpu" if jax.default_backend() == "tpu" else "reference"


# declared per-op support — a new op (or an op dropping a backend) must edit
# this table, and the README matrix mirrors it
_SUPPORT = {
    "flash_attention": ("tpu", "interpret", "reference"),
    "categorical_logprob": ("tpu", "interpret", "reference"),
    "ssd_scan": ("tpu", "interpret", "reference"),
}


def backend_support_matrix() -> dict:
    """Which backends each op supports (README's support matrix, as data)."""
    return {op: {b: b in sup for b in BACKENDS} for op, sup in _SUPPORT.items()}


# -- flash attention ---------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "backend"))
def _flash_attention(q, k, v, *, causal, block_q, block_k, backend):
    if backend == "reference":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    g = H // K
    qr = q.reshape(B, K, g, Sq, d).reshape(B * K, g, Sq, d)
    kr = k.reshape(B * K, Skv, d)
    vr = v.reshape(B * K, Skv, d)
    out = flash_attention_gqa(
        qr, kr, vr, causal=causal, block_q=block_q, block_k=block_k,
        interpret=(backend == "interpret"),
    )
    return out.reshape(B, K, g, Sq, d).reshape(B, H, Sq, d)


def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 256, block_k: int = 512,
    backend: Optional[str] = None,
):
    """q: (B, H, Sq, d); k/v: (B, K, Skv, d), H % K == 0. Returns (B,H,Sq,d)."""
    return _flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        backend=resolve_backend(backend),
    )


# -- categorical log-prob ----------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "backend"))
def _categorical_logprob(logits, tokens, *, block_t, block_v, backend):
    if backend == "reference":
        return ref.categorical_logprob_ref(logits, tokens)
    V = logits.shape[-1]
    batch_shape = logits.shape[:-1]
    out = categorical_logprob_flat(
        logits.reshape(-1, V), tokens.reshape(-1).astype(jnp.int32),
        block_t=block_t, block_v=block_v, interpret=(backend == "interpret"),
    )
    return out.reshape(batch_shape)


def categorical_logprob(
    logits, tokens, *, block_t: int = 256, block_v: int = 2048,
    backend: Optional[str] = None,
):
    """logits: (..., V); tokens: (...). Returns per-token log p, f32."""
    return _categorical_logprob(
        logits, tokens, block_t=block_t, block_v=block_v,
        backend=resolve_backend(backend),
    )


# -- Mamba-2 SSD scan --------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _ssd_scan(x, dt, A, B, C, *, chunk, backend):
    if backend == "reference":
        return ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = chunk
    C_ = s // Q
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, C_, Q, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, C_, Q).astype(jnp.float32)
    dAr = dtr * A[None, :, None, None]
    Br = B.reshape(b, C_, Q, n)
    Cr = C.reshape(b, C_, Q, n)
    y = ssd_scan_chunked(xr, dAr, dtr, Br, Cr, interpret=(backend == "interpret"))
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, backend: Optional[str] = None):
    """Mamba-2 SSD. x: (b,s,h,p), dt: (b,s,h), A: (h,), B/C: (b,s,n).
    Returns y: (b,s,h,p) float32. s must be a multiple of `chunk`
    (models/ssm.ssd_block pads)."""
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, backend=resolve_backend(backend))
