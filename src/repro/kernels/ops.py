"""Backend-dispatched public wrappers around the Pallas kernels.

Every op resolves a *kernel backend* and routes to one of three
implementations, so the hot log-prob paths work on every platform CI runs on:

  ``tpu``        compiled Mosaic kernels (requires a TPU jax backend)
  ``interpret``  Pallas interpret mode — the kernel body executed as XLA ops,
                 correct on any platform (what kernel tests exercise on CPU)
  ``reference``  the pure-jnp oracles in `kernels/ref.py` (fastest off-TPU)

Resolution precedence: explicit ``backend=`` argument > the
``REPRO_KERNEL_BACKEND`` env var (``tpu`` / ``interpret`` / ``reference`` /
``auto``) > the legacy ``REPRO_PALLAS_INTERPRET`` flag > platform default
(``tpu`` on TPU, ``reference`` everywhere else). The resolved backend is a
static argument of the underlying jit, so switching backends compiles a
separate executable instead of clobbering one cache entry.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from .. import settings
from . import ref
from .categorical_logprob import categorical_logprob_flat
from .flash_attention import flash_attention_gqa
from .gaussian import gaussian_combine_pairs
from .leapfrog import leapfrog_fused
from .resample import resample_counts_tiled
from .semiring import SEMIRINGS, semiring_matmul_tiled
from .ssd_scan import ssd_scan_chunked

BACKENDS = ("tpu", "interpret", "reference")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve an explicit/env/platform kernel-backend choice to one of
    `BACKENDS`. See module docstring for precedence."""
    if backend is None:
        backend = settings.get_str("REPRO_KERNEL_BACKEND")
    if backend == "ref":  # convenience alias
        backend = "reference"
    if backend in BACKENDS:
        return backend
    if backend != "auto":
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS + ('auto',)}"
        )
    legacy = settings.get_raw("REPRO_PALLAS_INTERPRET")
    if legacy is not None:
        resolved = "tpu" if legacy in ("0", "false", "False") else "interpret"
        # anything that isn't 0/false used to silently mean interpret — keep
        # that behavior for compatibility, but say so out loud. FutureWarning
        # (not DeprecationWarning) because the audience is users running
        # scripts with the flag exported, and Python hides DeprecationWarning
        # raised from library code by default.
        warnings.warn(
            f"REPRO_PALLAS_INTERPRET is deprecated (value {legacy!r} resolves to "
            f"{resolved!r}; any value other than '0'/'false' means 'interpret'). "
            "Set REPRO_KERNEL_BACKEND=tpu|interpret|reference|auto instead — see "
            "docs/backends.md for the migration.",
            FutureWarning,
            stacklevel=2,
        )
        return resolved
    return "tpu" if jax.default_backend() == "tpu" else "reference"


# declared per-op support — a new op (or an op dropping a backend) must edit
# this table, and the README matrix mirrors it
_SUPPORT = {
    "flash_attention": ("tpu", "interpret", "reference"),
    "categorical_logprob": ("tpu", "interpret", "reference"),
    "ssd_scan": ("tpu", "interpret", "reference"),
    "semiring_matmul": ("tpu", "interpret", "reference"),
    "hmm_scan": ("tpu", "interpret", "reference"),
    "leapfrog": ("tpu", "interpret", "reference"),
    "gaussian_combine": ("tpu", "interpret", "reference"),
    "gaussian_scan": ("tpu", "interpret", "reference"),
    "resample": ("tpu", "interpret", "reference"),
}


def backend_support_matrix() -> dict:
    """Which backends each op supports (README's support matrix, as data)."""
    return {op: {b: b in sup for b in BACKENDS} for op, sup in _SUPPORT.items()}


# -- flash attention ---------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "backend"))
def _flash_attention(q, k, v, *, causal, block_q, block_k, backend):
    if backend == "reference":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    g = H // K
    qr = q.reshape(B, K, g, Sq, d).reshape(B * K, g, Sq, d)
    kr = k.reshape(B * K, Skv, d)
    vr = v.reshape(B * K, Skv, d)
    out = flash_attention_gqa(
        qr, kr, vr, causal=causal, block_q=block_q, block_k=block_k,
        interpret=(backend == "interpret"),
    )
    return out.reshape(B, K, g, Sq, d).reshape(B, H, Sq, d)


def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 256, block_k: int = 512,
    backend: Optional[str] = None,
):
    """q: (B, H, Sq, d); k/v: (B, K, Skv, d), H % K == 0. Returns (B,H,Sq,d)."""
    return _flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        backend=resolve_backend(backend),
    )


# -- categorical log-prob ----------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "backend"))
def _categorical_logprob(logits, tokens, *, block_t, block_v, backend):
    if backend == "reference":
        return ref.categorical_logprob_ref(logits, tokens)
    V = logits.shape[-1]
    batch_shape = logits.shape[:-1]
    out = categorical_logprob_flat(
        logits.reshape(-1, V), tokens.reshape(-1).astype(jnp.int32),
        block_t=block_t, block_v=block_v, interpret=(backend == "interpret"),
    )
    return out.reshape(batch_shape)


def categorical_logprob(
    logits, tokens, *, block_t: int = 256, block_v: int = 2048,
    backend: Optional[str] = None,
):
    """logits: (..., V); tokens: (...). Returns per-token log p, f32."""
    return _categorical_logprob(
        logits, tokens, block_t=block_t, block_v=block_v,
        backend=resolve_backend(backend),
    )


# -- Mamba-2 SSD scan --------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _ssd_scan(x, dt, A, B, C, *, chunk, backend):
    if backend == "reference":
        return ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = chunk
    C_ = s // Q
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, C_, Q, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, C_, Q).astype(jnp.float32)
    dAr = dtr * A[None, :, None, None]
    Br = B.reshape(b, C_, Q, n)
    Cr = C.reshape(b, C_, Q, n)
    y = ssd_scan_chunked(xr, dAr, dtr, Br, Cr, interpret=(backend == "interpret"))
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, backend: Optional[str] = None):
    """Mamba-2 SSD. x: (b,s,h,p), dt: (b,s,h), A: (h,), B/C: (b,s,n).
    Returns y: (b,s,h,p) float32. s must be a multiple of `chunk`
    (models/ssm.ssd_block pads)."""
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, backend=resolve_backend(backend))


# -- log-space semiring matmul (enumeration hot path) ------------------------


def _semiring_matmul_impl(a, b, *, semiring, block, backend):
    """Batched semiring matmul on a resolved backend (no jit wrapper: called
    both standalone and from inside `_hmm_scan`'s combine)."""
    if backend == "reference":
        return ref.semiring_matmul_ref(a, b, semiring=semiring)
    if 0 in a.shape or 0 in b.shape:
        # degenerate slices (e.g. lax.associative_scan on a length-1 chain)
        # never reach the kernel; the pure-jnp path handles empties exactly
        return ref.semiring_matmul_ref(a, b, semiring=semiring)
    return _semiring_matmul_kernel(a, b, semiring, block, backend)


# The Pallas kernel has no AD rule, but the enumeration engine differentiates
# straight through its contractions (TraceEnum_ELBO SVI steps, the dice-factor
# gradient in discrete_marginals), so the kernel carries a custom VJP: fused
# forward, pure-jnp reference backward. ref.semiring_matmul_ref is the same
# function the kernel computes, so its VJP is the kernel's VJP.
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _semiring_matmul_kernel(a, b, semiring, block, backend):
    batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
    a = jnp.broadcast_to(a, batch + a.shape[-2:])
    b = jnp.broadcast_to(b, batch + b.shape[-2:])
    fn = functools.partial(
        semiring_matmul_tiled,
        semiring=semiring,
        block_m=block,
        block_n=block,
        block_k=block,
        interpret=(backend == "interpret"),
    )
    if not batch:
        return fn(a, b)
    out = jax.vmap(fn)(
        a.reshape((-1,) + a.shape[-2:]), b.reshape((-1,) + b.shape[-2:])
    )
    return out.reshape(batch + out.shape[-2:])


def _semiring_matmul_kernel_fwd(a, b, semiring, block, backend):
    return _semiring_matmul_kernel(a, b, semiring, block, backend), (a, b)


def _semiring_matmul_kernel_bwd(semiring, block, backend, res, g):
    a, b = res
    _, vjp = jax.vjp(
        functools.partial(ref.semiring_matmul_ref, semiring=semiring), a, b
    )
    return vjp(g)


_semiring_matmul_kernel.defvjp(_semiring_matmul_kernel_fwd, _semiring_matmul_kernel_bwd)


@functools.partial(jax.jit, static_argnames=("semiring", "block", "backend"))
def _semiring_matmul(a, b, *, semiring, block, backend):
    return _semiring_matmul_impl(a, b, semiring=semiring, block=block, backend=backend)


def semiring_matmul(
    a,
    b,
    *,
    semiring: str = "logsumexp",
    block: int = 64,
    backend: Optional[str] = None,
):
    """Log-space semiring matmul: ``out[..., i, j] = ⊕_k a[..., i, k] + b[..., k, j]``
    with ``⊕ = logsumexp`` (sum-product) or ``max`` (max-product), ``⊗ = +``.
    a: (..., M, K); b: (..., K, N); batch dims broadcast. Returns (..., M, N) f32."""
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; expected one of {SEMIRINGS}")
    return _semiring_matmul(
        a, b, semiring=semiring, block=block, backend=resolve_backend(backend)
    )


# -- systematic resampling (SMC hot path) -------------------------------------


# Resampling is piecewise-constant in the weights: perturbing a log-weight
# moves an ancestor index only at the measure-zero cell boundaries, so the
# true derivative is zero almost everywhere. The custom VJP makes that
# explicit (zero cotangents to the cumsum and the grid) instead of leaving
# the int32 output's differentiability to ambient float0 plumbing — the
# standard stop-gradient-through-ancestry estimator variational SMC uses;
# `infer.smc.NestedVariational` differentiates through the selected
# particles' continuous values, never through the selection itself.
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _resample_counts_kernel(c, u, block, backend):
    counts = resample_counts_tiled(
        c, u, block_u=block, block_c=block, interpret=(backend == "interpret")
    )
    # the clip lives inside the VJP boundary so no int arithmetic is ever
    # differentiated downstream of the kernel
    return jnp.minimum(counts, c.shape[-1] - 1)


def _resample_counts_kernel_fwd(c, u, block, backend):
    return _resample_counts_kernel(c, u, block, backend), (c, u)


def _resample_counts_kernel_bwd(block, backend, res, g):
    c, u = res
    return jnp.zeros_like(c), jnp.zeros_like(u)


_resample_counts_kernel.defvjp(_resample_counts_kernel_fwd, _resample_counts_kernel_bwd)


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _resample(log_weights, u0, *, block, backend):
    if backend == "reference":
        return ref.systematic_resample_ref(log_weights, u0)
    n = log_weights.shape[-1]
    # cumsum/grid construction is shared with the oracle, so reference and
    # kernel backends count the exact same comparisons bit-for-bit
    c = ref.resample_inputs_ref(log_weights)
    u = ref.resample_grid_ref(u0, n)
    return _resample_counts_kernel(c, u, block, backend)


def resample(log_weights, u0, *, block: int = 256, backend: Optional[str] = None):
    """Systematic resampling: ancestor indices for an SMC particle population.

    log_weights: (N,) unnormalized particle log-weights (``-inf`` = dead
    particle, never selected; an all ``-inf`` population degenerates to
    uniform). u0: scalar uniform draw in [0, 1), shared by the whole sorted
    grid u_i = (u0 + i)/N — one random number per resample event is what
    makes systematic resampling lower-variance than multinomial. Returns (N,)
    int32 ancestor indices, sorted (a free by-product of the sorted-grid
    formulation). Gradients: zero (see `_resample_counts_kernel`)."""
    log_weights = jnp.asarray(log_weights)
    if log_weights.ndim != 1:
        raise ValueError(
            f"log_weights must be 1-D (the particle axis), got shape "
            f"{log_weights.shape}; vmap over batch dims instead"
        )
    if log_weights.shape[0] < 1:
        raise ValueError("need at least one particle to resample")
    return _resample(log_weights, u0, block=block, backend=resolve_backend(backend))


# -- fused HMC leapfrog (MCMC hot path) ---------------------------------------


def leapfrog(
    z,
    r,
    inv_mass,
    step_size,
    num_steps,
    potential_fn,
    *,
    max_steps: int,
    block_chains: int = 8,
    backend: Optional[str] = None,
):
    """Run a batch of leapfrog trajectories in one fused program.

    z, r, inv_mass: (C, D) — positions, momenta, diagonal inverse mass per
    chain; step_size: (C,) f32 (the *sign* is the integration direction, so
    NUTS runs backward trajectories with a negative step size); num_steps:
    (C,) int (0 freezes a chain: its z/r pass through untouched and it only
    pays the final potential evaluation). potential_fn maps a (D,) vector to
    a scalar potential. Returns ``(z', r', potential(z'))``.

    Unlike the other ops this one takes a *function* argument, so there is no
    jit wrapper here — callers (the MCMC drivers) are jitted already, and the
    resolved backend must be static at their trace time. On the Pallas
    backends the potential is traced once via ``jax.value_and_grad`` →
    ``make_jaxpr`` and replayed inside the kernel; its captured constants
    (model data, transform parameters) become ordinary kernel inputs — see
    `kernels/leapfrog.py` for the closure-conversion details.

    No AD rule on purpose: MCMC never differentiates its own transition, and
    ``jax.grad`` through this op should fail loudly, not silently pick an
    unfused path.
    """
    backend = resolve_backend(backend)
    if backend == "reference":
        return ref.leapfrog_ref(
            z, r, inv_mass, step_size, num_steps, potential_fn,
            max_steps=max_steps,
        )
    closed = jax.make_jaxpr(jax.value_and_grad(potential_fn))(z[0])
    return leapfrog_fused(
        z,
        r,
        inv_mass,
        step_size,
        num_steps,
        closed.consts,
        jaxpr=closed.jaxpr,
        max_steps=max_steps,
        block_chains=block_chains,
        interpret=(backend == "interpret"),
    )


# -- information-form Gaussian combine / Kalman scan (Gaussian semiring) ------

# T-axis position per edge-factor leaf (J11, J12, J22, h1, h2, c): matrices
# carry the chain axis at -3, info vectors at -2, the log-normalizer at -1
_GAUSS_T_AXES = (-3, -3, -3, -2, -2, -1)


def _gauss_slice_t(factors, start, stop, step=1):
    out = []
    for x, ax in zip(factors, _GAUSS_T_AXES):
        idx = [slice(None)] * x.ndim
        idx[ax] = slice(start, stop, step)
        out.append(x[tuple(idx)])
    return tuple(out)


def _gaussian_widths(f):
    """(d_left, d_right) of an edge 6-tuple, from the J12 cross block."""
    return f[1].shape[-2], f[1].shape[-1]


# Like semiring_matmul, the Gaussian combine is differentiated straight
# through (TraceEnum_ELBO objectives, the perturbation trick behind
# gaussian_marginals), so the fused kernel carries a custom VJP with the
# pure-jnp reference as its backward — same function, so same gradient.
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _gaussian_combine_kernel(f, g, block, backend):
    leaves = f + g
    batch = jnp.broadcast_shapes(
        *(x.shape[:ax + 1 or None] for x, ax in zip(leaves, _GAUSS_T_AXES * 2))
    )

    def flat(x, ax):
        ev = x.shape[ax + 1:] if ax != -1 else ()
        x = jnp.broadcast_to(x, batch + ev)
        return x.reshape((-1,) + ev)

    ff = tuple(flat(x, ax) for x, ax in zip(f, _GAUSS_T_AXES))
    gf = tuple(flat(x, ax) for x, ax in zip(g, _GAUSS_T_AXES))
    out = gaussian_combine_pairs(
        ff, gf, block_b=block, interpret=(backend == "interpret")
    )
    return tuple(
        x.reshape(batch + x.shape[1:]) for x in out
    )


def _gaussian_combine_kernel_fwd(f, g, block, backend):
    return _gaussian_combine_kernel(f, g, block, backend), (f, g)


def _gaussian_combine_kernel_bwd(block, backend, res, ct):
    f, g = res
    _, vjp = jax.vjp(ref.gaussian_combine_ref, f, g)
    return vjp(ct)


_gaussian_combine_kernel.defvjp(_gaussian_combine_kernel_fwd, _gaussian_combine_kernel_bwd)


def _gaussian_combine_impl(f, g, *, block, backend):
    d1, db = _gaussian_widths(f)
    db2, d2 = _gaussian_widths(g)
    if backend == "reference" or not (d1 == db == db2 == d2):
        # ragged widths never reach the kernel (its Gauss-Jordan unroll and
        # lane layout assume one uniform square d); the jnp path is exact
        return ref.gaussian_combine_ref(f, g)
    if any(0 in x.shape for x in f + g):
        return ref.gaussian_combine_ref(f, g)
    return _gaussian_combine_kernel(f, g, block, backend)


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _gaussian_combine(f, g, *, block, backend):
    return _gaussian_combine_impl(f, g, block=block, backend=backend)


def gaussian_combine(f, g, *, block: int = 256, backend: Optional[str] = None):
    """Integrate out the shared middle variable of two Gaussian edge factors.

    f, g: information-form edge 6-tuples ``(J11, J12, J22, h1, h2, c)`` —
    ``log F(a, b) = -1/2 [a;b]^T J [a;b] + h^T [a;b] + c`` with J11 (..., d1, d1),
    J12 (..., d1, db), J22 (..., db, db), h1 (..., d1), h2 (..., db), c (...).
    g's left width must equal f's right width (db); batch dims broadcast.
    Returns the (..., d1)-by-(..., d2) edge factor of ``∫ F(a, b) G(b, c) db``
    — the associative Kalman-filter combine (see `ref.gaussian_combine_ref`
    for the Schur-complement algebra, `kernels/gaussian.py` for the
    conditioning contract).
    """
    d1, db = _gaussian_widths(f)
    db2, _ = _gaussian_widths(g)
    if db != db2:
        raise ValueError(
            f"middle widths disagree: f's right variable has width {db}, "
            f"g's left variable has width {db2}"
        )
    return _gaussian_combine(
        tuple(f), tuple(g), block=block, backend=resolve_backend(backend)
    )


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _gaussian_scan(factors, *, block, backend):
    if backend == "reference":
        return ref.gaussian_scan_ref(factors)
    x = factors
    T = x[0].shape[-3]
    # O(log T) associative tree, same shape as _hmm_scan's — except the
    # Gaussian combine has NO identity element (it would need an infinite-
    # precision delta factor), so an odd round carries its unpaired last
    # element forward instead of identity-padding; adjacency is preserved,
    # and associativity makes the regrouping exact
    while T > 1:
        m = (T // 2) * 2
        a = _gauss_slice_t(x, 0, m, 2)
        b = _gauss_slice_t(x, 1, m, 2)
        comb = _gaussian_combine_impl(a, b, block=block, backend=backend)
        if T % 2:
            last = _gauss_slice_t(x, m, T)
            comb = tuple(
                jnp.concatenate([c_, l_], axis=ax)
                for c_, l_, ax in zip(comb, last, _GAUSS_T_AXES)
            )
        x = comb
        T = x[0].shape[-3]
    return tuple(
        jnp.squeeze(x_, axis=ax) for x_, ax in zip(x, _GAUSS_T_AXES)
    )


def gaussian_scan(factors, *, block: int = 256, backend: Optional[str] = None):
    """Eliminate a linear-Gaussian Markov chain in O(log T) depth.

    ``factors`` is an information-form edge 6-tuple stacked along a chain
    axis: matrices (..., T, d, d), info vectors (..., T, d), log-normalizer
    (..., T), where slice t is the edge factor linking chain state t-1 to
    state t. Returns the single (..., d)-by-(..., d) edge factor of the full
    ordered combine F_0 ⊗ F_1 ⊗ ... ⊗ F_{T-1} — every interior state
    integrated out exactly (this *is* the parallel Kalman filter, in
    information form). Associativity of the combine legalizes the log-depth
    tree; the sequential O(T) oracle is `ref.gaussian_scan_ref`.
    """
    factors = tuple(factors)
    if len(factors) != 6:
        raise ValueError(f"expected an edge 6-tuple, got {len(factors)} leaves")
    d1, d2 = _gaussian_widths(factors)
    if d1 != d2:
        raise ValueError(
            f"chain edge factors must have a uniform square width, got ({d1}, {d2})"
        )
    return _gaussian_scan(factors, block=block, backend=resolve_backend(backend))


def _semiring_eye(k: int) -> jax.Array:
    """The semiring identity matrix: 0 on the diagonal, -inf off it —
    M ⊗ I == M exactly for both semirings (the -inf must be genuine: a finite
    stand-in would put a floor under fully -inf entries in max-product)."""
    return jnp.where(jnp.eye(k, dtype=bool), 0.0, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("semiring", "cumulative", "block", "backend"))
def _hmm_scan(factors, *, semiring, cumulative, block, backend):
    combine = functools.partial(
        _semiring_matmul_impl, semiring=semiring, block=block, backend=backend
    )
    if cumulative:
        return jax.lax.associative_scan(combine, factors, axis=-3)
    # total-product reduction: the same O(log T)-depth associative combine that
    # lax.associative_scan uses, minus the prefix completion it would also
    # compute (~2x less work when only the total is needed). Odd rounds pad
    # with the semiring identity, which is exact, not approximate.
    x = factors
    while x.shape[-3] > 1:
        n = x.shape[-3]
        if n % 2:
            eye = jnp.broadcast_to(
                _semiring_eye(x.shape[-1]), x.shape[:-3] + (1,) + x.shape[-2:]
            )
            x = jnp.concatenate([x, eye], axis=-3)
        x = combine(x[..., 0::2, :, :], x[..., 1::2, :, :])
    return x[..., 0, :, :]


def hmm_scan(
    factors,
    *,
    semiring: str = "logsumexp",
    cumulative: bool = False,
    block: int = 64,
    backend: Optional[str] = None,
):
    """Eliminate a Markov chain of K x K log-factors in O(log T) depth.

    factors: (..., T, K, K), where ``factors[..., t, i, j]`` is the log-factor
    linking state i of step t-1 to state j of step t. Returns the ordered
    semiring product ``F_0 ⊗ F_1 ⊗ ... ⊗ F_{T-1}`` — shape (..., K, K) — or,
    with ``cumulative=True``, all T prefix products via `lax.associative_scan`
    (shape (..., T, K, K); the last slice is the total). ``semiring="max"``
    gives the Viterbi (max-product) variant used by
    ``infer_discrete(temperature=0)``. Matmul associativity is what makes the
    log-depth tree legal; the sequential O(T) oracle is `ref.hmm_scan_ref`.
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; expected one of {SEMIRINGS}")
    if factors.shape[-1] != factors.shape[-2]:
        raise ValueError(f"chain factors must be square, got {factors.shape}")
    return _hmm_scan(
        factors,
        semiring=semiring,
        cumulative=cumulative,
        block=block,
        backend=resolve_backend(backend),
    )
