"""Systematic resampling — Pallas TPU kernel (sorted-uniform vs cumsum).

SMC's resample step maps the sorted systematic grid u_i = (u0 + i)/N onto
the normalized-weight cumsum c (also sorted): ancestor i is

    idx[i] = #{j : c[j] <= u[i]}          (== searchsorted(c, u, 'right'))

`jnp.searchsorted` is the reference oracle; on TPU a per-element binary
search is a scalar-heavy, lane-divergent access pattern, while the count
form is a dense comparison-reduction the VPU eats whole. The kernel tiles
the (N_u, N_c) comparison plane: the u axis is grid-parallel, the c axis is
the "arbitrary" accumulation axis — each (bc, 1) cumsum tile is broadcast
against a (1, bu) grid tile, the (bc, bu) boolean plane is summed over
sublanes, and partial counts accumulate into the revisited output block
(same init-at-first / dwell-on-last idiom as `kernels/semiring.py`).

Layout note: c rides the sublane axis ((bc, 1) blocks) and u the lane axis
((1, bu) blocks) so the broadcast-compare and the axis-0 reduction are both
layout-natural — no in-kernel transposes. Padding uses c = 2.0 (> any u,
never counted) and u = -1.0 (counts sliced off).

Clipping to N-1 and the cumsum/grid construction live in `ops.resample`,
which shares them bit-for-bit with the reference backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _count_kernel(c_ref, u_ref, o_ref, *, nc: int):
    jc = pl.program_id(1)

    @pl.when(jc == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = c_ref[...]  # (bc, 1) cumsum tile, sublane-major
    u = u_ref[...]  # (1, bu) grid tile, lane-major
    o_ref[...] += jnp.sum((c <= u).astype(jnp.int32), axis=0, keepdims=True)


def resample_counts_tiled(
    c: jax.Array,  # (N,) normalized-weight cumsum (sorted, c[-1] ~= 1)
    u: jax.Array,  # (M,) systematic grid (sorted, in [0, 1))
    *,
    block_u: int = 256,
    block_c: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """counts[i] = #{j : c[j] <= u[i]} as int32, shape (M,).

    1-D only; `kernels/ops.resample` builds the inputs, clips the counts to
    valid ancestor indices, and adds backend dispatch."""
    (n,) = c.shape
    (m,) = u.shape
    bu, bc = min(block_u, m), min(block_c, n)
    mp, np_ = -(-m // bu) * bu, -(-n // bc) * bc
    if mp != m:
        u = jnp.pad(u, (0, mp - m), constant_values=-1.0)
    if np_ != n:
        c = jnp.pad(c, (0, np_ - n), constant_values=2.0)
    nc = np_ // bc
    grid = (mp // bu, nc)

    out = pl.pallas_call(
        functools.partial(_count_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, 1), lambda iu, jc: (jc, 0)),
            pl.BlockSpec((1, bu), lambda iu, jc: (0, iu)),
        ],
        out_specs=pl.BlockSpec((1, bu), lambda iu, jc: (0, iu)),
        out_shape=jax.ShapeDtypeStruct((1, mp), jnp.int32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(c.reshape(np_, 1), u.reshape(1, mp))
    return out[0, :m]
