"""Fused information-form Gaussian combine — Pallas TPU kernel.

The Gaussian-semiring hot path (exact marginalization of linear-Gaussian
latents in `infer/contract/gaussian.py`) is a chain of *edge-factor*
combines: each factor F(a, b) over a left/right variable pair is held in
information form

    log F(a, b) = -1/2 [a;b]^T [[J11, J12],[J12^T, J22]] [a;b] + [h1;h2]^T [a;b] + c

and eliminating the shared middle variable of F(a, b) · G(b, c) is a Schur
complement of the middle block (see `kernels/ref.gaussian_combine_ref` for
the algebra). The combine is associative, so a T-step Kalman chain reduces
in O(log T) rounds of *pairwise* combines — this kernel runs one round: a
large flattened batch of independent (F, G) pairs, one grid step per batch
block, with the middle-block solve done in VMEM via an unrolled Gauss-Jordan
elimination (the state width d is small and static, so every index is
static and the whole inversion is straight-line VPU code — no pivot search,
no gather).

Layout note: the batch is the *last* (lane) axis — refs are (d, d, bb),
(d, bb), (1, bb) — so every elementwise op runs across full 128-lane
vectors regardless of how small d is; d-indexed loops unroll at trace time.

Conditioning contract (the Gaussian analogue of the ~88-nat underflow note
in `kernels/semiring.py`): the middle matrix M = F.J22 + G.J11 is inverted
without pivoting, which is exact-in-spirit only because M is positive
definite by construction — each factor's right diagonal block contains a
genuine conditional precision (Σ⁻¹ of some conditional density), so pivots
are strictly positive. Accuracy degrades linearly with the condition number
κ(M): in f32, expect ~κ(M)·1e-7 relative error in the eliminated marginals,
i.e. results are meaningless once κ(M) approaches 1e7 — e.g. correlations
|ρ| ≳ 1 - 1e-7 or observation noise ~1e-4 times the prior scale. Factors
that well-posed models produce stay far inside the contract (the
conformance suite pins |ρ| = 0.999, κ ≈ 2e3, at rtol 1e-5); rescale your
latents toward unit scale before marginalizing if you are near the edge.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LOG_2PI = 1.8378770664093453


def _inv_logdet(M, d: int):
    """Unrolled Gauss-Jordan inverse + log-determinant of a (d, d, bb) stack.

    No pivoting: M must be positive definite (see the conditioning contract
    in the module docstring), so every pivot is strictly positive and
    log(pivot) accumulates log|M| for free. All indices are static — the
    loop unrolls into straight-line elementwise code over the lane axis.
    """
    rows = [M[i] for i in range(d)]                     # each (d, bb)
    bb = M.shape[-1]
    eye_rows = [
        jnp.concatenate(
            [jnp.full((1, bb), 1.0 if j == i else 0.0, jnp.float32) for j in range(d)]
        )
        for i in range(d)
    ]
    inv = eye_rows
    logdet = jnp.zeros((bb,), jnp.float32)
    for k in range(d):
        piv = rows[k][k]                                # (bb,)
        logdet = logdet + jnp.log(piv)
        pivinv = (1.0 / piv)[None, :]
        rows[k] = rows[k] * pivinv
        inv[k] = inv[k] * pivinv
        for i in range(d):
            if i == k:
                continue
            f = rows[i][k][None, :]
            rows[i] = rows[i] - f * rows[k]
            inv[i] = inv[i] - f * inv[k]
    return jnp.concatenate([r[None] for r in inv]), logdet  # (d, d, bb), (bb,)


def _mm(a, b):
    """Lane-batched matmul: a (d1, k, bb) @ b (k, d2, bb) -> (d1, d2, bb).

    Broadcast-multiply-reduce on the VPU — the contracted dim k is tiny and
    static, and the MXU has nothing to offer a (lane-batched, k≤8) product.
    """
    return jnp.sum(a[:, :, None, :] * b[None, :, :, :], axis=1)


def _mv(a, v):
    """Lane-batched matvec: a (d1, k, bb) @ v (k, bb) -> (d1, bb)."""
    return jnp.sum(a * v[None, :, :], axis=1)


def _t(a):
    """Transpose the matrix dims of a (d1, d2, bb) stack."""
    return jnp.swapaxes(a, 0, 1)


def _gaussian_combine_kernel(
    fj11, fj12, fj22, fh1, fh2, fc,
    gj11, gj12, gj22, gh1, gh2, gc,
    oj11, oj12, oj22, oh1, oh2, oc,
    *, d: int,
):
    FJ11, FJ12, FJ22 = fj11[...], fj12[...], fj22[...]
    FH1, FH2 = fh1[...], fh2[...]
    GJ11, GJ12, GJ22 = gj11[...], gj12[...], gj22[...]
    GH1, GH2 = gh1[...], gh2[...]

    M = FJ22 + GJ11                                     # (d, d, bb)
    hb = FH2 + GH1                                      # (d, bb)
    Minv, logdet = _inv_logdet(M, d)

    MiFt = _mm(Minv, _t(FJ12))                          # M⁻¹ F.J12^T
    MiG = _mm(Minv, GJ12)                               # M⁻¹ G.J12
    Mih = _mv(Minv, hb)                                 # M⁻¹ hb

    J11 = FJ11 - _mm(FJ12, MiFt)
    J12 = -_mm(FJ12, MiG)
    J22 = GJ22 - _mm(_t(GJ12), MiG)
    # resymmetrize so float error never compounds across combine rounds
    oj11[...] = 0.5 * (J11 + _t(J11))
    oj12[...] = J12
    oj22[...] = 0.5 * (J22 + _t(J22))
    oh1[...] = FH1 - _mv(FJ12, Mih)
    oh2[...] = GH2 - _mv(_t(GJ12), Mih)
    oc[...] = fc[...] + gc[...] + (
        0.5 * jnp.sum(hb * Mih, axis=0) - 0.5 * logdet + 0.5 * d * LOG_2PI
    )[None, :]


def gaussian_combine_pairs(f, g, *, block_b: int = 256, interpret: bool = False):
    """One round of pairwise information-form combines over a flat batch.

    f, g: edge 6-tuples ``(J11, J12, J22, h1, h2, c)`` with ONE leading batch
    dim N and a uniform square state width d — matrices (N, d, d), info
    vectors (N, d), scalar (N,). Returns the combined 6-tuple, each pair's
    shared middle variable integrated out. `kernels/ops.gaussian_combine`
    adds general batch dims, ragged widths and backend dispatch.

    N is padded to a multiple of ``block_b``; pad entries get M = I (so the
    in-kernel inversion stays finite) and are sliced away on return.
    """
    fJ11 = jnp.asarray(f[0], jnp.float32)
    N, d = fJ11.shape[0], fJ11.shape[-1]
    bb = min(block_b, max(N, 1))
    Np = -(-max(N, 1) // bb) * bb

    half_eye = 0.5 * jnp.eye(d, dtype=jnp.float32)

    def prep(x, kind, diag_pad):
        x = jnp.asarray(x, jnp.float32)
        if Np != N:
            pad_shape = (Np - N,) + x.shape[1:]
            pad = jnp.broadcast_to(half_eye, pad_shape) if diag_pad else jnp.zeros(pad_shape)
            x = jnp.concatenate([x, pad], axis=0)
        if kind == "mat":                               # (Np, d, d) -> (d, d, Np)
            return jnp.transpose(x, (1, 2, 0))
        if kind == "vec":                               # (Np, d) -> (d, Np)
            return jnp.transpose(x, (1, 0))
        return x[None, :]                               # (Np,) -> (1, Np)

    # M = F.J22 + G.J11 on pad entries must be invertible: pad each with I/2
    kinds = ("mat", "mat", "mat", "vec", "vec", "sc")
    inputs = [prep(x, k, False) for x, k in zip(f[:2], kinds[:2])]
    inputs.append(prep(f[2], "mat", True))
    inputs += [prep(x, k, False) for x, k in zip(f[3:], kinds[3:])]
    inputs.append(prep(g[0], "mat", True))
    inputs += [prep(x, k, False) for x, k in zip(g[1:], kinds[1:])]

    mat = jax.ShapeDtypeStruct((d, d, Np), jnp.float32)
    vec = jax.ShapeDtypeStruct((d, Np), jnp.float32)
    sc = jax.ShapeDtypeStruct((1, Np), jnp.float32)
    mat_spec = pl.BlockSpec((d, d, bb), lambda i: (0, 0, i))
    vec_spec = pl.BlockSpec((d, bb), lambda i: (0, i))
    sc_spec = pl.BlockSpec((1, bb), lambda i: (0, i))
    specs = [mat_spec, mat_spec, mat_spec, vec_spec, vec_spec, sc_spec]

    out = pl.pallas_call(
        functools.partial(_gaussian_combine_kernel, d=d),
        grid=(Np // bb,),
        in_specs=specs + specs,
        out_specs=tuple(specs),
        out_shape=(mat, mat, mat, vec, vec, sc),
        compiler_params=pltpu.TPUCompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*inputs)

    def unprep(x, kind):
        if kind == "mat":
            return jnp.transpose(x, (2, 0, 1))[:N]
        if kind == "vec":
            return jnp.transpose(x, (1, 0))[:N]
        return x[0, :N]

    return tuple(unprep(x, k) for x, k in zip(out, kinds))
