"""Fused Categorical(logits).log_prob(token) — Pallas TPU kernel.

This is the paper-specific hot spot (DESIGN.md §7): every LM observe site
evaluates log_softmax(logits)[token] over vocabularies up to 256,000. The
naive path materializes the full (B, S, V) log-prob tensor in HBM; this
kernel streams vocab blocks through VMEM with an online logsumexp (the
flash-softmax trick applied to the PPL's density evaluation) and gathers the
target logit on the fly — HBM traffic drops from 2x(B,S,V) to 1x(B,S,V)
reads + (B,S) writes, and nothing (B,S,V)-sized is ever written.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _logprob_kernel(logits_ref, tokens_ref, o_ref, m_ref, s_ref, t_ref, *,
                    bt: int, bv: int, n_v: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = logits_ref[...].astype(jnp.float32)  # (bt, bv)
    tok = tokens_ref[...][:, 0]              # (bt,)

    # online logsumexp
    m_prev, s_prev = m_ref[...], s_ref[...]
    m_cur = jnp.max(x, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    s_ref[...] = s_prev * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new[:, None]), axis=-1
    )
    m_ref[...] = m_new

    # gather the target logit if it falls in this vocab block
    col = iv * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    hit = col == tok[:, None]
    t_ref[...] = t_ref[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)

    @pl.when(iv == n_v - 1)
    def _finalize():
        o_ref[...] = (t_ref[...] - (m_ref[...] + jnp.log(s_ref[...])))[:, None]


def categorical_logprob_flat(
    logits: jax.Array,  # (T, V)
    tokens: jax.Array,  # (T,) int32
    *,
    block_t: int = 256,
    block_v: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    T, V = logits.shape
    bt = min(block_t, T)
    bv = min(block_v, V)
    # pad: T to a block multiple (dummy rows), V with NEG_INF columns
    Tp, Vp = -(-T // bt) * bt, -(-V // bv) * bv
    if Tp != T or Vp != V:
        logits = jnp.pad(logits, ((0, Tp - T), (0, Vp - V)), constant_values=NEG_INF)
        tokens = jnp.pad(tokens, (0, Tp - T))
    n_v = Vp // bv
    grid = (Tp // bt, n_v)

    out = pl.pallas_call(
        functools.partial(_logprob_kernel, bt=bt, bv=bv, n_v=n_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bv), lambda it, iv: (it, iv)),
            pl.BlockSpec((bt, 1), lambda it, iv: (it, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda it, iv: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),  # running max
            pltpu.VMEM((bt,), jnp.float32),  # running sum
            pltpu.VMEM((bt,), jnp.float32),  # target logit
        ],
        compiler_params=pltpu.TPUCompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(logits, tokens[:, None].astype(jnp.int32))
    return out[:T, 0]
