"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,H,Sq,d), k/v: (B,K,Skv,d) with H % K == 0. f32 softmax."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    g = H // K
    qg = q.reshape(B, K, g, Sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), Skv - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)


def categorical_logprob_ref(logits, tokens) -> jax.Array:
    """logits: (..., V) f32/bf16; tokens: (...) int32. Returns (...) f32:
    log_softmax(logits)[token] — the LM observe-site hot spot."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tok = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return tok - lse


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int) -> jax.Array:
    """Mamba-2 SSD (see models/ssm.ssd_reference; re-exported here so kernel
    tests depend only on kernels.ref)."""
    from ..models.ssm import ssd_reference

    return ssd_reference(x, dt, A, B, C, chunk)
