"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,H,Sq,d), k/v: (B,K,Skv,d) with H % K == 0. f32 softmax."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    g = H // K
    qg = q.reshape(B, K, g, Sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), Skv - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)


def categorical_logprob_ref(logits, tokens) -> jax.Array:
    """logits: (..., V) f32/bf16; tokens: (...) int32. Returns (...) f32:
    log_softmax(logits)[token] — the LM observe-site hot spot."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tok = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return tok - lse


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int) -> jax.Array:
    """Mamba-2 SSD (see models/ssm.ssd_reference; re-exported here so kernel
    tests depend only on kernels.ref)."""
    from ..models.ssm import ssd_reference

    return ssd_reference(x, dt, A, B, C, chunk)


def semiring_matmul_ref(a, b, *, semiring: str = "logsumexp") -> jax.Array:
    """Log-space semiring matmul: out[..., i, j] = ⊕_k a[..., i, k] + b[..., k, j]
    with ⊕ = logsumexp (sum-product) or max (max-product). Batch dims broadcast.

    The sum-product form uses the shifted-exponential identity
    ``logsumexp_k(a+b) = am + bm + log(exp(a-am) @ exp(b-bm))`` so the inner
    loop is a real matmul instead of a materialized (..., M, K, N) broadcast —
    algebraically identical, and the shift keeps it overflow-safe (this is the
    same rewrite the Pallas kernel uses per tile). Max-plus has no matmul
    identity and keeps the broadcast form.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if semiring == "max":
        return jnp.max(a[..., :, :, None] + b[..., None, :, :], axis=-2)
    if semiring != "logsumexp":
        raise ValueError(f"unknown semiring {semiring!r}")
    am = jnp.max(a, axis=-1, keepdims=True)  # (..., M, 1)
    bm = jnp.max(b, axis=-2, keepdims=True)  # (..., 1, N)
    am_s = jnp.where(jnp.isfinite(am), am, 0.0)  # fully -inf rows stay -inf, not nan
    bm_s = jnp.where(jnp.isfinite(bm), bm, 0.0)
    p = jnp.einsum("...mk,...kn->...mn", jnp.exp(a - am_s), jnp.exp(b - bm_s))
    return jnp.log(p) + am_s + bm_s


def leapfrog_ref(z, r, inv_mass, step_size, num_steps, potential_fn, *, max_steps):
    """Batched leapfrog oracle for `ops.leapfrog`, in the textbook
    two-half-kicks-per-step form (deliberately *not* the fused kernel's
    shared-gradient rewrite, so parity tests compare independent algebra).

    z, r, inv_mass: (C, D); step_size: (C,) (sign = integration direction);
    num_steps: (C,) int (0 = chain frozen, position/momentum pass through).
    Runs `min(max(num_steps), max_steps)` masked iterations; returns
    (z', r', potential(z')).
    """
    vg = jax.vmap(jax.value_and_grad(potential_fn))
    eps = step_size[:, None].astype(jnp.float32)
    n = num_steps[:, None].astype(jnp.int32)
    nmax = jnp.minimum(jnp.max(n), max_steps)

    def cond(carry):
        return carry[0] < nmax

    def body(carry):
        i, z, r = carry
        active = i < n  # (C, 1)
        _, g = vg(z)
        r2 = r - 0.5 * eps * g
        z2 = z + eps * inv_mass * r2
        _, g2 = vg(z2)
        r2 = r2 - 0.5 * eps * g2
        z = jnp.where(active, z2, z)
        r = jnp.where(active, r2, r)
        return (i + 1, z, r)

    _, z, r = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), z, r))
    pe, _ = vg(z)
    return z, r, pe


def hmm_scan_ref(factors, *, semiring: str = "logsumexp") -> jax.Array:
    """Sequential left-fold oracle for `ops.hmm_scan`: the ordered semiring
    product F_0 ⊗ F_1 ⊗ ... ⊗ F_{T-1} of a (..., T, K, K) stack of log-factors,
    one pairwise `semiring_matmul_ref` at a time (O(T) depth — the allclose
    target for the O(log T) associative-tree path)."""
    out = factors[..., 0, :, :]
    for t in range(1, factors.shape[-3]):
        out = semiring_matmul_ref(out, factors[..., t, :, :], semiring=semiring)
    return out
